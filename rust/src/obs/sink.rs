//! Chrome `trace_event` export: serialize a drained trace as the JSON
//! array format `about:tracing` / Perfetto load directly, plus a
//! pure-Rust validator (over [`runtime::json`](crate::runtime::json))
//! the smoke tests use to keep the artifact well-formed without new
//! dependencies.

use super::SpanRecord;
use crate::runtime::json::Json;
use std::collections::BTreeSet;
use std::path::Path;

/// Serialize records as a Chrome trace: one complete (`"ph": "X"`) event
/// per span, microsecond timestamps, `pid` = trace id (one lane per
/// request), `tid` = recorder thread id. Load the file in
/// `chrome://tracing` or <https://ui.perfetto.dev>.
pub fn chrome_json(records: &[SpanRecord]) -> String {
    let mut out = String::with_capacity(64 + records.len() * 96);
    out.push_str("[\n");
    for (i, r) in records.iter().enumerate() {
        out.push_str(&format!(
            "{{\"name\": \"{}\", \"cat\": \"stage\", \"ph\": \"X\", \"ts\": {:.3}, \"dur\": {:.3}, \"pid\": {}, \"tid\": {}}}{}\n",
            r.stage.name(),
            r.start_ns as f64 / 1e3,
            r.dur_ns as f64 / 1e3,
            r.trace,
            r.thread,
            if i + 1 < records.len() { "," } else { "" },
        ));
    }
    out.push(']');
    out
}

/// Write [`chrome_json`] to `path`.
pub fn write_chrome_json(path: &Path, records: &[SpanRecord]) -> std::io::Result<()> {
    std::fs::write(path, chrome_json(records))
}

/// Parse a Chrome-trace JSON string and return the set of stage names it
/// contains, or an error describing how it is malformed (missing/mistyped
/// event fields included). The smoke tests assert mandatory stages
/// against the returned set.
pub fn validate_chrome_json(text: &str) -> Result<BTreeSet<String>, String> {
    let parsed = Json::parse(text)?;
    let events = parsed.as_arr().ok_or("trace root must be a JSON array")?;
    let mut names = BTreeSet::new();
    for (i, ev) in events.iter().enumerate() {
        let name = ev
            .get("name")
            .and_then(Json::as_str)
            .ok_or_else(|| format!("event {i}: missing string \"name\""))?;
        let ph = ev
            .get("ph")
            .and_then(Json::as_str)
            .ok_or_else(|| format!("event {i}: missing string \"ph\""))?;
        if ph != "X" {
            return Err(format!("event {i}: expected complete event \"X\", got {ph:?}"));
        }
        for key in ["ts", "dur", "pid", "tid"] {
            let v = ev
                .get(key)
                .and_then(Json::as_f64)
                .ok_or_else(|| format!("event {i}: missing numeric \"{key}\""))?;
            if !v.is_finite() || v < 0.0 {
                return Err(format!("event {i}: non-finite or negative \"{key}\""));
            }
        }
        names.insert(name.to_string());
    }
    Ok(names)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::Stage;

    fn rec(stage: Stage, trace: u64, start: u64, dur: u64) -> SpanRecord {
        SpanRecord { stage, trace, thread: 3, depth: 0, start_ns: start, dur_ns: dur, self_ns: dur }
    }

    #[test]
    fn chrome_json_round_trips_through_the_validator() {
        let recs = vec![
            rec(Stage::Plan, 7, 1_500, 2_000),
            rec(Stage::OracleTile, 7, 4_000, 10_500),
            rec(Stage::SolveEig, 7, 20_000, 1),
        ];
        let text = chrome_json(&recs);
        let names = validate_chrome_json(&text).unwrap();
        assert_eq!(
            names.into_iter().collect::<Vec<_>>(),
            vec!["oracle.tile", "plan", "solve.eig"]
        );
        // microsecond conversion: 1500 ns -> 1.5 us
        assert!(text.contains("\"ts\": 1.500"));
    }

    #[test]
    fn empty_trace_is_a_valid_empty_array() {
        let text = chrome_json(&[]);
        assert_eq!(validate_chrome_json(&text).unwrap().len(), 0);
    }

    #[test]
    fn validator_rejects_malformed_traces() {
        assert!(validate_chrome_json("{\"not\": \"an array\"}").is_err());
        assert!(validate_chrome_json("[{\"name\": \"x\"}]").is_err());
        assert!(validate_chrome_json(
            "[{\"name\": \"x\", \"ph\": \"B\", \"ts\": 0, \"dur\": 0, \"pid\": 1, \"tid\": 1}]"
        )
        .is_err());
        assert!(validate_chrome_json("[").is_err());
    }
}
