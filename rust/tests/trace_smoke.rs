//! Pure-Rust trace smoke test (the `make trace-smoke` target, ISSUE 7):
//! serve one streamed and one resident-with-spill request through a
//! service configured with a `trace_dir`, then validate that the emitted
//! Chrome `trace_event` JSON parses and covers the mandatory stages —
//! the same check a human would do by loading the file in
//! `about:tracing`, minus the browser.
//!
//! One `#[test]` on purpose: trace ids are minted from a process-global
//! counter, and the leak check at the bottom relies on this process
//! minting sequentially.

use fastspsd::coordinator::oracle::RbfOracle;
use fastspsd::coordinator::{
    ApproxRequest, ApproxService, KernelOracle, MethodSpec, ServiceConfig,
};
use fastspsd::exec::ExecPolicy;
use fastspsd::linalg::Matrix;
use fastspsd::obs::{self, sink};
use fastspsd::sketch::SketchKind;
use fastspsd::util::Rng;
use std::collections::BTreeSet;
use std::path::PathBuf;
use std::sync::{mpsc, Arc};

fn fresh_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("fastspsd-smoke-{}-{name}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn serve_one(svc: &ApproxService, req: ApproxRequest) {
    let (tx, rx) = mpsc::channel();
    svc.submit(req, tx);
    svc.drain();
    let r = rx.iter().next().unwrap();
    assert!(r.error.is_none(), "request {} failed: {:?}", r.id, r.error);
    assert!(r.meta.unwrap().stage_profile.is_some(), "traced service annotates RunMeta");
}

fn stages_of(dir: &std::path::Path, id: u64) -> BTreeSet<String> {
    let path = dir.join(format!("trace-req-{id}.json"));
    let text = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("missing trace file {path:?}: {e}"));
    sink::validate_chrome_json(&text)
        .unwrap_or_else(|e| panic!("malformed chrome trace {path:?}: {e}"))
}

fn assert_covers(stages: &BTreeSet<String>, mandatory: &[&str], what: &str) {
    for name in mandatory {
        assert!(stages.contains(*name), "{what}: trace is missing stage {name}: {stages:?}");
    }
}

#[test]
fn traced_requests_emit_wellformed_chrome_json_covering_mandatory_stages() {
    let n = 96;
    let mut rng = Rng::new(3);
    let spill = fresh_dir("spill");
    let traces = fresh_dir("traces");
    let svc = ApproxService::new(
        Arc::new(RbfOracle::cpu(Arc::new(Matrix::randn(n, 6, &mut rng)), 0.5))
            as Arc<dyn KernelOracle + Send + Sync>,
        ServiceConfig {
            workers: 1,
            spill_dir: Some(spill.clone()),
            trace_dir: Some(traces.clone()),
            ..Default::default()
        },
    );

    // Request 0: the bounded double-buffered pipeline (streamed policy).
    serve_one(
        &svc,
        ApproxRequest {
            id: 0,
            method: MethodSpec::Fast { s: 24, kind: SketchKind::Uniform },
            c: 8,
            k: 3,
            seed: 1,
            policy: Some(ExecPolicy::streamed(16)),
            precision: fastspsd::stream::Precision::F64,
            deadline: None,
        },
    );
    let streamed = stages_of(&traces, 0);
    assert_covers(
        &streamed,
        &["admission.queue", "plan", "exec.run", "pipeline.produce", "pipeline.fold",
          "solve.svd", "solve.eig"],
        "streamed request",
    );

    // Request 1: residency at a zero RAM budget — two-pass leverage, so
    // every tile writes through the spill arena and reloads from it.
    serve_one(
        &svc,
        ApproxRequest {
            id: 1,
            method: MethodSpec::Fast { s: 24, kind: SketchKind::Leverage { scaled: false } },
            c: 8,
            k: 3,
            seed: 2,
            policy: Some(ExecPolicy::resident(0).with_tile_rows(16)),
            precision: fastspsd::stream::Precision::F64,
            deadline: None,
        },
    );
    let resident = stages_of(&traces, 1);
    assert_covers(
        &resident,
        &["admission.queue", "plan", "exec.run", "pipeline.produce", "pipeline.fold",
          "residency.spill_write", "residency.spill_read", "solve.eig"],
        "resident request",
    );

    // Unserved requests must not leak their spans into the central store.
    // Minting is sequential in this process, so the next submit's trace
    // id is exactly `probe + 1`.
    let capped = ApproxService::new(
        Arc::new(RbfOracle::cpu(Arc::new(Matrix::randn(n, 6, &mut Rng::new(4))), 0.5))
            as Arc<dyn KernelOracle + Send + Sync>,
        ServiceConfig { workers: 1, memory_cap: Some(1), ..Default::default() },
    );
    let probe = obs::TraceId::mint().raw();
    let (tx, rx) = mpsc::channel();
    capped.submit(
        ApproxRequest {
            id: 2,
            method: MethodSpec::Fast { s: 16, kind: SketchKind::Uniform },
            c: 8,
            k: 3,
            seed: 5,
            policy: None,
            precision: fastspsd::stream::Precision::F64,
            deadline: None,
        },
        tx,
    );
    let r = rx.iter().next().unwrap();
    assert!(r.error.is_some(), "a 1-byte cap must reject every rung");
    assert!(
        obs::drain_trace(probe + 1).is_empty(),
        "the rejected request's planning spans must be discarded, not leaked"
    );

    let _ = std::fs::remove_dir_all(&spill);
    let _ = std::fs::remove_dir_all(&traces);
}
