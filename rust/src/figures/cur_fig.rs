//! Figure 2: CUR decomposition of the (synthetic) 1920 x 1168 image with
//! c = r = 100, comparing the optimal U, the Drineas-08 U, and the fast U
//! at several (s_c, s_r) settings. Optionally writes PGM reconstructions.

use super::Ctx;
use crate::cli::Args;
use crate::cur::{self, FastCurConfig};
use crate::data::image;
use crate::exec::{self, ExecPolicy};
use crate::util::Rng;

pub fn fig2(ctx: &Ctx, args: &Args) {
    // Full-size by default; --rows/--cols shrink for quick runs.
    let rows = args.get_usize("rows", 1920);
    let cols = args.get_usize("cols", 1168);
    let c = args.get_usize("c", 100);
    let r = args.get_usize("r", 100);
    let a = image::synth_image(rows, cols, ctx.seed);
    let mut rng = Rng::new(ctx.seed + 1);
    let col_idx = cur::select_uniform(cols, c, &mut rng);
    let row_idx = cur::select_uniform(rows, r, &mut rng);

    let mut csv = ctx.csv("fig2.csv", "setting,s_c,s_r,rel_err,secs,entries_for_u");
    let mut emit = |label: &str, dec: &cur::CurDecomp, s_c: usize, s_r: usize| {
        let err = dec.rel_fro_error(&a);
        csv.row(&format!(
            "{label},{s_c},{s_r},{err:.6e},{:.4},{}",
            dec.build_secs, dec.entries_for_u
        ));
        if args.flag("pgm") {
            let path = ctx.out_dir.join(format!("fig2_{}.pgm", label.replace(['=', ','], "_")));
            let _ = image::write_pgm(&dec.materialize(), &path);
        }
        err
    };

    // (b) optimal U* = C† A R†
    let opt = cur::cur_optimal(&a, &col_idx, &row_idx);
    let e_opt = emit("optimal", &opt, rows, cols);
    // (c) Drineas08: U = (P_R^T A P_C)† — the degenerate fast model
    let dri = cur::cur_drineas08(&a, &col_idx, &row_idx);
    let e_dri = emit("drineas08", &dri, r, c);
    // (d)/(e) fast U with growing sketches
    let mut last_fast = f64::INFINITY;
    for f in [2usize, 4] {
        let cfg = FastCurConfig::uniform(f * r, f * c);
        let fast = exec::cur_fast(&a, &col_idx, &row_idx, cfg, &ExecPolicy::Materialized, &mut rng).result;
        last_fast = emit(&format!("fast_s{f}x"), &fast, f * r, f * c);
    }
    if args.flag("pgm") {
        let _ = image::write_pgm(&a, &ctx.out_dir.join("fig2_original.pgm"));
    }
    println!(
        "# fig2 shape check: optimal {e_opt:.3e} <= fast(4x) {last_fast:.3e} << drineas08 {e_dri:.3e}"
    );
    csv.finish();
}
