//! Streaming kernel pipeline: tiled oracle access with bounded memory.
//!
//! The paper's accounting (Table 3) bounds how many entries of `K` each
//! model *observes*; this module turns that into an actual *memory* bound.
//! A [`TileSource`] yields fixed-height row-tiles of `K[:, P]` (or of the
//! full `K`, or of a dense data matrix) and composable [`TileConsumer`]s
//! fold each tile as it arrives — sketch application `S^T C` for all five
//! sketch families, Gram accumulation `C^T C`, row gathers for `W` /
//! `C[S, :]`, and matvec/top-k Lanczos against the implicit approximation
//! `C U C^T` — so `spsd::fast`, `spsd::prototype`, `spsd::nystrom` and
//! `cur::cur_fast_streamed` run with peak *extra* memory
//! `O(tile_rows · c + s²)` (prototype: `O(tile_rows · n)`) instead of
//! materializing `n x c` panels or the full `n x n` matrix in one
//! allocation.
//!
//! [`pipeline::run_pipeline`] is the scheduler: a bounded double-buffered
//! queue where the producer computes tile `i+1` on the global thread pool
//! while the consumers fold tile `i` on the caller's thread, so at most
//! `queue_depth + 2` tiles are ever live.
//!
//! [`residency`] is the layer between multi-pass plans and the oracle: a
//! [`ResidentSource`] keeps hot tiles in a byte-budgeted LRU and writes
//! every computed tile through to a disk spill arena, so repeated-access
//! workloads (Lanczos matvecs in [`implicit`], the two-pass leverage plan,
//! repeated sketch folds over the same `C`) pay the kernel oracle exactly
//! once per tile — at any RAM budget, including zero.

pub mod checkpoint;
pub mod consumers;
pub mod implicit;
pub mod pipeline;
pub mod record;
pub mod residency;

pub use checkpoint::CheckpointConfig;
pub use consumers::{
    ColSubsetCollect, CollectConsumer, ConjugateFold, GramFold, LeverageFold, LeverageSampler,
    MatvecFold, PrototypeUFold, RowGather, SketchFold, TileConsumer,
};
pub use implicit::matvec_cuc;
// Deprecated per-policy shims, re-exported for compatibility — the
// policy-carrying surface is `exec::{top_k_eigs, solve_regularized}`.
#[allow(deprecated)]
pub use implicit::{
    solve_regularized, solve_regularized_budgeted, solve_regularized_resident, top_k_eigs,
    top_k_eigs_budgeted, top_k_eigs_resident,
};
pub use pipeline::{
    run_pipeline, run_pipeline_prec, run_pipeline_resumable, run_pipeline_validated,
    PipelineError, ValidateMode,
};
pub use record::RecordError;
pub use residency::{
    ResidencyConfig, ResidencyStats, ResidentSource, DEFAULT_RESIDENT_TILE_ROWS,
};

use crate::coordinator::oracle::KernelOracle;
use crate::linalg::Matrix;
pub use crate::linalg::{MatrixF32, Precision, Tile};
use crate::obs::{self, Stage};
use std::sync::Mutex;

/// How a build should traverse the kernel: one whole-matrix tile (the
/// materialized path, bit-compatible with the historical code) or
/// fixed-height row tiles through the double-buffered pipeline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StreamConfig {
    /// Rows per tile. `usize::MAX` (via [`StreamConfig::whole`]) means a
    /// single tile spanning all rows.
    pub tile_rows: usize,
    /// Bounded producer queue depth: tiles computed ahead of the consumer.
    /// Depth 2 double-buffers (compute tile i+1 while folding tile i).
    pub queue_depth: usize,
    /// Element width of the tiles the pipeline carries. Fold state stays
    /// f64 either way; `F32` halves tile bytes (queue, spill, panel cache)
    /// and runs the narrow gemm/oracle plane.
    pub precision: Precision,
    /// Tile quarantine: scan every produced tile for non-finite (or
    /// absurd-magnitude) values *before* any consumer folds it —
    /// `PipelineError::PoisonedTile` instead of NaNs silently saturating
    /// a Gram/sketch accumulator. `Off` (the default) costs one branch
    /// per tile.
    pub validate: ValidateMode,
}

/// Default queue depth for tiled streams (double buffering + one in hand).
pub const DEFAULT_QUEUE_DEPTH: usize = 2;

impl StreamConfig {
    /// Stream in `tile_rows`-high tiles with the default queue depth.
    pub fn tiled(tile_rows: usize) -> Self {
        StreamConfig {
            tile_rows: tile_rows.max(1),
            queue_depth: DEFAULT_QUEUE_DEPTH,
            precision: Precision::F64,
            validate: ValidateMode::Off,
        }
    }

    /// One tile covering every row — the materialized path.
    pub fn whole() -> Self {
        StreamConfig {
            tile_rows: usize::MAX,
            queue_depth: 1,
            precision: Precision::F64,
            validate: ValidateMode::Off,
        }
    }

    /// Same traversal, tiles carried at `precision`.
    pub fn with_precision(mut self, precision: Precision) -> Self {
        self.precision = precision;
        self
    }

    /// Same traversal, tiles scanned per `validate` before folding.
    pub fn with_validate(mut self, validate: ValidateMode) -> Self {
        self.validate = validate;
        self
    }

    /// True when this config degenerates to the materialized path for an
    /// `n`-row stream.
    pub fn is_whole(&self, n: usize) -> bool {
        self.tile_rows >= n
    }

    /// The concrete tile height an `n`-row pipeline pass will use (the
    /// clamp [`run_pipeline`] applies) — also the grid the residency layer
    /// should cache at so requests align with cached tiles.
    pub fn effective_tile_rows(&self, n: usize) -> usize {
        self.tile_rows.clamp(1, n.max(1))
    }
}

impl Default for StreamConfig {
    fn default() -> Self {
        StreamConfig::whole()
    }
}

/// Bytes a `rows x cols` f64 panel occupies — the unit every budget gate
/// in this module shares (the planner's `memory_budget`, the
/// [`CachingSource`] whole-panel gate, the residency layer's LRU budget
/// and per-tile admission). Budgets are denominated in this f64 unit;
/// narrow tiles charge against them via [`panel_bytes_prec`].
pub fn panel_bytes(rows: usize, cols: usize) -> u64 {
    panel_bytes_prec(rows, cols, Precision::F64)
}

/// Bytes a `rows x cols` panel occupies at the given element width — the
/// width-aware sibling of [`panel_bytes`] used wherever f32 tiles earn
/// their halved footprint (residency admission/spill, planner peak).
pub fn panel_bytes_prec(rows: usize, cols: usize, prec: Precision) -> u64 {
    (rows as u64)
        .saturating_mul(cols as u64)
        .saturating_mul(prec.bytes() as u64)
}

/// The one budget gate for cached-panel modes: a panel is admitted
/// resident only when it fits `budget` whole. [`CachingSource`] and the
/// budgeted implicit ops both go through here, so the gate can never
/// drift between them.
pub fn panel_fits_budget(rows: usize, cols: usize, budget: u64) -> bool {
    rows > 0 && panel_bytes(rows, cols) <= budget
}

/// A virtual matrix that can be read in contiguous row-tiles. The streaming
/// pipeline never holds more than a bounded number of tiles alive.
pub trait TileSource: Sync {
    /// Total rows of the virtual matrix.
    fn rows(&self) -> usize;

    /// Columns of every tile.
    fn cols(&self) -> usize;

    /// Rows `[r0, r1)` as a dense `(r1-r0) x cols` tile.
    fn tile(&self, r0: usize, r1: usize) -> Matrix;

    /// Rows `[r0, r1)` at f32 width. The default computes the f64 tile and
    /// demotes — always correct, never faster; sources backed by a kernel
    /// oracle override it to compute natively narrow.
    fn tile_f32(&self, r0: usize, r1: usize) -> MatrixF32 {
        self.tile(r0, r1).demote()
    }

    /// Width-dispatched tile — what [`run_pipeline_prec`] calls.
    fn tile_elem(&self, r0: usize, r1: usize, prec: Precision) -> Tile {
        match prec {
            Precision::F64 => Tile::F64(self.tile(r0, r1)),
            Precision::F32 => Tile::F32(self.tile_f32(r0, r1)),
        }
    }
}

/// `K[:, cols]` served tile-wise by a [`KernelOracle`] (the `C` panel of
/// every SPSD model).
pub struct OracleColumnsSource<'a> {
    oracle: &'a dyn KernelOracle,
    cols: &'a [usize],
}

impl<'a> OracleColumnsSource<'a> {
    pub fn new(oracle: &'a dyn KernelOracle, cols: &'a [usize]) -> Self {
        OracleColumnsSource { oracle, cols }
    }
}

impl TileSource for OracleColumnsSource<'_> {
    fn rows(&self) -> usize {
        self.oracle.n()
    }

    fn cols(&self) -> usize {
        self.cols.len()
    }

    fn tile(&self, r0: usize, r1: usize) -> Matrix {
        let _s = obs::span(Stage::OracleTile);
        self.oracle.row_block(r0, r1, self.cols)
    }

    fn tile_f32(&self, r0: usize, r1: usize) -> MatrixF32 {
        let _s = obs::span(Stage::OracleTile);
        self.oracle.row_block_f32(r0, r1, self.cols)
    }
}

/// The full `K[:, :]` served tile-wise (prototype model / projection
/// sketches — the paths that must observe all `n²` entries but no longer
/// need to *store* them).
pub struct OracleFullSource<'a> {
    oracle: &'a dyn KernelOracle,
}

impl<'a> OracleFullSource<'a> {
    pub fn new(oracle: &'a dyn KernelOracle) -> Self {
        OracleFullSource { oracle }
    }
}

impl TileSource for OracleFullSource<'_> {
    fn rows(&self) -> usize {
        self.oracle.n()
    }

    fn cols(&self) -> usize {
        self.oracle.n()
    }

    fn tile(&self, r0: usize, r1: usize) -> Matrix {
        let _s = obs::span(Stage::OracleTile);
        self.oracle.full_rows(r0, r1)
    }

    fn tile_f32(&self, r0: usize, r1: usize) -> MatrixF32 {
        let _s = obs::span(Stage::OracleTile);
        self.oracle.full_rows_f32(r0, r1)
    }
}

/// Row-tiles of an in-memory dense matrix, optionally restricted to a
/// column subset — the CUR path, and the stand-in for a dataset-on-disk
/// source (the tile interface is what a spill-to-disk backend would
/// implement; see ROADMAP "Open items").
pub struct MatrixSource<'a> {
    a: &'a Matrix,
    cols: Option<&'a [usize]>,
}

impl<'a> MatrixSource<'a> {
    pub fn new(a: &'a Matrix) -> Self {
        MatrixSource { a, cols: None }
    }

    pub fn with_cols(a: &'a Matrix, cols: &'a [usize]) -> Self {
        MatrixSource { a, cols: Some(cols) }
    }
}

impl TileSource for MatrixSource<'_> {
    fn rows(&self) -> usize {
        self.a.rows()
    }

    fn cols(&self) -> usize {
        self.cols.map_or(self.a.cols(), |c| c.len())
    }

    fn tile(&self, r0: usize, r1: usize) -> Matrix {
        match self.cols {
            None => self.a.block(r0, r1, 0, self.a.cols()),
            Some(cols) => {
                Matrix::from_fn(r1 - r0, cols.len(), |i, j| self.a[(r0 + i, cols[j])])
            }
        }
    }
}

/// Budget-gated cached-`C` wrapper for the re-streaming implicit ops
/// (`stream::implicit` recomputes `C`'s kernel tiles on every Lanczos
/// matvec): the first sequential pass stores tiles into a resident panel;
/// once every row has been seen, later passes slice memory instead of
/// recomputing kernel tiles. Caching engages only when the whole
/// `rows x cols` panel fits within `memory_budget` bytes — the same unit
/// as the planner's [`Goal::memory_budget`](crate::coordinator::planner::Goal)
/// — otherwise `tile` is a pure passthrough and peak memory is unchanged.
pub struct CachingSource<'a> {
    inner: &'a dyn TileSource,
    cache: Mutex<CacheState>,
    enabled: bool,
}

struct CacheState {
    buf: Matrix,
    /// Rows `[0, filled)` of `buf` hold valid data. Pipeline passes visit
    /// tiles as an ascending contiguous prefix, so one high-water mark
    /// suffices; out-of-order requests simply bypass the fill.
    filled: usize,
}

impl<'a> CachingSource<'a> {
    pub fn new(inner: &'a dyn TileSource, memory_budget: u64) -> Self {
        let enabled = panel_fits_budget(inner.rows(), inner.cols(), memory_budget);
        let buf = if enabled {
            Matrix::zeros(inner.rows(), inner.cols())
        } else {
            Matrix::zeros(0, 0)
        };
        CachingSource { inner, cache: Mutex::new(CacheState { buf, filled: 0 }), enabled }
    }

    /// Whether the budget admitted the cache at all.
    pub fn cache_enabled(&self) -> bool {
        self.enabled
    }

    /// True once the whole panel is resident (subsequent passes are free).
    pub fn fully_cached(&self) -> bool {
        self.enabled && self.cache.lock().unwrap().filled == self.inner.rows()
    }
}

impl TileSource for CachingSource<'_> {
    fn rows(&self) -> usize {
        self.inner.rows()
    }

    fn cols(&self) -> usize {
        self.inner.cols()
    }

    fn tile(&self, r0: usize, r1: usize) -> Matrix {
        if !self.enabled {
            return self.inner.tile(r0, r1);
        }
        {
            let st = self.cache.lock().unwrap();
            if r1 <= st.filled {
                let w = st.buf.cols();
                return st.buf.block(r0, r1, 0, w);
            }
        }
        // compute outside the lock — kernel tiles can be expensive
        let t = self.inner.tile(r0, r1);
        let mut st = self.cache.lock().unwrap();
        if r0 <= st.filled && r1 > st.filled {
            // extends the contiguous prefix: keep it
            for i in st.filled.max(r0)..r1 {
                st.buf.row_mut(i).copy_from_slice(t.row(i - r0));
            }
            st.filled = r1;
        }
        t
    }
}

/// Adapter wrapping any [`KernelOracle`] with a stream configuration: the
/// entry point the streamed model builders use. It is itself a
/// [`KernelOracle`] (pure delegation), so it drops into every existing
/// call site, and adds the tile-pipeline verbs.
pub struct StreamingOracle<'a> {
    pub oracle: &'a dyn KernelOracle,
    pub cfg: StreamConfig,
}

impl<'a> StreamingOracle<'a> {
    pub fn new(oracle: &'a dyn KernelOracle, cfg: StreamConfig) -> Self {
        StreamingOracle { oracle, cfg }
    }

    /// Stream `K[:, cols]` through `consumers` (in tile order, each tile
    /// fed to every consumer before the next arrives) at the configured
    /// element width.
    pub fn stream_columns(&self, cols: &[usize], consumers: &mut [&mut dyn TileConsumer]) {
        let src = OracleColumnsSource::new(self.oracle, cols);
        run_pipeline_validated(
            &src,
            self.cfg.tile_rows,
            self.cfg.queue_depth,
            self.cfg.precision,
            self.cfg.validate,
            consumers,
        )
        .unwrap_or_else(|e| panic!("{e}"));
    }

    /// Stream the full `K` through `consumers` at the configured width.
    pub fn stream_full(&self, consumers: &mut [&mut dyn TileConsumer]) {
        let src = OracleFullSource::new(self.oracle);
        run_pipeline_validated(
            &src,
            self.cfg.tile_rows,
            self.cfg.queue_depth,
            self.cfg.precision,
            self.cfg.validate,
            consumers,
        )
        .unwrap_or_else(|e| panic!("{e}"));
    }
}

impl KernelOracle for StreamingOracle<'_> {
    fn n(&self) -> usize {
        self.oracle.n()
    }

    fn block(&self, rows: &[usize], cols: &[usize]) -> Matrix {
        self.oracle.block(rows, cols)
    }

    fn row_block(&self, r0: usize, r1: usize, cols: &[usize]) -> Matrix {
        self.oracle.row_block(r0, r1, cols)
    }

    fn full_rows(&self, r0: usize, r1: usize) -> Matrix {
        self.oracle.full_rows(r0, r1)
    }

    fn entries_observed(&self) -> u64 {
        self.oracle.entries_observed()
    }

    fn reset_entries(&self) {
        self.oracle.reset_entries()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::oracle::DenseOracle;
    use crate::util::Rng;

    #[test]
    fn matrix_source_tiles_roundtrip() {
        let mut rng = Rng::new(0);
        let a = Matrix::randn(13, 6, &mut rng);
        let src = MatrixSource::new(&a);
        assert_eq!((src.rows(), src.cols()), (13, 6));
        let mut collect = CollectConsumer::new(13, 6);
        run_pipeline(&src, 4, 2, &mut [&mut collect]);
        assert_eq!(collect.into_matrix().max_abs_diff(&a), 0.0);

        let cols = [1usize, 4, 5];
        let srcc = MatrixSource::with_cols(&a, &cols);
        assert_eq!(srcc.cols(), 3);
        let t = srcc.tile(2, 5);
        for i in 0..3 {
            for (j, &cc) in cols.iter().enumerate() {
                assert_eq!(t[(i, j)], a[(2 + i, cc)]);
            }
        }
    }

    #[test]
    fn oracle_sources_match_block_access() {
        let mut rng = Rng::new(1);
        let g = Matrix::randn(11, 11, &mut rng);
        let k = g.matmul_tr(&g);
        let o = DenseOracle::new(k.clone());
        let cols = [0usize, 3, 7];
        let src = OracleColumnsSource::new(&o, &cols);
        let t = src.tile(4, 9);
        for i in 0..5 {
            for (j, &cc) in cols.iter().enumerate() {
                assert_eq!(t[(i, j)], k[(4 + i, cc)]);
            }
        }
        let full = OracleFullSource::new(&o);
        assert_eq!(full.tile(0, 11).max_abs_diff(&k), 0.0);
    }

    #[test]
    fn streaming_oracle_delegates_and_streams() {
        let mut rng = Rng::new(2);
        let g = Matrix::randn(17, 17, &mut rng);
        let k = g.matmul_tr(&g);
        let o = DenseOracle::new(k.clone());
        let so = StreamingOracle::new(&o, StreamConfig::tiled(5));
        assert_eq!(so.n(), 17);
        let cols = [2usize, 8, 13, 16];
        let mut collect = CollectConsumer::new(17, 4);
        so.stream_columns(&cols, &mut [&mut collect]);
        let c = collect.into_matrix();
        assert_eq!(c.max_abs_diff(&o.columns(&cols)), 0.0);
        // entries accounting flows through the adapter
        assert!(so.entries_observed() >= 17 * 4);
        so.reset_entries();
        assert_eq!(so.entries_observed(), 0);
    }

    #[test]
    fn caching_source_serves_later_passes_from_memory() {
        use crate::coordinator::oracle::RbfOracle;
        use std::sync::Arc;
        let mut rng = Rng::new(5);
        let x = Arc::new(Matrix::randn(40, 4, &mut rng));
        let o = RbfOracle::cpu(x, 0.5);
        let cols = [1usize, 5, 9];
        let src = OracleColumnsSource::new(&o, &cols);
        let cached = CachingSource::new(&src, u64::MAX);
        assert!(cached.cache_enabled());
        let mut c1 = CollectConsumer::new(40, 3);
        run_pipeline(&cached, 8, 2, &mut [&mut c1]);
        let after_first = o.entries_observed();
        assert!(cached.fully_cached(), "one full pass must fill the cache");
        // second pass (different tile height): zero new kernel entries,
        // bit-identical tiles
        let mut c2 = CollectConsumer::new(40, 3);
        run_pipeline(&cached, 13, 2, &mut [&mut c2]);
        assert_eq!(o.entries_observed(), after_first, "cached pass re-observed the oracle");
        assert_eq!(c1.into_matrix().max_abs_diff(&c2.into_matrix()), 0.0);

        // budget below the panel: pure passthrough, entries keep accruing
        let strict = CachingSource::new(&src, 39 * 3 * 8);
        assert!(!strict.cache_enabled());
        let before = o.entries_observed();
        let mut c3 = CollectConsumer::new(40, 3);
        run_pipeline(&strict, 8, 2, &mut [&mut c3]);
        assert!(o.entries_observed() > before);
        assert!(!strict.fully_cached());
    }

    #[test]
    fn stream_config_whole_detection() {
        assert!(StreamConfig::whole().is_whole(10));
        assert!(StreamConfig::tiled(10).is_whole(10));
        assert!(StreamConfig::tiled(11).is_whole(10));
        assert!(!StreamConfig::tiled(9).is_whole(10));
        assert_eq!(StreamConfig::tiled(0).tile_rows, 1);
    }

    #[test]
    fn precision_knob_and_width_aware_panel_bytes() {
        // Constructors default to the bit-compat f64 plane.
        assert_eq!(StreamConfig::tiled(8).precision, Precision::F64);
        assert_eq!(StreamConfig::whole().precision, Precision::F64);
        let cfg = StreamConfig::tiled(8).with_precision(Precision::F32);
        assert_eq!(cfg.precision, Precision::F32);
        assert_eq!(cfg.tile_rows, 8);
        assert_eq!(cfg.validate, ValidateMode::Off, "validation is opt-in");
        assert_eq!(
            StreamConfig::whole().with_validate(ValidateMode::NonFinite).validate,
            ValidateMode::NonFinite
        );
        // f32 panels charge exactly half the f64 unit.
        assert_eq!(panel_bytes(100, 7), 100 * 7 * 8);
        assert_eq!(panel_bytes_prec(100, 7, Precision::F32), 100 * 7 * 4);
        assert_eq!(panel_bytes_prec(100, 7, Precision::F64), panel_bytes(100, 7));
    }

    #[test]
    fn oracle_sources_serve_native_f32_tiles() {
        use crate::coordinator::oracle::RbfOracle;
        use std::sync::Arc;
        let mut rng = Rng::new(6);
        let x = Arc::new(Matrix::randn(21, 3, &mut rng));
        let o = RbfOracle::cpu(x, 0.5);
        let cols = [0usize, 7, 20];
        let src = OracleColumnsSource::new(&o, &cols);
        let narrow = src.tile_f32(3, 12);
        let wide = src.tile(3, 12);
        assert_eq!((narrow.rows(), narrow.cols()), (9, 3));
        for i in 0..9 {
            for j in 0..3 {
                assert!((wide[(i, j)] - narrow.row(i)[j] as f64).abs() < 1e-4);
            }
        }
        match src.tile_elem(3, 12, Precision::F32) {
            Tile::F32(t) => assert_eq!(t.data(), narrow.data()),
            Tile::F64(_) => panic!("wrong width"),
        }
    }
}
