//! `repro` — CLI entrypoint. Subcommands regenerate every figure and table
//! of the paper (see DESIGN.md §4) plus an end-to-end serving demo; run
//! with no arguments for usage.
fn main() {
    fastspsd::figures::run_cli();
}
