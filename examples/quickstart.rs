//! Quickstart: approximate an RBF kernel matrix three ways and compare.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use fastspsd::coordinator::{oracle::KernelOracle, KernelEngine, RbfOracle};
use fastspsd::data::{make_blobs, sigma};
use fastspsd::exec::{self, ExecPolicy};
use fastspsd::spsd::{self, FastConfig};
use fastspsd::util::Rng;
use std::sync::Arc;

fn main() {
    // 1. A small dataset and its RBF kernel oracle (blocks computed on
    //    demand through the PJRT engine when artifacts are present).
    let ds = make_blobs("quickstart", 1200, 16, 6, 2.0, 7);
    let n = ds.x.rows();
    let sig = sigma::calibrate_sigma(&ds.x, 0.9, 400, 7);
    let gamma = sigma::gamma_of_sigma(sig);
    let engine = Arc::new(KernelEngine::auto());
    println!(
        "n={n}, sigma={sig:.3} (eta=0.9), engine={}",
        if engine.is_pjrt() { "PJRT" } else { "pure-rust" }
    );
    let oracle = RbfOracle::new(Arc::new(ds.x.clone()), gamma, engine);

    // 2. Sample c columns; build the three models of the paper.
    let mut rng = Rng::new(0);
    let c = 24;
    let s = 8 * c;
    let p = spsd::uniform_p(n, c, &mut rng);

    let kfull = oracle.full(); // only for error reporting
    let kf = kfull.fro_norm_sq();
    println!("\n{:<22} {:>12} {:>14} {:>10}", "method", "rel error", "entries of K", "build s");
    let pol = ExecPolicy::Materialized;
    for (name, approx) in [
        ("nystrom", exec::nystrom(&oracle, &p, &pol).result),
        ("fast (s=8c, uniform)", {
            oracle.reset_entries();
            exec::fast(&oracle, &p, FastConfig::uniform(s), &pol, &mut rng).result
        }),
        ("prototype", {
            oracle.reset_entries();
            exec::prototype(&oracle, &p, &pol).result
        }),
    ] {
        let err = kfull.sub(&approx.materialize()).fro_norm_sq() / kf;
        println!(
            "{:<22} {:>12.4e} {:>14} {:>10.3}",
            name, err, approx.entries_observed, approx.build_secs
        );
    }

    // 3. Downstream use without ever materializing K: top-5 eigenpairs and
    //    a regularized solve, both O(n c^2).
    oracle.reset_entries();
    let mut rng2 = Rng::new(1);
    let approx = exec::fast(&oracle, &p, FastConfig::uniform(s), &pol, &mut rng2).result;
    let (vals, _vecs) = approx.eig_k(5);
    println!("\ntop-5 eigenvalues via fast model: {vals:?}");
    let y: Vec<f64> = (0..n).map(|i| (i as f64 * 0.01).sin()).collect();
    let w = approx.solve_regularized(1.0, &y);
    println!("solved (K̃ + I) w = y; ||w|| = {:.4}", w.iter().map(|x| x * x).sum::<f64>().sqrt());
    println!("entries observed for all of the above: {} (n^2 = {})", approx.entries_observed, n * n);
}
