//! Checksummed on-disk record codec shared by the spill arena
//! ([`residency`](super::residency)) and the pipeline checkpoint files
//! ([`checkpoint`](super::checkpoint)).
//!
//! Every record is
//!
//! ```text
//! [ 1-byte width tag | 8-byte LE XXH64 digest of payload | payload ]
//! ```
//!
//! The tag is the element width in bytes (8 = f64, 4 = f32) so a reader
//! configured for one width never reinterprets the other's bytes; the
//! digest (seeded by the tag, so a payload cannot validate under the
//! wrong width) catches bit rot, torn writes, and buggy IO paths on
//! read-back. Integrity failures are *typed* ([`RecordError`]) — the
//! residency layer turns them into `corrupt_reads` + recompute, the
//! checkpoint loader into restart-from-zero; neither ever folds wrong
//! bits.
//!
//! Payload length is not stored: both consumers know the exact payload
//! size from out-of-band metadata (tile dims × width; checkpoint header
//! fields), and an append-only arena already tracks offsets. A
//! truncated read therefore surfaces as a short-read IO error before
//! checksum verification even runs.

use crate::linalg::{Matrix, MatrixF32, Precision, Tile};
use crate::util::xxh64;

/// Bytes preceding the payload: 1 tag + 8 checksum.
pub const RECORD_HEADER_BYTES: usize = 9;

/// Why a record failed integrity verification on read-back.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RecordError {
    /// The width tag disagrees with the reader's element width.
    TagMismatch { expected: u8, found: u8 },
    /// The stored digest does not match the payload read back.
    ChecksumMismatch,
}

impl std::fmt::Display for RecordError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RecordError::TagMismatch { expected, found } => {
                write!(f, "record width tag mismatch: expected {expected}, found {found}")
            }
            RecordError::ChecksumMismatch => write!(f, "record checksum mismatch"),
        }
    }
}

fn digest(tag: u8, payload: &[u8]) -> u64 {
    xxh64(payload, tag as u64)
}

/// Frame `payload` under `tag` as one record (header + payload).
pub fn encode(tag: u8, payload: &[u8]) -> Vec<u8> {
    let mut buf = Vec::with_capacity(RECORD_HEADER_BYTES + payload.len());
    buf.push(tag);
    buf.extend_from_slice(&digest(tag, payload).to_le_bytes());
    buf.extend_from_slice(payload);
    buf
}

/// Flip one payload byte of an already-encoded record *without*
/// refreshing the stored digest — the chaos harness's write-time
/// corruption seam ([`FaultPoint::SpillCorrupt`]), guaranteed to be
/// detected on read-back. No-op on a header-only record.
///
/// [`FaultPoint::SpillCorrupt`]: crate::testkit::faults::FaultPoint
pub fn corrupt_in_place(record: &mut [u8]) {
    if record.len() > RECORD_HEADER_BYTES {
        // middle of the payload: representative of real bit rot, and
        // never the header (a corrupted header is the tag-mismatch
        // path, which read-back also ends typed)
        let i = RECORD_HEADER_BYTES + (record.len() - RECORD_HEADER_BYTES) / 2;
        record[i] ^= 0x01;
    }
}

/// Verify a record read back as (9-byte header, payload).
pub fn verify(expected_tag: u8, header: &[u8; RECORD_HEADER_BYTES], payload: &[u8]) -> Result<(), RecordError> {
    if header[0] != expected_tag {
        return Err(RecordError::TagMismatch { expected: expected_tag, found: header[0] });
    }
    let stored = u64::from_le_bytes(header[1..9].try_into().unwrap());
    if stored != digest(header[0], payload) {
        return Err(RecordError::ChecksumMismatch);
    }
    Ok(())
}

/// Serialize a tile's elements row-major little-endian (the record
/// payload; the width tag is [`tile_tag`]).
pub fn tile_payload(t: &Tile) -> Vec<u8> {
    let mut buf = Vec::with_capacity(t.payload_bytes() as usize);
    match t {
        Tile::F64(m) => {
            for &v in m.data() {
                buf.extend_from_slice(&v.to_le_bytes());
            }
        }
        Tile::F32(m) => {
            for &v in m.data() {
                buf.extend_from_slice(&v.to_le_bytes());
            }
        }
    }
    buf
}

/// The record width tag for an element width.
pub fn width_tag(prec: Precision) -> u8 {
    prec.bytes() as u8
}

/// Rebuild a `rows × cols` tile from a record payload (bit-exact
/// inverse of [`tile_payload`]).
pub fn tile_from_payload(rows: usize, cols: usize, prec: Precision, payload: &[u8]) -> Tile {
    match prec {
        Precision::F64 => {
            let data: Vec<f64> = payload
                .chunks_exact(8)
                .map(|b| f64::from_le_bytes(b.try_into().unwrap()))
                .collect();
            Tile::F64(Matrix::from_vec(rows, cols, data))
        }
        Precision::F32 => {
            let data: Vec<f32> = payload
                .chunks_exact(4)
                .map(|b| f32::from_le_bytes(b.try_into().unwrap()))
                .collect();
            Tile::F32(MatrixF32::from_vec(rows, cols, data))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn sample_tile() -> Tile {
        let mut rng = Rng::new(13);
        Tile::F64(Matrix::randn(5, 3, &mut rng))
    }

    fn split(rec: &[u8]) -> ([u8; RECORD_HEADER_BYTES], &[u8]) {
        (rec[..RECORD_HEADER_BYTES].try_into().unwrap(), &rec[RECORD_HEADER_BYTES..])
    }

    #[test]
    fn round_trip_verifies_and_rebuilds_bit_exactly() {
        let t = sample_tile();
        let rec = encode(width_tag(t.precision()), &tile_payload(&t));
        let (header, payload) = split(&rec);
        verify(8, &header, payload).expect("clean record must verify");
        let back = tile_from_payload(5, 3, Precision::F64, payload);
        match (&t, &back) {
            (Tile::F64(a), Tile::F64(b)) => assert_eq!(a.max_abs_diff(b), 0.0),
            _ => panic!("width changed in round trip"),
        }
    }

    #[test]
    fn f32_round_trip_is_bit_exact() {
        let mut rng = Rng::new(14);
        let t = Tile::F32(Matrix::randn(4, 2, &mut rng).demote());
        let rec = encode(width_tag(t.precision()), &tile_payload(&t));
        let (header, payload) = split(&rec);
        verify(4, &header, payload).expect("clean f32 record must verify");
        match (tile_from_payload(4, 2, Precision::F32, payload), &t) {
            (Tile::F32(a), Tile::F32(b)) => assert_eq!(a.promote().max_abs_diff(&b.promote()), 0.0),
            _ => panic!("width changed in round trip"),
        }
    }

    #[test]
    fn corruption_is_detected_and_tag_mismatch_is_typed() {
        let t = sample_tile();
        let mut rec = encode(8, &tile_payload(&t));
        corrupt_in_place(&mut rec);
        let (header, payload) = split(&rec);
        assert_eq!(verify(8, &header, payload), Err(RecordError::ChecksumMismatch));
        // a clean record read under the wrong width ends tag-typed
        let clean = encode(8, &tile_payload(&t));
        let (header, payload) = split(&clean);
        assert_eq!(
            verify(4, &header, payload),
            Err(RecordError::TagMismatch { expected: 4, found: 8 })
        );
    }

    #[test]
    fn digest_is_tag_seeded() {
        // the same payload must not validate under a forged tag even if
        // the forger recomputes nothing — tag participates in the seed
        let payload = tile_payload(&sample_tile());
        let rec8 = encode(8, &payload);
        let mut forged: [u8; RECORD_HEADER_BYTES] = rec8[..RECORD_HEADER_BYTES].try_into().unwrap();
        forged[0] = 4;
        assert!(verify(4, &forged, &payload).is_err());
    }

    #[test]
    fn header_only_record_survives_corrupt_call() {
        let mut rec = encode(8, &[]);
        corrupt_in_place(&mut rec); // must not panic or touch the header
        let (header, payload) = split(&rec);
        verify(8, &header, payload).expect("empty payload stays clean");
    }
}
