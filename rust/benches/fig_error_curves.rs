//! Bench: Figures 3/4 — regenerates the error-vs-(s/n) series at bench
//! scale on one dataset per run (full sweep: `repro fig3` / `repro fig4`).

use fastspsd::cli::Args;
use fastspsd::figures::{error_curves, Ctx};

fn main() {
    let args = Args::parse(
        [
            "fig3", "--scale", "0.05", "--reps", "1", "--dataset", "PenDigit", "--cpu",
            "--sfactors", "2,8,24", "--out", "out",
        ]
        .iter()
        .map(|s| s.to_string()),
    );
    let ctx = Ctx::from_args(&args);
    println!("== Fig 3 series (bench scale) ==");
    error_curves::run(&ctx, &args, false);
    println!("== Fig 4 series (bench scale) ==");
    error_curves::run(&ctx, &args, true);
}
