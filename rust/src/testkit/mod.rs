//! Property-testing substrate (no `proptest` in the image).
//!
//! Seeded generators + a case runner: each property runs over `cases`
//! random inputs drawn from explicit generators; failures report the
//! case seed so they replay deterministically.

use crate::linalg::Matrix;
use crate::util::Rng;

pub mod faults;

/// Configuration for a property run.
pub struct Prop {
    pub cases: usize,
    pub seed: u64,
}

impl Default for Prop {
    fn default() -> Self {
        Prop { cases: 32, seed: 0xFA57_59D5 }
    }
}

impl Prop {
    pub fn new(cases: usize, seed: u64) -> Self {
        Prop { cases, seed }
    }

    /// Run `f` over `cases` independent RNG streams; panics with the case
    /// index + derived seed on the first failure (so it can be replayed).
    pub fn check(&self, name: &str, f: impl Fn(&mut Rng) -> Result<(), String>) {
        for case in 0..self.cases {
            let case_seed = self.seed.wrapping_add(0x9E37_79B9_7F4A_7C15u64.wrapping_mul(case as u64 + 1));
            let mut rng = Rng::new(case_seed);
            if let Err(msg) = f(&mut rng) {
                panic!("property {name:?} failed at case {case} (seed {case_seed:#x}): {msg}");
            }
        }
    }
}

/// Generators for common test inputs.
pub mod gen {
    use super::*;

    /// Integer in `[lo, hi]`.
    pub fn int(rng: &mut Rng, lo: usize, hi: usize) -> usize {
        lo + rng.usize_below(hi - lo + 1)
    }

    /// Random dense matrix with standard-normal entries.
    pub fn matrix(rng: &mut Rng, m: usize, n: usize) -> Matrix {
        Matrix::randn(m, n, rng)
    }

    /// Random SPSD matrix of exact rank `r` (n x n).
    pub fn spsd(rng: &mut Rng, n: usize, r: usize) -> Matrix {
        let b = Matrix::randn(n, r, rng);
        b.matmul_tr(&b)
    }

    /// Random matrix of exact rank `r`.
    pub fn low_rank(rng: &mut Rng, m: usize, n: usize, r: usize) -> Matrix {
        let b = Matrix::randn(m, r, rng);
        let c = Matrix::randn(r, n, rng);
        b.matmul(&c)
    }
}

/// Assert two matrices are elementwise close.
pub fn assert_close(a: &Matrix, b: &Matrix, tol: f64, what: &str) -> Result<(), String> {
    let d = a.max_abs_diff(b);
    if d <= tol {
        Ok(())
    } else {
        Err(format!("{what}: max |diff| = {d:.3e} > tol {tol:.1e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_trivial_property() {
        Prop::default().check("true", |_| Ok(()));
    }

    #[test]
    #[should_panic(expected = "failed at case")]
    fn check_reports_failure_with_seed() {
        Prop::new(4, 1).check("false", |_| Err("nope".into()));
    }

    #[test]
    fn generators_shapes() {
        let mut rng = Rng::new(0);
        let m = gen::matrix(&mut rng, 3, 4);
        assert_eq!((m.rows(), m.cols()), (3, 4));
        let s = gen::spsd(&mut rng, 5, 2);
        assert_eq!((s.rows(), s.cols()), (5, 5));
        // SPSD symmetric
        assert!(s.max_abs_diff(&s.transpose()) < 1e-12);
        let lr = gen::low_rank(&mut rng, 6, 7, 2);
        let f = crate::linalg::svd_thin(&lr);
        assert_eq!(f.rank(6, 7), 2);
        let k = gen::int(&mut rng, 2, 9);
        assert!((2..=9).contains(&k));
    }

    #[test]
    fn assert_close_works() {
        let a = Matrix::identity(3);
        assert!(assert_close(&a, &a, 0.0, "same").is_ok());
        let b = a.scale(1.1);
        assert!(assert_close(&a, &b, 0.01, "diff").is_err());
    }
}
