//! Structured solves from Appendix A of the paper.
//!
//! - [`eig_of_cuc`] — Lemma 10: eigendecomposition of `C U C^T` in O(n c^2).
//! - [`woodbury_solve`] — Lemma 11: solve `(C U C^T + a I) w = y` in
//!   O(n c^2) via Sherman–Morrison–Woodbury.
//! - Triangular and SPD solves used internally.

use super::eig::eigh;
use super::gemm;
use super::guard::guarded_spd_solve;
use super::svd::svd_thin;
use super::Matrix;

/// Eigendecomposition of the low-rank SPSD approximation `C U C^T`
/// (Lemma 10): returns (eigenvalues desc, eigenvectors n x r) where
/// r = rank(C), in O(n c^2) instead of O(n^3).
pub fn eig_of_cuc(c: &Matrix, u: &Matrix) -> (Vec<f64>, Matrix) {
    assert_eq!(c.cols(), u.rows());
    assert_eq!(u.rows(), u.cols());
    // C = Uc Sc Vc^T  (thin)
    let f = svd_thin(c);
    let rank = f.rank(c.rows(), c.cols());
    let idx: Vec<usize> = (0..rank).collect();
    let uc = f.u.select_cols(&idx);
    // Z = (Sc Vc^T) U (Sc Vc^T)^T, r x r — symmetric (U is), so the
    // triangular product keeps Z exactly symmetric for eigh.
    let svt = Matrix::from_fn(rank, c.cols(), |i, j| f.s[i] * f.v[(j, i)]);
    let z = gemm::symm_nt(&svt.matmul(u), &svt);
    let e = eigh(&z);
    // eigenvectors = Uc Vz
    let vecs = uc.matmul(&e.vectors);
    (e.values, vecs)
}

/// Top-k eigenpairs of `C U C^T` (k <= rank(C)).
pub fn eig_k_of_cuc(c: &Matrix, u: &Matrix, k: usize) -> (Vec<f64>, Matrix) {
    let (vals, vecs) = eig_of_cuc(c, u);
    let k = k.min(vals.len());
    let idx: Vec<usize> = (0..k).collect();
    (vals[..k].to_vec(), vecs.select_cols(&idx))
}

/// Solve `(C U C^T + alpha I_n) w = y` via Woodbury (Lemma 11).
///
/// For SPSD `U` we factor `U = G G^T` (via its eigendecomposition, dropping
/// the numerically-zero part so a merely semi-definite `U` is fine), set
/// `B = C G`, and apply `(B B^T + alpha I)^{-1} = (I - B (alpha I +
/// B^T B)^{-1} B^T) / alpha`. Total cost O(n c^2) — never forms the n x n
/// system.
pub fn woodbury_solve(c: &Matrix, u: &Matrix, alpha: f64, y: &[f64]) -> Vec<f64> {
    assert!(alpha > 0.0, "alpha must be positive");
    assert_eq!(c.rows(), y.len());
    let e = eigh(u);
    let lmax = e.values.first().copied().unwrap_or(0.0).max(0.0);
    let tol = lmax * u.rows() as f64 * f64::EPSILON;
    let keep: Vec<usize> = (0..e.values.len()).filter(|&i| e.values[i] > tol).collect();
    if keep.is_empty() {
        // C U C^T == 0 up to round-off
        return y.iter().map(|&yi| yi / alpha).collect();
    }
    // G = V_+ diag(sqrt(l_+)), B = C G  (n x r)
    let g = Matrix::from_fn(u.rows(), keep.len(), |i, j| {
        e.vectors[(i, keep[j])] * e.values[keep[j]].sqrt()
    });
    let b = c.matmul(&g);
    // inner = alpha I + B^T B (r x r, SPD) — Gram via triangular SYRK
    let mut inner = gemm::syrk_tn(&b);
    inner.add_diag(alpha);
    let bty = b.tr_matvec(y);
    // inner is SPD by construction, so the guarded solve is the plain LU
    // solve whenever the inputs are sane — the ladder only engages when a
    // corrupted or degenerate core sneaks an ill-conditioned system here
    // (where the old .expect would have panicked or amplified noise).
    let z = guarded_spd_solve(&inner, &bty);
    let bz = b.matvec(&z);
    y.iter()
        .zip(&bz)
        .map(|(&yi, &bi)| (yi - bi) / alpha)
        .collect()
}

/// Dense LU solve with partial pivoting (small systems, fallbacks, tests).
pub fn lu_solve(a: &Matrix, b: &[f64]) -> Option<Vec<f64>> {
    let n = a.rows();
    assert_eq!(n, a.cols());
    assert_eq!(n, b.len());
    let mut m = a.clone();
    let mut x: Vec<f64> = b.to_vec();
    for k in 0..n {
        // pivot
        let mut piv = k;
        let mut pmax = m[(k, k)].abs();
        for i in (k + 1)..n {
            if m[(i, k)].abs() > pmax {
                pmax = m[(i, k)].abs();
                piv = i;
            }
        }
        if pmax < 1e-300 {
            return None; // singular
        }
        if piv != k {
            for j in 0..n {
                let t = m[(k, j)];
                m[(k, j)] = m[(piv, j)];
                m[(piv, j)] = t;
            }
            x.swap(k, piv);
        }
        for i in (k + 1)..n {
            let f = m[(i, k)] / m[(k, k)];
            if f == 0.0 {
                continue;
            }
            for j in k..n {
                let v = m[(k, j)];
                m[(i, j)] -= f * v;
            }
            x[i] -= f * x[k];
        }
    }
    // back substitution
    for i in (0..n).rev() {
        let mut s = x[i];
        for j in (i + 1)..n {
            s -= m[(i, j)] * x[j];
        }
        x[i] = s / m[(i, i)];
    }
    Some(x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn lu_solves_random_system() {
        let mut rng = Rng::new(0);
        let a = Matrix::randn(8, 8, &mut rng);
        let xtrue: Vec<f64> = (0..8).map(|i| i as f64 - 3.0).collect();
        let b = a.matvec(&xtrue);
        let x = lu_solve(&a, &b).unwrap();
        for i in 0..8 {
            assert!((x[i] - xtrue[i]).abs() < 1e-8);
        }
    }

    #[test]
    fn lu_detects_singular() {
        let a = Matrix::zeros(3, 3);
        assert!(lu_solve(&a, &[1.0, 2.0, 3.0]).is_none());
    }

    #[test]
    fn eig_of_cuc_matches_direct() {
        let mut rng = Rng::new(1);
        let c = Matrix::randn(30, 5, &mut rng);
        let mut u = Matrix::randn(5, 5, &mut rng);
        u.symmetrize();
        let full = c.matmul(&u).matmul_tr(&c);
        let (vals, vecs) = eig_of_cuc(&c, &u);
        // reconstruct
        let vl = Matrix::from_fn(30, vals.len(), |i, j| vecs[(i, j)] * vals[j]);
        let recon = vl.matmul_tr(&vecs);
        assert!(recon.max_abs_diff(&full) < 1e-8);
        // eigenvectors orthonormal
        let vtv = vecs.tr_matmul(&vecs);
        assert!(vtv.max_abs_diff(&Matrix::identity(vals.len())) < 1e-8);
    }

    #[test]
    fn eig_of_cuc_rank_deficient_c() {
        let mut rng = Rng::new(2);
        let b = Matrix::randn(20, 2, &mut rng);
        let c = b.matmul(&Matrix::randn(2, 6, &mut rng)); // rank 2
        let mut u = Matrix::randn(6, 6, &mut rng);
        u.symmetrize();
        let (vals, vecs) = eig_of_cuc(&c, &u);
        assert_eq!(vals.len(), 2);
        assert_eq!(vecs.cols(), 2);
        let full = c.matmul(&u).matmul_tr(&c);
        let vl = Matrix::from_fn(20, 2, |i, j| vecs[(i, j)] * vals[j]);
        assert!(vl.matmul_tr(&vecs).max_abs_diff(&full) < 1e-8);
    }

    #[test]
    fn woodbury_matches_dense_solve() {
        let mut rng = Rng::new(3);
        let c = Matrix::randn(25, 4, &mut rng);
        let g = Matrix::randn(4, 4, &mut rng);
        let u = g.matmul_tr(&g); // SPSD
        let alpha = 0.7;
        let y: Vec<f64> = (0..25).map(|_| rng.gaussian()).collect();
        // dense: (C U C^T + alpha I) w = y
        let mut kk = c.matmul(&u).matmul_tr(&c);
        for i in 0..25 {
            kk[(i, i)] += alpha;
        }
        let dense = lu_solve(&kk, &y).unwrap();
        let fast = woodbury_solve(&c, &u, alpha, &y);
        for i in 0..25 {
            assert!((dense[i] - fast[i]).abs() < 1e-7, "i={i}: {} vs {}", dense[i], fast[i]);
        }
    }

    #[test]
    fn woodbury_singular_u_still_works() {
        let mut rng = Rng::new(4);
        let c = Matrix::randn(15, 3, &mut rng);
        let g = Matrix::randn(3, 1, &mut rng);
        let u = g.matmul_tr(&g); // rank-1 SPSD
        let alpha = 0.5;
        let y: Vec<f64> = (0..15).map(|_| rng.gaussian()).collect();
        let mut kk = c.matmul(&u).matmul_tr(&c);
        for i in 0..15 {
            kk[(i, i)] += alpha;
        }
        let dense = lu_solve(&kk, &y).unwrap();
        let fast = woodbury_solve(&c, &u, alpha, &y);
        for i in 0..15 {
            assert!((dense[i] - fast[i]).abs() < 1e-7);
        }
    }
}
