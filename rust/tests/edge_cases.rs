//! Edge-case and robustness tests across modules: degenerate sizes,
//! rank-deficient inputs, clamping behaviour, and numerical corner cases.

use fastspsd::apps::{kmeans, knn_classify, kpca};
use fastspsd::coordinator::oracle::{DenseOracle, KernelOracle};
use fastspsd::cur;
use fastspsd::data;
use fastspsd::exec::{self, ExecPolicy};
use fastspsd::linalg::{eigh, pinv, svd_thin, Matrix};
use fastspsd::sketch;
use fastspsd::spsd::{self, FastConfig};
use fastspsd::testkit::gen;
use fastspsd::util::Rng;

// ---------------------------------------------------------------- linalg

#[test]
fn gemm_with_zero_dims() {
    let a = Matrix::zeros(0, 5);
    let b = Matrix::zeros(5, 3);
    let c = a.matmul(&b);
    assert_eq!((c.rows(), c.cols()), (0, 3));
    let d = Matrix::zeros(3, 0);
    let e = Matrix::zeros(0, 4);
    let f = d.matmul(&e);
    assert_eq!((f.rows(), f.cols()), (3, 4));
    assert_eq!(f, Matrix::zeros(3, 4));
}

#[test]
fn svd_of_single_row_and_column() {
    let row = Matrix::from_vec(1, 4, vec![3.0, 0.0, 4.0, 0.0]);
    let f = svd_thin(&row);
    assert!((f.s[0] - 5.0).abs() < 1e-12);
    let col = row.transpose();
    let f2 = svd_thin(&col);
    assert!((f2.s[0] - 5.0).abs() < 1e-12);
}

#[test]
fn eigh_handles_repeated_eigenvalues() {
    // 2 I ⊕ block: repeated eigenvalue 2 with multiplicity 3
    let a = Matrix::diag(&[2.0, 2.0, 2.0, 7.0]);
    let e = eigh(&a);
    assert!((e.values[0] - 7.0).abs() < 1e-12);
    for i in 1..4 {
        assert!((e.values[i] - 2.0).abs() < 1e-12);
    }
    assert!(e.reconstruct().max_abs_diff(&a) < 1e-10);
}

#[test]
fn pinv_of_ill_conditioned() {
    // LAPACK-style tolerance is smax * max(m,n) * eps ≈ 2.2e-15 here:
    // a 1e-16 direction must be dropped, a 1e-13 one must be kept.
    let mut rng = Rng::new(0);
    let u = fastspsd::linalg::qr::qr_thin(&Matrix::randn(10, 2, &mut rng)).q;
    let v = fastspsd::linalg::qr::qr_thin(&Matrix::randn(8, 2, &mut rng)).q;
    let below = Matrix::from_fn(10, 2, |i, j| u[(i, j)] * if j == 0 { 1.0 } else { 1e-16 });
    let a = below.matmul_tr(&v);
    let ap = pinv(&a);
    assert!(ap.fro_norm() < 10.0, "below-tolerance direction kept: {}", ap.fro_norm());
    let above = Matrix::from_fn(10, 2, |i, j| u[(i, j)] * if j == 0 { 1.0 } else { 1e-13 });
    let b = above.matmul_tr(&v);
    let bp = pinv(&b);
    assert!(bp.fro_norm() > 1e12, "above-tolerance direction dropped: {}", bp.fro_norm());
}

// ---------------------------------------------------------------- sketch

#[test]
fn srht_exact_power_of_two() {
    let mut rng = Rng::new(1);
    let n = 32;
    let a = Matrix::randn(n, 3, &mut rng);
    let op = sketch::srht_sketch(n, 8, &mut rng);
    let fast = op.apply_left(&a);
    let dense = sketch::materialize(&op).tr_matmul(&a);
    assert!(fast.max_abs_diff(&dense) < 1e-9);
}

#[test]
fn leverage_with_rank_deficient_c_including_zero_rows() {
    let mut rng = Rng::new(2);
    let mut c = gen::low_rank(&mut rng, 20, 5, 2);
    // zero out some rows entirely → zero leverage scores
    for r in [3usize, 7, 11] {
        for v in c.row_mut(r) {
            *v = 0.0;
        }
    }
    let scores = sketch::leverage_scores(&c);
    assert!(scores[3] < 1e-12 && scores[7] < 1e-12);
    let op = sketch::leverage(&scores, 6, true, &mut rng);
    // zero-score rows are never selected
    if let Some(idx) = op.indices() {
        assert!(!idx.contains(&3) && !idx.contains(&7) && !idx.contains(&11));
    }
}

#[test]
fn sketch_s_larger_than_n_clamps() {
    let mut rng = Rng::new(3);
    let op = sketch::uniform(10, 50, true, &mut rng);
    assert_eq!(op.s(), 10);
}

// ------------------------------------------------------------------ spsd

#[test]
fn nystrom_with_single_column() {
    let mut rng = Rng::new(4);
    let k = gen::spsd(&mut rng, 15, 15);
    let o = DenseOracle::new(k.clone());
    let a = exec::nystrom(&o, &[7], &ExecPolicy::Materialized).result;
    assert_eq!((a.u.rows(), a.u.cols()), (1, 1));
    // rank-1 approximation error is bounded by ||K||
    assert!(a.rel_fro_error(&k) <= 1.0 + 1e-9);
}

#[test]
fn fast_with_s_exceeding_n() {
    let mut rng = Rng::new(5);
    let k = gen::spsd(&mut rng, 20, 4);
    let o = DenseOracle::new(k.clone());
    let p = spsd::uniform_p(20, 6, &mut rng);
    let a = exec::fast(&o, &p, FastConfig::uniform(100), &ExecPolicy::Materialized, &mut rng).result;
    // covers all indices → equals prototype objective; rank(K)=4<6 → exact
    assert!(a.rel_fro_error(&k) < 1e-9);
}

#[test]
fn uniform_p_is_sorted_distinct_and_clamped() {
    let mut rng = Rng::new(6);
    let p = spsd::uniform_p(10, 25, &mut rng);
    assert_eq!(p.len(), 10);
    assert!(p.windows(2).all(|w| w[0] < w[1]));
}

#[test]
fn models_preserve_spsd_structure() {
    // U matrices must stay symmetric so C U C^T is symmetric.
    let mut rng = Rng::new(7);
    let k = gen::spsd(&mut rng, 30, 10);
    let o = DenseOracle::new(k);
    let p = spsd::uniform_p(30, 6, &mut rng);
    let pol = ExecPolicy::Materialized;
    for a in [
        exec::nystrom(&o, &p, &pol).result,
        exec::fast(&o, &p, FastConfig::uniform(15), &pol, &mut rng).result,
        exec::prototype(&o, &p, &pol).result,
    ] {
        assert!(a.u.max_abs_diff(&a.u.transpose()) < 1e-10, "{}", a.method);
        let m = a.materialize();
        assert!(m.max_abs_diff(&m.transpose()) < 1e-8, "{}", a.method);
    }
}

// ------------------------------------------------------------------- cur

#[test]
fn cur_with_all_rows_and_columns_is_exact() {
    let mut rng = Rng::new(8);
    let a = Matrix::randn(12, 9, &mut rng);
    let cols: Vec<usize> = (0..9).collect();
    let rows: Vec<usize> = (0..12).collect();
    let dec = cur::cur_optimal(&a, &cols, &rows);
    assert!(dec.rel_fro_error(&a) < 1e-12);
}

#[test]
fn cur_single_row_single_column() {
    let mut rng = Rng::new(9);
    let a = gen::low_rank(&mut rng, 10, 8, 1); // rank 1
    let dec = cur::cur_optimal(&a, &[2], &[5]);
    assert!(dec.rel_fro_error(&a) < 1e-9, "rank-1 A from one row/col");
}

#[test]
fn uniform_adaptive2_returns_enough_columns() {
    let mut rng = Rng::new(10);
    let a = gen::matrix(&mut rng, 30, 25);
    let idx = cur::uniform_adaptive2(&a, 9, &mut rng);
    assert!(idx.len() >= 7 && idx.len() <= 10, "got {}", idx.len());
    assert!(idx.windows(2).all(|w| w[0] < w[1]));
}

// ------------------------------------------------------------------ apps

#[test]
fn kpca_k_exceeding_rank_clamps() {
    let mut rng = Rng::new(11);
    let k = gen::spsd(&mut rng, 20, 3);
    let o = DenseOracle::new(k);
    let p = spsd::uniform_p(20, 6, &mut rng);
    let a = exec::fast(&o, &p, FastConfig::uniform(12), &ExecPolicy::Materialized, &mut rng).result;
    let model = kpca::kpca_from_approx(&a, 10);
    // eig_k_of_cuc truncates at rank(C) <= 6
    assert!(model.k() <= 6);
    assert!(model.eigvals.iter().all(|&v| v >= 0.0));
}

#[test]
fn knn_with_k_larger_than_train_set() {
    let train = Matrix::from_vec(3, 1, vec![0.0, 1.0, 10.0]);
    let labels = vec![0, 0, 1];
    let test = Matrix::from_vec(1, 1, vec![0.5]);
    // k = 10 > 3 neighbours available: majority over all of them
    let pred = knn_classify(&train, &labels, &test, 10);
    assert_eq!(pred, vec![0]);
}

#[test]
fn kmeans_with_duplicate_points() {
    let pts = Matrix::from_vec(6, 1, vec![1.0, 1.0, 1.0, 9.0, 9.0, 9.0]);
    let mut rng = Rng::new(12);
    let assign = kmeans(&pts, 2, 20, &mut rng);
    assert_eq!(assign[0], assign[1]);
    assert_eq!(assign[1], assign[2]);
    assert_eq!(assign[3], assign[4]);
    assert_ne!(assign[0], assign[3]);
}

// ------------------------------------------------------------------ data

#[test]
fn dataset_scale_clamps_to_minimum() {
    let spec = data::find_spec("DNA").unwrap();
    let ds = spec.generate(1e-9, 0);
    assert_eq!(ds.x.rows(), 200); // floor
    let full = spec.generate(5.0, 0);
    assert_eq!(full.x.rows(), 2000); // ceiling = paper size
}

#[test]
fn eta_of_identity_kernel_is_k_over_n() {
    let k = Matrix::identity(50);
    let e = data::sigma::eta(&k, 5);
    assert!((e - 0.1).abs() < 1e-9);
}

// ----------------------------------------------------------- coordinator

#[test]
fn oracle_entries_accumulate_across_calls() {
    let mut rng = Rng::new(13);
    let o = DenseOracle::new(gen::spsd(&mut rng, 10, 10));
    o.block(&[0, 1], &[0, 1, 2]);
    o.block(&[3], &[4]);
    assert_eq!(o.entries_observed(), 7);
}

#[test]
fn histogram_quantiles_are_ordered() {
    use fastspsd::coordinator::metrics::Histogram;
    use std::time::Duration;
    let h = Histogram::default();
    for i in 1..=100u64 {
        h.observe(Duration::from_micros(i * 10));
    }
    assert!(h.quantile(0.1) <= h.quantile(0.5));
    assert!(h.quantile(0.5) <= h.quantile(0.95));
    assert!(h.quantile(0.95) <= h.max());
}
