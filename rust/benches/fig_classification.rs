//! Bench: Figures 7-10 — KPCA + 10-NN classification error at bench scale.

use fastspsd::cli::Args;
use fastspsd::figures::{kpca_class, Ctx};

fn main() {
    let args = Args::parse(
        [
            "fig7", "--scale", "0.05", "--reps", "1", "--dataset", "PenDigit", "--cpu",
            "--cs", "10,20,40", "--out", "out",
        ]
        .iter()
        .map(|s| s.to_string()),
    );
    let ctx = Ctx::from_args(&args);
    println!("== Fig 7/8 series (k=3, bench scale) ==");
    kpca_class::run(&ctx, &args, 3);
    println!("== Fig 9/10 series (k=10, bench scale) ==");
    kpca_class::run(&ctx, &args, 10);
}
