//! Chaos acceptance matrix (ISSUE 6, extended by the integrity and
//! sharding PRs): deterministic fault injection over {spill write, spill
//! read, oracle tile, consumer fold, spill corruption, tile poisoning,
//! shard worker death} × {transient, persistent}. Every cell must end in
//! a typed error or a correct (possibly degraded) result — never a hang,
//! never a poisoned worker, never silently wrong bits — with the memory
//! meter back at zero and no spill temp files left behind.
//!
//! Tests that arm the process-global fault plan serialize on
//! `CHAOS_LOCK` (the arm slot is process-wide). The seeded matrix at the
//! bottom replays the fixed seed set from `FASTSPSD_CHAOS_SEEDS`
//! (default "11 23 47" — the `make chaos` pin).

use fastspsd::coordinator::oracle::{KernelOracle, RbfOracle};
use fastspsd::coordinator::{
    ApproxRequest, ApproxService, MethodSpec, ServiceConfig, ServiceError,
};
use fastspsd::exec::{self, ExecPolicy};
use fastspsd::linalg::Matrix;
use fastspsd::obs::{self, sink, Stage};
use fastspsd::sketch::SketchKind;
use fastspsd::stream::{
    OracleColumnsSource, ResidencyConfig, ResidentSource, TileSource, ValidateMode,
};
use fastspsd::testkit::faults::{
    self, FaultPlan, FaultPoint, FaultSpec, FaultyOracle,
};
use fastspsd::util::Rng;
use std::path::PathBuf;
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::Duration;

/// Serializes tests that touch the process-global fault-plan slot.
static CHAOS_LOCK: Mutex<()> = Mutex::new(());

fn chaos_guard() -> std::sync::MutexGuard<'static, ()> {
    // A previous test's assert must not wedge the rest of the suite.
    CHAOS_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

const N: usize = 53;
const C: usize = 5;

fn oracle() -> RbfOracle {
    let mut rng = Rng::new(3);
    RbfOracle::cpu(Arc::new(Matrix::randn(N, 6, &mut rng)), 0.5)
}

fn landmarks() -> Vec<usize> {
    vec![2, 11, 23, 37, 50]
}

/// Fresh per-test spill directory under the system temp dir; asserting it
/// is empty afterwards is the "no leftover temp files" acceptance check.
fn spill_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("fastspsd-chaos-{}-{name}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn assert_no_spill_files(dir: &PathBuf) {
    let leftover: Vec<_> = std::fs::read_dir(dir)
        .unwrap()
        .map(|e| e.unwrap().path())
        .collect();
    assert!(leftover.is_empty(), "leftover spill files: {leftover:?}");
    let _ = std::fs::remove_dir_all(dir);
}

/// The multi-pass build the spill faults target: q Lanczos iterations at a
/// zero RAM budget, so every re-read goes through the arena.
fn lanczos_under(
    o: &RbfOracle,
    cols: &[usize],
    policy: &ExecPolicy,
) -> (Vec<f64>, Matrix, fastspsd::stream::ResidencyStats) {
    let src = fastspsd::stream::OracleColumnsSource::new(o, cols);
    let u = Matrix::identity(C);
    let rep = exec::top_k_eigs(&src, &u, 3, 7, policy);
    let (vals, vecs) = rep.result;
    (vals, vecs, rep.meta.residency.expect("resident policy carries stats"))
}

fn spilled_in(dir: &PathBuf) -> ExecPolicy {
    ExecPolicy::resident(0).with_tile_rows(8).with_spill_dir(dir.clone())
}

#[test]
fn spill_write_faults_recover_or_degrade_bit_identically() {
    let _g = chaos_guard();
    let o = oracle();
    let cols = landmarks();
    let dir = spill_dir("spill-write");
    let (vals_ref, vecs_ref, _) = lanczos_under(&o, &cols, &spilled_in(&dir));

    // transient: the 2nd tile write fails once; the retry-with-backoff
    // path absorbs it invisibly (counted in io_retries).
    let plan = Arc::new(FaultPlan::none().fail(FaultPoint::SpillWrite, FaultSpec::transient(2)));
    {
        let _armed = faults::arm(Arc::clone(&plan));
        let (vals, vecs, stats) = lanczos_under(&o, &cols, &spilled_in(&dir));
        assert_eq!(vals_ref, vals, "transient write fault must not change results");
        assert_eq!(vecs_ref.max_abs_diff(&vecs), 0.0);
        assert!(stats.io_retries >= 1, "the retry must be visible in stats");
        assert!(stats.spill_hits > 0, "the arena survives a transient fault");
    }
    assert_eq!(plan.injected(FaultPoint::SpillWrite), 1);

    // persistent: every write fails; after the retry budget the arena is
    // dropped wholesale and the layer degrades to recompute-on-miss —
    // still bit-identical, never an error.
    let plan =
        Arc::new(FaultPlan::none().fail(FaultPoint::SpillWrite, FaultSpec::persistent(1)));
    {
        let _armed = faults::arm(Arc::clone(&plan));
        let (vals, vecs, stats) = lanczos_under(&o, &cols, &spilled_in(&dir));
        assert_eq!(vals_ref, vals, "persistent write fault must degrade, not corrupt");
        assert_eq!(vecs_ref.max_abs_diff(&vecs), 0.0);
        assert_eq!(stats.spill_hits, 0, "a dead arena serves nothing");
        assert!(stats.computes > (N.div_ceil(8)) as u64, "degraded = recompute on miss");
    }
    assert!(plan.injected(FaultPoint::SpillWrite) >= 3, "one write, all attempts failed");
    assert_no_spill_files(&dir);
}

#[test]
fn spill_read_faults_recover_or_degrade_bit_identically() {
    let _g = chaos_guard();
    let o = oracle();
    let cols = landmarks();
    let dir = spill_dir("spill-read");
    let (vals_ref, vecs_ref, stats_ref) = lanczos_under(&o, &cols, &spilled_in(&dir));
    assert!(stats_ref.spill_hits > 0, "premise: the clean run re-reads the arena");

    // transient: the 1st arena read fails once, the retry serves it.
    let plan = Arc::new(FaultPlan::none().fail(FaultPoint::SpillRead, FaultSpec::transient(1)));
    {
        let _armed = faults::arm(Arc::clone(&plan));
        let (vals, vecs, stats) = lanczos_under(&o, &cols, &spilled_in(&dir));
        assert_eq!(vals_ref, vals, "transient read fault must not change results");
        assert_eq!(vecs_ref.max_abs_diff(&vecs), 0.0);
        assert!(stats.io_retries >= 1);
        assert_eq!(stats.spill_hits, stats_ref.spill_hits, "all re-reads still served");
    }

    // persistent: reads keep failing; the arena is dropped and every
    // former spill hit becomes a recompute.
    let plan =
        Arc::new(FaultPlan::none().fail(FaultPoint::SpillRead, FaultSpec::persistent(1)));
    {
        let _armed = faults::arm(Arc::clone(&plan));
        let (vals, vecs, stats) = lanczos_under(&o, &cols, &spilled_in(&dir));
        assert_eq!(vals_ref, vals, "persistent read fault must degrade, not corrupt");
        assert_eq!(vecs_ref.max_abs_diff(&vecs), 0.0);
        assert_eq!(stats.spill_hits, 0);
        assert!(stats.computes > stats_ref.computes, "degraded = recompute on miss");
    }
    assert_no_spill_files(&dir);
}

/// Chaos must stay visible in traces (ISSUE 7): the residency layer
/// records one `residency.spill_write` span per IO *attempt*, so an
/// injected transient fault shows up as an extra span over the tile
/// count — and the whole trace still renders as well-formed Chrome
/// `trace_event` JSON.
#[test]
fn injected_spill_retries_are_visible_in_the_chrome_trace() {
    let _g = chaos_guard();
    obs::ensure_installed();
    let o = oracle();
    let cols = landmarks();
    let dir = spill_dir("trace");
    let trace = obs::TraceId::mint().raw();
    let plan = Arc::new(FaultPlan::none().fail(FaultPoint::SpillWrite, FaultSpec::transient(2)));
    {
        let _armed = faults::arm(Arc::clone(&plan));
        let _scope = obs::trace_scope(trace);
        let (_, _, stats) = lanczos_under(&o, &cols, &spilled_in(&dir));
        assert!(stats.io_retries >= 1, "premise: the transient fault forced a retry");
    }
    let records = obs::drain_trace(trace);
    let writes =
        records.iter().filter(|r| r.stage == Stage::ResidencySpillWrite).count() as u64;
    let tiles = N.div_ceil(8) as u64;
    assert!(
        writes > tiles,
        "per-attempt spans must make the retry visible: {writes} write spans, {tiles} tiles"
    );
    let stages = sink::validate_chrome_json(&sink::chrome_json(&records))
        .expect("a chaos run still emits well-formed trace JSON");
    assert!(stages.contains("residency.spill_write"), "{stages:?}");
    assert!(stages.contains("residency.spill_read"), "{stages:?}");
    assert_no_spill_files(&dir);
}

/// Service over a fault-wrapped oracle: worker panics must be isolated.
fn faulty_service(plan: Arc<FaultPlan>, workers: usize) -> ApproxService {
    let inner: Arc<dyn KernelOracle + Send + Sync> = Arc::new(oracle());
    let faulty = Arc::new(FaultyOracle::new(inner, plan));
    ApproxService::new(faulty, ServiceConfig { workers, ..Default::default() })
}

fn req(id: u64, policy: Option<ExecPolicy>) -> ApproxRequest {
    ApproxRequest {
        id,
        method: MethodSpec::Fast { s: 20, kind: SketchKind::Uniform },
        c: 8,
        k: 3,
        seed: id,
        policy,
        precision: fastspsd::stream::Precision::F64,
        deadline: None,
    }
}

#[test]
fn oracle_tile_panic_is_isolated_and_the_service_keeps_serving() {
    // No global arming (the plan rides inside FaultyOracle), so no lock.
    for (spec, faulted_requests) in [
        (FaultSpec::transient(2), 1u64),   // one tile panic, one dead request
        (FaultSpec::persistent(1), 2u64),  // every tile panics until disarmed... it never is
    ] {
        let plan = Arc::new(FaultPlan::none().fail(FaultPoint::OracleTile, spec));
        let svc = faulty_service(Arc::clone(&plan), 2);
        let (tx, rx) = mpsc::channel();
        svc.submit(req(0, None), tx.clone());
        svc.submit(req(1, None), tx.clone());
        svc.drain();
        drop(tx);
        let mut resps: Vec<_> = rx.iter().collect();
        resps.sort_by_key(|r| r.id);
        assert_eq!(resps.len(), 2, "{spec:?}: panicking builds still reply");
        let faulted = resps
            .iter()
            .filter(|r| matches!(r.error, Some(ServiceError::Faulted(_))))
            .count() as u64;
        assert_eq!(faulted, faulted_requests, "{spec:?}");
        for r in &resps {
            match &r.error {
                None => assert_eq!(r.eigvals.len(), 3),
                Some(ServiceError::Faulted(msg)) => {
                    assert!(msg.contains("injected fault: oracle tile"), "{msg}");
                }
                other => panic!("{spec:?}: unexpected error {other:?}"),
            }
        }
        let m = svc.metrics();
        assert_eq!(m.faulted.get(), faulted_requests);
        assert_eq!(m.completed.get(), 2 - faulted_requests);
        assert_eq!(m.mem_in_use.get(), 0, "{spec:?}: reservations released on panic");
        assert_eq!(svc.inflight(), 0);

        // The worker that caught the panic is still alive: with the fault
        // schedule exhausted (transient) the same service serves clean.
        if !spec.persistent {
            let (tx, rx) = mpsc::channel();
            svc.submit(req(2, None), tx);
            svc.drain();
            let r = rx.iter().next().unwrap();
            assert!(r.error.is_none(), "worker must survive the earlier panic: {:?}", r.error);
            assert_eq!(m.completed.get(), 2);
        }
    }
}

#[test]
fn consumer_fold_panic_is_isolated_and_the_service_keeps_serving() {
    let _g = chaos_guard();
    let dir = spill_dir("consumer-fold");
    for spec in [FaultSpec::transient(2), FaultSpec::persistent(2)] {
        let svc = ApproxService::new(
            Arc::new(oracle()) as Arc<dyn KernelOracle + Send + Sync>,
            ServiceConfig {
                workers: 1,
                spill_dir: Some(dir.clone()),
                ..Default::default()
            },
        );
        let plan = Arc::new(FaultPlan::none().fail(FaultPoint::ConsumerFold, spec));
        {
            let _armed = faults::arm(Arc::clone(&plan));
            // resident streamed build → spill arena + pipeline folds
            let (tx, rx) = mpsc::channel();
            svc.submit(req(0, Some(ExecPolicy::resident(0).with_tile_rows(8))), tx);
            svc.drain();
            let r = rx.iter().next().unwrap();
            match &r.error {
                Some(ServiceError::Faulted(msg)) => {
                    assert!(msg.contains("injected fault: consumer fold"), "{msg}");
                }
                other => panic!("{spec:?}: expected Faulted, got {other:?}"),
            }
            assert!(r.meta.is_none() && r.eigvals.is_empty());
        }
        assert!(plan.injected(FaultPoint::ConsumerFold) >= 1, "{spec:?}");
        let m = svc.metrics();
        assert_eq!(m.faulted.get(), 1);
        assert_eq!(m.mem_in_use.get(), 0, "{spec:?}: reservation released through the unwind");
        assert_eq!(svc.inflight(), 0);

        // Disarmed, the same service (same worker thread) serves clean and
        // the panicked build's spill arena was cleaned by its guard.
        let (tx, rx) = mpsc::channel();
        svc.submit(req(1, Some(ExecPolicy::resident(0).with_tile_rows(8))), tx);
        svc.drain();
        let r = rx.iter().next().unwrap();
        assert!(r.error.is_none(), "{spec:?}: worker must survive: {:?}", r.error);
        assert!(r.meta.unwrap().residency.unwrap().computes > 0);
    }
    assert_no_spill_files(&dir);
}

/// Corruption chaos: flipped spill bytes must be *detected* (checksum),
/// *counted* (`corrupt_reads`, mirrored into `numeric_health`), and
/// *healed* (recompute) — the result stays bit-identical in every cell.
#[test]
fn spill_corruption_is_detected_recomputed_and_stays_bit_identical() {
    let _g = chaos_guard();
    let o = oracle();
    let cols = landmarks();
    let dir = spill_dir("spill-corrupt");
    let (vals_ref, vecs_ref, stats_ref) = lanczos_under(&o, &cols, &spilled_in(&dir));
    assert!(stats_ref.spill_hits > 0, "premise: the clean run re-reads the arena");

    for spec in [FaultSpec::transient(2), FaultSpec::persistent(1)] {
        let plan = Arc::new(FaultPlan::none().fail(FaultPoint::SpillCorrupt, spec));
        let _armed = faults::arm(Arc::clone(&plan));
        let src = OracleColumnsSource::new(&o, &cols);
        let u = Matrix::identity(C);
        let rep = exec::top_k_eigs(&src, &u, 3, 7, &spilled_in(&dir));
        let (vals, vecs) = rep.result;
        assert_eq!(vals_ref, vals, "{spec:?}: corruption must never change bits");
        assert_eq!(vecs_ref.max_abs_diff(&vecs), 0.0, "{spec:?}");
        let stats = rep.meta.residency.expect("resident policy carries stats");
        assert!(stats.corrupt_reads >= 1, "{spec:?}: detection must be visible: {stats:?}");
        assert_eq!(
            rep.meta.numeric_health.corrupt_reads, stats.corrupt_reads,
            "{spec:?}: numeric health mirrors the residency counter"
        );
        assert!(plan.injected(FaultPoint::SpillCorrupt) >= 1, "{spec:?}");
        if spec.persistent {
            // every re-read hit a corrupted record and was recomputed
            assert!(
                stats.corrupt_reads >= stats_ref.spill_hits,
                "{spec:?}: all former spill hits must detect: {stats:?} vs {stats_ref:?}"
            );
        }
    }
    assert_no_spill_files(&dir);
}

/// Regression for the per-IO-attempt fault-plan read: a plan armed
/// *after* the spill arena was created (mid-request, from another test's
/// perspective) must still reach its IO paths. The old code captured the
/// plan once at arena construction and never saw later arming.
#[test]
fn fault_plans_armed_mid_run_reach_a_live_arena() {
    let _g = chaos_guard();
    let o = oracle();
    let cols = landmarks();
    let src = OracleColumnsSource::new(&o, &cols);
    let dir = spill_dir("mid-arm");
    let cfg = ResidencyConfig::new(0).with_tile_rows(8).with_spill_dir(dir.clone());
    let res = ResidentSource::new(&src, &cfg);
    // Populate the arena with nothing armed (zero RAM budget: every
    // revisit must come back through a spill read).
    let tiles = N.div_ceil(8);
    for g in 0..tiles {
        let _ = res.tile(g * 8, ((g + 1) * 8).min(N));
    }
    assert!(res.spill_active(), "premise: the arena is live before arming");
    assert_eq!(res.stats().io_retries, 0);
    // Arm only now; the very next arena read must consult the new plan.
    let clean = src.tile(0, 8);
    let plan = Arc::new(FaultPlan::none().fail(FaultPoint::SpillRead, FaultSpec::transient(1)));
    {
        let _armed = faults::arm(Arc::clone(&plan));
        let served = res.tile(0, 8);
        assert_eq!(served.max_abs_diff(&clean), 0.0, "the retried read serves the right bits");
    }
    assert_eq!(plan.injected(FaultPoint::SpillRead), 1, "the mid-run plan must trip");
    assert!(res.stats().io_retries >= 1, "and the retry must be visible in stats");
    drop(res);
    assert_no_spill_files(&dir);
}

/// Poisoned tiles under a validating policy end in a typed quarantine
/// fault — never NaN eigenvalues — and the worker survives to serve the
/// next request cleanly.
#[test]
fn poisoned_tiles_fail_typed_under_validation_and_the_worker_survives() {
    let _g = chaos_guard();
    let validated =
        || ExecPolicy::streamed(8).with_validate(ValidateMode::NonFinite);
    for spec in [FaultSpec::transient(2), FaultSpec::persistent(2)] {
        let svc = ApproxService::new(
            Arc::new(oracle()) as Arc<dyn KernelOracle + Send + Sync>,
            ServiceConfig { workers: 1, ..Default::default() },
        );
        let plan = Arc::new(FaultPlan::none().fail(FaultPoint::PoisonTile, spec));
        {
            let _armed = faults::arm(Arc::clone(&plan));
            let (tx, rx) = mpsc::channel();
            svc.submit(req(0, Some(validated())), tx);
            svc.drain();
            let r = rx.iter().next().unwrap();
            match &r.error {
                Some(ServiceError::Faulted(msg)) => {
                    assert!(msg.contains("poisoned tile"), "{spec:?}: typed end: {msg}");
                }
                other => panic!("{spec:?}: expected Faulted, got {other:?}"),
            }
            assert!(r.eigvals.is_empty(), "{spec:?}: no numbers from a poisoned build");
            assert_eq!(
                r.numeric_health.map(|h| h.quarantined_tiles >= 1),
                Some(true),
                "{spec:?}: the quarantine must be visible on the reply"
            );
        }
        assert!(plan.injected(FaultPoint::PoisonTile) >= 1, "{spec:?}");
        let m = svc.metrics();
        assert_eq!(m.faulted.get(), 1, "{spec:?}");
        assert_eq!(m.mem_in_use.get(), 0, "{spec:?}: reservation released");
        // Disarmed, the same worker serves the same request clean.
        let (tx, rx) = mpsc::channel();
        svc.submit(req(1, Some(validated())), tx);
        svc.drain();
        let r = rx.iter().next().unwrap();
        assert!(r.error.is_none(), "{spec:?}: worker must survive: {:?}", r.error);
        assert_eq!(r.eigvals.len(), 3);
        assert!(r.numeric_health.unwrap().is_clean(), "{spec:?}");
    }
}

/// `retry_faulted`: a transiently poisoned build recovers on the retry —
/// bit-identical to a never-faulted service — and the reply carries the
/// health its failed attempt observed. A persistently poisoned build
/// still ends typed after the retry budget.
#[test]
fn faulted_requests_retry_to_bit_identical_results_and_carry_health() {
    let _g = chaos_guard();
    let dir = spill_dir("retry");
    let retrying = || {
        ApproxService::new(
            Arc::new(oracle()) as Arc<dyn KernelOracle + Send + Sync>,
            ServiceConfig {
                workers: 1,
                spill_dir: Some(dir.clone()),
                retry_faulted: 1,
                ..Default::default()
            },
        )
    };
    let validated =
        || ExecPolicy::streamed(8).with_validate(ValidateMode::NonFinite);
    // Clean reference: same oracle data, same request, no faults.
    let eig_ref = {
        let svc = retrying();
        let (tx, rx) = mpsc::channel();
        svc.submit(req(0, Some(validated())), tx);
        svc.drain();
        let r = rx.iter().next().unwrap();
        assert!(r.error.is_none(), "{:?}", r.error);
        r.eigvals
    };

    // Transient poison: attempt 1 quarantines and faults, attempt 2 runs
    // past the exhausted schedule and completes.
    let svc = retrying();
    let plan = Arc::new(FaultPlan::none().fail(FaultPoint::PoisonTile, FaultSpec::transient(3)));
    {
        let _armed = faults::arm(Arc::clone(&plan));
        let (tx, rx) = mpsc::channel();
        svc.submit(req(0, Some(validated())), tx);
        svc.drain();
        let r = rx.iter().next().unwrap();
        assert!(r.error.is_none(), "the retry must recover: {:?}", r.error);
        assert_eq!(r.eigvals, eig_ref, "recovered ≠ different: bit-identity is the contract");
        let health = r.numeric_health.expect("served responses carry health");
        assert!(
            health.quarantined_tiles >= 1,
            "the failed attempt's quarantine must be carried: {health:?}"
        );
    }
    assert_eq!(plan.injected(FaultPoint::PoisonTile), 1);
    let m = svc.metrics();
    assert_eq!(m.faulted.get(), 1, "per-attempt fault accounting");
    assert_eq!(m.completed.get(), 1, "one request, one completion");

    // Persistent poison: both attempts fault; the reply is typed.
    let svc = retrying();
    let plan =
        Arc::new(FaultPlan::none().fail(FaultPoint::PoisonTile, FaultSpec::persistent(1)));
    {
        let _armed = faults::arm(Arc::clone(&plan));
        let (tx, rx) = mpsc::channel();
        svc.submit(req(0, Some(validated())), tx);
        svc.drain();
        let r = rx.iter().next().unwrap();
        match &r.error {
            Some(ServiceError::Faulted(msg)) => {
                assert!(msg.contains("poisoned tile"), "{msg}");
            }
            other => panic!("expected Faulted after the retry budget, got {other:?}"),
        }
        assert!(
            r.numeric_health.map_or(false, |h| h.quarantined_tiles >= 2),
            "both attempts' quarantines are carried: {:?}",
            r.numeric_health
        );
    }
    assert!(plan.injected(FaultPoint::PoisonTile) >= 2);
    assert_eq!(svc.metrics().faulted.get(), 2, "per-attempt fault accounting");
    assert_eq!(svc.metrics().completed.get(), 0);
    assert_eq!(svc.metrics().mem_in_use.get(), 0);
    drop(svc);
    // Per-request checkpoint directories are removed on every outcome.
    assert_no_spill_files(&dir);
}

/// Worker-death cells (ISSUE 10): a shard worker that dies transiently
/// has its row-range re-executed — bit-identical reply, death visible
/// only in `ShardStats::reexecuted` — while a persistent death exhausts
/// the one re-execution and ends as a typed `Faulted`, never a hang,
/// with the worker thread surviving to serve the next request.
#[test]
fn shard_worker_death_reexecutes_transiently_and_ends_typed_persistently() {
    let _g = chaos_guard();
    let sharded = || Some(ExecPolicy::sharded(3, ExecPolicy::streamed(8)));
    let svc = ApproxService::new(
        Arc::new(oracle()) as Arc<dyn KernelOracle + Send + Sync>,
        ServiceConfig { workers: 1, ..Default::default() },
    );
    // Clean sharded reference (same service, nothing armed).
    let eig_ref = {
        let (tx, rx) = mpsc::channel();
        svc.submit(req(0, sharded()), tx);
        svc.drain();
        let r = rx.iter().next().unwrap();
        assert!(r.error.is_none(), "{:?}", r.error);
        assert_eq!(r.meta.as_ref().unwrap().shard.as_ref().unwrap().reexecuted, 0);
        r.eigvals
    };

    // transient: the 2nd shard's worker dies once; its row-range is
    // re-executed and the reply is bit-identical.
    let plan = Arc::new(
        FaultPlan::none().fail(FaultPoint::ShardWorkerDeath, FaultSpec::transient(2)),
    );
    {
        let _armed = faults::arm(Arc::clone(&plan));
        let (tx, rx) = mpsc::channel();
        svc.submit(req(0, sharded()), tx); // same seed as the reference
        svc.drain();
        let r = rx.iter().next().unwrap();
        assert!(r.error.is_none(), "transient death must be re-executed: {:?}", r.error);
        assert_eq!(r.eigvals, eig_ref, "the re-executed shard must reproduce the bits");
        let stats = r.meta.unwrap().shard.unwrap();
        assert_eq!(stats.reexecuted, 1, "the death is accounted, never silent");
        assert_eq!(stats.workers.len(), 3);
    }
    assert_eq!(plan.injected(FaultPoint::ShardWorkerDeath), 1);
    assert_eq!(svc.metrics().faulted.get(), 0, "the service never saw the death");

    // persistent: the worker dies on the re-execution too; the request
    // ends typed, reservations drain, and the worker thread survives.
    let plan = Arc::new(
        FaultPlan::none().fail(FaultPoint::ShardWorkerDeath, FaultSpec::persistent(1)),
    );
    {
        let _armed = faults::arm(Arc::clone(&plan));
        let (tx, rx) = mpsc::channel();
        svc.submit(req(2, sharded()), tx);
        svc.drain();
        let r = rx.iter().next().unwrap();
        match &r.error {
            Some(ServiceError::Faulted(msg)) => {
                assert!(msg.contains("injected fault: shard worker death"), "{msg}");
            }
            other => panic!("expected Faulted after the re-execution budget, got {other:?}"),
        }
        assert!(r.eigvals.is_empty(), "no numbers from a dead shard");
    }
    assert!(plan.injected(FaultPoint::ShardWorkerDeath) >= 2, "first run + re-execution");
    let m = svc.metrics();
    assert_eq!(m.faulted.get(), 1);
    assert_eq!(m.mem_in_use.get(), 0, "reservation released through the unwind");
    assert_eq!(svc.inflight(), 0);

    // Disarmed, the same worker serves the same sharded request clean.
    let (tx, rx) = mpsc::channel();
    svc.submit(req(0, sharded()), tx);
    svc.drain();
    let r = rx.iter().next().unwrap();
    assert!(r.error.is_none(), "worker must survive the dead shard: {:?}", r.error);
    assert_eq!(r.eigvals, eig_ref);
}

/// A [`KernelOracle`] whose tile production blocks until released —
/// deterministic "slow request" for queue/deadline/shutdown tests.
struct GateOracle {
    inner: Arc<dyn KernelOracle + Send + Sync>,
    open: Mutex<bool>,
    cv: Condvar,
}

impl GateOracle {
    fn new(inner: Arc<dyn KernelOracle + Send + Sync>) -> Self {
        GateOracle { inner, open: Mutex::new(false), cv: Condvar::new() }
    }

    fn release(&self) {
        *self.open.lock().unwrap() = true;
        self.cv.notify_all();
    }

    fn wait_open(&self) {
        let mut open = self.open.lock().unwrap();
        while !*open {
            open = self.cv.wait(open).unwrap();
        }
    }
}

impl KernelOracle for GateOracle {
    fn n(&self) -> usize {
        self.inner.n()
    }

    fn block(&self, rows: &[usize], cols: &[usize]) -> Matrix {
        self.wait_open();
        self.inner.block(rows, cols)
    }

    fn row_block(&self, r0: usize, r1: usize, cols: &[usize]) -> Matrix {
        self.wait_open();
        self.inner.row_block(r0, r1, cols)
    }

    fn full_rows(&self, r0: usize, r1: usize) -> Matrix {
        self.wait_open();
        self.inner.full_rows(r0, r1)
    }

    fn entries_observed(&self) -> u64 {
        self.inner.entries_observed()
    }

    fn reset_entries(&self) {
        self.inner.reset_entries();
    }
}

fn gated_service(workers: usize) -> (Arc<GateOracle>, ApproxService) {
    let gate = Arc::new(GateOracle::new(Arc::new(oracle())));
    let n = gate.n();
    let cap = fastspsd::coordinator::planner::predicted_policy_peak_bytes(
        n,
        8,
        &MethodSpec::Fast { s: 20, kind: SketchKind::Uniform },
        &ExecPolicy::Materialized,
    );
    let svc = ApproxService::new(
        Arc::clone(&gate) as Arc<dyn KernelOracle + Send + Sync>,
        ServiceConfig { workers, memory_cap: Some(cap), ..Default::default() },
    );
    (gate, svc)
}

#[test]
fn queued_request_past_its_deadline_is_reaped_with_a_typed_reply() {
    // A holds the whole cap behind the gate; B (deadline 0) must queue and
    // then be expired by the reaper — typed Overloaded, not a hang, and
    // the queue drains so A still completes untouched.
    let (gate, svc) = gated_service(1);
    let (tx_a, rx_a) = mpsc::channel();
    svc.submit(req(0, None), tx_a);
    let (tx_b, rx_b) = mpsc::channel();
    let mut b = req(1, None);
    b.deadline = Some(Duration::ZERO);
    svc.submit(b, tx_b);
    let rb = rx_b
        .recv_timeout(Duration::from_secs(10))
        .expect("the reaper must expire B, not leave it hanging");
    match rb.error {
        Some(ServiceError::Overloaded { retry_after }) => {
            assert!(retry_after > Duration::ZERO);
        }
        other => panic!("expected Overloaded, got {other:?}"),
    }
    gate.release();
    svc.drain();
    let ra = rx_a.recv_timeout(Duration::from_secs(10)).unwrap();
    assert!(ra.error.is_none(), "{:?}", ra.error);
    let m = svc.metrics();
    assert_eq!(m.expired_deadline.get(), 1);
    assert_eq!(m.queued.get(), 1);
    assert_eq!(m.completed.get(), 1);
    assert_eq!(m.rejected_overload.get(), 0);
    assert_eq!(m.mem_in_use.get(), 0);
}

#[test]
fn shutdown_flushes_the_admission_queue_with_stopping_replies() {
    let (gate, svc) = gated_service(1);
    let (tx_a, rx_a) = mpsc::channel();
    svc.submit(req(0, None), tx_a);
    let (tx_b, rx_b) = mpsc::channel();
    svc.submit(req(1, None), tx_b); // queues: A holds the whole cap
    std::thread::scope(|s| {
        let h = s.spawn(|| svc.shutdown());
        // B's reply proves the flush happened while A was still in flight.
        let rb = rx_b
            .recv_timeout(Duration::from_secs(10))
            .expect("shutdown must flush the queue, not drop reply channels");
        assert_eq!(rb.error, Some(ServiceError::Stopping));
        gate.release();
        h.join().unwrap();
    });
    let ra = rx_a.recv_timeout(Duration::from_secs(10)).unwrap();
    assert!(ra.error.is_none(), "in-flight work completes through shutdown: {:?}", ra.error);
    // Post-shutdown submissions are refused up front.
    let (tx_c, rx_c) = mpsc::channel();
    svc.submit(req(2, None), tx_c);
    assert_eq!(rx_c.iter().next().unwrap().error, Some(ServiceError::Stopping));
    assert_eq!(svc.metrics().completed.get(), 1);
    assert_eq!(svc.metrics().mem_in_use.get(), 0);
}

/// The fixed seed set (`make chaos` pins FASTSPSD_CHAOS_SEEDS="11 23 47").
fn chaos_seeds() -> Vec<u64> {
    std::env::var("FASTSPSD_CHAOS_SEEDS")
        .unwrap_or_else(|_| "11 23 47".into())
        .split_whitespace()
        .map(|t| t.parse().expect("FASTSPSD_CHAOS_SEEDS must be u64s"))
        .collect()
}

#[test]
fn seeded_chaos_matrix_never_hangs_never_leaks_never_corrupts() {
    let _g = chaos_guard();
    let o = oracle();
    let cols = landmarks();
    let dir = spill_dir("seeded");
    // Validation on: a seeded PoisonTile fault must end *typed* (a
    // quarantine panic through the oracle wrapper), never as silent NaNs.
    let seeded_policy = || spilled_in(&dir).with_validate(ValidateMode::NonFinite);
    let (vals_ref, vecs_ref, _) = lanczos_under(&o, &cols, &seeded_policy());
    for seed in chaos_seeds() {
        let plan = Arc::new(FaultPlan::seeded(seed));
        {
            let _armed = faults::arm(Arc::clone(&plan));
            // Whatever the seed armed: the run must either complete
            // bit-identically (spill write/read/corruption faults retry,
            // degrade, or recompute) or panic in a contained, propagated
            // way (consumer-fold and poisoned-tile faults) — never hang,
            // never return silently wrong numbers.
            let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                lanczos_under(&o, &cols, &seeded_policy())
            }));
            match outcome {
                Ok((vals, vecs, _)) => {
                    assert_eq!(vals_ref, vals, "seed {seed}: degraded ≠ corrupted");
                    assert_eq!(vecs_ref.max_abs_diff(&vecs), 0.0, "seed {seed}");
                }
                Err(_) => {
                    assert!(
                        plan.injected(FaultPoint::ConsumerFold) > 0
                            || plan.injected(FaultPoint::PoisonTile) > 0,
                        "seed {seed}: only fold or poison faults may panic this build"
                    );
                }
            }
        }
        // After every cell: the arena guard ran (no files) whether the
        // build finished or unwound.
        let leftover: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().path())
            .collect();
        assert!(leftover.is_empty(), "seed {seed}: leftover spill files {leftover:?}");
    }
    assert_no_spill_files(&dir);
}
