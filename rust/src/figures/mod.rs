//! Experiment drivers: one function per paper figure/table (DESIGN.md §4).
//!
//! Each driver prints the series the paper plots and writes a CSV under
//! `out/`. They are shared by the `repro` CLI, the cargo benches, and the
//! examples. Sizes default to laptop scale (`--scale`, `--reps` adjust).

pub mod ablations;
pub mod cur_fig;
pub mod e2e;
pub mod error_curves;
pub mod kpca_class;
pub mod kpca_fig;
pub mod krr_fig;
pub mod spectral_fig;
pub mod tables;

use crate::cli::Args;
use crate::coordinator::{KernelEngine, RbfOracle};
use crate::data::{self, sigma, Dataset};
use std::io::Write;
use std::sync::Arc;

/// Shared experiment context.
pub struct Ctx {
    pub engine: Arc<KernelEngine>,
    pub scale: f64,
    pub reps: usize,
    pub seed: u64,
    pub out_dir: std::path::PathBuf,
}

impl Ctx {
    pub fn from_args(args: &Args) -> Ctx {
        let engine = if args.flag("cpu") {
            Arc::new(KernelEngine::cpu())
        } else {
            Arc::new(KernelEngine::auto())
        };
        if engine.is_pjrt() {
            eprintln!("# engine: PJRT (AOT artifacts)");
        } else if args.flag("cpu") {
            eprintln!("# engine: pure-rust (--cpu)");
        } else {
            eprintln!("# engine: pure-rust fallback (run `make artifacts` for PJRT)");
        }
        let out_dir = std::path::PathBuf::from(args.get_str("out", "out"));
        let _ = std::fs::create_dir_all(&out_dir);
        Ctx {
            engine,
            scale: args.get_f64("scale", 0.12),
            reps: args.get_usize("reps", 3),
            seed: args.get_u64("seed", 42),
            out_dir,
        }
    }

    /// Generate a dataset + calibrated RBF oracle at `target_eta`.
    pub fn oracle_for(&self, spec: data::DatasetSpec, target_eta: f64) -> (Dataset, Arc<RbfOracle>, f64) {
        let ds = spec.generate(self.scale, self.seed);
        let sig = sigma::calibrate_sigma(&ds.x, target_eta, 600, self.seed ^ 0x5161);
        let gamma = sigma::gamma_of_sigma(sig);
        let oracle = Arc::new(RbfOracle::new(Arc::new(ds.x.clone()), gamma, Arc::clone(&self.engine)));
        (ds, oracle, sig)
    }

    /// Open a CSV in the output directory.
    pub fn csv(&self, name: &str, header: &str) -> CsvOut {
        let path = self.out_dir.join(name);
        let mut f = std::io::BufWriter::new(std::fs::File::create(&path).expect("create csv"));
        writeln!(f, "{header}").unwrap();
        println!("{header}");
        CsvOut { f, path }
    }
}

/// CSV writer that mirrors rows to stdout.
pub struct CsvOut {
    f: std::io::BufWriter<std::fs::File>,
    pub path: std::path::PathBuf,
}

impl CsvOut {
    pub fn row(&mut self, line: &str) {
        writeln!(self.f, "{line}").unwrap();
        println!("{line}");
    }

    pub fn finish(mut self) {
        self.f.flush().unwrap();
        eprintln!("# wrote {}", self.path.display());
    }
}

const USAGE: &str = "\
repro — reproduce 'Towards More Efficient SPSD Matrix Approximation and CUR
Matrix Decomposition' (Wang, Zhang & Zhang, 2015)

USAGE: repro <command> [--scale F] [--reps N] [--seed N] [--cpu] [--out DIR]

COMMANDS
  fig2        CUR image reconstruction vs (s_c, s_r)        [paper Fig 2]
  fig3        kernel approx error vs s/n, uniform C         [paper Fig 3]
  fig4        same with uniform+adaptive^2 C                [paper Fig 4]
  fig5 fig6   KPCA misalignment vs time / vs c              [paper Fig 5-6]
  fig7 fig8   classification error vs c / time (k=3)        [paper Fig 7-8]
  fig9 fig10  classification error vs c / time (k=10)       [paper Fig 9-10]
  fig11 fig12 spectral clustering NMI vs c / time           [paper Fig 11-12]
  table3      U-matrix time + #entries per model            [paper Table 3]
  table4      sketch cost for the 5 S families              [paper Table 4]
  table5      CUR U-matrix cost: optimal vs fast            [paper Table 5]
  e2e         end-to-end approximation service demo
  ablate      DESIGN.md §5 ablations (P⊂S, leverage scaling, tile fill)
  krr         kernel ridge regression: exact vs approximate solves
  all         every figure + table at default scale

Common options:
  --scale F   dataset size factor vs the paper's n (default 0.12)
  --reps N    repetitions per randomized point (default 3)
  --cpu       force the pure-rust kernel engine (skip PJRT)
  --out DIR   CSV output directory (default ./out)
";

/// CLI dispatch for the `repro` binary.
pub fn run_cli() {
    let args = Args::from_env();
    let cmd = args.command.clone().unwrap_or_default();
    let ctx = || Ctx::from_args(&args);
    match cmd.as_str() {
        "fig2" => cur_fig::fig2(&ctx(), &args),
        "fig3" => error_curves::run(&ctx(), &args, false),
        "fig4" => error_curves::run(&ctx(), &args, true),
        "fig5" | "fig6" => kpca_fig::run(&ctx(), &args),
        "fig7" | "fig8" => kpca_class::run(&ctx(), &args, 3),
        "fig9" | "fig10" => kpca_class::run(&ctx(), &args, 10),
        "fig11" | "fig12" => spectral_fig::run(&ctx(), &args),
        "table3" => tables::table3(&ctx(), &args),
        "table4" => tables::table4(&ctx(), &args),
        "table5" => tables::table5(&ctx(), &args),
        "e2e" => e2e::run(&ctx(), &args),
        "ablate" => ablations::run(&ctx(), &args),
        "krr" => krr_fig::run(&ctx(), &args),
        "all" => {
            let c = ctx();
            cur_fig::fig2(&c, &args);
            error_curves::run(&c, &args, false);
            error_curves::run(&c, &args, true);
            kpca_fig::run(&c, &args);
            kpca_class::run(&c, &args, 3);
            kpca_class::run(&c, &args, 10);
            spectral_fig::run(&c, &args);
            tables::table3(&c, &args);
            tables::table4(&c, &args);
            tables::table5(&c, &args);
            e2e::run(&c, &args);
        }
        _ => {
            print!("{USAGE}");
            if !cmd.is_empty() {
                eprintln!("\nerror: unknown command {cmd:?}");
                std::process::exit(2);
            }
        }
    }
}
