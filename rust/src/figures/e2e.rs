//! End-to-end serving demo: run the coordinator as an approximation
//! service over a real (synthetic-LIBSVM) workload, stream a mixed batch
//! of requests through the bounded queue, and report latency/throughput
//! plus the quality each method achieved. This is the driver behind
//! `examples/e2e_service.rs` and the EXPERIMENTS.md end-to-end record.

use super::Ctx;
use crate::cli::Args;
use crate::coordinator::{ApproxRequest, ApproxService, MethodSpec, ServiceConfig};
use crate::data::{self, sigma};
use crate::exec::ExecPolicy;
use crate::sketch::SketchKind;
use crate::util::Stopwatch;
use std::sync::{mpsc, Arc};

pub fn run(ctx: &Ctx, args: &Args) {
    let spec = data::find_spec(args.get_str("dataset", "PenDigit")).expect("unknown dataset");
    let ds = spec.generate(ctx.scale, ctx.seed);
    let n = ds.x.rows();
    let sig = sigma::calibrate_sigma(&ds.x, 0.9, 500, ctx.seed);
    let gamma = sigma::gamma_of_sigma(sig);
    let oracle = Arc::new(crate::coordinator::RbfOracle::new(
        Arc::new(ds.x.clone()),
        gamma,
        Arc::clone(&ctx.engine),
    ));
    let workers = args.get_usize("workers", 4);
    let capacity = args.get_usize("capacity", 16);
    // Optional service-level memory cap (bytes): over-cap requests queue
    // in the admission FIFO and may be served down the degrade ladder
    // instead of being shed.
    let memory_cap = match args.get_u64("memory-cap", 0) {
        0 => None,
        cap => Some(cap),
    };
    let svc = ApproxService::new(
        Arc::clone(&oracle) as Arc<dyn crate::coordinator::KernelOracle + Send + Sync>,
        ServiceConfig {
            workers,
            queue_capacity: capacity,
            memory_cap,
            ..Default::default()
        },
    );

    let c = (n / 100).max(10);
    let requests = args.get_usize("requests", 48);
    // Mixed execution policies: the service default (materialized) and
    // the streamed pipeline — same unified exec surface either way.
    let tile = args.get_usize("tile", 0);
    println!("# e2e: dataset={} n={n} c={c} workers={workers} capacity={capacity}", spec.name);
    let (tx, rx) = mpsc::channel();
    let sw = Stopwatch::start();
    for i in 0..requests {
        let method = match i % 3 {
            0 => MethodSpec::Nystrom,
            1 => MethodSpec::Fast { s: 4 * c, kind: SketchKind::Uniform },
            _ => MethodSpec::Fast { s: 8 * c, kind: SketchKind::Uniform },
        };
        let policy = (tile > 0).then(|| ExecPolicy::streamed(tile));
        svc.submit(
            ApproxRequest {
                id: i as u64,
                method,
                c,
                k: 5,
                seed: ctx.seed + i as u64,
                policy,
                precision: crate::stream::Precision::F64,
                deadline: None,
            },
            tx.clone(),
        );
    }
    svc.drain();
    let wall = sw.secs();
    drop(tx);
    let resps: Vec<_> = rx.iter().collect();
    assert_eq!(resps.len(), requests, "all requests must be answered");

    let mut csv = ctx.csv(
        "e2e.csv",
        "id,method,entries,compute_secs,total_secs,queue_wait_secs,ladder_secs,predicted_peak_bytes,numeric_health",
    );
    for r in &resps {
        let (entries, compute, predicted) = match &r.meta {
            Some(m) => (
                m.entries.unwrap_or(0),
                m.compute_secs,
                m.predicted_peak_bytes.unwrap_or(0),
            ),
            None => (0, 0.0, 0),
        };
        // One health cell per request: "clean", or the regularization
        // name plus the integrity counters when anything was noted.
        let health = match &r.numeric_health {
            None => "unserved".to_string(),
            Some(h) if h.is_clean() => "clean".to_string(),
            Some(h) => format!(
                "{}:esc={}:quar={}:corrupt={}",
                h.regularization.name(),
                h.escalations,
                h.quarantined_tiles,
                h.corrupt_reads
            ),
        };
        csv.row(&format!(
            "{},{},{},{:.4},{:.4},{:.4},{:.4},{},{}",
            r.id, r.method, entries, compute, r.total_secs, r.queue_wait_secs, r.ladder_secs,
            predicted, health
        ));
    }
    csv.finish();

    // One coherent read of every counter — the per-field .get() reads
    // this replaces could interleave with concurrently finishing work.
    let m = svc.metrics().snapshot();
    println!(
        "# completed={} failed={} rejected={} expired={} faulted={} queued={} degraded={}",
        m.completed, m.failed, m.rejected_overload, m.expired_deadline, m.faulted, m.queued,
        m.degraded
    );
    println!(
        "# latency: n={} mean={:?} p50={:?} p95={:?} max={:?}",
        m.latency.count, m.latency.mean, m.latency.p50, m.latency.p95, m.latency.max
    );
    println!(
        "# queue-wait: n={} mean={:?} p50={:?} p95={:?} max={:?}",
        m.queue_wait.count, m.queue_wait.mean, m.queue_wait.p50, m.queue_wait.p95, m.queue_wait.max
    );
    let served_wait: f64 = resps.iter().map(|r| r.queue_wait_secs).sum();
    let ladder: f64 = resps.iter().map(|r| r.ladder_secs).sum();
    println!("# admission: queue_wait_total={served_wait:.4}s ladder_total={ladder:.6}s");
    let healths: Vec<_> = resps.iter().filter_map(|r| r.numeric_health.as_ref()).collect();
    let clean = healths.iter().filter(|h| h.is_clean()).count();
    let worst_cond = healths.iter().map(|h| h.core_cond_est).fold(0.0f64, f64::max);
    let escalations: u64 = healths.iter().map(|h| h.escalations).sum();
    let quarantined: u64 = healths.iter().map(|h| h.quarantined_tiles).sum();
    let corrupt: u64 = healths.iter().map(|h| h.corrupt_reads).sum();
    println!(
        "# numeric-health: clean={clean}/{} worst_cond={worst_cond:.3e} \
         escalations={escalations} quarantined_tiles={quarantined} corrupt_reads={corrupt}",
        healths.len()
    );
    if let Some(profile) = resps.iter().filter_map(|r| r.meta.as_ref()).find_map(|m| m.stage_profile.as_ref()) {
        println!("# stage profile (first served request):");
        for line in profile.summary_lines() {
            println!("#   {line}");
        }
    }
    println!("# throughput: {:.2} req/s ({} requests in {:.2}s)", requests as f64 / wall, requests, wall);
    if ctx.engine.is_pjrt() {
        let (batches, execs, secs) = oracle_stats(&ctx.engine);
        println!("# PJRT: {batches} batches, {execs} tile execs, {secs:.2}s in runtime");
    }
}

fn oracle_stats(engine: &crate::coordinator::KernelEngine) -> (u64, u64, f64) {
    let tiles = engine.pjrt_tiles.load(std::sync::atomic::Ordering::Relaxed);
    (0, tiles, 0.0)
}
