//! Composable tile consumers: each folds one streamed row-tile into a
//! bounded accumulator as it arrives.
//!
//! Gather-style consumers ([`CollectConsumer`], [`RowGather`],
//! [`ColSubsetCollect`], and [`SketchFold`] over column-selection /
//! CountSketch ops) are bit-identical to the materialized path because
//! tiles arrive in ascending row order and every destination element is
//! touched by the same additions in the same order. Accumulation-style
//! consumers ([`GramFold`], [`PrototypeUFold`], [`ConjugateFold`], dense /
//! SRHT [`SketchFold`]) regroup a sum over `n` by tile boundaries, so they
//! match the materialized path only up to reduction reordering (≤1e-12
//! relative — asserted by `tests/stream_equiv.rs`).

use crate::linalg::{gemm, Matrix, MatrixF32, Tile};
use crate::obs::{self, Stage};
use crate::sketch::{self, SketchOp};
use crate::util::Rng;

/// Folds streamed row-tiles. `consume` is called once per tile, in
/// ascending `r0` order, with `tile.rows()` rows starting at virtual row
/// `r0`.
///
/// Mixed precision: the pipeline hands each consumer a typed [`Tile`]
/// through `consume_tile`. The default `consume_f32` promotes the tile to
/// f64 (`f32 -> f64` is exact, so promotion changes no bits of the tile
/// data) and reuses `consume` — every fold therefore accumulates into f64
/// state regardless of the tile element type, and the row-ordered
/// bit-compat contract documented above holds *within* each precision.
pub trait TileConsumer {
    fn consume(&mut self, r0: usize, tile: &Matrix);

    /// Fold an f32 tile. The default promotes (exactly) and delegates to
    /// the f64 fold; consumers with a profitable native narrow path may
    /// override.
    fn consume_f32(&mut self, r0: usize, tile: &MatrixF32) {
        self.consume(r0, &tile.promote());
    }

    /// Typed dispatch used by `run_pipeline_prec`.
    fn consume_tile(&mut self, r0: usize, tile: &Tile) {
        match tile {
            Tile::F64(m) => self.consume(r0, m),
            Tile::F32(m) => self.consume_f32(r0, m),
        }
    }

    /// Serialize the fold's accumulated state as one matrix, for the
    /// checkpointed pipeline. `None` (the default) opts the consumer out
    /// of checkpointing — the pipeline persists state only when *every*
    /// consumer in the pass snapshots, so a single gather or sampler in
    /// the set disables resume for that pass rather than corrupting it.
    ///
    /// Contract: `restore(snapshot())` followed by the remaining tiles
    /// must be bit-identical to an uninterrupted fold. Only the
    /// prefix-sum folds (Gram, sketch, leverage pass-1, matvec) can
    /// honor that; rng-consuming or position-dependent consumers must
    /// keep the `None` default.
    fn snapshot(&self) -> Option<Matrix> {
        None
    }

    /// Restore state captured by [`TileConsumer::snapshot`]. Returns
    /// `false` (leaving the consumer untouched) when the state's shape
    /// does not match — the pipeline treats that as "start from scratch".
    fn restore(&mut self, _state: &Matrix) -> bool {
        false
    }
}

/// Reassembles the streamed matrix (used when the full panel *is* the
/// output, e.g. the `C` of `C U C^T`).
pub struct CollectConsumer {
    out: Matrix,
}

impl CollectConsumer {
    pub fn new(rows: usize, cols: usize) -> Self {
        CollectConsumer { out: Matrix::zeros(rows, cols) }
    }

    pub fn into_matrix(self) -> Matrix {
        self.out
    }
}

impl TileConsumer for CollectConsumer {
    fn consume(&mut self, r0: usize, tile: &Matrix) {
        for r in 0..tile.rows() {
            self.out.row_mut(r0 + r).copy_from_slice(tile.row(r));
        }
    }
}

/// Gathers the rows at `indices` (in the order given, duplicates allowed)
/// into an `indices.len() x width` matrix: `out[j, :] = stream[indices[j],
/// cols]`. With `cols = None` the full tile width is kept. This is how the
/// streamed builds extract `W = C[P, :]` and `C[S, :]` without a second
/// pass.
pub struct RowGather {
    indices: Vec<usize>,
    cols: Option<Vec<usize>>,
    out: Matrix,
}

impl RowGather {
    pub fn new(indices: Vec<usize>, width: usize) -> Self {
        let out = Matrix::zeros(indices.len(), width);
        RowGather { indices, cols: None, out }
    }

    /// Gather only the given columns of each selected row.
    pub fn with_cols(indices: Vec<usize>, cols: Vec<usize>) -> Self {
        let out = Matrix::zeros(indices.len(), cols.len());
        RowGather { indices, cols: Some(cols), out }
    }

    pub fn into_matrix(self) -> Matrix {
        self.out
    }
}

impl TileConsumer for RowGather {
    fn consume(&mut self, r0: usize, tile: &Matrix) {
        let r1 = r0 + tile.rows();
        for (j, &i) in self.indices.iter().enumerate() {
            if i >= r0 && i < r1 {
                let src = tile.row(i - r0);
                match &self.cols {
                    None => self.out.row_mut(j).copy_from_slice(src),
                    Some(cols) => {
                        let dst = self.out.row_mut(j);
                        for (d, &cc) in dst.iter_mut().zip(cols.iter()) {
                            *d = src[cc];
                        }
                    }
                }
            }
        }
    }
}

/// Collects a column subset of the stream: `out[:, j] = stream[:,
/// cols[j]]` (the `C = A[:, P_C]` of a streamed CUR build over full-width
/// tiles).
pub struct ColSubsetCollect {
    cols: Vec<usize>,
    out: Matrix,
}

impl ColSubsetCollect {
    pub fn new(rows: usize, cols: Vec<usize>) -> Self {
        let out = Matrix::zeros(rows, cols.len());
        ColSubsetCollect { cols, out }
    }

    pub fn into_matrix(self) -> Matrix {
        self.out
    }
}

impl TileConsumer for ColSubsetCollect {
    fn consume(&mut self, r0: usize, tile: &Matrix) {
        for r in 0..tile.rows() {
            let src = tile.row(r);
            let dst = self.out.row_mut(r0 + r);
            for (d, &cc) in dst.iter_mut().zip(self.cols.iter()) {
                *d = src[cc];
            }
        }
    }
}

/// Fused sketch application: accumulates `S^T A` tile by tile via
/// [`SketchOp::fold_rows`] — row gather for column selection, signed
/// hash-accumulate for CountSketch, direct Sylvester-Hadamard rows for
/// SRHT, `gemm_tn` for Gaussian. Peak memory `O(s · width)` regardless of
/// `n`.
pub struct SketchFold<'a> {
    op: &'a SketchOp,
    acc: Matrix,
    /// Persistent `s x width` scratch for the Gaussian (`Dense`) fold, so
    /// the hot path runs `gemm_tn_into` with zero per-tile output
    /// allocation. Empty for the other families.
    scratch: Matrix,
}

impl<'a> SketchFold<'a> {
    pub fn new(op: &'a SketchOp, width: usize) -> Self {
        let scratch = match op {
            SketchOp::Dense(_) => Matrix::zeros(op.s(), width),
            _ => Matrix::zeros(0, 0),
        };
        SketchFold { op, acc: Matrix::zeros(op.s(), width), scratch }
    }

    /// The accumulated `S^T A`.
    pub fn into_matrix(self) -> Matrix {
        self.acc
    }
}

impl TileConsumer for SketchFold<'_> {
    fn consume(&mut self, r0: usize, tile: &Matrix) {
        let _s = obs::span(Stage::SketchFold);
        if let SketchOp::Dense(s_mat) = self.op {
            // acc += S[r0..r1, :]^T · tile (same product as fold_rows's
            // Dense branch, through the reused scratch)
            let sub = s_mat.block(r0, r0 + tile.rows(), 0, s_mat.cols());
            gemm::gemm_tn_into(&sub, tile, &mut self.scratch);
            self.acc.axpy(1.0, &self.scratch);
        } else {
            self.op.fold_rows(r0, tile, &mut self.acc);
        }
    }

    // `scratch` is fully overwritten each consume, so the accumulator is
    // the whole state.
    fn snapshot(&self) -> Option<Matrix> {
        Some(self.acc.clone())
    }

    fn restore(&mut self, state: &Matrix) -> bool {
        if state.rows() != self.acc.rows() || state.cols() != self.acc.cols() {
            return false;
        }
        self.acc = state.clone();
        true
    }
}

/// Gram accumulation `A^T A = Σ_t tile_t^T tile_t` via per-tile `syrk_tn`
/// into a reused scratch — exactly symmetric output, `O(width²)` memory.
pub struct GramFold {
    acc: Matrix,
    scratch: Matrix,
}

impl GramFold {
    pub fn new(width: usize) -> Self {
        GramFold { acc: Matrix::zeros(width, width), scratch: Matrix::zeros(width, width) }
    }

    pub fn into_matrix(self) -> Matrix {
        self.acc
    }
}

impl TileConsumer for GramFold {
    fn consume(&mut self, _r0: usize, tile: &Matrix) {
        let _s = obs::span(Stage::GramFold);
        gemm::syrk_tn_into(tile, &mut self.scratch);
        self.acc.axpy(1.0, &self.scratch);
    }

    // `scratch` is fully overwritten each consume, so the accumulator is
    // the whole state.
    fn snapshot(&self) -> Option<Matrix> {
        Some(self.acc.clone())
    }

    fn restore(&mut self, state: &Matrix) -> bool {
        if state.rows() != self.acc.rows() || state.cols() != self.acc.cols() {
            return false;
        }
        self.acc = state.clone();
        true
    }
}

/// Pass-1 leverage fold (the streamed leverage estimator): accumulates the
/// state approximate row-leverage scores of the streamed panel are computed
/// from, in `O(c²)` (exact Gram `C^T C`) or `O(m·c)` (projection surrogate
/// `Ω^T C`) memory — never the `n x c` panel.
///
/// The exact mode accumulates the Gram **row by row in ascending order**
/// (not per-tile `syrk` like [`GramFold`]): every `G[i][j]` receives the
/// same additions in the same order for every tile grouping, so the folded
/// Gram — and every score, draw and index derived from it — is
/// bit-identical across tile sizes. That determinism is what lets
/// `tests/stream_equiv.rs` assert bit-equality for the streamed leverage
/// family; the per-row rank-1 updates cost the same flops as `syrk`, just
/// less blocked (fine at leverage-sized `c`). The sketched mode folds
/// `Ω^T C` through [`SketchOp::fold_rows`]; its reductions regroup by
/// tile, so results match only to reduction-reordering tolerance.
pub struct LeverageFold<'a> {
    acc: LevAcc<'a>,
}

enum LevAcc<'a> {
    /// Upper triangle of `C^T C`, row-ordered accumulation.
    Exact { gram: Matrix },
    /// `Ω^T C` for a projection sketch `Ω` (surrogate `(Ω^T C)^T (Ω^T C)`).
    Sketched { op: &'a SketchOp, acc: Matrix },
}

impl<'a> LeverageFold<'a> {
    /// Exact `width x width` Gram fold.
    pub fn exact(width: usize) -> Self {
        LeverageFold { acc: LevAcc::Exact { gram: Matrix::zeros(width, width) } }
    }

    /// Sketched fold `Ω^T C`; the estimate comes from the Gram surrogate
    /// `C^T Ω Ω^T C` (a subspace embedding makes it `(1±ε)`-accurate).
    pub fn sketched(op: &'a SketchOp, width: usize) -> Self {
        LeverageFold { acc: LevAcc::Sketched { op, acc: Matrix::zeros(op.s(), width) } }
    }

    /// Finish the fold: whitening factor + numerical rank.
    pub fn into_estimate(self) -> sketch::LeverageEstimate {
        match self.acc {
            LevAcc::Exact { mut gram } => {
                // mirror the accumulated upper triangle (exact copy, so the
                // result stays deterministic)
                for i in 0..gram.rows() {
                    for j in (i + 1)..gram.cols() {
                        gram[(j, i)] = gram[(i, j)];
                    }
                }
                sketch::approx_leverage_from_gram(&gram)
            }
            LevAcc::Sketched { acc, .. } => sketch::approx_leverage_from_gram(&acc.gram_tn()),
        }
    }
}

impl TileConsumer for LeverageFold<'_> {
    fn consume(&mut self, r0: usize, tile: &Matrix) {
        let _s = obs::span(Stage::GramFold);
        match &mut self.acc {
            LevAcc::Exact { gram } => {
                let w = tile.cols();
                debug_assert_eq!(w, gram.cols(), "tile width != gram size");
                for r in 0..tile.rows() {
                    let row = tile.row(r);
                    for i in 0..w {
                        let vi = row[i];
                        let dst = gram.row_mut(i);
                        for j in i..w {
                            dst[j] += vi * row[j];
                        }
                    }
                }
            }
            LevAcc::Sketched { op, acc } => op.fold_rows(r0, tile, acc),
        }
    }

    // Both variants keep their whole state in one matrix (the exact Gram
    // triangle or the sketched `Ω^T C`); the mirror in `into_estimate`
    // runs after the fold, so an upper-triangle snapshot restores exactly.
    fn snapshot(&self) -> Option<Matrix> {
        Some(match &self.acc {
            LevAcc::Exact { gram } => gram.clone(),
            LevAcc::Sketched { acc, .. } => acc.clone(),
        })
    }

    fn restore(&mut self, state: &Matrix) -> bool {
        let dst = match &mut self.acc {
            LevAcc::Exact { gram } => gram,
            LevAcc::Sketched { acc, .. } => acc,
        };
        if state.rows() != dst.rows() || state.cols() != dst.cols() {
            return false;
        }
        *dst = state.clone();
        true
    }
}

/// Pass-2 leverage sampler: scores each streamed row of `C` against a
/// [`LeverageEstimate`](sketch::LeverageEstimate), draws membership with
/// `p_i = min(1, s·l_i/rank)` (Algorithm 2), and gathers the selected rows
/// — scoring, drawing `S` and extracting `C[S, :]` in one sweep over the
/// panel, with `O(|S|·c)` retained state. Forced indices (the `P ⊂ S`
/// trick) are always kept, at scale 1.
///
/// Exactly one Bernoulli is drawn per row, in ascending row order, whether
/// or not the row is forced: the rng stream is therefore independent of
/// the tile grouping, which keeps the drawn `S` bit-identical across tile
/// sizes (given a bit-identical estimate — see [`LeverageFold`]).
pub struct LeverageSampler<'a> {
    est: &'a sketch::LeverageEstimate,
    /// Expected number of sampled (non-forced) rows.
    s_target: usize,
    /// Apply the `1/sqrt(p)` importance scaling (§4.5: off is the paper's
    /// stability trick).
    scaled: bool,
    /// Sorted, deduplicated forced indices (`P`).
    forced: Vec<usize>,
    n: usize,
    rng: &'a mut Rng,
    indices: Vec<usize>,
    scales: Vec<f64>,
    /// Gathered rows, flattened row-major at `width` columns.
    data: Vec<f64>,
    width: usize,
    /// Rows the Bernoulli draw hit — forced or not, exactly like the index
    /// count `sketch::leverage` checks before its uniform-pick fallback
    /// (callers use 0 to trigger the same fallback).
    sampled: usize,
}

impl<'a> LeverageSampler<'a> {
    pub fn new(
        est: &'a sketch::LeverageEstimate,
        s_target: usize,
        scaled: bool,
        mut forced: Vec<usize>,
        n: usize,
        width: usize,
        rng: &'a mut Rng,
    ) -> Self {
        forced.sort_unstable();
        forced.dedup();
        LeverageSampler {
            est,
            s_target,
            scaled,
            forced,
            n,
            rng,
            indices: Vec::new(),
            scales: Vec::new(),
            data: Vec::new(),
            width,
            sampled: 0,
        }
    }

    /// `(indices, scales, gathered rows C[S, :], Bernoulli hit count)`.
    /// Indices are ascending; rows are unscaled (scales are reported
    /// separately, matching what `assemble_sks` expects).
    pub fn into_parts(self) -> (Vec<usize>, Vec<f64>, Matrix, usize) {
        let rows = Matrix::from_vec(self.indices.len(), self.width, self.data);
        (self.indices, self.scales, rows, self.sampled)
    }
}

impl TileConsumer for LeverageSampler<'_> {
    fn consume(&mut self, r0: usize, tile: &Matrix) {
        debug_assert_eq!(tile.cols(), self.width, "tile width != sampler width");
        for r in 0..tile.rows() {
            let i = r0 + r;
            let row = tile.row(r);
            let l = self.est.row_score(row);
            let p = if self.est.rank > 0.0 {
                (self.s_target as f64 * l / self.est.rank).min(1.0)
            } else {
                (self.s_target as f64 / self.n.max(1) as f64).min(1.0)
            };
            let hit = self.rng.bernoulli(p);
            let is_forced = self.forced.binary_search(&i).is_ok();
            if hit {
                self.sampled += 1;
            }
            if hit || is_forced {
                self.indices.push(i);
                self.scales.push(if !is_forced && self.scaled && p > 0.0 {
                    1.0 / p.sqrt()
                } else {
                    1.0
                });
                self.data.extend_from_slice(row);
            }
        }
    }
}

/// Matvec fold `A^T x`: each tile contributes `tile^T x[r0..r1]`. The
/// first pass of the implicit `C U C^T` matvec.
pub struct MatvecFold<'a> {
    x: &'a [f64],
    acc: Vec<f64>,
}

impl<'a> MatvecFold<'a> {
    pub fn new(x: &'a [f64], width: usize) -> Self {
        MatvecFold { x, acc: vec![0.0; width] }
    }

    pub fn into_vec(self) -> Vec<f64> {
        self.acc
    }
}

impl TileConsumer for MatvecFold<'_> {
    fn consume(&mut self, r0: usize, tile: &Matrix) {
        let part = tile.tr_matvec(&self.x[r0..r0 + tile.rows()]);
        for (a, p) in self.acc.iter_mut().zip(part) {
            *a += p;
        }
    }

    fn snapshot(&self) -> Option<Matrix> {
        Some(Matrix::from_vec(1, self.acc.len(), self.acc.clone()))
    }

    fn restore(&mut self, state: &Matrix) -> bool {
        if state.rows() != 1 || state.cols() != self.acc.len() {
            return false;
        }
        self.acc.copy_from_slice(state.row(0));
        true
    }
}

/// Prototype-model U fold over full-K row tiles:
/// `U = C† K (C†)^T = Σ_t C†[:, t-rows] · (K_t · (C†)^T)`, so the `n x n`
/// kernel is never stored — peak extra memory `O(tile_rows · n + c²)`.
pub struct PrototypeUFold<'a> {
    /// `C†`, c x n.
    cp: &'a Matrix,
    acc: Matrix,
    /// `tile_rows x c` scratch for `K_t (C†)^T`, reallocated only when the
    /// tile height changes (once, at the ragged last tile).
    tmp: Matrix,
    /// `c x c` scratch for the per-tile product.
    prod: Matrix,
}

impl<'a> PrototypeUFold<'a> {
    pub fn new(cp: &'a Matrix) -> Self {
        let c = cp.rows();
        PrototypeUFold {
            cp,
            acc: Matrix::zeros(c, c),
            tmp: Matrix::zeros(0, c),
            prod: Matrix::zeros(c, c),
        }
    }

    /// The accumulated `C† K (C†)^T` (symmetrized — tile grouping breaks
    /// exact symmetry at the last bit).
    pub fn into_matrix(self) -> Matrix {
        let mut u = self.acc;
        u.symmetrize();
        u
    }
}

impl TileConsumer for PrototypeUFold<'_> {
    fn consume(&mut self, r0: usize, tile: &Matrix) {
        let _s = obs::span(Stage::GramFold);
        let t = tile.rows();
        let c = self.cp.rows();
        if self.tmp.rows() != t {
            self.tmp = Matrix::zeros(t, c);
        }
        // tmp = K_t (C†)^T : (t x n)·(n x c) — cp is stored c x n, so this
        // is a plain nt-product into the reused scratch.
        gemm::gemm_nt_into(tile, self.cp, &mut self.tmp);
        // acc += C†[:, r0..r1] · tmp : (c x t)·(t x c)
        let cp_block = self.cp.block(0, c, r0, r0 + t);
        gemm::gemm_into(&cp_block, &self.tmp, &mut self.prod);
        self.acc.axpy(1.0, &self.prod);
    }
}

/// Streamed `S^T K S` for projection sketches over full-K row tiles:
/// each tile contributes `S[r0..r1, :]^T · (K_t S)` with
/// `K_t S = (S^T K_t^T)^T`, so the projection families observe their `n²`
/// entries (Table 4) without ever storing them — peak extra memory
/// `O(tile_rows · (n + s) + s²)`.
pub struct ConjugateFold<'a> {
    op: &'a SketchOp,
    acc: Matrix,
}

impl<'a> ConjugateFold<'a> {
    pub fn new(op: &'a SketchOp) -> Self {
        let s = op.s();
        ConjugateFold { op, acc: Matrix::zeros(s, s) }
    }

    /// The accumulated `S^T K S` (symmetrized).
    pub fn into_matrix(self) -> Matrix {
        let mut m = self.acc;
        m.symmetrize();
        m
    }
}

impl TileConsumer for ConjugateFold<'_> {
    fn consume(&mut self, r0: usize, tile: &Matrix) {
        let _s = obs::span(Stage::SketchFold);
        let kts = self.op.apply_left(&tile.transpose()).transpose(); // t x s
        self.op.fold_rows(r0, &kts, &mut self.acc);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::{self, SketchKind};
    use crate::stream::{run_pipeline, MatrixSource};
    use crate::util::Rng;

    fn stream_all(a: &Matrix, tile: usize, consumers: &mut [&mut dyn TileConsumer]) {
        let src = MatrixSource::new(a);
        run_pipeline(&src, tile, 2, consumers);
    }

    #[test]
    fn row_gather_matches_select_rows() {
        let mut rng = Rng::new(0);
        let a = Matrix::randn(23, 5, &mut rng);
        let idx = vec![0usize, 7, 7, 19, 22];
        for tile in [1usize, 4, 23] {
            let mut g = RowGather::new(idx.clone(), 5);
            stream_all(&a, tile, &mut [&mut g]);
            assert_eq!(g.into_matrix().max_abs_diff(&a.select_rows(&idx)), 0.0);
        }
        let mut g = RowGather::with_cols(vec![3, 11], vec![1, 4]);
        stream_all(&a, 6, &mut [&mut g]);
        let got = g.into_matrix();
        assert_eq!(got[(0, 0)], a[(3, 1)]);
        assert_eq!(got[(1, 1)], a[(11, 4)]);
    }

    #[test]
    fn col_subset_collect_matches_select_cols() {
        let mut rng = Rng::new(1);
        let a = Matrix::randn(17, 9, &mut rng);
        let cols = vec![0usize, 2, 8];
        let mut c = ColSubsetCollect::new(17, cols.clone());
        stream_all(&a, 5, &mut [&mut c]);
        assert_eq!(c.into_matrix().max_abs_diff(&a.select_cols(&cols)), 0.0);
    }

    #[test]
    fn sketch_fold_matches_apply_left_all_families() {
        let mut rng = Rng::new(2);
        let n = 40;
        let a = Matrix::randn(n, 6, &mut rng);
        for kind in [
            SketchKind::Uniform,
            SketchKind::Leverage { scaled: true },
            SketchKind::Gaussian,
            SketchKind::Srht,
            SketchKind::CountSketch,
        ] {
            let basis = Matrix::randn(n, 4, &mut rng);
            let op = sketch::build(kind, n, 12, Some(&basis), &mut rng);
            let direct = op.apply_left(&a);
            for tile in [1usize, 7, 40] {
                let mut fold = SketchFold::new(&op, 6);
                stream_all(&a, tile, &mut [&mut fold]);
                let folded = fold.into_matrix();
                let scale = direct.fro_norm().max(1.0);
                assert!(
                    folded.max_abs_diff(&direct) < 1e-12 * scale,
                    "{} tile={tile}",
                    kind.name()
                );
            }
        }
    }

    #[test]
    fn gram_fold_matches_syrk_tn() {
        let mut rng = Rng::new(3);
        let a = Matrix::randn(31, 7, &mut rng);
        let direct = gemm::syrk_tn(&a);
        for tile in [1usize, 8, 31] {
            let mut fold = GramFold::new(7);
            stream_all(&a, tile, &mut [&mut fold]);
            let g = fold.into_matrix();
            assert!(g.max_abs_diff(&direct) < 1e-12 * direct.fro_norm().max(1.0));
            assert_eq!(g.max_abs_diff(&g.transpose()), 0.0, "exactly symmetric");
        }
    }

    #[test]
    fn matvec_fold_matches_tr_matvec() {
        let mut rng = Rng::new(4);
        let a = Matrix::randn(26, 5, &mut rng);
        let x: Vec<f64> = (0..26).map(|i| (i as f64 * 0.3).sin()).collect();
        let direct = a.tr_matvec(&x);
        let mut fold = MatvecFold::new(&x, 5);
        stream_all(&a, 9, &mut [&mut fold]);
        let got = fold.into_vec();
        for (g, d) in got.iter().zip(&direct) {
            assert!((g - d).abs() < 1e-12);
        }
    }

    #[test]
    fn conjugate_fold_matches_dense_conjugate() {
        let mut rng = Rng::new(5);
        let g = Matrix::randn(24, 24, &mut rng);
        let k = g.matmul_tr(&g);
        for kind in [SketchKind::Gaussian, SketchKind::Srht, SketchKind::CountSketch] {
            let op = sketch::build(kind, 24, 10, None, &mut rng);
            let mut direct = op.conjugate(&k);
            direct.symmetrize();
            for tile in [5usize, 24] {
                let mut fold = ConjugateFold::new(&op);
                stream_all(&k, tile, &mut [&mut fold]);
                let got = fold.into_matrix();
                assert!(
                    got.max_abs_diff(&direct) < 1e-11 * direct.fro_norm().max(1.0),
                    "{} tile={tile}",
                    kind.name()
                );
            }
        }
    }

    #[test]
    fn leverage_fold_estimate_is_bit_identical_across_tilings() {
        let mut rng = Rng::new(7);
        let c = Matrix::randn(53, 6, &mut rng);
        let reference = {
            let mut fold = LeverageFold::exact(6);
            stream_all(&c, 53, &mut [&mut fold]);
            fold.into_estimate()
        };
        // the exact scores must come out of the Gram factorization
        let exact = sketch::leverage_scores(&c);
        let got = reference.scores(&c);
        for (g, e) in got.iter().zip(&exact) {
            assert!((g - e).abs() < 1e-8, "gram score {g} vs svd {e}");
        }
        for tile in [1usize, 7, 16] {
            let mut fold = LeverageFold::exact(6);
            stream_all(&c, tile, &mut [&mut fold]);
            let est = fold.into_estimate();
            assert_eq!(est.rank, reference.rank, "tile={tile}");
            assert_eq!(
                est.whiten.max_abs_diff(&reference.whiten),
                0.0,
                "tile={tile}: row-ordered Gram must not depend on tiling"
            );
        }
    }

    #[test]
    fn leverage_fold_sketched_surrogate_close_on_low_rank() {
        let mut rng = Rng::new(8);
        let c = Matrix::randn(48, 3, &mut rng).matmul(&Matrix::randn(3, 6, &mut rng));
        // m = n_pad rows: the SRHT is orthogonal, surrogate == exact Gram
        let op = sketch::srht_sketch(48, 64, &mut rng);
        let mut fold = LeverageFold::sketched(&op, 6);
        stream_all(&c, 10, &mut [&mut fold]);
        let est = fold.into_estimate();
        let exact = sketch::leverage_scores(&c);
        for (i, (g, e)) in est.scores(&c).iter().zip(&exact).enumerate() {
            assert!((g - e).abs() < 1e-8, "row {i}: surrogate {g} vs exact {e}");
        }
    }

    #[test]
    fn leverage_sampler_is_tile_invariant_and_keeps_forced() {
        let mut rng = Rng::new(9);
        let c = Matrix::randn(61, 5, &mut rng);
        let est = sketch::approx_leverage_from_gram(&c.gram_tn());
        let reference = {
            let mut r = Rng::new(11);
            let mut s = LeverageSampler::new(&est, 12, false, vec![40, 3, 3], 61, 5, &mut r);
            stream_all(&c, 61, &mut [&mut s]);
            s.into_parts()
        };
        let (ref_idx, ref_scales, ref_rows, _) = reference;
        assert!(ref_idx.windows(2).all(|w| w[0] < w[1]), "ascending, unique");
        assert!(ref_idx.contains(&3) && ref_idx.contains(&40), "forced kept");
        assert!(ref_scales.iter().all(|&s| s == 1.0), "unscaled mode");
        assert_eq!(ref_rows.max_abs_diff(&c.select_rows(&ref_idx)), 0.0);
        for tile in [1usize, 9, 32] {
            let mut r = Rng::new(11);
            let mut s = LeverageSampler::new(&est, 12, false, vec![40, 3, 3], 61, 5, &mut r);
            stream_all(&c, tile, &mut [&mut s]);
            let (idx, _, rows, _) = s.into_parts();
            assert_eq!(idx, ref_idx, "tile={tile}: drawn S must not depend on tiling");
            assert_eq!(rows.max_abs_diff(&ref_rows), 0.0, "tile={tile}");
        }
    }

    #[test]
    fn consume_tile_dispatch_promotes_f32_exactly() {
        let mut rng = Rng::new(12);
        let a = Matrix::randn(14, 4, &mut rng);
        let narrow = a.demote();
        let mut c64 = CollectConsumer::new(14, 4);
        c64.consume_tile(0, &Tile::F64(a.clone()));
        assert_eq!(c64.into_matrix().max_abs_diff(&a), 0.0);
        let mut c32 = CollectConsumer::new(14, 4);
        c32.consume_tile(0, &Tile::F32(narrow.clone()));
        assert_eq!(
            c32.into_matrix().max_abs_diff(&narrow.promote()),
            0.0,
            "default f32 path must equal exact promotion"
        );
    }

    #[test]
    fn snapshot_restore_round_trips_bit_identically_for_sum_folds() {
        // Fold rows [0, split) in one consumer, snapshot, restore into a
        // fresh consumer, fold [split, n): the result must be bit-identical
        // to an uninterrupted fold — the contract the checkpointed
        // pipeline leans on.
        let mut rng = Rng::new(13);
        let n = 37;
        let a = Matrix::randn(n, 5, &mut rng);
        let split = 16;
        let head = a.block(0, split, 0, 5);
        let tail = a.block(split, n, 0, 5);
        let op = sketch::build(SketchKind::Gaussian, n, 8, None, &mut rng);
        let x: Vec<f64> = (0..n).map(|i| (i as f64 * 0.7).cos()).collect();

        // GramFold
        let gram_ref = {
            let mut f = GramFold::new(5);
            f.consume(0, &head);
            f.consume(split, &tail);
            f.into_matrix()
        };
        let snap = {
            let mut f = GramFold::new(5);
            f.consume(0, &head);
            f.snapshot().unwrap()
        };
        let mut f = GramFold::new(5);
        assert!(f.restore(&snap));
        f.consume(split, &tail);
        assert_eq!(f.into_matrix().max_abs_diff(&gram_ref), 0.0, "GramFold");
        assert!(!GramFold::new(4).restore(&snap), "shape mismatch must refuse");

        // SketchFold (dense branch)
        let sk_ref = {
            let mut f = SketchFold::new(&op, 5);
            f.consume(0, &head);
            f.consume(split, &tail);
            f.into_matrix()
        };
        let snap = {
            let mut f = SketchFold::new(&op, 5);
            f.consume(0, &head);
            f.snapshot().unwrap()
        };
        let mut f = SketchFold::new(&op, 5);
        assert!(f.restore(&snap));
        f.consume(split, &tail);
        assert_eq!(f.into_matrix().max_abs_diff(&sk_ref), 0.0, "SketchFold");

        // LeverageFold, both variants
        let lev_ref = {
            let mut f = LeverageFold::exact(5);
            f.consume(0, &head);
            f.consume(split, &tail);
            f.into_estimate()
        };
        let snap = {
            let mut f = LeverageFold::exact(5);
            f.consume(0, &head);
            f.snapshot().unwrap()
        };
        let mut f = LeverageFold::exact(5);
        assert!(f.restore(&snap));
        f.consume(split, &tail);
        let est = f.into_estimate();
        assert_eq!(est.whiten.max_abs_diff(&lev_ref.whiten), 0.0, "LeverageFold exact");
        assert_eq!(est.rank, lev_ref.rank);
        let snap = {
            let mut f = LeverageFold::sketched(&op, 5);
            f.consume(0, &head);
            f.snapshot().unwrap()
        };
        let mut f = LeverageFold::sketched(&op, 5);
        assert!(f.restore(&snap));
        f.consume(split, &tail);
        let lev_sk_ref = {
            let mut f = LeverageFold::sketched(&op, 5);
            f.consume(0, &head);
            f.consume(split, &tail);
            f.into_estimate()
        };
        assert_eq!(
            f.into_estimate().whiten.max_abs_diff(&lev_sk_ref.whiten),
            0.0,
            "LeverageFold sketched"
        );

        // MatvecFold
        let mv_ref = {
            let mut f = MatvecFold::new(&x, 5);
            f.consume(0, &head);
            f.consume(split, &tail);
            f.into_vec()
        };
        let snap = {
            let mut f = MatvecFold::new(&x, 5);
            f.consume(0, &head);
            f.snapshot().unwrap()
        };
        let mut f = MatvecFold::new(&x, 5);
        assert!(f.restore(&snap));
        f.consume(split, &tail);
        assert_eq!(f.into_vec(), mv_ref, "MatvecFold");

        // consumers without state support stay opted out
        assert!(CollectConsumer::new(3, 3).snapshot().is_none());
        assert!(RowGather::new(vec![0], 3).snapshot().is_none());
    }

    #[test]
    fn prototype_fold_matches_dense_chain() {
        let mut rng = Rng::new(6);
        let g = Matrix::randn(30, 30, &mut rng);
        let k = g.matmul_tr(&g);
        let c = k.select_cols(&[1, 5, 9, 20]);
        let cp = crate::linalg::pinv(&c);
        let direct = gemm::symm_nt(&cp.matmul(&k), &cp);
        for tile in [4usize, 30] {
            let mut fold = PrototypeUFold::new(&cp);
            stream_all(&k, tile, &mut [&mut fold]);
            let u = fold.into_matrix();
            assert!(
                u.max_abs_diff(&direct) < 1e-11 * direct.fro_norm().max(1.0),
                "tile={tile}"
            );
        }
    }
}
