//! Kernel ridge regression — the paper's "matrix inversion" motivation
//! (§1, Appendix A Lemma 11): Gaussian-process-style regression requires
//! solving `(K + α I) w = y`, O(n³) exactly. With `K ≈ C U C^T` the
//! Woodbury path solves it in O(n c²), and prediction on a new point is
//! `f(x) = k(x)^T w`.

use crate::coordinator::oracle::RbfOracle;
use crate::linalg::Matrix;
use crate::spsd::SpsdApprox;

/// A fitted approximate-KRR model.
#[derive(Debug, Clone)]
pub struct KrrModel {
    /// Dual weights w (n_train).
    pub weights: Vec<f64>,
    pub alpha: f64,
}

/// Fit with an SPSD approximation of the train kernel (O(n c²)).
pub fn fit_approx(approx: &SpsdApprox, alpha: f64, y: &[f64]) -> KrrModel {
    KrrModel { weights: approx.solve_regularized(alpha, y), alpha }
}

/// Fit exactly against the dense kernel (O(n³) baseline).
pub fn fit_exact(kmat: &Matrix, alpha: f64, y: &[f64]) -> KrrModel {
    let mut kk = kmat.clone();
    kk.add_diag(alpha);
    let w = crate::linalg::solve::lu_solve(&kk, y).expect("K + alpha I is SPD");
    KrrModel { weights: w, alpha }
}

impl KrrModel {
    /// Predict for test points given the cross kernel `kx` (n_train x n_test).
    pub fn predict(&self, kx: &Matrix) -> Vec<f64> {
        kx.tr_matvec(&self.weights)
    }
}

/// Convenience: fit + predict through an RBF oracle.
pub fn predict_with_oracle(
    model: &KrrModel,
    oracle: &RbfOracle,
    test_x: &Matrix,
) -> Vec<f64> {
    let kx = oracle.cross(test_x);
    model.predict(&kx)
}

/// Mean squared error.
pub fn mse(pred: &[f64], truth: &[f64]) -> f64 {
    assert_eq!(pred.len(), truth.len());
    if pred.is_empty() {
        return 0.0;
    }
    pred.iter()
        .zip(truth)
        .map(|(p, t)| (p - t) * (p - t))
        .sum::<f64>()
        / pred.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::oracle::KernelOracle;
    use crate::data::{make_blobs, sigma};
    use crate::spsd::{self, FastConfig};
    use crate::util::Rng;
    use std::sync::Arc;

    /// Smooth target over blob data.
    fn regression_problem(n: usize, seed: u64) -> (Matrix, Vec<f64>, Matrix, Vec<f64>, f64) {
        let ds = make_blobs("krr", 2 * n, 4, 3, 2.0, seed);
        let f = |row: &[f64]| row.iter().map(|x| (x * 0.7).sin()).sum::<f64>();
        let xtr = ds.x.block(0, n, 0, 4);
        let xte = ds.x.block(n, 2 * n, 0, 4);
        let ytr: Vec<f64> = (0..n).map(|i| f(xtr.row(i))).collect();
        let yte: Vec<f64> = (0..n).map(|i| f(xte.row(i))).collect();
        let sig = sigma::calibrate_sigma(&xtr, 0.95, 300, seed);
        (xtr, ytr, xte, yte, sigma::gamma_of_sigma(sig))
    }

    #[test]
    fn approx_krr_tracks_exact_krr() {
        let (xtr, ytr, xte, yte, gamma) = regression_problem(250, 0);
        let oracle = RbfOracle::cpu(Arc::new(xtr.clone()), gamma);
        let kfull = oracle.full();
        let alpha = 0.1;
        let exact = fit_exact(&kfull, alpha, &ytr);
        let kx = oracle.cross(&xte);
        let mse_exact = mse(&exact.predict(&kx), &yte);

        let mut rng = Rng::new(1);
        let p = spsd::uniform_p(250, 40, &mut rng);
        let approx = crate::exec::fast(&oracle, &p, FastConfig::uniform(160), &crate::exec::ExecPolicy::Materialized, &mut rng).result;
        let fast_model = fit_approx(&approx, alpha, &ytr);
        let mse_fast = mse(&fast_model.predict(&kx), &yte);
        // exact should be good, approximate within a modest factor
        assert!(mse_exact < 0.1, "exact mse {mse_exact}");
        assert!(
            mse_fast < mse_exact * 4.0 + 0.05,
            "fast mse {mse_fast} vs exact {mse_exact}"
        );
    }

    #[test]
    fn fast_beats_nystrom_krr_on_average() {
        let (xtr, ytr, xte, yte, gamma) = regression_problem(200, 2);
        let oracle = RbfOracle::cpu(Arc::new(xtr.clone()), gamma);
        let kx = oracle.cross(&xte);
        let alpha = 0.1;
        let mut mse_ny = 0.0;
        let mut mse_fast = 0.0;
        for t in 0..5u64 {
            let mut rng = Rng::new(10 + t);
            let p = spsd::uniform_p(200, 16, &mut rng);
            let ny = crate::exec::nystrom(&oracle, &p, &crate::exec::ExecPolicy::Materialized).result;
            mse_ny += mse(&fit_approx(&ny, alpha, &ytr).predict(&kx), &yte);
            let fa = crate::exec::fast(&oracle, &p, FastConfig::uniform(96), &crate::exec::ExecPolicy::Materialized, &mut rng).result;
            mse_fast += mse(&fit_approx(&fa, alpha, &ytr).predict(&kx), &yte);
        }
        assert!(
            mse_fast <= mse_ny * 1.05,
            "fast {mse_fast} should be at least as good as nystrom {mse_ny}"
        );
    }

    #[test]
    fn exact_fit_interpolates_with_tiny_alpha() {
        let (xtr, ytr, _xte, _yte, gamma) = regression_problem(60, 3);
        let oracle = RbfOracle::cpu(Arc::new(xtr.clone()), gamma);
        let kfull = oracle.full();
        let model = fit_exact(&kfull, 1e-8, &ytr);
        let pred = model.predict(&kfull);
        let train_mse = mse(&pred, &ytr);
        assert!(train_mse < 1e-6, "train mse {train_mse}");
    }

    #[test]
    fn mse_edge_cases() {
        assert_eq!(mse(&[], &[]), 0.0);
        assert_eq!(mse(&[1.0, 2.0], &[1.0, 4.0]), 2.0);
    }
}
