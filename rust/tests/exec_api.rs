//! The execution-policy API acceptance matrix (ISSUE 5): every algorithm
//! family served by `exec` must produce the same answer under every
//! [`ExecPolicy`] —
//!
//! - **bit-identical** for the selection/gather paths (Nyström,
//!   uniform/leverage fast, fast CUR, the implicit top-k and solve), and
//! - within **1e-12 relative** for the reduction-regrouped paths
//!   (prototype, projection-sketch fast),
//!
//! against the `Materialized` reference, for policies
//! `Streamed{1, 7, 64, n}` and `Resident{0, one-tile, ∞}` (spilling and
//! RAM-only). The deprecated per-policy shims must forward to the same
//! unified builders exactly.

use fastspsd::coordinator::oracle::{KernelOracle, RbfOracle};
use fastspsd::cur::FastCurConfig;
use fastspsd::exec::{self, ExecPolicy};
use fastspsd::linalg::Matrix;
use fastspsd::sketch::SketchKind;
use fastspsd::spsd::{FastConfig, LeverageBasis, SpsdApprox};
use fastspsd::stream::{OracleColumnsSource, Precision, StreamConfig};
use fastspsd::util::Rng;
use std::sync::Arc;

const N: usize = 53; // prime: no tile height divides it
const C: usize = 5;

fn oracle() -> RbfOracle {
    let mut rng = Rng::new(3);
    RbfOracle::cpu(Arc::new(Matrix::randn(N, 6, &mut rng)), 0.5)
}

fn landmarks() -> Vec<usize> {
    let mut rng = Rng::new(21);
    fastspsd::spsd::uniform_p(N, C, &mut rng)
}

/// The issue's policy matrix: streamed tiles {1, 7, 64, n} and resident
/// budgets {0, one-tile, ∞} (both spilling and RAM-only), at a tile
/// height that does not divide n.
fn policies() -> Vec<(String, ExecPolicy)> {
    let mut out = vec![];
    for t in [1usize, 7, 64, N] {
        out.push((format!("streamed[{t}]"), ExecPolicy::streamed(t)));
    }
    let one_tile = (7 * C * 8) as u64;
    for b in [0u64, one_tile, u64::MAX] {
        out.push((format!("resident[spill,{b}]"), ExecPolicy::resident(b).with_tile_rows(7)));
        out.push((format!("resident[ram,{b}]"), ExecPolicy::ram_cached(b).with_tile_rows(7)));
    }
    out
}

fn policy_is_resident(p: &ExecPolicy) -> bool {
    matches!(p, ExecPolicy::Resident { .. })
}

fn assert_spsd_bits(a: &SpsdApprox, b: &SpsdApprox, label: &str) {
    assert_eq!(a.c.max_abs_diff(&b.c), 0.0, "{label}: C must be bit-identical");
    assert_eq!(a.u.max_abs_diff(&b.u), 0.0, "{label}: U must be bit-identical");
    assert_eq!(a.entries_observed, b.entries_observed, "{label}: entry accounting");
}

#[test]
fn nystrom_matrix_is_bit_identical() {
    let o = oracle();
    let p = landmarks();
    let reference = exec::nystrom(&o, &p, &ExecPolicy::Materialized).result;
    for (label, pol) in policies() {
        let rep = exec::nystrom(&o, &p, &pol);
        assert_spsd_bits(&reference, &rep.result, &format!("nystrom {label}"));
        assert_eq!(
            rep.meta.residency.is_some(),
            policy_is_resident(&pol),
            "nystrom {label}: residency stats iff resident policy"
        );
    }
}

#[test]
fn fast_selection_matrix_is_bit_identical() {
    let o = oracle();
    let p = landmarks();
    for cfg in [
        FastConfig::uniform(20),
        FastConfig::leverage(20),
        FastConfig::leverage(20).with_basis(LeverageBasis::ExactSvd),
    ] {
        let reference =
            exec::fast(&o, &p, cfg, &ExecPolicy::Materialized, &mut Rng::new(99)).result;
        let multi_pass = matches!(cfg.kind, SketchKind::Leverage { .. });
        for (label, pol) in policies() {
            let rep = exec::fast(&o, &p, cfg, &pol, &mut Rng::new(99));
            let st = &rep.result;
            let label = format!("{} {label}", reference.method);
            assert_eq!(st.c.max_abs_diff(&reference.c), 0.0, "{label}: C bits");
            assert_eq!(st.u.max_abs_diff(&reference.u), 0.0, "{label}: U bits");
            // Entry accounting is policy-invariant except for the one
            // documented case: the leverage family's two-pass plan under a
            // RAM-only resident policy re-pays the oracle for pass-2
            // tiles the partial cache evicted (no spill arena to reload
            // from). Bits are unchanged even then.
            let ram_only_partial = multi_pass
                && matches!(pol, ExecPolicy::Resident { spill: false, budget, .. } if budget != u64::MAX);
            if ram_only_partial {
                assert!(st.entries_observed >= reference.entries_observed, "{label}");
            } else {
                assert_eq!(st.entries_observed, reference.entries_observed, "{label}");
            }
            assert_eq!(rep.meta.residency.is_some(), policy_is_resident(&pol));
        }
    }
}

#[test]
fn prototype_and_projection_matrix_within_1e12() {
    let o = oracle();
    let p = landmarks();
    let proto_ref = exec::prototype(&o, &p, &ExecPolicy::Materialized).result;
    let gauss_cfg = FastConfig {
        s: 20,
        kind: SketchKind::Gaussian,
        force_p_in_s: false,
        leverage_basis: LeverageBasis::Gram,
    };
    let gauss_ref =
        exec::fast(&o, &p, gauss_cfg, &ExecPolicy::Materialized, &mut Rng::new(5)).result;
    for (label, pol) in policies() {
        let st = exec::prototype(&o, &p, &pol).result;
        assert_eq!(st.c.max_abs_diff(&proto_ref.c), 0.0, "prototype C {label}");
        let rel = st.u.sub(&proto_ref.u).fro_norm() / proto_ref.u.fro_norm().max(1e-300);
        assert!(rel <= 1e-12, "prototype {label}: rel U err {rel}");

        // projection sketches stream the full K: resident policies fall
        // back to plain streaming (no stats), results stay within 1e-12
        let rep = exec::fast(&o, &p, gauss_cfg, &pol, &mut Rng::new(5));
        assert!(rep.meta.residency.is_none(), "projection {label}: no residency stats");
        let rel = rep.result.materialize().sub(&gauss_ref.materialize()).fro_norm()
            / gauss_ref.materialize().fro_norm().max(1e-300);
        assert!(rel <= 1e-12, "fast[gaussian] {label}: rel err {rel}");
    }
}

#[test]
fn cur_matrix_is_bit_identical() {
    let mut rng = Rng::new(9);
    let a = Matrix::randn(N, 41, &mut rng);
    let cols = fastspsd::cur::select_uniform(41, 5, &mut Rng::new(11));
    let rows = fastspsd::cur::select_uniform(N, 5, &mut Rng::new(12));
    for cfg in [FastCurConfig::uniform(18, 18), FastCurConfig::leverage(18, 18)] {
        let reference =
            exec::cur_fast(&a, &cols, &rows, cfg, &ExecPolicy::Materialized, &mut Rng::new(77))
                .result;
        for (label, pol) in policies() {
            let rep = exec::cur_fast(&a, &cols, &rows, cfg, &pol, &mut Rng::new(77));
            let st = rep.result;
            assert_eq!(st.c.max_abs_diff(&reference.c), 0.0, "cur C {label}");
            assert_eq!(st.r.max_abs_diff(&reference.r), 0.0, "cur R {label}");
            assert_eq!(st.u.max_abs_diff(&reference.u), 0.0, "{} U {label}", reference.method);
            assert_eq!(st.entries_for_u, reference.entries_for_u, "cur entries {label}");
            assert_eq!(rep.meta.residency.is_some(), policy_is_resident(&pol));
        }
    }
}

#[test]
fn implicit_ops_matrix_is_bit_identical() {
    let o = oracle();
    let p = landmarks();
    let src = OracleColumnsSource::new(&o, &p);
    let mut rng = Rng::new(4);
    let mut u = Matrix::randn(C, C, &mut rng);
    u.symmetrize();
    let uspd = u.gram_nt(); // SPSD for the solve
    let y: Vec<f64> = (0..N).map(|i| (i as f64 * 0.4).cos()).collect();

    let (vals_ref, vecs_ref) = exec::top_k_eigs(&src, &u, 3, 7, &ExecPolicy::Materialized).result;
    let w_ref = exec::solve_regularized(&src, &uspd, 0.3, &y, &ExecPolicy::Materialized).result;
    for (label, pol) in policies() {
        let rep = exec::top_k_eigs(&src, &u, 3, 7, &pol);
        let (vals, vecs) = rep.result;
        assert_eq!(vals_ref, vals, "top_k {label}");
        assert_eq!(vecs_ref.max_abs_diff(&vecs), 0.0, "top_k vecs {label}");
        assert_eq!(rep.meta.residency.is_some(), policy_is_resident(&pol), "top_k {label}");
        assert!(rep.meta.predicted_peak_bytes.unwrap() > 0);

        let w = exec::solve_regularized(&src, &uspd, 0.3, &y, &pol).result;
        assert_eq!(w_ref, w, "solve {label}");
    }

    // the residency entry-elimination contract through exec: one n·c at
    // any spilling budget
    o.reset_entries();
    let _ = exec::top_k_eigs(&src, &u, 3, 7, &ExecPolicy::resident(0).with_tile_rows(7));
    assert_eq!(o.entries_observed(), (N * C) as u64);
}

/// The deprecated shims must forward to the exact same builders: same
/// bits, same entries, same rng consumption.
#[test]
#[allow(deprecated)]
fn deprecated_shims_forward_exactly() {
    use fastspsd::stream::ResidencyConfig;
    let o = oracle();
    let p = landmarks();
    let cfg = FastConfig::leverage(20);
    let tiled = StreamConfig::tiled(7);
    let rc = ResidencyConfig::new(0).with_tile_rows(7);

    // spsd family
    assert_spsd_bits(
        &fastspsd::spsd::nystrom(&o, &p),
        &exec::nystrom(&o, &p, &ExecPolicy::Materialized).result,
        "shim nystrom",
    );
    assert_spsd_bits(
        &fastspsd::spsd::nystrom_streamed(&o, &p, tiled),
        &exec::nystrom(&o, &p, &ExecPolicy::streamed(7)).result,
        "shim nystrom_streamed",
    );
    let (a, stats) = fastspsd::spsd::nystrom_resident(&o, &p, tiled, &rc);
    let rep = exec::nystrom(&o, &p, &ExecPolicy::resident(0).with_tile_rows(7));
    assert_spsd_bits(&a, &rep.result, "shim nystrom_resident");
    assert_eq!(stats.computes, rep.meta.residency.unwrap().computes);

    assert_spsd_bits(
        &fastspsd::spsd::prototype(&o, &p),
        &exec::prototype(&o, &p, &ExecPolicy::Materialized).result,
        "shim prototype",
    );
    assert_spsd_bits(
        &fastspsd::spsd::prototype_streamed(&o, &p, tiled),
        &exec::prototype(&o, &p, &ExecPolicy::streamed(7)).result,
        "shim prototype_streamed",
    );
    assert_spsd_bits(
        &fastspsd::spsd::fast(&o, &p, cfg, &mut Rng::new(1)),
        &exec::fast(&o, &p, cfg, &ExecPolicy::Materialized, &mut Rng::new(1)).result,
        "shim fast",
    );
    assert_spsd_bits(
        &fastspsd::spsd::fast_streamed(&o, &p, cfg, tiled, &mut Rng::new(1)),
        &exec::fast(&o, &p, cfg, &ExecPolicy::streamed(7), &mut Rng::new(1)).result,
        "shim fast_streamed",
    );
    let (a, _) = fastspsd::spsd::fast_streamed_resident(&o, &p, cfg, tiled, &rc, &mut Rng::new(1));
    assert_spsd_bits(
        &a,
        &exec::fast(&o, &p, cfg, &ExecPolicy::resident(0).with_tile_rows(7), &mut Rng::new(1))
            .result,
        "shim fast_streamed_resident",
    );

    // cur family
    let mut rng = Rng::new(9);
    let amat = Matrix::randn(N, 41, &mut rng);
    let cols = fastspsd::cur::select_uniform(41, 5, &mut Rng::new(11));
    let rows = fastspsd::cur::select_uniform(N, 5, &mut Rng::new(12));
    let ccfg = FastCurConfig::leverage(18, 18);
    let d1 = fastspsd::cur::cur_fast(&amat, &cols, &rows, ccfg, &mut Rng::new(2));
    let d2 = exec::cur_fast(&amat, &cols, &rows, ccfg, &ExecPolicy::Materialized, &mut Rng::new(2))
        .result;
    assert_eq!(d1.u.max_abs_diff(&d2.u), 0.0, "shim cur_fast");
    let d1 = fastspsd::cur::cur_fast_streamed(&amat, &cols, &rows, ccfg, tiled, &mut Rng::new(2));
    let d2 = exec::cur_fast(&amat, &cols, &rows, ccfg, &ExecPolicy::streamed(7), &mut Rng::new(2))
        .result;
    assert_eq!(d1.u.max_abs_diff(&d2.u), 0.0, "shim cur_fast_streamed");
    let (d1, _) = fastspsd::cur::cur_fast_streamed_resident(
        &amat,
        &cols,
        &rows,
        ccfg,
        tiled,
        &rc,
        &mut Rng::new(2),
    );
    let d2 = exec::cur_fast(
        &amat,
        &cols,
        &rows,
        ccfg,
        &ExecPolicy::resident(0).with_tile_rows(7),
        &mut Rng::new(2),
    )
    .result;
    assert_eq!(d1.u.max_abs_diff(&d2.u), 0.0, "shim cur_fast_streamed_resident");

    // implicit family
    let src = OracleColumnsSource::new(&o, &p);
    let mut u = Matrix::randn(C, C, &mut Rng::new(4));
    u.symmetrize();
    let uspd = u.gram_nt();
    let y: Vec<f64> = (0..N).map(|i| (i as f64 * 0.4).cos()).collect();
    let (v1, _) = fastspsd::stream::top_k_eigs(&src, &u, 3, 7, tiled);
    let (v2, _) = exec::top_k_eigs(&src, &u, 3, 7, &ExecPolicy::streamed(7)).result;
    assert_eq!(v1, v2, "shim top_k_eigs");
    let (v1, _) = fastspsd::stream::top_k_eigs_budgeted(&src, &u, 3, 7, tiled, u64::MAX);
    let (v2, _) =
        exec::top_k_eigs(&src, &u, 3, 7, &ExecPolicy::ram_cached(u64::MAX).with_tile_rows(7))
            .result;
    assert_eq!(v1, v2, "shim top_k_eigs_budgeted");
    let (v1, _, st1) = fastspsd::stream::top_k_eigs_resident(&src, &u, 3, 7, tiled, &rc);
    let rep = exec::top_k_eigs(&src, &u, 3, 7, &ExecPolicy::resident(0).with_tile_rows(7));
    assert_eq!(v1, rep.result.0, "shim top_k_eigs_resident");
    assert_eq!(st1.computes, rep.meta.residency.unwrap().computes);
    let w1 = fastspsd::stream::solve_regularized(&src, &uspd, 0.3, &y, tiled);
    let w2 = exec::solve_regularized(&src, &uspd, 0.3, &y, &ExecPolicy::streamed(7)).result;
    assert_eq!(w1, w2, "shim solve_regularized");
    let w1 = fastspsd::stream::solve_regularized_budgeted(&src, &uspd, 0.3, &y, tiled, 0);
    let w2 = exec::solve_regularized(&src, &uspd, 0.3, &y, &ExecPolicy::ram_cached(0).with_tile_rows(7))
        .result;
    assert_eq!(w1, w2, "shim solve_regularized_budgeted");
    let (w1, _) = fastspsd::stream::solve_regularized_resident(&src, &uspd, 0.3, &y, tiled, &rc);
    let w2 = exec::solve_regularized(&src, &uspd, 0.3, &y, &ExecPolicy::resident(0).with_tile_rows(7))
        .result;
    assert_eq!(w1, w2, "shim solve_regularized_resident");
}

/// The mixed-precision acceptance sweep (ISSUE 8): every method × policy
/// cell re-run with the policy narrowed to f32 must approximate the exact
/// kernel within 10× the f64 cell's error — the ~1e-7 relative tile
/// rounding has to disappear under the sampling error — and the report
/// must surface the width it ran at.
#[test]
fn f32_matrix_stays_within_10x_of_f64_error() {
    let o = oracle();
    let p = landmarks();
    let k = o.full();
    let build = |m: usize, pol: &ExecPolicy| -> SpsdApprox {
        match m {
            0 => exec::nystrom(&o, &p, pol).result,
            1 => exec::fast(&o, &p, FastConfig::uniform(20), pol, &mut Rng::new(99)).result,
            2 => exec::fast(&o, &p, FastConfig::leverage(20), pol, &mut Rng::new(99)).result,
            _ => exec::prototype(&o, &p, pol).result,
        }
    };
    for m in 0..4usize {
        for (label, pol) in policies() {
            let narrow = pol.clone().with_precision(Precision::F32);
            let a64 = build(m, &pol);
            let a32 = build(m, &narrow);
            let e64 = a64.rel_fro_error(&k);
            let e32 = a32.rel_fro_error(&k);
            assert!(
                e32 <= 10.0 * e64 + 1e-12,
                "{} {label}: f32 err {e32} vs f64 err {e64}",
                a64.method
            );
        }
    }
    // The report records the served width for both cells.
    let rep64 = exec::nystrom(&o, &p, &ExecPolicy::streamed(7));
    let rep32 = exec::nystrom(&o, &p, &ExecPolicy::streamed(7).with_precision(Precision::F32));
    assert_eq!(rep64.meta.precision, Precision::F64);
    assert_eq!(rep32.meta.precision, Precision::F32);
}

/// f32 selection paths are tile-size invariant: tiles are converted (or
/// natively computed) row-by-row, so conversion commutes with tiling and
/// gathers, the leverage fold, and the sampler see the same bits at any
/// tile height — streamed or reloaded through the f32 spill arena.
#[test]
fn f32_selection_paths_are_tile_invariant() {
    let o = oracle();
    let p = landmarks();
    let build = |m: usize, pol: &ExecPolicy| -> SpsdApprox {
        match m {
            0 => exec::nystrom(&o, &p, pol).result,
            1 => exec::fast(&o, &p, FastConfig::uniform(20), pol, &mut Rng::new(99)).result,
            _ => exec::fast(&o, &p, FastConfig::leverage(20), pol, &mut Rng::new(99)).result,
        }
    };
    for m in 0..3usize {
        let reference = build(m, &ExecPolicy::streamed(1).with_precision(Precision::F32));
        for t in [7usize, 64, N] {
            let b = build(m, &ExecPolicy::streamed(t).with_precision(Precision::F32));
            assert_eq!(reference.c.max_abs_diff(&b.c), 0.0, "method {m} tile={t}: f32 C bits");
            assert_eq!(reference.u.max_abs_diff(&b.u), 0.0, "method {m} tile={t}: f32 U bits");
        }
        for budget in [0u64, u64::MAX] {
            let pol =
                ExecPolicy::resident(budget).with_tile_rows(7).with_precision(Precision::F32);
            let r = build(m, &pol);
            assert_eq!(
                reference.c.max_abs_diff(&r.c),
                0.0,
                "method {m} resident[{budget}]: f32 C bits"
            );
            assert_eq!(
                reference.u.max_abs_diff(&r.u),
                0.0,
                "method {m} resident[{budget}]: f32 U bits"
            );
        }
    }
}

/// RunReport accounting invariants that hold for every policy.
#[test]
fn run_reports_carry_uniform_accounting() {
    let o = oracle();
    let p = landmarks();
    for (label, pol) in policies() {
        o.reset_entries();
        let rep = exec::nystrom(&o, &p, &pol);
        assert_eq!(
            rep.meta.entries,
            Some(o.entries_observed()),
            "{label}: meta.entries matches the oracle counter"
        );
        assert_eq!(rep.meta.entries, Some(rep.result.entries_observed));
        assert!(rep.meta.compute_secs >= 0.0);
        let predicted = rep.meta.predicted_peak_bytes.expect("spsd builds are predicted");
        assert!(
            predicted >= (N * C * 8) as u64,
            "{label}: prediction must at least cover the C panel"
        );
    }
}
