//! Figures 3 & 4: kernel approximation error `‖K - C U C^T‖_F² / ‖K‖_F²`
//! against `s/n`, for the fast model (uniform and leverage S), with the
//! Nyström method and the prototype model as horizontal references.
//!
//! Fig 3 forms `C` by uniform column sampling; Fig 4 by the
//! uniform+adaptive² algorithm of Wang et al. (2016).

use super::Ctx;
use crate::cli::Args;
use crate::coordinator::oracle::KernelOracle;
use crate::cur;
use crate::data::TABLE6;
use crate::exec::{self, ExecPolicy};
use crate::spsd::{self, FastConfig};
use crate::util::Rng;

pub fn run(ctx: &Ctx, args: &Args, adaptive_c: bool) {
    let pol = ExecPolicy::Materialized;
    let fig = if adaptive_c { "fig4" } else { "fig3" };
    let etas = [0.9, 0.99];
    let mut csv = ctx.csv(
        &format!("{fig}.csv"),
        "dataset,eta,n,c,s,s_over_n,method,rel_err,entries,secs",
    );
    let only = args.get("dataset").map(|s| s.to_lowercase());
    for spec in TABLE6 {
        if let Some(o) = &only {
            if !spec.name.eq_ignore_ascii_case(o) {
                continue;
            }
        }
        for &eta in &etas {
            let (ds, oracle, sig) = ctx.oracle_for(spec, eta);
            let n = ds.x.rows();
            let c = (n as f64 / 100.0).ceil() as usize;
            let c = c.max(8);
            eprintln!("# {fig}: {} n={n} c={c} eta={eta} sigma={sig:.3}", spec.name);
            // evaluation needs the full K once
            let kfull = oracle.full();
            let kf_sq = kfull.fro_norm_sq();
            let s_factors = args.get_usize_list("sfactors", &[2, 4, 8, 16, 24, 40]);

            for rep in 0..ctx.reps {
                let mut rng = Rng::new(ctx.seed + rep as u64 * 7919);
                let p = if adaptive_c {
                    cur::uniform_adaptive2(&kfull, c, &mut rng)
                } else {
                    spsd::uniform_p(n, c, &mut rng)
                };
                // baselines
                for (name, approx) in [
                    ("nystrom", exec::nystrom(oracle.as_ref(), &p, &pol).result),
                    ("prototype", exec::prototype(oracle.as_ref(), &p, &pol).result),
                ] {
                    let err = kfull.sub(&approx.materialize()).fro_norm_sq() / kf_sq;
                    csv.row(&format!(
                        "{},{eta},{n},{c},{},{:.4},{name},{err:.6e},{},{:.4}",
                        spec.name,
                        if name == "prototype" { n } else { c },
                        if name == "prototype" { 1.0 } else { c as f64 / n as f64 },
                        approx.entries_observed,
                        approx.build_secs
                    ));
                }
                // fast model sweep over s
                for &f in &s_factors {
                    let s = (f * c).min(n);
                    for cfg in [FastConfig::uniform(s), FastConfig::leverage(s)] {
                        oracle.reset_entries();
                        let approx = exec::fast(oracle.as_ref(), &p, cfg, &pol, &mut rng).result;
                        let err = kfull.sub(&approx.materialize()).fro_norm_sq() / kf_sq;
                        csv.row(&format!(
                            "{},{eta},{n},{c},{s},{:.4},{},{err:.6e},{},{:.4}",
                            spec.name,
                            s as f64 / n as f64,
                            approx.method,
                            approx.entries_observed,
                            approx.build_secs
                        ));
                    }
                }
            }
        }
    }
    csv.finish();
}
