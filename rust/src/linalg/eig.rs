//! Symmetric eigendecomposition via cyclic Jacobi rotations.
//!
//! Used for the c x c / s x s inner problems (Lemma 10's `Z`, Nyström's
//! `W`), the exact baselines in the experiments, and leverage scores.

use super::Matrix;

/// Eigendecomposition of a symmetric matrix: `A = V diag(l) V^T`,
/// eigenvalues descending.
pub struct Eigh {
    pub values: Vec<f64>,
    /// n x n, column j is the eigenvector for values[j].
    pub vectors: Matrix,
}

const MAX_SWEEPS: usize = 100;

/// Cyclic Jacobi eigendecomposition. `a` must be symmetric (enforced up to
/// round-off by symmetrizing a copy).
pub fn eigh(a: &Matrix) -> Eigh {
    let n = a.rows();
    assert_eq!(n, a.cols(), "eigh needs a square matrix");
    let mut m = a.clone();
    m.symmetrize();
    let mut v = Matrix::identity(n);
    if n <= 1 {
        return Eigh { values: (0..n).map(|i| m[(i, i)]).collect(), vectors: v };
    }
    for _sweep in 0..MAX_SWEEPS {
        // off-diagonal Frobenius mass
        let mut off = 0.0;
        for i in 0..n {
            for j in (i + 1)..n {
                off += m[(i, j)] * m[(i, j)];
            }
        }
        let diag_scale: f64 = (0..n).map(|i| m[(i, i)] * m[(i, i)]).sum::<f64>().max(1e-300);
        if off <= 1e-28 * diag_scale {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = m[(p, q)];
                if apq == 0.0 {
                    continue;
                }
                let app = m[(p, p)];
                let aqq = m[(q, q)];
                if apq.abs() < 1e-18 * (app.abs() + aqq.abs() + 1e-300) {
                    continue;
                }
                let theta = (aqq - app) / (2.0 * apq);
                let t = theta.signum() / (theta.abs() + (1.0 + theta * theta).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                // rows/cols p and q of m
                for i in 0..n {
                    let mip = m[(i, p)];
                    let miq = m[(i, q)];
                    m[(i, p)] = c * mip - s * miq;
                    m[(i, q)] = s * mip + c * miq;
                }
                for j in 0..n {
                    let mpj = m[(p, j)];
                    let mqj = m[(q, j)];
                    m[(p, j)] = c * mpj - s * mqj;
                    m[(q, j)] = s * mpj + c * mqj;
                }
                for i in 0..n {
                    let vip = v[(i, p)];
                    let viq = v[(i, q)];
                    v[(i, p)] = c * vip - s * viq;
                    v[(i, q)] = s * vip + c * viq;
                }
            }
        }
    }
    let mut order: Vec<usize> = (0..n).collect();
    let diag: Vec<f64> = (0..n).map(|i| m[(i, i)]).collect();
    order.sort_by(|&i, &j| diag[j].partial_cmp(&diag[i]).unwrap());
    Eigh {
        values: order.iter().map(|&i| diag[i]).collect(),
        vectors: v.select_cols(&order),
    }
}

impl Eigh {
    /// Top-k eigenpairs (values may include negatives for indefinite input).
    pub fn top_k(&self, k: usize) -> (Vec<f64>, Matrix) {
        let k = k.min(self.values.len());
        let idx: Vec<usize> = (0..k).collect();
        (self.values[..k].to_vec(), self.vectors.select_cols(&idx))
    }

    /// Reconstruct `V diag(l) V^T`.
    pub fn reconstruct(&self) -> Matrix {
        let vl = Matrix::from_fn(self.vectors.rows(), self.values.len(), |i, j| {
            self.vectors[(i, j)] * self.values[j]
        });
        vl.matmul_tr(&self.vectors)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn random_spsd(n: usize, rank: usize, rng: &mut Rng) -> Matrix {
        let b = Matrix::randn(n, rank, rng);
        b.matmul_tr(&b)
    }

    #[test]
    fn reconstructs_symmetric() {
        let mut rng = Rng::new(0);
        for &n in &[1usize, 2, 5, 12, 30] {
            let mut a = Matrix::randn(n, n, &mut rng);
            a.symmetrize();
            let e = eigh(&a);
            assert!(e.reconstruct().max_abs_diff(&a) < 1e-8, "n={n}");
            // descending
            for i in 1..n {
                assert!(e.values[i - 1] >= e.values[i] - 1e-10);
            }
            // orthonormal
            let vtv = e.vectors.tr_matmul(&e.vectors);
            assert!(vtv.max_abs_diff(&Matrix::identity(n)) < 1e-8);
        }
    }

    #[test]
    fn known_eigenvalues() {
        // [[2,1],[1,2]] -> eigenvalues 3, 1
        let a = Matrix::from_vec(2, 2, vec![2.0, 1.0, 1.0, 2.0]);
        let e = eigh(&a);
        assert!((e.values[0] - 3.0).abs() < 1e-12);
        assert!((e.values[1] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn spsd_has_nonnegative_spectrum() {
        let mut rng = Rng::new(1);
        let a = random_spsd(20, 5, &mut rng);
        let e = eigh(&a);
        assert!(e.values.iter().all(|&l| l > -1e-9));
        // rank 5: exactly 5 eigenvalues materially positive
        assert!(e.values[4] > 1e-6);
        assert!(e.values[5].abs() < 1e-8);
    }

    #[test]
    fn eigenvector_equation_holds() {
        let mut rng = Rng::new(2);
        let a = random_spsd(10, 10, &mut rng);
        let e = eigh(&a);
        for j in 0..3 {
            let v: Vec<f64> = e.vectors.col(j);
            let av = a.matvec(&v);
            for i in 0..10 {
                assert!((av[i] - e.values[j] * v[i]).abs() < 1e-8);
            }
        }
    }

    #[test]
    fn diagonal_matrix() {
        let a = Matrix::diag(&[1.0, 5.0, -2.0]);
        let e = eigh(&a);
        assert_eq!(e.values.len(), 3);
        assert!((e.values[0] - 5.0).abs() < 1e-12);
        assert!((e.values[2] + 2.0).abs() < 1e-12);
    }
}
