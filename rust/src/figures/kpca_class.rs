//! Figures 7-10: generalization — KPCA feature extraction (k = 3 or 10)
//! followed by 10-NN classification on a 50/50 split; classification error
//! against c (Figs 7/9) and elapsed time (Figs 8/10).

use super::Ctx;
use crate::apps::{knn_classify, kpca, metrics::error_rate};
use crate::cli::Args;
use crate::coordinator::RbfOracle;
use crate::data::{self, sigma, TABLE7};
use crate::exec::{self, ExecPolicy};
use crate::sketch::SketchKind;
use crate::spsd::{self, FastConfig};
use crate::util::{Rng, Stopwatch};
use std::sync::Arc;

pub fn run(ctx: &Ctx, args: &Args, k: usize) {
    let pol = ExecPolicy::Materialized;
    let fig = if k == 3 { "fig7_8" } else { "fig9_10" };
    let datasets = ["PenDigit", "USPS", "Mushrooms", "DNA"];
    let only = args.get("dataset").map(|s| s.to_lowercase());
    let mut csv = ctx.csv(
        &format!("{fig}.csv"),
        "dataset,n_train,k,c,method,s,class_err,secs",
    );
    for name in datasets {
        if let Some(o) = &only {
            if !name.eq_ignore_ascii_case(o) {
                continue;
            }
        }
        let spec = data::find_spec(name).unwrap();
        let ds = spec.generate(ctx.scale, ctx.seed);
        let mut rng0 = Rng::new(ctx.seed ^ 0xC1A5);
        let (train, test) = data::train_test_split(&ds, &mut rng0);
        let sig = sigma::calibrate_sigma(&train.x, 0.9, 500, ctx.seed);
        let gamma = sigma::gamma_of_sigma(sig);
        let engine = Arc::clone(&ctx.engine);
        let oracle = Arc::new(RbfOracle::new(Arc::new(train.x.clone()), gamma, engine));
        let n1 = train.x.rows();
        // cross-kernel columns k(x) for the test set (shared by all methods)
        let kx = oracle.cross(&test.x); // n_train x n_test

        let cs = args.get_usize_list("cs", &[10, 20, 40, 80]);
        for &c in &cs {
            let c = c.min(n1 / 2);
            for rep in 0..ctx.reps {
                let mut rng = Rng::new(ctx.seed + rep as u64 * 131 + c as u64);
                let p = spsd::uniform_p(n1, c, &mut rng);
                let mut eval = |method: &str, s: usize, approx: spsd::SpsdApprox, secs: f64| {
                    let model = kpca::kpca_from_approx(&approx, k);
                    let ftr = model.train_features();
                    let fte = model.test_features(&kx);
                    let pred = knn_classify(&ftr, &train.labels, &fte, 10);
                    let err = error_rate(&pred, &test.labels);
                    csv.row(&format!("{name},{n1},{k},{c},{method},{s},{err:.4},{secs:.4}"));
                };
                let sw = Stopwatch::start();
                let a = exec::nystrom(oracle.as_ref(), &p, &pol).result;
                eval("nystrom", c, a, sw.secs());
                for f in [4usize, 8] {
                    let s = (f * c).min(n1);
                    let sw = Stopwatch::start();
                    let a = exec::fast(
                        oracle.as_ref(),
                        &p,
                        FastConfig {
                            s,
                            kind: SketchKind::Uniform,
                            force_p_in_s: true,
                            leverage_basis: spsd::LeverageBasis::Gram,
                        },
                        &pol,
                        &mut rng,
                    )
                    .result;
                    eval(&format!("fast_s{f}c"), s, a, sw.secs());
                }
                let sw = Stopwatch::start();
                let a = exec::prototype(oracle.as_ref(), &p, &pol).result;
                eval("prototype", n1, a, sw.secs());
            }
        }
        let _ = TABLE7; // datasets follow Table 7's naming
    }
    csv.finish();
}
