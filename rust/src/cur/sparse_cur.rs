//! Sparse CUR (paper §5.1): "CUR preserves the sparsity ... of A; it is
//! thus more attractive than SVD in certain applications."
//!
//! `C` and `R` are sparse column/row selections of a CSR matrix; only the
//! small `U` is dense. The fast U of eq. (9) needs just the
//! `(s_c x s_r)` core block — densified from the sparse selection — so the
//! whole decomposition runs without ever materializing `A` densely.

use super::FastCurConfig;
use crate::linalg::sparse::CsrMatrix;
use crate::linalg::{pinv, Matrix};
use crate::sketch::SketchKind;
use crate::util::Rng;

/// A CUR decomposition of a sparse matrix: sparse C and R, dense U.
#[derive(Debug, Clone)]
pub struct SparseCur {
    pub c: CsrMatrix,
    pub u: Matrix,
    pub r: CsrMatrix,
    pub entries_for_u: u64,
}

impl SparseCur {
    /// Densify `C U R` (evaluation only).
    pub fn materialize(&self) -> Matrix {
        // (C U) is m x r dense, then times sparse R via R^T path:
        let cu = self.c.matmul_dense(&self.u); // m x r
        // (C U) R  = (R^T (C U)^T)^T, computed as dense x dense after
        // densifying R — fine at evaluation scale.
        cu.matmul(&self.r.to_dense())
    }

    pub fn rel_fro_error(&self, a: &CsrMatrix) -> f64 {
        let dense = a.to_dense();
        dense.sub(&self.materialize()).fro_norm_sq() / a.fro_norm_sq()
    }
}

/// Fast sparse CUR: uniform (or leverage-free) row/column sketches; the U
/// solve touches only the `s_c x s_r` core.
pub fn sparse_cur_fast(
    a: &CsrMatrix,
    col_idx: &[usize],
    row_idx: &[usize],
    cfg: FastCurConfig,
    rng: &mut Rng,
) -> SparseCur {
    assert!(
        matches!(cfg.kind, SketchKind::Uniform),
        "sparse fast CUR supports uniform sketches (leverage would densify)"
    );
    let (m, n) = (a.rows(), a.cols());
    let c = a.select_cols(col_idx);
    let r = a.select_rows(row_idx);

    let mut sc: Vec<usize> = rng.sample_without_replacement(m, cfg.s_c.min(m));
    let mut sr: Vec<usize> = rng.sample_without_replacement(n, cfg.s_r.min(n));
    if cfg.force_overlap {
        sc.extend_from_slice(row_idx);
        sr.extend_from_slice(col_idx);
    }
    sc.sort_unstable();
    sc.dedup();
    sr.sort_unstable();
    sr.dedup();

    let stc = c.select_rows(&sc).to_dense(); // s_c x c
    let rsr = r.select_cols(&sr).to_dense(); // r x s_r
    let core = a.select_rows(&sc).select_cols(&sr).to_dense(); // s_c x s_r
    let u = pinv(&stc).matmul(&core).matmul(&pinv(&rsr));
    SparseCur {
        c,
        u,
        r,
        entries_for_u: (sc.len() * sr.len()) as u64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cur::select_uniform;
    use crate::linalg::sparse::sprandn;

    /// Sparse low-rank-ish matrix: product of two sparse factors.
    fn sparse_low_rank(m: usize, n: usize, r: usize, rng: &mut Rng) -> CsrMatrix {
        let b = sprandn(m, r, 0.4, rng).to_dense();
        let c = sprandn(r, n, 0.4, rng).to_dense();
        CsrMatrix::from_dense(&b.matmul(&c), 1e-12)
    }

    #[test]
    fn c_and_r_stay_sparse() {
        let mut rng = Rng::new(0);
        let a = sprandn(60, 50, 0.1, &mut rng);
        let cols = select_uniform(50, 8, &mut rng);
        let rows = select_uniform(60, 8, &mut rng);
        let dec = sparse_cur_fast(&a, &cols, &rows, FastCurConfig::uniform(24, 24), &mut rng);
        // sparsity preserved: density of C/R within ~3x of A's
        assert!(dec.c.density() < a.density() * 3.0 + 0.05);
        assert!(dec.r.density() < a.density() * 3.0 + 0.05);
        assert_eq!(dec.c.rows(), 60);
        assert_eq!(dec.r.cols(), 50);
    }

    #[test]
    fn exact_on_sparse_low_rank() {
        let mut rng = Rng::new(1);
        let a = sparse_low_rank(40, 35, 3, &mut rng);
        let cols = select_uniform(35, 6, &mut rng);
        let rows = select_uniform(40, 6, &mut rng);
        let dec = sparse_cur_fast(&a, &cols, &rows, FastCurConfig::uniform(20, 20), &mut rng);
        let err = dec.rel_fro_error(&a);
        assert!(err < 1e-9, "err={err}");
    }

    #[test]
    fn matches_dense_fast_cur_quality() {
        let mut rng = Rng::new(2);
        let a = sparse_low_rank(50, 45, 5, &mut rng);
        let cols = select_uniform(45, 10, &mut rng);
        let rows = select_uniform(50, 10, &mut rng);
        let dec_sparse = sparse_cur_fast(&a, &cols, &rows, FastCurConfig::uniform(30, 30), &mut rng);
        let dec_dense = crate::exec::cur_fast(
            &a.to_dense(),
            &cols,
            &rows,
            FastCurConfig::uniform(30, 30),
            &crate::exec::ExecPolicy::Materialized,
            &mut rng,
        )
        .result;
        let es = dec_sparse.rel_fro_error(&a);
        let ed = dec_dense.rel_fro_error(&a.to_dense());
        assert!(es < 1e-8 && ed < 1e-8, "sparse {es} dense {ed}");
    }

    #[test]
    fn core_entry_count_bounded() {
        let mut rng = Rng::new(3);
        let a = sprandn(80, 70, 0.1, &mut rng);
        let cols = select_uniform(70, 5, &mut rng);
        let rows = select_uniform(80, 5, &mut rng);
        let dec = sparse_cur_fast(&a, &cols, &rows, FastCurConfig::uniform(15, 15), &mut rng);
        assert!(dec.entries_for_u <= (20 * 20) as u64);
    }

    #[test]
    #[should_panic(expected = "uniform")]
    fn leverage_rejected() {
        let mut rng = Rng::new(4);
        let a = sprandn(10, 10, 0.3, &mut rng);
        sparse_cur_fast(&a, &[0], &[0], FastCurConfig::leverage(4, 4), &mut rng);
    }
}
