//! Always-available span tracer: per-request trace ids, RAII span guards
//! over a stable stage taxonomy, and near-zero cost when disabled.
//!
//! The recorder is process-global but **off by default**: until
//! [`ensure_installed`] runs, [`span`] is one relaxed atomic load and
//! returns an inert guard — cheap enough to leave in every hot seam
//! (verified by `tests/obs_overhead.rs`). When enabled, each thread
//! accumulates closed spans in a thread-local buffer (no locks on the
//! span path) that drains into a bounded central store whenever the
//! thread's span stack empties or the buffer fills.
//!
//! Spans carry the [`TraceId`] that was current on their thread when they
//! opened. The service mints one id per request ([`TraceId::mint`]) and
//! re-establishes it on the worker via [`trace_scope`]; `run_pipeline`
//! forwards it into the producer thread the same way, so one request's
//! timeline is reassembled across threads by [`drain_trace`]. Aggregation
//! lives in [`profile::StageProfile`]; Chrome `trace_event` export in
//! [`sink`].

pub mod profile;
pub mod sink;

pub use profile::{StageAgg, StageProfile};

use std::cell::RefCell;
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

/// The stable stage taxonomy every span is tagged with. Names (the
/// `name()` strings) are the public contract: they key `StageProfile`
/// rows, Chrome-trace event names, and the per-stage `BENCH_stream.json`
/// counters, so renaming one is a breaking change to the artifacts.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Stage {
    /// Time a request sat in the service admission queue (recorded
    /// manually from the enqueue/dispatch timestamps, not via a guard).
    AdmissionQueue,
    /// Planner work: policy resolution + degrade-ladder construction.
    Plan,
    /// Walking the precomputed degrade ladder looking for a rung that
    /// fits the current memory pressure.
    DegradeLadder,
    /// Umbrella span over one `exec` entry point's whole body. Every
    /// other same-thread stage nests inside it, so the sum of
    /// main-thread self times equals this span's duration — the
    /// invariant `StageProfile::covered_secs` is built on.
    ExecRun,
    /// A kernel-oracle tile materialization (`row_block` / `full_rows`).
    OracleTile,
    /// Producer side of the double-buffered pipeline building one tile.
    PipelineProduce,
    /// Producer blocked pushing into the bounded channel (consumer-bound
    /// pipeline when large).
    PipelineProduceStall,
    /// Consumer side folding one tile through the consumer stack.
    PipelineFold,
    /// Consumer blocked waiting for the next tile (producer-bound
    /// pipeline when large).
    PipelineFoldStall,
    /// Residency cache served a tile from RAM.
    ResidencyRamHit,
    /// Residency cache reloaded a tile from the spill arena (one span
    /// per IO attempt, so fault-injected retries are visible).
    ResidencySpillRead,
    /// Residency cache wrote a tile through to the spill arena (one span
    /// per IO attempt).
    ResidencySpillWrite,
    /// Residency cache re-derived a tile from the underlying source.
    ResidencyRecompute,
    /// A sketch-application fold (`S^T A` accumulation).
    SketchFold,
    /// A Gram/accumulation fold (`A^T A`, leverage state, prototype U).
    GramFold,
    /// Dense symmetric eigendecomposition.
    SolveEig,
    /// Woodbury/LU solve of the small regularized system.
    SolveWoodbury,
    /// SVD-backed pseudoinverse.
    SolveSvd,
    /// One shard worker's local pass over its row-block (pipeline stages
    /// for that block nest inside it).
    ShardWorker,
    /// Coordinator merging one worker's partial fold state.
    ShardReduce,
}

impl Stage {
    /// Every stage, in taxonomy order (profile rows use this order).
    pub const ALL: [Stage; 20] = [
        Stage::AdmissionQueue,
        Stage::Plan,
        Stage::DegradeLadder,
        Stage::ExecRun,
        Stage::OracleTile,
        Stage::PipelineProduce,
        Stage::PipelineProduceStall,
        Stage::PipelineFold,
        Stage::PipelineFoldStall,
        Stage::ResidencyRamHit,
        Stage::ResidencySpillRead,
        Stage::ResidencySpillWrite,
        Stage::ResidencyRecompute,
        Stage::SketchFold,
        Stage::GramFold,
        Stage::SolveEig,
        Stage::SolveWoodbury,
        Stage::SolveSvd,
        Stage::ShardWorker,
        Stage::ShardReduce,
    ];

    /// The stable dotted name (artifact contract — see type docs).
    pub fn name(self) -> &'static str {
        match self {
            Stage::AdmissionQueue => "admission.queue",
            Stage::Plan => "plan",
            Stage::DegradeLadder => "degrade.ladder",
            Stage::ExecRun => "exec.run",
            Stage::OracleTile => "oracle.tile",
            Stage::PipelineProduce => "pipeline.produce",
            Stage::PipelineProduceStall => "pipeline.produce.stall",
            Stage::PipelineFold => "pipeline.fold",
            Stage::PipelineFoldStall => "pipeline.fold.stall",
            Stage::ResidencyRamHit => "residency.ram_hit",
            Stage::ResidencySpillRead => "residency.spill_read",
            Stage::ResidencySpillWrite => "residency.spill_write",
            Stage::ResidencyRecompute => "residency.recompute",
            Stage::SketchFold => "sketch.fold",
            Stage::GramFold => "gram.fold",
            Stage::SolveEig => "solve.eig",
            Stage::SolveWoodbury => "solve.woodbury",
            Stage::SolveSvd => "solve.svd",
            Stage::ShardWorker => "shard.worker",
            Stage::ShardReduce => "shard.reduce",
        }
    }
}

/// One closed span. `self_ns` is `dur_ns` minus the summed durations of
/// same-thread child spans — the double-count-free quantity stage totals
/// are safe to sum over.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SpanRecord {
    /// Stage taxonomy tag.
    pub stage: Stage,
    /// Raw trace id current on the recording thread (0 = untraced).
    pub trace: u64,
    /// Recorder-assigned id of the recording thread.
    pub thread: u32,
    /// Nesting depth on the recording thread when the span closed.
    pub depth: u16,
    /// Start, nanoseconds since the process trace epoch.
    pub start_ns: u64,
    /// Wall-clock duration in nanoseconds.
    pub dur_ns: u64,
    /// Duration minus same-thread child span durations.
    pub self_ns: u64,
}

/// Per-request trace identity, minted from a process-global counter.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct TraceId(u64);

impl TraceId {
    /// Mint a fresh, process-unique id (never 0).
    pub fn mint() -> TraceId {
        TraceId(NEXT_TRACE.fetch_add(1, Ordering::Relaxed))
    }

    /// The raw id, as carried by [`SpanRecord::trace`].
    pub fn raw(self) -> u64 {
        self.0
    }
}

static ENABLED: AtomicBool = AtomicBool::new(false);
static NEXT_TRACE: AtomicU64 = AtomicU64::new(1);
static NEXT_THREAD: AtomicU32 = AtomicU32::new(1);
static DROPPED: AtomicU64 = AtomicU64::new(0);
static CENTRAL: OnceLock<Mutex<Vec<SpanRecord>>> = OnceLock::new();

/// Flush the thread-local buffer whenever it reaches this many records
/// even if spans are still open (bounds per-thread memory).
const LOCAL_CAP: usize = 4096;
/// Drop (and count) records beyond this many in the central store — a
/// backstop against a run that never drains.
const CENTRAL_CAP: usize = 1 << 20;

fn central() -> &'static Mutex<Vec<SpanRecord>> {
    CENTRAL.get_or_init(|| Mutex::new(Vec::new()))
}

fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

/// Nanoseconds since the process trace epoch (the clock all span
/// timestamps share).
pub fn now_ns() -> u64 {
    epoch().elapsed().as_nanos() as u64
}

struct OpenFrame {
    stage: Stage,
    trace: u64,
    start_ns: u64,
    /// Summed durations of already-closed direct children.
    child_ns: u64,
}

struct Local {
    stack: Vec<OpenFrame>,
    buf: Vec<SpanRecord>,
    thread: u32,
    /// Trace id applied to spans opened on this thread (0 = untraced).
    trace: u64,
}

thread_local! {
    static LOCAL: RefCell<Local> = RefCell::new(Local {
        stack: Vec::new(),
        buf: Vec::new(),
        thread: NEXT_THREAD.fetch_add(1, Ordering::Relaxed),
        trace: 0,
    });
}

/// Turn the recorder on for the rest of the process. Idempotent; there is
/// deliberately no way to turn it off (tests that need the disabled mode
/// run in their own process — see `tests/obs_overhead.rs`).
pub fn ensure_installed() {
    central();
    // fix the epoch before any span reads it, so timestamps are
    // monotone from here on
    epoch();
    ENABLED.store(true, Ordering::Release);
}

/// Whether the recorder is collecting spans.
#[inline]
pub fn installed() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Open a span for `stage`; it closes (and records) when the returned
/// guard drops. When the recorder is not installed this is one atomic
/// load and the guard is inert.
#[inline]
pub fn span(stage: Stage) -> SpanGuard {
    if !ENABLED.load(Ordering::Relaxed) {
        return SpanGuard { active: false };
    }
    let start_ns = now_ns();
    LOCAL.with(|l| {
        let mut l = l.borrow_mut();
        let trace = l.trace;
        l.stack.push(OpenFrame { stage, trace, start_ns, child_ns: 0 });
    });
    SpanGuard { active: true }
}

/// RAII guard returned by [`span`]; records the span on drop.
#[must_use = "a span measures the scope holding its guard"]
pub struct SpanGuard {
    active: bool,
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if !self.active {
            return;
        }
        let end_ns = now_ns();
        LOCAL.with(|l| {
            let mut l = l.borrow_mut();
            let Some(f) = l.stack.pop() else { return };
            let dur_ns = end_ns.saturating_sub(f.start_ns);
            if let Some(parent) = l.stack.last_mut() {
                parent.child_ns += dur_ns;
            }
            let rec = SpanRecord {
                stage: f.stage,
                trace: f.trace,
                thread: l.thread,
                depth: l.stack.len() as u16,
                start_ns: f.start_ns,
                dur_ns,
                self_ns: dur_ns.saturating_sub(f.child_ns),
            };
            l.buf.push(rec);
            if l.stack.is_empty() || l.buf.len() >= LOCAL_CAP {
                flush_buf(&mut l.buf);
            }
        });
    }
}

fn flush_buf(buf: &mut Vec<SpanRecord>) {
    if buf.is_empty() {
        return;
    }
    let mut c = central().lock().unwrap();
    let room = CENTRAL_CAP.saturating_sub(c.len());
    if room < buf.len() {
        DROPPED.fetch_add((buf.len() - room) as u64, Ordering::Relaxed);
        buf.truncate(room);
    }
    c.append(buf);
}

/// Push any closed-but-unflushed spans of the calling thread to the
/// central store (drains call this; also useful before reading
/// [`dropped`]).
pub fn flush_current_thread() {
    if !installed() {
        return;
    }
    LOCAL.with(|l| flush_buf(&mut l.borrow_mut().buf));
}

/// Records discarded because the central store hit its cap.
pub fn dropped() -> u64 {
    DROPPED.load(Ordering::Relaxed)
}

/// The recorder-assigned id of the calling thread (what
/// [`SpanRecord::thread`] holds for spans recorded here).
pub fn current_thread_id() -> u32 {
    LOCAL.with(|l| l.borrow().thread)
}

/// The raw trace id spans opened on this thread are currently tagged
/// with (0 when untraced or the recorder is off).
pub fn current_trace_raw() -> u64 {
    if !installed() {
        return 0;
    }
    LOCAL.with(|l| l.borrow().trace)
}

/// Tag spans opened on this thread with `raw` until the returned guard
/// drops (restores the previous tag). `raw = 0` or a disabled recorder
/// makes this a no-op — callers can always forward
/// [`current_trace_raw`] across a thread hop unconditionally.
pub fn trace_scope(raw: u64) -> TraceScope {
    if !installed() || raw == 0 {
        return TraceScope { prev: 0, active: false };
    }
    let prev = LOCAL.with(|l| {
        let mut l = l.borrow_mut();
        std::mem::replace(&mut l.trace, raw)
    });
    TraceScope { prev, active: true }
}

/// RAII guard from [`trace_scope`]; restores the previous trace tag.
#[must_use = "the trace tag reverts when this guard drops"]
pub struct TraceScope {
    prev: u64,
    active: bool,
}

impl Drop for TraceScope {
    fn drop(&mut self) {
        if self.active {
            let prev = self.prev;
            LOCAL.with(|l| l.borrow_mut().trace = prev);
        }
    }
}

/// Record a span from explicit timestamps (for intervals that cross
/// threads, like queue wait, where no single scope holds the guard).
pub fn record_manual(stage: Stage, trace: u64, start_ns: u64, dur_ns: u64) {
    if !installed() {
        return;
    }
    let rec = SpanRecord {
        stage,
        trace,
        thread: current_thread_id(),
        depth: 0,
        start_ns,
        dur_ns,
        self_ns: dur_ns,
    };
    let mut c = central().lock().unwrap();
    if c.len() < CENTRAL_CAP {
        c.push(rec);
    } else {
        DROPPED.fetch_add(1, Ordering::Relaxed);
    }
}

/// Remove and return every record of `trace`, sorted by start time.
/// Threads that finished their top-level spans (e.g. a joined pipeline
/// producer) are fully captured; the calling thread is flushed first.
pub fn drain_trace(trace: u64) -> Vec<SpanRecord> {
    flush_current_thread();
    if !installed() {
        return Vec::new();
    }
    let mut c = central().lock().unwrap();
    let mut out = Vec::new();
    c.retain(|r| {
        if r.trace == trace {
            out.push(*r);
            false
        } else {
            true
        }
    });
    drop(c);
    out.sort_by_key(|r| r.start_ns);
    out
}

/// Copy (without removing) every record of `trace`, sorted by start
/// time — for mid-request consumers like `exec` when the service owns
/// the trace and will drain it at reply time.
pub fn snapshot_trace(trace: u64) -> Vec<SpanRecord> {
    flush_current_thread();
    if !installed() {
        return Vec::new();
    }
    let c = central().lock().unwrap();
    let mut out: Vec<SpanRecord> = c.iter().filter(|r| r.trace == trace).copied().collect();
    drop(c);
    out.sort_by_key(|r| r.start_ns);
    out
}

/// Remove and return everything in the central store, sorted by start
/// time (bench/figure runs that trace without per-request ids).
pub fn drain_all() -> Vec<SpanRecord> {
    flush_current_thread();
    if !installed() {
        return Vec::new();
    }
    let mut c = central().lock().unwrap();
    let mut out = std::mem::take(&mut *c);
    drop(c);
    out.sort_by_key(|r| r.start_ns);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    // Every test here must install the recorder (process-global, never
    // uninstalled); the disabled path is covered by the dedicated
    // single-test binary `tests/obs_overhead.rs`.

    #[test]
    fn spans_nest_and_partition_self_time() {
        ensure_installed();
        let t = TraceId::mint().raw();
        let _ts = trace_scope(t);
        {
            let _outer = span(Stage::ExecRun);
            {
                let _inner = span(Stage::SolveEig);
                std::hint::black_box((0..2000).sum::<u64>());
            }
            {
                let _inner = span(Stage::SolveSvd);
                std::hint::black_box((0..2000).sum::<u64>());
            }
        }
        let recs = drain_trace(t);
        assert_eq!(recs.len(), 3);
        let outer = recs.iter().find(|r| r.stage == Stage::ExecRun).unwrap();
        let kids: u64 = recs
            .iter()
            .filter(|r| r.stage != Stage::ExecRun)
            .map(|r| r.dur_ns)
            .sum();
        assert_eq!(outer.depth, 0);
        assert!(recs.iter().filter(|r| r.stage != Stage::ExecRun).all(|r| r.depth == 1));
        // self + children == total, exactly (same-thread accounting)
        assert_eq!(outer.self_ns + kids, outer.dur_ns);
        // children fall inside the parent interval
        for r in &recs {
            assert!(r.start_ns >= outer.start_ns);
            assert!(r.start_ns + r.dur_ns <= outer.start_ns + outer.dur_ns);
        }
    }

    #[test]
    fn trace_scope_restores_and_untraced_spans_stay_out() {
        ensure_installed();
        let a = TraceId::mint().raw();
        let b = TraceId::mint().raw();
        {
            let _ta = trace_scope(a);
            assert_eq!(current_trace_raw(), a);
            {
                let _tb = trace_scope(b);
                assert_eq!(current_trace_raw(), b);
                let _s = span(Stage::Plan);
            }
            assert_eq!(current_trace_raw(), a);
            let _s = span(Stage::Plan);
        }
        assert_eq!(drain_trace(a).len(), 1);
        assert_eq!(drain_trace(b).len(), 1);
        // spans opened with no trace never leak into a drain-by-id
        {
            let _s = span(Stage::Plan);
        }
        assert!(drain_trace(a).is_empty());
    }

    #[test]
    fn snapshot_keeps_records_for_the_final_drain() {
        ensure_installed();
        let t = TraceId::mint().raw();
        {
            let _ts = trace_scope(t);
            let _s = span(Stage::GramFold);
        }
        assert_eq!(snapshot_trace(t).len(), 1);
        assert_eq!(snapshot_trace(t).len(), 1, "snapshot must not consume");
        assert_eq!(drain_trace(t).len(), 1);
        assert!(drain_trace(t).is_empty(), "drain must consume");
    }

    #[test]
    fn manual_records_and_mint_are_distinct() {
        ensure_installed();
        let t = TraceId::mint().raw();
        assert_ne!(t, TraceId::mint().raw());
        record_manual(Stage::AdmissionQueue, t, 100, 50);
        let recs = drain_trace(t);
        assert_eq!(recs.len(), 1);
        assert_eq!(recs[0].stage, Stage::AdmissionQueue);
        assert_eq!(recs[0].dur_ns, 50);
        assert_eq!(recs[0].self_ns, 50);
    }

    #[test]
    fn stage_names_are_stable_and_unique() {
        let mut seen = std::collections::BTreeSet::new();
        for s in Stage::ALL {
            assert!(seen.insert(s.name()), "duplicate stage name {}", s.name());
        }
        assert_eq!(seen.len(), Stage::ALL.len());
        assert_eq!(Stage::AdmissionQueue.name(), "admission.queue");
        assert_eq!(Stage::ResidencySpillRead.name(), "residency.spill_read");
        assert_eq!(Stage::PipelineProduceStall.name(), "pipeline.produce.stall");
    }
}
