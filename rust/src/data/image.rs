//! Synthetic "natural image" for the Fig-2 CUR experiment.
//!
//! The paper decomposes a 1920 x 1168 internet photo. We generate a
//! procedural image with the properties CUR cares about: a strong
//! approximately-low-rank background (smooth gradients), mid-frequency
//! texture, and localized structures that break exact low-rankness.
//! Output values live in [0, 255]. A PGM writer is provided so results can
//! be eyeballed.

use crate::linalg::Matrix;
use crate::util::Rng;

/// Generate the synthetic image (rows x cols).
pub fn synth_image(rows: usize, cols: usize, seed: u64) -> Matrix {
    let mut rng = Rng::new(seed);
    let mut img = Matrix::zeros(rows, cols);
    // (a) smooth low-rank background: sum of a few separable smooth terms
    let terms = 6;
    let mut row_basis = Vec::new();
    let mut col_basis = Vec::new();
    for t in 0..terms {
        let fr = 0.5 + 1.7 * t as f64 + rng.f64();
        let fc = 0.4 + 1.3 * t as f64 + rng.f64();
        let pr = rng.f64() * std::f64::consts::TAU;
        let pc = rng.f64() * std::f64::consts::TAU;
        let amp = 60.0 / (t as f64 + 1.0);
        row_basis.push(
            (0..rows)
                .map(|i| amp * (fr * i as f64 / rows as f64 * std::f64::consts::TAU + pr).sin())
                .collect::<Vec<f64>>(),
        );
        col_basis.push(
            (0..cols)
                .map(|j| (fc * j as f64 / cols as f64 * std::f64::consts::TAU + pc).cos())
                .collect::<Vec<f64>>(),
        );
    }
    for i in 0..rows {
        let dst = img.row_mut(i);
        for t in 0..terms {
            let r = row_basis[t][i];
            for (j, v) in dst.iter_mut().enumerate() {
                *v += r * col_basis[t][j];
            }
        }
    }
    // (b) mid-frequency texture (still fairly structured)
    for i in 0..rows {
        let si = (i as f64 * 0.21).sin();
        let dst = img.row_mut(i);
        for (j, v) in dst.iter_mut().enumerate() {
            *v += 8.0 * si * (j as f64 * 0.17).cos();
        }
    }
    // (c) localized shapes: *rotated* soft ellipses — the cross term
    // (rho * di * dj) breaks separability, so these genuinely raise the
    // numerical rank the way objects in a photo do.
    for _ in 0..10 {
        let ci = rng.f64() * rows as f64;
        let cj = rng.f64() * cols as f64;
        let ri = 30.0 + rng.f64() * 120.0;
        let rj = 30.0 + rng.f64() * 120.0;
        let rho = 1.2 * (rng.f64() - 0.5); // rotation / shear
        let amp = 40.0 * rng.sign();
        let i0 = ((ci - 3.0 * ri).max(0.0)) as usize;
        let i1 = ((ci + 3.0 * ri).min(rows as f64 - 1.0)) as usize;
        for i in i0..=i1 {
            let di = (i as f64 - ci) / ri;
            let j0 = ((cj - 3.0 * rj).max(0.0)) as usize;
            let j1 = ((cj + 3.0 * rj).min(cols as f64 - 1.0)) as usize;
            let dst = img.row_mut(i);
            for (j, v) in dst.iter_mut().enumerate().take(j1 + 1).skip(j0) {
                let dj = (j as f64 - cj) / rj;
                let r2 = di * di + dj * dj + rho * di * dj;
                if r2 < 9.0 {
                    *v += amp * (-r2).exp();
                }
            }
        }
    }
    // (c') faint sensor noise — keeps the tail spectrum non-zero like a
    // real photograph (std ≈ 0.6 gray levels after rescaling).
    for v in img.data_mut() {
        *v += 1.5 * rng.gaussian();
    }
    // (d) shift/clip into [0, 255]
    let (mut lo, mut hi) = (f64::INFINITY, f64::NEG_INFINITY);
    for &v in img.data() {
        lo = lo.min(v);
        hi = hi.max(v);
    }
    let span = (hi - lo).max(1e-9);
    for v in img.data_mut() {
        *v = (*v - lo) / span * 255.0;
    }
    img
}

/// Write as binary PGM (for eyeballing reconstructions).
pub fn write_pgm(img: &Matrix, path: &std::path::Path) -> std::io::Result<()> {
    use std::io::Write;
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(f, "P5\n{} {}\n255", img.cols(), img.rows())?;
    let bytes: Vec<u8> = img.data().iter().map(|&v| v.clamp(0.0, 255.0) as u8).collect();
    f.write_all(&bytes)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::svd_thin;

    #[test]
    fn range_and_determinism() {
        let a = synth_image(64, 48, 0);
        let b = synth_image(64, 48, 0);
        assert!(a.max_abs_diff(&b) == 0.0);
        for &v in a.data() {
            assert!((0.0..=255.0).contains(&v));
        }
        let c = synth_image(64, 48, 1);
        assert!(a.max_abs_diff(&c) > 0.0);
    }

    #[test]
    fn approximately_low_rank() {
        // top-20 singular values should capture most of the energy —
        // the property Fig 2's CUR experiment relies on.
        let img = synth_image(120, 90, 2);
        let f = svd_thin(&img);
        let total: f64 = f.s.iter().map(|s| s * s).sum();
        let top20: f64 = f.s.iter().take(20).map(|s| s * s).sum();
        assert!(top20 / total > 0.95, "top20 share = {}", top20 / total);
        // but not exactly low rank
        assert!(f.s[40] > 1e-8);
    }

    #[test]
    fn pgm_roundtrip_header() {
        let img = synth_image(10, 8, 3);
        let path = std::env::temp_dir().join("fastspsd_test.pgm");
        write_pgm(&img, &path).unwrap();
        let data = std::fs::read(&path).unwrap();
        assert!(data.starts_with(b"P5\n8 10\n255\n"));
        assert_eq!(data.len(), "P5\n8 10\n255\n".len() + 80);
    }
}
