//! Bench: Figures 11/12 — spectral clustering NMI at bench scale.

use fastspsd::cli::Args;
use fastspsd::figures::{spectral_fig, Ctx};

fn main() {
    let args = Args::parse(
        [
            "fig11", "--scale", "0.05", "--reps", "1", "--dataset", "PenDigit", "--cpu",
            "--cs", "10,20,40", "--out", "out",
        ]
        .iter()
        .map(|s| s.to_string()),
    );
    let ctx = Ctx::from_args(&args);
    println!("== Fig 11/12 series (bench scale) ==");
    spectral_fig::run(&ctx, &args);
}
