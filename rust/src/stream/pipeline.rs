//! The bounded double-buffered tile scheduler.
//!
//! `run_pipeline` splits a [`TileSource`](super::TileSource) into
//! `tile_rows`-high tiles, computes them on the global thread pool, and
//! feeds each tile to every consumer *in row order* on the caller's
//! thread. The producer runs at most `queue_depth` tiles ahead (a bounded
//! `Mutex<VecDeque>` + two condvars), so peak live tiles are
//! `queue_depth + 2` (one being produced, `queue_depth` queued, one being
//! folded) regardless of `n` — this is what turns the paper's entry-count
//! accounting into a memory bound.
//!
//! Consumption order is deterministic (ascending `r0`), so gather-style
//! consumers are bit-identical to the materialized path and
//! accumulation-style consumers differ only by reduction grouping.
//!
//! Two integrity layers ride the same scheduler (both opt-in, both free
//! when off):
//!
//! - **Tile quarantine** ([`ValidateMode`] via
//!   [`run_pipeline_validated`]): every tile is scanned for non-finite
//!   (or absurd-magnitude) values on the consumer thread *before* any
//!   fold sees it; a hit fails the pass fast with the typed
//!   [`PipelineError::PoisonedTile`] instead of letting one NaN saturate
//!   a Gram/sketch accumulator into an all-NaN result. `Off` costs one
//!   enum branch per tile.
//! - **Checkpoint/resume** ([`checkpoint`](super::checkpoint)): when a
//!   checkpoint context is armed on the calling thread and every
//!   consumer supports [`TileConsumer::snapshot`], fold state is
//!   persisted every K tiles and an interrupted pass resumes from the
//!   last completed tile — the producer starts at the resumed row, so
//!   the oracle is re-charged only for tiles after the checkpoint.
//!
//! Both sides are span-traced ([`obs`]): tile builds as
//! `pipeline.produce`, folds as `pipeline.fold`, and the time each side
//! spends blocked on the bounded channel as `pipeline.produce.stall` /
//! `pipeline.fold.stall` — the stall fractions that answer whether a run
//! is oracle-bound or fold-bound (EXPERIMENTS.md §Observability).

use super::checkpoint::{self, CheckpointConfig};
use super::{TileConsumer, TileSource};
use crate::linalg::{Precision, Tile};
use crate::obs::{self, Stage};
use crate::pool;
use crate::testkit::faults::{self, FaultPlan, FaultPoint};
use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};

/// What the per-tile quarantine scan looks for (see
/// [`StreamConfig::validate`](super::StreamConfig::validate)).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ValidateMode {
    /// No scan — one branch per tile, the bit-compat default.
    #[default]
    Off,
    /// Reject tiles containing NaN or ±Inf.
    NonFinite,
    /// Additionally reject finite values with `|v| > 1e154` — magnitudes
    /// whose square overflows f64, i.e. values guaranteed to poison a
    /// Gram fold even though they are technically finite. (f32 tiles
    /// cannot reach that magnitude, so `Full` equals `NonFinite` there.)
    Full,
}

/// Finite values above this magnitude overflow when squared by a Gram
/// fold (`sqrt(f64::MAX) ≈ 1.34e154`).
const SQUARE_SAFE_MAX: f64 = 1e154;

impl ValidateMode {
    /// Scan `tile`; `Some(lane)` is the column of the first offending
    /// value.
    fn scan(self, tile: &Tile) -> Option<usize> {
        match self {
            ValidateMode::Off => None,
            ValidateMode::NonFinite | ValidateMode::Full => {
                let full = self == ValidateMode::Full;
                match tile {
                    Tile::F64(m) => {
                        let cols = m.cols().max(1);
                        m.data()
                            .iter()
                            .position(|v| !v.is_finite() || (full && v.abs() > SQUARE_SAFE_MAX))
                            .map(|p| p % cols)
                    }
                    Tile::F32(m) => {
                        let cols = m.cols().max(1);
                        m.data().iter().position(|v| !v.is_finite()).map(|p| p % cols)
                    }
                }
            }
        }
    }
}

/// Typed failure of a validated pipeline pass.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PipelineError {
    /// Tile `index` (ascending tile ordinal) carried a non-finite or
    /// out-of-range value in column `lane`. No consumer folded it.
    PoisonedTile { index: usize, lane: usize },
}

impl std::fmt::Display for PipelineError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PipelineError::PoisonedTile { index, lane } => write!(
                f,
                "poisoned tile {index}: non-finite or out-of-range value in lane {lane}"
            ),
        }
    }
}

impl std::error::Error for PipelineError {}

struct ChanState {
    buf: VecDeque<(usize, Tile)>,
    /// Producer finished pushing every tile.
    tx_done: bool,
    /// Consumer stopped (normally or by unwinding); producer must bail out
    /// rather than block on a queue nobody drains.
    rx_dead: bool,
}

/// Bounded SPSC tile queue.
struct Chan {
    state: Mutex<ChanState>,
    nonempty: Condvar,
    nonfull: Condvar,
    capacity: usize,
}

impl Chan {
    fn new(capacity: usize) -> Self {
        Chan {
            state: Mutex::new(ChanState {
                buf: VecDeque::with_capacity(capacity),
                tx_done: false,
                rx_dead: false,
            }),
            nonempty: Condvar::new(),
            nonfull: Condvar::new(),
            capacity,
        }
    }

    /// Blocks while the queue is full. Returns false when the receiver is
    /// gone (the producer should stop computing tiles).
    fn push(&self, item: (usize, Tile)) -> bool {
        let mut st = self.state.lock().unwrap();
        while st.buf.len() >= self.capacity && !st.rx_dead {
            st = self.nonfull.wait(st).unwrap();
        }
        if st.rx_dead {
            return false;
        }
        st.buf.push_back(item);
        drop(st);
        self.nonempty.notify_one();
        true
    }

    fn close_tx(&self) {
        self.state.lock().unwrap().tx_done = true;
        self.nonempty.notify_all();
    }

    fn close_rx(&self) {
        self.state.lock().unwrap().rx_dead = true;
        self.nonfull.notify_all();
    }

    /// Blocks until a tile is available; `None` once the producer is done
    /// and the queue is drained.
    fn pop(&self) -> Option<(usize, Tile)> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(item) = st.buf.pop_front() {
                drop(st);
                self.nonfull.notify_one();
                return Some(item);
            }
            if st.tx_done {
                return None;
            }
            st = self.nonempty.wait(st).unwrap();
        }
    }
}

/// Marks the receiver dead on drop so a panicking consumer can never
/// deadlock the producer against a full queue.
struct RxGuard<'a>(&'a Chan);

impl Drop for RxGuard<'_> {
    fn drop(&mut self) {
        self.0.close_rx();
    }
}

/// Marks the producer done on drop — including when `TileSource::tile`
/// panics (the pool catches job panics without rethrowing, so without this
/// guard the consumer would wait on `nonempty` forever).
struct TxGuard<'a>(&'a Chan);

impl Drop for TxGuard<'_> {
    fn drop(&mut self) {
        self.0.close_tx();
    }
}

/// Stream `src` through `consumers` in `tile_rows`-high f64 tiles — the
/// historical surface, an exact alias of
/// [`run_pipeline_prec`]`(.., Precision::F64, ..)`; every seam it crosses
/// is bit-identical to the pre-typed-tile pipeline.
pub fn run_pipeline(
    src: &dyn TileSource,
    tile_rows: usize,
    queue_depth: usize,
    consumers: &mut [&mut dyn TileConsumer],
) {
    run_pipeline_prec(src, tile_rows, queue_depth, Precision::F64, consumers);
}

/// Stream `src` through `consumers` in `tile_rows`-high tiles of the
/// requested element width, without validation — an exact alias of
/// [`run_pipeline_validated`]`(.., ValidateMode::Off, ..)` (which cannot
/// fail). Checkpointing still engages when a
/// [`checkpoint`](super::checkpoint) context is armed on this thread.
pub fn run_pipeline_prec(
    src: &dyn TileSource,
    tile_rows: usize,
    queue_depth: usize,
    precision: Precision,
    consumers: &mut [&mut dyn TileConsumer],
) {
    match run_pipeline_validated(src, tile_rows, queue_depth, precision, ValidateMode::Off, consumers)
    {
        Ok(()) => {}
        Err(e) => unreachable!("ValidateMode::Off cannot fail: {e}"),
    }
}

/// One explicitly checkpointed pass: arms `ckpt` for the duration of the
/// run (so a later identical call resumes from whatever this one
/// persisted) and streams with validation. See the module docs of
/// [`checkpoint`](super::checkpoint) for the resume contract.
pub fn run_pipeline_resumable(
    src: &dyn TileSource,
    tile_rows: usize,
    queue_depth: usize,
    precision: Precision,
    validate: ValidateMode,
    ckpt: &CheckpointConfig,
    consumers: &mut [&mut dyn TileConsumer],
) -> Result<(), PipelineError> {
    let _g = checkpoint::arm(ckpt);
    run_pipeline_validated(src, tile_rows, queue_depth, precision, validate, consumers)
}

/// Stream `src` through `consumers` in `tile_rows`-high tiles of the
/// requested element width, scanning each tile per `validate` before any
/// consumer folds it.
///
/// When one tile covers every row the pipeline is skipped entirely: the
/// tile is computed inline and fed once (the materialized fallback). A
/// `queue_depth` of 1 still overlaps producer and consumer; 2 (the
/// default) double-buffers. The width changes only what the channel
/// carries: consumption order, fault seams, and span accounting are
/// identical in both precisions, and every consumer folds into f64 state
/// regardless of the tile type.
///
/// On [`PipelineError::PoisonedTile`] the offending tile has been folded
/// by **no** consumer, the producer is stopped, and — if a checkpoint
/// context is armed — the last persisted checkpoint is left in place, so
/// a retry after fixing the source resumes rather than restarting.
pub fn run_pipeline_validated(
    src: &dyn TileSource,
    tile_rows: usize,
    queue_depth: usize,
    precision: Precision,
    validate: ValidateMode,
    consumers: &mut [&mut dyn TileConsumer],
) -> Result<(), PipelineError> {
    let n = src.rows();
    if n == 0 {
        return Ok(());
    }
    // Chaos seam: a globally armed FaultPlan can schedule a consumer-fold
    // panic or a poisoned tile (captured once per pipeline run).
    let faults = faults::current();
    let t = tile_rows.clamp(1, n);
    // Claim this run's pass ordinal even when the whole-tile shortcut or
    // the consumers make checkpointing moot: the ordinal must be a
    // function of the run sequence alone so a retried request maps every
    // pass onto the same checkpoint file.
    let pass = checkpoint::next_pass_spec();
    if t >= n {
        let mut tile = {
            let _s = obs::span(Stage::PipelineProduce);
            src.tile_elem(0, n, precision)
        };
        maybe_poison(&faults, &mut tile);
        if let Some(lane) = validate.scan(&tile) {
            crate::linalg::guard::note_quarantined_tile();
            return Err(PipelineError::PoisonedTile { index: 0, lane });
        }
        trip_fold_fault(&faults, 0);
        let _s = obs::span(Stage::PipelineFold);
        for c in consumers.iter_mut() {
            c.consume_tile(0, &tile);
        }
        return Ok(());
    }
    // Checkpointing engages only when every consumer can snapshot (the
    // row-ordered sum folds); a pass with any gather/sampler consumer
    // streams exactly as before.
    let ckpt = pass.filter(|_| consumers.iter().all(|c| c.snapshot().is_some()));
    let meta = checkpoint::PassMeta {
        n,
        cols: src.cols(),
        tile_rows: t,
        precision,
        consumers: consumers.len(),
    };
    let mut start_r0 = 0usize;
    if let Some(spec) = &ckpt {
        if let Some((next_r0, snaps)) = checkpoint::load(&spec.path, &meta) {
            let shapes_match = snaps.len() == consumers.len()
                && consumers.iter().zip(&snaps).all(|(c, s)| {
                    c.snapshot()
                        .map_or(false, |cur| cur.rows() == s.rows() && cur.cols() == s.cols())
                });
            if shapes_match {
                for (c, s) in consumers.iter_mut().zip(&snaps) {
                    let restored = c.restore(s);
                    debug_assert!(restored, "restore failed after shape check");
                }
                start_r0 = next_r0;
            }
        }
    }
    // Forward the caller's trace id into the pool-spawned producer so
    // both sides of the pipeline land in the same request timeline.
    let trace = obs::current_trace_raw();
    let chan = Chan::new(queue_depth.max(1));
    let chan_ref = &chan;
    let faults_prod = faults.clone();
    let mut outcome: Result<(), PipelineError> = Ok(());
    pool::global().scoped(|scope| {
        scope.spawn(move || {
            let _trace = obs::trace_scope(trace);
            let _done = TxGuard(chan_ref);
            let mut r0 = start_r0;
            while r0 < n {
                let r1 = (r0 + t).min(n);
                let mut tile = {
                    let _s = obs::span(Stage::PipelineProduce);
                    src.tile_elem(r0, r1, precision)
                };
                maybe_poison(&faults_prod, &mut tile);
                let pushed = {
                    let _s = obs::span(Stage::PipelineProduceStall);
                    chan_ref.push((r0, tile))
                };
                if !pushed {
                    return; // receiver gone — stop producing
                }
                r0 = r1;
            }
        });
        let _guard = RxGuard(chan_ref);
        let mut folded = 0usize;
        loop {
            let item = {
                let _s = obs::span(Stage::PipelineFoldStall);
                chan_ref.pop()
            };
            let Some((r0, tile)) = item else { break };
            if let Some(lane) = validate.scan(&tile) {
                // quarantine: no consumer sees the tile; RxGuard stops
                // the producer on drop
                crate::linalg::guard::note_quarantined_tile();
                outcome = Err(PipelineError::PoisonedTile { index: r0 / t, lane });
                break;
            }
            trip_fold_fault(&faults, r0);
            {
                let _s = obs::span(Stage::PipelineFold);
                for c in consumers.iter_mut() {
                    c.consume_tile(r0, &tile);
                }
            }
            if let Some(spec) = &ckpt {
                folded += 1;
                let r1 = (r0 + t).min(n);
                if folded % spec.every == 0 && r1 < n {
                    let snaps: Vec<_> = consumers
                        .iter()
                        .map(|c| c.snapshot().expect("snapshot support checked at pass start"))
                        .collect();
                    // a failed write only costs resume granularity
                    let _ = checkpoint::save(&spec.path, &meta, r1, &snaps);
                }
            }
        }
    });
    if outcome.is_ok() {
        if let Some(spec) = &ckpt {
            checkpoint::discard(&spec.path);
        }
    }
    outcome
}

/// Panic on the fold the armed plan scheduled (counted once per tile, on
/// the consumer thread, so the unwind exercises the RxGuard exactly like
/// a real consumer bug would).
fn trip_fold_fault(faults: &Option<Arc<FaultPlan>>, r0: usize) {
    if let Some(plan) = faults {
        if plan.should_fail(FaultPoint::ConsumerFold) {
            panic!("injected fault: consumer fold at r0={r0}");
        }
    }
}

/// Write a NaN into the scheduled tile on the producer side — the seam
/// [`ValidateMode`] quarantines; with validation off the NaN flows into
/// the folds exactly like an unguarded oracle bug would.
fn maybe_poison(faults: &Option<Arc<FaultPlan>>, tile: &mut Tile) {
    if let Some(plan) = faults {
        if plan.should_fail(FaultPoint::PoisonTile) {
            match tile {
                Tile::F64(m) => {
                    if let Some(v) = m.data_mut().first_mut() {
                        *v = f64::NAN;
                    }
                }
                Tile::F32(m) => {
                    if let Some(v) = m.data_mut().first_mut() {
                        *v = f32::NAN;
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;
    use crate::stream::{CollectConsumer, GramFold, MatrixSource, TileSource};
    use crate::util::Rng;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn visits_every_row_once_in_order_for_awkward_tile_sizes() {
        let mut rng = Rng::new(0);
        let a = Matrix::randn(29, 3, &mut rng);
        for tile in [1usize, 2, 7, 13, 28, 29, 64] {
            struct Probe {
                next: usize,
            }
            impl TileConsumer for Probe {
                fn consume(&mut self, r0: usize, tile: &Matrix) {
                    assert_eq!(r0, self.next, "tiles must arrive in order");
                    assert!(tile.rows() > 0);
                    self.next = r0 + tile.rows();
                }
            }
            let src = MatrixSource::new(&a);
            let mut probe = Probe { next: 0 };
            let mut collect = CollectConsumer::new(29, 3);
            run_pipeline(&src, tile, 2, &mut [&mut probe, &mut collect]);
            assert_eq!(probe.next, 29, "tile={tile}");
            assert_eq!(collect.into_matrix().max_abs_diff(&a), 0.0, "tile={tile}");
        }
    }

    #[test]
    fn producer_stays_within_queue_depth() {
        // A source that counts outstanding tiles: produced - consumed must
        // never exceed depth + 2 (one in production, depth queued, one
        // being folded).
        struct CountingSource {
            produced: AtomicUsize,
        }
        impl TileSource for CountingSource {
            fn rows(&self) -> usize {
                64
            }
            fn cols(&self) -> usize {
                2
            }
            fn tile(&self, r0: usize, r1: usize) -> Matrix {
                self.produced.fetch_add(1, Ordering::SeqCst);
                Matrix::from_fn(r1 - r0, 2, |i, j| (r0 + i + j) as f64)
            }
        }
        struct SlowConsumer<'a> {
            src: &'a CountingSource,
            consumed: usize,
            max_outstanding: usize,
        }
        impl TileConsumer for SlowConsumer<'_> {
            fn consume(&mut self, _r0: usize, _tile: &Matrix) {
                std::thread::sleep(std::time::Duration::from_millis(2));
                let produced = self.src.produced.load(Ordering::SeqCst);
                self.max_outstanding = self.max_outstanding.max(produced - self.consumed);
                self.consumed += 1;
            }
        }
        for depth in [1usize, 2, 3] {
            let src = CountingSource { produced: AtomicUsize::new(0) };
            let mut cons = SlowConsumer { src: &src, consumed: 0, max_outstanding: 0 };
            run_pipeline(&src, 4, depth, &mut [&mut cons]);
            assert_eq!(cons.consumed, 16);
            assert!(
                cons.max_outstanding <= depth + 2,
                "depth {depth}: {} tiles outstanding",
                cons.max_outstanding
            );
        }
    }

    #[test]
    fn f32_stream_is_tile_size_invariant_for_gathers() {
        // Collect-style consumers see the same demoted values whatever the
        // tiling: the per-row demotion is independent of tile boundaries.
        let mut rng = Rng::new(3);
        let a = Matrix::randn(29, 3, &mut rng);
        let src = MatrixSource::new(&a);
        let mut reference = CollectConsumer::new(29, 3);
        run_pipeline_prec(&src, 29, 2, Precision::F32, &mut [&mut reference]);
        let reference = reference.into_matrix();
        assert_eq!(reference.max_abs_diff(&a.demote().promote()), 0.0);
        for tile in [1usize, 2, 7, 13, 28] {
            let mut collect = CollectConsumer::new(29, 3);
            run_pipeline_prec(&src, tile, 2, Precision::F32, &mut [&mut collect]);
            assert_eq!(collect.into_matrix().max_abs_diff(&reference), 0.0, "tile={tile}");
        }
    }

    #[test]
    fn empty_source_is_a_noop() {
        let a = Matrix::zeros(0, 4);
        let src = MatrixSource::new(&a);
        struct MustNotRun;
        impl TileConsumer for MustNotRun {
            fn consume(&mut self, _: usize, _: &Matrix) {
                panic!("no tiles expected");
            }
        }
        run_pipeline(&src, 8, 2, &mut [&mut MustNotRun]);
    }

    #[test]
    fn panicking_producer_does_not_deadlock_consumer() {
        // A TileSource that panics mid-stream: the TxGuard must close the
        // channel so the consumer unblocks, and ThreadPool::scoped must
        // re-raise the job panic so the truncated stream never escapes
        // silently.
        struct BombSource;
        impl TileSource for BombSource {
            fn rows(&self) -> usize {
                32
            }
            fn cols(&self) -> usize {
                2
            }
            fn tile(&self, r0: usize, r1: usize) -> Matrix {
                if r0 >= 8 {
                    panic!("producer bomb");
                }
                Matrix::zeros(r1 - r0, 2)
            }
        }
        struct Sink;
        impl TileConsumer for Sink {
            fn consume(&mut self, _: usize, _: &Matrix) {}
        }
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            run_pipeline(&BombSource, 4, 2, &mut [&mut Sink]);
        }));
        assert!(result.is_err(), "producer panic must propagate, not hang or vanish");
    }

    #[test]
    fn panicking_consumer_does_not_deadlock_producer() {
        let mut rng = Rng::new(1);
        let a = Matrix::randn(128, 2, &mut rng);
        let src = MatrixSource::new(&a);
        struct Bomb {
            seen: usize,
        }
        impl TileConsumer for Bomb {
            fn consume(&mut self, _: usize, _: &Matrix) {
                self.seen += 1;
                if self.seen == 2 {
                    panic!("consumer bomb");
                }
            }
        }
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut bomb = Bomb { seen: 0 };
            run_pipeline(&src, 4, 1, &mut [&mut bomb]);
        }));
        assert!(result.is_err(), "panic must propagate, not hang");
    }

    /// A matrix with one poisoned value at `(row, lane)`.
    fn poisoned(n: usize, cols: usize, row: usize, lane: usize, v: f64) -> Matrix {
        let mut rng = Rng::new(17);
        let mut a = Matrix::randn(n, cols, &mut rng);
        a.row_mut(row)[lane] = v;
        a
    }

    #[test]
    fn validation_quarantines_nan_with_typed_index_and_lane() {
        let a = poisoned(29, 4, 13, 2, f64::NAN);
        let src = MatrixSource::new(&a);
        // tile 5 → row 13 falls in tile ordinal 2
        struct CountFolds(usize);
        impl TileConsumer for CountFolds {
            fn consume(&mut self, _: usize, _: &Matrix) {
                self.0 += 1;
            }
        }
        let mut c = CountFolds(0);
        let err = run_pipeline_validated(
            &src,
            5,
            2,
            Precision::F64,
            ValidateMode::NonFinite,
            &mut [&mut c],
        )
        .unwrap_err();
        assert_eq!(err, PipelineError::PoisonedTile { index: 2, lane: 2 });
        assert!(err.to_string().contains("poisoned tile"), "{err}");
        assert_eq!(c.0, 2, "tiles before the poisoned one folded, none after");

        // whole-tile shortcut reports ordinal 0 and folds nothing
        let mut c = CountFolds(0);
        let err = run_pipeline_validated(
            &src,
            64,
            2,
            Precision::F64,
            ValidateMode::NonFinite,
            &mut [&mut c],
        )
        .unwrap_err();
        assert_eq!(err, PipelineError::PoisonedTile { index: 0, lane: 2 });
        assert_eq!(c.0, 0);

        // Off mode streams the same source without complaint (the
        // pre-validation behavior, bit for bit)
        let mut collect = CollectConsumer::new(29, 4);
        run_pipeline(&src, 5, 2, &mut [&mut collect]);
        assert!(collect.into_matrix()[(13, 2)].is_nan());
    }

    #[test]
    fn full_mode_rejects_square_overflow_magnitudes() {
        let a = poisoned(20, 3, 7, 1, 1e200);
        let src = MatrixSource::new(&a);
        // NonFinite accepts it (1e200 is finite)…
        let mut sink = CollectConsumer::new(20, 3);
        run_pipeline_validated(&src, 4, 2, Precision::F64, ValidateMode::NonFinite, &mut [
            &mut sink,
        ])
        .expect("finite values pass NonFinite");
        // …Full rejects it before a Gram fold can overflow
        let mut gram = GramFold::new(3);
        let err = run_pipeline_validated(&src, 4, 2, Precision::F64, ValidateMode::Full, &mut [
            &mut gram,
        ])
        .unwrap_err();
        assert_eq!(err, PipelineError::PoisonedTile { index: 1, lane: 1 });
        // ±Inf in an f32 stream is caught by the narrow scan too
        let b = poisoned(20, 3, 2, 0, f64::INFINITY);
        let srcb = MatrixSource::new(&b);
        let mut sink = CollectConsumer::new(20, 3);
        let err = run_pipeline_validated(
            &srcb,
            4,
            2,
            Precision::F32,
            ValidateMode::NonFinite,
            &mut [&mut sink],
        )
        .unwrap_err();
        assert_eq!(err, PipelineError::PoisonedTile { index: 0, lane: 0 });
    }

    /// Column-sum fold with snapshot/restore and a scheduled panic — the
    /// checkpoint/resume test double.
    struct BombSum {
        acc: Vec<f64>,
        panic_at: Option<usize>,
    }

    impl BombSum {
        fn new(width: usize, panic_at: Option<usize>) -> Self {
            BombSum { acc: vec![0.0; width], panic_at }
        }
    }

    impl TileConsumer for BombSum {
        fn consume(&mut self, r0: usize, tile: &Matrix) {
            if self.panic_at == Some(r0) {
                panic!("interrupted at r0={r0}");
            }
            for r in 0..tile.rows() {
                for (a, v) in self.acc.iter_mut().zip(tile.row(r)) {
                    *a += v;
                }
            }
        }

        fn snapshot(&self) -> Option<Matrix> {
            Some(Matrix::from_vec(1, self.acc.len(), self.acc.clone()))
        }

        fn restore(&mut self, state: &Matrix) -> bool {
            if state.rows() != 1 || state.cols() != self.acc.len() {
                return false;
            }
            self.acc.copy_from_slice(state.row(0));
            true
        }
    }

    struct CountingSrc {
        a: Matrix,
        tiles: AtomicUsize,
    }

    impl TileSource for CountingSrc {
        fn rows(&self) -> usize {
            self.a.rows()
        }
        fn cols(&self) -> usize {
            self.a.cols()
        }
        fn tile(&self, r0: usize, r1: usize) -> Matrix {
            self.tiles.fetch_add(1, Ordering::SeqCst);
            self.a.block(r0, r1, 0, self.a.cols())
        }
    }

    #[test]
    fn interrupted_pass_resumes_from_checkpoint_bit_identically() {
        let mut rng = Rng::new(23);
        let src = CountingSrc { a: Matrix::randn(40, 3, &mut rng), tiles: AtomicUsize::new(0) };
        let reference = {
            let mut fold = BombSum::new(3, None);
            run_pipeline(&src, 8, 2, &mut [&mut fold]);
            fold.acc.clone()
        };
        let dir = std::env::temp_dir().join(format!("fastspsd-ckpt-pipe-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let cfg = CheckpointConfig::new(&dir).with_every(1);
        let ckpt_file = dir.join("ckpt-pass-1.bin");

        // attempt 1 dies folding the tile at r0=16; tiles 0 and 8 are
        // checkpointed
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut fold = BombSum::new(3, Some(16));
            let _ = run_pipeline_resumable(
                &src,
                8,
                2,
                Precision::F64,
                ValidateMode::Off,
                &cfg,
                &mut [&mut fold],
            );
        }));
        assert!(result.is_err(), "scheduled interruption must propagate");
        assert!(ckpt_file.exists(), "interrupted pass must leave its checkpoint");

        // attempt 2 resumes at r0=16: only tiles 16, 24, 32 are recomputed
        src.tiles.store(0, Ordering::SeqCst);
        let mut fold = BombSum::new(3, None);
        run_pipeline_resumable(
            &src,
            8,
            2,
            Precision::F64,
            ValidateMode::Off,
            &cfg,
            &mut [&mut fold],
        )
        .unwrap();
        assert_eq!(
            src.tiles.load(Ordering::SeqCst),
            3,
            "resume must re-charge the source only for tiles after the checkpoint"
        );
        assert_eq!(fold.acc, reference, "interrupted+resumed must be bit-identical");
        assert!(!ckpt_file.exists(), "completed pass must discard its checkpoint");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn unsupported_consumers_stream_unchanged_under_armed_checkpoints() {
        // CollectConsumer has no snapshot: the pass must neither write a
        // checkpoint nor change results.
        let mut rng = Rng::new(27);
        let a = Matrix::randn(24, 2, &mut rng);
        let src = MatrixSource::new(&a);
        let dir = std::env::temp_dir().join(format!("fastspsd-ckpt-skip-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let cfg = CheckpointConfig::new(&dir).with_every(1);
        let mut collect = CollectConsumer::new(24, 2);
        run_pipeline_resumable(
            &src,
            4,
            2,
            Precision::F64,
            ValidateMode::Off,
            &cfg,
            &mut [&mut collect],
        )
        .unwrap();
        assert_eq!(collect.into_matrix().max_abs_diff(&a), 0.0);
        assert_eq!(
            std::fs::read_dir(&dir).unwrap().count(),
            0,
            "no checkpoint files for snapshot-less consumers"
        );
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
