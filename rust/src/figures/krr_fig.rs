//! Extension experiment (paper §1 motivation, Appendix A Lemma 11):
//! kernel ridge regression — test MSE and fit time for the exact O(n³)
//! solve vs the three approximation models' O(n c²) Woodbury path.

use super::Ctx;
use crate::apps::krr;
use crate::cli::Args;
use crate::coordinator::oracle::KernelOracle;
use crate::coordinator::RbfOracle;
use crate::data::{self, sigma};
use crate::exec::{self, ExecPolicy};
use crate::sketch::SketchKind;
use crate::spsd::{self, FastConfig};
use crate::util::{Rng, Stopwatch};
use std::sync::Arc;

pub fn run(ctx: &Ctx, args: &Args) {
    let pol = ExecPolicy::Materialized;
    let spec = data::find_spec(args.get_str("dataset", "Cpusmall")).expect("unknown dataset");
    let ds = spec.generate(ctx.scale, ctx.seed);
    let mut rng0 = Rng::new(ctx.seed ^ 0x44AA);
    let (train, test) = data::train_test_split(&ds, &mut rng0);
    let n1 = train.x.rows();
    // smooth synthetic regression target over the features
    let f = |row: &[f64]| row.iter().map(|x| (0.6 * x).sin()).sum::<f64>();
    let ytr: Vec<f64> = (0..n1).map(|i| f(train.x.row(i))).collect();
    let yte: Vec<f64> = (0..test.x.rows()).map(|i| f(test.x.row(i))).collect();

    let sig = sigma::calibrate_sigma(&train.x, 0.95, 500, ctx.seed);
    let oracle = Arc::new(RbfOracle::new(
        Arc::new(train.x.clone()),
        sigma::gamma_of_sigma(sig),
        Arc::clone(&ctx.engine),
    ));
    let kx = oracle.cross(&test.x);
    let alpha = args.get_f64("alpha", 0.1);

    let mut csv = ctx.csv("krr.csv", "dataset,n_train,c,method,s,mse,fit_secs");
    // exact baseline
    let kfull = oracle.full();
    let sw = Stopwatch::start();
    let exact = krr::fit_exact(&kfull, alpha, &ytr);
    let t_exact = sw.secs();
    let mse_exact = krr::mse(&exact.predict(&kx), &yte);
    csv.row(&format!("{},{n1},{n1},exact,0,{mse_exact:.6e},{t_exact:.4}", spec.name));

    let cs = args.get_usize_list("cs", &[10, 20, 40, 80]);
    for &c in &cs {
        let c = c.min(n1 / 2);
        for rep in 0..ctx.reps {
            let mut rng = Rng::new(ctx.seed + 31 * rep as u64 + c as u64);
            let p = spsd::uniform_p(n1, c, &mut rng);
            let mut eval = |method: &str, s: usize, approx: &spsd::SpsdApprox, secs: f64| {
                let sw = Stopwatch::start();
                let model = krr::fit_approx(approx, alpha, &ytr);
                let mse = krr::mse(&model.predict(&kx), &yte);
                csv.row(&format!(
                    "{},{n1},{c},{method},{s},{mse:.6e},{:.4}",
                    spec.name,
                    secs + sw.secs()
                ));
            };
            let sw = Stopwatch::start();
            let ny = exec::nystrom(oracle.as_ref(), &p, &pol).result;
            eval("nystrom", c, &ny, sw.secs());
            for f in [4usize, 8] {
                let s = (f * c).min(n1);
                let sw = Stopwatch::start();
                let fa = exec::fast(
                    oracle.as_ref(),
                    &p,
                    FastConfig {
                        s,
                        kind: SketchKind::Uniform,
                        force_p_in_s: true,
                        leverage_basis: spsd::LeverageBasis::Gram,
                    },
                    &pol,
                    &mut rng,
                )
                .result;
                eval(&format!("fast_s{f}c"), s, &fa, sw.secs());
            }
            let sw = Stopwatch::start();
            let pr = exec::prototype(oracle.as_ref(), &p, &pol).result;
            eval("prototype", n1, &pr, sw.secs());
        }
    }
    csv.finish();
}
