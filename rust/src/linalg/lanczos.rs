//! Lanczos iteration for top-k eigenpairs of symmetric matrices.
//!
//! The experiments repeatedly need exact top-k eigenpairs of dense kernel
//! matrices as baselines (exact KPCA, spectral clustering, η calibration).
//! Full cyclic-Jacobi is O(n³) per sweep; Lanczos with full
//! reorthogonalization gets the top k ≪ n pairs in O(n² · iters), which on
//! the single-core testbed is the difference between seconds and minutes.

use super::eig::eigh;
use super::Matrix;
use crate::util::Rng;

/// Top-k eigenpairs (descending) of symmetric `a` via Lanczos with full
/// reorthogonalization. Deterministic given `seed`.
pub fn lanczos_top_k(a: &Matrix, k: usize, seed: u64) -> (Vec<f64>, Matrix) {
    assert_eq!(a.rows(), a.cols(), "lanczos needs a square symmetric matrix");
    lanczos_top_k_op(a.rows(), k, seed, |q| a.matvec(q))
}

/// Matrix-free Lanczos: top-k eigenpairs of the symmetric operator
/// `matvec: R^n -> R^n`. This is what the streaming layer uses to run
/// Lanczos against the implicit `C U C^T` without materializing it.
pub fn lanczos_top_k_op(
    n: usize,
    k: usize,
    seed: u64,
    matvec: impl Fn(&[f64]) -> Vec<f64>,
) -> (Vec<f64>, Matrix) {
    let k = k.min(n);
    if k == 0 {
        return (vec![], Matrix::zeros(n, 0));
    }
    // Krylov dimension: generous head-room so the top k Ritz values
    // converge to ~machine precision even with clustered spectra.
    let m = (4 * k + 30).min(n);
    let mut rng = Rng::new(seed);

    // Lanczos vectors stored as rows of Q (m x n) for cache-friendly axpy.
    let mut q = Matrix::zeros(m, n);
    let mut alpha = vec![0.0f64; m];
    let mut beta = vec![0.0f64; m]; // beta[j] links q_j and q_{j+1}
    let mut v: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
    normalize(&mut v);
    q.row_mut(0).copy_from_slice(&v);

    let mut actual_m = m;
    for j in 0..m {
        // w = A q_j
        let mut w = matvec(q.row(j));
        // alpha_j = q_j . w
        let aj = dot(q.row(j), &w);
        alpha[j] = aj;
        if j + 1 == m {
            break;
        }
        // w -= alpha_j q_j + beta_{j-1} q_{j-1}
        axpy(&mut w, -aj, q.row(j));
        if j > 0 {
            axpy(&mut w, -beta[j - 1], q.row(j - 1));
        }
        // full reorthogonalization (twice is enough, Parlett)
        for _ in 0..2 {
            for i in 0..=j {
                let c = dot(q.row(i), &w);
                if c != 0.0 {
                    axpy(&mut w, -c, q.row(i));
                }
            }
        }
        let b = norm(&w);
        if b < 1e-13 {
            // invariant subspace found: restart with a random orthogonal
            // vector, or stop if we already span enough.
            if j + 1 >= k {
                actual_m = j + 1;
                break;
            }
            let mut r: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
            for i in 0..=j {
                let c = dot(q.row(i), &r);
                axpy(&mut r, -c, q.row(i));
            }
            normalize(&mut r);
            beta[j] = 0.0;
            q.row_mut(j + 1).copy_from_slice(&r);
            continue;
        }
        beta[j] = b;
        for (t, x) in w.iter().enumerate() {
            q[(j + 1, t)] = x / b;
        }
    }

    // Tridiagonal T (actual_m x actual_m): eigendecompose (tiny, Jacobi OK).
    let mm = actual_m;
    let mut t = Matrix::zeros(mm, mm);
    for j in 0..mm {
        t[(j, j)] = alpha[j];
        if j + 1 < mm {
            t[(j, j + 1)] = beta[j];
            t[(j + 1, j)] = beta[j];
        }
    }
    let e = eigh(&t);
    // Ritz vectors: columns of Q^T * V_T (n x k)
    let kk = k.min(mm);
    let mut vecs = Matrix::zeros(n, kk);
    for col in 0..kk {
        for j in 0..mm {
            let w = e.vectors[(j, col)];
            if w == 0.0 {
                continue;
            }
            let qr = q.row(j);
            for i in 0..n {
                vecs[(i, col)] += w * qr[i];
            }
        }
    }
    (e.values[..kk].to_vec(), vecs)
}

#[inline]
fn dot(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

#[inline]
fn norm(a: &[f64]) -> f64 {
    dot(a, a).sqrt()
}

#[inline]
fn normalize(a: &mut [f64]) {
    let nn = norm(a);
    if nn > 0.0 {
        for x in a {
            *x /= nn;
        }
    }
}

#[inline]
fn axpy(y: &mut [f64], alpha: f64, x: &[f64]) {
    for (yy, &xx) in y.iter_mut().zip(x) {
        *yy += alpha * xx;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::gen;

    #[test]
    fn matches_jacobi_on_random_spsd() {
        let mut rng = Rng::new(0);
        let a = gen::spsd(&mut rng, 60, 60);
        let (vals, vecs) = lanczos_top_k(&a, 5, 1);
        let exact = eigh(&a);
        for i in 0..5 {
            assert!(
                (vals[i] - exact.values[i]).abs() < 1e-7 * exact.values[0],
                "eigenvalue {i}: {} vs {}",
                vals[i],
                exact.values[i]
            );
        }
        // eigen equation residuals
        for i in 0..5 {
            let v = vecs.col(i);
            let av = a.matvec(&v);
            let resid: f64 = av
                .iter()
                .zip(&v)
                .map(|(x, y)| (x - vals[i] * y).powi(2))
                .sum::<f64>()
                .sqrt();
            assert!(resid < 1e-6 * exact.values[0], "residual {i}: {resid}");
        }
        // orthonormal Ritz vectors
        let vtv = vecs.tr_matmul(&vecs);
        assert!(vtv.max_abs_diff(&Matrix::identity(5)) < 1e-8);
    }

    #[test]
    fn handles_low_rank_with_invariant_subspace() {
        let mut rng = Rng::new(2);
        let a = gen::spsd(&mut rng, 50, 3); // rank 3
        let (vals, _vecs) = lanczos_top_k(&a, 5, 3);
        let exact = eigh(&a);
        for i in 0..3 {
            assert!((vals[i] - exact.values[i]).abs() < 1e-7 * exact.values[0]);
        }
        // tail eigenvalues ~ 0
        for &v in vals.iter().skip(3) {
            assert!(v.abs() < 1e-7 * exact.values[0]);
        }
    }

    #[test]
    fn k_zero_and_k_equals_n() {
        let mut rng = Rng::new(4);
        let a = gen::spsd(&mut rng, 10, 10);
        let (vals, vecs) = lanczos_top_k(&a, 0, 0);
        assert!(vals.is_empty());
        assert_eq!(vecs.cols(), 0);
        let (vals_all, _) = lanczos_top_k(&a, 10, 5);
        let exact = eigh(&a);
        for i in 0..10 {
            assert!((vals_all[i] - exact.values[i]).abs() < 1e-6 * exact.values[0].max(1.0));
        }
    }

    #[test]
    fn diagonal_matrix_fast_path() {
        let a = Matrix::diag(&[9.0, 1.0, 4.0, 0.0, 25.0]);
        let (vals, _) = lanczos_top_k(&a, 3, 7);
        assert!((vals[0] - 25.0).abs() < 1e-9);
        assert!((vals[1] - 9.0).abs() < 1e-9);
        assert!((vals[2] - 4.0).abs() < 1e-9);
    }
}
