//! Deterministic PRNG substrate (no `rand` crate in the image).
//!
//! `Rng` is xoshiro256** seeded through SplitMix64, with helpers for the
//! distributions the library needs: uniform ints/floats, Gaussians
//! (Box–Muller), Rademacher signs, weighted index sampling, and
//! permutations. All experiment code takes an explicit seed so every figure
//! is reproducible.

/// xoshiro256** generator (Blackman & Vigna), seeded via SplitMix64.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second Box–Muller variate
    gauss_spare: Option<f64>,
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    /// Derive an independent stream (for per-worker RNGs).
    pub fn split(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, n)`; `n > 0`. Lemire-style rejection-free
    /// multiply-shift is fine here (bias < 2^-64 * n, negligible for n « 2^64).
    #[inline]
    pub fn usize_below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn gaussian(&mut self) -> f64 {
        if let Some(z) = self.gauss_spare.take() {
            return z;
        }
        // u1 in (0,1] to avoid ln(0)
        let u1 = 1.0 - self.f64();
        let u2 = self.f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.gauss_spare = Some(r * theta.sin());
        r * theta.cos()
    }

    /// ±1 with equal probability.
    #[inline]
    pub fn sign(&mut self) -> f64 {
        if self.next_u64() & 1 == 0 {
            1.0
        } else {
            -1.0
        }
    }

    /// Bernoulli(p).
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Sample one index from the (unnormalized, non-negative) weights.
    /// Falls back to uniform if all weights are zero.
    pub fn weighted_index(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        if total <= 0.0 || !total.is_finite() {
            return self.usize_below(weights.len());
        }
        let mut t = self.f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            t -= w;
            if t <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// `k` distinct indices sampled uniformly from `[0, n)` (partial
    /// Fisher–Yates over an index array).
    pub fn sample_without_replacement(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "k={k} > n={n}");
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.usize_below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.usize_below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(Rng::new(42).next_u64(), c.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn usize_below_bounds_and_coverage() {
        let mut r = Rng::new(2);
        let mut seen = [false; 7];
        for _ in 0..1_000 {
            let x = r.usize_below(7);
            assert!(x < 7);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(3);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.gaussian();
            sum += z;
            sq += z * z;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn weighted_index_respects_weights() {
        let mut r = Rng::new(4);
        let w = [0.0, 1.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[r.weighted_index(&w)] += 1;
        }
        assert_eq!(counts[0], 0);
        let ratio = counts[2] as f64 / counts[1] as f64;
        assert!((ratio - 3.0).abs() < 0.3, "ratio={ratio}");
    }

    #[test]
    fn sample_without_replacement_distinct() {
        let mut r = Rng::new(5);
        let s = r.sample_without_replacement(50, 20);
        assert_eq!(s.len(), 20);
        let mut sorted = s.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 20);
        assert!(sorted.iter().all(|&i| i < 50));
    }

    #[test]
    #[should_panic]
    fn sample_without_replacement_k_gt_n_panics() {
        Rng::new(0).sample_without_replacement(3, 4);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(6);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn split_streams_diverge() {
        let mut base = Rng::new(7);
        let mut a = base.split(0);
        let mut b = base.split(1);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
