//! Bench: Table 3 — time to compute the U matrix per model, plus the
//! entries-of-K accounting. Regenerates the paper's complexity comparison
//! as measured rows (also emitted by `repro table3` with error columns).

use fastspsd::benchkit::{black_box, BenchSuite};
use fastspsd::coordinator::oracle::{DenseOracle, KernelOracle};
use fastspsd::coordinator::engine::rbf_cross_cpu;
use fastspsd::data::{make_blobs, sigma};
use fastspsd::exec::{self, ExecPolicy};
use fastspsd::spsd::{self, FastConfig};
use fastspsd::util::Rng;

fn main() {
    let mut suite = BenchSuite::new("Table 3: U-matrix computation");
    suite.header();
    for &n in &[512usize, 1024, 2048] {
        let ds = make_blobs("bench", n, 16, 8, 2.0, 1);
        let sig = sigma::calibrate_sigma(&ds.x, 0.9, 400, 1);
        let k = rbf_cross_cpu(&ds.x, &ds.x, sigma::gamma_of_sigma(sig));
        let oracle = DenseOracle::new(k);
        let c = (n / 100).max(8);
        let s = 8 * c;
        let mut rng = Rng::new(2);
        let p = spsd::uniform_p(n, c, &mut rng);

        suite.bench(&format!("nystrom/n={n}/c={c}"), || {
            black_box(exec::nystrom(&oracle, &p, &ExecPolicy::Materialized));
        });
        suite.bench(&format!("fast/n={n}/c={c}/s={s}"), || {
            let mut r = Rng::new(3);
            black_box(exec::fast(&oracle, &p, FastConfig::uniform(s), &ExecPolicy::Materialized, &mut r));
        });
        suite.bench(&format!("prototype/n={n}/c={c}"), || {
            black_box(exec::prototype(&oracle, &p, &ExecPolicy::Materialized));
        });
        // entries accounting (printed once per n)
        oracle.reset_entries();
        let _ = exec::nystrom(&oracle, &p, &ExecPolicy::Materialized);
        let e_ny = oracle.entries_observed();
        oracle.reset_entries();
        let mut r = Rng::new(3);
        let _ = exec::fast(&oracle, &p, FastConfig::uniform(s), &ExecPolicy::Materialized, &mut r);
        let e_fast = oracle.entries_observed();
        oracle.reset_entries();
        let _ = exec::prototype(&oracle, &p, &ExecPolicy::Materialized);
        let e_proto = oracle.entries_observed();
        println!(
            "  #entries n={n}: nystrom={e_ny} (nc={}), fast={e_fast} (nc+(s-c)^2≈{}), prototype={e_proto} (n^2+nc={})",
            n * c,
            n * c + (s - c) * (s - c),
            n * n + n * c
        );
    }
}
