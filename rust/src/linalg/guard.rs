//! Conditioned core solves: condition-estimated, ladder-regularized
//! versions of the c×c solves every model funnels through.
//!
//! The paper's U matrices are built from small core solves — `pinv(W)`
//! (Nyström), `pinv(SᵀC)` (fast model), `pinv(C)` (prototype), and the
//! Woodbury inner system `αI + BᵀB`. Each is tiny (c×c or s×s) but sits
//! downstream of *sampled* data: an unlucky landmark set or a
//! near-duplicate column pair can make the core numerically singular, and
//! the unguarded solve then either panics (`lu_solve(...).expect`) or
//! amplifies noise by `1/s_min` into every entry of the output.
//!
//! [`guarded_pinv`] and [`guarded_spd_solve`] wrap those seams:
//!
//! 1. **Estimate** — one spectral factorization (which the solve needs
//!    anyway, or costs O(c³) ≪ the O(nc²) that produced the core) gives
//!    `cond = s_max/s_min`.
//! 2. **Healthy fast path** — `cond ≤` [`COND_GUARD`] runs the *exact*
//!    pre-existing computation, bit for bit. Guarding is free of numeric
//!    drift on every well-posed problem.
//! 3. **Regularization ladder** — otherwise escalate through doubling
//!    Tikhonov jitter (`λ` on the diagonal / `s/(s²+λ)` gains) until the
//!    effective condition clears the guard, and as a final rung fall back
//!    to a truncated-spectrum pseudoinverse whose condition is bounded by
//!    construction. Never a panic, never an unbounded amplification.
//!
//! Every estimate and escalation is noted in a thread-local
//! [`NumericHealth`] that `exec` drains into `RunMeta::numeric_health`
//! (and the service surfaces on `ApproxResponse`), alongside the
//! pipeline's quarantined-tile count and the spill arena's corrupt-read
//! count — the one-stop "was this answer numerically clean?" record.

use super::eig::eigh;
use super::pinv::pinv;
use super::solve::lu_solve;
use super::svd::{svd_thin, SvdThin};
use super::Matrix;
use std::cell::RefCell;

/// Condition estimate above which a core solve regularizes:
/// `1/sqrt(f64::EPSILON)` ≈ 6.7e7, the classic "half your digits are
/// gone" threshold. Below it the guarded solves are bit-identical to the
/// unguarded ones.
pub const COND_GUARD: f64 = 6.7108864e7;

/// Doubling rungs tried before falling back to the truncated rung.
const MAX_JITTER_RUNGS: u64 = 8;

/// How a guarded core solve was stabilized.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum Regularization {
    /// Every guarded solve ran the exact unguarded computation.
    #[default]
    None,
    /// Tikhonov jitter: `λ` added to the diagonal (SPD solve) or
    /// `s/(s²+λ)` inverse gains (pinv).
    Jitter {
        /// The λ of the rung that cleared the guard.
        lambda: f64,
    },
    /// Final rung: truncated-spectrum pseudoinverse with condition
    /// bounded by [`COND_GUARD`] by construction.
    TruncatedPinv,
}

impl Regularization {
    /// Severity order for merging: `None < Jitter (by λ) < TruncatedPinv`.
    fn strength(&self) -> (u8, f64) {
        match self {
            Regularization::None => (0, 0.0),
            Regularization::Jitter { lambda } => (1, *lambda),
            Regularization::TruncatedPinv => (2, 0.0),
        }
    }

    /// Stable lowercase name for logs / bench rows / service replies.
    pub fn name(&self) -> &'static str {
        match self {
            Regularization::None => "none",
            Regularization::Jitter { .. } => "jitter",
            Regularization::TruncatedPinv => "truncated-pinv",
        }
    }
}

/// Numeric integrity record of one run: the worst core condition seen,
/// the strongest regularization applied, and the integrity counters from
/// the streaming layers. Collected thread-locally while a run executes;
/// `exec` drains it into `RunMeta::numeric_health`.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct NumericHealth {
    /// Largest condition estimate observed across the run's guarded core
    /// solves (0 when no guarded solve ran).
    pub core_cond_est: f64,
    /// Strongest regularization any guarded solve escalated to.
    pub regularization: Regularization,
    /// Total regularization ladder rungs tried across all guarded solves.
    pub escalations: u64,
    /// Tiles rejected by pipeline validation
    /// ([`ValidateMode`](crate::stream::ValidateMode)).
    pub quarantined_tiles: u64,
    /// Checksummed spill records that failed verification on read-back
    /// and were transparently recomputed (mirrors
    /// `ResidencyStats::corrupt_reads`).
    pub corrupt_reads: u64,
}

impl NumericHealth {
    /// True when nothing noteworthy happened: no ill-conditioned core, no
    /// regularization, no quarantined tiles, no corrupt spill reads.
    pub fn is_clean(&self) -> bool {
        self.core_cond_est <= COND_GUARD
            && self.regularization == Regularization::None
            && self.quarantined_tiles == 0
            && self.corrupt_reads == 0
    }

    /// Fold `other` in: worst condition, strongest regularization, summed
    /// counters. The service uses this to carry health observed by failed
    /// attempts of a retried request into the final reply.
    pub fn merge(&mut self, other: &NumericHealth) {
        self.core_cond_est = self.core_cond_est.max(other.core_cond_est);
        if other.regularization.strength() > self.regularization.strength() {
            self.regularization = other.regularization;
        }
        self.escalations += other.escalations;
        self.quarantined_tiles += other.quarantined_tiles;
        self.corrupt_reads += other.corrupt_reads;
    }
}

thread_local! {
    static HEALTH: RefCell<NumericHealth> = RefCell::new(NumericHealth::default());
}

/// Record a core condition estimate (keeps the max).
pub(crate) fn note_core_cond(cond: f64) {
    HEALTH.with(|h| {
        let mut h = h.borrow_mut();
        if cond > h.core_cond_est {
            h.core_cond_est = cond;
        }
    });
}

/// Record a completed escalation (keeps the strongest regularization,
/// sums the rung count).
pub(crate) fn note_regularization(reg: Regularization, rungs: u64) {
    HEALTH.with(|h| {
        let mut h = h.borrow_mut();
        if reg.strength() > h.regularization.strength() {
            h.regularization = reg;
        }
        h.escalations += rungs;
    });
}

/// Record a tile rejected by pipeline validation.
pub(crate) fn note_quarantined_tile() {
    HEALTH.with(|h| h.borrow_mut().quarantined_tiles += 1);
}

/// Drain this thread's health record, resetting it to default. `exec`
/// calls this at run start (discarding residue from unrelated earlier
/// work on the thread) and at run end (into `RunMeta`).
pub(crate) fn take_health() -> NumericHealth {
    HEALTH.with(|h| std::mem::take(&mut *h.borrow_mut()))
}

/// Rebuild `pinv`'s exact output from an already-computed SVD — the same
/// arithmetic as [`pinv`], so the healthy path stays bit-identical while
/// paying for only one factorization.
fn pinv_from_svd(f: &SvdThin, rank: usize, rows: usize, cols: usize) -> Matrix {
    if rank == 0 {
        return Matrix::zeros(cols, rows);
    }
    let vs = Matrix::from_fn(f.v.rows(), rank, |i, j| f.v[(i, j)] / f.s[j]);
    let idx: Vec<usize> = (0..rank).collect();
    let ur = f.u.select_cols(&idx);
    vs.matmul_tr(&ur)
}

/// Tikhonov-regularized pseudoinverse `V diag(s/(s²+λ)) Uᵀ`.
fn tikhonov_pinv(f: &SvdThin, rank: usize, lambda: f64) -> Matrix {
    let vs = Matrix::from_fn(f.v.rows(), rank, |i, j| {
        f.v[(i, j)] * f.s[j] / (f.s[j] * f.s[j] + lambda)
    });
    let idx: Vec<usize> = (0..rank).collect();
    let ur = f.u.select_cols(&idx);
    vs.matmul_tr(&ur)
}

/// Effective condition of the Tikhonov inverse: `s_max · max_i gain(s_i)`
/// with `gain(s) = s/(s²+λ)` (the amplification the regularized inverse
/// can still apply, relative to the best-resolved direction).
fn tikhonov_cond(s: &[f64], lambda: f64) -> f64 {
    let smax = s.first().copied().unwrap_or(0.0);
    let gmax = s.iter().map(|&si| si / (si * si + lambda)).fold(0.0f64, f64::max);
    smax * gmax
}

/// Condition-guarded Moore–Penrose pseudoinverse.
///
/// Healthy cores (`s_max/s_min ≤` [`COND_GUARD`]) return exactly
/// [`pinv`]`(a)` — same SVD, same arithmetic, same bits. Ill-conditioned
/// cores escalate through doubling Tikhonov λ (base
/// `s_max² · max(m,n) · ε`) until the effective condition clears the
/// guard, then — if [`MAX_JITTER_RUNGS`] doublings were not enough — fall
/// back to the truncated pseudoinverse that drops every singular value
/// below `s_max /` [`COND_GUARD`]. Each estimate/escalation is noted in
/// the thread-local [`NumericHealth`].
pub fn guarded_pinv(a: &Matrix) -> Matrix {
    if a.rows() == 0 || a.cols() == 0 {
        return Matrix::zeros(a.cols(), a.rows());
    }
    let f = svd_thin(a);
    let rank = f.rank(a.rows(), a.cols());
    if rank == 0 {
        return Matrix::zeros(a.cols(), a.rows());
    }
    let cond = f.s[0] / f.s[rank - 1];
    note_core_cond(cond);
    if cond.is_finite() && cond <= COND_GUARD {
        return pinv_from_svd(&f, rank, a.rows(), a.cols());
    }
    let mut lambda = f.s[0] * f.s[0] * (a.rows().max(a.cols()) as f64) * f64::EPSILON;
    for rung in 1..=MAX_JITTER_RUNGS {
        if tikhonov_cond(&f.s[..rank], lambda) <= COND_GUARD {
            note_regularization(Regularization::Jitter { lambda }, rung);
            return tikhonov_pinv(&f, rank, lambda);
        }
        lambda *= 2.0;
    }
    // truncation keeps only directions resolvable within the guard
    note_regularization(Regularization::TruncatedPinv, MAX_JITTER_RUNGS + 1);
    let tol = f.s[0] / COND_GUARD;
    let keep = f.s[..rank].iter().take_while(|&&s| s > tol).count().max(1);
    pinv_from_svd(&f, keep, a.rows(), a.cols())
}

/// Condition-guarded solve of a symmetric positive (semi-)definite
/// system `a x = b`.
///
/// Healthy systems run exactly [`lu_solve`]`(a, b)` — bit-identical to
/// the unguarded call sites this replaces (the Woodbury inner system,
/// which is SPD by construction whenever the inputs are sane). When the
/// eigendecomposition says the system is ill-conditioned or indefinite
/// (a corrupted or degenerate core), escalate through doubling diagonal
/// jitter (base `tr(a)/n · ε`) and finally a truncated-eigenspectrum
/// pseudo-solve with condition bounded by [`COND_GUARD`].
pub fn guarded_spd_solve(a: &Matrix, b: &[f64]) -> Vec<f64> {
    let n = a.rows();
    assert_eq!(n, a.cols(), "guarded_spd_solve needs a square matrix");
    assert_eq!(n, b.len());
    if n == 0 {
        return Vec::new();
    }
    let e = eigh(a);
    let lmax = e.values.first().copied().unwrap_or(0.0);
    let lmin = e.values.last().copied().unwrap_or(0.0);
    let cond = if lmin > 0.0 { lmax / lmin } else { f64::INFINITY };
    note_core_cond(cond);
    if cond.is_finite() && cond <= COND_GUARD {
        if let Some(x) = lu_solve(a, b) {
            return x;
        }
        // estimate said healthy but the factorization disagreed — fall
        // through to the ladder rather than trust either side
    }
    let base = (a.trace().abs() / n as f64).max(f64::MIN_POSITIVE);
    let mut lambda = base * f64::EPSILON;
    for rung in 1..=MAX_JITTER_RUNGS {
        let cond_j = (lmax.max(0.0) + lambda) / (lmin.max(0.0) + lambda);
        if cond_j <= COND_GUARD {
            let mut m = a.clone();
            m.add_diag(lambda);
            if let Some(x) = lu_solve(&m, b) {
                note_regularization(Regularization::Jitter { lambda }, rung);
                return x;
            }
        }
        lambda *= 2.0;
    }
    // truncated-eig pseudo-solve: x = Σ_{λi > λmax/guard} v_i (v_iᵀ b)/λ_i
    note_regularization(Regularization::TruncatedPinv, MAX_JITTER_RUNGS + 1);
    let tol = (lmax / COND_GUARD).max(0.0);
    let mut x = vec![0.0; n];
    if lmax <= 0.0 {
        return x; // zero (or corrupt-negative) core: pseudo-solution is 0
    }
    for (j, &lj) in e.values.iter().enumerate() {
        if lj <= tol {
            break; // descending order
        }
        let mut vb = 0.0;
        for i in 0..n {
            vb += e.vectors[(i, j)] * b[i];
        }
        let scale = vb / lj;
        for i in 0..n {
            x[i] += e.vectors[(i, j)] * scale;
        }
    }
    x
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    /// Drain before and after so parallel-unrelated residue never leaks in.
    fn with_clean_health<T>(f: impl FnOnce() -> T) -> (T, NumericHealth) {
        let _ = take_health();
        let out = f();
        (out, take_health())
    }

    #[test]
    fn healthy_pinv_is_bit_identical_to_unguarded() {
        let mut rng = Rng::new(0);
        for &(m, n) in &[(6usize, 6usize), (9, 4), (4, 9)] {
            let a = Matrix::randn(m, n, &mut rng);
            let (guarded, health) = with_clean_health(|| guarded_pinv(&a));
            assert_eq!(guarded.max_abs_diff(&pinv(&a)), 0.0, "{m}x{n}");
            assert!(health.core_cond_est > 0.0, "cond must be recorded");
            assert_eq!(health.regularization, Regularization::None);
            assert_eq!(health.escalations, 0);
            assert!(health.is_clean());
        }
    }

    #[test]
    fn ill_conditioned_pinv_escalates_and_bounds_amplification() {
        // diag spectrum spanning 1e12: cond far beyond the guard
        let a = Matrix::diag(&[1.0, 0.5, 1e-12]);
        let (guarded, health) = with_clean_health(|| guarded_pinv(&a));
        assert!(health.core_cond_est > COND_GUARD);
        assert_ne!(health.regularization, Regularization::None);
        assert!(health.escalations > 0);
        assert!(!health.is_clean());
        // amplification bounded: the unguarded pinv has a 1e12 entry, the
        // guarded one stays within the guard
        let amp = guarded.data().iter().fold(0.0f64, |m, v| m.max(v.abs()));
        assert!(amp <= COND_GUARD, "guarded amplification {amp:.3e}");
        assert!(guarded.data().iter().all(|v| v.is_finite()));
        // the well-resolved directions are still inverted exactly
        assert!((guarded[(0, 0)] - 1.0).abs() < 1e-6);
        assert!((guarded[(1, 1)] - 2.0).abs() < 1e-6);
    }

    #[test]
    fn healthy_spd_solve_is_bit_identical_to_lu() {
        let mut rng = Rng::new(1);
        let g = Matrix::randn(7, 7, &mut rng);
        let mut a = g.matmul_tr(&g);
        a.add_diag(1.0); // well away from singular
        let b: Vec<f64> = (0..7).map(|i| (i as f64 * 0.9).sin()).collect();
        let (x, health) = with_clean_health(|| guarded_spd_solve(&a, &b));
        assert_eq!(x, lu_solve(&a, &b).unwrap(), "healthy path must be the exact lu solve");
        assert_eq!(health.regularization, Regularization::None);
        assert!(health.core_cond_est >= 1.0);
    }

    #[test]
    fn singular_spd_solve_never_panics_and_solves_the_resolvable_part() {
        // rank-2 Gram of a 5x2 factor: lu would fail, the old call sites
        // would panic via .expect
        let mut rng = Rng::new(2);
        let g = Matrix::randn(5, 2, &mut rng);
        let a = g.matmul_tr(&g);
        let xtrue = a.matvec(&[1.0, -2.0, 0.5, 0.0, 3.0]); // in range(a)
        let (x, health) = with_clean_health(|| guarded_spd_solve(&a, &xtrue));
        assert!(x.iter().all(|v| v.is_finite()));
        assert_ne!(health.regularization, Regularization::None);
        // a x must reproduce the rhs (it lies in the range)
        let ax = a.matvec(&x);
        for (got, want) in ax.iter().zip(&xtrue) {
            assert!((got - want).abs() < 1e-6, "{got} vs {want}");
        }
    }

    #[test]
    fn zero_core_yields_zero_solution() {
        let a = Matrix::zeros(3, 3);
        let (x, health) = with_clean_health(|| guarded_spd_solve(&a, &[1.0, 2.0, 3.0]));
        assert_eq!(x, vec![0.0; 3]);
        assert_eq!(health.regularization, Regularization::TruncatedPinv);
    }

    #[test]
    fn health_collector_drains_and_merges() {
        let _ = take_health();
        note_core_cond(10.0);
        note_core_cond(5.0); // keeps max
        note_quarantined_tile();
        note_quarantined_tile();
        note_regularization(Regularization::Jitter { lambda: 1e-8 }, 3);
        note_regularization(Regularization::TruncatedPinv, 9); // stronger wins
        note_regularization(Regularization::Jitter { lambda: 1.0 }, 1); // weaker loses
        let h = take_health();
        assert_eq!(h.core_cond_est, 10.0);
        assert_eq!(h.quarantined_tiles, 2);
        assert_eq!(h.regularization, Regularization::TruncatedPinv);
        assert_eq!(h.escalations, 13);
        assert_eq!(take_health(), NumericHealth::default(), "take must drain");
    }
}
