//! Request planner: turn an accuracy/budget target into (method, c, s).
//!
//! This encodes the paper's complexity model as a routing policy — the
//! coordinator's answer to "I have n points and want 1+ε error against the
//! best rank-k approximation; what do I run?":
//!
//! - prototype needs `c = O(k/ε)` but observes n² entries (Thm 1),
//! - Nyström needs `c ≥ Ω(√(nk/ε))` (Wang & Zhang 2013 lower bound),
//! - fast needs `c = O(k/ε)` and `s = O(c√(n/ε))` with `nc + (s−c)²`
//!   entries (Thm 3 / Remark 4) — linear in n.
//!
//! `plan` picks the cheapest method whose predicted entry budget fits, and
//! clamps against n. Constants are calibrated pragmatically (c = 2k/ε,
//! matching the paper's near-optimal column selection results).

use super::service::MethodSpec;
use crate::sketch::SketchKind;

/// What the caller wants.
#[derive(Debug, Clone, Copy)]
pub struct Goal {
    /// matrix size
    pub n: usize,
    /// target rank of the downstream task
    pub k: usize,
    /// relative-error parameter ε in (0, 1]
    pub epsilon: f64,
    /// max kernel entries the caller can afford to evaluate
    /// (`u64::MAX` = unconstrained)
    pub entry_budget: u64,
}

/// A concrete plan.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Plan {
    pub method: MethodSpec,
    pub c: usize,
    /// predicted kernel entries observed
    pub predicted_entries: u64,
}

/// Sketch sizes from the paper's theory with pragmatic constants.
pub fn theory_c(k: usize, epsilon: f64) -> usize {
    ((2.0 * k as f64 / epsilon).ceil() as usize).max(k + 1)
}

pub fn theory_s(n: usize, c: usize, epsilon: f64) -> usize {
    ((c as f64 * (n as f64 / epsilon).sqrt()).ceil() as usize).max(2 * c)
}

pub fn nystrom_c_lower_bound(n: usize, k: usize, epsilon: f64) -> usize {
    ((n as f64 * k as f64 / epsilon).sqrt().ceil()) as usize
}

/// Predicted entries for each model (Table 3 right column).
pub fn predicted_entries(n: usize, c: usize, s: usize, method: &MethodSpec) -> u64 {
    match method {
        MethodSpec::Nystrom => (n * c) as u64,
        MethodSpec::Prototype => (n as u64) * (n as u64) + (n * c) as u64,
        MethodSpec::Fast { .. } => {
            let extra = s.saturating_sub(c) as u64;
            (n * c) as u64 + extra * extra
        }
    }
}

/// Predicted flops: U computation (Table 3 middle column) plus the
/// downstream O(nc²) eig/solve every method pays. This is where the
/// paper's "linear vs quadratic in n" separation shows up: at the c each
/// model needs for a (1+ε) guarantee, Nyström's c = Ω(√(nk/ε)) makes its
/// downstream term n·c² = n²k/ε quadratic, while the fast model stays
/// linear (with a large k,ε-dependent constant).
pub fn predicted_flops(n: usize, c: usize, s: usize, method: &MethodSpec) -> f64 {
    let (nf, cf, sf) = (n as f64, c as f64, s as f64);
    let downstream = nf * cf * cf;
    match method {
        MethodSpec::Nystrom => cf.powi(3) + downstream,
        MethodSpec::Prototype => nf * nf * cf + downstream,
        MethodSpec::Fast { .. } => nf * cf * cf + sf * sf * cf + downstream,
    }
}

/// Choose the fastest method whose predicted entry count fits the budget.
pub fn plan(goal: Goal) -> Plan {
    let n = goal.n.max(2);
    let eps = goal.epsilon.clamp(1e-6, 1.0);
    // Fast model at theory sizes.
    let c_fast = theory_c(goal.k, eps).min(n / 2).max(1);
    let s_fast = theory_s(n, c_fast, eps).min(n);
    let fast = MethodSpec::Fast { s: s_fast, kind: SketchKind::Uniform };

    // Nyström needs a much larger c for the same guarantee.
    let c_ny = nystrom_c_lower_bound(n, goal.k, eps).min(n / 2).max(1);

    // Prototype: small c but n² observation.
    let c_proto = theory_c(goal.k, eps).min(n / 2).max(1);

    let mut candidates = [
        Plan {
            method: fast,
            c: c_fast,
            predicted_entries: predicted_entries(n, c_fast, s_fast, &fast),
        },
        Plan {
            method: MethodSpec::Nystrom,
            c: c_ny,
            predicted_entries: predicted_entries(n, c_ny, c_ny, &MethodSpec::Nystrom),
        },
        Plan {
            method: MethodSpec::Prototype,
            c: c_proto,
            predicted_entries: predicted_entries(n, c_proto, n, &MethodSpec::Prototype),
        },
    ];
    // fastest first
    candidates.sort_by(|a, b| {
        let fa = predicted_flops(n, a.c, plan_s(a), &a.method);
        let fb = predicted_flops(n, b.c, plan_s(b), &b.method);
        fa.partial_cmp(&fb).unwrap()
    });
    for cand in candidates {
        if cand.predicted_entries <= goal.entry_budget {
            return cand;
        }
    }
    // nothing fits: return the fewest-entries candidate (caller sees the
    // overshoot)
    *candidates
        .iter()
        .min_by_key(|p| p.predicted_entries)
        .unwrap()
}

fn plan_s(p: &Plan) -> usize {
    match p.method {
        MethodSpec::Fast { s, .. } => s,
        MethodSpec::Nystrom => p.c,
        MethodSpec::Prototype => 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fast_wins_at_large_n() {
        // Theorem 1 / §1.1: under a 1+ε guarantee the fast model is the
        // only linear-time option once n is large enough that Nyström's
        // c = Ω(√(nk/ε)) makes its downstream n·c² quadratic.
        let p = plan(Goal { n: 100_000_000, k: 5, epsilon: 0.5, entry_budget: u64::MAX });
        assert!(matches!(p.method, MethodSpec::Fast { .. }), "{p:?}");
        // and it stays far below n² observation
        let n2 = 100_000_000u64 as f64 * 100_000_000u64 as f64;
        assert!((p.predicted_entries as f64) < n2 / 1e3);
    }

    #[test]
    fn predicted_flops_linear_vs_quadratic_in_n() {
        // Fast model flops grow ~linearly in n at guarantee sizes; Nyström's
        // grow ~quadratically. Ratio test across a 10x n jump.
        let (k, eps) = (5, 0.5);
        let flops = |n: usize| {
            let c_f = theory_c(k, eps);
            let s_f = theory_s(n, c_f, eps);
            let fast =
                predicted_flops(n, c_f, s_f, &MethodSpec::Fast { s: s_f, kind: SketchKind::Uniform });
            let c_n = nystrom_c_lower_bound(n, k, eps);
            let ny = predicted_flops(n, c_n, c_n, &MethodSpec::Nystrom);
            (fast, ny)
        };
        let (f1, n1) = flops(1_000_000);
        let (f10, n10) = flops(10_000_000);
        let fast_growth = f10 / f1;
        let ny_growth = n10 / n1;
        assert!(fast_growth < 15.0, "fast growth {fast_growth} should be ~linear");
        assert!(ny_growth > 60.0, "nystrom growth {ny_growth} should be ~quadratic");
    }

    #[test]
    fn tiny_budget_falls_back_to_cheapest() {
        let p = plan(Goal { n: 10_000, k: 5, epsilon: 0.1, entry_budget: 10 });
        // can't fit anything: returns cheapest (never prototype)
        assert!(!matches!(p.method, MethodSpec::Prototype));
    }

    #[test]
    fn small_n_clamps() {
        let p = plan(Goal { n: 50, k: 10, epsilon: 0.01, entry_budget: u64::MAX });
        assert!(p.c <= 25);
        if let MethodSpec::Fast { s, .. } = p.method {
            assert!(s <= 50);
        }
    }

    #[test]
    fn prototype_only_when_budget_allows_n2() {
        let n = 2_000u64;
        let with_budget = plan(Goal {
            n: n as usize,
            k: 5,
            epsilon: 0.05,
            entry_budget: n * n / 2,
        });
        assert!(
            !matches!(with_budget.method, MethodSpec::Prototype),
            "n²-observing prototype must not be chosen under an n²/2 budget"
        );
    }

    #[test]
    fn theory_sizes_monotone() {
        assert!(theory_c(10, 0.1) > theory_c(5, 0.1));
        assert!(theory_c(5, 0.05) > theory_c(5, 0.1));
        assert!(theory_s(10_000, 20, 0.1) > theory_s(1_000, 20, 0.1));
    }
}
