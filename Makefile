# fastspsd build/verify entry points.
#
#   make perf-check   — tier-1 verify + quick hotpath bench (perf gate):
#                       builds release, runs the test suite, then runs the
#                       hotpath microbenchmarks in quick mode and leaves
#                       machine-readable results in BENCH_hotpath.json.
#   make artifacts    — AOT-compile the PJRT kernel artifacts (needs the
#                       python/jax toolchain; optional — everything falls
#                       back to the pure-rust engine without them).
#   make test / build — the tier-1 pieces individually.

CARGO ?= cargo
PYTHON ?= python3

.PHONY: build test bench perf-check artifacts

build:
	$(CARGO) build --release

test:
	$(CARGO) test -q

bench:
	$(CARGO) bench --bench hotpath
	$(CARGO) bench --bench stream

perf-check: build test
	FASTSPSD_BENCH_QUICK=1 $(CARGO) bench --bench hotpath
	FASTSPSD_BENCH_QUICK=1 $(CARGO) bench --bench stream
	@echo "perf-check OK — smoke numbers in BENCH_hotpath.quick.json / BENCH_stream.quick.json; run 'make bench' for the full-budget JSONs"

artifacts:
	cd python && $(PYTHON) -m compile.aot --out-dir ../artifacts
