//! Small shared utilities: RNG, timing.

pub mod rng;
pub mod timer;

pub use rng::Rng;
pub use timer::Stopwatch;
