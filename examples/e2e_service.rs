//! End-to-end driver: the full stack on a real small workload.
//!
//! Spins up the Layer-3 approximation service over a synthetic-LIBSVM
//! dataset, streams a mixed batch of approximation requests through the
//! bounded queue (kernel blocks flow through the PJRT-compiled Pallas
//! kernel when artifacts are present), and reports latency percentiles,
//! throughput, and per-method quality. Recorded in EXPERIMENTS.md.
//!
//! ```sh
//! make artifacts && cargo run --release --example e2e_service
//! ```

use fastspsd::cli::Args;
use fastspsd::figures::{e2e, Ctx};

fn main() {
    let mut argv: Vec<String> = std::env::args().skip(1).collect();
    argv.insert(0, "e2e".into());
    let args = Args::parse(argv);
    let ctx = Ctx::from_args(&args);
    e2e::run(&ctx, &args);
}
