//! Bench: Table 5 / §5.2 — CUR U-matrix cost: optimal U* = C†AR† vs the
//! fast Ũ of eq. (9) at several sketch sizes.

use fastspsd::benchkit::{black_box, BenchSuite};
use fastspsd::cur::{self, FastCurConfig};
use fastspsd::exec::{self, ExecPolicy};
use fastspsd::data::image;
use fastspsd::util::Rng;

fn main() {
    let (m, n) = (1536usize, 1024usize);
    let a = image::synth_image(m, n, 0);
    let (c, r) = (50usize, 50usize);
    let mut rng = Rng::new(1);
    let cols = cur::select_uniform(n, c, &mut rng);
    let rows = cur::select_uniform(m, r, &mut rng);

    let mut suite = BenchSuite::new(&format!("Table 5: CUR U computation ({m}x{n}, c=r={c})"));
    suite.header();
    suite.bench("optimal  U=C†AR†", || {
        black_box(cur::cur_optimal(&a, &cols, &rows));
    });
    suite.bench("drineas08 U=(PᵀAP)†", || {
        black_box(cur::cur_drineas08(&a, &cols, &rows));
    });
    for f in [2usize, 4, 8] {
        suite.bench(&format!("fast uniform s={f}x"), || {
            let mut rr = Rng::new(2);
            black_box(exec::cur_fast(&a, &cols, &rows, FastCurConfig::uniform(f * r, f * c), &ExecPolicy::Materialized, &mut rr));
        });
    }
    suite.bench("fast leverage s=4x", || {
        let mut rr = Rng::new(3);
        black_box(exec::cur_fast(&a, &cols, &rows, FastCurConfig::leverage(4 * r, 4 * c), &ExecPolicy::Materialized, &mut rr));
    });
    // quality check rows
    for (label, dec) in [
        ("optimal", cur::cur_optimal(&a, &cols, &rows)),
        ("drineas08", cur::cur_drineas08(&a, &cols, &rows)),
        ("fast4x", {
            let mut rr = Rng::new(2);
            exec::cur_fast(&a, &cols, &rows, FastCurConfig::uniform(4 * r, 4 * c), &ExecPolicy::Materialized, &mut rr)
                .result
        }),
    ] {
        println!("    rel_err[{label}] = {:.4e} (entries for U: {})", dec.rel_fro_error(&a), dec.entries_for_u);
    }
}
