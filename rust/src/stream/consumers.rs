//! Composable tile consumers: each folds one streamed row-tile into a
//! bounded accumulator as it arrives.
//!
//! Gather-style consumers ([`CollectConsumer`], [`RowGather`],
//! [`ColSubsetCollect`], and [`SketchFold`] over column-selection /
//! CountSketch ops) are bit-identical to the materialized path because
//! tiles arrive in ascending row order and every destination element is
//! touched by the same additions in the same order. Accumulation-style
//! consumers ([`GramFold`], [`PrototypeUFold`], [`ConjugateFold`], dense /
//! SRHT [`SketchFold`]) regroup a sum over `n` by tile boundaries, so they
//! match the materialized path only up to reduction reordering (≤1e-12
//! relative — asserted by `tests/stream_equiv.rs`).

use crate::linalg::{gemm, Matrix};
use crate::sketch::SketchOp;

/// Folds streamed row-tiles. `consume` is called once per tile, in
/// ascending `r0` order, with `tile.rows()` rows starting at virtual row
/// `r0`.
pub trait TileConsumer {
    fn consume(&mut self, r0: usize, tile: &Matrix);
}

/// Reassembles the streamed matrix (used when the full panel *is* the
/// output, e.g. the `C` of `C U C^T`).
pub struct CollectConsumer {
    out: Matrix,
}

impl CollectConsumer {
    pub fn new(rows: usize, cols: usize) -> Self {
        CollectConsumer { out: Matrix::zeros(rows, cols) }
    }

    pub fn into_matrix(self) -> Matrix {
        self.out
    }
}

impl TileConsumer for CollectConsumer {
    fn consume(&mut self, r0: usize, tile: &Matrix) {
        for r in 0..tile.rows() {
            self.out.row_mut(r0 + r).copy_from_slice(tile.row(r));
        }
    }
}

/// Gathers the rows at `indices` (in the order given, duplicates allowed)
/// into an `indices.len() x width` matrix: `out[j, :] = stream[indices[j],
/// cols]`. With `cols = None` the full tile width is kept. This is how the
/// streamed builds extract `W = C[P, :]` and `C[S, :]` without a second
/// pass.
pub struct RowGather {
    indices: Vec<usize>,
    cols: Option<Vec<usize>>,
    out: Matrix,
}

impl RowGather {
    pub fn new(indices: Vec<usize>, width: usize) -> Self {
        let out = Matrix::zeros(indices.len(), width);
        RowGather { indices, cols: None, out }
    }

    /// Gather only the given columns of each selected row.
    pub fn with_cols(indices: Vec<usize>, cols: Vec<usize>) -> Self {
        let out = Matrix::zeros(indices.len(), cols.len());
        RowGather { indices, cols: Some(cols), out }
    }

    pub fn into_matrix(self) -> Matrix {
        self.out
    }
}

impl TileConsumer for RowGather {
    fn consume(&mut self, r0: usize, tile: &Matrix) {
        let r1 = r0 + tile.rows();
        for (j, &i) in self.indices.iter().enumerate() {
            if i >= r0 && i < r1 {
                let src = tile.row(i - r0);
                match &self.cols {
                    None => self.out.row_mut(j).copy_from_slice(src),
                    Some(cols) => {
                        let dst = self.out.row_mut(j);
                        for (d, &cc) in dst.iter_mut().zip(cols.iter()) {
                            *d = src[cc];
                        }
                    }
                }
            }
        }
    }
}

/// Collects a column subset of the stream: `out[:, j] = stream[:,
/// cols[j]]` (the `C = A[:, P_C]` of a streamed CUR build over full-width
/// tiles).
pub struct ColSubsetCollect {
    cols: Vec<usize>,
    out: Matrix,
}

impl ColSubsetCollect {
    pub fn new(rows: usize, cols: Vec<usize>) -> Self {
        let out = Matrix::zeros(rows, cols.len());
        ColSubsetCollect { cols, out }
    }

    pub fn into_matrix(self) -> Matrix {
        self.out
    }
}

impl TileConsumer for ColSubsetCollect {
    fn consume(&mut self, r0: usize, tile: &Matrix) {
        for r in 0..tile.rows() {
            let src = tile.row(r);
            let dst = self.out.row_mut(r0 + r);
            for (d, &cc) in dst.iter_mut().zip(self.cols.iter()) {
                *d = src[cc];
            }
        }
    }
}

/// Fused sketch application: accumulates `S^T A` tile by tile via
/// [`SketchOp::fold_rows`] — row gather for column selection, signed
/// hash-accumulate for CountSketch, direct Sylvester-Hadamard rows for
/// SRHT, `gemm_tn` for Gaussian. Peak memory `O(s · width)` regardless of
/// `n`.
pub struct SketchFold<'a> {
    op: &'a SketchOp,
    acc: Matrix,
    /// Persistent `s x width` scratch for the Gaussian (`Dense`) fold, so
    /// the hot path runs `gemm_tn_into` with zero per-tile output
    /// allocation. Empty for the other families.
    scratch: Matrix,
}

impl<'a> SketchFold<'a> {
    pub fn new(op: &'a SketchOp, width: usize) -> Self {
        let scratch = match op {
            SketchOp::Dense(_) => Matrix::zeros(op.s(), width),
            _ => Matrix::zeros(0, 0),
        };
        SketchFold { op, acc: Matrix::zeros(op.s(), width), scratch }
    }

    /// The accumulated `S^T A`.
    pub fn into_matrix(self) -> Matrix {
        self.acc
    }
}

impl TileConsumer for SketchFold<'_> {
    fn consume(&mut self, r0: usize, tile: &Matrix) {
        if let SketchOp::Dense(s_mat) = self.op {
            // acc += S[r0..r1, :]^T · tile (same product as fold_rows's
            // Dense branch, through the reused scratch)
            let sub = s_mat.block(r0, r0 + tile.rows(), 0, s_mat.cols());
            gemm::gemm_tn_into(&sub, tile, &mut self.scratch);
            self.acc.axpy(1.0, &self.scratch);
        } else {
            self.op.fold_rows(r0, tile, &mut self.acc);
        }
    }
}

/// Gram accumulation `A^T A = Σ_t tile_t^T tile_t` via per-tile `syrk_tn`
/// into a reused scratch — exactly symmetric output, `O(width²)` memory.
pub struct GramFold {
    acc: Matrix,
    scratch: Matrix,
}

impl GramFold {
    pub fn new(width: usize) -> Self {
        GramFold { acc: Matrix::zeros(width, width), scratch: Matrix::zeros(width, width) }
    }

    pub fn into_matrix(self) -> Matrix {
        self.acc
    }
}

impl TileConsumer for GramFold {
    fn consume(&mut self, _r0: usize, tile: &Matrix) {
        gemm::syrk_tn_into(tile, &mut self.scratch);
        self.acc.axpy(1.0, &self.scratch);
    }
}

/// Matvec fold `A^T x`: each tile contributes `tile^T x[r0..r1]`. The
/// first pass of the implicit `C U C^T` matvec.
pub struct MatvecFold<'a> {
    x: &'a [f64],
    acc: Vec<f64>,
}

impl<'a> MatvecFold<'a> {
    pub fn new(x: &'a [f64], width: usize) -> Self {
        MatvecFold { x, acc: vec![0.0; width] }
    }

    pub fn into_vec(self) -> Vec<f64> {
        self.acc
    }
}

impl TileConsumer for MatvecFold<'_> {
    fn consume(&mut self, r0: usize, tile: &Matrix) {
        let part = tile.tr_matvec(&self.x[r0..r0 + tile.rows()]);
        for (a, p) in self.acc.iter_mut().zip(part) {
            *a += p;
        }
    }
}

/// Prototype-model U fold over full-K row tiles:
/// `U = C† K (C†)^T = Σ_t C†[:, t-rows] · (K_t · (C†)^T)`, so the `n x n`
/// kernel is never stored — peak extra memory `O(tile_rows · n + c²)`.
pub struct PrototypeUFold<'a> {
    /// `C†`, c x n.
    cp: &'a Matrix,
    acc: Matrix,
    /// `tile_rows x c` scratch for `K_t (C†)^T`, reallocated only when the
    /// tile height changes (once, at the ragged last tile).
    tmp: Matrix,
    /// `c x c` scratch for the per-tile product.
    prod: Matrix,
}

impl<'a> PrototypeUFold<'a> {
    pub fn new(cp: &'a Matrix) -> Self {
        let c = cp.rows();
        PrototypeUFold {
            cp,
            acc: Matrix::zeros(c, c),
            tmp: Matrix::zeros(0, c),
            prod: Matrix::zeros(c, c),
        }
    }

    /// The accumulated `C† K (C†)^T` (symmetrized — tile grouping breaks
    /// exact symmetry at the last bit).
    pub fn into_matrix(self) -> Matrix {
        let mut u = self.acc;
        u.symmetrize();
        u
    }
}

impl TileConsumer for PrototypeUFold<'_> {
    fn consume(&mut self, r0: usize, tile: &Matrix) {
        let t = tile.rows();
        let c = self.cp.rows();
        if self.tmp.rows() != t {
            self.tmp = Matrix::zeros(t, c);
        }
        // tmp = K_t (C†)^T : (t x n)·(n x c) — cp is stored c x n, so this
        // is a plain nt-product into the reused scratch.
        gemm::gemm_nt_into(tile, self.cp, &mut self.tmp);
        // acc += C†[:, r0..r1] · tmp : (c x t)·(t x c)
        let cp_block = self.cp.block(0, c, r0, r0 + t);
        gemm::gemm_into(&cp_block, &self.tmp, &mut self.prod);
        self.acc.axpy(1.0, &self.prod);
    }
}

/// Streamed `S^T K S` for projection sketches over full-K row tiles:
/// each tile contributes `S[r0..r1, :]^T · (K_t S)` with
/// `K_t S = (S^T K_t^T)^T`, so the projection families observe their `n²`
/// entries (Table 4) without ever storing them — peak extra memory
/// `O(tile_rows · (n + s) + s²)`.
pub struct ConjugateFold<'a> {
    op: &'a SketchOp,
    acc: Matrix,
}

impl<'a> ConjugateFold<'a> {
    pub fn new(op: &'a SketchOp) -> Self {
        let s = op.s();
        ConjugateFold { op, acc: Matrix::zeros(s, s) }
    }

    /// The accumulated `S^T K S` (symmetrized).
    pub fn into_matrix(self) -> Matrix {
        let mut m = self.acc;
        m.symmetrize();
        m
    }
}

impl TileConsumer for ConjugateFold<'_> {
    fn consume(&mut self, r0: usize, tile: &Matrix) {
        let kts = self.op.apply_left(&tile.transpose()).transpose(); // t x s
        self.op.fold_rows(r0, &kts, &mut self.acc);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::{self, SketchKind};
    use crate::stream::{run_pipeline, MatrixSource};
    use crate::util::Rng;

    fn stream_all(a: &Matrix, tile: usize, consumers: &mut [&mut dyn TileConsumer]) {
        let src = MatrixSource::new(a);
        run_pipeline(&src, tile, 2, consumers);
    }

    #[test]
    fn row_gather_matches_select_rows() {
        let mut rng = Rng::new(0);
        let a = Matrix::randn(23, 5, &mut rng);
        let idx = vec![0usize, 7, 7, 19, 22];
        for tile in [1usize, 4, 23] {
            let mut g = RowGather::new(idx.clone(), 5);
            stream_all(&a, tile, &mut [&mut g]);
            assert_eq!(g.into_matrix().max_abs_diff(&a.select_rows(&idx)), 0.0);
        }
        let mut g = RowGather::with_cols(vec![3, 11], vec![1, 4]);
        stream_all(&a, 6, &mut [&mut g]);
        let got = g.into_matrix();
        assert_eq!(got[(0, 0)], a[(3, 1)]);
        assert_eq!(got[(1, 1)], a[(11, 4)]);
    }

    #[test]
    fn col_subset_collect_matches_select_cols() {
        let mut rng = Rng::new(1);
        let a = Matrix::randn(17, 9, &mut rng);
        let cols = vec![0usize, 2, 8];
        let mut c = ColSubsetCollect::new(17, cols.clone());
        stream_all(&a, 5, &mut [&mut c]);
        assert_eq!(c.into_matrix().max_abs_diff(&a.select_cols(&cols)), 0.0);
    }

    #[test]
    fn sketch_fold_matches_apply_left_all_families() {
        let mut rng = Rng::new(2);
        let n = 40;
        let a = Matrix::randn(n, 6, &mut rng);
        for kind in [
            SketchKind::Uniform,
            SketchKind::Leverage { scaled: true },
            SketchKind::Gaussian,
            SketchKind::Srht,
            SketchKind::CountSketch,
        ] {
            let basis = Matrix::randn(n, 4, &mut rng);
            let op = sketch::build(kind, n, 12, Some(&basis), &mut rng);
            let direct = op.apply_left(&a);
            for tile in [1usize, 7, 40] {
                let mut fold = SketchFold::new(&op, 6);
                stream_all(&a, tile, &mut [&mut fold]);
                let folded = fold.into_matrix();
                let scale = direct.fro_norm().max(1.0);
                assert!(
                    folded.max_abs_diff(&direct) < 1e-12 * scale,
                    "{} tile={tile}",
                    kind.name()
                );
            }
        }
    }

    #[test]
    fn gram_fold_matches_syrk_tn() {
        let mut rng = Rng::new(3);
        let a = Matrix::randn(31, 7, &mut rng);
        let direct = gemm::syrk_tn(&a);
        for tile in [1usize, 8, 31] {
            let mut fold = GramFold::new(7);
            stream_all(&a, tile, &mut [&mut fold]);
            let g = fold.into_matrix();
            assert!(g.max_abs_diff(&direct) < 1e-12 * direct.fro_norm().max(1.0));
            assert_eq!(g.max_abs_diff(&g.transpose()), 0.0, "exactly symmetric");
        }
    }

    #[test]
    fn matvec_fold_matches_tr_matvec() {
        let mut rng = Rng::new(4);
        let a = Matrix::randn(26, 5, &mut rng);
        let x: Vec<f64> = (0..26).map(|i| (i as f64 * 0.3).sin()).collect();
        let direct = a.tr_matvec(&x);
        let mut fold = MatvecFold::new(&x, 5);
        stream_all(&a, 9, &mut [&mut fold]);
        let got = fold.into_vec();
        for (g, d) in got.iter().zip(&direct) {
            assert!((g - d).abs() < 1e-12);
        }
    }

    #[test]
    fn conjugate_fold_matches_dense_conjugate() {
        let mut rng = Rng::new(5);
        let g = Matrix::randn(24, 24, &mut rng);
        let k = g.matmul_tr(&g);
        for kind in [SketchKind::Gaussian, SketchKind::Srht, SketchKind::CountSketch] {
            let op = sketch::build(kind, 24, 10, None, &mut rng);
            let mut direct = op.conjugate(&k);
            direct.symmetrize();
            for tile in [5usize, 24] {
                let mut fold = ConjugateFold::new(&op);
                stream_all(&k, tile, &mut [&mut fold]);
                let got = fold.into_matrix();
                assert!(
                    got.max_abs_diff(&direct) < 1e-11 * direct.fro_norm().max(1.0),
                    "{} tile={tile}",
                    kind.name()
                );
            }
        }
    }

    #[test]
    fn prototype_fold_matches_dense_chain() {
        let mut rng = Rng::new(6);
        let g = Matrix::randn(30, 30, &mut rng);
        let k = g.matmul_tr(&g);
        let c = k.select_cols(&[1, 5, 9, 20]);
        let cp = crate::linalg::pinv(&c);
        let direct = gemm::symm_nt(&cp.matmul(&k), &cp);
        for tile in [4usize, 30] {
            let mut fold = PrototypeUFold::new(&cp);
            stream_all(&k, tile, &mut [&mut fold]);
            let u = fold.into_matrix();
            assert!(
                u.max_abs_diff(&direct) < 1e-11 * direct.fro_norm().max(1.0),
                "tile={tile}"
            );
        }
    }
}
