# fastspsd build/verify entry points.
#
#   make ci           — toolchain guard + build + test + rustdoc gate
#                       (RUSTDOCFLAGS=-D warnings) + clippy (if
#                       installed). The guard FAILS FAST with a loud
#                       message when no Rust toolchain is present, so
#                       "authored but never compiled" cannot silently
#                       recur (it already has, PRs 1-3 — see CHANGES.md).
#   make perf-check   — ci + quick hotpath/stream benches (perf gate):
#                       leaves machine-readable results in
#                       BENCH_hotpath.quick.json / BENCH_stream.quick.json.
#   make bench-quick  — quick hotpath/stream benches written to the
#                       canonical BENCH_hotpath.json / BENCH_stream.json
#                       artifacts and committed (the tracked perf
#                       trajectory; the JSONs carry "quick": true so the
#                       budget is never ambiguous).
#   make artifacts    — AOT-compile the PJRT kernel artifacts (needs the
#                       python/jax toolchain; optional — everything falls
#                       back to the pure-rust engine without them).
#   make chaos        — the deterministic fault-injection matrix
#                       (rust/tests/chaos.rs) over the pinned seed set:
#                       {spill write, spill read, oracle tile, consumer
#                       fold, spill corrupt, poisoned tile, shard worker
#                       death} × {transient, persistent} must end typed
#                       or degraded — never silently wrong bits, never
#                       hung. Corrupt spill records are caught by the
#                       per-record checksum and recomputed bit-identically;
#                       poisoned tiles fail typed under ValidateMode before
#                       any fold sees them; a dead shard worker's row-range
#                       is re-executed or the request fails typed. Part of
#                       `make ci`.
#   make shard-smoke  — small-n sharded service round-trip: row-sharded
#                       workers, per-shard accounting on the reply, and
#                       one injected transient worker death absorbed by
#                       re-execution (rust/tests/shard_smoke.rs). Part of
#                       `make ci`.
#   make trace-smoke  — serve one streamed and one resident-with-spill
#                       request with tracing on and validate the emitted
#                       Chrome trace_event JSON covers the mandatory
#                       stages (rust/tests/trace_smoke.rs, pure Rust).
#                       Part of `make ci`.
#   make test / build — the tier-1 pieces individually.

CARGO ?= cargo
PYTHON ?= python3

# The pinned chaos seed set: deterministic, replayed by `make chaos` and
# overridable for exploration (FASTSPSD_CHAOS_SEEDS="1 2 3" make chaos).
FASTSPSD_CHAOS_SEEDS ?= 11 23 47

.PHONY: build test bench bench-quick chaos trace-smoke shard-smoke ci doc perf-check artifacts toolchain-guard

toolchain-guard:
	@command -v $(CARGO) >/dev/null 2>&1 || { \
	  echo "================================================================"; \
	  echo "ERROR: '$(CARGO)' not found — no Rust toolchain is installed."; \
	  echo ""; \
	  echo "Nothing in this repo can be verified without it: code that is"; \
	  echo "only statically reviewed MUST NOT be treated as green. Install"; \
	  echo "rustup (https://rustup.rs) or set CARGO=/path/to/cargo, then"; \
	  echo "re-run 'make ci'."; \
	  echo "================================================================"; \
	  exit 1; }

build: toolchain-guard
	$(CARGO) build --release

test: toolchain-guard
	$(CARGO) test -q

bench: toolchain-guard
	$(CARGO) bench --bench hotpath
	$(CARGO) bench --bench stream

chaos: toolchain-guard
	FASTSPSD_CHAOS_SEEDS="$(FASTSPSD_CHAOS_SEEDS)" $(CARGO) test -q --test chaos

trace-smoke: toolchain-guard
	$(CARGO) test -q --test trace_smoke

shard-smoke: toolchain-guard
	$(CARGO) test -q --test shard_smoke

ci: toolchain-guard build test chaos trace-smoke shard-smoke doc
	@if $(CARGO) clippy --version >/dev/null 2>&1; then \
	  $(CARGO) clippy --release -- -D warnings; \
	else \
	  echo "clippy not installed — skipping lint"; \
	fi
	@echo "ci OK — build + test + doc green$$($(CARGO) clippy --version >/dev/null 2>&1 && echo ' + clippy clean')"

# Rustdoc gate: the public surface (in particular the `exec` policy API)
# must stay documented and its intra-doc links resolving.
doc: toolchain-guard
	RUSTDOCFLAGS="-D warnings" $(CARGO) doc --no-deps

bench-quick: toolchain-guard
	FASTSPSD_BENCH_QUICK=1 FASTSPSD_BENCH_COMMIT=1 $(CARGO) bench --bench hotpath
	FASTSPSD_BENCH_QUICK=1 FASTSPSD_BENCH_COMMIT=1 $(CARGO) bench --bench stream
	@git add BENCH_hotpath.json BENCH_stream.json && \
	 (git diff --cached --quiet -- BENCH_hotpath.json BENCH_stream.json || \
	  git commit -m "bench: refresh quick bench artifacts (make bench-quick)" \
	    -- BENCH_hotpath.json BENCH_stream.json)
	@echo "bench-quick OK — BENCH_hotpath.json / BENCH_stream.json refreshed"

perf-check: ci
	FASTSPSD_BENCH_QUICK=1 $(CARGO) bench --bench hotpath
	FASTSPSD_BENCH_QUICK=1 $(CARGO) bench --bench stream
	@echo "perf-check OK — smoke numbers in BENCH_hotpath.quick.json / BENCH_stream.quick.json; run 'make bench' for the full-budget JSONs"

artifacts:
	cd python && $(PYTHON) -m compile.aot --out-dir ../artifacts
