//! Clustering / classification quality metrics.

/// Normalized mutual information between two labelings, in [0, 1].
/// NMI = I(A; B) / sqrt(H(A) H(B)); 1 for identical partitions (up to
/// relabeling), ~0 for independent ones.
pub fn nmi(a: &[usize], b: &[usize]) -> f64 {
    assert_eq!(a.len(), b.len());
    let n = a.len();
    if n == 0 {
        return 0.0;
    }
    let ka = a.iter().copied().max().unwrap() + 1;
    let kb = b.iter().copied().max().unwrap() + 1;
    let mut joint = vec![vec![0usize; kb]; ka];
    let mut ca = vec![0usize; ka];
    let mut cb = vec![0usize; kb];
    for i in 0..n {
        joint[a[i]][b[i]] += 1;
        ca[a[i]] += 1;
        cb[b[i]] += 1;
    }
    let nf = n as f64;
    let mut mi = 0.0;
    for i in 0..ka {
        for j in 0..kb {
            let nij = joint[i][j] as f64;
            if nij > 0.0 {
                mi += nij / nf * ((nij * nf) / (ca[i] as f64 * cb[j] as f64)).ln();
            }
        }
    }
    let ha: f64 = ca
        .iter()
        .filter(|&&c| c > 0)
        .map(|&c| {
            let p = c as f64 / nf;
            -p * p.ln()
        })
        .sum();
    let hb: f64 = cb
        .iter()
        .filter(|&&c| c > 0)
        .map(|&c| {
            let p = c as f64 / nf;
            -p * p.ln()
        })
        .sum();
    if ha <= 0.0 || hb <= 0.0 {
        // one side is a single cluster: NMI is 1 iff both are
        return if ha <= 0.0 && hb <= 0.0 { 1.0 } else { 0.0 };
    }
    (mi / (ha * hb).sqrt()).clamp(0.0, 1.0)
}

/// Fraction of mismatched labels.
pub fn error_rate(pred: &[usize], truth: &[usize]) -> f64 {
    assert_eq!(pred.len(), truth.len());
    if pred.is_empty() {
        return 0.0;
    }
    pred.iter().zip(truth).filter(|(p, t)| p != t).count() as f64 / pred.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn nmi_identical_is_one() {
        let a = vec![0, 0, 1, 1, 2, 2];
        assert!((nmi(&a, &a) - 1.0).abs() < 1e-12);
        // invariant to relabeling
        let b = vec![2, 2, 0, 0, 1, 1];
        assert!((nmi(&a, &b) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn nmi_independent_is_small() {
        let mut rng = Rng::new(0);
        let a: Vec<usize> = (0..2000).map(|_| rng.usize_below(4)).collect();
        let b: Vec<usize> = (0..2000).map(|_| rng.usize_below(4)).collect();
        assert!(nmi(&a, &b) < 0.05);
    }

    #[test]
    fn nmi_single_cluster_edge() {
        let a = vec![0, 0, 0];
        let b = vec![0, 1, 2];
        assert_eq!(nmi(&a, &a), 1.0);
        assert_eq!(nmi(&a, &b), 0.0);
    }

    #[test]
    fn error_rate_counts() {
        assert_eq!(error_rate(&[0, 1, 1], &[0, 1, 0]), 1.0 / 3.0);
        assert_eq!(error_rate(&[], &[]), 0.0);
    }
}
