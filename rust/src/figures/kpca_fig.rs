//! Figures 5 & 6: approximate KPCA quality — misalignment (eq. 10) of the
//! approximate top-k eigenvectors against the exact ones, plotted against
//! elapsed time (Fig 5) and against c = memory (Fig 6). k = 3.

use super::Ctx;
use crate::apps::kpca;
use crate::cli::Args;
use crate::coordinator::oracle::KernelOracle;
use crate::exec::{self, ExecPolicy};
use crate::sketch::SketchKind;
use crate::spsd::{self, FastConfig};
use crate::util::{Rng, Stopwatch};

pub fn run(ctx: &Ctx, args: &Args) {
    let pol = ExecPolicy::Materialized;
    let k = args.get_usize("k", 3);
    let datasets = ["PenDigit", "USPS", "Mushrooms", "DNA"];
    let only = args.get("dataset").map(|s| s.to_lowercase());
    let mut csv = ctx.csv(
        "fig5_6.csv",
        "dataset,n,k,c,method,s,misalignment,secs,entries",
    );
    for name in datasets {
        if let Some(o) = &only {
            if !name.eq_ignore_ascii_case(o) {
                continue;
            }
        }
        let spec = crate::data::find_spec(name).unwrap();
        let (ds, oracle, _sig) = ctx.oracle_for(spec, 0.9);
        let n = ds.x.rows();
        // exact KPCA baseline (the expensive thing the paper contrasts)
        let kfull = oracle.full();
        let sw = Stopwatch::start();
        let exact = kpca::exact_kpca(&kfull, k);
        let exact_secs = sw.secs();
        csv.row(&format!("{name},{n},{k},{n},exact,0,0.0,{exact_secs:.4},{}", n * n));

        let cs = args.get_usize_list("cs", &[10, 20, 40, 80]);
        for &c in &cs {
            let c = c.min(n / 2);
            for rep in 0..ctx.reps {
                let mut rng = Rng::new(ctx.seed + rep as u64 * 31 + c as u64);
                let p = spsd::uniform_p(n, c, &mut rng);
                let mut runs: Vec<(String, usize, f64, f64, u64)> = Vec::new();
                {
                    oracle.reset_entries();
                    let sw = Stopwatch::start();
                    let a = exec::nystrom(oracle.as_ref(), &p, &pol).result;
                    let m = kpca::kpca_from_approx(&a, k);
                    runs.push((
                        "nystrom".into(),
                        c,
                        kpca::misalignment(&exact.v, &m.v),
                        sw.secs(),
                        a.entries_observed,
                    ));
                }
                for f in [2usize, 4, 8] {
                    let s = (f * c).min(n);
                    oracle.reset_entries();
                    let sw = Stopwatch::start();
                    let a = exec::fast(
                        oracle.as_ref(),
                        &p,
                        FastConfig {
                            s,
                            kind: SketchKind::Uniform,
                            force_p_in_s: true,
                            leverage_basis: spsd::LeverageBasis::Gram,
                        },
                        &pol,
                        &mut rng,
                    )
                    .result;
                    let m = kpca::kpca_from_approx(&a, k);
                    runs.push((
                        format!("fast_s{f}c"),
                        s,
                        kpca::misalignment(&exact.v, &m.v),
                        sw.secs(),
                        a.entries_observed,
                    ));
                }
                {
                    oracle.reset_entries();
                    let sw = Stopwatch::start();
                    let a = exec::prototype(oracle.as_ref(), &p, &pol).result;
                    let m = kpca::kpca_from_approx(&a, k);
                    runs.push((
                        "prototype".into(),
                        n,
                        kpca::misalignment(&exact.v, &m.v),
                        sw.secs(),
                        a.entries_observed,
                    ));
                }
                for (method, s, mis, secs, entries) in runs {
                    csv.row(&format!(
                        "{name},{n},{k},{c},{method},{s},{mis:.6e},{secs:.4},{entries}"
                    ));
                }
            }
        }
    }
    csv.finish();
}
