//! Integration tests for the PJRT runtime + block-scheduler engine.
//! These need `make artifacts`; they skip (with a note) when the artifact
//! directory is missing so `cargo test` works in a fresh checkout.

use fastspsd::coordinator::engine::{rbf_cross_cpu, KernelEngine};
use fastspsd::linalg::Matrix;
use fastspsd::runtime::{default_artifact_dir, RuntimeHandle};
use fastspsd::util::Rng;

fn runtime_or_skip() -> Option<RuntimeHandle> {
    match RuntimeHandle::spawn(default_artifact_dir()) {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("SKIP (no artifacts): {e:#}");
            None
        }
    }
}

#[test]
fn manifest_lists_expected_artifacts() {
    let Some(rt) = runtime_or_skip() else { return };
    let m = rt.manifest();
    assert!(m.find("rbf_block_256x256x16").is_some());
    assert!(m.find("rbf_block_256x256x128").is_some());
    assert!(m.find("rbf_block_256x256x1024").is_some());
    assert!(m.find("matmul_256x256x256").is_some());
    let buckets = m.rbf_buckets();
    assert_eq!(buckets.iter().map(|(d, _)| *d).collect::<Vec<_>>(), vec![16, 128, 1024]);
}

#[test]
fn raw_rbf_artifact_matches_cpu_reference() {
    let Some(rt) = runtime_or_skip() else { return };
    let mut rng = Rng::new(0);
    let x = Matrix::randn(256, 16, &mut rng);
    let y = Matrix::randn(256, 16, &mut rng);
    let gamma = 0.35f64;
    let to_f32 = |m: &Matrix| m.data().iter().map(|&v| v as f32).collect::<Vec<f32>>();
    let out = rt
        .execute_one(
            "rbf_block_256x256x16",
            vec![
                (vec![gamma as f32], vec![1, 1]),
                (to_f32(&x), vec![256, 16]),
                (to_f32(&y), vec![256, 16]),
            ],
        )
        .expect("execute");
    assert_eq!(out.len(), 256 * 256);
    let got = Matrix::from_f32(256, 256, &out);
    let expect = rbf_cross_cpu(&x, &y, gamma);
    assert!(got.max_abs_diff(&expect) < 1e-4, "diff={}", got.max_abs_diff(&expect));
}

#[test]
fn engine_pjrt_matches_cpu_on_ragged_sizes() {
    let Some(rt) = runtime_or_skip() else { return };
    let engine = KernelEngine::pjrt(rt);
    assert!(engine.is_pjrt());
    let mut rng = Rng::new(1);
    // ragged sizes that force padding + multi-tile assembly
    for &(m, n, d) in &[(300usize, 300usize, 10usize), (512, 260, 16), (257, 700, 100)] {
        let x = Matrix::randn(m, d, &mut rng);
        let y = Matrix::randn(n, d, &mut rng);
        let fast = engine.rbf_cross(&x, &y, 0.5);
        let slow = rbf_cross_cpu(&x, &y, 0.5);
        assert_eq!((fast.rows(), fast.cols()), (m, n));
        assert!(
            fast.max_abs_diff(&slow) < 1e-4,
            "({m},{n},{d}) diff={}",
            fast.max_abs_diff(&slow)
        );
    }
    assert!(engine.pjrt_tiles.load(std::sync::atomic::Ordering::Relaxed) > 0);
}

#[test]
fn engine_matmul_matches_gemm() {
    let Some(rt) = runtime_or_skip() else { return };
    let engine = KernelEngine::pjrt(rt);
    let mut rng = Rng::new(2);
    let a = Matrix::randn(300, 200, &mut rng);
    let b = Matrix::randn(200, 280, &mut rng);
    let fast = engine.matmul(&a, &b);
    let slow = a.matmul(&b);
    assert!(fast.max_abs_diff(&slow) < 2e-3, "diff={}", fast.max_abs_diff(&slow));
}

#[test]
fn engine_falls_back_for_small_or_wide_inputs() {
    let Some(rt) = runtime_or_skip() else { return };
    let engine = KernelEngine::pjrt(rt);
    let mut rng = Rng::new(3);
    // tiny: padding waste → CPU path
    let x = Matrix::randn(8, 4, &mut rng);
    let _ = engine.rbf_cross(&x, &x, 1.0);
    assert!(engine.cpu_blocks.load(std::sync::atomic::Ordering::Relaxed) > 0);
    // d beyond the largest bucket → CPU path
    let wide = Matrix::randn(300, 2000, &mut rng);
    let before = engine.pjrt_tiles.load(std::sync::atomic::Ordering::Relaxed);
    let k = engine.rbf_cross(&wide, &wide, 0.01);
    assert_eq!(k.rows(), 300);
    assert_eq!(engine.pjrt_tiles.load(std::sync::atomic::Ordering::Relaxed), before);
}

#[test]
fn runtime_rejects_bad_requests() {
    let Some(rt) = runtime_or_skip() else { return };
    // unknown artifact
    assert!(rt.execute_one("nope", vec![]).is_err());
    // wrong arity
    assert!(rt.execute_one("rbf_block_256x256x16", vec![]).is_err());
    // wrong shape
    let bad = rt.execute_one(
        "rbf_block_256x256x16",
        vec![
            (vec![1.0], vec![1, 1]),
            (vec![0.0; 10], vec![10, 1]),
            (vec![0.0; 256 * 16], vec![256, 16]),
        ],
    );
    assert!(bad.is_err());
    // wrong element count for declared shape
    let bad2 = rt.execute_one(
        "rbf_block_256x256x16",
        vec![
            (vec![1.0], vec![1, 1]),
            (vec![0.0; 5], vec![256, 16]),
            (vec![0.0; 256 * 16], vec![256, 16]),
        ],
    );
    assert!(bad2.is_err());
}

#[test]
fn runtime_shared_across_threads() {
    let Some(rt) = runtime_or_skip() else { return };
    let engine = std::sync::Arc::new(KernelEngine::pjrt(rt));
    let mut rng = Rng::new(4);
    let x = std::sync::Arc::new(Matrix::randn(300, 16, &mut rng));
    let expect = rbf_cross_cpu(&x, &x, 0.5);
    std::thread::scope(|s| {
        for _ in 0..4 {
            let e = std::sync::Arc::clone(&engine);
            let xx = std::sync::Arc::clone(&x);
            let ex = &expect;
            s.spawn(move || {
                let k = e.rbf_cross(&xx, &xx, 0.5);
                assert!(k.max_abs_diff(ex) < 1e-4);
            });
        }
    });
}
