//! Minimal CLI argument parser substrate (no `clap` in the image).
//!
//! Supports `command --flag value --switch positional` style invocations
//! with typed getters, defaults, and a usage printer.

use std::collections::BTreeMap;

/// Parsed command line: a subcommand, `--key value` options, bare
/// `--switch` flags, and positionals.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub command: Option<String>,
    opts: BTreeMap<String, String>,
    switches: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.opts.insert(name.to_string(), v);
                } else {
                    out.switches.push(name.to_string());
                }
            } else if out.command.is_none() {
                out.command = Some(tok);
            } else {
                out.positional.push(tok);
            }
        }
        out
    }

    /// Parse the process arguments.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects an integer, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn get_u64(&self, name: &str, default: u64) -> u64 {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects an integer, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects a number, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn get_str<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    /// Comma-separated list of integers, e.g. `--cs 50,100,200`.
    pub fn get_usize_list(&self, name: &str, default: &[usize]) -> Vec<usize> {
        match self.get(name) {
            None => default.to_vec(),
            Some(v) => v
                .split(',')
                .filter(|s| !s.is_empty())
                .map(|s| s.trim().parse().unwrap_or_else(|_| panic!("--{name}: bad integer {s:?}")))
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn command_opts_switches_positionals() {
        // NB: a bare `--switch` must come after positionals (or last) —
        // `--switch value` is indistinguishable from an option otherwise.
        let a = parse("fig3 --n 2000 --eta=0.9 input.txt --verbose");
        assert_eq!(a.command.as_deref(), Some("fig3"));
        assert_eq!(a.get_usize("n", 0), 2000);
        assert_eq!(a.get_f64("eta", 0.0), 0.9);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
        assert_eq!(a.positional, vec!["input.txt"]);
    }

    #[test]
    fn defaults_apply() {
        let a = parse("run");
        assert_eq!(a.get_usize("c", 7), 7);
        assert_eq!(a.get_str("name", "x"), "x");
        assert_eq!(a.get_usize_list("cs", &[1, 2]), vec![1, 2]);
    }

    #[test]
    fn int_lists() {
        let a = parse("x --cs 50,100,200");
        assert_eq!(a.get_usize_list("cs", &[]), vec![50, 100, 200]);
    }

    #[test]
    fn trailing_switch() {
        let a = parse("x --fast");
        assert!(a.flag("fast"));
    }

    #[test]
    #[should_panic]
    fn bad_int_panics() {
        parse("x --n abc").get_usize("n", 0);
    }
}
