//! Ablations for the design choices DESIGN.md §5 calls out:
//!
//! 1. `P ⊂ S` union trick on/off (Corollary 5 / §4.5),
//! 2. leverage-score scaling on/off (§4.5 stability note),
//! 3. engine tile fill threshold: PJRT padding overhead vs CPU fallback,
//! 4. GEMM thread scaling.

use super::Ctx;
use crate::cli::Args;
use crate::coordinator::engine::rbf_cross_cpu;
use crate::coordinator::oracle::DenseOracle;
use crate::data::{make_blobs, sigma};
use crate::exec::{self, ExecPolicy};
use crate::linalg::Matrix;
use crate::sketch::SketchKind;
use crate::spsd::{self, FastConfig};
use crate::util::{Rng, Stopwatch};

pub fn run(ctx: &Ctx, args: &Args) {
    ablate_p_in_s(ctx, args);
    ablate_leverage_scaling(ctx, args);
    ablate_engine_fill(ctx, args);
    ablate_gemm_threads(ctx);
}

/// (1) Corollary 5: forcing P ⊂ S should improve (or not hurt) accuracy at
/// equal total sketch size.
fn ablate_p_in_s(ctx: &Ctx, args: &Args) {
    let n = args.get_usize("n", 1000);
    let (kmat, _) = kernel(n, ctx.seed);
    let o = DenseOracle::new(kmat.clone());
    let kf = kmat.fro_norm_sq();
    let c = (n / 100).max(8);
    let mut csv = ctx.csv("ablate_p_in_s.csv", "n,c,s,force_p,rel_err_mean");
    for &f in &[2usize, 4, 8] {
        let s = f * c;
        for force in [true, false] {
            let mut err = 0.0;
            for rep in 0..ctx.reps.max(5) {
                let mut rng = Rng::new(ctx.seed + rep as u64);
                let p = spsd::uniform_p(n, c, &mut rng);
                let cfg = FastConfig {
                    s,
                    kind: SketchKind::Uniform,
                    force_p_in_s: force,
                    leverage_basis: spsd::LeverageBasis::Gram,
                };
                let a = exec::fast(&o, &p, cfg, &ExecPolicy::Materialized, &mut rng).result;
                err += kmat.sub(&a.materialize()).fro_norm_sq() / kf;
            }
            err /= ctx.reps.max(5) as f64;
            csv.row(&format!("{n},{c},{s},{force},{err:.6e}"));
        }
    }
    csv.finish();
}

/// (2) §4.5: unscaled leverage-score sampling is reported more stable than
/// the theoretically-scaled version.
fn ablate_leverage_scaling(ctx: &Ctx, args: &Args) {
    let n = args.get_usize("n", 1000);
    let (kmat, _) = kernel(n, ctx.seed + 1);
    let o = DenseOracle::new(kmat.clone());
    let kf = kmat.fro_norm_sq();
    let c = (n / 100).max(8);
    let mut csv = ctx.csv("ablate_leverage_scaling.csv", "n,c,s,scaled,rel_err_mean,rel_err_max");
    for &f in &[4usize, 8] {
        let s = f * c;
        for scaled in [false, true] {
            let mut mean = 0.0;
            let mut worst: f64 = 0.0;
            let reps = ctx.reps.max(5);
            for rep in 0..reps {
                let mut rng = Rng::new(ctx.seed + 100 + rep as u64);
                let p = spsd::uniform_p(n, c, &mut rng);
                let cfg = FastConfig {
                    s,
                    kind: SketchKind::Leverage { scaled },
                    force_p_in_s: true,
                    leverage_basis: spsd::LeverageBasis::Gram,
                };
                let a = exec::fast(&o, &p, cfg, &ExecPolicy::Materialized, &mut rng).result;
                let e = kmat.sub(&a.materialize()).fro_norm_sq() / kf;
                mean += e;
                worst = worst.max(e);
            }
            mean /= reps as f64;
            csv.row(&format!("{n},{c},{s},{scaled},{mean:.6e},{worst:.6e}"));
        }
    }
    csv.finish();
}

/// (3) Where is the PJRT/CPU crossover? Time the same RBF cross block both
/// ways across sizes (PJRT pays padding to 256-tiles + channel hop).
fn ablate_engine_fill(ctx: &Ctx, args: &Args) {
    if !ctx.engine.is_pjrt() {
        eprintln!("# ablate_engine_fill: PJRT unavailable, skipping");
        return;
    }
    let d = args.get_usize("d", 16);
    let mut csv = ctx.csv("ablate_engine_fill.csv", "m,d,fill,cpu_secs,pjrt_secs");
    let mut rng = Rng::new(ctx.seed);
    for &m in &[64usize, 128, 192, 256, 512, 1024] {
        let x = Matrix::randn(m, d, &mut rng);
        // Time the CPU path against a distinct (identical) y so it measures
        // the full cross block like the PJRT side — same-reference inputs
        // would dispatch to the ~half-FLOP symmetric gram path and skew the
        // crossover.
        let y = x.clone();
        let sw = Stopwatch::start();
        let reps = 3;
        for _ in 0..reps {
            let _ = rbf_cross_cpu(&x, &y, 0.5);
        }
        let cpu = sw.secs() / reps as f64;
        // call the tiled PJRT path directly regardless of fill heuristic
        let sw = Stopwatch::start();
        for _ in 0..reps {
            let _ = ctx.engine.rbf_cross(&x, &x, 0.5);
        }
        let pjrt = sw.secs() / reps as f64;
        let mp = m.div_ceil(256) * 256;
        let fill = (m * m) as f64 / (mp * mp) as f64;
        csv.row(&format!("{m},{d},{fill:.3},{cpu:.5},{pjrt:.5}"));
    }
    csv.finish();
}

/// (4) GEMM thread scaling at the coordinator's typical shapes.
fn ablate_gemm_threads(ctx: &Ctx) {
    let mut rng = Rng::new(ctx.seed);
    let a = Matrix::randn(768, 768, &mut rng);
    let b = Matrix::randn(768, 768, &mut rng);
    let sw = Stopwatch::start();
    let reps = 5;
    for _ in 0..reps {
        let _ = a.matmul(&b);
    }
    let secs = sw.secs() / reps as f64;
    let flops = 2.0 * 768f64.powi(3);
    println!(
        "# gemm 768^3: {:.4}s/iter = {:.2} GFLOP/s on {} cores",
        secs,
        flops / secs / 1e9,
        crate::pool::configured_threads()
    );
}

fn kernel(n: usize, seed: u64) -> (Matrix, f64) {
    let ds = make_blobs("ablate", n, 12, 6, 2.0, seed);
    let sig = sigma::calibrate_sigma(&ds.x, 0.9, 400, seed);
    (rbf_cross_cpu(&ds.x, &ds.x, sigma::gamma_of_sigma(sig)), sig)
}
