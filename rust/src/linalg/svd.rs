//! Thin SVD via one-sided Jacobi (Hestenes) with QR preconditioning.
//!
//! One-sided Jacobi orthogonalizes pairs of columns of `A V` until all
//! column pairs are numerically orthogonal; singular values are the final
//! column norms. It is simple, backward stable, and accurate for the small
//! to mid-size factors (c, s « n) the paper's algorithms decompose. For
//! tall matrices we first QR-reduce so Jacobi runs on the n x n `R`.

use super::qr::qr_thin;
use super::Matrix;

/// Thin SVD: `A (m x n) = U (m x r) diag(s) V^T (r x n)` with r = min(m, n);
/// singular values descending, including zeros for rank-deficient inputs.
pub struct SvdThin {
    pub u: Matrix,
    pub s: Vec<f64>,
    pub v: Matrix, // n x r (columns are right singular vectors)
}

const MAX_SWEEPS: usize = 60;

/// One-sided Jacobi on a square-ish work matrix; returns (W, V) with
/// W = A*V having orthogonal columns.
fn jacobi_orthogonalize(a: &Matrix) -> (Matrix, Matrix) {
    let n = a.cols();
    let mut w = a.clone();
    let mut v = Matrix::identity(n);
    // tolerance relative to the largest column norm
    let eps = 1e-15;
    for _sweep in 0..MAX_SWEEPS {
        let mut rotated = false;
        for p in 0..n {
            for q in (p + 1)..n {
                // alpha = w_p . w_p, beta = w_q . w_q, gamma = w_p . w_q
                let (mut alpha, mut beta, mut gamma) = (0.0f64, 0.0f64, 0.0f64);
                for i in 0..w.rows() {
                    let wp = w[(i, p)];
                    let wq = w[(i, q)];
                    alpha += wp * wp;
                    beta += wq * wq;
                    gamma += wp * wq;
                }
                if gamma.abs() <= eps * (alpha * beta).sqrt() || alpha * beta == 0.0 {
                    continue;
                }
                rotated = true;
                // Jacobi rotation angle
                let zeta = (beta - alpha) / (2.0 * gamma);
                let t = zeta.signum() / (zeta.abs() + (1.0 + zeta * zeta).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                for i in 0..w.rows() {
                    let wp = w[(i, p)];
                    let wq = w[(i, q)];
                    w[(i, p)] = c * wp - s * wq;
                    w[(i, q)] = s * wp + c * wq;
                }
                for i in 0..n {
                    let vp = v[(i, p)];
                    let vq = v[(i, q)];
                    v[(i, p)] = c * vp - s * vq;
                    v[(i, q)] = s * vp + c * vq;
                }
            }
        }
        if !rotated {
            break;
        }
    }
    (w, v)
}

/// Compute the thin SVD of `a`.
pub fn svd_thin(a: &Matrix) -> SvdThin {
    let (m, n) = (a.rows(), a.cols());
    if m < n {
        // SVD of A^T = U' S V'^T  =>  A = V' S U'^T
        let t = svd_thin(&a.transpose());
        return SvdThin { u: t.v, s: t.s, v: t.u };
    }
    // QR precondition: A = Q R, SVD(R) = Ur S V^T, so U = Q Ur.
    let (q, work) = if m > n {
        let f = qr_thin(a);
        (Some(f.q), f.r)
    } else {
        (None, a.clone())
    };
    let (w, v) = jacobi_orthogonalize(&work);
    // singular values = column norms of w
    let mut order: Vec<usize> = (0..n).collect();
    let norms: Vec<f64> = (0..n)
        .map(|j| (0..w.rows()).map(|i| w[(i, j)] * w[(i, j)]).sum::<f64>().sqrt())
        .collect();
    order.sort_by(|&i, &j| norms[j].partial_cmp(&norms[i]).unwrap());
    let s: Vec<f64> = order.iter().map(|&j| norms[j]).collect();
    let smax = s.first().copied().unwrap_or(0.0);
    // U columns: w_j / sigma_j; fill zero-sigma columns with zeros (callers
    // use rank-aware helpers, e.g. pinv, that drop them).
    let mut ur = Matrix::zeros(w.rows(), n);
    for (jj, &j) in order.iter().enumerate() {
        if norms[j] > smax * 1e-300 && norms[j] > 0.0 {
            for i in 0..w.rows() {
                ur[(i, jj)] = w[(i, j)] / norms[j];
            }
        }
    }
    let v_sorted = v.select_cols(&order);
    let u = match q {
        Some(q) => q.matmul(&ur),
        None => ur,
    };
    SvdThin { u, s, v: v_sorted }
}

impl SvdThin {
    /// Numerical rank with tolerance `max(m, n) * eps * s_max` (LAPACK-style).
    pub fn rank(&self, m: usize, n: usize) -> usize {
        let smax = self.s.first().copied().unwrap_or(0.0);
        let tol = smax * (m.max(n) as f64) * f64::EPSILON;
        self.s.iter().take_while(|&&x| x > tol).count()
    }

    /// Reconstruct `U diag(s) V^T`.
    pub fn reconstruct(&self) -> Matrix {
        let us = Matrix::from_fn(self.u.rows(), self.s.len(), |i, j| self.u[(i, j)] * self.s[j]);
        us.matmul_tr(&self.v)
    }

    /// Best rank-k truncation (returns U_k, s_k, V_k).
    pub fn truncate(&self, k: usize) -> SvdThin {
        let k = k.min(self.s.len());
        let idx: Vec<usize> = (0..k).collect();
        SvdThin {
            u: self.u.select_cols(&idx),
            s: self.s[..k].to_vec(),
            v: self.v.select_cols(&idx),
        }
    }
}

/// `‖A - A_k‖_F^2` via the tail singular values of `a`.
pub fn best_rank_k_error_sq(a: &Matrix, k: usize) -> f64 {
    let f = svd_thin(a);
    f.s.iter().skip(k).map(|&x| x * x).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn check_svd(a: &Matrix, tol: f64) {
        let f = svd_thin(a);
        let r = f.s.len();
        assert_eq!(r, a.rows().min(a.cols()));
        // descending
        for i in 1..r {
            assert!(f.s[i - 1] >= f.s[i] - 1e-12);
        }
        // reconstruction
        assert!(f.reconstruct().max_abs_diff(a) < tol, "recon {}x{}", a.rows(), a.cols());
        // V orthonormal on the nonzero part
        let rank = f.rank(a.rows(), a.cols());
        let idx: Vec<usize> = (0..rank).collect();
        let vr = f.v.select_cols(&idx);
        assert!(vr.tr_matmul(&vr).max_abs_diff(&Matrix::identity(rank)) < 1e-8);
        let ur = f.u.select_cols(&idx);
        assert!(ur.tr_matmul(&ur).max_abs_diff(&Matrix::identity(rank)) < 1e-8);
    }

    #[test]
    fn random_shapes() {
        let mut rng = Rng::new(0);
        for &(m, n) in &[(1, 1), (5, 5), (12, 7), (7, 12), (40, 10), (10, 40)] {
            let a = Matrix::randn(m, n, &mut rng);
            check_svd(&a, 1e-8);
        }
    }

    #[test]
    fn known_diagonal() {
        let a = Matrix::diag(&[4.0, 1.0, 9.0]);
        let f = svd_thin(&a);
        assert!((f.s[0] - 9.0).abs() < 1e-10);
        assert!((f.s[1] - 4.0).abs() < 1e-10);
        assert!((f.s[2] - 1.0).abs() < 1e-10);
    }

    #[test]
    fn rank_deficient() {
        let mut rng = Rng::new(1);
        let b = Matrix::randn(20, 3, &mut rng);
        let c = Matrix::randn(3, 15, &mut rng);
        let a = b.matmul(&c);
        let f = svd_thin(&a);
        assert_eq!(f.rank(20, 15), 3);
        assert!(f.s[3] < 1e-8);
        check_svd(&a, 1e-8);
    }

    #[test]
    fn truncate_is_best_rank_k() {
        let mut rng = Rng::new(2);
        let a = Matrix::randn(15, 10, &mut rng);
        let f = svd_thin(&a);
        let k = 4;
        let ak = f.truncate(k).reconstruct();
        let err = a.sub(&ak).fro_norm_sq();
        let tail: f64 = f.s.iter().skip(k).map(|&x| x * x).sum();
        assert!((err - tail).abs() < 1e-8 * tail.max(1.0));
        assert!((best_rank_k_error_sq(&a, k) - tail).abs() < 1e-8);
    }

    #[test]
    fn zero_matrix() {
        let f = svd_thin(&Matrix::zeros(4, 3));
        assert!(f.s.iter().all(|&x| x == 0.0));
        assert_eq!(f.rank(4, 3), 0);
    }
}
