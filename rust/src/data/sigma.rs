//! RBF scale calibration (paper §6.1).
//!
//! The paper sets σ so that `η = ‖K_k‖_F² / ‖K‖_F²` (k = ⌈n/100⌉) hits 0.9
//! or 0.99. η is monotone increasing in σ, so we bisect, measuring η on a
//! subsample for tractability.

use crate::coordinator::engine::rbf_cross_cpu;
use crate::linalg::{lanczos_top_k, Matrix};
use crate::util::Rng;

/// `η(K, k) = Σ_{i<=k} σ_i²(K) / Σ_i σ_i²(K)` — the share of Frobenius mass
/// in the top-k spectrum. For SPSD K, `Σ_i σ_i² = ‖K‖_F²` and the top-k
/// singular values are the top-k eigenvalues, so Lanczos gives this in
/// O(n²·k) instead of a full O(n³) eigendecomposition.
pub fn eta(kmat: &Matrix, k: usize) -> f64 {
    let total = kmat.fro_norm_sq();
    if total <= 0.0 {
        return 1.0;
    }
    let (vals, _) = lanczos_top_k(kmat, k, 0x17A);
    let top: f64 = vals.iter().map(|&v| v.max(0.0) * v.max(0.0)).sum();
    (top / total).min(1.0)
}

/// η for the RBF kernel of `x` at scale `sigma`.
pub fn eta_for_sigma(x: &Matrix, sigma: f64, k: usize) -> f64 {
    let gamma = 1.0 / (2.0 * sigma * sigma);
    let kmat = rbf_cross_cpu(x, x, gamma);
    eta(&kmat, k)
}

/// Find σ with `η(σ) ≈ target` by bisection on a subsample of at most
/// `max_sub` points (k scales with the subsample as ⌈n_sub/100⌉).
pub fn calibrate_sigma(x: &Matrix, target_eta: f64, max_sub: usize, seed: u64) -> f64 {
    assert!((0.0..1.0).contains(&target_eta));
    let mut rng = Rng::new(seed);
    let n = x.rows();
    let xs = if n > max_sub {
        let idx = rng.sample_without_replacement(n, max_sub);
        x.select_rows(&idx)
    } else {
        x.clone()
    };
    let k = xs.rows().div_ceil(100).max(1);

    // Bracket: large σ ⇒ K → all-ones ⇒ η → 1; small σ ⇒ K → I ⇒ η → k/n.
    let mut lo = 1e-3;
    let mut hi = 1.0;
    while eta_for_sigma(&xs, hi, k) < target_eta && hi < 1e4 {
        hi *= 2.0;
    }
    while eta_for_sigma(&xs, lo, k) > target_eta && lo > 1e-6 {
        lo *= 0.5;
    }
    for _ in 0..40 {
        let mid = (lo * hi).sqrt(); // geometric bisection (σ spans decades)
        if eta_for_sigma(&xs, mid, k) < target_eta {
            lo = mid;
        } else {
            hi = mid;
        }
        if hi / lo < 1.01 {
            break;
        }
    }
    (lo * hi).sqrt()
}

/// Convert σ to the RBF precision γ = 1/(2σ²).
pub fn gamma_of_sigma(sigma: f64) -> f64 {
    1.0 / (2.0 * sigma * sigma)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::make_blobs;

    #[test]
    fn eta_bounds_and_monotonicity_in_k() {
        let ds = make_blobs("t", 60, 4, 3, 2.0, 0);
        let k = rbf_cross_cpu(&ds.x, &ds.x, 0.5);
        let e1 = eta(&k, 1);
        let e5 = eta(&k, 5);
        let e60 = eta(&k, 60);
        assert!(e1 > 0.0 && e1 <= e5 && e5 <= e60);
        assert!((e60 - 1.0).abs() < 1e-9);
    }

    #[test]
    fn eta_monotone_in_sigma() {
        let ds = make_blobs("t", 80, 4, 3, 2.0, 1);
        let small = eta_for_sigma(&ds.x, 0.05, 1);
        let large = eta_for_sigma(&ds.x, 20.0, 1);
        assert!(large > small, "eta(20)={large} <= eta(0.05)={small}");
        assert!(large > 0.9);
    }

    #[test]
    fn calibration_hits_target() {
        let ds = make_blobs("t", 300, 6, 4, 2.0, 2);
        for target in [0.9, 0.99] {
            let sigma = calibrate_sigma(&ds.x, target, 300, 3);
            let k = 300usize.div_ceil(100);
            let achieved = eta_for_sigma(&ds.x, sigma, k);
            assert!(
                (achieved - target).abs() < 0.03,
                "target {target}: sigma={sigma} achieved={achieved}"
            );
        }
    }
}
