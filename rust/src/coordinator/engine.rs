//! Block scheduler: map arbitrary-shape kernel/matmul requests onto the
//! fixed-shape AOT artifacts.
//!
//! The AOT computations have frozen shapes (256x256 output tiles, feature
//! buckets {16, 128, 1024}); the engine
//!   1. picks the smallest feature bucket >= d and zero-pads features
//!      (RBF distances and matmul contractions are invariant to zero
//!      columns),
//!   2. zero-pads rows up to the tile size (padded rows produce garbage
//!      kernel values that are cropped at assembly),
//!   3. batches all tiles of a request into one runtime-thread submission
//!      (the dynamic batching that keeps channel overhead off the hot
//!      path), and
//!   4. assembles the cropped tiles into the output matrix.
//!
//! Small requests fall back to the pure-rust path: padding a 20x20 block to
//! 256x256 would waste 99% of the FLOPs. The crossover is tunable and
//! benchmarked in `hotpath` (EXPERIMENTS.md §Perf).

use crate::linalg::{gemm, Matrix, MatrixF32};
use crate::runtime::{ExecRequest, RuntimeHandle};
use std::sync::atomic::{AtomicU64, Ordering};

/// Output tile edge of the AOT artifacts.
pub const TILE: usize = 256;

/// Minimum fraction of a tile that must be useful before PJRT is preferred
/// over the pure-rust fallback for that request.
const MIN_FILL: f64 = 0.25;

/// Executes kernel blocks either through PJRT artifacts or pure rust.
pub struct KernelEngine {
    runtime: Option<RuntimeHandle>,
    /// (d_bucket, artifact name), ascending.
    rbf_buckets: Vec<(usize, String)>,
    /// (d_bucket, artifact name) for the polynomial kernel, ascending.
    poly_buckets: Vec<(usize, String)>,
    /// (k_bucket, artifact name), ascending.
    mm_buckets: Vec<(usize, String)>,
    pub pjrt_tiles: AtomicU64,
    pub cpu_blocks: AtomicU64,
}

impl KernelEngine {
    /// PJRT-backed engine over a spawned runtime.
    pub fn pjrt(runtime: RuntimeHandle) -> Self {
        let rbf_buckets = runtime.manifest().rbf_buckets();
        let mut poly_buckets: Vec<(usize, String)> = runtime
            .manifest()
            .artifacts
            .iter()
            .filter(|a| a.kind == "poly_block")
            .map(|a| (a.inputs[3][1], a.name.clone()))
            .collect();
        poly_buckets.sort();
        let mut mm_buckets: Vec<(usize, String)> = runtime
            .manifest()
            .artifacts
            .iter()
            .filter(|a| a.kind == "matmul")
            .map(|a| (a.inputs[0][1], a.name.clone()))
            .collect();
        mm_buckets.sort();
        KernelEngine {
            runtime: Some(runtime),
            rbf_buckets,
            poly_buckets,
            mm_buckets,
            pjrt_tiles: AtomicU64::new(0),
            cpu_blocks: AtomicU64::new(0),
        }
    }

    /// Pure-rust engine (tests, artifact-less runs).
    pub fn cpu() -> Self {
        KernelEngine {
            runtime: None,
            rbf_buckets: Vec::new(),
            poly_buckets: Vec::new(),
            mm_buckets: Vec::new(),
            pjrt_tiles: AtomicU64::new(0),
            cpu_blocks: AtomicU64::new(0),
        }
    }

    /// Try the default artifacts, fall back to CPU.
    pub fn auto() -> Self {
        match RuntimeHandle::spawn_default() {
            Ok(rt) => Self::pjrt(rt),
            Err(_) => Self::cpu(),
        }
    }

    pub fn is_pjrt(&self) -> bool {
        self.runtime.is_some()
    }

    /// Cross RBF kernel: `out[i, j] = exp(-gamma ||x_i - y_j||^2)` for row
    /// blocks `x` (m x d) and `y` (n x d).
    pub fn rbf_cross(&self, x: &Matrix, y: &Matrix, gamma: f64) -> Matrix {
        assert_eq!(x.cols(), y.cols(), "feature dims differ");
        let (m, n, d) = (x.rows(), y.rows(), x.cols());
        if m == 0 || n == 0 {
            return Matrix::zeros(m, n);
        }
        if let Some(bucket) = self.pick_rbf_bucket(m, n, d) {
            match self.rbf_cross_pjrt(x, y, gamma, bucket) {
                Ok(out) => return out,
                Err(e) => eprintln!("warn: PJRT rbf_cross failed ({e:#}); falling back to CPU"),
            }
        }
        self.cpu_blocks.fetch_add(1, Ordering::Relaxed);
        rbf_cross_cpu(x, y, gamma)
    }

    /// Cross polynomial kernel `(gamma <x_i, y_j> + coef0)^degree`.
    pub fn poly_cross(&self, x: &Matrix, y: &Matrix, gamma: f64, coef0: f64, degree: f64) -> Matrix {
        assert_eq!(x.cols(), y.cols(), "feature dims differ");
        let (m, n, d) = (x.rows(), y.rows(), x.cols());
        if m == 0 || n == 0 {
            return Matrix::zeros(m, n);
        }
        if self.runtime.is_some() {
            if let Some((db, name)) = self
                .poly_buckets
                .iter()
                .find(|(db, _)| *db >= d)
                .cloned()
            {
                let mp = m.div_ceil(TILE) * TILE;
                let np = n.div_ceil(TILE) * TILE;
                let fill = (m * n * d) as f64 / (mp * np * db) as f64;
                if fill >= MIN_FILL {
                    match self.poly_cross_pjrt(x, y, gamma, coef0, degree, (db, name)) {
                        Ok(out) => return out,
                        Err(e) => {
                            eprintln!("warn: PJRT poly_cross failed ({e:#}); falling back to CPU")
                        }
                    }
                }
            }
        }
        self.cpu_blocks.fetch_add(1, Ordering::Relaxed);
        poly_cross_cpu(x, y, gamma, coef0, degree)
    }

    fn poly_cross_pjrt(
        &self,
        x: &Matrix,
        y: &Matrix,
        gamma: f64,
        coef0: f64,
        degree: f64,
        (db, artifact): (usize, String),
    ) -> anyhow::Result<Matrix> {
        let rt = self.runtime.as_ref().unwrap();
        let (m, n) = (x.rows(), y.rows());
        let xp = pad_rows_cols_f32(x, m.div_ceil(TILE) * TILE, db);
        let yp = pad_rows_cols_f32(y, n.div_ceil(TILE) * TILE, db);
        let tiles_m = m.div_ceil(TILE);
        let tiles_n = n.div_ceil(TILE);
        let scalars: Vec<(Vec<f32>, Vec<usize>)> = [gamma, coef0, degree]
            .iter()
            .map(|&v| (vec![v as f32], vec![1usize, 1]))
            .collect();
        let mut reqs = Vec::with_capacity(tiles_m * tiles_n);
        for ti in 0..tiles_m {
            for tj in 0..tiles_n {
                let mut inputs = scalars.clone();
                inputs.push((slice_tile(&xp, db, ti), vec![TILE, db]));
                inputs.push((slice_tile(&yp, db, tj), vec![TILE, db]));
                reqs.push(ExecRequest { artifact: artifact.clone(), inputs });
            }
        }
        self.pjrt_tiles.fetch_add(reqs.len() as u64, Ordering::Relaxed);
        let results = rt.execute_batch(reqs)?;
        Ok(assemble_tiles(&results, m, n, tiles_n))
    }

    /// Matmul through the AOT tiles when profitable, else rust gemm.
    pub fn matmul(&self, a: &Matrix, b: &Matrix) -> Matrix {
        assert_eq!(a.cols(), b.rows());
        let (m, k, n) = (a.rows(), a.cols(), b.cols());
        if m == 0 || n == 0 || k == 0 {
            return Matrix::zeros(m, n);
        }
        if let Some(bucket) = self.pick_mm_bucket(m, n, k) {
            match self.matmul_pjrt(a, b, bucket) {
                Ok(out) => return out,
                Err(e) => eprintln!("warn: PJRT matmul failed ({e:#}); falling back to CPU"),
            }
        }
        self.cpu_blocks.fetch_add(1, Ordering::Relaxed);
        gemm::gemm(a, b)
    }

    fn pick_rbf_bucket(&self, m: usize, n: usize, d: usize) -> Option<(usize, String)> {
        let rt = self.runtime.as_ref()?;
        let _ = rt;
        let (db, name) = self.rbf_buckets.iter().find(|(db, _)| *db >= d)?;
        // fill fraction of the padded problem
        let mp = m.div_ceil(TILE) * TILE;
        let np = n.div_ceil(TILE) * TILE;
        let fill = (m * n * d) as f64 / (mp * np * *db) as f64;
        if fill < MIN_FILL {
            return None;
        }
        Some((*db, name.clone()))
    }

    fn pick_mm_bucket(&self, m: usize, n: usize, k: usize) -> Option<(usize, String)> {
        self.runtime.as_ref()?;
        let (kb, name) = self.mm_buckets.iter().find(|(kb, _)| *kb >= k)?;
        let mp = m.div_ceil(TILE) * TILE;
        let np = n.div_ceil(TILE) * TILE;
        let fill = (m * n * k) as f64 / (mp * np * *kb) as f64;
        if fill < MIN_FILL {
            return None;
        }
        Some((*kb, name.clone()))
    }

    fn rbf_cross_pjrt(
        &self,
        x: &Matrix,
        y: &Matrix,
        gamma: f64,
        (db, artifact): (usize, String),
    ) -> anyhow::Result<Matrix> {
        let rt = self.runtime.as_ref().unwrap();
        let (m, n) = (x.rows(), y.rows());
        let xp = pad_rows_cols_f32(x, m.div_ceil(TILE) * TILE, db);
        let yp = pad_rows_cols_f32(y, n.div_ceil(TILE) * TILE, db);
        let tiles_m = m.div_ceil(TILE);
        let tiles_n = n.div_ceil(TILE);
        let gamma_in = (vec![gamma as f32], vec![1usize, 1]);
        let mut reqs = Vec::with_capacity(tiles_m * tiles_n);
        for ti in 0..tiles_m {
            for tj in 0..tiles_n {
                reqs.push(ExecRequest {
                    artifact: artifact.clone(),
                    inputs: vec![
                        gamma_in.clone(),
                        (slice_tile(&xp, db, ti), vec![TILE, db]),
                        (slice_tile(&yp, db, tj), vec![TILE, db]),
                    ],
                });
            }
        }
        self.pjrt_tiles.fetch_add(reqs.len() as u64, Ordering::Relaxed);
        let results = rt.execute_batch(reqs)?;
        Ok(assemble_tiles(&results, m, n, tiles_n))
    }

    fn matmul_pjrt(&self, a: &Matrix, b: &Matrix, (kb, artifact): (usize, String)) -> anyhow::Result<Matrix> {
        let rt = self.runtime.as_ref().unwrap();
        let (m, n) = (a.rows(), b.cols());
        // a: pad rows to tiles, features (k) to bucket
        let ap = pad_rows_cols_f32(a, m.div_ceil(TILE) * TILE, kb);
        // b: pad k (rows) to bucket, n to tiles; store b^T-style tiles? The
        // artifact takes b as (kb, TILE) column panels.
        let bt = b.transpose(); // n x k, row = a column of b
        let btp = pad_rows_cols_f32(&bt, n.div_ceil(TILE) * TILE, kb);
        let tiles_m = m.div_ceil(TILE);
        let tiles_n = n.div_ceil(TILE);
        let mut reqs = Vec::with_capacity(tiles_m * tiles_n);
        for ti in 0..tiles_m {
            for tj in 0..tiles_n {
                // column panel tj of b: (kb x TILE) — transpose back
                let bpanel_t = slice_tile(&btp, kb, tj); // TILE x kb flat
                let mut bpanel = vec![0f32; kb * TILE];
                for r in 0..TILE {
                    for c in 0..kb {
                        bpanel[c * TILE + r] = bpanel_t[r * kb + c];
                    }
                }
                reqs.push(ExecRequest {
                    artifact: artifact.clone(),
                    inputs: vec![
                        (slice_tile(&ap, kb, ti), vec![TILE, kb]),
                        (bpanel, vec![kb, TILE]),
                    ],
                });
            }
        }
        self.pjrt_tiles.fetch_add(reqs.len() as u64, Ordering::Relaxed);
        let results = rt.execute_batch(reqs)?;
        Ok(assemble_tiles(&results, m, n, tiles_n))
    }
}

/// Pure-rust RBF cross block: `exp(-gamma (|x|^2 + |y|^2 - 2 x y^T))`.
///
/// The exponentiation is fused into the GEMM tile loop as an epilogue
/// (EXPERIMENTS.md §Perf): each kernel block is produced in one blocked
/// pass — no second full sweep over the output, and the `exp` work is
/// parallelized by the same pooled tile loop as the dot products. When `x`
/// and `y` are the same matrix the symmetric [`rbf_gram_cpu`] path is used.
pub fn rbf_cross_cpu(x: &Matrix, y: &Matrix, gamma: f64) -> Matrix {
    if std::ptr::eq(x, y) {
        return rbf_gram_cpu(x, gamma);
    }
    let xn = x.row_sq_norms();
    let yn = y.row_sq_norms();
    gemm::gemm_nt_map(x, y, &|i, j, dot| {
        let d2 = (xn[i] + yn[j] - 2.0 * dot).max(0.0);
        (-gamma * d2).exp()
    })
}

/// Symmetric RBF Gram block `K[i, j] = exp(-gamma ||x_i - x_j||^2)`:
/// triangular SYRK + fused epilogue — ~2x fewer dot-product FLOPs than the
/// cross path and exactly symmetric output.
pub fn rbf_gram_cpu(x: &Matrix, gamma: f64) -> Matrix {
    let xn = x.row_sq_norms();
    gemm::syrk_nt_map(x, &|i, j, dot| {
        let d2 = (xn[i] + xn[j] - 2.0 * dot).max(0.0);
        (-gamma * d2).exp()
    })
}

/// Pure-rust polynomial cross block, epilogue fused like the RBF path.
pub fn poly_cross_cpu(x: &Matrix, y: &Matrix, gamma: f64, coef0: f64, degree: f64) -> Matrix {
    if std::ptr::eq(x, y) {
        return gemm::syrk_nt_map(x, &|_, _, dot| (gamma * dot + coef0).powf(degree));
    }
    gemm::gemm_nt_map(x, y, &|_, _, dot| (gamma * dot + coef0).powf(degree))
}

// -------------------------------------------------------- f32 tile kernels
//
// Native narrow-tile kernel blocks: the dot products run on the f32 packed
// plane (f64 accumulation), the row norms and the exp/pow epilogue stay in
// f64, and only the final kernel value is rounded to f32 — NOT a
// compute-f64-then-demote shim, so the 2× bandwidth is real.

/// [`rbf_cross_cpu`] producing an f32 tile.
pub fn rbf_cross_cpu_f32(x: &Matrix, y: &Matrix, gamma: f64) -> MatrixF32 {
    if std::ptr::eq(x, y) {
        return rbf_gram_cpu_f32(x, gamma);
    }
    let xn = x.row_sq_norms();
    let yn = y.row_sq_norms();
    gemm::gemm_nt_map_f32(x, y, &|i, j, dot| {
        let d2 = (xn[i] + yn[j] - 2.0 * dot).max(0.0);
        (-gamma * d2).exp()
    })
}

/// [`rbf_gram_cpu`] producing an f32 tile (triangular + mirror).
pub fn rbf_gram_cpu_f32(x: &Matrix, gamma: f64) -> MatrixF32 {
    let xn = x.row_sq_norms();
    gemm::syrk_nt_map_f32(x, &|i, j, dot| {
        let d2 = (xn[i] + xn[j] - 2.0 * dot).max(0.0);
        (-gamma * d2).exp()
    })
}

/// [`poly_cross_cpu`] producing an f32 tile.
pub fn poly_cross_cpu_f32(x: &Matrix, y: &Matrix, gamma: f64, coef0: f64, degree: f64) -> MatrixF32 {
    if std::ptr::eq(x, y) {
        return gemm::syrk_nt_map_f32(x, &|_, _, dot| (gamma * dot + coef0).powf(degree));
    }
    gemm::gemm_nt_map_f32(x, y, &|_, _, dot| (gamma * dot + coef0).powf(degree))
}

/// Pad `m` to `rows_to x cols_to` with zeros and flatten to f32 row-major.
fn pad_rows_cols_f32(m: &Matrix, rows_to: usize, cols_to: usize) -> Vec<f32> {
    assert!(rows_to >= m.rows() && cols_to >= m.cols());
    let mut out = vec![0f32; rows_to * cols_to];
    for i in 0..m.rows() {
        let src = m.row(i);
        let dst = &mut out[i * cols_to..i * cols_to + m.cols()];
        for (d, &s) in dst.iter_mut().zip(src) {
            *d = s as f32;
        }
    }
    out
}

/// Rows `[t*TILE, (t+1)*TILE)` of a padded flat buffer with `width` columns.
fn slice_tile(padded: &[f32], width: usize, t: usize) -> Vec<f32> {
    padded[t * TILE * width..(t + 1) * TILE * width].to_vec()
}

/// Stitch TILE x TILE result tiles (row-major per tile, tiles in row-major
/// tile order) into an m x n matrix, cropping padding.
fn assemble_tiles(results: &[Vec<f32>], m: usize, n: usize, tiles_n: usize) -> Matrix {
    let mut out = Matrix::zeros(m, n);
    for (idx, tile) in results.iter().enumerate() {
        let ti = idx / tiles_n;
        let tj = idx % tiles_n;
        let r0 = ti * TILE;
        let c0 = tj * TILE;
        for r in 0..TILE.min(m.saturating_sub(r0)) {
            let dst = &mut out.row_mut(r0 + r)[c0..(c0 + TILE).min(n)];
            let src = &tile[r * TILE..r * TILE + dst.len()];
            for (d, &s) in dst.iter_mut().zip(src) {
                *d = s as f64;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn cpu_rbf_matches_formula() {
        let mut rng = Rng::new(0);
        let x = Matrix::randn(7, 3, &mut rng);
        let y = Matrix::randn(5, 3, &mut rng);
        let k = rbf_cross_cpu(&x, &y, 0.9);
        for i in 0..7 {
            for j in 0..5 {
                let d2: f64 = (0..3).map(|t| (x[(i, t)] - y[(j, t)]).powi(2)).sum();
                assert!((k[(i, j)] - (-0.9 * d2).exp()).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn gram_path_matches_cross_path() {
        let mut rng = Rng::new(9);
        let x = Matrix::randn(33, 5, &mut rng);
        let y = x.clone(); // distinct allocation → cross path
        let g = rbf_gram_cpu(&x, 0.8);
        let c = rbf_cross_cpu(&x, &y, 0.8);
        assert!(g.max_abs_diff(&c) < 1e-12);
        assert_eq!(g.max_abs_diff(&g.transpose()), 0.0);
        for i in 0..33 {
            assert!((g[(i, i)] - 1.0).abs() < 1e-9);
        }
        // same-reference dispatch takes the symmetric path
        let via_cross = rbf_cross_cpu(&x, &x, 0.8);
        assert!(via_cross.max_abs_diff(&g) < 1e-12);

        let p = poly_cross_cpu(&x, &x, 0.5, 1.0, 2.0);
        let p2 = poly_cross_cpu(&x, &y, 0.5, 1.0, 2.0);
        assert!(p.max_abs_diff(&p2) < 1e-12);
        assert_eq!(p.max_abs_diff(&p.transpose()), 0.0);
    }

    #[test]
    fn cpu_engine_never_uses_pjrt() {
        let e = KernelEngine::cpu();
        assert!(!e.is_pjrt());
        let mut rng = Rng::new(1);
        let x = Matrix::randn(10, 4, &mut rng);
        let k = e.rbf_cross(&x, &x, 0.5);
        assert_eq!((k.rows(), k.cols()), (10, 10));
        assert_eq!(e.pjrt_tiles.load(Ordering::Relaxed), 0);
        assert!(e.cpu_blocks.load(Ordering::Relaxed) > 0);
    }

    #[test]
    fn cpu_engine_matmul_is_gemm() {
        let e = KernelEngine::cpu();
        let mut rng = Rng::new(2);
        let a = Matrix::randn(6, 9, &mut rng);
        let b = Matrix::randn(9, 4, &mut rng);
        assert!(e.matmul(&a, &b).max_abs_diff(&gemm::gemm(&a, &b)) < 1e-12);
    }

    #[test]
    fn padding_and_tiles_roundtrip() {
        let m = Matrix::from_fn(3, 2, |i, j| (i * 2 + j) as f64);
        let p = pad_rows_cols_f32(&m, 4, 5);
        assert_eq!(p.len(), 20);
        assert_eq!(p[0], 0.0f32.max(0.0)); // m[0,0] = 0
        assert_eq!(p[5], 2.0); // m[1,0]
        assert_eq!(p[2], 0.0); // padded col
        assert_eq!(p[15], 0.0); // padded row
    }

    #[test]
    fn assemble_crops() {
        // one 256-tile, target 2x3
        let mut tile = vec![0f32; TILE * TILE];
        for r in 0..2 {
            for c in 0..3 {
                tile[r * TILE + c] = (r * 10 + c) as f32;
            }
        }
        let out = assemble_tiles(&[tile], 2, 3, 1);
        assert_eq!(out[(1, 2)], 12.0);
        assert_eq!(out[(0, 1)], 1.0);
    }

    #[test]
    fn empty_inputs() {
        let e = KernelEngine::cpu();
        let x = Matrix::zeros(0, 3);
        let y = Matrix::zeros(4, 3);
        let k = e.rbf_cross(&x, &y, 1.0);
        assert_eq!((k.rows(), k.cols()), (0, 4));
    }

    #[test]
    fn f32_kernel_blocks_track_f64_within_rounding() {
        let mut rng = Rng::new(21);
        let x = Matrix::randn(23, 4, &mut rng);
        let y = Matrix::randn(9, 4, &mut rng);
        let k64 = rbf_cross_cpu(&x, &y, 0.7);
        let k32 = rbf_cross_cpu_f32(&x, &y, 0.7);
        for i in 0..23 {
            for j in 0..9 {
                assert!((k64[(i, j)] - k32.row(i)[j] as f64).abs() < 1e-4, "rbf ({i},{j})");
            }
        }
        let g32 = rbf_gram_cpu_f32(&x, 0.7);
        for i in 0..23 {
            assert!((g32.row(i)[i] - 1.0).abs() < 1e-6);
            for j in 0..23 {
                assert_eq!(g32.row(i)[j].to_bits(), g32.row(j)[i].to_bits());
            }
        }
        let p64 = poly_cross_cpu(&x, &y, 0.5, 1.0, 2.0);
        let p32 = poly_cross_cpu_f32(&x, &y, 0.5, 1.0, 2.0);
        for i in 0..23 {
            for j in 0..9 {
                let rel = (p64[(i, j)] - p32.row(i)[j] as f64).abs() / p64[(i, j)].abs().max(1.0);
                assert!(rel < 1e-4, "poly ({i},{j})");
            }
        }
    }
}
