//! Cross-module integration: the full algorithm pipelines at small scale —
//! dataset → oracle → approximation → downstream task → metric. Runs on
//! the pure-rust engine so it works without artifacts; the PJRT variant
//! runs when artifacts exist.

use fastspsd::apps::{knn_classify, kpca, metrics, spectral};
use fastspsd::coordinator::oracle::KernelOracle;
use fastspsd::coordinator::{ApproxRequest, ApproxService, KernelEngine, MethodSpec, RbfOracle, ServiceConfig};
use fastspsd::data::{self, sigma};
use fastspsd::exec::{self, ExecPolicy};
use fastspsd::linalg::Matrix;
use fastspsd::sketch::SketchKind;
use fastspsd::spsd::{self, FastConfig};
use fastspsd::util::Rng;
use std::sync::{mpsc, Arc};

fn small_oracle(n: usize, seed: u64) -> (data::Dataset, Arc<RbfOracle>) {
    let ds = data::make_blobs("it", n, 8, 4, 3.0, seed);
    let sig = sigma::calibrate_sigma(&ds.x, 0.9, 300, seed);
    let oracle = Arc::new(RbfOracle::cpu(
        Arc::new(ds.x.clone()),
        sigma::gamma_of_sigma(sig),
    ));
    (ds, oracle)
}

#[test]
fn fig1_observed_entries_accounting() {
    // The Figure-1 claim: Nyström sees an n x c block; the fast model an
    // n x c block plus an (s'-c)^2 block; the prototype everything.
    let (_ds, oracle) = small_oracle(200, 0);
    let n = 200usize;
    let c = 10usize;
    let mut rng = Rng::new(1);
    let p = spsd::uniform_p(n, c, &mut rng);

    oracle.reset_entries();
    let _ = exec::nystrom(oracle.as_ref(), &p, &ExecPolicy::Materialized).result;
    assert_eq!(oracle.entries_observed(), (n * c) as u64);

    oracle.reset_entries();
    let fast = exec::fast(oracle.as_ref(), &p, FastConfig::uniform(4 * c), &ExecPolicy::Materialized, &mut rng).result;
    let fresh = fast.entries_observed - (n * c) as u64;
    let s_minus_c = (fresh as f64).sqrt();
    assert!((s_minus_c.round() * s_minus_c.round() - fresh as f64).abs() < 1e-9);
    assert!(fast.entries_observed < (n * n) as u64 / 2);

    oracle.reset_entries();
    let _ = exec::prototype(oracle.as_ref(), &p, &ExecPolicy::Materialized).result;
    assert!(oracle.entries_observed() >= (n * n) as u64);
}

#[test]
fn kpca_pipeline_fast_beats_nystrom_misalignment() {
    let (_ds, oracle) = small_oracle(300, 2);
    let kfull = oracle.full();
    let exact = kpca::exact_kpca(&kfull, 3);
    let c = 12;
    let mut mis_ny = 0.0;
    let mut mis_fast = 0.0;
    for t in 0..5u64 {
        let mut rng = Rng::new(10 + t);
        let p = spsd::uniform_p(300, c, &mut rng);
        let ny = kpca::kpca_from_approx(&exec::nystrom(oracle.as_ref(), &p, &ExecPolicy::Materialized).result, 3);
        mis_ny += kpca::misalignment(&exact.v, &ny.v);
        let fa = kpca::kpca_from_approx(
            &exec::fast(oracle.as_ref(), &p, FastConfig::uniform(8 * c), &ExecPolicy::Materialized, &mut rng)
                .result,
            3,
        );
        mis_fast += kpca::misalignment(&exact.v, &fa.v);
    }
    assert!(
        mis_fast <= mis_ny,
        "fast misalignment {mis_fast} should beat nystrom {mis_ny}"
    );
}

#[test]
fn classification_pipeline_end_to_end() {
    let ds = data::make_blobs("clf", 400, 10, 3, 4.0, 3);
    let mut rng = Rng::new(4);
    let (train, test) = data::train_test_split(&ds, &mut rng);
    let sig = sigma::calibrate_sigma(&train.x, 0.9, 300, 5);
    let oracle = RbfOracle::cpu(Arc::new(train.x.clone()), sigma::gamma_of_sigma(sig));
    let p = spsd::uniform_p(train.x.rows(), 16, &mut rng);
    let approx = exec::fast(&oracle, &p, FastConfig::uniform(64), &ExecPolicy::Materialized, &mut rng).result;
    let model = kpca::kpca_from_approx(&approx, 3);
    let kx = oracle.cross(&test.x);
    let ftr = model.train_features();
    let fte = model.test_features(&kx);
    let pred = knn_classify(&ftr, &train.labels, &fte, 10);
    let err = metrics::error_rate(&pred, &test.labels);
    assert!(err < 0.1, "well-separated blobs must classify well, err={err}");
}

#[test]
fn spectral_pipeline_end_to_end() {
    let ds = data::make_blobs("spec", 240, 6, 3, 6.0, 6);
    let sig = sigma::calibrate_sigma(&ds.x, 0.9, 240, 7);
    let oracle = RbfOracle::cpu(Arc::new(ds.x.clone()), sigma::gamma_of_sigma(sig));
    let mut rng = Rng::new(8);
    let p = spsd::uniform_p(240, 12, &mut rng);
    let approx = exec::fast(&oracle, &p, FastConfig::uniform(48), &ExecPolicy::Materialized, &mut rng).result;
    let pred = spectral::spectral_cluster_from_approx(&approx, 3, &mut rng);
    let score = metrics::nmi(&pred, &ds.labels);
    assert!(score > 0.8, "nmi={score}");
}

#[test]
fn service_over_pjrt_engine_if_available() {
    let engine = Arc::new(KernelEngine::auto());
    let ds = data::make_blobs("svc", 600, 16, 4, 3.0, 9);
    let sig = sigma::calibrate_sigma(&ds.x, 0.9, 300, 10);
    let oracle = Arc::new(RbfOracle::new(
        Arc::new(ds.x.clone()),
        sigma::gamma_of_sigma(sig),
        Arc::clone(&engine),
    ));
    let svc = ApproxService::new(
        oracle,
        ServiceConfig { workers: 3, queue_capacity: 8, ..Default::default() },
    );
    let (tx, rx) = mpsc::channel();
    for i in 0..12u64 {
        svc.submit(
            ApproxRequest {
                id: i,
                method: MethodSpec::Fast { s: 48, kind: SketchKind::Uniform },
                c: 12,
                k: 4,
                seed: i,
                // alternate materialized / tile-pipeline policies: both
                // must serve identical results through the same service
                policy: if i % 2 == 0 { None } else { Some(ExecPolicy::streamed(64)) },
                precision: fastspsd::stream::Precision::F64,
                deadline: None,
            },
            tx.clone(),
        );
    }
    svc.drain();
    drop(tx);
    let resps: Vec<_> = rx.iter().collect();
    assert_eq!(resps.len(), 12);
    for r in &resps {
        assert_eq!(r.eigvals.len(), 4);
        assert!(r.eigvals[0] > 0.0);
    }
    assert_eq!(svc.metrics().failed.get(), 0);
    if engine.is_pjrt() {
        // The service's small c-column blocks correctly fall back to the
        // CPU path (padding a 600x12 block to 768x256 tiles would waste
        // >96% of the FLOPs); a dense full-kernel request must hit PJRT.
        let x = Matrix::randn(600, 16, &mut Rng::new(99));
        let _ = engine.rbf_cross(&x, &x, 0.5);
        assert!(engine.pjrt_tiles.load(std::sync::atomic::Ordering::Relaxed) > 0);
    }
}

#[test]
fn regularized_solve_via_all_three_models() {
    let (_ds, oracle) = small_oracle(150, 11);
    let mut rng = Rng::new(12);
    let p = spsd::uniform_p(150, 20, &mut rng);
    let y: Vec<f64> = (0..150).map(|i| ((i * 7 % 13) as f64) - 6.0).collect();
    let pol = ExecPolicy::Materialized;
    for approx in [
        exec::nystrom(oracle.as_ref(), &p, &pol).result,
        exec::fast(oracle.as_ref(), &p, FastConfig::uniform(60), &pol, &mut rng).result,
        exec::prototype(oracle.as_ref(), &p, &pol).result,
    ] {
        let w = approx.solve_regularized(0.8, &y);
        let mut kk = approx.materialize();
        for i in 0..150 {
            kk[(i, i)] += 0.8;
        }
        let resid: f64 = kk
            .matvec(&w)
            .iter()
            .zip(&y)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            .sqrt();
        assert!(resid < 1e-6, "{}: residual {resid}", approx.method);
    }
}
