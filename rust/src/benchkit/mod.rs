//! Criterion-lite benchmark harness substrate (no `criterion` in the image).
//!
//! Each `cargo bench` target (`harness = false`) builds a [`BenchSuite`],
//! registers closures, and gets warmup + adaptive iteration counts +
//! mean/p50/p95 reporting. Results can also be captured programmatically
//! for the table-generation benches.

use std::time::{Duration, Instant};

/// One benchmark's measured statistics.
#[derive(Debug, Clone)]
pub struct Stats {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
    pub min: Duration,
}

impl Stats {
    pub fn mean_secs(&self) -> f64 {
        self.mean.as_secs_f64()
    }
}

fn fmt_dur(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else {
        format!("{:.1} µs", s * 1e6)
    }
}

/// Measure one closure: warm up for `warmup`, then run until `budget`
/// elapses (at least `min_iters` iterations).
pub fn measure(name: &str, warmup: Duration, budget: Duration, min_iters: usize, mut f: impl FnMut()) -> Stats {
    // Warmup.
    let w = Instant::now();
    while w.elapsed() < warmup {
        f();
    }
    // Timed runs.
    let mut samples: Vec<Duration> = Vec::new();
    let start = Instant::now();
    while samples.len() < min_iters || (start.elapsed() < budget && samples.len() < 10_000) {
        let t = Instant::now();
        f();
        samples.push(t.elapsed());
    }
    samples.sort();
    let total: Duration = samples.iter().sum();
    let n = samples.len();
    Stats {
        name: name.to_string(),
        iters: n,
        mean: total / n as u32,
        p50: samples[n / 2],
        p95: samples[(n * 95 / 100).min(n - 1)],
        min: samples[0],
    }
}

/// A named collection of benchmarks with uniform budgets.
pub struct BenchSuite {
    pub title: String,
    pub warmup: Duration,
    pub budget: Duration,
    pub min_iters: usize,
    pub results: Vec<Stats>,
}

impl BenchSuite {
    pub fn new(title: &str) -> Self {
        BenchSuite {
            title: title.to_string(),
            warmup: Duration::from_millis(200),
            budget: Duration::from_secs(1),
            min_iters: 3,
            results: Vec::new(),
        }
    }

    /// Quick preset for expensive end-to-end benches.
    pub fn slow(title: &str) -> Self {
        BenchSuite {
            warmup: Duration::from_millis(0),
            budget: Duration::from_millis(1),
            min_iters: 1,
            ..BenchSuite::new(title)
        }
    }

    pub fn bench(&mut self, name: &str, f: impl FnMut()) -> &Stats {
        let stats = measure(name, self.warmup, self.budget, self.min_iters, f);
        println!(
            "  {:<44} {:>12} (p50 {:>12}, p95 {:>12}, {} iters)",
            stats.name,
            fmt_dur(stats.mean),
            fmt_dur(stats.p50),
            fmt_dur(stats.p95),
            stats.iters
        );
        self.results.push(stats);
        self.results.last().unwrap()
    }

    pub fn header(&self) {
        println!("\n== {} ==", self.title);
    }
}

/// Keep a value alive and opaque to the optimizer.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_counts_iterations() {
        let s = measure(
            "noop",
            Duration::from_millis(1),
            Duration::from_millis(5),
            4,
            || {
                black_box(3 + 4);
            },
        );
        assert!(s.iters >= 4);
        assert!(s.min <= s.p50 && s.p50 <= s.p95);
    }

    #[test]
    fn suite_records_results() {
        let mut suite = BenchSuite::slow("t");
        suite.bench("a", || {
            black_box(1);
        });
        suite.bench("b", || {
            black_box(2);
        });
        assert_eq!(suite.results.len(), 2);
        assert_eq!(suite.results[0].name, "a");
    }

    #[test]
    fn fmt_dur_ranges() {
        assert!(fmt_dur(Duration::from_secs(2)).ends_with(" s"));
        assert!(fmt_dur(Duration::from_millis(5)).ends_with(" ms"));
        assert!(fmt_dur(Duration::from_micros(5)).ends_with(" µs"));
    }
}
