"""L2/AOT checks: graphs lower to HLO text, shapes match the manifest spec,
and the lowered HLO evaluates to the same numbers as the jax graph."""

import json
import os
import tempfile

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile.model import ARTIFACT_SPECS, rbf_block_graph, matmul_graph
from compile.kernels.ref import rbf_block_ref, matmul_ref
from compile import aot

jax.config.update("jax_platform_name", "cpu")


def test_artifact_specs_well_formed():
    assert len(ARTIFACT_SPECS) >= 5
    for name, (fn, shapes) in ARTIFACT_SPECS.items():
        assert callable(fn)
        for s in shapes:
            assert all(isinstance(d, int) and d > 0 for d in s)
        if name.startswith("rbf_block"):
            bm, bn, d = map(int, name.split("_")[-1].split("x"))
            assert shapes == [(1, 1), (bm, d), (bn, d)]
        if name.startswith("matmul"):
            m, k, n = map(int, name.split("_")[-1].split("x"))
            assert shapes == [(m, k), (k, n)]


def test_lower_one_produces_hlo_text():
    name = "rbf_block_256x256x16"
    fn, shapes = ARTIFACT_SPECS[name]
    text = aot.lower_one(name, fn, shapes)
    assert "HloModule" in text
    assert "ENTRY" in text
    # exp shows the fused RBF made it into the module
    assert "exponential" in text


def test_aot_main_writes_manifest(tmp_path):
    import sys
    argv = sys.argv
    sys.argv = ["aot", "--out-dir", str(tmp_path), "--only", "rbf_block_256x256x16"]
    try:
        aot.main()
    finally:
        sys.argv = argv
    man = json.loads((tmp_path / "manifest.json").read_text())
    assert man["format"] == "hlo-text"
    assert len(man["artifacts"]) == 1
    a = man["artifacts"][0]
    assert (tmp_path / a["file"]).exists()
    assert a["inputs"] == [[1, 1], [256, 16], [256, 16]]


def test_rbf_graph_equals_ref_at_aot_shape():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((256, 16), dtype=np.float32))
    y = jnp.asarray(rng.standard_normal((256, 16), dtype=np.float32))
    g = jnp.full((1, 1), 0.25, dtype=jnp.float32)
    (out,) = rbf_block_graph(g, x, y)
    ref = rbf_block_ref(0.25, x, y)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4, atol=1e-5)


def test_matmul_graph_equals_ref_at_aot_shape():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((256, 256), dtype=np.float32))
    y = jnp.asarray(rng.standard_normal((256, 256), dtype=np.float32))
    (out,) = matmul_graph(x, y)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(matmul_ref(x, y)), rtol=1e-4, atol=1e-3
    )


def test_fingerprint_stable():
    assert aot.source_fingerprint() == aot.source_fingerprint()
