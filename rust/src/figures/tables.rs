//! Tables 3, 4, 5 — measured analogues of the paper's complexity tables:
//! wall-clock for the U matrices and the entries of K/A observed, across
//! models (Table 3), S families (Table 4), and CUR variants (Table 5).

use super::Ctx;
use crate::cli::Args;
use crate::coordinator::oracle::{DenseOracle, KernelOracle};
use crate::cur::{self, FastCurConfig};
use crate::data;
use crate::exec::{self, ExecPolicy};
use crate::sketch::SketchKind;
use crate::spsd::{self, FastConfig};
use crate::util::{Rng, Stopwatch};

/// Table 3: time to compute U + #entries, per model, as n grows — the
/// measured version of {Nyström O(c³), prototype O(nnz(K)c + nc²),
/// fast O(nc² + s²c)} and {nc, n², nc + (s−c)²} entries.
pub fn table3(ctx: &Ctx, args: &Args) {
    let pol = ExecPolicy::Materialized;
    let ns = args.get_usize_list("ns", &[512, 1024, 2048]);
    let mut csv = ctx.csv("table3.csv", "n,c,s,method,u_secs,entries,rel_err");
    for &n in &ns {
        let spec = data::DatasetSpec { name: "synthetic", n, d: 16, classes: 8, sep: 2.0 };
        let ds = spec.generate(1.0, ctx.seed);
        let sig = data::sigma::calibrate_sigma(&ds.x, 0.9, 500, ctx.seed);
        let gamma = data::sigma::gamma_of_sigma(sig);
        let oracle = crate::coordinator::RbfOracle::new(
            std::sync::Arc::new(ds.x.clone()),
            gamma,
            std::sync::Arc::clone(&ctx.engine),
        );
        let kfull = oracle.full();
        let kf = kfull.fro_norm_sq();
        let c = (n / 100).max(8);
        let s = 8 * c;
        for rep in 0..ctx.reps {
            let mut rng = Rng::new(ctx.seed + rep as u64);
            let p = spsd::uniform_p(n, c, &mut rng);
            oracle.reset_entries();
            let ny = exec::nystrom(&oracle, &p, &pol).result;
            csv.row(&format!(
                "{n},{c},{c},nystrom,{:.5},{},{:.4e}",
                ny.build_secs,
                ny.entries_observed,
                kfull.sub(&ny.materialize()).fro_norm_sq() / kf
            ));
            oracle.reset_entries();
            let pr = exec::prototype(&oracle, &p, &pol).result;
            csv.row(&format!(
                "{n},{c},{n},prototype,{:.5},{},{:.4e}",
                pr.build_secs,
                pr.entries_observed,
                kfull.sub(&pr.materialize()).fro_norm_sq() / kf
            ));
            oracle.reset_entries();
            let fa = exec::fast(&oracle, &p, FastConfig::uniform(s), &pol, &mut rng).result;
            csv.row(&format!(
                "{n},{c},{s},fast,{:.5},{},{:.4e}",
                fa.build_secs,
                fa.entries_observed,
                kfull.sub(&fa.materialize()).fro_norm_sq() / kf
            ));
        }
    }
    csv.finish();
}

/// Table 4: the five sketching families inside the fast model — sketch
/// formation + U time, entries observed, and resulting error.
pub fn table4(ctx: &Ctx, args: &Args) {
    let n = args.get_usize("n", 1024);
    let mut csv = ctx.csv("table4.csv", "n,c,s,sketch,u_secs,entries,rel_err");
    let spec = data::DatasetSpec { name: "synthetic", n, d: 16, classes: 8, sep: 2.0 };
    let ds = spec.generate(1.0, ctx.seed);
    let sig = data::sigma::calibrate_sigma(&ds.x, 0.9, 500, ctx.seed);
    let kfull = crate::coordinator::engine::rbf_cross_cpu(
        &ds.x,
        &ds.x,
        data::sigma::gamma_of_sigma(sig),
    );
    let oracle = DenseOracle::new(kfull.clone());
    let kf = kfull.fro_norm_sq();
    let c = (n / 100).max(8);
    let s = 8 * c;
    let kinds = [
        SketchKind::Uniform,
        SketchKind::Leverage { scaled: false },
        SketchKind::Leverage { scaled: true },
        SketchKind::Gaussian,
        SketchKind::Srht,
        SketchKind::CountSketch,
    ];
    for rep in 0..ctx.reps {
        let mut rng = Rng::new(ctx.seed + rep as u64);
        let p = spsd::uniform_p(n, c, &mut rng);
        for kind in kinds {
            oracle.reset_entries();
            let cfg = FastConfig {
                s,
                kind,
                force_p_in_s: kind.is_column_selection(),
                leverage_basis: spsd::LeverageBasis::Gram,
            };
            let fa = exec::fast(&oracle, &p, cfg, &ExecPolicy::Materialized, &mut rng).result;
            csv.row(&format!(
                "{n},{c},{s},{},{:.5},{},{:.4e}",
                kind.name(),
                fa.build_secs,
                fa.entries_observed,
                kfull.sub(&fa.materialize()).fro_norm_sq() / kf
            ));
        }
    }
    csv.finish();
}

/// Table 5 / §5.2: CUR U-matrix cost — optimal O(mn·min{c,r}) vs fast
/// O(s_c s_r · min{c,r}) with uniform and leverage sketches.
pub fn table5(ctx: &Ctx, args: &Args) {
    let m = args.get_usize("m", 1536);
    let n = args.get_usize("n", 1024);
    let mut csv = ctx.csv("table5.csv", "m,n,c,r,method,s_c,s_r,u_secs,entries_for_u,rel_err");
    let a = data::image::synth_image(m, n, ctx.seed);
    let c = args.get_usize("c", 50);
    let r = args.get_usize("r", 50);
    for rep in 0..ctx.reps {
        let mut rng = Rng::new(ctx.seed + 17 * rep as u64);
        let cols = cur::select_uniform(n, c, &mut rng);
        let rows = cur::select_uniform(m, r, &mut rng);
        let opt = cur::cur_optimal(&a, &cols, &rows);
        csv.row(&format!(
            "{m},{n},{c},{r},optimal,{m},{n},{:.5},{},{:.4e}",
            opt.build_secs,
            opt.entries_for_u,
            opt.rel_fro_error(&a)
        ));
        let dri = cur::cur_drineas08(&a, &cols, &rows);
        csv.row(&format!(
            "{m},{n},{c},{r},drineas08,{r},{c},{:.5},{},{:.4e}",
            dri.build_secs,
            dri.entries_for_u,
            dri.rel_fro_error(&a)
        ));
        for f in [2usize, 4] {
            for cfg in [
                FastCurConfig::uniform(f * r, f * c),
                FastCurConfig::leverage(f * r, f * c),
            ] {
                let fast = exec::cur_fast(&a, &cols, &rows, cfg, &ExecPolicy::Materialized, &mut rng).result;
                csv.row(&format!(
                    "{m},{n},{c},{r},{},{},{},{:.5},{},{:.4e}",
                    fast.method,
                    f * r,
                    f * c,
                    fast.build_secs,
                    fast.entries_for_u,
                    fast.rel_fro_error(&a)
                ));
            }
        }
    }
    let sw = Stopwatch::start();
    let _ = sw; // (placeholder to keep timing imports uniform)
    csv.finish();
}
