//! Adversarial matrices from the paper's lower bounds (Appendix B / F).
//!
//! `K = diag(B, ..., B)` with `B = (1-a) I_p + a 1 1^T`, `p = n/k`, `a → 1`.
//! Theorem 7 lower-bounds the fast model's error on this family; Theorem 1
//! uses it to show the Nyström method cannot be linear-time under a 1+ε
//! requirement.

use crate::linalg::Matrix;

/// The block-diagonal adversarial matrix (Lemma 21). `n` must be a
/// multiple of `k`.
pub fn block_diag(n: usize, k: usize, alpha: f64) -> Matrix {
    assert!(n % k == 0, "n={n} must be divisible by k={k}");
    assert!((0.0..1.0).contains(&alpha));
    let p = n / k;
    Matrix::from_fn(n, n, |i, j| {
        if i / p != j / p {
            0.0
        } else if i == j {
            1.0
        } else {
            alpha
        }
    })
}

/// `‖A - A_k‖_F^2 = (1-a)^2 (n-k)` for the adversarial matrix (Lemma 21).
pub fn best_rank_k_error_sq(n: usize, k: usize, alpha: f64) -> f64 {
    (1.0 - alpha).powi(2) * (n - k) as f64
}

/// Theorem 7's lower bound on `‖K - K̃_fast‖_F^2 / ‖K - K_k‖_F^2` for
/// column-selection P ⊂ S.
pub fn theorem7_lower_bound(n: usize, k: usize, c: usize, s: usize) -> f64 {
    let (nf, kf, cf, sf) = (n as f64, k as f64, c as f64, s as f64);
    (nf - cf) / (nf - kf) * (1.0 + 2.0 * kf / cf)
        + (nf - sf) / (nf - kf) * (kf * (nf - sf)) / (sf * sf)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::svd::best_rank_k_error_sq as svd_tail;

    #[test]
    fn structure_is_block_diagonal() {
        let a = block_diag(12, 3, 0.5);
        assert_eq!(a[(0, 0)], 1.0);
        assert_eq!(a[(0, 3)], 0.5);
        assert_eq!(a[(0, 4)], 0.0); // across blocks
        assert!(a.max_abs_diff(&a.transpose()) < 1e-15);
    }

    #[test]
    fn lemma21_rank_k_error() {
        let (n, k, alpha) = (20, 4, 0.9);
        let a = block_diag(n, k, alpha);
        let exact = svd_tail(&a, k);
        let formula = best_rank_k_error_sq(n, k, alpha);
        assert!(
            (exact - formula).abs() < 1e-8 * formula.max(1e-12),
            "exact={exact} formula={formula}"
        );
    }

    #[test]
    fn lower_bound_limits_match_paper_remarks() {
        // s = n ⇒ second term vanishes: prototype-model lower bound shape.
        let lb_proto = theorem7_lower_bound(1000, 10, 50, 1000);
        assert!((lb_proto - (950.0 / 990.0) * (1.0 + 20.0 / 50.0)).abs() < 1e-12);
        // s = c ⇒ Nyström-shaped Ω(1 + kn/c^2) behaviour: bound grows with n.
        let lb_small_n = theorem7_lower_bound(1_000, 10, 50, 50);
        let lb_big_n = theorem7_lower_bound(10_000, 10, 50, 50);
        assert!(lb_big_n > 5.0 * lb_small_n);
    }
}
