//! Fast Walsh–Hadamard transform (unnormalized), applied to each column of
//! a matrix in place. Row count must be a power of two.

use crate::linalg::Matrix;

/// In-place unnormalized FWHT over each column of `m` (rows = 2^p).
pub fn fwht_columns(m: &mut Matrix) {
    let n = m.rows();
    assert!(n.is_power_of_two(), "FWHT needs power-of-two rows, got {n}");
    let cols = m.cols();
    let mut h = 1;
    while h < n {
        let mut i = 0;
        while i < n {
            for j in i..i + h {
                for c in 0..cols {
                    let x = m[(j, c)];
                    let y = m[(j + h, c)];
                    m[(j, c)] = x + y;
                    m[(j + h, c)] = x - y;
                }
            }
            i += 2 * h;
        }
        h *= 2;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    /// Naive Hadamard matrix H_n (Sylvester construction).
    fn hadamard(n: usize) -> Matrix {
        assert!(n.is_power_of_two());
        let mut h = Matrix::from_vec(1, 1, vec![1.0]);
        while h.rows() < n {
            let m = h.rows();
            let mut next = Matrix::zeros(2 * m, 2 * m);
            next.set_block(0, 0, &h);
            next.set_block(0, m, &h);
            next.set_block(m, 0, &h);
            next.set_block(m, m, &h.scale(-1.0));
            h = next;
        }
        h
    }

    #[test]
    fn matches_dense_hadamard() {
        let mut rng = Rng::new(0);
        for &n in &[1usize, 2, 4, 16, 32] {
            let a = Matrix::randn(n, 3, &mut rng);
            let mut fast = a.clone();
            fwht_columns(&mut fast);
            let dense = hadamard(n).matmul(&a);
            assert!(fast.max_abs_diff(&dense) < 1e-9, "n={n}");
        }
    }

    #[test]
    fn involution_up_to_n() {
        let mut rng = Rng::new(1);
        let a = Matrix::randn(8, 2, &mut rng);
        let mut b = a.clone();
        fwht_columns(&mut b);
        fwht_columns(&mut b);
        assert!(b.scale(1.0 / 8.0).max_abs_diff(&a) < 1e-10);
    }

    #[test]
    #[should_panic]
    fn non_power_of_two_panics() {
        let mut m = Matrix::zeros(6, 1);
        fwht_columns(&mut m);
    }
}
