//! GEMM v2 — packed, pooled, register-blocked dense products.
//!
//! The L3 hot path for sketch products and kernel-block assembly. Design
//! (EXPERIMENTS.md §Perf):
//!
//! - **Packed panels.** Both operands are repacked into 64-byte-aligned
//!   sliver panels (`MR`-row slivers of A in `[t*MR + r]` order, `NR`-column
//!   slivers of B in `[t*NR + c]` order) so the micro-kernel reads two
//!   contiguous, aligned streams regardless of the logical transpose. Pack
//!   buffers are grow-only thread-locals — steady state does zero
//!   allocations, and [`gemm_into`] writes into a caller-provided matrix.
//! - **Register-blocked micro-kernel, two-level cache blocking.**
//!   `MR x NR = 4 x 4` accumulators live in registers for each `KC`
//!   k-chunk (16 doubles + operand registers fit the x86-64 baseline
//!   register file; with AVX the compiler vectorizes each accumulator
//!   row); `KC = 256` keeps both 8 KiB stream chunks L1-resident at any
//!   k, and `IB = 8` i-slivers share each B chunk with accumulators
//!   parked in a 1 KiB stack block between chunks. C is written exactly
//!   once per tile — no read-modify-write traffic against the output.
//! - **Pooled execution.** Row-sliver spans are distributed over the shared
//!   [`crate::pool::global`] pool via `scoped` — no per-call thread spawn.
//!   Chunk boundaries never change per-element summation order, so results
//!   are bit-identical across thread counts (`FASTSPSD_THREADS=1` included).
//! - **Fused epilogues.** Every driver takes an `epi(i, j, dot) -> f64`
//!   applied at tile-store time while the tile is register/cache hot; the
//!   RBF/polynomial kernels in `coordinator::engine` use this to produce
//!   kernel blocks in one blocked pass (no second sweep over the output).
//! - **Symmetric products.** [`syrk_nt`] / [`syrk_tn`] / [`symm_nt`]
//!   compute only tiles touching the upper triangle and mirror the rest —
//!   ~2x fewer FLOPs for Gram-shaped products (`A A^T`, `A^T A`,
//!   `C† K (C†)^T`, ...).

//! **Mixed precision.** The f32 tile plane ([`gemm_nt_map_f32`] /
//! [`syrk_nt_map_f32`]) packs narrow panels and accumulates in f64. Every
//! `f32 -> f64` conversion is exact and each f32×f32 product fits a 48-bit
//! mantissa (≤ the 53 f64 carries), so a fused multiply-add performs the
//! same single rounding as mul-then-add — the AVX2/NEON kernels are
//! bit-identical to the scalar fallback, and runtime feature detection
//! cannot change results.

use super::{Matrix, MatrixF32};
use crate::pool;
use std::cell::Cell;

/// Rows per A sliver (micro-kernel height).
const MR: usize = 4;
/// Columns per B sliver (micro-kernel width).
const NR: usize = 4;
/// k-chunk per micro-kernel call: each packed stream chunk is
/// `KC * {MR,NR} * 8 = 8 KiB`, so both stay L1-resident at any k.
const KC: usize = 256;
/// i-slivers whose accumulator tiles are kept live together so one
/// B-sliver k-chunk is reused from L1 across IB tiles (IB * MR * NR
/// doubles = 1 KiB of accumulators).
const IB: usize = 8;
/// Extra f64 slots reserved so pack panels can start 64-byte aligned.
const ALIGN_F64: usize = 8;
/// Extra f32 slots for the same 64-byte alignment (half the element width,
/// twice the element slack).
const ALIGN_F32: usize = 16;

// ------------------------------------------------------------- public API

/// C = A * B.
pub fn gemm(a: &Matrix, b: &Matrix) -> Matrix {
    let mut c = Matrix::zeros(a.rows(), b.cols());
    gemm_into(a, b, &mut c);
    c
}

/// C = A * B into a caller-provided output (no allocation on this path).
pub fn gemm_into(a: &Matrix, b: &Matrix, out: &mut Matrix) {
    assert_eq!(a.cols(), b.rows(), "gemm dims: {}x{} * {}x{}", a.rows(), a.cols(), b.rows(), b.cols());
    assert_eq!((out.rows(), out.cols()), (a.rows(), b.cols()), "gemm_into: bad output shape");
    let (m, n) = (a.rows(), b.cols());
    gemm_driver(a, false, b, false, out.data_mut(), m, n, usize::MAX, &|_, _, v| v);
}

/// C = A^T * B (A is k x m, result m x n) without materializing A^T.
pub fn gemm_tn(a: &Matrix, b: &Matrix) -> Matrix {
    let mut c = Matrix::zeros(a.cols(), b.cols());
    gemm_tn_into(a, b, &mut c);
    c
}

/// C = A^T * B into a caller-provided output.
pub fn gemm_tn_into(a: &Matrix, b: &Matrix, out: &mut Matrix) {
    assert_eq!(a.rows(), b.rows(), "gemm_tn dims");
    assert_eq!((out.rows(), out.cols()), (a.cols(), b.cols()), "gemm_tn_into: bad output shape");
    let (m, n) = (a.cols(), b.cols());
    gemm_driver(a, true, b, false, out.data_mut(), m, n, usize::MAX, &|_, _, v| v);
}

/// C = A * B^T — both operands already row-major in the "right" layout.
pub fn gemm_nt(a: &Matrix, b: &Matrix) -> Matrix {
    let mut c = Matrix::zeros(a.rows(), b.rows());
    gemm_nt_into(a, b, &mut c);
    c
}

/// C = A * B^T into a caller-provided output.
pub fn gemm_nt_into(a: &Matrix, b: &Matrix, out: &mut Matrix) {
    assert_eq!(a.cols(), b.cols(), "gemm_nt dims");
    assert_eq!((out.rows(), out.cols()), (a.rows(), b.rows()), "gemm_nt_into: bad output shape");
    let (m, n) = (a.rows(), b.rows());
    gemm_driver(a, false, b, true, out.data_mut(), m, n, usize::MAX, &|_, _, v| v);
}

/// C[i, j] = epi(i, j, (A B^T)[i, j]) — the fused-epilogue entry used by
/// the kernel engines: the epilogue runs per tile while the dot products
/// are still register/cache hot, so e.g. an RBF block needs no second pass.
pub fn gemm_nt_map<E>(a: &Matrix, b: &Matrix, epi: &E) -> Matrix
where
    E: Fn(usize, usize, f64) -> f64 + Sync,
{
    assert_eq!(a.cols(), b.cols(), "gemm_nt dims");
    let (m, n) = (a.rows(), b.rows());
    let mut c = Matrix::zeros(m, n);
    gemm_driver(a, false, b, true, c.data_mut(), m, n, usize::MAX, epi);
    c
}

/// C = A * B with the parallel width capped at `max_threads` — the
/// determinism/bench hook (results are bit-identical for every cap).
pub fn gemm_with_threads(a: &Matrix, b: &Matrix, max_threads: usize) -> Matrix {
    assert_eq!(a.cols(), b.rows(), "gemm dims: {}x{} * {}x{}", a.rows(), a.cols(), b.rows(), b.cols());
    let (m, n) = (a.rows(), b.cols());
    let mut c = Matrix::zeros(m, n);
    gemm_driver(a, false, b, false, c.data_mut(), m, n, max_threads.max(1), &|_, _, v| v);
    c
}

/// Symmetric rank-k update `C = A A^T` (A is m x k): computes only tiles
/// touching the upper triangle, then mirrors — ~2x fewer FLOPs than
/// `gemm_nt(A, A)`.
pub fn syrk_nt(a: &Matrix) -> Matrix {
    let mut c = Matrix::zeros(a.rows(), a.rows());
    symm_driver(a, false, a, false, &mut c, usize::MAX, &|_, _, v| v);
    c
}

/// `C = A^T A` (A is k x m, result m x m), triangle + mirror.
pub fn syrk_tn(a: &Matrix) -> Matrix {
    let mut c = Matrix::zeros(a.cols(), a.cols());
    symm_driver(a, true, a, true, &mut c, usize::MAX, &|_, _, v| v);
    c
}

/// `C = A^T A` into a caller-provided (fully overwritten) output — the
/// zero-allocation form the streaming Gram fold reuses per tile.
pub fn syrk_tn_into(a: &Matrix, out: &mut Matrix) {
    symm_driver(a, true, a, true, out, usize::MAX, &|_, _, v| v);
}

/// `C[i, j] = epi(i, j, (A A^T)[i, j])` over the upper triangle, mirrored.
/// Used for Gram-shaped kernel blocks (RBF/poly gram, squared distances).
/// `epi` must be symmetric in (i, j) for the result to be meaningful.
pub fn syrk_nt_map<E>(a: &Matrix, epi: &E) -> Matrix
where
    E: Fn(usize, usize, f64) -> f64 + Sync,
{
    let mut c = Matrix::zeros(a.rows(), a.rows());
    symm_driver(a, false, a, false, &mut c, usize::MAX, epi);
    c
}

/// `C = A B^T` for a product known to be symmetric (e.g. `M W M^T` chains
/// split as `A = M W`, `B = M` with symmetric `W`): computes the upper
/// triangle only and mirrors, making the result exactly symmetric.
pub fn symm_nt(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.rows(), b.rows(), "symm_nt: result must be square");
    assert_eq!(a.cols(), b.cols(), "symm_nt dims");
    let mut c = Matrix::zeros(a.rows(), a.rows());
    symm_driver(a, false, b, false, &mut c, usize::MAX, &|_, _, v| v);
    c
}

// ---------------------------------------------------------- f32 tile API

/// `C[i, j] = epi(i, j, (A B^T)[i, j]) as f32` over f32 panels with f64
/// accumulation — the narrow-tile twin of [`gemm_nt_map`]. Operands are
/// demoted once at pack time; the dot product reaching `epi` is the exact
/// f64 sum of the rounded f32 factors, so the only f32 rounding on the
/// whole path is one per input element and one per output element.
pub fn gemm_nt_map_f32<E>(a: &Matrix, b: &Matrix, epi: &E) -> MatrixF32
where
    E: Fn(usize, usize, f64) -> f64 + Sync,
{
    assert_eq!(a.cols(), b.cols(), "gemm_nt dims");
    let (m, n) = (a.rows(), b.rows());
    let mut c = MatrixF32::zeros(m, n);
    gemm_driver_f32(a, b, c.data_mut(), m, n, usize::MAX, epi);
    c
}

/// `C[i, j] = epi(i, j, (A A^T)[i, j]) as f32` over the upper triangle,
/// mirrored — the narrow-tile twin of [`syrk_nt_map`]. `epi` must be
/// symmetric in (i, j).
pub fn syrk_nt_map_f32<E>(a: &Matrix, epi: &E) -> MatrixF32
where
    E: Fn(usize, usize, f64) -> f64 + Sync,
{
    let mut c = MatrixF32::zeros(a.rows(), a.rows());
    symm_driver_f32(a, a, &mut c, usize::MAX, epi);
    c
}

// -------------------------------------------------------- pack workspaces

thread_local! {
    // Grow-only pack buffers: one A panel per executing thread, one B panel
    // per calling thread, one pair per element width. Taken/put back around
    // each use so nested calls degrade to a fresh allocation instead of
    // aliasing.
    static A_PACK: Cell<Vec<f64>> = const { Cell::new(Vec::new()) };
    static B_PACK: Cell<Vec<f64>> = const { Cell::new(Vec::new()) };
    static A_PACK_F32: Cell<Vec<f32>> = const { Cell::new(Vec::new()) };
    static B_PACK_F32: Cell<Vec<f32>> = const { Cell::new(Vec::new()) };
}

/// Largest workspace kept cached per thread slot, in **bytes** so the cap
/// means the same footprint at every element width (4M f64 or 8M f32
/// elements). Bigger panels are freed after use so one huge product
/// doesn't pin its high-water footprint for the life of the process.
const MAX_CACHED_WORKSPACE_BYTES: usize = 32 << 20;

fn with_buf<T: Copy + Default, R>(
    slot: &'static std::thread::LocalKey<Cell<Vec<T>>>,
    len: usize,
    f: impl FnOnce(&mut [T]) -> R,
) -> R {
    let mut buf = slot.with(|c| c.take());
    if buf.len() < len {
        buf.resize(len, T::default());
    }
    let r = f(&mut buf[..len]);
    if std::mem::size_of_val(buf.as_slice()) > MAX_CACHED_WORKSPACE_BYTES {
        buf = Vec::new();
    }
    slot.with(|c| c.set(buf));
    r
}

/// First 64-byte-aligned window of `len` elements inside `buf`
/// (`buf.len() >= len + ALIGN_F64` / `ALIGN_F32` per width).
fn align64<T>(buf: &mut [T], len: usize) -> &mut [T] {
    let off = buf.as_ptr().align_offset(64);
    let off = if off == usize::MAX { 0 } else { off };
    &mut buf[off..off + len]
}

/// Parallel width for `flops` of work: small products stay on the caller.
fn workers_for(flops: usize) -> usize {
    // Threshold chosen so small algebra (c x c) stays single-threaded.
    const PAR_THRESHOLD: usize = 1 << 21; // ~2M flops
    if flops < PAR_THRESHOLD {
        1
    } else {
        pool::configured_threads()
    }
}

/// Pack logical-B (k x n) into NR-column slivers: sliver `js` holds
/// `dst[js*k*NR + t*NR + c] = B[t, js*NR + c]`, zero-padded to NR columns.
/// `b_rowmajor_is_bt == true` means `b` is stored n x k (its rows are
/// logical B columns — the `gemm_nt` layout).
fn pack_b(b: &Matrix, b_rowmajor_is_bt: bool, k: usize, n: usize, dst: &mut [f64]) {
    let nsliv = n.div_ceil(NR);
    debug_assert_eq!(dst.len(), nsliv * k * NR);
    if !b_rowmajor_is_bt {
        // single pass over B's rows; writes touch one cache line per sliver
        for t in 0..k {
            let row = b.row(t);
            for js in 0..nsliv {
                let j0 = js * NR;
                let live = NR.min(n - j0);
                let d = &mut dst[js * k * NR + t * NR..js * k * NR + t * NR + NR];
                d[..live].copy_from_slice(&row[j0..j0 + live]);
                for v in &mut d[live..] {
                    *v = 0.0;
                }
            }
        }
    } else {
        // b stored n x k: storage row j is logical column j
        if n % NR != 0 {
            for v in dst[(nsliv - 1) * k * NR..].iter_mut() {
                *v = 0.0;
            }
        }
        for j in 0..n {
            let row = b.row(j);
            let base = (j / NR) * k * NR + (j % NR);
            for (t, &v) in row.iter().enumerate() {
                dst[base + t * NR] = v;
            }
        }
    }
}

/// Pack `live_rows` logical-A rows starting at `i0` into MR-row slivers:
/// sliver `s` holds `dst[s*k*MR + t*MR + r] = A[i0 + s*MR + r, t]`,
/// zero-padded to a multiple of MR rows. `a_trans == true` means `a` is
/// stored k x m (logical row i is storage column i).
fn pack_a_block(a: &Matrix, a_trans: bool, i0: usize, live_rows: usize, k: usize, dst: &mut [f64]) {
    let ns = live_rows.div_ceil(MR);
    debug_assert_eq!(dst.len(), ns * k * MR);
    if live_rows % MR != 0 {
        for v in dst[(ns - 1) * k * MR..].iter_mut() {
            *v = 0.0;
        }
    }
    if !a_trans {
        for r in 0..live_rows {
            let row = a.row(i0 + r);
            let base = (r / MR) * k * MR + (r % MR);
            for (t, &v) in row.iter().enumerate() {
                dst[base + t * MR] = v;
            }
        }
    } else {
        for t in 0..k {
            let row = a.row(t);
            for r in 0..live_rows {
                dst[(r / MR) * k * MR + t * MR + (r % MR)] = row[i0 + r];
            }
        }
    }
}

/// [`pack_b`] at f32 width: logical-B columns demoted once while packing,
/// so the micro-kernel streams narrow panels at double the elements per
/// cache line.
fn pack_b_f32(b: &Matrix, b_rowmajor_is_bt: bool, k: usize, n: usize, dst: &mut [f32]) {
    let nsliv = n.div_ceil(NR);
    debug_assert_eq!(dst.len(), nsliv * k * NR);
    if !b_rowmajor_is_bt {
        for t in 0..k {
            let row = b.row(t);
            for js in 0..nsliv {
                let j0 = js * NR;
                let live = NR.min(n - j0);
                let d = &mut dst[js * k * NR + t * NR..js * k * NR + t * NR + NR];
                for (dv, &v) in d[..live].iter_mut().zip(&row[j0..j0 + live]) {
                    *dv = v as f32;
                }
                for v in &mut d[live..] {
                    *v = 0.0;
                }
            }
        }
    } else {
        if n % NR != 0 {
            for v in dst[(nsliv - 1) * k * NR..].iter_mut() {
                *v = 0.0;
            }
        }
        for j in 0..n {
            let row = b.row(j);
            let base = (j / NR) * k * NR + (j % NR);
            for (t, &v) in row.iter().enumerate() {
                dst[base + t * NR] = v as f32;
            }
        }
    }
}

/// [`pack_a_block`] at f32 width (logical rows only — the f32 drivers
/// always pack from row-major storage).
fn pack_a_block_f32(a: &Matrix, i0: usize, live_rows: usize, k: usize, dst: &mut [f32]) {
    let ns = live_rows.div_ceil(MR);
    debug_assert_eq!(dst.len(), ns * k * MR);
    if live_rows % MR != 0 {
        for v in dst[(ns - 1) * k * MR..].iter_mut() {
            *v = 0.0;
        }
    }
    for r in 0..live_rows {
        let row = a.row(i0 + r);
        let base = (r / MR) * k * MR + (r % MR);
        for (t, &v) in row.iter().enumerate() {
            dst[base + t * MR] = v as f32;
        }
    }
}

// ----------------------------------------------------------- micro-kernel

/// MR x NR register-blocked inner product over packed slivers: the
/// accumulator tile stays in registers for the whole k loop; `ap`/`bp` are
/// contiguous aligned streams, so the k loop auto-vectorizes.
#[inline(always)]
fn microkernel(ap: &[f64], bp: &[f64], acc: &mut [[f64; NR]; MR]) {
    debug_assert_eq!(ap.len() / MR, bp.len() / NR);
    for (a, b) in ap.chunks_exact(MR).zip(bp.chunks_exact(NR)) {
        for r in 0..MR {
            let ar = a[r];
            let accr = &mut acc[r];
            for c in 0..NR {
                accr[c] += ar * b[c];
            }
        }
    }
}

// ------------------------------------------------- f32 micro-kernel plane
//
// All three variants compute, per element, the identical sequence
//   acc[r][c] = round(acc[r][c] + (a[r] as f64) * (b[c] as f64))
// over ascending t. The f32→f64 conversion is exact and the product of two
// converted f32s carries at most 48 mantissa bits (≤ 53), so it is exact
// too; a fused multiply-add's single rounding therefore equals the scalar
// mul-then-add. Kernel choice is a pure speed knob — never a results knob.

/// Uniform signature for the runtime-selected f32 inner kernel. `unsafe`
/// only because the SIMD variants require their target features; the
/// selector guarantees that before handing the pointer out.
type MicroF32 = unsafe fn(&[f32], &[f32], &mut [[f64; NR]; MR]);

/// Scalar fallback — the semantic reference for the SIMD variants.
#[inline(always)]
fn microkernel_f32_scalar(ap: &[f32], bp: &[f32], acc: &mut [[f64; NR]; MR]) {
    debug_assert_eq!(ap.len() / MR, bp.len() / NR);
    for (a, b) in ap.chunks_exact(MR).zip(bp.chunks_exact(NR)) {
        for r in 0..MR {
            let ar = a[r] as f64;
            let accr = &mut acc[r];
            for c in 0..NR {
                accr[c] += ar * (b[c] as f64);
            }
        }
    }
}

unsafe fn microkernel_f32_scalar_erased(ap: &[f32], bp: &[f32], acc: &mut [[f64; NR]; MR]) {
    microkernel_f32_scalar(ap, bp, acc);
}

/// AVX2+FMA: one 256-bit f64 accumulator per tile row (NR = 4 lanes), the
/// B sliver widened with `cvtps_pd` once per t.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn microkernel_f32_avx2(ap: &[f32], bp: &[f32], acc: &mut [[f64; NR]; MR]) {
    use std::arch::x86_64::*;
    debug_assert_eq!(ap.len() / MR, bp.len() / NR);
    let mut accv = [_mm256_setzero_pd(); MR];
    for (r, v) in accv.iter_mut().enumerate() {
        *v = _mm256_loadu_pd(acc[r].as_ptr());
    }
    for (a, b) in ap.chunks_exact(MR).zip(bp.chunks_exact(NR)) {
        let bv = _mm256_cvtps_pd(_mm_loadu_ps(b.as_ptr()));
        for (r, v) in accv.iter_mut().enumerate() {
            let ar = _mm256_set1_pd(a[r] as f64);
            *v = _mm256_fmadd_pd(ar, bv, *v);
        }
    }
    for (r, v) in accv.iter().enumerate() {
        _mm256_storeu_pd(acc[r].as_mut_ptr(), *v);
    }
}

/// NEON (aarch64 baseline): two 128-bit f64 accumulators per tile row,
/// the B sliver widened with `vcvt_f64_f32` per t.
#[cfg(target_arch = "aarch64")]
unsafe fn microkernel_f32_neon(ap: &[f32], bp: &[f32], acc: &mut [[f64; NR]; MR]) {
    use std::arch::aarch64::*;
    debug_assert_eq!(ap.len() / MR, bp.len() / NR);
    let mut lo = [vdupq_n_f64(0.0); MR];
    let mut hi = [vdupq_n_f64(0.0); MR];
    for r in 0..MR {
        lo[r] = vld1q_f64(acc[r].as_ptr());
        hi[r] = vld1q_f64(acc[r].as_ptr().add(2));
    }
    for (a, b) in ap.chunks_exact(MR).zip(bp.chunks_exact(NR)) {
        let b32 = vld1q_f32(b.as_ptr());
        let blo = vcvt_f64_f32(vget_low_f32(b32));
        let bhi = vcvt_high_f64_f32(b32);
        for r in 0..MR {
            let ar = vdupq_n_f64(a[r] as f64);
            lo[r] = vfmaq_f64(lo[r], ar, blo);
            hi[r] = vfmaq_f64(hi[r], ar, bhi);
        }
    }
    for r in 0..MR {
        vst1q_f64(acc[r].as_mut_ptr(), lo[r]);
        vst1q_f64(acc[r].as_mut_ptr().add(2), hi[r]);
    }
}

/// Pick the widest available f32 inner kernel once per driver call.
#[allow(unreachable_code)]
fn select_microkernel_f32() -> MicroF32 {
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma") {
            return microkernel_f32_avx2;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        return microkernel_f32_neon;
    }
    microkernel_f32_scalar_erased
}

// -------------------------------------------------------- general driver

/// Compute `out[i, j] = epi(i, j, sum_t A[i, t] * B[t, j])` for logical
/// A (m x k) and B (k x n), with storage transposes handled by packing.
/// `out` is fully overwritten. Parallel over MR-row sliver spans on the
/// global pool; per-element summation order is independent of the width.
#[allow(clippy::too_many_arguments)]
fn gemm_driver<E>(
    a: &Matrix,
    a_trans: bool,
    b: &Matrix,
    b_rowmajor_is_bt: bool,
    out: &mut [f64],
    m: usize,
    n: usize,
    max_width: usize,
    epi: &E,
) where
    E: Fn(usize, usize, f64) -> f64 + Sync,
{
    let k = if a_trans { a.rows() } else { a.cols() };
    debug_assert_eq!(out.len(), m * n);
    if m == 0 || n == 0 {
        return;
    }
    if k == 0 {
        for i in 0..m {
            for (j, v) in out[i * n..(i + 1) * n].iter_mut().enumerate() {
                *v = epi(i, j, 0.0);
            }
        }
        return;
    }
    let nsliv_i = m.div_ceil(MR);
    let nsliv_j = n.div_ceil(NR);
    let width = workers_for(2 * m * n * k).min(nsliv_i).min(max_width).max(1);
    with_buf(&B_PACK, nsliv_j * k * NR + ALIGN_F64, |bbuf| {
        let bp = align64(bbuf, nsliv_j * k * NR);
        pack_b(b, b_rowmajor_is_bt, k, n, bp);
        let bp: &[f64] = bp;
        if width == 1 {
            compute_span(a, a_trans, bp, out, 0, nsliv_i, m, n, k, epi);
            return;
        }
        // Split the output into row spans on MR-sliver boundaries; each span
        // is an exclusive &mut slice, so no synchronization on stores.
        let span = nsliv_i.div_ceil(width);
        let mut spans: Vec<(usize, usize, &mut [f64])> = Vec::with_capacity(width);
        let mut rest = out;
        let mut s0 = 0;
        while s0 < nsliv_i {
            let s1 = (s0 + span).min(nsliv_i);
            let rows = (s1 * MR).min(m) - s0 * MR;
            let (head, tail) = std::mem::take(&mut rest).split_at_mut(rows * n);
            spans.push((s0, s1, head));
            rest = tail;
            s0 = s1;
        }
        let mut iter = spans.into_iter();
        let first = iter.next().expect("at least one span");
        pool::global().scoped(|scope| {
            for (lo, hi, cspan) in iter {
                scope.spawn(move || compute_span(a, a_trans, bp, cspan, lo, hi, m, n, k, epi));
            }
            let (lo, hi, cspan) = first;
            compute_span(a, a_trans, bp, cspan, lo, hi, m, n, k, epi);
        });
    });
}

/// Compute slivers `[s0, s1)` of the output into `cspan` (exactly those
/// rows): pack the A block once, then run the micro-kernel tile by tile,
/// applying the epilogue as each tile is stored.
#[allow(clippy::too_many_arguments)]
fn compute_span<E>(
    a: &Matrix,
    a_trans: bool,
    bp: &[f64],
    cspan: &mut [f64],
    s0: usize,
    s1: usize,
    m: usize,
    n: usize,
    k: usize,
    epi: &E,
) where
    E: Fn(usize, usize, f64) -> f64 + Sync,
{
    let live_rows = (s1 * MR).min(m) - s0 * MR;
    let ns = s1 - s0;
    debug_assert_eq!(cspan.len(), live_rows * n);
    with_buf(&A_PACK, ns * k * MR + ALIGN_F64, |abuf| {
        let ap_all = align64(abuf, ns * k * MR);
        pack_a_block(a, a_trans, s0 * MR, live_rows, k, ap_all);
        let nsliv_j = n.div_ceil(NR);
        // Two-level cache blocking: KC-chunked k keeps both packed streams
        // in L1, and IB i-slivers share each B-sliver chunk while their
        // accumulator tiles stay in a 1 KiB stack block. Per element the
        // summation order is still plain ascending t, so blocking changes
        // nothing at the bit level (and neither does the thread width).
        let mut sb = 0;
        while sb < ns {
            let se = (sb + IB).min(ns);
            for js in 0..nsliv_j {
                let j0 = js * NR;
                let tile_cols = NR.min(n - j0);
                let mut accs = [[[0.0f64; NR]; MR]; IB];
                let mut t0 = 0;
                while t0 < k {
                    let t1 = (t0 + KC).min(k);
                    let bsl = &bp[js * k * NR + t0 * NR..js * k * NR + t1 * NR];
                    for s in sb..se {
                        let ap = &ap_all[s * k * MR + t0 * MR..s * k * MR + t1 * MR];
                        microkernel(ap, bsl, &mut accs[s - sb]);
                    }
                    t0 = t1;
                }
                for s in sb..se {
                    let i0 = (s0 + s) * MR;
                    let tile_rows = MR.min(m - i0);
                    let row_base = s * MR * n;
                    let acc = &accs[s - sb];
                    for r in 0..tile_rows {
                        let dst = &mut cspan[row_base + r * n + j0..row_base + r * n + j0 + tile_cols];
                        let arow = &acc[r];
                        for (cc, v) in dst.iter_mut().enumerate() {
                            *v = epi(i0 + r, j0 + cc, arow[cc]);
                        }
                    }
                }
            }
            sb = se;
        }
    });
}

// ------------------------------------------------------------ f32 driver

/// f32 twin of [`gemm_driver`] for the `A B^T` form the kernel engines
/// use (both operands row-major, `b`'s rows are the logical columns).
/// Panels are packed narrow, accumulators are f64, and the epilogue result
/// is rounded once to f32 at store time. Span split and per-element
/// summation order mirror the f64 driver, so results are bit-identical
/// across thread widths and kernel variants alike.
fn gemm_driver_f32<E>(
    a: &Matrix,
    b: &Matrix,
    out: &mut [f32],
    m: usize,
    n: usize,
    max_width: usize,
    epi: &E,
) where
    E: Fn(usize, usize, f64) -> f64 + Sync,
{
    let k = a.cols();
    debug_assert_eq!(out.len(), m * n);
    if m == 0 || n == 0 {
        return;
    }
    if k == 0 {
        for i in 0..m {
            for (j, v) in out[i * n..(i + 1) * n].iter_mut().enumerate() {
                *v = epi(i, j, 0.0) as f32;
            }
        }
        return;
    }
    let kern = select_microkernel_f32();
    let nsliv_i = m.div_ceil(MR);
    let nsliv_j = n.div_ceil(NR);
    let width = workers_for(2 * m * n * k).min(nsliv_i).min(max_width).max(1);
    with_buf(&B_PACK_F32, nsliv_j * k * NR + ALIGN_F32, |bbuf| {
        let bp = align64(bbuf, nsliv_j * k * NR);
        pack_b_f32(b, true, k, n, bp);
        let bp: &[f32] = bp;
        if width == 1 {
            compute_span_f32(a, bp, out, 0, nsliv_i, m, n, k, kern, epi);
            return;
        }
        let span = nsliv_i.div_ceil(width);
        let mut spans: Vec<(usize, usize, &mut [f32])> = Vec::with_capacity(width);
        let mut rest = out;
        let mut s0 = 0;
        while s0 < nsliv_i {
            let s1 = (s0 + span).min(nsliv_i);
            let rows = (s1 * MR).min(m) - s0 * MR;
            let (head, tail) = std::mem::take(&mut rest).split_at_mut(rows * n);
            spans.push((s0, s1, head));
            rest = tail;
            s0 = s1;
        }
        let mut iter = spans.into_iter();
        let first = iter.next().expect("at least one span");
        pool::global().scoped(|scope| {
            for (lo, hi, cspan) in iter {
                scope.spawn(move || compute_span_f32(a, bp, cspan, lo, hi, m, n, k, kern, epi));
            }
            let (lo, hi, cspan) = first;
            compute_span_f32(a, bp, cspan, lo, hi, m, n, k, kern, epi);
        });
    });
}

/// f32 twin of [`compute_span`]: same KC/IB blocking, same ascending-t
/// per-element order, f64 accumulator tiles, narrow packed streams.
#[allow(clippy::too_many_arguments)]
fn compute_span_f32<E>(
    a: &Matrix,
    bp: &[f32],
    cspan: &mut [f32],
    s0: usize,
    s1: usize,
    m: usize,
    n: usize,
    k: usize,
    kern: MicroF32,
    epi: &E,
) where
    E: Fn(usize, usize, f64) -> f64 + Sync,
{
    let live_rows = (s1 * MR).min(m) - s0 * MR;
    let ns = s1 - s0;
    debug_assert_eq!(cspan.len(), live_rows * n);
    with_buf(&A_PACK_F32, ns * k * MR + ALIGN_F32, |abuf| {
        let ap_all = align64(abuf, ns * k * MR);
        pack_a_block_f32(a, s0 * MR, live_rows, k, ap_all);
        let nsliv_j = n.div_ceil(NR);
        let mut sb = 0;
        while sb < ns {
            let se = (sb + IB).min(ns);
            for js in 0..nsliv_j {
                let j0 = js * NR;
                let tile_cols = NR.min(n - j0);
                let mut accs = [[[0.0f64; NR]; MR]; IB];
                let mut t0 = 0;
                while t0 < k {
                    let t1 = (t0 + KC).min(k);
                    let bsl = &bp[js * k * NR + t0 * NR..js * k * NR + t1 * NR];
                    for s in sb..se {
                        let ap = &ap_all[s * k * MR + t0 * MR..s * k * MR + t1 * MR];
                        // SAFETY: `kern` was vetted by select_microkernel_f32
                        // against the running CPU's features.
                        unsafe { kern(ap, bsl, &mut accs[s - sb]) };
                    }
                    t0 = t1;
                }
                for s in sb..se {
                    let i0 = (s0 + s) * MR;
                    let tile_rows = MR.min(m - i0);
                    let row_base = s * MR * n;
                    let acc = &accs[s - sb];
                    for r in 0..tile_rows {
                        let dst = &mut cspan[row_base + r * n + j0..row_base + r * n + j0 + tile_cols];
                        let arow = &acc[r];
                        for (cc, v) in dst.iter_mut().enumerate() {
                            *v = epi(i0 + r, j0 + cc, arow[cc]) as f32;
                        }
                    }
                }
            }
            sb = se;
        }
    });
}

// ------------------------------------------------------ symmetric driver

/// Raw output pointer shared across sliver tasks. Each task writes a
/// disjoint set of rows (slivers form a partition), so access is race-free.
#[derive(Clone, Copy)]
struct SendPtr(*mut f64);
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

/// [`SendPtr`] at f32 width.
#[derive(Clone, Copy)]
struct SendPtrF32(*mut f32);
unsafe impl Send for SendPtrF32 {}
unsafe impl Sync for SendPtrF32 {}

/// Compute `out[i, j] = epi(i, j, sum_t A[i, t] * B[j, t])` for a product
/// known to be symmetric: only tiles intersecting the upper triangle are
/// computed; the strict lower triangle is mirrored afterwards. Sliver order
/// is zigzagged so the triangular workload balances across contiguous
/// chunks. Results are bit-identical across widths.
fn symm_driver<E>(
    a: &Matrix,
    a_trans: bool,
    b: &Matrix,
    b_trans: bool,
    out: &mut Matrix,
    max_width: usize,
    epi: &E,
) where
    E: Fn(usize, usize, f64) -> f64 + Sync,
{
    let (m, k) = if a_trans { (a.cols(), a.rows()) } else { (a.rows(), a.cols()) };
    let (mb, kb) = if b_trans { (b.cols(), b.rows()) } else { (b.rows(), b.cols()) };
    assert_eq!(m, mb, "symm: operands must produce a square result");
    assert_eq!(k, kb, "symm dims");
    assert_eq!((out.rows(), out.cols()), (m, m), "symm: bad output shape");
    if m == 0 {
        return;
    }
    let n = m;
    if k == 0 {
        for i in 0..m {
            for j in i..n {
                out[(i, j)] = epi(i, j, 0.0);
            }
        }
        mirror_lower_from_upper(out);
        return;
    }
    let nsliv_i = m.div_ceil(MR);
    let nsliv_j = n.div_ceil(NR);
    // triangle ~halves the flops; threshold on the actual work
    let width = workers_for(m * n * k).min(nsliv_i).min(max_width).max(1);
    with_buf(&B_PACK, nsliv_j * k * NR + ALIGN_F64, |bbuf| {
        let bp = align64(bbuf, nsliv_j * k * NR);
        // right operand is logical B^T: when b is stored m x k its rows are
        // exactly the right operand's columns
        pack_b(b, !b_trans, k, n, bp);
        let bp: &[f64] = bp;
        let cptr = SendPtr(out.data_mut().as_mut_ptr());
        if width == 1 {
            for s in 0..nsliv_i {
                symm_sliver(a, a_trans, bp, cptr, s, m, n, k, epi);
            }
        } else {
            let chunk = nsliv_i.div_ceil(width);
            pool::global().scoped(|scope| {
                for t in 1..width {
                    let lo = t * chunk;
                    let hi = ((t + 1) * chunk).min(nsliv_i);
                    if lo >= hi {
                        break;
                    }
                    scope.spawn(move || {
                        for idx in lo..hi {
                            symm_sliver(a, a_trans, bp, cptr, zigzag(idx, nsliv_i), m, n, k, epi);
                        }
                    });
                }
                for idx in 0..chunk.min(nsliv_i) {
                    symm_sliver(a, a_trans, bp, cptr, zigzag(idx, nsliv_i), m, n, k, epi);
                }
            });
        }
    });
    mirror_lower_from_upper(out);
}

/// Balance the triangular workload: even indices walk from the top (wide
/// rows), odd indices from the bottom (narrow rows), so contiguous index
/// chunks carry near-equal work. Bijective on `0..n`.
fn zigzag(idx: usize, n: usize) -> usize {
    if idx % 2 == 0 {
        idx / 2
    } else {
        n - 1 - idx / 2
    }
}

/// One MR-row sliver of the symmetric product: tiles strictly below the
/// diagonal are skipped; boundary tiles may compute a few sub-diagonal
/// entries, which the mirror pass overwrites.
#[allow(clippy::too_many_arguments)]
fn symm_sliver<E>(
    a: &Matrix,
    a_trans: bool,
    bp: &[f64],
    c: SendPtr,
    s: usize,
    m: usize,
    n: usize,
    k: usize,
    epi: &E,
) where
    E: Fn(usize, usize, f64) -> f64 + Sync,
{
    let i0 = s * MR;
    let tile_rows = MR.min(m - i0);
    with_buf(&A_PACK, k * MR + ALIGN_F64, |abuf| {
        let ap = align64(abuf, k * MR);
        pack_a_block(a, a_trans, i0, tile_rows, k, ap);
        let nsliv_j = n.div_ceil(NR);
        // first sliver whose column range reaches the diagonal: js*NR+NR > i0
        for js in (i0 / NR)..nsliv_j {
            let j0 = js * NR;
            let tile_cols = NR.min(n - j0);
            let bsl = &bp[js * k * NR..(js + 1) * k * NR];
            let mut acc = [[0.0f64; NR]; MR];
            microkernel(ap, bsl, &mut acc);
            for r in 0..tile_rows {
                let i = i0 + r;
                // SAFETY: slivers partition the rows; row `i` is written
                // only by this call, and no other task reads it.
                let dst = unsafe { std::slice::from_raw_parts_mut(c.0.add(i * n + j0), tile_cols) };
                let arow = &acc[r];
                for (cc, v) in dst.iter_mut().enumerate() {
                    *v = epi(i, j0 + cc, arow[cc]);
                }
            }
        }
    });
}

/// Copy the strict upper triangle onto the strict lower one, in 64x64
/// blocks for cache locality, parallel over row blocks. Readers touch only
/// strictly-upper elements and writers only strictly-lower ones, so the
/// tasks are race-free.
fn mirror_lower_from_upper(out: &mut Matrix) {
    let n = out.rows();
    if n < 2 {
        return;
    }
    const B: usize = 64;
    let nblk = n.div_ceil(B);
    let ptr = SendPtr(out.data_mut().as_mut_ptr());
    pool::parallel_for(nblk, pool::configured_threads(), |bi| {
        let r0 = bi * B;
        let r1 = (r0 + B).min(n);
        for cb in 0..=bi {
            let c0 = cb * B;
            for i in r0.max(1)..r1 {
                let c1 = (c0 + B).min(i);
                if c0 >= c1 {
                    continue;
                }
                // SAFETY: row block `bi` is owned by this task; reads are
                // from strictly-upper elements no task writes.
                let row = unsafe { std::slice::from_raw_parts_mut(ptr.0.add(i * n + c0), c1 - c0) };
                for (off, v) in row.iter_mut().enumerate() {
                    let j = c0 + off;
                    *v = unsafe { *ptr.0.add(j * n + i) };
                }
            }
        }
    });
}

/// f32 twin of [`symm_driver`] for the `A B^T` symmetric form (both
/// operands row-major, same column count): upper-triangle tiles only,
/// zigzag balance, mirror pass — bit-identical across widths and kernels.
fn symm_driver_f32<E>(
    a: &Matrix,
    b: &Matrix,
    out: &mut MatrixF32,
    max_width: usize,
    epi: &E,
) where
    E: Fn(usize, usize, f64) -> f64 + Sync,
{
    let (m, k) = (a.rows(), a.cols());
    assert_eq!(m, b.rows(), "symm: operands must produce a square result");
    assert_eq!(k, b.cols(), "symm dims");
    assert_eq!((out.rows(), out.cols()), (m, m), "symm: bad output shape");
    if m == 0 {
        return;
    }
    let n = m;
    if k == 0 {
        for i in 0..m {
            for j in i..n {
                out.row_mut(i)[j] = epi(i, j, 0.0) as f32;
            }
        }
        mirror_lower_from_upper_f32(out);
        return;
    }
    let kern = select_microkernel_f32();
    let nsliv_i = m.div_ceil(MR);
    let nsliv_j = n.div_ceil(NR);
    let width = workers_for(m * n * k).min(nsliv_i).min(max_width).max(1);
    with_buf(&B_PACK_F32, nsliv_j * k * NR + ALIGN_F32, |bbuf| {
        let bp = align64(bbuf, nsliv_j * k * NR);
        // b is stored m x k: its rows are the right operand's columns
        pack_b_f32(b, true, k, n, bp);
        let bp: &[f32] = bp;
        let cptr = SendPtrF32(out.data_mut().as_mut_ptr());
        if width == 1 {
            for s in 0..nsliv_i {
                symm_sliver_f32(a, bp, cptr, s, m, n, k, kern, epi);
            }
        } else {
            let chunk = nsliv_i.div_ceil(width);
            pool::global().scoped(|scope| {
                for t in 1..width {
                    let lo = t * chunk;
                    let hi = ((t + 1) * chunk).min(nsliv_i);
                    if lo >= hi {
                        break;
                    }
                    scope.spawn(move || {
                        for idx in lo..hi {
                            symm_sliver_f32(a, bp, cptr, zigzag(idx, nsliv_i), m, n, k, kern, epi);
                        }
                    });
                }
                for idx in 0..chunk.min(nsliv_i) {
                    symm_sliver_f32(a, bp, cptr, zigzag(idx, nsliv_i), m, n, k, kern, epi);
                }
            });
        }
    });
    mirror_lower_from_upper_f32(out);
}

/// One MR-row sliver of the f32 symmetric product (cf. [`symm_sliver`]).
#[allow(clippy::too_many_arguments)]
fn symm_sliver_f32<E>(
    a: &Matrix,
    bp: &[f32],
    c: SendPtrF32,
    s: usize,
    m: usize,
    n: usize,
    k: usize,
    kern: MicroF32,
    epi: &E,
) where
    E: Fn(usize, usize, f64) -> f64 + Sync,
{
    let i0 = s * MR;
    let tile_rows = MR.min(m - i0);
    with_buf(&A_PACK_F32, k * MR + ALIGN_F32, |abuf| {
        let ap = align64(abuf, k * MR);
        pack_a_block_f32(a, i0, tile_rows, k, ap);
        let nsliv_j = n.div_ceil(NR);
        for js in (i0 / NR)..nsliv_j {
            let j0 = js * NR;
            let tile_cols = NR.min(n - j0);
            let bsl = &bp[js * k * NR..(js + 1) * k * NR];
            let mut acc = [[0.0f64; NR]; MR];
            // SAFETY: `kern` was vetted by select_microkernel_f32.
            unsafe { kern(ap, bsl, &mut acc) };
            for r in 0..tile_rows {
                let i = i0 + r;
                // SAFETY: slivers partition the rows; row `i` is written
                // only by this call, and no other task reads it.
                let dst = unsafe { std::slice::from_raw_parts_mut(c.0.add(i * n + j0), tile_cols) };
                let arow = &acc[r];
                for (cc, v) in dst.iter_mut().enumerate() {
                    *v = epi(i, j0 + cc, arow[cc]) as f32;
                }
            }
        }
    });
}

/// [`mirror_lower_from_upper`] at f32 width.
fn mirror_lower_from_upper_f32(out: &mut MatrixF32) {
    let n = out.rows();
    if n < 2 {
        return;
    }
    const B: usize = 64;
    let nblk = n.div_ceil(B);
    let ptr = SendPtrF32(out.data_mut().as_mut_ptr());
    pool::parallel_for(nblk, pool::configured_threads(), |bi| {
        let r0 = bi * B;
        let r1 = (r0 + B).min(n);
        for cb in 0..=bi {
            let c0 = cb * B;
            for i in r0.max(1)..r1 {
                let c1 = (c0 + B).min(i);
                if c0 >= c1 {
                    continue;
                }
                // SAFETY: row block `bi` is owned by this task; reads are
                // from strictly-upper elements no task writes.
                let row = unsafe { std::slice::from_raw_parts_mut(ptr.0.add(i * n + c0), c1 - c0) };
                for (off, v) in row.iter_mut().enumerate() {
                    let j = c0 + off;
                    *v = unsafe { *ptr.0.add(j * n + i) };
                }
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn naive(a: &Matrix, b: &Matrix) -> Matrix {
        let mut c = Matrix::zeros(a.rows(), b.cols());
        for i in 0..a.rows() {
            for j in 0..b.cols() {
                let mut s = 0.0;
                for kk in 0..a.cols() {
                    s += a[(i, kk)] * b[(kk, j)];
                }
                c[(i, j)] = s;
            }
        }
        c
    }

    #[test]
    fn matches_naive_small() {
        let mut rng = Rng::new(0);
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 2), (7, 4, 9), (16, 16, 16), (33, 17, 29)] {
            let a = Matrix::randn(m, k, &mut rng);
            let b = Matrix::randn(k, n, &mut rng);
            let c = gemm(&a, &b);
            assert!(c.max_abs_diff(&naive(&a, &b)) < 1e-10, "{}x{}x{}", m, k, n);
        }
    }

    #[test]
    fn matches_naive_threaded_size() {
        let mut rng = Rng::new(1);
        let a = Matrix::randn(200, 150, &mut rng);
        let b = Matrix::randn(150, 180, &mut rng);
        let c = gemm(&a, &b);
        assert!(c.max_abs_diff(&naive(&a, &b)) < 1e-9);
    }

    #[test]
    fn tn_and_nt_variants() {
        let mut rng = Rng::new(2);
        let a = Matrix::randn(20, 30, &mut rng);
        let b = Matrix::randn(20, 25, &mut rng);
        let c = gemm_tn(&a, &b); // 30 x 25
        assert!(c.max_abs_diff(&naive(&a.transpose(), &b)) < 1e-10);
        let d = Matrix::randn(15, 30, &mut rng);
        let e = gemm_nt(&a, &d); // 20 x 15
        assert!(e.max_abs_diff(&naive(&a, &d.transpose())) < 1e-10);
    }

    #[test]
    fn identity_is_neutral() {
        let mut rng = Rng::new(3);
        let a = Matrix::randn(10, 10, &mut rng);
        assert!(gemm(&a, &Matrix::identity(10)).max_abs_diff(&a) < 1e-12);
        assert!(gemm(&Matrix::identity(10), &a).max_abs_diff(&a) < 1e-12);
    }

    #[test]
    #[should_panic]
    fn dim_mismatch_panics() {
        gemm(&Matrix::zeros(2, 3), &Matrix::zeros(4, 2));
    }

    #[test]
    fn into_variants_overwrite_dirty_buffers() {
        let mut rng = Rng::new(4);
        for &(m, k, n) in &[(1, 1, 1), (5, 7, 3), (9, 2, 13), (12, 12, 12), (31, 33, 2)] {
            let a = Matrix::randn(m, k, &mut rng);
            let b = Matrix::randn(k, n, &mut rng);
            let mut c = Matrix::from_fn(m, n, |_, _| f64::NAN);
            gemm_into(&a, &b, &mut c);
            assert!(c.max_abs_diff(&naive(&a, &b)) < 1e-10, "gemm_into {m}x{k}x{n}");

            let at = a.transpose(); // k... logical A via trans storage
            let mut c2 = Matrix::from_fn(m, n, |_, _| f64::NAN);
            gemm_tn_into(&at, &b, &mut c2);
            assert!(c2.max_abs_diff(&naive(&a, &b)) < 1e-10, "gemm_tn_into {m}x{k}x{n}");

            let bt = b.transpose(); // n x k
            let mut c3 = Matrix::from_fn(m, n, |_, _| f64::NAN);
            gemm_nt_into(&a, &bt, &mut c3);
            assert!(c3.max_abs_diff(&naive(&a, &b)) < 1e-10, "gemm_nt_into {m}x{k}x{n}");
        }
    }

    #[test]
    fn zero_k_applies_epilogue_over_zero_dot() {
        let a = Matrix::zeros(3, 0);
        let b = Matrix::zeros(0, 4);
        let c = gemm(&a, &b);
        assert_eq!((c.rows(), c.cols()), (3, 4));
        assert!(c.data().iter().all(|&v| v == 0.0));
        let bt = Matrix::zeros(4, 0);
        let k = gemm_nt_map(&a, &bt, &|i, j, dot| dot + (i * 10 + j) as f64);
        assert_eq!(k[(2, 3)], 23.0);
    }

    #[test]
    fn epilogue_fuses_elementwise_map() {
        let mut rng = Rng::new(5);
        let a = Matrix::randn(13, 6, &mut rng);
        let b = Matrix::randn(9, 6, &mut rng);
        let fused = gemm_nt_map(&a, &b, &|i, j, dot| (2.0 * dot).exp() + (i + j) as f64);
        let plain = gemm_nt(&a, &b);
        for i in 0..13 {
            for j in 0..9 {
                let expect = (2.0 * plain[(i, j)]).exp() + (i + j) as f64;
                assert!((fused[(i, j)] - expect).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn syrk_matches_naive_and_is_exactly_symmetric() {
        let mut rng = Rng::new(6);
        for &(m, k) in &[(1, 1), (2, 9), (5, 3), (12, 12), (33, 7), (40, 64)] {
            let a = Matrix::randn(m, k, &mut rng);
            let c = syrk_nt(&a);
            assert!(c.max_abs_diff(&naive(&a, &a.transpose())) < 1e-10, "syrk_nt {m}x{k}");
            let ct = syrk_tn(&a.transpose());
            assert!(ct.max_abs_diff(&naive(&a, &a.transpose())) < 1e-10, "syrk_tn {m}x{k}");
            for i in 0..m {
                for j in (i + 1)..m {
                    // bitwise symmetry, not just approximate
                    assert_eq!(c[(i, j)].to_bits(), c[(j, i)].to_bits());
                }
            }
        }
    }

    #[test]
    fn syrk_tn_into_overwrites_dirty_output() {
        let mut rng = Rng::new(11);
        for &(k, m) in &[(1usize, 1usize), (9, 5), (17, 13)] {
            let a = Matrix::randn(k, m, &mut rng);
            let mut out = Matrix::from_fn(m, m, |_, _| f64::NAN);
            syrk_tn_into(&a, &mut out);
            assert!(out.max_abs_diff(&syrk_tn(&a)) == 0.0, "{k}x{m}");
        }
    }

    #[test]
    fn symm_nt_matches_full_product_for_symmetric_chains() {
        let mut rng = Rng::new(7);
        let x = Matrix::randn(17, 9, &mut rng);
        let mut w = Matrix::randn(9, 9, &mut rng);
        w.symmetrize();
        let xw = x.matmul(&w);
        let full = naive(&xw, &x.transpose()); // X W X^T, symmetric
        let sym = symm_nt(&xw, &x);
        assert!(sym.max_abs_diff(&full) < 1e-9);
        assert!(sym.max_abs_diff(&sym.transpose()) == 0.0);
    }

    #[test]
    fn syrk_map_applies_symmetric_epilogue() {
        let mut rng = Rng::new(8);
        let x = Matrix::randn(21, 5, &mut rng);
        let g = syrk_nt_map(&x, &|i, j, dot| dot * 0.5 + ((i * j) as f64).sqrt());
        let plain = gemm_nt(&x, &x);
        for i in 0..21 {
            for j in 0..21 {
                let expect = plain[(i, j)] * 0.5 + ((i * j) as f64).sqrt();
                assert!((g[(i, j)] - expect).abs() < 1e-9, "({i},{j})");
            }
        }
    }

    #[test]
    fn bit_identical_across_thread_counts() {
        // The determinism contract: pooled execution must not change a
        // single bit of the result for any parallel width. Sizes exceed the
        // parallel threshold so the width caps actually bite.
        let mut rng = Rng::new(9);
        let a = Matrix::randn(200, 150, &mut rng);
        let b = Matrix::randn(150, 180, &mut rng);
        let reference = gemm_with_threads(&a, &b, 1);
        for threads in [2, 3, 4, 8, 16] {
            let c = gemm_with_threads(&a, &b, threads);
            assert_eq!(reference.data().len(), c.data().len());
            for (x, y) in reference.data().iter().zip(c.data()) {
                assert_eq!(x.to_bits(), y.to_bits(), "width {threads} changed bits");
            }
        }
    }

    #[test]
    fn symm_driver_bit_identical_across_widths() {
        // 210*210*60 flops > the 2M parallel threshold
        let mut rng = Rng::new(10);
        let x = Matrix::randn(210, 60, &mut rng);
        let mut reference = Matrix::zeros(210, 210);
        symm_driver(&x, false, &x, false, &mut reference, 1, &|_, _, v| v);
        for threads in [2, 5, 8] {
            let mut c = Matrix::zeros(210, 210);
            symm_driver(&x, false, &x, false, &mut c, threads, &|_, _, v| v);
            for (p, q) in reference.data().iter().zip(c.data()) {
                assert_eq!(p.to_bits(), q.to_bits(), "width {threads} changed bits");
            }
        }
    }

    #[test]
    fn zigzag_is_a_permutation() {
        for n in [1usize, 2, 5, 8, 13] {
            let mut seen = vec![false; n];
            for idx in 0..n {
                let z = zigzag(idx, n);
                assert!(z < n && !seen[z], "n={n} idx={idx}");
                seen[z] = true;
            }
        }
    }

    // ------------------------------------------------------ f32 plane

    /// Reference: demote inputs, accumulate the dot in f64, round once.
    fn naive_nt_f32(a: &Matrix, b: &Matrix) -> Vec<f32> {
        let mut out = vec![0.0f32; a.rows() * b.rows()];
        for i in 0..a.rows() {
            for j in 0..b.rows() {
                let mut s = 0.0f64;
                for t in 0..a.cols() {
                    s += (a[(i, t)] as f32 as f64) * (b[(j, t)] as f32 as f64);
                }
                out[i * b.rows() + j] = s as f32;
            }
        }
        out
    }

    #[test]
    fn f32_nt_matches_f64_accumulated_reference_bitwise() {
        let mut rng = Rng::new(12);
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 2), (7, 4, 9), (33, 17, 29)] {
            let a = Matrix::randn(m, k, &mut rng);
            let b = Matrix::randn(n, k, &mut rng);
            let c = gemm_nt_map_f32(&a, &b, &|_, _, v| v);
            let r = naive_nt_f32(&a, &b);
            for (x, y) in c.data().iter().zip(&r) {
                assert_eq!(x.to_bits(), y.to_bits(), "{m}x{k}x{n}");
            }
        }
    }

    #[test]
    fn f32_simd_kernel_bit_identical_to_scalar() {
        // Whatever kernel the CPU selects must reproduce the scalar
        // fallback bit for bit (exact products, single rounding per add).
        let mut rng = Rng::new(13);
        let kern = select_microkernel_f32();
        for kk in [1usize, 3, 17, 256, 301] {
            let ap: Vec<f32> = (0..kk * MR).map(|_| rng.gaussian() as f32).collect();
            let bp: Vec<f32> = (0..kk * NR).map(|_| rng.gaussian() as f32).collect();
            let mut a0 = [[0.5f64; NR]; MR];
            let mut a1 = [[0.5f64; NR]; MR];
            microkernel_f32_scalar(&ap, &bp, &mut a0);
            unsafe { kern(&ap, &bp, &mut a1) };
            for r in 0..MR {
                for c in 0..NR {
                    assert_eq!(a0[r][c].to_bits(), a1[r][c].to_bits(), "k={kk} ({r},{c})");
                }
            }
        }
    }

    #[test]
    fn f32_bit_identical_across_thread_counts() {
        let mut rng = Rng::new(14);
        let a = Matrix::randn(200, 150, &mut rng);
        let b = Matrix::randn(180, 150, &mut rng);
        let mut reference = MatrixF32::zeros(200, 180);
        gemm_driver_f32(&a, &b, reference.data_mut(), 200, 180, 1, &|_, _, v| v);
        for threads in [2, 3, 8] {
            let mut c = MatrixF32::zeros(200, 180);
            gemm_driver_f32(&a, &b, c.data_mut(), 200, 180, threads, &|_, _, v| v);
            for (x, y) in reference.data().iter().zip(c.data()) {
                assert_eq!(x.to_bits(), y.to_bits(), "width {threads} changed bits");
            }
        }
    }

    #[test]
    fn f32_syrk_map_is_exactly_symmetric_and_close_to_f64() {
        let mut rng = Rng::new(15);
        let x = Matrix::randn(47, 9, &mut rng);
        let g32 = syrk_nt_map_f32(&x, &|_, _, d| (-0.5 * d).exp());
        let g64 = syrk_nt_map(&x, &|_, _, d| (-0.5 * d).exp());
        for i in 0..47 {
            for j in 0..47 {
                assert_eq!(
                    g32.row(i)[j].to_bits(),
                    g32.row(j)[i].to_bits(),
                    "asymmetry at ({i},{j})"
                );
                assert!(
                    (g32.row(i)[j] as f64 - g64[(i, j)]).abs() < 1e-4,
                    "f32 drifted at ({i},{j})"
                );
            }
        }
    }

    #[test]
    fn f32_symm_bit_identical_across_widths() {
        let mut rng = Rng::new(16);
        let x = Matrix::randn(210, 60, &mut rng);
        let mut reference = MatrixF32::zeros(210, 210);
        symm_driver_f32(&x, &x, &mut reference, 1, &|_, _, v| v);
        for threads in [2, 5, 8] {
            let mut c = MatrixF32::zeros(210, 210);
            symm_driver_f32(&x, &x, &mut c, threads, &|_, _, v| v);
            for (p, q) in reference.data().iter().zip(c.data()) {
                assert_eq!(p.to_bits(), q.to_bits(), "width {threads} changed bits");
            }
        }
    }
}
