//! Property-based tests (testkit substrate) over the paper's theorems and
//! the linear-algebra invariants they rest on.

use fastspsd::coordinator::oracle::DenseOracle;
use fastspsd::exec::{self, ExecPolicy};
use fastspsd::linalg::{eigh, pinv, svd_thin, Matrix};
use fastspsd::sketch;
use fastspsd::spsd::{self, adversarial, FastConfig};
use fastspsd::testkit::{assert_close, gen, Prop};
use fastspsd::util::Rng;

#[test]
fn prop_pinv_penrose_conditions() {
    Prop::new(24, 0xA11CE).check("pinv penrose", |rng| {
        let m = gen::int(rng, 1, 14);
        let n = gen::int(rng, 1, 14);
        let r = gen::int(rng, 1, m.min(n));
        let a = gen::low_rank(rng, m, n, r);
        let ap = pinv(&a);
        assert_close(&a.matmul(&ap).matmul(&a), &a, 1e-7, "A A† A")?;
        assert_close(&ap.matmul(&a).matmul(&ap), &ap, 1e-7, "A† A A†")?;
        let aap = a.matmul(&ap);
        assert_close(&aap, &aap.transpose(), 1e-8, "A A† sym")?;
        let apa = ap.matmul(&a);
        assert_close(&apa, &apa.transpose(), 1e-8, "A† A sym")
    });
}

#[test]
fn prop_svd_reconstruction_and_rank() {
    Prop::new(24, 0xBEEF).check("svd", |rng| {
        let m = gen::int(rng, 1, 16);
        let n = gen::int(rng, 1, 16);
        let r = gen::int(rng, 1, m.min(n));
        let a = gen::low_rank(rng, m, n, r);
        let f = svd_thin(&a);
        assert_close(&f.reconstruct(), &a, 1e-7, "recon")?;
        if f.rank(m, n) != r {
            return Err(format!("rank {} != {r}", f.rank(m, n)));
        }
        Ok(())
    });
}

#[test]
fn prop_eigh_reconstruction() {
    Prop::new(24, 0xCAFE).check("eigh", |rng| {
        let n = gen::int(rng, 1, 18);
        let mut a = gen::matrix(rng, n, n);
        a.symmetrize();
        let e = eigh(&a);
        assert_close(&e.reconstruct(), &a, 1e-7, "recon")?;
        for w in e.values.windows(2) {
            if w[0] < w[1] - 1e-10 {
                return Err("not descending".into());
            }
        }
        Ok(())
    });
}

#[test]
fn prop_theorem6_exact_recovery_iff_rank_match() {
    // rank(C) == rank(K)  =>  exact recovery (all sketching matrices);
    // rank(C) < rank(K)   =>  strictly positive error.
    Prop::new(16, 0x7E06).check("theorem 6", |rng| {
        let n = gen::int(rng, 20, 40);
        let r = gen::int(rng, 2, 6);
        let k = gen::spsd(rng, n, r);
        let o = DenseOracle::new(k.clone());
        // c >= r columns: rank(C) = rank(K) almost surely
        let c = r + gen::int(rng, 1, 4);
        let p = spsd::uniform_p(n, c, rng);
        let a = exec::fast(&o, &p, FastConfig::uniform(2 * c + 2), &ExecPolicy::Materialized, rng).result;
        let err = a.rel_fro_error(&k);
        if err > 1e-8 {
            return Err(format!("rank-match case: err {err}"));
        }
        // c < r columns: rank(C) < rank(K) → cannot be exact
        if r >= 3 {
            let p2 = spsd::uniform_p(n, r - 1, rng);
            let a2 = exec::fast(&o, &p2, FastConfig::uniform(3 * r), &ExecPolicy::Materialized, rng).result;
            let err2 = a2.rel_fro_error(&k);
            if err2 < 1e-12 {
                return Err("deficient C recovered exactly?!".into());
            }
        }
        Ok(())
    });
}

#[test]
fn prop_theorem3_fast_near_optimal_objective() {
    // With S = everything (s = n), the fast model equals the prototype's
    // optimal objective; with large s it should be within a modest factor.
    Prop::new(10, 0x7E03).check("theorem 3", |rng| {
        let n = gen::int(rng, 40, 70);
        // decaying spectrum
        let q = fastspsd::linalg::qr::qr_thin(&gen::matrix(rng, n, n)).q;
        let qd = Matrix::from_fn(n, n, |i, j| q[(i, j)] / ((j + 1) as f64).powi(2));
        let k = qd.matmul_tr(&q);
        let o = DenseOracle::new(k.clone());
        let c = 8;
        let p = spsd::uniform_p(n, c, rng);
        let opt = spsd::optimal_objective(&k, &o.inner().select_cols(&p));
        // s = n + c makes the union S = sample ∪ P cover every index, so
        // the fast model coincides with the prototype (S^T = I up to perm).
        let a = exec::fast(&o, &p, FastConfig::uniform(n + c), &ExecPolicy::Materialized, rng).result;
        let obj = k.sub(&a.materialize()).fro_norm_sq();
        if obj > opt * (1.0 + 1e-6) + 1e-12 {
            return Err(format!("s=n should be optimal: {obj} vs {opt}"));
        }
        let a2 = exec::fast(&o, &p, FastConfig::uniform(n / 2), &ExecPolicy::Materialized, rng).result;
        let obj2 = k.sub(&a2.materialize()).fro_norm_sq();
        if obj2 > opt * 3.0 + 1e-12 {
            return Err(format!("s=n/2 too far from optimal: {obj2} vs {opt}"));
        }
        Ok(())
    });
}

#[test]
fn theorem7_lower_bound_holds_on_adversarial_matrix() {
    // On K = diag(B..B) with a → 1, the measured fast-model error ratio
    // must respect the Theorem-7 lower bound (we use a < 1 so we allow a
    // small slack factor).
    let n = 60;
    let k = 3;
    let alpha = 0.999;
    let kmat = adversarial::block_diag(n, k, alpha);
    let o = DenseOracle::new(kmat.clone());
    let best_k = adversarial::best_rank_k_error_sq(n, k, alpha);
    let mut rng = Rng::new(0);
    for (c, s) in [(6usize, 12usize), (9, 18), (6, 30)] {
        let bound = adversarial::theorem7_lower_bound(n, k, c, s);
        let mut worst_ratio: f64 = f64::INFINITY;
        for t in 0..6 {
            let mut r = Rng::new(t);
            let p = spsd::uniform_p(n, c, &mut r);
            let a = exec::fast(&o, &p, FastConfig::uniform(s), &ExecPolicy::Materialized, &mut rng).result;
            let err = kmat.sub(&a.materialize()).fro_norm_sq();
            worst_ratio = worst_ratio.min(err / best_k);
        }
        // allow 10% slack for finite alpha and |S| randomness
        assert!(
            worst_ratio >= 0.90 * bound,
            "c={c} s={s}: measured ratio {worst_ratio:.3} < 0.90 * bound {bound:.3}"
        );
    }
}

#[test]
fn prop_sketch_apply_consistency() {
    // Every sketch family: apply_left(A) == materialize(S)^T A.
    Prop::new(12, 0x51E7).check("sketch ops", |rng| {
        let n = gen::int(rng, 8, 40);
        let d = gen::int(rng, 1, 6);
        let s = gen::int(rng, 2, n.max(3) - 1);
        let a = gen::matrix(rng, n, d);
        let c = gen::matrix(rng, n, 3);
        for kind in [
            sketch::SketchKind::Uniform,
            sketch::SketchKind::Leverage { scaled: true },
            sketch::SketchKind::Gaussian,
            sketch::SketchKind::Srht,
            sketch::SketchKind::CountSketch,
        ] {
            let op = sketch::build(kind, n, s, Some(&c), rng);
            let fastp = op.apply_left(&a);
            let dense = sketch::materialize(&op).tr_matmul(&a);
            assert_close(&fastp, &dense, 1e-8, kind.name())?;
        }
        Ok(())
    });
}

#[test]
fn prop_woodbury_solves_system() {
    Prop::new(16, 0x50_1E).check("woodbury", |rng| {
        let n = gen::int(rng, 10, 40);
        let c = gen::int(rng, 1, 8);
        let cm = gen::matrix(rng, n, c);
        let g = gen::matrix(rng, c, c);
        let u = g.matmul_tr(&g);
        let alpha = 0.1 + rng.f64();
        let y: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
        let w = fastspsd::linalg::solve::woodbury_solve(&cm, &u, alpha, &y);
        let mut kk = cm.matmul(&u).matmul_tr(&cm);
        for i in 0..n {
            kk[(i, i)] += alpha;
        }
        let resid: f64 = kk
            .matvec(&w)
            .iter()
            .zip(&y)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            .sqrt();
        if resid > 1e-6 {
            return Err(format!("residual {resid}"));
        }
        Ok(())
    });
}
