//! Blocked, threaded GEMM — the L3 hot path for sketch products.
//!
//! Strategy: pack the B panel transposed so the inner loop is two contiguous
//! slices (auto-vectorizes), block for L1/L2, and split the M dimension
//! across `std::thread::scope` workers when the problem is big enough to
//! amortize thread spawn. Tuning notes live in EXPERIMENTS.md §Perf.

use super::Matrix;

/// Number of worker threads for large products (0 = all cores).
fn thread_count(work: usize) -> usize {
    // Threshold chosen so small algebra (c x c) stays single-threaded.
    const PAR_THRESHOLD: usize = 1 << 21; // ~2M flops
    if work < PAR_THRESHOLD {
        return 1;
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// C = A * B.
pub fn gemm(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols(), b.rows(), "gemm dims: {}x{} * {}x{}", a.rows(), a.cols(), b.rows(), b.cols());
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    // Pack B^T so dot products run over contiguous rows of both operands.
    let bt = b.transpose();
    let mut c = Matrix::zeros(m, n);
    gemm_rows_nt(a, &bt, &mut c, m * n * k);
    c
}

/// C = A^T * B (A is k x m, result m x n) without materializing A^T.
pub fn gemm_tn(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.rows(), b.rows(), "gemm_tn dims");
    let at = a.transpose();
    let bt = b.transpose();
    let mut c = Matrix::zeros(a.cols(), b.cols());
    gemm_rows_nt(&at, &bt, &mut c, a.cols() * b.cols() * a.rows());
    c
}

/// C = A * B^T — both operands already row-major in the "right" layout.
pub fn gemm_nt(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols(), b.cols(), "gemm_nt dims");
    let mut c = Matrix::zeros(a.rows(), b.rows());
    gemm_rows_nt(a, b, &mut c, a.rows() * b.rows() * a.cols());
    c
}

/// Core: C[i, j] = sum_k A[i, k] * BT[j, k]; rows of C split across threads.
fn gemm_rows_nt(a: &Matrix, bt: &Matrix, c: &mut Matrix, work: usize) {
    let m = a.rows();
    let n = bt.rows();
    let k = a.cols();
    debug_assert_eq!(bt.cols(), k);
    let nthreads = thread_count(work).min(m.max(1));
    if nthreads <= 1 {
        let rows = c.data_mut();
        gemm_chunk(a, bt, rows, 0, m, n, k);
        return;
    }
    let chunk_rows = m.div_ceil(nthreads);
    let a_ref = &*a;
    let bt_ref = &*bt;
    let mut chunks: Vec<&mut [f64]> = c.data_mut().chunks_mut(chunk_rows * n).collect();
    std::thread::scope(|s| {
        for (t, chunk) in chunks.iter_mut().enumerate() {
            let r0 = t * chunk_rows;
            let r1 = (r0 + chunk.len() / n).min(m);
            let chunk: &mut [f64] = chunk;
            s.spawn(move || gemm_chunk(a_ref, bt_ref, chunk, r0, r1, n, k));
        }
    });
}

/// Compute rows [r0, r1) of C into `out` (which holds exactly those rows).
///
/// 2x4 register-blocked micro-kernel over (i, j) with a k-blocked outer
/// loop so the active B panel stays in L1/L2 at large k. Perf history in
/// EXPERIMENTS.md §Perf.
#[inline]
fn gemm_chunk(a: &Matrix, bt: &Matrix, out: &mut [f64], r0: usize, r1: usize, n: usize, k: usize) {
    const JB: usize = 4;
    const KB: usize = 256; // k-panel: 4 rows of B = 8 KiB ≪ L1
    for v in out.iter_mut() {
        *v = 0.0;
    }
    let mut k0 = 0;
    while k0 < k {
        let k1 = (k0 + KB).min(k);
        // Only sub-block j when the full B k-panel overflows L2 (~512 KiB);
        // otherwise the extra loop bookkeeping costs more than it saves.
        let jblk = if n * (k1 - k0) * 8 > 512 * 1024 { 64 } else { n };
        let mut jb0 = 0;
        while jb0 < n {
        let jb1 = (jb0 + jblk).min(n);
        let mut i = r0;
        // 2-row blocks of A amortize each B panel load across two outputs.
        while i + 2 <= r1 {
            let a0 = &a.row(i)[k0..k1];
            let a1 = &a.row(i + 1)[k0..k1];
            let (c0_all, c1_all) = out[(i - r0) * n..].split_at_mut(n);
            let c0 = &mut c0_all[..n];
            let c1 = &mut c1_all[..n];
            let mut j = jb0;
            while j + JB <= jb1 {
                let b0 = &bt.row(j)[k0..k1];
                let b1 = &bt.row(j + 1)[k0..k1];
                let b2 = &bt.row(j + 2)[k0..k1];
                let b3 = &bt.row(j + 3)[k0..k1];
                let (mut s00, mut s01, mut s02, mut s03) = (0.0f64, 0.0, 0.0, 0.0);
                let (mut s10, mut s11, mut s12, mut s13) = (0.0f64, 0.0, 0.0, 0.0);
                for t in 0..a0.len() {
                    let av0 = a0[t];
                    let av1 = a1[t];
                    s00 += av0 * b0[t];
                    s01 += av0 * b1[t];
                    s02 += av0 * b2[t];
                    s03 += av0 * b3[t];
                    s10 += av1 * b0[t];
                    s11 += av1 * b1[t];
                    s12 += av1 * b2[t];
                    s13 += av1 * b3[t];
                }
                c0[j] += s00;
                c0[j + 1] += s01;
                c0[j + 2] += s02;
                c0[j + 3] += s03;
                c1[j] += s10;
                c1[j + 1] += s11;
                c1[j + 2] += s12;
                c1[j + 3] += s13;
                j += JB;
            }
            while j < jb1 {
                let brow = &bt.row(j)[k0..k1];
                c0[j] += dot(a0, brow);
                c1[j] += dot(a1, brow);
                j += 1;
            }
            i += 2;
        }
        // remainder row
        while i < r1 {
            let arow = &a.row(i)[k0..k1];
            let crow = &mut out[(i - r0) * n..(i - r0 + 1) * n];
            let mut j = jb0;
            while j + JB <= jb1 {
                let b0 = &bt.row(j)[k0..k1];
                let b1 = &bt.row(j + 1)[k0..k1];
                let b2 = &bt.row(j + 2)[k0..k1];
                let b3 = &bt.row(j + 3)[k0..k1];
                let (mut s0, mut s1, mut s2, mut s3) = (0.0f64, 0.0, 0.0, 0.0);
                for t in 0..arow.len() {
                    let av = arow[t];
                    s0 += av * b0[t];
                    s1 += av * b1[t];
                    s2 += av * b2[t];
                    s3 += av * b3[t];
                }
                crow[j] += s0;
                crow[j + 1] += s1;
                crow[j + 2] += s2;
                crow[j + 3] += s3;
                j += JB;
            }
            while j < jb1 {
                crow[j] += dot(arow, &bt.row(j)[k0..k1]);
                j += 1;
            }
            i += 1;
        }
        jb0 = jb1;
        }
        k0 = k1;
    }
}

#[inline]
fn dot(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn naive(a: &Matrix, b: &Matrix) -> Matrix {
        let mut c = Matrix::zeros(a.rows(), b.cols());
        for i in 0..a.rows() {
            for j in 0..b.cols() {
                let mut s = 0.0;
                for kk in 0..a.cols() {
                    s += a[(i, kk)] * b[(kk, j)];
                }
                c[(i, j)] = s;
            }
        }
        c
    }

    #[test]
    fn matches_naive_small() {
        let mut rng = Rng::new(0);
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 2), (7, 4, 9), (16, 16, 16), (33, 17, 29)] {
            let a = Matrix::randn(m, k, &mut rng);
            let b = Matrix::randn(k, n, &mut rng);
            let c = gemm(&a, &b);
            assert!(c.max_abs_diff(&naive(&a, &b)) < 1e-10, "{}x{}x{}", m, k, n);
        }
    }

    #[test]
    fn matches_naive_threaded_size() {
        let mut rng = Rng::new(1);
        let a = Matrix::randn(200, 150, &mut rng);
        let b = Matrix::randn(150, 180, &mut rng);
        let c = gemm(&a, &b);
        assert!(c.max_abs_diff(&naive(&a, &b)) < 1e-9);
    }

    #[test]
    fn tn_and_nt_variants() {
        let mut rng = Rng::new(2);
        let a = Matrix::randn(20, 30, &mut rng);
        let b = Matrix::randn(20, 25, &mut rng);
        let c = gemm_tn(&a, &b); // 30 x 25
        assert!(c.max_abs_diff(&naive(&a.transpose(), &b)) < 1e-10);
        let d = Matrix::randn(15, 30, &mut rng);
        let e = gemm_nt(&a, &d); // 20 x 15
        assert!(e.max_abs_diff(&naive(&a, &d.transpose())) < 1e-10);
    }

    #[test]
    fn identity_is_neutral() {
        let mut rng = Rng::new(3);
        let a = Matrix::randn(10, 10, &mut rng);
        assert!(gemm(&a, &Matrix::identity(10)).max_abs_diff(&a) < 1e-12);
        assert!(gemm(&Matrix::identity(10), &a).max_abs_diff(&a) < 1e-12);
    }

    #[test]
    #[should_panic]
    fn dim_mismatch_panics() {
        gemm(&Matrix::zeros(2, 3), &Matrix::zeros(4, 2));
    }
}
