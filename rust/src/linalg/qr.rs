//! Thin Householder QR: `A (m x n, m >= n) = Q (m x n) R (n x n)`.
//!
//! Used for orthonormal bases of sketches (Algorithm 1 step 3) and inside
//! the pseudo-inverse fallbacks. Column pivoting is not needed for the
//! paper's algorithms; rank deficiency is handled downstream by the SVD.

use super::Matrix;

/// Thin QR factorization result.
pub struct QrThin {
    /// m x n with orthonormal columns (spanning col(A) when A has full rank).
    pub q: Matrix,
    /// n x n upper triangular.
    pub r: Matrix,
}

/// Compute the thin QR of `a` (requires `m >= n`).
pub fn qr_thin(a: &Matrix) -> QrThin {
    let (m, n) = (a.rows(), a.cols());
    assert!(m >= n, "qr_thin needs m >= n, got {m}x{n}");
    let mut r = a.clone(); // will be reduced in place
    // Householder vectors stored per column.
    let mut vs: Vec<Vec<f64>> = Vec::with_capacity(n);
    for k in 0..n {
        // norm of column k below (and including) row k
        let mut alpha = 0.0;
        for i in k..m {
            alpha += r[(i, k)] * r[(i, k)];
        }
        alpha = alpha.sqrt();
        if r[(k, k)] > 0.0 {
            alpha = -alpha;
        }
        let mut v = vec![0.0; m - k];
        if alpha == 0.0 {
            // zero column: identity reflector
            vs.push(v);
            continue;
        }
        v[0] = r[(k, k)] - alpha;
        for i in (k + 1)..m {
            v[i - k] = r[(i, k)];
        }
        let vnorm_sq: f64 = v.iter().map(|x| x * x).sum();
        if vnorm_sq == 0.0 {
            vs.push(v);
            continue;
        }
        // apply reflector H = I - 2 v v^T / (v^T v) to R[k.., k..]
        for j in k..n {
            let mut dot = 0.0;
            for i in k..m {
                dot += v[i - k] * r[(i, j)];
            }
            let scale = 2.0 * dot / vnorm_sq;
            for i in k..m {
                r[(i, j)] -= scale * v[i - k];
            }
        }
        vs.push(v);
    }
    // Build thin Q by applying reflectors to the first n columns of I.
    let mut q = Matrix::zeros(m, n);
    for j in 0..n {
        q[(j, j)] = 1.0;
    }
    for k in (0..n).rev() {
        let v = &vs[k];
        let vnorm_sq: f64 = v.iter().map(|x| x * x).sum();
        if vnorm_sq == 0.0 {
            continue;
        }
        for j in 0..n {
            let mut dot = 0.0;
            for i in k..m {
                dot += v[i - k] * q[(i, j)];
            }
            let scale = 2.0 * dot / vnorm_sq;
            for i in k..m {
                q[(i, j)] -= scale * v[i - k];
            }
        }
    }
    // Zero the strictly-lower part of R (numerical dust) and truncate.
    let mut r_out = Matrix::zeros(n, n);
    for i in 0..n {
        for j in i..n {
            r_out[(i, j)] = r[(i, j)];
        }
    }
    QrThin { q, r: r_out }
}

/// Orthonormal basis of col(A): thin-QR Q with near-zero columns dropped
/// when A is rank deficient (detected via |R[i,i]|).
pub fn orthonormal_basis(a: &Matrix, tol_rel: f64) -> Matrix {
    let f = qr_thin(a);
    let n = f.r.rows();
    let rmax = (0..n).map(|i| f.r[(i, i)].abs()).fold(0.0, f64::max);
    if rmax == 0.0 {
        return Matrix::zeros(a.rows(), 0);
    }
    let keep: Vec<usize> = (0..n).filter(|&i| f.r[(i, i)].abs() > tol_rel * rmax).collect();
    f.q.select_cols(&keep)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn reconstructs_and_orthonormal() {
        let mut rng = Rng::new(0);
        for &(m, n) in &[(5, 5), (10, 4), (40, 17), (3, 1)] {
            let a = Matrix::randn(m, n, &mut rng);
            let f = qr_thin(&a);
            let qr = f.q.matmul(&f.r);
            assert!(qr.max_abs_diff(&a) < 1e-9, "{m}x{n} recon");
            let qtq = f.q.tr_matmul(&f.q);
            assert!(qtq.max_abs_diff(&Matrix::identity(n)) < 1e-9, "{m}x{n} ortho");
            // R upper triangular
            for i in 0..n {
                for j in 0..i {
                    assert_eq!(f.r[(i, j)], 0.0);
                }
            }
        }
    }

    #[test]
    fn rank_deficient_basis() {
        let mut rng = Rng::new(1);
        let b = Matrix::randn(20, 3, &mut rng);
        let c = Matrix::randn(3, 7, &mut rng);
        let a = b.matmul(&c); // rank 3, 20x7
        let q = orthonormal_basis(&a, 1e-10);
        assert_eq!(q.cols(), 3);
        let qtq = q.tr_matmul(&q);
        assert!(qtq.max_abs_diff(&Matrix::identity(3)) < 1e-9);
        // Projection Q Q^T A == A
        let proj = q.matmul(&q.tr_matmul(&a));
        assert!(proj.max_abs_diff(&a) < 1e-8);
    }

    #[test]
    fn zero_matrix_basis_is_empty() {
        let q = orthonormal_basis(&Matrix::zeros(5, 3), 1e-12);
        assert_eq!(q.cols(), 0);
    }
}
