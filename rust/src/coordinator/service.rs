//! The approximation service: the Layer-3 request loop, now with a
//! degrade-don't-die admission path.
//!
//! Clients submit [`ApproxRequest`]s (which model, c, downstream task
//! size k, and optionally an [`ExecPolicy`] — the planner fills the
//! default); the service routes them to a worker pool with a bounded
//! queue (backpressure), each worker builds the approximation against the
//! shared kernel oracle through the unified [`exec`](crate::exec)
//! surface, and replies with eigenvalues plus the run's [`RunMeta`]
//! accounting.
//!
//! ## Admission under a memory cap
//!
//! The service meters the **predicted working set of in-flight requests**
//! (`Metrics::mem_in_use`, the sum of `predicted_peak_bytes`). With a
//! [`ServiceConfig::memory_cap`] set, an over-cap request is no longer
//! shed — it takes the degrade-don't-die path:
//!
//! 1. **Queue**: requests that fit the cap but not the current headroom
//!    wait in a bounded FIFO ([`ServiceConfig::admission_capacity`]) with
//!    a per-request deadline; a reaper thread admits from the head as
//!    in-flight reservations drain and expires entries whose deadline
//!    passes with a typed [`ServiceError::Overloaded`] reply carrying a
//!    `retry_after` hint.
//! 2. **Degrade**: under pressure (queue depth ≥
//!    [`ServiceConfig::degrade_queue_depth`], half the deadline burnt, or
//!    a request that can never fit the cap as asked) admission walks the
//!    request's [`planner::degrade_ladder`] — cheaper policy, uniform
//!    instead of leverage sampling, smaller `c`/`s` — and serves the
//!    first rung that fits. The response records the rung in
//!    [`ApproxResponse::degraded`] (mirrored in `meta.degraded`), so
//!    accuracy is traded *visibly*, never silently.
//! 3. **Reject**: only when the queue is full or no rung of the ladder
//!    can ever fit the cap does the service reply `Overloaded`.
//!
//! ## Fault isolation
//!
//! Worker jobs run under `catch_unwind`: a panicking build (a poisoned
//! request, an injected oracle fault) is isolated — the reply is a typed
//! [`ServiceError::Faulted`], the memory reservation is released, spill
//! arenas are cleaned by their guards, and the worker keeps serving.
//! Shutdown replies [`ServiceError::Stopping`] to everything still
//! queued instead of dropping reply channels.

use super::metrics::Metrics;
use super::oracle::KernelOracle;
use super::planner;
use crate::cur::{self, FastCurConfig};
use crate::exec::{self, DegradeInfo, ExecPolicy, RunMeta};
use crate::linalg::{guard, svd_thin, NumericHealth};
use crate::obs::{self, sink, Stage, StageProfile};
use crate::pool::ThreadPool;
use crate::spsd::{self, FastConfig, LeverageBasis};
use crate::stream::{checkpoint, CheckpointConfig, Precision};
use crate::util::Rng;
use std::cell::Cell;
use std::collections::VecDeque;
use std::fmt;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

pub use super::planner::MethodSpec;

/// One approximation job.
#[derive(Debug, Clone)]
pub struct ApproxRequest {
    pub id: u64,
    pub method: MethodSpec,
    /// sketch size c (columns of C).
    pub c: usize,
    /// downstream top-k eigenpairs to return.
    pub k: usize,
    pub seed: u64,
    /// How to traverse the kernel (`None` = the planner's default,
    /// [`planner::default_policy`]). Spilling
    /// [`Resident`](ExecPolicy::Resident) policies inherit the service's
    /// spill directory unless they pin their own.
    pub policy: Option<ExecPolicy>,
    /// Element width the build streams its tiles at. The default `F64` is
    /// bit-compatible with every pre-precision client; `F32` halves the
    /// streamed/spilled tile bytes (outputs, solves, and fold state stay
    /// f64). Applied on top of `policy` — a policy that already narrowed
    /// itself via [`ExecPolicy::with_precision`] is left alone.
    pub precision: Precision,
    /// How long this request may wait in the admission queue before the
    /// reaper expires it (`None` = [`ServiceConfig::default_deadline`]).
    pub deadline: Option<Duration>,
}

/// Why a request was not served.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServiceError {
    /// The service is over capacity: the admission queue was full, the
    /// request's deadline expired while queued, or no rung of the degrade
    /// ladder fits the memory cap. `retry_after` is the service's current
    /// mean latency — a reasonable backoff hint.
    Overloaded { retry_after: Duration },
    /// The service is shutting down; queued requests are flushed with
    /// this reply instead of having their channels dropped.
    Stopping,
    /// The build failed or panicked; the worker survived, the reservation
    /// was released, and this request alone failed.
    Faulted(String),
}

impl fmt::Display for ServiceError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServiceError::Overloaded { retry_after } => {
                write!(f, "overloaded (retry after {retry_after:?})")
            }
            ServiceError::Stopping => write!(f, "service stopping"),
            ServiceError::Faulted(msg) => write!(f, "faulted: {msg}"),
        }
    }
}

/// Reply for one job.
#[derive(Debug, Clone)]
pub struct ApproxResponse {
    pub id: u64,
    /// The method that actually served the request (differs from the
    /// requested one when the degrade ladder relaxed it).
    pub method: String,
    /// top-k eigenvalues of C U C^T (for `Cur`: top singular values of
    /// the core U).
    pub eigvals: Vec<f64>,
    /// `(rows, cols)` of the CUR core U (only for `Cur` requests).
    pub core_dims: Option<(usize, usize)>,
    /// seconds from submit to completion.
    pub total_secs: f64,
    /// The run's uniform accounting (entries, compute seconds, residency
    /// counters, predicted peak bytes). `None` only on unserved requests.
    /// `meta.entries` is a delta read off the oracle's single shared
    /// counter, so with multiple workers a request's figure can absorb
    /// entries from builds that overlap it (exact on a 1-worker service).
    pub meta: Option<RunMeta>,
    /// Which rung of the degrade ladder served this request (`None` =
    /// served exactly as asked). Also present in `meta.degraded`.
    pub degraded: Option<DegradeInfo>,
    /// Element width the build actually streamed at (mirrors
    /// `meta.precision`; `F64` on unserved requests). Differs from the
    /// requested width only when the degrade ladder lowered it — which
    /// `degraded` then records as
    /// [`DegradeAction::PrecisionLowered`](crate::exec::DegradeAction::PrecisionLowered).
    pub precision: Precision,
    /// Seconds this request waited in the admission queue before a
    /// worker picked it up (0 for requests never dispatched).
    pub queue_wait_secs: f64,
    /// Seconds admission spent walking this request's degrade ladder,
    /// summed over every attempt (0 when rung 0 reserved directly).
    pub ladder_secs: f64,
    /// Numeric integrity of the served build (mirrors
    /// `meta.numeric_health`, folding in health observed by failed
    /// attempts of a retried request): worst core condition estimate,
    /// strongest regularization, quarantined tiles, corrupt spill reads.
    /// A finally-Faulted reply still carries what its attempts observed;
    /// `None` when no build ran (rejected/expired/stopping) or a failed
    /// build observed nothing noteworthy.
    pub numeric_health: Option<NumericHealth>,
    /// This request rode a shared stream pass: it was coalesced with at
    /// least one identical same-oracle request (same method, sizes, seed,
    /// policy), so the kernel was charged once for the whole batch. True
    /// on the batch leader and every rider; riders' `meta` is a clone of
    /// the leader's run accounting.
    pub batched: bool,
    /// Why the request was not served (`None` on success).
    pub error: Option<ServiceError>,
}

/// Service configuration.
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    pub workers: usize,
    /// max queued jobs before `submit` blocks (backpressure).
    pub queue_capacity: usize,
    /// Directory for residency spill arenas (`None` = the system temp
    /// dir). Arena files are per-request and removed when the build ends.
    pub spill_dir: Option<PathBuf>,
    /// Service-level memory cap in bytes: requests whose predicted peak
    /// does not fit the in-flight sum (`Metrics::mem_in_use`) wait in the
    /// admission queue and may be served degraded; only requests no
    /// ladder rung can ever fit — or that find the queue full — are
    /// rejected. `None` = meter but always admit.
    pub memory_cap: Option<u64>,
    /// Bound of the admission FIFO (`Overloaded` beyond it).
    pub admission_capacity: usize,
    /// Deadline for queued requests that carry none of their own.
    pub default_deadline: Duration,
    /// Queue depth at (or above) which admission starts walking the
    /// degrade ladder for requests that would otherwise keep waiting.
    pub degrade_queue_depth: usize,
    /// Directory to write one Chrome `trace_event` JSON file per served
    /// request into (`trace-req-<id>.json`, loadable in `about:tracing`
    /// or Perfetto). Setting it installs the span recorder
    /// ([`obs::ensure_installed`]). `Default` reads the `FASTSPSD_TRACE`
    /// environment variable; `None` = no trace files (spans still feed
    /// `RunMeta::stage_profile` whenever the recorder is installed).
    pub trace_dir: Option<PathBuf>,
    /// Extra worker-side attempts for a build that panics or fails
    /// (default 0 = fail fast, the pre-retry behavior). With retries
    /// enabled each request gets a private checkpoint directory under
    /// `spill_dir` (or the system temp dir): streaming folds persist
    /// their state every [`checkpoint::DEFAULT_CKPT_EVERY`] tiles
    /// (`FASTSPSD_CKPT_EVERY` overrides), and a retried attempt resumes
    /// from the last checkpoint instead of re-charging the oracle for
    /// tiles already folded — bit-identically. `metrics.faulted` /
    /// `metrics.failed` count per *attempt*; a request that recovers on
    /// a retry still counts once in `metrics.completed`, and the health
    /// its failed attempts observed is merged into the reply's
    /// [`ApproxResponse::numeric_health`].
    pub retry_faulted: usize,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            workers: 4,
            queue_capacity: 64,
            spill_dir: None,
            memory_cap: None,
            admission_capacity: 64,
            default_deadline: Duration::from_secs(30),
            degrade_queue_depth: 4,
            trace_dir: std::env::var_os("FASTSPSD_TRACE")
                .filter(|v| !v.is_empty())
                .map(PathBuf::from),
            retry_faulted: 0,
        }
    }
}

/// How an admitted request will actually run: the (possibly degraded)
/// method/size/policy plus the reservation it holds.
#[derive(Clone)]
struct ServeAs {
    method: MethodSpec,
    c: usize,
    policy: ExecPolicy,
    predicted: u64,
    degraded: Option<DegradeInfo>,
}

/// A request waiting in the admission FIFO.
struct QueuedJob {
    req: ApproxRequest,
    rung0: ServeAs,
    /// Precomputed degrade ladder (best rung first).
    ladder: Vec<ServeAs>,
    /// Whether rung 0 fits the cap on an empty meter (a request that can
    /// never fit as asked goes straight to the ladder).
    fits_alone: bool,
    reply: mpsc::Sender<ApproxResponse>,
    enqueued: Instant,
    deadline: Instant,
    /// Raw [`obs`] trace id for this request (0 = recorder off).
    trace: u64,
    /// Enqueue time on the trace clock, for the manual
    /// `admission.queue` span (0 when untraced).
    enqueue_ns: u64,
    /// Nanoseconds spent walking the degrade ladder for this job,
    /// accumulated across admission attempts (reaper + submit threads,
    /// serialized by the queue lock).
    ladder_ns: Cell<u64>,
}

/// State shared by the service handle, the reaper thread, and workers.
struct Shared {
    oracle: Arc<dyn KernelOracle + Send + Sync>,
    pool: ThreadPool,
    metrics: Arc<Metrics>,
    inflight: AtomicU64,
    spill_dir: Option<PathBuf>,
    memory_cap: Option<u64>,
    admission_capacity: usize,
    default_deadline: Duration,
    degrade_queue_depth: usize,
    trace_dir: Option<PathBuf>,
    retry_faulted: usize,
    stopping: AtomicBool,
    queue: Mutex<VecDeque<QueuedJob>>,
    /// Woken when headroom opens (a reservation drops), when a job is
    /// enqueued, and on shutdown. The reaper also polls every 50ms as a
    /// backstop, so a missed wakeup only delays admission.
    queue_cv: Condvar,
    /// Jobs popped from the queue but not yet handed to the pool (drain
    /// must not declare idle while one is in this window).
    dispatching: AtomicU64,
}

/// The running service. Dropping it shuts down: queued requests get
/// [`ServiceError::Stopping`] replies, in-flight work completes, and the
/// reaper thread is joined.
pub struct ApproxService {
    shared: Arc<Shared>,
    reaper: Option<JoinHandle<()>>,
}

impl ApproxService {
    pub fn new(oracle: Arc<dyn KernelOracle + Send + Sync>, cfg: ServiceConfig) -> Self {
        if let Some(dir) = &cfg.trace_dir {
            obs::ensure_installed();
            let _ = std::fs::create_dir_all(dir);
        }
        let shared = Arc::new(Shared {
            oracle,
            pool: ThreadPool::new(cfg.workers.max(1), cfg.queue_capacity.max(1)),
            metrics: Arc::new(Metrics::default()),
            inflight: AtomicU64::new(0),
            spill_dir: cfg.spill_dir,
            memory_cap: cfg.memory_cap,
            admission_capacity: cfg.admission_capacity.max(1),
            default_deadline: cfg.default_deadline,
            degrade_queue_depth: cfg.degrade_queue_depth.max(1),
            trace_dir: cfg.trace_dir,
            retry_faulted: cfg.retry_faulted,
            stopping: AtomicBool::new(false),
            queue: Mutex::new(VecDeque::new()),
            queue_cv: Condvar::new(),
            dispatching: AtomicU64::new(0),
        });
        let for_reaper = Arc::clone(&shared);
        let reaper = std::thread::Builder::new()
            .name("fastspsd-reaper".into())
            .spawn(move || reaper_loop(for_reaper))
            .ok();
        ApproxService { shared, reaper }
    }

    pub fn metrics(&self) -> &Metrics {
        &self.shared.metrics
    }

    pub fn inflight(&self) -> u64 {
        self.shared.inflight.load(Ordering::SeqCst)
    }

    /// Submit a job; the response is delivered on `reply`.
    ///
    /// Requests that fit the meter dispatch immediately (blocking only on
    /// worker-queue backpressure). Over-headroom requests queue (FIFO,
    /// bounded, deadline-reaped) and may be served down the degrade
    /// ladder; see the module docs for the full admission contract.
    pub fn submit(&self, req: ApproxRequest, reply: mpsc::Sender<ApproxResponse>) {
        let s = &self.shared;
        s.metrics.requests.inc();
        if s.stopping.load(Ordering::SeqCst) {
            let _ = reply.send(error_response(req.id, req.method.name(), ServiceError::Stopping));
            return;
        }
        // One trace per request: planning below is tagged through the
        // scope; the worker re-establishes the id on its own thread.
        let trace = if obs::installed() { obs::TraceId::mint().raw() } else { 0 };
        let _tscope = obs::trace_scope(trace);
        let n = s.oracle.n();
        let c = req.c.clamp(1, n.max(1));
        let mut policy = req.policy.clone().unwrap_or_else(planner::default_policy);
        inherit_spill_dir(&mut policy, &s.spill_dir);
        if req.precision == Precision::F32 {
            policy = policy.with_precision(Precision::F32);
        }
        let predicted = planner::predicted_policy_peak_bytes(n, c, &req.method, &policy);
        let rung0 =
            ServeAs { method: req.method, c, policy: policy.clone(), predicted, degraded: None };
        let ladder: Vec<ServeAs> = planner::degrade_ladder(n, req.k, &req.method, c, &policy)
            .into_iter()
            .map(|d| ServeAs {
                method: d.method,
                c: d.c,
                policy: d.policy,
                predicted: d.predicted_peak_bytes,
                degraded: Some(d.info),
            })
            .collect();
        let fits_alone = s.memory_cap.map_or(true, |cap| predicted <= cap);
        let admissible_ever = fits_alone
            || s.memory_cap.map_or(true, |cap| ladder.iter().any(|r| r.predicted <= cap));
        let now = Instant::now();
        let deadline = now + req.deadline.unwrap_or(s.default_deadline);
        let job = QueuedJob {
            req,
            rung0,
            ladder,
            fits_alone,
            reply,
            enqueued: now,
            deadline,
            trace,
            enqueue_ns: if trace != 0 { obs::now_ns() } else { 0 },
            ladder_ns: Cell::new(0),
        };

        let mut q = s.queue.lock().unwrap();
        if q.is_empty() {
            // Fast path: nothing is waiting, so FIFO order allows serving
            // this request right now if a reservation succeeds (walking
            // the ladder immediately only when it can never fit as asked).
            if let Some(serve) = try_admit(s, &job, false) {
                drop(q);
                dispatch(s, job, serve);
                return;
            }
            if !admissible_ever {
                drop(q);
                s.metrics.rejected_overload.inc();
                let err = ServiceError::Overloaded { retry_after: retry_hint(s) };
                let _ = job.reply.send(error_response(job.req.id, job.req.method.name(), err));
                discard_trace(job.trace);
                return;
            }
        }
        if q.len() >= s.admission_capacity {
            drop(q);
            s.metrics.rejected_overload.inc();
            let err = ServiceError::Overloaded { retry_after: retry_hint(s) };
            let _ = job.reply.send(error_response(job.req.id, job.req.method.name(), err));
            discard_trace(job.trace);
            return;
        }
        s.metrics.queued.inc();
        q.push_back(job);
        drop(q);
        s.queue_cv.notify_all();
    }

    /// Wait until every submitted request has been resolved: the
    /// admission queue is empty (served, degraded, or reaped) and all
    /// dispatched work has finished.
    pub fn drain(&self) {
        let s = &self.shared;
        loop {
            {
                let mut q = s.queue.lock().unwrap();
                while !q.is_empty() {
                    q = s.queue_cv.wait_timeout(q, Duration::from_millis(20)).unwrap().0;
                }
            }
            while s.dispatching.load(Ordering::SeqCst) > 0 {
                std::thread::yield_now();
            }
            s.pool.wait_idle();
            // A finishing job may have let the reaper admit more work
            // between our checks; only an all-clear snapshot ends drain.
            let q = s.queue.lock().unwrap();
            if q.is_empty()
                && s.dispatching.load(Ordering::SeqCst) == 0
                && s.inflight.load(Ordering::SeqCst) == 0
            {
                return;
            }
        }
    }

    /// Stop admitting: flush the queue with [`ServiceError::Stopping`]
    /// replies, then wait for in-flight work to finish. Idempotent; also
    /// runs on drop.
    pub fn shutdown(&self) {
        let s = &self.shared;
        s.stopping.store(true, Ordering::SeqCst);
        {
            let mut q = s.queue.lock().unwrap();
            while let Some(job) = q.pop_front() {
                let _ = job
                    .reply
                    .send(error_response(job.req.id, job.req.method.name(), ServiceError::Stopping));
                discard_trace(job.trace);
            }
        }
        s.queue_cv.notify_all();
        while s.dispatching.load(Ordering::SeqCst) > 0 {
            std::thread::yield_now();
        }
        s.pool.wait_idle();
    }
}

impl Drop for ApproxService {
    fn drop(&mut self) {
        self.shutdown();
        if let Some(h) = self.reaper.take() {
            let _ = h.join();
        }
    }
}

/// Try to reserve memory for `job`: rung 0 first; the degrade ladder only
/// under `pressure` or when rung 0 can never fit the cap. Ladder walks
/// are recorded as `degrade.ladder` spans on the job's trace and
/// accumulated into its `ladder_ns` (reported as
/// [`ApproxResponse::ladder_secs`]).
fn try_admit(s: &Shared, job: &QueuedJob, pressure: bool) -> Option<ServeAs> {
    if reserve(s, job.rung0.predicted) {
        return Some(job.rung0.clone());
    }
    let walk_ladder = pressure || !job.fits_alone;
    if !walk_ladder {
        return None;
    }
    let t0 = (job.trace != 0).then(obs::now_ns);
    let mut admitted = None;
    for rung in &job.ladder {
        if reserve(s, rung.predicted) {
            admitted = Some(rung.clone());
            break;
        }
    }
    if let Some(t0) = t0 {
        let dur = obs::now_ns().saturating_sub(t0);
        job.ladder_ns.set(job.ladder_ns.get() + dur);
        obs::record_manual(Stage::DegradeLadder, job.trace, t0, dur);
    }
    admitted
}

/// Fill the service's spill directory into every spilling
/// [`Resident`](ExecPolicy::Resident) policy in this (possibly
/// `Sharded`-wrapped) policy tree that has not pinned its own.
fn inherit_spill_dir(policy: &mut ExecPolicy, dir: &Option<PathBuf>) {
    match policy {
        ExecPolicy::Resident { spill: true, spill_dir, .. } if spill_dir.is_none() => {
            *spill_dir = dir.clone();
        }
        ExecPolicy::Sharded { inner, .. } => inherit_spill_dir(inner, dir),
        _ => {}
    }
}

/// Drop the spans of a trace that will never reach a worker (rejected,
/// expired, or flushed at shutdown) so the central store cannot
/// accumulate orphaned records.
fn discard_trace(trace: u64) {
    if trace != 0 {
        let _ = obs::drain_trace(trace);
    }
}

/// Check-and-reserve against the memory cap (always succeeds uncapped —
/// the gauge still meters).
fn reserve(s: &Shared, predicted: u64) -> bool {
    match s.memory_cap {
        Some(cap) => s.metrics.mem_in_use.try_add_below(predicted, cap),
        None => {
            s.metrics.mem_in_use.add(predicted);
            true
        }
    }
}

/// Backoff hint for `Overloaded` replies: the observed mean latency, or
/// 100ms before any request has completed.
fn retry_hint(s: &Shared) -> Duration {
    let m = s.metrics.latency.mean();
    if m.is_zero() {
        Duration::from_millis(100)
    } else {
        m
    }
}

fn error_response(id: u64, method: String, error: ServiceError) -> ApproxResponse {
    ApproxResponse {
        id,
        method,
        eigvals: Vec::new(),
        core_dims: None,
        total_secs: 0.0,
        meta: None,
        degraded: None,
        precision: Precision::F64,
        queue_wait_secs: 0.0,
        ladder_secs: 0.0,
        numeric_health: None,
        batched: false,
        error: Some(error),
    }
}

/// The admission reaper: expires queued requests past their deadline and
/// admits from the head (FIFO — no skipping) as headroom opens. The only
/// thread that dispatches queued work, so a reservation-guard drop never
/// recursively runs a build.
fn reaper_loop(s: Arc<Shared>) {
    let mut q = s.queue.lock().unwrap();
    loop {
        if s.stopping.load(Ordering::SeqCst) {
            return; // shutdown flushes the queue itself
        }
        let now = Instant::now();
        // 1) expire timed-out entries (anywhere in the queue)
        let mut i = 0;
        while i < q.len() {
            if q[i].deadline <= now {
                let job = q.remove(i).unwrap();
                s.metrics.expired_deadline.inc();
                let err = ServiceError::Overloaded { retry_after: retry_hint(&s) };
                let _ = job.reply.send(error_response(job.req.id, job.req.method.name(), err));
                discard_trace(job.trace);
            } else {
                i += 1;
            }
        }
        // 2) admit from the head while reservations succeed
        while let Some(head) = q.front() {
            let depth_pressure = q.len() >= s.degrade_queue_depth;
            let waited = now.saturating_duration_since(head.enqueued);
            let budget = head.deadline.saturating_duration_since(head.enqueued);
            let wait_pressure = waited * 2 >= budget;
            match try_admit(&s, head, depth_pressure || wait_pressure) {
                Some(serve) => {
                    let job = q.pop_front().unwrap();
                    s.dispatching.fetch_add(1, Ordering::SeqCst);
                    drop(q); // pool.submit may block on backpressure
                    dispatch(&s, job, serve);
                    s.dispatching.fetch_sub(1, Ordering::SeqCst);
                    s.queue_cv.notify_all(); // drain() watches the queue
                    q = s.queue.lock().unwrap();
                }
                None => break, // head blocked: keep FIFO, wait for headroom
            }
        }
        // 3) sleep until the next deadline, a notify, or the poll backstop
        let poll = Duration::from_millis(50);
        let timeout = q
            .iter()
            .map(|j| j.deadline.saturating_duration_since(Instant::now()))
            .min()
            .unwrap_or(poll)
            .clamp(Duration::from_millis(1), poll);
        q = s.queue_cv.wait_timeout(q, timeout).unwrap().0;
    }
}

/// Two requests the service may serve with one stream pass: everything
/// that determines the computed result must match — method, sizes, seed,
/// tile element width, and the requested traversal policy. (`k` shapes
/// the reply's eigenvalue count, so it is part of the key.)
fn coalescable(a: &ApproxRequest, b: &ApproxRequest) -> bool {
    a.method == b.method
        && a.c == b.c
        && a.k == b.k
        && a.seed == b.seed
        && a.precision == b.precision
        && a.policy == b.policy
}

/// Hand an admitted job (holding its reservation) to the worker pool.
///
/// Same-oracle coalescing happens here: a leader admitted at rung 0 (not
/// degraded — riders must get exactly what they asked for) sweeps the
/// admission queue for identical unexpired requests and carries them as
/// riders. The batch runs ONE build — K tenants charge the oracle one
/// `n·c` — and every rider's reply is a clone of the leader's with its
/// own id/queue accounting and `batched = true`. Riders never held a
/// memory reservation (they were queued), so nothing extra is released.
fn dispatch(s: &Arc<Shared>, job: QueuedJob, serve: ServeAs) {
    let riders: Vec<QueuedJob> = if serve.degraded.is_none() {
        let mut q = s.queue.lock().unwrap();
        let now = Instant::now();
        let mut riders = Vec::new();
        let mut i = 0;
        while i < q.len() {
            if q[i].deadline > now && coalescable(&job.req, &q[i].req) {
                riders.push(q.remove(i).unwrap());
            } else {
                i += 1;
            }
        }
        riders
    } else {
        Vec::new()
    };
    s.metrics.batch_occupancy.observe(1 + riders.len() as u64);
    if !riders.is_empty() {
        s.metrics.coalesced_requests.add(riders.len() as u64);
        s.queue_cv.notify_all(); // drain() watches the queue shrink
    }
    s.inflight.fetch_add(1, Ordering::SeqCst);
    let shared = Arc::clone(s);
    let QueuedJob { req, reply, enqueued: submitted, trace, enqueue_ns, ladder_ns, .. } = job;
    let ladder_ns = ladder_ns.get();
    s.pool.submit(move || {
        // Release the admission reservation on every exit path — including
        // the catch_unwind's — and wake the reaper so queued work can take
        // the freed headroom.
        let _guard = ReservationGuard { shared: Arc::clone(&shared), predicted: serve.predicted };
        let started = Instant::now();
        let queue_wait = started.duration_since(submitted);
        shared.metrics.queue_wait.observe(queue_wait);
        // Re-establish the request's trace on this worker and backfill
        // the queue wait as a manual span (no thread held a guard open
        // across the submit → dispatch hop).
        let _tscope = obs::trace_scope(trace);
        if trace != 0 {
            let waited = obs::now_ns().saturating_sub(enqueue_ns);
            obs::record_manual(Stage::AdmissionQueue, trace, enqueue_ns, waited);
        }
        // With retries enabled, arm per-request checkpointing: every
        // attempt (first included) runs under the same private directory,
        // so a retried attempt's pass k restores the fold state attempt
        // k-1 persisted and re-charges the oracle only for tiles after
        // the checkpoint. Health observed by failed attempts (quarantined
        // tiles, escalations the aborted Scope never drained) is carried
        // into the final reply rather than lost.
        let ckpt_dir = (shared.retry_faulted > 0).then(|| {
            shared
                .spill_dir
                .clone()
                .unwrap_or_else(std::env::temp_dir)
                .join(format!("fastspsd-ckpt-req-{}", req.id))
        });
        let mut carried = NumericHealth::default();
        let mut attempt = 0usize;
        let mut resp = loop {
            let _ckpt = ckpt_dir.as_ref().map(|d| {
                let _ = std::fs::create_dir_all(d);
                checkpoint::arm(&CheckpointConfig::new(d))
            });
            let outcome = catch_unwind(AssertUnwindSafe(|| {
                run_request(shared.oracle.as_ref(), &req, &serve, submitted)
            }));
            match outcome {
                Ok(Ok(r)) => {
                    shared.metrics.completed.inc();
                    if serve.degraded.is_some() {
                        shared.metrics.degraded.inc();
                    }
                    break r;
                }
                Ok(Err(e)) => {
                    shared.metrics.failed.inc();
                    carried.merge(&guard::take_health());
                    if attempt < shared.retry_faulted {
                        attempt += 1;
                        continue;
                    }
                    break error_response(
                        req.id,
                        serve.method.name(),
                        ServiceError::Faulted(e.to_string()),
                    );
                }
                Err(payload) => {
                    // Panic isolation: the request fails alone; the
                    // worker, the pool, and every other request keep
                    // going (and may retry, resuming from checkpoints).
                    shared.metrics.faulted.inc();
                    carried.merge(&guard::take_health());
                    if attempt < shared.retry_faulted {
                        attempt += 1;
                        continue;
                    }
                    let msg = panic_message(payload.as_ref());
                    break error_response(req.id, serve.method.name(), ServiceError::Faulted(msg));
                }
            }
        };
        if let Some(d) = &ckpt_dir {
            let _ = std::fs::remove_dir_all(d);
        }
        if let Some(meta) = resp.meta.as_mut() {
            meta.numeric_health.merge(&carried);
            resp.numeric_health = Some(meta.numeric_health);
        } else if carried != NumericHealth::default() {
            // Even a finally-Faulted reply reports what its attempts saw
            // (quarantined tiles, escalations) — failures stay diagnosable.
            resp.numeric_health = Some(carried);
        }
        resp.queue_wait_secs = queue_wait.as_secs_f64();
        resp.ladder_secs = ladder_ns as f64 / 1e9;
        if trace != 0 {
            // Reassemble the request's full timeline — plan + ladder +
            // queue + every exec/stream span from any thread — exactly
            // once, on every outcome (success, error, or panic), so the
            // central store never accumulates finished traces.
            let records = obs::drain_trace(trace);
            if let Some(meta) = resp.meta.as_mut() {
                meta.stage_profile =
                    Some(StageProfile::from_records(&records, obs::current_thread_id()));
            }
            if let Some(dir) = &shared.trace_dir {
                let path = dir.join(format!("trace-req-{}.json", req.id));
                let _ = sink::write_chrome_json(&path, &records);
            }
        }
        shared.metrics.latency.observe(submitted.elapsed());
        // Fan the one result out to the batch: riders get a clone with
        // their own id and queue accounting. A faulted leader faults its
        // riders too (an identical build would have failed identically);
        // only successful riders count as completed.
        resp.batched = !riders.is_empty();
        for rider in riders {
            let waited = started.saturating_duration_since(rider.enqueued);
            shared.metrics.queue_wait.observe(waited);
            shared.metrics.latency.observe(rider.enqueued.elapsed());
            if resp.error.is_none() {
                shared.metrics.completed.inc();
            }
            let mut rr = resp.clone();
            rr.id = rider.req.id;
            rr.batched = true;
            rr.queue_wait_secs = waited.as_secs_f64();
            rr.ladder_secs = 0.0;
            discard_trace(rider.trace);
            let _ = rider.reply.send(rr);
        }
        let _ = reply.send(resp);
    });
}

fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        format!("panic: {s}")
    } else if let Some(s) = payload.downcast_ref::<String>() {
        format!("panic: {s}")
    } else {
        "panic: (non-string payload)".into()
    }
}

/// Drops the in-flight accounting (memory reservation + inflight count)
/// when a worker job ends — normally or by unwinding — and wakes the
/// reaper so the freed headroom admits queued work.
struct ReservationGuard {
    shared: Arc<Shared>,
    predicted: u64,
}

impl Drop for ReservationGuard {
    fn drop(&mut self) {
        self.shared.metrics.mem_in_use.sub(self.predicted);
        self.shared.inflight.fetch_sub(1, Ordering::SeqCst);
        // Lock-then-notify so the wakeup cannot race a reaper that is
        // between its headroom check and its condvar wait.
        drop(self.shared.queue.lock().unwrap());
        self.shared.queue_cv.notify_all();
    }
}

fn run_request(
    oracle: &dyn KernelOracle,
    req: &ApproxRequest,
    serve: &ServeAs,
    submitted: Instant,
) -> anyhow::Result<ApproxResponse> {
    let mut rng = Rng::new(req.seed);
    let n = oracle.n();
    let c = serve.c;
    let policy = &serve.policy;
    let p = spsd::uniform_p(n, c, &mut rng);
    let k_top = req.k.max(1);
    // The response's compute time covers the whole request — kernel
    // materialization (Cur), the build, and the downstream eig/SVD — not
    // just the exec entry point's slice of it.
    let t0 = Instant::now();
    // The downstream eig/SVD is span-tagged here (depth 0 on the worker,
    // outside the exec.run umbrella) so the request's stage profile
    // covers the whole compute_secs window, not just the build.
    let eig_k = |a: &spsd::SpsdApprox| {
        let _s = obs::span(Stage::SolveEig);
        a.eig_k(k_top).0
    };
    let (eigvals, core_dims, mut meta) = match serve.method {
        MethodSpec::Nystrom => {
            let rep = exec::nystrom(oracle, &p, policy);
            (eig_k(&rep.result), None, rep.meta)
        }
        MethodSpec::Prototype => {
            let rep = exec::prototype(oracle, &p, policy);
            (eig_k(&rep.result), None, rep.meta)
        }
        MethodSpec::Fast { s, kind } => {
            // Gram basis: leverage requests stream with O(c²) score
            // state, matching the peak the planner predicts here.
            let cfg =
                FastConfig { s, kind, force_p_in_s: true, leverage_basis: LeverageBasis::Gram };
            let rep = exec::fast(oracle, &p, cfg, policy, &mut rng);
            (eig_k(&rep.result), None, rep.meta)
        }
        MethodSpec::Cur { r, s } => {
            // CUR of the kernel matrix itself: `p` picks the columns, a
            // second uniform draw the rows. Serving materializes K — the
            // n² cost the planner's Cur model predicts and the memory
            // meter charges.
            let before = oracle.entries_observed();
            let kmat = {
                let _s = obs::span(Stage::OracleTile);
                oracle.full()
            };
            let rows = cur::select_uniform(n, r.clamp(1, n), &mut rng);
            let rep =
                exec::cur_fast(&kmat, &p, &rows, FastCurConfig::uniform(s, s), policy, &mut rng);
            let dims = (rep.result.u.rows(), rep.result.u.cols());
            let mut sv = {
                let _s = obs::span(Stage::SolveSvd);
                svd_thin(&rep.result.u).s
            };
            sv.truncate(k_top);
            let mut meta = rep.meta;
            meta.entries = Some(oracle.entries_observed() - before);
            (sv, Some(dims), meta)
        }
    };
    meta.compute_secs = t0.elapsed().as_secs_f64();
    meta.predicted_peak_bytes = Some(serve.predicted);
    meta.degraded = serve.degraded.clone();
    let precision = meta.precision;
    let numeric_health = Some(meta.numeric_health);
    Ok(ApproxResponse {
        id: req.id,
        method: serve.method.name(),
        eigvals,
        core_dims,
        total_secs: submitted.elapsed().as_secs_f64(),
        meta: Some(meta),
        degraded: serve.degraded.clone(),
        precision,
        queue_wait_secs: 0.0, // filled by dispatch, which owns the clock
        ladder_secs: 0.0,
        numeric_health,
        batched: false, // filled by dispatch, which knows the batch
        error: None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::super::oracle::RbfOracle;
    use crate::linalg::Matrix;
    use crate::sketch::SketchKind;

    fn service(n: usize, workers: usize, cap: usize) -> ApproxService {
        service_cfg(n, ServiceConfig { workers, queue_capacity: cap, ..Default::default() })
    }

    fn service_cfg(n: usize, cfg: ServiceConfig) -> ApproxService {
        let mut rng = Rng::new(0);
        let x = Arc::new(Matrix::randn(n, 6, &mut rng));
        let oracle = Arc::new(RbfOracle::cpu(x, 0.4));
        ApproxService::new(oracle, cfg)
    }

    fn req(id: u64, method: MethodSpec, seed: u64, policy: Option<ExecPolicy>) -> ApproxRequest {
        ApproxRequest {
            id,
            method,
            c: 8,
            k: 3,
            seed,
            policy,
            precision: Precision::F64,
            deadline: None,
        }
    }

    fn entries_of(r: &ApproxResponse) -> u64 {
        r.meta.as_ref().unwrap().entries.unwrap()
    }

    #[test]
    fn serves_all_methods() {
        // One worker: the per-request entry delta is read off a single
        // shared oracle counter, so overlapping builds would misattribute
        // entries and make the ordering assertions below flaky.
        let svc = service(80, 1, 16);
        let (tx, rx) = mpsc::channel();
        let methods = [
            MethodSpec::Nystrom,
            MethodSpec::Prototype,
            MethodSpec::Fast { s: 24, kind: SketchKind::Uniform },
            MethodSpec::Cur { r: 8, s: 24 },
        ];
        for (i, m) in methods.iter().enumerate() {
            svc.submit(req(i as u64, *m, i as u64, None), tx.clone());
        }
        svc.drain();
        drop(tx);
        let mut resps: Vec<ApproxResponse> = rx.iter().collect();
        resps.sort_by_key(|r| r.id);
        assert_eq!(resps.len(), 4);
        for r in &resps {
            assert_eq!(r.eigvals.len(), 3, "{}", r.method);
            assert!(r.eigvals[0] >= r.eigvals[1]);
            assert!(r.error.is_none());
            assert!(r.degraded.is_none(), "uncapped service never degrades");
            let meta = r.meta.as_ref().expect("served responses carry meta");
            assert!(meta.compute_secs <= r.total_secs + 1e-9);
            assert!(meta.predicted_peak_bytes.unwrap() > 0);
            assert!(meta.degraded.is_none());
            let health = r.numeric_health.expect("served responses carry numeric health");
            assert_eq!(health, meta.numeric_health, "response mirrors meta");
            assert!(health.is_clean(), "RBF kernels build clean: {health:?}");
        }
        // prototype and CUR observe n² + extras; nystrom the fewest
        assert!(entries_of(&resps[1]) > entries_of(&resps[2]));
        assert!(entries_of(&resps[2]) > entries_of(&resps[0]));
        assert!(entries_of(&resps[3]) >= 80 * 80, "served CUR materializes K");
        assert_eq!(resps[3].core_dims, Some((8, 8)), "c x r core");
        assert_eq!(svc.metrics().completed.get(), 4);
        assert_eq!(svc.metrics().failed.get(), 0);
        assert_eq!(svc.metrics().faulted.get(), 0);
        assert_eq!(svc.metrics().latency.count(), 4);
        assert_eq!(svc.metrics().mem_in_use.get(), 0, "meter must drain to zero");
    }

    #[test]
    fn many_concurrent_requests_complete() {
        let svc = service(60, 4, 8);
        let (tx, rx) = mpsc::channel();
        let total = 30u64;
        for i in 0..total {
            svc.submit(
                req(i, MethodSpec::Fast { s: 16, kind: SketchKind::Uniform }, i, None),
                tx.clone(),
            );
        }
        svc.drain();
        drop(tx);
        assert_eq!(rx.iter().count() as u64, total);
        assert_eq!(svc.metrics().requests.get(), total);
        assert_eq!(svc.inflight(), 0);
        assert_eq!(svc.metrics().mem_in_use.get(), 0);
    }

    #[test]
    fn streamed_requests_match_materialized_results() {
        // The same (method, c, seed) served materialized and through the
        // tile pipeline must agree: bit-identically for the gather-based
        // fast/nystrom paths, to reduction-reordering tolerance for the
        // prototype. One worker: the per-request entry delta is read off a
        // single shared oracle counter, so overlapping builds would
        // misattribute entries and make the equality assertion flaky.
        let svc = service(70, 1, 16);
        let (tx, rx) = mpsc::channel();
        let methods = [
            MethodSpec::Nystrom,
            MethodSpec::Prototype,
            MethodSpec::Fast { s: 20, kind: SketchKind::Uniform },
            MethodSpec::Fast { s: 20, kind: SketchKind::Leverage { scaled: false } },
            MethodSpec::Cur { r: 7, s: 20 },
        ];
        let mut id = 0u64;
        for m in methods {
            for policy in [None, Some(ExecPolicy::streamed(13))] {
                svc.submit(req(id, m, 42, policy), tx.clone());
                id += 1;
            }
        }
        svc.drain();
        drop(tx);
        let mut resps: Vec<ApproxResponse> = rx.iter().collect();
        resps.sort_by_key(|r| r.id);
        assert_eq!(resps.len(), 10);
        for pair in resps.chunks(2) {
            let (mat, st) = (&pair[0], &pair[1]);
            assert_eq!(
                entries_of(mat),
                entries_of(st),
                "{}: entry accounting must not change",
                mat.method
            );
            for (a, b) in mat.eigvals.iter().zip(&st.eigvals) {
                let scale = mat.eigvals[0].abs().max(1e-12);
                assert!(
                    (a - b).abs() <= 1e-9 * scale,
                    "{}: streamed eig {b} vs materialized {a}",
                    mat.method
                );
            }
        }
    }

    #[test]
    fn residency_requests_match_plain_and_report_stats() {
        // The same (method, c, seed) with and without residency routing
        // must agree bit-identically (the routed build replays the same
        // rng sequence and gathers the same tiles), carry the same entry
        // count, and attach hit/miss/spill counters. One worker for the
        // same shared-counter reason as above.
        let svc = service(70, 1, 16);
        let (tx, rx) = mpsc::channel();
        let methods = [
            MethodSpec::Nystrom,
            MethodSpec::Fast { s: 20, kind: SketchKind::Uniform },
            MethodSpec::Fast { s: 20, kind: SketchKind::Leverage { scaled: false } },
        ];
        let mut id = 0u64;
        for m in methods {
            for policy in [
                Some(ExecPolicy::streamed(13)),
                Some(ExecPolicy::resident(0).with_tile_rows(13)),
            ] {
                svc.submit(req(id, m, 42, policy), tx.clone());
                id += 1;
            }
        }
        svc.drain();
        drop(tx);
        let mut resps: Vec<ApproxResponse> = rx.iter().collect();
        resps.sort_by_key(|r| r.id);
        assert_eq!(resps.len(), 6);
        for pair in resps.chunks(2) {
            let (plain, routed) = (&pair[0], &pair[1]);
            assert!(plain.meta.as_ref().unwrap().residency.is_none());
            let stats = routed
                .meta
                .as_ref()
                .unwrap()
                .residency
                .expect("routed request must report stats");
            assert_eq!(entries_of(plain), entries_of(routed), "{}", plain.method);
            for (a, b) in plain.eigvals.iter().zip(&routed.eigvals) {
                assert_eq!(a, b, "{}: residency must not change results", plain.method);
            }
            assert_eq!(stats.computes, 70u64.div_ceil(13), "one oracle pass per tile");
            if routed.method.contains("leverage") {
                // two-pass plan at a zero RAM budget: pass 2 reads the arena
                assert_eq!(stats.spill_hits, stats.computes, "{}", routed.method);
            }
        }
    }

    #[test]
    fn never_fitting_requests_get_typed_overload_replies() {
        let n = 80;
        // Cap sized for exactly one materialized nystrom request.
        let one = planner::predicted_policy_peak_bytes(
            n,
            8,
            &MethodSpec::Nystrom,
            &ExecPolicy::Materialized,
        );
        let svc = service_cfg(n, ServiceConfig { memory_cap: Some(one), ..Default::default() });
        // Prototype's predicted peak can never fit a cap sized for one
        // nystrom — not even at the bottom of its degrade ladder — so the
        // reply is an immediate typed Overloaded, nothing reserved.
        let (tx, rx) = mpsc::channel();
        svc.submit(req(0, MethodSpec::Prototype, 1, None), tx.clone());
        drop(tx);
        let resps: Vec<ApproxResponse> = rx.iter().collect();
        assert_eq!(resps.len(), 1, "rejected requests still get a reply");
        match resps[0].error.as_ref() {
            Some(ServiceError::Overloaded { retry_after }) => {
                assert!(*retry_after > Duration::ZERO);
            }
            other => panic!("expected Overloaded, got {other:?}"),
        }
        assert!(resps[0].meta.is_none() && resps[0].eigvals.is_empty());
        assert_eq!(svc.metrics().rejected_overload.get(), 1);
        assert_eq!(svc.metrics().queued.get(), 0, "never-fits must not occupy the queue");
        assert_eq!(svc.metrics().mem_in_use.get(), 0, "a reject reserves nothing");
    }

    #[test]
    fn over_cap_requests_queue_and_complete_instead_of_shedding() {
        let n = 80;
        let one = planner::predicted_policy_peak_bytes(
            n,
            8,
            &MethodSpec::Nystrom,
            &ExecPolicy::Materialized,
        );
        let svc = service_cfg(
            n,
            ServiceConfig { workers: 1, memory_cap: Some(one), ..Default::default() },
        );
        // A burst sized for one request at a time: everything beyond the
        // in-flight one waits in the admission queue and is served as the
        // gauge drains — nothing is shed, nothing degrades (the headroom
        // is all-or-nothing at this cap).
        let (tx, rx) = mpsc::channel();
        let total = 10u64;
        for i in 0..total {
            svc.submit(req(i, MethodSpec::Nystrom, i, None), tx.clone());
        }
        svc.drain();
        drop(tx);
        let resps: Vec<ApproxResponse> = rx.iter().collect();
        assert_eq!(resps.len(), total as usize);
        for r in &resps {
            assert!(r.error.is_none(), "{}: queued requests complete: {:?}", r.id, r.error);
            assert!(r.meta.is_some());
        }
        let m = svc.metrics();
        assert_eq!(m.completed.get(), total);
        assert_eq!(m.rejected_overload.get(), 0, "queueing replaces shedding");
        assert_eq!(m.expired_deadline.get(), 0);
        assert!(m.queued.get() >= 1, "the burst must actually exercise the queue");
        assert_eq!(m.mem_in_use.get(), 0);
        assert_eq!(svc.inflight(), 0);

        // Uncapped services meter without queueing or shedding.
        let svc = service(40, 1, 8);
        let (tx, rx) = mpsc::channel();
        svc.submit(req(0, MethodSpec::Prototype, 1, None), tx);
        svc.drain();
        assert!(rx.iter().next().unwrap().error.is_none());
        assert_eq!(svc.metrics().rejected_overload.get(), 0);
        assert_eq!(svc.metrics().mem_in_use.get(), 0);
    }

    #[test]
    fn ladder_serves_degraded_when_request_can_never_fit() {
        let n = 80;
        // Cap = exactly the uniform-sampling rung of a leverage request:
        // the leverage rung 0 (which additionally carries its 2c² score
        // state) can never fit, so admission walks the ladder and serves
        // the SamplingRelaxed rung — synchronously, visibly degraded.
        let lev = MethodSpec::Fast { s: 24, kind: SketchKind::Leverage { scaled: false } };
        let uni = MethodSpec::Fast { s: 24, kind: SketchKind::Uniform };
        let cap =
            planner::predicted_policy_peak_bytes(n, 8, &uni, &ExecPolicy::Materialized);
        assert!(
            planner::predicted_policy_peak_bytes(n, 8, &lev, &ExecPolicy::Materialized) > cap,
            "test premise: leverage rung 0 must exceed the cap"
        );
        let svc = service_cfg(n, ServiceConfig { memory_cap: Some(cap), ..Default::default() });
        let (tx, rx) = mpsc::channel();
        svc.submit(req(7, lev, 3, None), tx);
        svc.drain();
        let r = rx.iter().next().unwrap();
        assert!(r.error.is_none(), "{:?}", r.error);
        let info = r.degraded.as_ref().expect("ladder service must be visible");
        assert_eq!(info.rung, 1);
        assert_eq!(info.requested_c, 8);
        assert_eq!(info.c, 8, "first rung only relaxes the sampling");
        assert_eq!(info.actions, vec![crate::exec::DegradeAction::SamplingRelaxed]);
        assert_eq!(r.meta.as_ref().unwrap().degraded.as_ref(), Some(info));
        assert!(r.method.contains("uniform"), "served method is the degraded one: {}", r.method);
        assert_eq!(r.eigvals.len(), 3);
        let m = svc.metrics();
        assert_eq!(m.degraded.get(), 1);
        assert_eq!(m.completed.get(), 1);
        assert_eq!(m.mem_in_use.get(), 0);
    }

    #[test]
    fn ladder_lowers_precision_visibly_when_that_is_what_fits() {
        use crate::exec::DegradeAction;
        let n = 80;
        let m = MethodSpec::Fast { s: 24, kind: SketchKind::Uniform };
        let policy = ExecPolicy::resident(0).with_tile_rows(13);
        // Cap = exactly the ladder's f32 rung: rung 0 as asked and every
        // rung before the precision one are strictly larger, so admission
        // walks down to the narrowed policy and serves it — synchronously,
        // and the trade is recorded, never silent.
        let ladder = planner::degrade_ladder(n, 3, &m, 8, &policy);
        let rung = ladder
            .iter()
            .find(|d| d.info.actions.last() == Some(&DegradeAction::PrecisionLowered))
            .expect("resident ladder must carry a precision rung");
        let svc = service_cfg(
            n,
            ServiceConfig { memory_cap: Some(rung.predicted_peak_bytes), ..Default::default() },
        );
        let (tx, rx) = mpsc::channel();
        svc.submit(req(11, m, 5, Some(policy)), tx);
        svc.drain();
        let r = rx.iter().next().unwrap();
        assert!(r.error.is_none(), "{:?}", r.error);
        let info = r.degraded.as_ref().expect("precision trade must be visible");
        assert!(
            info.actions.contains(&DegradeAction::PrecisionLowered),
            "actions: {:?}",
            info.actions
        );
        assert_eq!(r.precision, Precision::F32, "response surfaces the served width");
        let meta = r.meta.as_ref().unwrap();
        assert_eq!(meta.precision, Precision::F32);
        assert_eq!(r.eigvals.len(), 3);
        let metrics = svc.metrics();
        assert_eq!(metrics.degraded.get(), 1);
        assert_eq!(metrics.completed.get(), 1);
        assert_eq!(metrics.mem_in_use.get(), 0);
    }
}
