"""Layer-2 JAX model: the compute graphs that get AOT-lowered to HLO.

Each public function here is a pure jax function over fixed-shape arrays that
calls the Layer-1 Pallas kernels, so that the kernels lower into the same HLO
module. `aot.py` lowers every (function, shape-bucket) pair listed in
`ARTIFACT_SPECS` to `artifacts/<name>.hlo.txt` plus a manifest the rust
runtime reads.

Shape buckets: the rust coordinator pads data blocks to these shapes (rows
with zero rows — cropped at assembly — and features with zero columns, which
leaves RBF distances and matmul products unchanged).
"""

from __future__ import annotations

from . import kernels
from .kernels.rbf_block import rbf_block
from .kernels.matmul import matmul
from .kernels.poly_block import poly_block

__all__ = ["rbf_block_graph", "matmul_graph", "poly_block_graph", "ARTIFACT_SPECS"]


def rbf_block_graph(gamma, x, y):
    """One (BM, BN) RBF kernel block; gamma is a (1,1) operand."""
    return (rbf_block(gamma, x, y),)


def matmul_graph(x, y):
    """One (BM, BN) matmul tile with full-depth contraction."""
    return (matmul(x, y),)


def poly_block_graph(gamma, coef0, degree, x, y):
    """One (BM, BN) polynomial kernel block; params are (1,1) operands."""
    return (poly_block(gamma, coef0, degree, x, y),)


# Output block edge for the kernel-matrix tiles.
BM = 256
BN = 256
# Feature-dimension buckets covering the paper's datasets (d = 12..5000;
# Gisette-like d=5000 maps to the 1024 bucket after PCA-style truncation or
# two passes — the coordinator picks the smallest bucket >= d, capped here).
D_BUCKETS = (16, 128, 1024)
# Matmul tile: (BM x K) @ (K x BN) for sketch products / feature projection.
MM_K = (256, 1024)

# name -> (function, input shapes); every entry becomes one artifact.
ARTIFACT_SPECS = {}
for _d in D_BUCKETS:
    ARTIFACT_SPECS[f"rbf_block_{BM}x{BN}x{_d}"] = (
        rbf_block_graph,
        [(1, 1), (BM, _d), (BN, _d)],
    )
for _k in MM_K:
    ARTIFACT_SPECS[f"matmul_{BM}x{_k}x{BN}"] = (
        matmul_graph,
        [(BM, _k), (_k, BN)],
    )
# Polynomial kernel buckets (small-d datasets are its common use case).
for _d in (16, 128):
    ARTIFACT_SPECS[f"poly_block_{BM}x{BN}x{_d}"] = (
        poly_block_graph,
        [(1, 1), (1, 1), (1, 1), (BM, _d), (BN, _d)],
    )
