//! RBF scale calibration (paper §6.1).
//!
//! The paper sets σ so that `η = ‖K_k‖_F² / ‖K‖_F²` (k = ⌈n/100⌉) hits 0.9
//! or 0.99. η is monotone increasing in σ, so we bisect, measuring η on a
//! subsample for tractability. The squared-distance matrix of the subsample
//! is computed **once** (a single triangular SYRK with a fused epilogue)
//! and every bisection step only re-exponentiates it — the bracketing +
//! 40-step loop costs ~1 GEMM instead of ~40.

use crate::linalg::{gemm, lanczos_top_k, Matrix};
use crate::util::Rng;

/// `η(K, k) = Σ_{i<=k} σ_i²(K) / Σ_i σ_i²(K)` — the share of Frobenius mass
/// in the top-k spectrum. For SPSD K, `Σ_i σ_i² = ‖K‖_F²` and the top-k
/// singular values are the top-k eigenvalues, so Lanczos gives this in
/// O(n²·k) instead of a full O(n³) eigendecomposition.
pub fn eta(kmat: &Matrix, k: usize) -> f64 {
    let total = kmat.fro_norm_sq();
    if total <= 0.0 {
        return 1.0;
    }
    let (vals, _) = lanczos_top_k(kmat, k, 0x17A);
    let top: f64 = vals.iter().map(|&v| v.max(0.0) * v.max(0.0)).sum();
    (top / total).min(1.0)
}

/// Pairwise squared-distance matrix `D2[i, j] = ||x_i - x_j||²`, computed
/// with the triangular SYRK path and a fused epilogue (exactly symmetric,
/// clamped at 0).
pub fn sq_dist_matrix(x: &Matrix) -> Matrix {
    let xn = x.row_sq_norms();
    gemm::syrk_nt_map(x, &|i, j, dot| (xn[i] + xn[j] - 2.0 * dot).max(0.0))
}

/// `out = exp(-gamma * d2)` elementwise (the per-σ work of calibration).
fn exp_into(d2: &Matrix, gamma: f64, out: &mut Matrix) {
    debug_assert_eq!((out.rows(), out.cols()), (d2.rows(), d2.cols()));
    for (kv, &dv) in out.data_mut().iter_mut().zip(d2.data()) {
        *kv = (-gamma * dv).exp();
    }
}

/// η for the RBF kernel at scale `sigma` given a precomputed
/// squared-distance matrix — the bisection hot loop. Only the elementwise
/// `exp` is recomputed per σ (calibration additionally reuses one scratch
/// kernel buffer across all steps).
pub fn eta_for_sigma_with_d2(d2: &Matrix, sigma: f64, k: usize) -> f64 {
    let mut kmat = Matrix::zeros(d2.rows(), d2.cols());
    exp_into(d2, 1.0 / (2.0 * sigma * sigma), &mut kmat);
    eta(&kmat, k)
}

/// η for the RBF kernel of `x` at scale `sigma` (one-shot convenience;
/// calibration uses [`eta_for_sigma_with_d2`] to avoid rebuilding K).
pub fn eta_for_sigma(x: &Matrix, sigma: f64, k: usize) -> f64 {
    eta_for_sigma_with_d2(&sq_dist_matrix(x), sigma, k)
}

/// Find σ with `η(σ) ≈ target` by bisection on a subsample of at most
/// `max_sub` points (k scales with the subsample as ⌈n_sub/100⌉).
pub fn calibrate_sigma(x: &Matrix, target_eta: f64, max_sub: usize, seed: u64) -> f64 {
    assert!((0.0..1.0).contains(&target_eta));
    let mut rng = Rng::new(seed);
    let n = x.rows();
    let xs = if n > max_sub {
        let idx = rng.sample_without_replacement(n, max_sub);
        x.select_rows(&idx)
    } else {
        x.clone()
    };
    let k = xs.rows().div_ceil(100).max(1);
    // One kernel-shaped product and one scratch buffer for the whole
    // calibration; every step below only re-exponentiates.
    let d2 = sq_dist_matrix(&xs);
    let mut scratch = Matrix::zeros(d2.rows(), d2.cols());
    let mut eta_at = |sigma: f64| -> f64 {
        exp_into(&d2, 1.0 / (2.0 * sigma * sigma), &mut scratch);
        eta(&scratch, k)
    };

    // Bracket: large σ ⇒ K → all-ones ⇒ η → 1; small σ ⇒ K → I ⇒ η → k/n.
    let mut lo = 1e-3;
    let mut hi = 1.0;
    while eta_at(hi) < target_eta && hi < 1e4 {
        hi *= 2.0;
    }
    while eta_at(lo) > target_eta && lo > 1e-6 {
        lo *= 0.5;
    }
    for _ in 0..40 {
        let mid = (lo * hi).sqrt(); // geometric bisection (σ spans decades)
        if eta_at(mid) < target_eta {
            lo = mid;
        } else {
            hi = mid;
        }
        if hi / lo < 1.01 {
            break;
        }
    }
    (lo * hi).sqrt()
}

/// Convert σ to the RBF precision γ = 1/(2σ²).
pub fn gamma_of_sigma(sigma: f64) -> f64 {
    1.0 / (2.0 * sigma * sigma)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::rbf_cross_cpu;
    use crate::data::make_blobs;

    #[test]
    fn eta_bounds_and_monotonicity_in_k() {
        let ds = make_blobs("t", 60, 4, 3, 2.0, 0);
        let k = rbf_cross_cpu(&ds.x, &ds.x, 0.5);
        let e1 = eta(&k, 1);
        let e5 = eta(&k, 5);
        let e60 = eta(&k, 60);
        assert!(e1 > 0.0 && e1 <= e5 && e5 <= e60);
        assert!((e60 - 1.0).abs() < 1e-9);
    }

    #[test]
    fn eta_monotone_in_sigma() {
        let ds = make_blobs("t", 80, 4, 3, 2.0, 1);
        let small = eta_for_sigma(&ds.x, 0.05, 1);
        let large = eta_for_sigma(&ds.x, 20.0, 1);
        assert!(large > small, "eta(20)={large} <= eta(0.05)={small}");
        assert!(large > 0.9);
    }

    #[test]
    fn precomputed_d2_matches_direct_kernel() {
        let ds = make_blobs("t", 50, 4, 3, 2.0, 4);
        let d2 = sq_dist_matrix(&ds.x);
        assert_eq!(d2.max_abs_diff(&d2.transpose()), 0.0);
        for sigma in [0.3, 1.0, 4.0] {
            let gamma = gamma_of_sigma(sigma);
            let direct = rbf_cross_cpu(&ds.x, &ds.x, gamma);
            let mut from_d2 = Matrix::zeros(50, 50);
            for (kv, &dv) in from_d2.data_mut().iter_mut().zip(d2.data()) {
                *kv = (-gamma * dv).exp();
            }
            assert!(direct.max_abs_diff(&from_d2) < 1e-12, "sigma={sigma}");
        }
    }

    #[test]
    fn calibration_hits_target() {
        let ds = make_blobs("t", 300, 6, 4, 2.0, 2);
        for target in [0.9, 0.99] {
            let sigma = calibrate_sigma(&ds.x, target, 300, 3);
            let k = 300usize.div_ceil(100);
            let achieved = eta_for_sigma(&ds.x, sigma, k);
            assert!(
                (achieved - target).abs() < 0.03,
                "target {target}: sigma={sigma} achieved={achieved}"
            );
        }
    }
}
