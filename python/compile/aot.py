"""AOT pipeline: lower every Layer-2 graph to HLO text + manifest.

Interchange format is HLO *text*, not a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which the image's xla_extension
0.5.1 rejects (`proto.id() <= INT_MAX`); the HLO text parser reassigns ids
and round-trips cleanly (see /opt/xla-example/README.md).

Run once at build time (`make artifacts`); python never appears on the rust
request path. Usage:

    cd python && python -m compile.aot --out-dir ../artifacts
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import ARTIFACT_SPECS


def to_hlo_text(lowered) -> str:
    """stablehlo MLIR -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_one(name: str, fn, shapes) -> str:
    specs = [jax.ShapeDtypeStruct(s, jnp.float32) for s in shapes]
    lowered = jax.jit(fn).lower(*specs)
    return to_hlo_text(lowered)


def source_fingerprint() -> str:
    """Hash of the compile-path sources, so `make` can skip unchanged builds."""
    root = os.path.dirname(os.path.abspath(__file__))
    h = hashlib.sha256()
    for dirpath, _, files in sorted(os.walk(root)):
        if "__pycache__" in dirpath:
            continue
        for f in sorted(files):
            if f.endswith(".py"):
                with open(os.path.join(dirpath, f), "rb") as fh:
                    h.update(fh.read())
    return h.hexdigest()


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="comma-separated artifact names")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    only = set(args.only.split(",")) if args.only else None
    manifest = {"format": "hlo-text", "fingerprint": source_fingerprint(), "artifacts": []}
    for name, (fn, shapes) in ARTIFACT_SPECS.items():
        if only is not None and name not in only:
            continue
        text = lower_one(name, fn, shapes)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out_dir, fname), "w") as f:
            f.write(text)
        # "<kind>_<MxKxN>" — the shape suffix is a single token
        kind = name.rsplit("_", 1)[0]
        manifest["artifacts"].append(
            {
                "name": name,
                "file": fname,
                "kind": kind,
                "inputs": [list(s) for s in shapes],
                "dtype": "f32",
            }
        )
        print(f"  lowered {name}: {len(text)} chars")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {len(manifest['artifacts'])} artifacts to {args.out_dir}")


if __name__ == "__main__":
    main()
