//! Residency-layer acceptance tests (ISSUE 4): cached / spilled /
//! uncached results must be **bit-identical** across tile sizes
//! {1, 7, 64, n} and cache budgets {0, one-tile, half-panel, ∞}, and the
//! oracle entry counter must prove kernel-eval elimination — across q ≥ 5
//! Lanczos iterations the residency-backed path charges exactly one `n·c`
//! observation at **any** RAM budget (including 0, where every re-read
//! comes from the disk arena), versus `q·n·c`-style re-streaming without
//! it.

use fastspsd::coordinator::oracle::{KernelOracle, RbfOracle};
use fastspsd::exec::{self, ExecPolicy};
use fastspsd::linalg::Matrix;
use fastspsd::sketch::SketchKind;
use fastspsd::spsd::FastConfig;
use fastspsd::stream::{
    self, CollectConsumer, OracleColumnsSource, ResidencyConfig, ResidentSource,
};
use fastspsd::testkit::faults::{FaultPlan, FaultPoint, FaultSpec, FaultyConsumer, FaultyOracle};
use fastspsd::util::Rng;

/// Spilling residency at `budget` bytes, grid = pipeline tile = `tile`.
fn spilled(budget: u64, tile: usize) -> ExecPolicy {
    ExecPolicy::resident(budget).with_tile_rows(tile)
}

/// RAM-only cached-panel policy (the old `*_budgeted` contract).
fn cached(budget: u64, tile: usize) -> ExecPolicy {
    ExecPolicy::ram_cached(budget).with_tile_rows(tile)
}
use std::sync::Arc;

const N: usize = 53; // prime: no tile height divides it
const C: usize = 5;

fn oracle() -> RbfOracle {
    let mut rng = Rng::new(3);
    RbfOracle::cpu(Arc::new(Matrix::randn(N, 6, &mut rng)), 0.5)
}

fn landmarks() -> Vec<usize> {
    vec![2, 11, 23, 37, 50]
}

/// The budget sweep the issue names: zero (all-disk), one tile, half the
/// panel, unbounded.
fn budgets(tile: usize) -> [u64; 4] {
    let one_tile = (tile.min(N) * C * 8) as u64;
    let panel = (N * C * 8) as u64;
    [0, one_tile, panel / 2, u64::MAX]
}

#[test]
fn lanczos_is_bit_identical_across_tiles_and_budgets() {
    let o = oracle();
    let cols = landmarks();
    let mut rng = Rng::new(4);
    let mut u = Matrix::randn(C, C, &mut rng);
    u.symmetrize();
    let src = OracleColumnsSource::new(&o, &cols);

    // uncached reference (whole-tile = the materialized path)
    let (vals_ref, vecs_ref) =
        exec::top_k_eigs(&src, &u, 3, 7, &ExecPolicy::Materialized).result;

    for tile in [1usize, 7, 64, N] {
        // plain re-streaming at this tile height
        let (vals_plain, vecs_plain) =
            exec::top_k_eigs(&src, &u, 3, 7, &ExecPolicy::streamed(tile)).result;
        assert_eq!(vals_ref, vals_plain, "tile={tile}: tiling must not change Lanczos");
        assert_eq!(vecs_ref.max_abs_diff(&vecs_plain), 0.0);

        for budget in budgets(tile) {
            // spilled (LRU budget + disk arena)
            o.reset_entries();
            let rep = exec::top_k_eigs(&src, &u, 3, 7, &spilled(budget, tile));
            let (vals, vecs) = rep.result;
            let stats = rep.meta.residency.expect("stats");
            assert_eq!(vals_ref, vals, "tile={tile} budget={budget}");
            assert_eq!(vecs_ref.max_abs_diff(&vecs), 0.0, "tile={tile} budget={budget}");
            assert_eq!(
                o.entries_observed(),
                (N * C) as u64,
                "tile={tile} budget={budget}: spill must charge exactly one n·c"
            );
            assert_eq!(stats.computes, N.div_ceil(tile.min(N)) as u64);
            assert!(stats.hits() > 0, "Lanczos re-reads must hit the residency layer");

            // cached (RAM-only budget gate, the old *_budgeted contract)
            o.reset_entries();
            let (vals_b, vecs_b) =
                exec::top_k_eigs(&src, &u, 3, 7, &cached(budget, tile)).result;
            assert_eq!(vals_ref, vals_b, "tile={tile} budget={budget}");
            assert_eq!(vecs_ref.max_abs_diff(&vecs_b), 0.0);
            if budget == u64::MAX {
                assert_eq!(o.entries_observed(), (N * C) as u64);
            }
        }
    }
}

#[test]
fn entry_counter_proves_kernel_eval_elimination() {
    // The acceptance bar: q ≥ 5 Lanczos iterations cost one n·c with the
    // cache+spill layer enabled (any budget, including 0 RAM) vs the
    // re-streaming path's many-pass bill.
    let o = oracle();
    let cols = landmarks();
    let u = Matrix::identity(C);
    let src = OracleColumnsSource::new(&o, &cols);
    let k = 5; // ≥ 5 Lanczos iterations, 2 panel passes per matvec

    o.reset_entries();
    let (vals_plain, _) = exec::top_k_eigs(&src, &u, k, 9, &ExecPolicy::streamed(7)).result;
    let entries_plain = o.entries_observed();
    assert!(
        entries_plain >= 5 * (N * C) as u64,
        "re-streaming path must pay ≥ q·n·c, got {entries_plain}"
    );

    for budget in [0u64, u64::MAX] {
        o.reset_entries();
        let rep = exec::top_k_eigs(&src, &u, k, 9, &spilled(budget, 7));
        let (vals, _) = rep.result;
        let stats = rep.meta.residency.expect("stats");
        assert_eq!(
            o.entries_observed(),
            (N * C) as u64,
            "budget={budget}: exactly one n·c charge"
        );
        assert_eq!(vals_plain, vals, "budget={budget}: bit-identical to uncached");
        if budget == 0 {
            assert_eq!(stats.ram_hits, 0, "zero RAM keeps nothing hot");
            assert_eq!(stats.spilled_bytes, (N * C * 8) as u64);
            assert!(stats.spill_hits > 0);
        } else {
            assert_eq!(stats.spill_hits, 0, "unbounded RAM never touches the arena");
        }
    }
}

#[test]
fn regularized_solve_round_trips_through_spill() {
    let o = oracle();
    let cols = landmarks();
    let mut rng = Rng::new(5);
    let g = Matrix::randn(C, C, &mut rng);
    let u = g.matmul_tr(&g); // SPSD
    let y: Vec<f64> = (0..N).map(|i| (i as f64 * 0.4).cos()).collect();
    let src = OracleColumnsSource::new(&o, &cols);
    let w_ref = exec::solve_regularized(&src, &u, 0.3, &y, &ExecPolicy::Materialized).result;
    for tile in [1usize, 7, 64, N] {
        for budget in budgets(tile) {
            o.reset_entries();
            let w = exec::solve_regularized(&src, &u, 0.3, &y, &spilled(budget, tile)).result;
            assert_eq!(w_ref, w, "tile={tile} budget={budget}");
            assert_eq!(o.entries_observed(), (N * C) as u64);
            let w_b =
                exec::solve_regularized(&src, &u, 0.3, &y, &cached(budget, tile)).result;
            assert_eq!(w_ref, w_b, "cached tile={tile} budget={budget}");
        }
    }
}

#[test]
fn leverage_builds_are_bit_identical_through_residency() {
    // The two-pass leverage plan routed through the residency layer (pass
    // 1 folds scores, pass 2 reloads tiles to collect C and sample S) must
    // reproduce the single-pass streamed build bit-for-bit, at every tile
    // height and budget, with the same oracle bill.
    let o = oracle();
    let p = {
        let mut rng = Rng::new(21);
        fastspsd::spsd::uniform_p(N, C, &mut rng)
    };
    for tile in [1usize, 7, 64, N] {
        for cfg in [FastConfig::uniform(20), FastConfig::leverage(20)] {
            let mut r1 = Rng::new(99);
            let a = exec::fast(&o, &p, cfg, &ExecPolicy::streamed(tile), &mut r1).result;
            for budget in budgets(tile) {
                let mut r2 = Rng::new(99);
                let rep = exec::fast(&o, &p, cfg, &spilled(budget, tile), &mut r2);
                let (b, stats) = (rep.result, rep.meta.residency.expect("stats"));
                assert_eq!(a.c.max_abs_diff(&b.c), 0.0, "{} C tile={tile} budget={budget}", a.method);
                assert_eq!(a.u.max_abs_diff(&b.u), 0.0, "{} U tile={tile} budget={budget}", a.method);
                assert_eq!(
                    a.entries_observed, b.entries_observed,
                    "{} tile={tile} budget={budget}: residency must not change the oracle bill",
                    a.method
                );
                let tiles = N.div_ceil(tile.min(N)) as u64;
                assert_eq!(stats.computes, tiles, "one oracle compute per grid tile");
                if matches!(cfg.kind, SketchKind::Leverage { .. }) {
                    // pass 2 re-reads the full panel from residency
                    assert_eq!(stats.hits(), tiles, "{} tile={tile} budget={budget}", a.method);
                    if budget == 0 {
                        assert_eq!(stats.spill_hits, tiles);
                    }
                }
            }
        }
        // Nyström through the same layer
        let a = exec::nystrom(&o, &p, &ExecPolicy::streamed(tile)).result;
        let b = exec::nystrom(&o, &p, &spilled(0, tile)).result;
        assert_eq!(a.c.max_abs_diff(&b.c), 0.0);
        assert_eq!(a.u.max_abs_diff(&b.u), 0.0);
    }
}

#[test]
fn residency_serves_misaligned_pass_tilings_from_one_grid() {
    // One residency grid can back passes at other tile heights: the grid
    // stays the unit of caching/spilling, requests are assembled from it,
    // and the oracle is still charged exactly once per grid tile.
    let o = oracle();
    let cols = landmarks();
    let src = OracleColumnsSource::new(&o, &cols);
    let rc = ResidencyConfig::new(0).with_tile_rows(8);
    let resident = ResidentSource::new(&src, &rc);
    o.reset_entries();
    let reference = o.columns(&cols);
    let first = o.entries_observed();
    o.reset_entries();
    for pass_tile in [8usize, 13, 1, N] {
        let mut collect = CollectConsumer::new(N, C);
        stream::run_pipeline(&resident, pass_tile, 2, &mut [&mut collect]);
        assert_eq!(
            collect.into_matrix().max_abs_diff(&reference),
            0.0,
            "pass_tile={pass_tile}"
        );
    }
    assert_eq!(o.entries_observed(), first, "grid tiles computed once, reused by every pass");
    assert_eq!(resident.stats().computes, N.div_ceil(8) as u64);
}

#[test]
fn consumer_panic_mid_fold_cleans_spill_and_leaves_pool_healthy() {
    // A consumer panicking on the Kth tile must surface as an error (not a
    // hang), unlink the spill arena during unwind, and leave the global
    // pool able to run the next pipeline.
    let o = oracle();
    let cols = landmarks();
    let plan =
        Arc::new(FaultPlan::none().fail(FaultPoint::ConsumerFold, FaultSpec::transient(3)));
    let src = OracleColumnsSource::new(&o, &cols);
    let rc = ResidencyConfig::new(0).with_tile_rows(8);
    let path = {
        let resident = ResidentSource::new(&src, &rc);
        let path = resident.spill_path().expect("arena live");
        assert!(path.exists());
        let mut bomb = FaultyConsumer::new(Arc::clone(&plan));
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            stream::run_pipeline(&resident, 8, 2, &mut [&mut bomb]);
        }));
        assert!(result.is_err(), "consumer fault must propagate, not hang or vanish");
        path
    };
    assert!(!path.exists(), "arena must be unlinked by the unwind");
    assert_eq!(plan.injected(FaultPoint::ConsumerFold), 1, "exactly the scheduled fault");

    // The pool survives: an identical pipeline right after serves cleanly.
    let resident = ResidentSource::new(&src, &rc);
    let mut collect = CollectConsumer::new(N, C);
    stream::run_pipeline(&resident, 8, 2, &mut [&mut collect]);
    assert_eq!(collect.into_matrix().max_abs_diff(&o.columns(&cols)), 0.0);
}

#[test]
fn source_panic_mid_tile_cleans_spill_and_leaves_pool_healthy() {
    // The dual fault: the oracle (tile *producer*, running on a pool
    // worker) panics on the Kth tile. `ThreadPool::scoped` must re-raise
    // it on the consumer thread, the spill guard must still unlink the
    // arena, and the worker thread must survive for the next run.
    let o = oracle();
    let cols = landmarks();
    let plan =
        Arc::new(FaultPlan::none().fail(FaultPoint::OracleTile, FaultSpec::transient(3)));
    let faulty = FaultyOracle::new(Arc::new(oracle()), Arc::clone(&plan));
    let src = OracleColumnsSource::new(&faulty, &cols);
    let rc = ResidencyConfig::new(0).with_tile_rows(8);
    let path = {
        let resident = ResidentSource::new(&src, &rc);
        let path = resident.spill_path().expect("arena live");
        let mut collect = CollectConsumer::new(N, C);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            stream::run_pipeline(&resident, 8, 2, &mut [&mut collect]);
        }));
        assert!(result.is_err(), "source fault must propagate, not hang or vanish");
        path
    };
    assert!(!path.exists(), "arena must be unlinked by the unwind");
    assert_eq!(plan.injected(FaultPoint::OracleTile), 1);

    // Same wrapped source, fault spent: the retryed pipeline completes and
    // matches the unwrapped oracle bit-for-bit.
    let resident = ResidentSource::new(&src, &rc);
    let mut collect = CollectConsumer::new(N, C);
    stream::run_pipeline(&resident, 8, 2, &mut [&mut collect]);
    assert_eq!(collect.into_matrix().max_abs_diff(&o.columns(&cols)), 0.0);
}
