//! Dense linear algebra substrate, built from scratch (no BLAS/LAPACK in
//! the image). Row-major `f64` matrices with the operations the paper's
//! algorithms need: blocked/threaded GEMM, Householder QR, one-sided Jacobi
//! SVD, cyclic-Jacobi symmetric eigendecomposition, Moore–Penrose
//! pseudo-inverse, and structured solves (Appendix A of the paper).

pub mod eig;
pub mod gemm;
pub mod guard;
pub mod lanczos;
pub mod pinv;
pub mod qr;
pub mod solve;
pub mod sparse;
pub mod svd;

pub use eig::{eigh, Eigh};
pub use guard::{guarded_pinv, guarded_spd_solve, NumericHealth, Regularization};
pub use gemm::{gemm_into, gemm_nt_into, gemm_tn_into, symm_nt, syrk_nt, syrk_tn, syrk_tn_into};
pub use gemm::{gemm_nt_map_f32, syrk_nt_map_f32};
pub use lanczos::{lanczos_top_k, lanczos_top_k_op};
pub use pinv::pinv;
pub use qr::{qr_thin, QrThin};
pub use svd::{svd_thin, SvdThin};

use crate::util::Rng;
use std::fmt;

/// Element width of a tile buffer. The tile plane (gemm panels, oracle
/// blocks, stream tiles, residency spill) can run in either width; the
/// small `c×c`/`s×s` solves and every fold accumulator stay `f64`
/// regardless. Sampling error dwarfs f32 rounding on the tile path
/// (EXPERIMENTS.md §Precision), so `F32` buys 2× bandwidth and spill
/// density at unchanged approximation quality.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum Precision {
    /// 32-bit tiles, 64-bit accumulation.
    F32,
    /// Full 64-bit tiles — the bit-compat reference path.
    #[default]
    F64,
}

impl Precision {
    /// Bytes per element at this width.
    #[inline]
    pub fn bytes(self) -> usize {
        match self {
            Precision::F32 => 4,
            Precision::F64 => 8,
        }
    }

    /// Stable lowercase name for logs / bench rows / service replies.
    #[inline]
    pub fn name(self) -> &'static str {
        match self {
            Precision::F32 => "f32",
            Precision::F64 => "f64",
        }
    }
}

/// Dense row-major matrix of `f64`.
#[derive(Clone, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl fmt::Debug for Matrix {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Matrix({}x{})", self.rows, self.cols)?;
        let rmax = self.rows.min(6);
        let cmax = self.cols.min(8);
        for i in 0..rmax {
            let row: Vec<String> = (0..cmax).map(|j| format!("{:9.4}", self[(i, j)])).collect();
            writeln!(f, "  [{}{}]", row.join(", "), if cmax < self.cols { ", ..." } else { "" })?;
        }
        if rmax < self.rows {
            writeln!(f, "  ...")?;
        }
        Ok(())
    }
}

impl std::ops::Index<(usize, usize)> for Matrix {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Matrix {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

impl Matrix {
    // ------------------------------------------------------- constructors

    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn identity(n: usize) -> Self {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Build from a function of (row, col).
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Matrix { rows, cols, data }
    }

    /// Wrap an existing row-major buffer.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols, "buffer size mismatch");
        Matrix { rows, cols, data }
    }

    /// Matrix with i.i.d. standard-normal entries.
    pub fn randn(rows: usize, cols: usize, rng: &mut Rng) -> Self {
        Matrix::from_fn(rows, cols, |_, _| rng.gaussian())
    }

    pub fn diag(values: &[f64]) -> Self {
        let n = values.len();
        let mut m = Matrix::zeros(n, n);
        for (i, &v) in values.iter().enumerate() {
            m[(i, i)] = v;
        }
        m
    }

    // ------------------------------------------------------------ queries

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn data(&self) -> &[f64] {
        &self.data
    }

    #[inline]
    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn col(&self, j: usize) -> Vec<f64> {
        (0..self.rows).map(|i| self[(i, j)]).collect()
    }

    // --------------------------------------------------------- structure

    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        // blocked transpose for cache friendliness
        const B: usize = 32;
        for i0 in (0..self.rows).step_by(B) {
            for j0 in (0..self.cols).step_by(B) {
                for i in i0..(i0 + B).min(self.rows) {
                    for j in j0..(j0 + B).min(self.cols) {
                        t[(j, i)] = self[(i, j)];
                    }
                }
            }
        }
        t
    }

    /// Rows selected by `idx` (may repeat / reorder).
    pub fn select_rows(&self, idx: &[usize]) -> Matrix {
        let mut out = Matrix::zeros(idx.len(), self.cols);
        for (r, &i) in idx.iter().enumerate() {
            out.row_mut(r).copy_from_slice(self.row(i));
        }
        out
    }

    /// Columns selected by `idx`.
    pub fn select_cols(&self, idx: &[usize]) -> Matrix {
        let mut out = Matrix::zeros(self.rows, idx.len());
        for i in 0..self.rows {
            let src = self.row(i);
            let dst = out.row_mut(i);
            for (c, &j) in idx.iter().enumerate() {
                dst[c] = src[j];
            }
        }
        out
    }

    /// Contiguous sub-block `[r0..r1) x [c0..c1)`.
    pub fn block(&self, r0: usize, r1: usize, c0: usize, c1: usize) -> Matrix {
        assert!(r0 <= r1 && r1 <= self.rows && c0 <= c1 && c1 <= self.cols);
        let mut out = Matrix::zeros(r1 - r0, c1 - c0);
        for i in r0..r1 {
            out.row_mut(i - r0).copy_from_slice(&self.row(i)[c0..c1]);
        }
        out
    }

    /// Write `src` into this matrix starting at (r0, c0).
    pub fn set_block(&mut self, r0: usize, c0: usize, src: &Matrix) {
        assert!(r0 + src.rows <= self.rows && c0 + src.cols <= self.cols);
        for i in 0..src.rows {
            self.row_mut(r0 + i)[c0..c0 + src.cols].copy_from_slice(src.row(i));
        }
    }

    /// Horizontal concatenation `[self | other]`.
    pub fn hcat(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.rows, other.rows);
        let mut out = Matrix::zeros(self.rows, self.cols + other.cols);
        out.set_block(0, 0, self);
        out.set_block(0, self.cols, other);
        out
    }

    /// Vertical concatenation.
    pub fn vcat(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.cols);
        let mut out = Matrix::zeros(self.rows + other.rows, self.cols);
        out.set_block(0, 0, self);
        out.set_block(self.rows, 0, other);
        out
    }

    // --------------------------------------------------------- arithmetic

    pub fn matmul(&self, other: &Matrix) -> Matrix {
        gemm::gemm(self, other)
    }

    /// `self^T * other` without forming the transpose.
    pub fn tr_matmul(&self, other: &Matrix) -> Matrix {
        gemm::gemm_tn(self, other)
    }

    /// `self * other^T` without forming the transpose.
    pub fn matmul_tr(&self, other: &Matrix) -> Matrix {
        gemm::gemm_nt(self, other)
    }

    /// Gram matrix `self * self^T` via the triangular [`gemm::syrk_nt`]
    /// path (~2x fewer FLOPs than `matmul_tr(self)`), exactly symmetric.
    pub fn gram_nt(&self) -> Matrix {
        gemm::syrk_nt(self)
    }

    /// Gram matrix `self^T * self` via [`gemm::syrk_tn`].
    pub fn gram_tn(&self) -> Matrix {
        gemm::syrk_tn(self)
    }

    /// Squared euclidean norm of every row (the RBF epilogue input).
    pub fn row_sq_norms(&self) -> Vec<f64> {
        (0..self.rows)
            .map(|i| self.row(i).iter().map(|v| v * v).sum())
            .collect()
    }

    /// `self += alpha * I` (ridge shifts; square matrices).
    pub fn add_diag(&mut self, alpha: f64) {
        assert_eq!(self.rows, self.cols);
        for i in 0..self.rows {
            self[(i, i)] += alpha;
        }
    }

    /// Matrix-vector product.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.cols);
        (0..self.rows)
            .map(|i| self.row(i).iter().zip(x).map(|(a, b)| a * b).sum())
            .collect()
    }

    /// `self^T * x`.
    pub fn tr_matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.rows);
        let mut out = vec![0.0; self.cols];
        for i in 0..self.rows {
            let xi = x[i];
            if xi == 0.0 {
                continue;
            }
            for (o, &a) in out.iter_mut().zip(self.row(i)) {
                *o += a * xi;
            }
        }
        out
    }

    pub fn add(&self, other: &Matrix) -> Matrix {
        self.zip(other, |a, b| a + b)
    }

    pub fn sub(&self, other: &Matrix) -> Matrix {
        self.zip(other, |a, b| a - b)
    }

    pub fn scale(&self, s: f64) -> Matrix {
        let mut out = self.clone();
        for v in out.data.iter_mut() {
            *v *= s;
        }
        out
    }

    fn zip(&self, other: &Matrix, f: impl Fn(f64, f64) -> f64) -> Matrix {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(&a, &b)| f(a, b))
            .collect();
        Matrix { rows: self.rows, cols: self.cols, data }
    }

    /// `self += alpha * other`.
    pub fn axpy(&mut self, alpha: f64, other: &Matrix) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, &b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
    }

    // ------------------------------------------------------------- norms

    pub fn fro_norm(&self) -> f64 {
        self.fro_norm_sq().sqrt()
    }

    pub fn fro_norm_sq(&self) -> f64 {
        self.data.iter().map(|&v| v * v).sum()
    }

    /// Spectral norm estimate via power iteration on `A^T A`.
    pub fn spectral_norm_est(&self, iters: usize, rng: &mut Rng) -> f64 {
        let mut v: Vec<f64> = (0..self.cols).map(|_| rng.gaussian()).collect();
        let mut norm = 0.0;
        for _ in 0..iters {
            let av = self.matvec(&v);
            let atav = self.tr_matvec(&av);
            norm = atav.iter().map(|x| x * x).sum::<f64>().sqrt().sqrt();
            let n2: f64 = atav.iter().map(|x| x * x).sum::<f64>().sqrt();
            if n2 == 0.0 {
                return 0.0;
            }
            v = atav.iter().map(|x| x / n2).collect();
        }
        norm
    }

    /// Trace (square matrices).
    pub fn trace(&self) -> f64 {
        assert_eq!(self.rows, self.cols);
        (0..self.rows).map(|i| self[(i, i)]).sum()
    }

    /// Max |a_ij - b_ij|.
    pub fn max_abs_diff(&self, other: &Matrix) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(&a, &b)| (a - b).abs())
            .fold(0.0, f64::max)
    }

    /// Symmetrize in place: `(A + A^T) / 2`.
    pub fn symmetrize(&mut self) {
        assert_eq!(self.rows, self.cols);
        for i in 0..self.rows {
            for j in (i + 1)..self.cols {
                let v = 0.5 * (self[(i, j)] + self[(j, i)]);
                self[(i, j)] = v;
                self[(j, i)] = v;
            }
        }
    }

    // ------------------------------------------------------- conversions

    pub fn to_f32(&self) -> Vec<f32> {
        self.data.iter().map(|&v| v as f32).collect()
    }

    pub fn from_f32(rows: usize, cols: usize, data: &[f32]) -> Matrix {
        assert_eq!(data.len(), rows * cols);
        Matrix {
            rows,
            cols,
            data: data.iter().map(|&v| v as f64).collect(),
        }
    }

    /// Demote to an f32 tile (round-to-nearest per element).
    pub fn demote(&self) -> MatrixF32 {
        MatrixF32 {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&v| v as f32).collect(),
        }
    }
}

/// Dense row-major `f32` tile buffer — the narrow half of the tile plane.
///
/// Deliberately minimal: tiles are produced (oracle/gemm), streamed,
/// spilled, and promoted into `f64` fold state; all algebra beyond the
/// tile product stays on [`Matrix`]. f32→f64 promotion is exact, so a
/// consumer that promotes-then-folds accumulates identically to a native
/// f64 fold over the same (rounded) tile values.
#[derive(Clone, PartialEq)]
pub struct MatrixF32 {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl fmt::Debug for MatrixF32 {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "MatrixF32({}x{})", self.rows, self.cols)
    }
}

impl MatrixF32 {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        MatrixF32 { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Wrap an existing row-major buffer.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "buffer size mismatch");
        MatrixF32 { rows, cols, data }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    #[inline]
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Promote to f64 (exact — every f32 is representable).
    pub fn promote(&self) -> Matrix {
        Matrix::from_f32(self.rows, self.cols, &self.data)
    }
}

/// A tile in either element width. Enum-tagged rather than generic so the
/// streaming channel, residency slots, and consumer dispatch stay
/// monomorphic — one pipeline, two payload widths.
#[derive(Clone, Debug)]
pub enum Tile {
    F64(Matrix),
    F32(MatrixF32),
}

impl Tile {
    #[inline]
    pub fn rows(&self) -> usize {
        match self {
            Tile::F64(m) => m.rows(),
            Tile::F32(m) => m.rows(),
        }
    }

    #[inline]
    pub fn cols(&self) -> usize {
        match self {
            Tile::F64(m) => m.cols(),
            Tile::F32(m) => m.cols(),
        }
    }

    /// Element width of this tile.
    #[inline]
    pub fn precision(&self) -> Precision {
        match self {
            Tile::F64(_) => Precision::F64,
            Tile::F32(_) => Precision::F32,
        }
    }

    /// Bytes of payload this tile occupies (header excluded).
    #[inline]
    pub fn payload_bytes(&self) -> u64 {
        (self.rows() * self.cols() * self.precision().bytes()) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> Matrix {
        Matrix::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0])
    }

    #[test]
    fn index_and_rowcol() {
        let m = small();
        assert_eq!(m[(0, 1)], 2.0);
        assert_eq!(m.row(1), &[4.0, 5.0, 6.0]);
        assert_eq!(m.col(2), vec![3.0, 6.0]);
    }

    #[test]
    fn transpose_roundtrip() {
        let m = small();
        let t = m.transpose();
        assert_eq!(t.rows(), 3);
        assert_eq!(t[(2, 1)], 6.0);
        assert_eq!(t.transpose(), m);
    }

    #[test]
    fn select_rows_cols() {
        let m = small();
        let r = m.select_rows(&[1, 0, 1]);
        assert_eq!(r.row(0), &[4.0, 5.0, 6.0]);
        assert_eq!(r.rows(), 3);
        let c = m.select_cols(&[2, 0]);
        assert_eq!(c.row(0), &[3.0, 1.0]);
    }

    #[test]
    fn block_and_set_block() {
        let m = small();
        let b = m.block(0, 2, 1, 3);
        assert_eq!(b.row(0), &[2.0, 3.0]);
        let mut z = Matrix::zeros(3, 4);
        z.set_block(1, 2, &b);
        assert_eq!(z[(1, 2)], 2.0);
        assert_eq!(z[(2, 3)], 6.0);
    }

    #[test]
    fn concat() {
        let m = small();
        let h = m.hcat(&m);
        assert_eq!(h.cols(), 6);
        assert_eq!(h[(1, 4)], 5.0);
        let v = m.vcat(&m);
        assert_eq!(v.rows(), 4);
        assert_eq!(v[(3, 0)], 4.0);
    }

    #[test]
    fn matvec_and_tr_matvec() {
        let m = small();
        assert_eq!(m.matvec(&[1.0, 0.0, 1.0]), vec![4.0, 10.0]);
        assert_eq!(m.tr_matvec(&[1.0, 1.0]), vec![5.0, 7.0, 9.0]);
    }

    #[test]
    fn arithmetic() {
        let m = small();
        assert_eq!(m.add(&m), m.scale(2.0));
        assert_eq!(m.sub(&m), Matrix::zeros(2, 3));
        let mut a = m.clone();
        a.axpy(2.0, &m);
        assert_eq!(a, m.scale(3.0));
    }

    #[test]
    fn norms_and_trace() {
        let m = Matrix::diag(&[3.0, 4.0]);
        assert!((m.fro_norm() - 5.0).abs() < 1e-12);
        assert_eq!(m.trace(), 7.0);
    }

    #[test]
    fn spectral_norm_of_diag() {
        let m = Matrix::diag(&[1.0, -7.0, 3.0]);
        let mut rng = Rng::new(0);
        let est = m.spectral_norm_est(50, &mut rng);
        assert!((est - 7.0).abs() < 1e-6, "est={est}");
    }

    #[test]
    fn gram_and_row_norms_and_add_diag() {
        let m = small();
        let g = m.gram_nt(); // 2x2
        assert!((g[(0, 0)] - 14.0).abs() < 1e-12);
        assert!((g[(0, 1)] - 32.0).abs() < 1e-12);
        assert_eq!(g[(0, 1)], g[(1, 0)]);
        let gt = m.gram_tn(); // 3x3
        assert!((gt[(0, 0)] - 17.0).abs() < 1e-12);
        assert_eq!(m.row_sq_norms(), vec![14.0, 77.0]);
        let mut d = Matrix::identity(2);
        d.add_diag(0.5);
        assert_eq!(d[(0, 0)], 1.5);
        assert_eq!(d[(0, 1)], 0.0);
    }

    #[test]
    fn symmetrize() {
        let mut m = Matrix::from_vec(2, 2, vec![1.0, 2.0, 4.0, 3.0]);
        m.symmetrize();
        assert_eq!(m[(0, 1)], 3.0);
        assert_eq!(m[(1, 0)], 3.0);
    }

    #[test]
    fn f32_roundtrip() {
        let m = small();
        let f = m.to_f32();
        let back = Matrix::from_f32(2, 3, &f);
        assert!(m.max_abs_diff(&back) < 1e-6);
    }

    #[test]
    fn precision_bytes_and_names() {
        assert_eq!(Precision::F32.bytes(), 4);
        assert_eq!(Precision::F64.bytes(), 8);
        assert_eq!(Precision::default(), Precision::F64);
        assert_eq!(Precision::F32.name(), "f32");
    }

    #[test]
    fn demote_promote_is_exact_for_f32_representable() {
        // Small integers are exactly representable in f32, so
        // demote → promote must be bit-exact for them.
        let m = small();
        let narrow = m.demote();
        assert_eq!(narrow.rows(), 2);
        assert_eq!(narrow.row(1), &[4.0f32, 5.0, 6.0]);
        let wide = narrow.promote();
        assert_eq!(wide, m);
    }

    #[test]
    fn tile_reports_width_and_payload() {
        let t64 = Tile::F64(Matrix::zeros(3, 5));
        let t32 = Tile::F32(MatrixF32::zeros(3, 5));
        assert_eq!(t64.precision(), Precision::F64);
        assert_eq!(t32.precision(), Precision::F32);
        assert_eq!((t64.rows(), t64.cols()), (3, 5));
        assert_eq!(t64.payload_bytes(), 3 * 5 * 8);
        assert_eq!(t32.payload_bytes(), 3 * 5 * 4);
    }

    #[test]
    #[should_panic]
    fn from_vec_size_mismatch_panics() {
        Matrix::from_vec(2, 2, vec![1.0]);
    }
}
