//! Bench: Table 4 — the five sketching families inside the fast model:
//! time to form S^T C and S^T K S and solve for U^fast.

use fastspsd::benchkit::{black_box, BenchSuite};
use fastspsd::coordinator::engine::rbf_cross_cpu;
use fastspsd::coordinator::oracle::DenseOracle;
use fastspsd::data::{make_blobs, sigma};
use fastspsd::sketch::SketchKind;
use fastspsd::exec::{self, ExecPolicy};
use fastspsd::spsd::{self, FastConfig};
use fastspsd::util::Rng;

fn main() {
    let n = 1024usize;
    let ds = make_blobs("bench", n, 16, 8, 2.0, 1);
    let sig = sigma::calibrate_sigma(&ds.x, 0.9, 400, 1);
    let k = rbf_cross_cpu(&ds.x, &ds.x, sigma::gamma_of_sigma(sig));
    let oracle = DenseOracle::new(k.clone());
    let c = (n / 100).max(8);
    let s = 8 * c;
    let mut rng = Rng::new(2);
    let p = spsd::uniform_p(n, c, &mut rng);

    let mut suite = BenchSuite::new(&format!("Table 4: sketches in the fast model (n={n}, c={c}, s={s})"));
    suite.header();
    for kind in [
        SketchKind::Uniform,
        SketchKind::Leverage { scaled: false },
        SketchKind::Leverage { scaled: true },
        SketchKind::Gaussian,
        SketchKind::Srht,
        SketchKind::CountSketch,
    ] {
        let cfg = FastConfig {
            s,
            kind,
            force_p_in_s: kind.is_column_selection(),
            leverage_basis: spsd::LeverageBasis::Gram,
        };
        let stats = suite.bench(kind.name(), || {
            let mut r = Rng::new(3);
            black_box(exec::fast(&oracle, &p, cfg, &ExecPolicy::Materialized, &mut r));
        });
        let _ = stats;
        // quality alongside cost
        let mut r = Rng::new(3);
        let a = exec::fast(&oracle, &p, cfg, &ExecPolicy::Materialized, &mut r).result;
        let err = k.sub(&a.materialize()).fro_norm_sq() / k.fro_norm_sq();
        println!("    rel_err[{}] = {err:.4e}", kind.name());
    }
    println!(
        "  expected shape: column selection ≈ fastest (sees nc+(s-c)^2 entries); projections pay nnz(K)·s"
    );
}
