//! Approximate spectral clustering (paper §6.4, following Fowlkes et al.).
//!
//! With `C U C^T ≈ K` as the weight matrix: degrees `d = C U (C^T 1)`,
//! normalized affinity `D^{-1/2} C U C^T D^{-1/2}`, whose top-k
//! eigenvectors come from the Lemma-10 trick on `(D^{-1/2} C, U)` in
//! O(n c^2). Rows are normalized and fed to k-means.

use super::kmeans::kmeans;
use crate::linalg::{solve, Matrix};
use crate::spsd::SpsdApprox;
use crate::util::Rng;

/// Spectral clustering from a low-rank kernel approximation.
pub fn spectral_cluster_from_approx(approx: &SpsdApprox, k: usize, rng: &mut Rng) -> Vec<usize> {
    let n = approx.c.rows();
    // degrees d = C (U (C^T 1))
    let ones = vec![1.0; n];
    let ct1 = approx.c.tr_matvec(&ones);
    let uct1 = approx.u.matvec(&ct1);
    let d = approx.c.matvec(&uct1);
    let dinv_sqrt: Vec<f64> = d
        .iter()
        .map(|&x| if x > 1e-12 { 1.0 / x.sqrt() } else { 0.0 })
        .collect();
    // C' = D^{-1/2} C; top-k eigenvectors of C' U C'^T
    let mut cprime = approx.c.clone();
    for i in 0..n {
        let s = dinv_sqrt[i];
        for v in cprime.row_mut(i) {
            *v *= s;
        }
    }
    let (_vals, vecs) = solve::eig_k_of_cuc(&cprime, &approx.u, k);
    cluster_rows(&vecs, k, rng)
}

/// Exact spectral clustering baseline (top-k of the dense normalized
/// affinity via Lanczos).
pub fn spectral_cluster_exact(kmat: &Matrix, k: usize, rng: &mut Rng) -> Vec<usize> {
    let n = kmat.rows();
    let ones = vec![1.0; n];
    let d = kmat.matvec(&ones);
    let mut norm = kmat.clone();
    for i in 0..n {
        let si = if d[i] > 1e-12 { 1.0 / d[i].sqrt() } else { 0.0 };
        for j in 0..n {
            let sj = if d[j] > 1e-12 { 1.0 / d[j].sqrt() } else { 0.0 };
            norm[(i, j)] *= si * sj;
        }
    }
    let (_vals, vecs) = crate::linalg::lanczos_top_k(&norm, k, 0x5BEC);
    cluster_rows(&vecs, k, rng)
}

/// Row-normalize the spectral embedding and run k-means.
fn cluster_rows(vecs: &Matrix, k: usize, rng: &mut Rng) -> Vec<usize> {
    let mut emb = vecs.clone();
    for i in 0..emb.rows() {
        let norm: f64 = emb.row(i).iter().map(|v| v * v).sum::<f64>().sqrt();
        if norm > 1e-12 {
            for v in emb.row_mut(i) {
                *v /= norm;
            }
        }
    }
    kmeans(&emb, k, 50, rng)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::metrics::nmi;
    use crate::coordinator::engine::rbf_cross_cpu;
    use crate::coordinator::oracle::DenseOracle;
    use crate::exec::{self, ExecPolicy};
    use crate::spsd::{uniform_p, FastConfig};

    /// Three well-separated 2-d blobs + their RBF kernel.
    fn blobs_kernel(n_per: usize, seed: u64) -> (Matrix, Vec<usize>) {
        let mut rng = Rng::new(seed);
        let n = 3 * n_per;
        let mut x = Matrix::zeros(n, 2);
        let mut labels = Vec::with_capacity(n);
        for i in 0..n {
            let c = i / n_per;
            let (cx, cy) = [(0.0, 0.0), (8.0, 0.0), (0.0, 8.0)][c];
            x[(i, 0)] = cx + rng.gaussian() * 0.5;
            x[(i, 1)] = cy + rng.gaussian() * 0.5;
            labels.push(c);
        }
        let k = rbf_cross_cpu(&x, &x, 0.5);
        (k, labels)
    }

    #[test]
    fn exact_spectral_recovers_blobs() {
        let (k, labels) = blobs_kernel(20, 0);
        let mut rng = Rng::new(1);
        let pred = spectral_cluster_exact(&k, 3, &mut rng);
        assert!(nmi(&pred, &labels) > 0.95, "nmi={}", nmi(&pred, &labels));
    }

    #[test]
    fn approx_spectral_recovers_blobs() {
        let (k, labels) = blobs_kernel(20, 2);
        let o = DenseOracle::new(k);
        let mut rng = Rng::new(3);
        let p = uniform_p(60, 12, &mut rng);
        let a = exec::fast(&o, &p, FastConfig::uniform(30), &ExecPolicy::Materialized, &mut rng).result;
        let pred = spectral_cluster_from_approx(&a, 3, &mut rng);
        assert!(nmi(&pred, &labels) > 0.9, "nmi={}", nmi(&pred, &labels));
    }
}
