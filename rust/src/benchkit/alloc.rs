//! Allocation high-water gauge: a counting `GlobalAlloc` wrapper so tests
//! and benches can *assert* the streaming memory bound instead of assuming
//! it.
//!
//! The counters live in this module as process-wide atomics; they only
//! move when a binary actually installs the wrapper:
//!
//! ```ignore
//! #[global_allocator]
//! static ALLOC: fastspsd::benchkit::alloc::CountingAlloc =
//!     fastspsd::benchkit::alloc::CountingAlloc;
//! ```
//!
//! (`tests/stream_memory.rs` and `benches/stream.rs` do exactly this; the
//! library itself never forces the wrapper on downstream users.) Without
//! installation [`installed`] stays false and gauges read zero — callers
//! must check it before trusting a measurement.
//!
//! [`AllocGauge`] measures *extra* peak: it marks the live-byte baseline at
//! start and reports how far the high-water rose above it. Measurements
//! are process-global, so run one gauged region at a time (the memory
//! tests live in a single `#[test]` for this reason).
//!
//! The gauge counts raw `Layout` bytes and is element-width-agnostic: an
//! f32 tile registers exactly half the bytes of its f64 twin, so the
//! mixed-precision plane's footprint saving shows up directly in
//! `peak_extra_bytes` with no unit conversion (compare the f32/f64 rows
//! in `benches/stream.rs`).

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};

static CURRENT: AtomicUsize = AtomicUsize::new(0);
static PEAK: AtomicUsize = AtomicUsize::new(0);
static INSTALLED: AtomicBool = AtomicBool::new(false);

/// Counting wrapper around the system allocator.
pub struct CountingAlloc;

fn record_alloc(size: usize) {
    INSTALLED.store(true, Ordering::Relaxed);
    let cur = CURRENT.fetch_add(size, Ordering::Relaxed) + size;
    PEAK.fetch_max(cur, Ordering::Relaxed);
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let p = System.alloc(layout);
        if !p.is_null() {
            record_alloc(layout.size());
        }
        p
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout);
        CURRENT.fetch_sub(layout.size(), Ordering::Relaxed);
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        let p = System.realloc(ptr, layout, new_size);
        if !p.is_null() {
            if new_size >= layout.size() {
                record_alloc(new_size - layout.size());
            } else {
                CURRENT.fetch_sub(layout.size() - new_size, Ordering::Relaxed);
            }
        }
        p
    }
}

/// True once the counting allocator has served at least one allocation —
/// i.e. the binary installed it as `#[global_allocator]`.
pub fn installed() -> bool {
    INSTALLED.load(Ordering::Relaxed)
}

/// Live heap bytes right now (0 unless installed).
pub fn current_bytes() -> usize {
    CURRENT.load(Ordering::Relaxed)
}

/// Process-wide allocation high-water mark since the last gauge reset.
pub fn peak_bytes() -> usize {
    PEAK.load(Ordering::Relaxed)
}

/// RAII-style measurement of peak allocation *above* the live baseline at
/// construction time.
pub struct AllocGauge {
    baseline: usize,
}

impl AllocGauge {
    /// Mark the baseline and reset the high-water mark to it.
    pub fn start() -> Self {
        let cur = CURRENT.load(Ordering::Relaxed);
        PEAK.store(cur, Ordering::Relaxed);
        AllocGauge { baseline: cur }
    }

    /// Bytes the high-water mark rose above the baseline since `start`.
    pub fn peak_extra_bytes(&self) -> usize {
        PEAK.load(Ordering::Relaxed).saturating_sub(self.baseline)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gauge_is_inert_without_installation() {
        // The library's own test binary does not install the wrapper, so
        // the counters must stay at zero and the gauge must read zero —
        // this is the contract that makes the gauge safe to ship in the
        // library without hijacking anyone's allocator.
        let g = AllocGauge::start();
        let v: Vec<u8> = vec![0u8; 1 << 16];
        assert_eq!(v.len(), 1 << 16);
        if !installed() {
            assert_eq!(g.peak_extra_bytes(), 0);
            assert_eq!(current_bytes(), 0);
        } else {
            // some other binary-level harness installed it: the vec above
            // must then have registered
            assert!(peak_bytes() > 0);
        }
    }
}
