//! `fastspsd` — Fast SPSD matrix approximation and CUR decomposition.
//!
//! Rust + JAX + Pallas reproduction of *Wang, Zhang & Zhang (2015), "Towards
//! More Efficient SPSD Matrix Approximation and CUR Matrix Decomposition"*.
//!
//! Layer map (see DESIGN.md):
//! - [`runtime`] loads the AOT-compiled HLO artifacts (Layer 1/2, authored in
//!   python/jax/pallas at build time) onto a PJRT CPU client.
//! - [`coordinator`] is the Layer-3 service: it tiles kernel matrices into
//!   fixed-shape blocks, routes block evaluations to PJRT executables across
//!   a worker pool, and assembles sketches without materializing `K`.
//! - [`spsd`] / [`cur`] implement the paper's models (Nyström, prototype,
//!   fast; CUR with optimal and fast `U`).
//! - [`exec`] is the execution-policy surface: one public entry per
//!   algorithm family, each taking an [`ExecPolicy`]
//!   (materialized / streamed / resident) and returning a [`RunReport`]
//!   with uniform accounting. The per-policy `_streamed`/`_budgeted`/
//!   `_resident` functions in [`spsd`], [`cur`] and `stream::implicit`
//!   are deprecated shims over it.
//! - [`stream`] is the tiled producer/consumer pipeline between the oracle
//!   and the models: row-tiles of `K` flow through fused consumers with a
//!   bounded double-buffered queue, so builds run with peak extra memory
//!   `O(tile_rows·c + s²)` instead of materializing `n x c` (or `n x n`)
//!   panels.
//! - [`shard`] is the row-sharded scale-out plane: N workers each run the
//!   streaming pipeline over a contiguous row-block of the kernel, the
//!   coordinator merges their tiny associative fold states
//!   ([`shard::ShardReduce`]) and finishes the solve once — same bits,
//!   per-worker working sets (EXPERIMENTS.md §Sharding).
//! - [`sketch`] implements the five sketching matrices of Lemma 2 / Table 4.
//! - [`obs`] is the always-on span tracer: per-request trace ids, a
//!   stable stage taxonomy over the hot seams (oracle tiles, pipeline
//!   produce/fold + stalls, residency hits/spills, solves), per-stage
//!   [`StageProfile`]s on every [`RunMeta`], and Chrome-trace export
//!   (EXPERIMENTS.md §Observability).
//! - [`linalg`], [`pool`], [`cli`], [`benchkit`], [`testkit`], [`util`] are
//!   substrates built from scratch (the image has no tokio/clap/criterion/
//!   proptest — see DESIGN.md §3).
//! - [`apps`] are the paper's evaluation workloads: KPCA, spectral
//!   clustering, KNN classification, and their metrics.
//! - [`data`] generates the synthetic stand-ins for the paper's LIBSVM
//!   datasets and the Fig-2 image.

pub mod apps;
pub mod benchkit;
pub mod figures;
pub mod cli;
pub mod coordinator;
pub mod cur;
pub mod exec;
pub mod data;
pub mod linalg;
pub mod obs;
pub mod pool;
pub mod runtime;
pub mod shard;
pub mod sketch;
pub mod spsd;
pub mod stream;
pub mod testkit;
pub mod util;

pub use exec::{DegradeAction, DegradeInfo, ExecPolicy, RunMeta, RunReport};
pub use linalg::{NumericHealth, Regularization};
pub use obs::StageProfile;
pub use stream::ValidateMode;
