//! Deterministic fault injection for the exec/service stack.
//!
//! A [`FaultPlan`] arms up to one fault per [`FaultPoint`]: the spill
//! arena's write and read paths (`stream::residency`), silent record
//! corruption at write time ([`FaultPoint::SpillCorrupt`], caught by the
//! checksum on read-back), the kernel oracle's tile production (via
//! [`FaultyOracle`]), NaN-poisoning of produced tiles
//! ([`FaultPoint::PoisonTile`], caught by `ValidateMode`), and the
//! consumer fold inside `stream::run_pipeline` (globally armed, or
//! per-consumer via [`FaultyConsumer`]). Faults are counted in
//! *operations at that point*:
//! `at = N` trips on the Nth operation, `persistent` keeps tripping from
//! the Nth on, `at = 0` never trips. Everything is driven by explicit
//! numbers or a seed ([`FaultPlan::seeded`]), so every chaos run replays
//! bit-for-bit.
//!
//! Plans reach library seams through a process-global arm slot:
//! [`arm`] installs a plan and returns a guard that restores the previous
//! plan on drop; [`current`] is what `residency`/`pipeline` consult. Tests
//! that arm a plan must serialize (the slot is process-wide) — the chaos
//! suite does this with a single mutex.

use crate::coordinator::oracle::KernelOracle;
use crate::linalg::Matrix;
use crate::stream::TileConsumer;
use crate::util::Rng;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// Where a fault can be injected.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultPoint {
    /// A spill-arena tile write fails (ENOSPC-style: the write returns
    /// nothing and the tile is not persisted).
    SpillWrite,
    /// A spill-arena tile read fails (short read / IO error).
    SpillRead,
    /// The kernel oracle panics while producing a tile.
    OracleTile,
    /// A consumer fold panics mid-pipeline.
    ConsumerFold,
    /// A spill-arena record is silently corrupted at write time (one
    /// payload byte flipped after the checksum is computed) — the bit-rot
    /// seam. Detected on read-back as `ResidencyStats::corrupt_reads`.
    SpillCorrupt,
    /// The pipeline producer poisons a tile with a NaN before sending it
    /// — the seam `ValidateMode` quarantines.
    PoisonTile,
    /// A shard worker dies (panics) at the start of its row-block pass —
    /// the scale-out seam. Transient: the coordinator re-executes the
    /// row-range, bit-identical. Persistent: the second attempt dies too
    /// and the panic propagates to the service's typed-error machinery.
    ShardWorkerDeath,
}

/// Every fault point, in index order.
pub const FAULT_POINTS: [FaultPoint; 7] = [
    FaultPoint::SpillWrite,
    FaultPoint::SpillRead,
    FaultPoint::OracleTile,
    FaultPoint::ConsumerFold,
    FaultPoint::SpillCorrupt,
    FaultPoint::PoisonTile,
    FaultPoint::ShardWorkerDeath,
];

impl FaultPoint {
    fn idx(self) -> usize {
        match self {
            FaultPoint::SpillWrite => 0,
            FaultPoint::SpillRead => 1,
            FaultPoint::OracleTile => 2,
            FaultPoint::ConsumerFold => 3,
            FaultPoint::SpillCorrupt => 4,
            FaultPoint::PoisonTile => 5,
            FaultPoint::ShardWorkerDeath => 6,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            FaultPoint::SpillWrite => "spill write",
            FaultPoint::SpillRead => "spill read",
            FaultPoint::OracleTile => "oracle tile",
            FaultPoint::ConsumerFold => "consumer fold",
            FaultPoint::SpillCorrupt => "spill corrupt",
            FaultPoint::PoisonTile => "poisoned tile",
            FaultPoint::ShardWorkerDeath => "shard worker death",
        }
    }
}

/// When a fault point trips: on the `at`-th operation (1-based), once
/// (`persistent = false`) or on every operation from the `at`-th on.
/// `at = 0` disarms the point.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultSpec {
    pub at: u64,
    pub persistent: bool,
}

impl FaultSpec {
    pub fn never() -> Self {
        FaultSpec { at: 0, persistent: false }
    }

    /// Fail exactly the `at`-th operation, then recover.
    pub fn transient(at: u64) -> Self {
        FaultSpec { at, persistent: false }
    }

    /// Fail every operation from the `at`-th on.
    pub fn persistent(at: u64) -> Self {
        FaultSpec { at, persistent: true }
    }

    fn trips(&self, op: u64) -> bool {
        self.at != 0 && (op == self.at || (self.persistent && op > self.at))
    }
}

/// A deterministic fault schedule over the seven [`FaultPoint`]s, with
/// per-point operation and injection counters for post-mortem assertions.
#[derive(Debug, Default)]
pub struct FaultPlan {
    specs: [FaultSpec; 7],
    ops: [AtomicU64; 7],
    injected: [AtomicU64; 7],
}

impl Default for FaultSpec {
    fn default() -> Self {
        FaultSpec::never()
    }
}

impl FaultPlan {
    /// A plan with every point disarmed (all counters still tick).
    pub fn none() -> Self {
        FaultPlan::default()
    }

    /// Builder: arm `point` with `spec`.
    pub fn fail(mut self, point: FaultPoint, spec: FaultSpec) -> Self {
        self.specs[point.idx()] = spec;
        self
    }

    /// A seed-driven plan: each point is independently armed with a small
    /// `at` and a random persistence bit; at least one point is always
    /// armed so a seeded plan is never a no-op.
    pub fn seeded(seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let mut plan = FaultPlan::none();
        for i in 0..plan.specs.len() {
            if rng.usize_below(2) == 1 {
                plan.specs[i] = FaultSpec {
                    at: 1 + rng.usize_below(3) as u64,
                    persistent: rng.usize_below(2) == 1,
                };
            }
        }
        if plan.specs.iter().all(|s| s.at == 0) {
            plan.specs[rng.usize_below(plan.specs.len())] = FaultSpec::transient(1);
        }
        plan
    }

    /// Count one operation at `point`; true when this operation must fail.
    pub fn should_fail(&self, point: FaultPoint) -> bool {
        let i = point.idx();
        let op = self.ops[i].fetch_add(1, Ordering::SeqCst) + 1;
        let trip = self.specs[i].trips(op);
        if trip {
            self.injected[i].fetch_add(1, Ordering::SeqCst);
        }
        trip
    }

    /// Operations observed at `point` so far.
    pub fn ops(&self, point: FaultPoint) -> u64 {
        self.ops[point.idx()].load(Ordering::SeqCst)
    }

    /// Faults actually injected at `point` so far.
    pub fn injected(&self, point: FaultPoint) -> u64 {
        self.injected[point.idx()].load(Ordering::SeqCst)
    }

    /// The armed spec at `point`.
    pub fn spec(&self, point: FaultPoint) -> FaultSpec {
        self.specs[point.idx()]
    }
}

fn armed() -> &'static Mutex<Option<Arc<FaultPlan>>> {
    static ARMED: OnceLock<Mutex<Option<Arc<FaultPlan>>>> = OnceLock::new();
    ARMED.get_or_init(|| Mutex::new(None))
}

/// The globally armed plan, if any. Library seams (spill arena, pipeline
/// fold) call this once per operation scope; it is `None` in normal runs.
pub fn current() -> Option<Arc<FaultPlan>> {
    armed().lock().unwrap().clone()
}

/// Install `plan` as the process-global fault plan until the returned
/// guard drops (which restores whatever was armed before).
#[must_use = "dropping the guard immediately disarms the plan"]
pub fn arm(plan: Arc<FaultPlan>) -> ArmedGuard {
    ArmedGuard { prev: armed().lock().unwrap().replace(plan) }
}

/// Disarms (or restores the previous plan) on drop.
pub struct ArmedGuard {
    prev: Option<Arc<FaultPlan>>,
}

impl Drop for ArmedGuard {
    fn drop(&mut self) {
        *armed().lock().unwrap() = self.prev.take();
    }
}

/// A [`KernelOracle`] wrapper that panics on the scheduled tile-producing
/// call (`block`, `row_block`, or `full_rows` each count as one
/// [`FaultPoint::OracleTile`] operation).
pub struct FaultyOracle {
    inner: Arc<dyn KernelOracle + Send + Sync>,
    plan: Arc<FaultPlan>,
}

impl FaultyOracle {
    pub fn new(inner: Arc<dyn KernelOracle + Send + Sync>, plan: Arc<FaultPlan>) -> Self {
        FaultyOracle { inner, plan }
    }

    fn trip(&self) {
        if self.plan.should_fail(FaultPoint::OracleTile) {
            panic!(
                "injected fault: oracle tile (op {})",
                self.plan.ops(FaultPoint::OracleTile)
            );
        }
    }
}

impl KernelOracle for FaultyOracle {
    fn n(&self) -> usize {
        self.inner.n()
    }

    fn block(&self, rows: &[usize], cols: &[usize]) -> Matrix {
        self.trip();
        self.inner.block(rows, cols)
    }

    fn row_block(&self, r0: usize, r1: usize, cols: &[usize]) -> Matrix {
        self.trip();
        self.inner.row_block(r0, r1, cols)
    }

    fn full_rows(&self, r0: usize, r1: usize) -> Matrix {
        self.trip();
        self.inner.full_rows(r0, r1)
    }

    fn entries_observed(&self) -> u64 {
        self.inner.entries_observed()
    }

    fn reset_entries(&self) {
        self.inner.reset_entries();
    }
}

/// A [`TileConsumer`] that panics on the scheduled fold (counts its own
/// folds against the plan's [`FaultPoint::ConsumerFold`] spec — no global
/// arming needed).
pub struct FaultyConsumer {
    plan: Arc<FaultPlan>,
    pub folds: u64,
}

impl FaultyConsumer {
    pub fn new(plan: Arc<FaultPlan>) -> Self {
        FaultyConsumer { plan, folds: 0 }
    }
}

impl TileConsumer for FaultyConsumer {
    fn consume(&mut self, r0: usize, _tile: &Matrix) {
        self.folds += 1;
        if self.plan.should_fail(FaultPoint::ConsumerFold) {
            panic!("injected fault: consumer fold at r0={r0}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transient_trips_exactly_once() {
        let p = FaultPlan::none().fail(FaultPoint::SpillWrite, FaultSpec::transient(2));
        let hits: Vec<bool> = (0..5).map(|_| p.should_fail(FaultPoint::SpillWrite)).collect();
        assert_eq!(hits, [false, true, false, false, false]);
        assert_eq!(p.ops(FaultPoint::SpillWrite), 5);
        assert_eq!(p.injected(FaultPoint::SpillWrite), 1);
        // other points untouched
        assert!(!p.should_fail(FaultPoint::SpillRead));
    }

    #[test]
    fn persistent_trips_from_at_onward() {
        let p = FaultPlan::none().fail(FaultPoint::SpillRead, FaultSpec::persistent(3));
        let hits: Vec<bool> = (0..5).map(|_| p.should_fail(FaultPoint::SpillRead)).collect();
        assert_eq!(hits, [false, false, true, true, true]);
        assert_eq!(p.injected(FaultPoint::SpillRead), 3);
    }

    #[test]
    fn seeded_plans_are_deterministic_and_armed() {
        for seed in [0u64, 11, 23, 47, 0xDEAD] {
            let a = FaultPlan::seeded(seed);
            let b = FaultPlan::seeded(seed);
            for pt in FAULT_POINTS {
                assert_eq!(a.spec(pt), b.spec(pt), "seed {seed} must replay");
            }
            assert!(
                FAULT_POINTS.iter().any(|&pt| a.spec(pt).at != 0),
                "seed {seed}: at least one point armed"
            );
        }
    }

    #[test]
    fn arm_guard_restores_previous_plan() {
        // Runs in the lib test binary; no other lib test arms plans.
        let outer = Arc::new(FaultPlan::none().fail(FaultPoint::OracleTile, FaultSpec::transient(1)));
        let g1 = arm(Arc::clone(&outer));
        assert_eq!(current().unwrap().spec(FaultPoint::OracleTile), FaultSpec::transient(1));
        {
            let inner = Arc::new(FaultPlan::none());
            let _g2 = arm(inner);
            assert_eq!(current().unwrap().spec(FaultPoint::OracleTile), FaultSpec::never());
        }
        assert_eq!(current().unwrap().spec(FaultPoint::OracleTile), FaultSpec::transient(1));
        drop(g1);
        assert!(current().is_none());
    }
}
