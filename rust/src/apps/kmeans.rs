//! Lloyd's k-means with k-means++ initialization (used by the spectral
//! clustering pipeline of §6.4).

use crate::linalg::Matrix;
use crate::util::Rng;

/// Cluster rows of `points` into `k` groups; returns per-row assignments.
pub fn kmeans(points: &Matrix, k: usize, max_iters: usize, rng: &mut Rng) -> Vec<usize> {
    let n = points.rows();
    let d = points.cols();
    assert!(k >= 1 && n >= 1);
    let k = k.min(n);

    // --- k-means++ seeding
    let mut centers = Matrix::zeros(k, d);
    let first = rng.usize_below(n);
    centers.row_mut(0).copy_from_slice(points.row(first));
    let mut dist2 = vec![f64::INFINITY; n];
    for c in 1..k {
        for i in 0..n {
            let dd = sqdist(points.row(i), centers.row(c - 1));
            if dd < dist2[i] {
                dist2[i] = dd;
            }
        }
        let next = rng.weighted_index(&dist2);
        centers.row_mut(c).copy_from_slice(points.row(next));
    }

    // --- Lloyd iterations
    let mut assign = vec![0usize; n];
    for _ in 0..max_iters {
        let mut changed = false;
        for i in 0..n {
            let mut best = 0usize;
            let mut bd = f64::INFINITY;
            for c in 0..k {
                let dd = sqdist(points.row(i), centers.row(c));
                if dd < bd {
                    bd = dd;
                    best = c;
                }
            }
            if assign[i] != best {
                assign[i] = best;
                changed = true;
            }
        }
        if !changed {
            break;
        }
        // recompute centers; re-seed empty clusters at the farthest point
        let mut counts = vec![0usize; k];
        let mut sums = Matrix::zeros(k, d);
        for i in 0..n {
            counts[assign[i]] += 1;
            let row = points.row(i);
            let dst = sums.row_mut(assign[i]);
            for (s, &v) in dst.iter_mut().zip(row) {
                *s += v;
            }
        }
        for c in 0..k {
            if counts[c] == 0 {
                let far = (0..n)
                    .max_by(|&a, &b| {
                        sqdist(points.row(a), centers.row(assign[a]))
                            .partial_cmp(&sqdist(points.row(b), centers.row(assign[b])))
                            .unwrap()
                    })
                    .unwrap();
                centers.row_mut(c).copy_from_slice(points.row(far));
            } else {
                let inv = 1.0 / counts[c] as f64;
                for j in 0..d {
                    centers[(c, j)] = sums[(c, j)] * inv;
                }
            }
        }
    }
    assign
}

#[inline]
fn sqdist(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn separates_obvious_blobs() {
        let mut rng = Rng::new(0);
        let mut pts = Matrix::zeros(60, 2);
        for i in 0..60 {
            let c = i % 3;
            pts[(i, 0)] = c as f64 * 20.0 + rng.gaussian() * 0.5;
            pts[(i, 1)] = rng.gaussian() * 0.5;
        }
        let assign = kmeans(&pts, 3, 50, &mut rng);
        // all points of the same true blob share a label
        for blob in 0..3 {
            let labels: Vec<usize> = (0..60).filter(|i| i % 3 == blob).map(|i| assign[i]).collect();
            assert!(labels.windows(2).all(|w| w[0] == w[1]), "blob {blob} split");
        }
    }

    #[test]
    fn k_one_assigns_all_zero() {
        let mut rng = Rng::new(1);
        let pts = Matrix::randn(10, 3, &mut rng);
        assert!(kmeans(&pts, 1, 10, &mut rng).iter().all(|&a| a == 0));
    }

    #[test]
    fn k_clamped_to_n() {
        let mut rng = Rng::new(2);
        let pts = Matrix::randn(3, 2, &mut rng);
        let a = kmeans(&pts, 10, 5, &mut rng);
        assert_eq!(a.len(), 3);
        assert!(a.iter().all(|&c| c < 3));
    }
}
