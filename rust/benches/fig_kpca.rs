//! Bench: Figures 5/6 — KPCA misalignment vs time/memory at bench scale.

use fastspsd::cli::Args;
use fastspsd::figures::{kpca_fig, Ctx};

fn main() {
    let args = Args::parse(
        [
            "fig5", "--scale", "0.05", "--reps", "1", "--dataset", "PenDigit", "--cpu",
            "--cs", "10,20,40", "--out", "out",
        ]
        .iter()
        .map(|s| s.to_string()),
    );
    let ctx = Ctx::from_args(&args);
    println!("== Fig 5/6 series (bench scale) ==");
    kpca_fig::run(&ctx, &args);
}
