//! Wall-clock timing helpers.

use std::time::{Duration, Instant};

/// A simple stopwatch for measuring elapsed phases.
#[derive(Debug)]
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    /// Elapsed seconds as f64.
    pub fn secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    /// Restart and return the lap duration.
    pub fn lap(&mut self) -> Duration {
        let e = self.start.elapsed();
        self.start = Instant::now();
        e
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn elapsed_monotone() {
        let sw = Stopwatch::start();
        let a = sw.elapsed();
        let b = sw.elapsed();
        assert!(b >= a);
    }

    #[test]
    fn lap_resets() {
        let mut sw = Stopwatch::start();
        std::thread::sleep(Duration::from_millis(2));
        let lap = sw.lap();
        assert!(lap >= Duration::from_millis(1));
        assert!(sw.elapsed() < lap + Duration::from_millis(50));
    }
}
