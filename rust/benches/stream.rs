//! Bench: streamed-vs-materialized build throughput for the tile pipeline
//! (EXPERIMENTS.md §Streaming).
//!
//! Emits machine-readable `BENCH_stream.json` (quick mode:
//! `BENCH_stream.quick.json`, via the same `FASTSPSD_BENCH_QUICK=1` flag
//! as the hotpath bench) with one entry per (model, path, tile) so the
//! streamed-within-10%-of-materialized acceptance bar is checkable across
//! PRs. Also prints the allocation gauge's peak for each path — the bench
//! binary installs the counting allocator, so the memory numbers here are
//! real, not predicted.

use fastspsd::benchkit::alloc::{AllocGauge, CountingAlloc};
use fastspsd::benchkit::{black_box, BenchSuite};
use fastspsd::coordinator::oracle::{DenseOracle, KernelOracle, RbfOracle};
use fastspsd::cur::FastCurConfig;
use fastspsd::exec::{self, ExecPolicy};
use fastspsd::linalg::Matrix;
use fastspsd::spsd::{self, FastConfig, LeverageBasis};
use fastspsd::stream::{OracleColumnsSource, Precision, ValidateMode};
use fastspsd::util::Rng;
use std::sync::Arc;

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

/// Default streaming tile height (the acceptance bar's "default tile").
const DEFAULT_TILE: usize = 256;

fn fmt_mib(bytes: usize) -> String {
    format!("{:.2} MiB", bytes as f64 / (1024.0 * 1024.0))
}

/// Peak extra allocation of one run of `f`, measured AFTER the suite's
/// bench pass has already warmed pool threads and grow-only pack buffers
/// (EXPERIMENTS.md §Streaming measurement method).
fn gauged<R>(mut f: impl FnMut() -> R) -> usize {
    let g = AllocGauge::start();
    black_box(f());
    g.peak_extra_bytes()
}

fn main() {
    let quick = fastspsd::benchkit::quick_mode();
    let mut suite = BenchSuite::new("stream pipeline");
    suite.header();
    println!("  ({} worker threads)", fastspsd::pool::configured_threads());

    // ---- fast model on an RBF oracle: the headline path ----------------
    let n = if quick { 800 } else { 3000 };
    let (c, s) = (32, 96);
    let mut rng = Rng::new(0);
    let x = Arc::new(Matrix::randn(n, 16, &mut rng));
    let oracle = RbfOracle::cpu(x, 0.4);
    let p = spsd::uniform_p(n, c, &mut rng);

    let mat = ExecPolicy::Materialized;
    suite.bench(&format!("fast[uniform] materialized n={n}"), || {
        black_box(exec::fast(&oracle, &p, FastConfig::uniform(s), &mat, &mut Rng::new(1)));
    });
    let peak = gauged(|| exec::fast(&oracle, &p, FastConfig::uniform(s), &mat, &mut Rng::new(1)));
    println!("    peak extra: {}", fmt_mib(peak));
    for tile in [64usize, DEFAULT_TILE] {
        let pol = ExecPolicy::streamed(tile);
        suite.bench(&format!("fast[uniform] streamed t={tile} n={n}"), || {
            black_box(exec::fast(&oracle, &p, FastConfig::uniform(s), &pol, &mut Rng::new(1)));
        });
        let peak =
            gauged(|| exec::fast(&oracle, &p, FastConfig::uniform(s), &pol, &mut Rng::new(1)));
        println!("    peak extra: {}", fmt_mib(peak));
    }
    if let (Some(mat), Some(st)) = (
        suite.mean_of(&format!("fast[uniform] materialized n={n}")),
        suite.mean_of(&format!("fast[uniform] streamed t={DEFAULT_TILE} n={n}")),
    ) {
        println!("    streamed/materialized at default tile: {:.3}x", st / mat);
    }
    // f32 tile plane: the same streamed build with half-width tiles (outputs
    // and fold state stay f64) — the wall-time and peak-extra deltas against
    // the f64 row above are what the narrow plane buys end to end.
    {
        let pol32 = ExecPolicy::streamed(DEFAULT_TILE).with_precision(Precision::F32);
        suite.bench(&format!("fast[uniform] streamed f32 t={DEFAULT_TILE} n={n}"), || {
            black_box(exec::fast(&oracle, &p, FastConfig::uniform(s), &pol32, &mut Rng::new(1)));
        });
        let peak32 =
            gauged(|| exec::fast(&oracle, &p, FastConfig::uniform(s), &pol32, &mut Rng::new(1)));
        println!("    peak extra: {}", fmt_mib(peak32));
        if let (Some(wide), Some(narrow)) = (
            suite.mean_of(&format!("fast[uniform] streamed t={DEFAULT_TILE} n={n}")),
            suite.mean_of(&format!("fast[uniform] streamed f32 t={DEFAULT_TILE} n={n}")),
        ) {
            println!("    f32/f64 streamed wall time: {:.3}x", narrow / wide);
        }
    }

    // ---- fast model, leverage family (streamed Gram scores) -------------
    suite.bench(&format!("fast[leverage] materialized n={n}"), || {
        black_box(exec::fast(&oracle, &p, FastConfig::leverage(s), &mat, &mut Rng::new(5)));
    });
    let peak = gauged(|| exec::fast(&oracle, &p, FastConfig::leverage(s), &mat, &mut Rng::new(5)));
    println!("    peak extra: {}", fmt_mib(peak));
    let tiled = ExecPolicy::streamed(DEFAULT_TILE);
    suite.bench(&format!("fast[leverage] streamed t={DEFAULT_TILE} n={n}"), || {
        black_box(exec::fast(&oracle, &p, FastConfig::leverage(s), &tiled, &mut Rng::new(5)));
    });
    let peak =
        gauged(|| exec::fast(&oracle, &p, FastConfig::leverage(s), &tiled, &mut Rng::new(5)));
    println!("    peak extra: {}", fmt_mib(peak));
    // reference: the historical resident-SVD scoring (O(n·c) scratch) —
    // the memory delta against the Gram rows above is the tentpole win
    let svd_cfg = FastConfig::leverage(s).with_basis(LeverageBasis::ExactSvd);
    suite.bench(&format!("fast[leverage-svd] materialized n={n}"), || {
        black_box(exec::fast(&oracle, &p, svd_cfg, &mat, &mut Rng::new(5)));
    });
    let peak = gauged(|| exec::fast(&oracle, &p, svd_cfg, &mat, &mut Rng::new(5)));
    println!("    peak extra: {}", fmt_mib(peak));

    // ---- nystrom --------------------------------------------------------
    suite.bench(&format!("nystrom materialized n={n}"), || {
        black_box(exec::nystrom(&oracle, &p, &mat));
    });
    let peak = gauged(|| exec::nystrom(&oracle, &p, &mat));
    println!("    peak extra: {}", fmt_mib(peak));
    suite.bench(&format!("nystrom streamed t={DEFAULT_TILE} n={n}"), || {
        black_box(exec::nystrom(&oracle, &p, &tiled));
    });
    let peak = gauged(|| exec::nystrom(&oracle, &p, &tiled));
    println!("    peak extra: {}", fmt_mib(peak));

    // ---- prototype (the n² -> tile·n memory win) ------------------------
    let np = if quick { 500 } else { 1200 };
    let mut rng = Rng::new(2);
    let xp = Arc::new(Matrix::randn(np, 16, &mut rng));
    let oracle_p = RbfOracle::cpu(xp, 0.4);
    let pp = spsd::uniform_p(np, c, &mut rng);
    suite.bench(&format!("prototype materialized n={np}"), || {
        black_box(exec::prototype(&oracle_p, &pp, &mat));
    });
    let peak = gauged(|| exec::prototype(&oracle_p, &pp, &mat));
    println!("    peak extra: {}", fmt_mib(peak));
    suite.bench(&format!("prototype streamed t={DEFAULT_TILE} n={np}"), || {
        black_box(exec::prototype(&oracle_p, &pp, &tiled));
    });
    let peak = gauged(|| exec::prototype(&oracle_p, &pp, &tiled));
    println!("    peak extra: {}", fmt_mib(peak));

    // ---- implicit ops: residency vs re-streaming Lanczos ----------------
    // The headline of the residency layer: q Lanczos iterations against the
    // implicit C·U·Cᵀ cost one n·c kernel observation instead of re-paying
    // the oracle every pass — at any RAM budget once spill is on. Rows
    // report oracle entries, cache hits and spilled bytes next to wall time.
    let k_eigs = 4;
    let u_id = Matrix::identity(c);
    let src = OracleColumnsSource::new(&oracle, &p);
    suite.bench(&format!("implicit top-k restream t={DEFAULT_TILE} n={n}"), || {
        black_box(exec::top_k_eigs(&src, &u_id, k_eigs, 7, &tiled));
    });
    oracle.reset_entries();
    let _ = exec::top_k_eigs(&src, &u_id, k_eigs, 7, &tiled);
    let entries_restream = oracle.entries_observed();
    println!(
        "    oracle entries: {entries_restream} ({}x one n·c)",
        entries_restream / (n as u64 * c as u64)
    );
    // resident[ram] is the all-RAM bound: ram_cached, so no arena write-
    // through pollutes the wall time. resident[spill] is the all-disk one.
    for (label, pol) in [
        ("resident[ram]", ExecPolicy::ram_cached(u64::MAX).with_tile_rows(DEFAULT_TILE)),
        ("resident[spill]", ExecPolicy::resident(0).with_tile_rows(DEFAULT_TILE)),
    ] {
        suite.bench(&format!("implicit top-k {label} t={DEFAULT_TILE} n={n}"), || {
            black_box(exec::top_k_eigs(&src, &u_id, k_eigs, 7, &pol));
        });
        oracle.reset_entries();
        let st = exec::top_k_eigs(&src, &u_id, k_eigs, 7, &pol)
            .meta
            .residency
            .expect("resident policies report stats");
        println!(
            "    oracle entries: {} (one n·c = {}), ram hits {}, spill hits {}, spilled {}",
            oracle.entries_observed(),
            n * c,
            st.ram_hits,
            st.spill_hits,
            fmt_mib(st.spilled_bytes as usize)
        );
        if label == "resident[spill]" {
            suite.counter("residency.spilled_bytes_f64", st.spilled_bytes as f64);
        }
    }
    // f32 residency: the same spill-everything policy at half element width —
    // spilled bytes halve (the arena's accounting is payload-only), while the
    // eigenvalues still come out of f64 fold state. The counter pair above/
    // below lands in BENCH_stream.json so the halving is tracked like timings.
    {
        let pol32 =
            ExecPolicy::resident(0).with_tile_rows(DEFAULT_TILE).with_precision(Precision::F32);
        suite.bench(&format!("implicit top-k resident[spill] f32 t={DEFAULT_TILE} n={n}"), || {
            black_box(exec::top_k_eigs(&src, &u_id, k_eigs, 7, &pol32));
        });
        let st32 = exec::top_k_eigs(&src, &u_id, k_eigs, 7, &pol32)
            .meta
            .residency
            .expect("resident policies report stats");
        println!(
            "    spilled {} (exactly half the f64 row's bytes)",
            fmt_mib(st32.spilled_bytes as usize)
        );
        suite.counter("residency.spilled_bytes_f32", st32.spilled_bytes as f64);
    }

    // ---- CUR over a dense matrix ---------------------------------------
    let (m_cur, n_cur) = if quick { (600, 450) } else { (2000, 1500) };
    let mut rng = Rng::new(3);
    let a = Matrix::randn(m_cur, n_cur, &mut rng);
    let cols = fastspsd::cur::select_uniform(n_cur, 40, &mut rng);
    let rows = fastspsd::cur::select_uniform(m_cur, 40, &mut rng);
    suite.bench(&format!("cur_fast materialized {m_cur}x{n_cur}"), || {
        black_box(exec::cur_fast(
            &a,
            &cols,
            &rows,
            FastCurConfig::uniform(120, 120),
            &mat,
            &mut Rng::new(4),
        ));
    });
    suite.bench(&format!("cur_fast streamed t={DEFAULT_TILE} {m_cur}x{n_cur}"), || {
        black_box(exec::cur_fast(
            &a,
            &cols,
            &rows,
            FastCurConfig::uniform(120, 120),
            &tiled,
            &mut Rng::new(4),
        ));
    });

    // ---- robustness: degrade-don't-die service under a memory cap -------
    // A burst against a cap sized for one uniform-fast request: nystrom
    // requests queue and complete as the meter drains; leverage requests
    // can never fit as asked and are served down the degrade ladder
    // (leverage → uniform). The counters land in BENCH_stream.json so the
    // queue/degrade/reject trajectory is tracked like the timings.
    {
        use fastspsd::coordinator::{
            planner, ApproxRequest, ApproxService, MethodSpec, ServiceConfig,
        };
        use fastspsd::sketch::SketchKind;
        let n_svc = if quick { 400 } else { 800 };
        let (c_svc, s_svc) = (16, 48);
        let mut rng = Rng::new(9);
        let svc_oracle: Arc<dyn KernelOracle + Send + Sync> =
            Arc::new(RbfOracle::cpu(Arc::new(Matrix::randn(n_svc, 16, &mut rng)), 0.4));
        let uni = MethodSpec::Fast { s: s_svc, kind: SketchKind::Uniform };
        let lev = MethodSpec::Fast { s: s_svc, kind: SketchKind::Leverage { scaled: false } };
        let cap = planner::predicted_policy_peak_bytes(
            n_svc,
            c_svc,
            &uni,
            &ExecPolicy::Materialized,
        );
        let svc = ApproxService::new(
            Arc::clone(&svc_oracle),
            ServiceConfig { workers: 2, memory_cap: Some(cap), ..Default::default() },
        );
        let (tx, rx) = std::sync::mpsc::channel();
        let burst = 12u64;
        let sw = std::time::Instant::now();
        for i in 0..burst {
            let method = if i % 6 == 5 { lev } else { MethodSpec::Nystrom };
            svc.submit(
                ApproxRequest {
                    id: i,
                    method,
                    c: c_svc,
                    k: 4,
                    seed: i,
                    policy: None,
                    precision: fastspsd::stream::Precision::F64,
                    deadline: None,
                },
                tx.clone(),
            );
        }
        svc.drain();
        drop(tx);
        let resps: Vec<_> = rx.iter().collect();
        println!(
            "  capped service burst: {} requests in {:.3} s (cap = one uniform-fast)",
            resps.len(),
            sw.elapsed().as_secs_f64()
        );
        let m = svc.metrics();
        suite.counter("service.requests", m.requests.get() as f64);
        suite.counter("service.completed", m.completed.get() as f64);
        suite.counter("service.queued", m.queued.get() as f64);
        suite.counter("service.degraded", m.degraded.get() as f64);
        suite.counter("service.rejected_overload", m.rejected_overload.get() as f64);
        suite.counter("service.expired_deadline", m.expired_deadline.get() as f64);
        suite.counter("service.faulted", m.faulted.get() as f64);
        suite.counter("service.queue_wait_p95_secs", m.queue_wait.quantile(0.95).as_secs_f64());
        suite.counter("service.mem_in_use_after", m.mem_in_use.get() as f64);
    }

    // ---- robustness: transient spill IO fault absorbed by retries -------
    {
        use fastspsd::testkit::faults::{self, FaultPlan, FaultPoint, FaultSpec};
        let plan = std::sync::Arc::new(
            FaultPlan::none().fail(FaultPoint::SpillWrite, FaultSpec::transient(1)),
        );
        let spill = ExecPolicy::resident(0).with_tile_rows(DEFAULT_TILE);
        let armed = faults::arm(std::sync::Arc::clone(&plan));
        let st = exec::top_k_eigs(&src, &u_id, k_eigs, 7, &spill)
            .meta
            .residency
            .expect("resident policies report stats");
        drop(armed);
        println!(
            "  transient spill-write fault: {} retries absorbed, {} spill hits",
            st.io_retries, st.spill_hits
        );
        suite.counter("residency.io_retries", st.io_retries as f64);
        suite.counter("residency.spill_hits_after_fault", st.spill_hits as f64);
    }

    // ---- integrity: checksum catches, quarantine, guarded solves --------
    // The three integrity counters of EXPERIMENTS.md §Integrity land in
    // BENCH_stream.json so regressions in the detect-and-recover machinery
    // (silently passing corrupt bytes, validation not engaging, guards not
    // escalating on degenerate cores) show up in the artifact trajectory.
    {
        use fastspsd::coordinator::{ApproxRequest, ApproxService, MethodSpec, ServiceConfig};
        use fastspsd::testkit::faults::{self, FaultPlan, FaultPoint, FaultSpec};

        // A corrupted spill record is detected by its checksum on read-back
        // and transparently recomputed: the run succeeds, the catch counts.
        let plan = std::sync::Arc::new(
            FaultPlan::none().fail(FaultPoint::SpillCorrupt, FaultSpec::transient(1)),
        );
        let spill = ExecPolicy::resident(0).with_tile_rows(DEFAULT_TILE);
        let armed = faults::arm(std::sync::Arc::clone(&plan));
        let rep = exec::top_k_eigs(&src, &u_id, k_eigs, 7, &spill);
        drop(armed);
        let st = rep.meta.residency.expect("resident policies report stats");
        println!(
            "  corrupt spill record: {} checksum catches, recomputed (health mirrors: {})",
            st.corrupt_reads, rep.meta.numeric_health.corrupt_reads
        );
        suite.counter("residency.corrupt_reads", st.corrupt_reads as f64);

        // A poisoned tile under NonFinite validation faults the first
        // attempt; retry_faulted serves the request clean on the second and
        // the reply carries the quarantine count from the failed attempt.
        let n_q = if quick { 300 } else { 600 };
        let mut rng = Rng::new(23);
        let q_oracle: std::sync::Arc<dyn KernelOracle + Send + Sync> =
            std::sync::Arc::new(RbfOracle::cpu(Arc::new(Matrix::randn(n_q, 8, &mut rng)), 0.4));
        let svc = ApproxService::new(
            std::sync::Arc::clone(&q_oracle),
            ServiceConfig { workers: 1, retry_faulted: 1, ..Default::default() },
        );
        let plan = std::sync::Arc::new(
            FaultPlan::none().fail(FaultPoint::PoisonTile, FaultSpec::transient(1)),
        );
        let armed = faults::arm(std::sync::Arc::clone(&plan));
        let (tx, rx) = std::sync::mpsc::channel();
        svc.submit(
            ApproxRequest {
                id: 0,
                method: MethodSpec::Nystrom,
                c: 16,
                k: 4,
                seed: 7,
                policy: Some(ExecPolicy::streamed(64).with_validate(ValidateMode::NonFinite)),
                precision: Precision::F64,
                deadline: None,
            },
            tx.clone(),
        );
        svc.drain();
        drop(armed);
        drop(tx);
        let r = rx.recv().expect("request answered");
        let quarantined = r.numeric_health.map_or(0, |h| h.quarantined_tiles);
        println!(
            "  poisoned tile + retry: error={:?}, {} tiles quarantined across attempts",
            r.error.is_some(),
            quarantined
        );
        suite.counter("pipeline.quarantined_tiles", quarantined as f64);

        // A rank-deficient core (rank-2 Gram, 16 landmarks) forces the
        // guarded W⁺ through the regularization ladder.
        let n_low = if quick { 300 } else { 600 };
        let mut rng = Rng::new(29);
        let g_low = Matrix::randn(n_low, 2, &mut rng);
        let o_low = DenseOracle::new(g_low.matmul_tr(&g_low));
        let p_low = spsd::uniform_p(n_low, 16, &mut rng);
        suite.bench(&format!("nystrom guarded rank-deficient n={n_low}"), || {
            black_box(exec::nystrom(&o_low, &p_low, &mat));
        });
        let h = exec::nystrom(&o_low, &p_low, &mat).meta.numeric_health;
        println!(
            "    guard: cond est {:.3e}, {} after {} ladder rungs",
            h.core_cond_est,
            h.regularization.name(),
            h.escalations
        );
        suite.counter("solve.regularization_escalations", h.escalations as f64);
        suite.counter("solve.core_cond_est", h.core_cond_est.min(1e300));
    }

    // ---- sharded scale-out: per-worker working sets + coalescing --------
    // The sharding headline (EXPERIMENTS.md §Sharding): a row-sharded
    // build's memory story is the per-WORKER peak — each shard pass runs
    // under its own allocator gauge — and K tenants asking for the same
    // approximation ride ONE stream pass, so the oracle is charged one
    // n·c for the whole batch instead of K of them.
    {
        use fastspsd::coordinator::{
            planner, ApproxRequest, ApproxService, MethodSpec, ServiceConfig,
        };
        let shards = 4usize;
        let budget = planner::predicted_policy_peak_bytes(
            n,
            c,
            &MethodSpec::Nystrom,
            &ExecPolicy::streamed(DEFAULT_TILE),
        );
        let split = planner::plan_shards(n, c, shards, budget);
        let pol_sh = split.policy();
        suite.bench(&format!("nystrom sharded w={shards} n={n}"), || {
            black_box(exec::nystrom(&oracle, &p, &pol_sh));
        });
        let stats = exec::nystrom(&oracle, &p, &pol_sh)
            .meta
            .shard
            .expect("sharded policies report per-shard stats");
        println!(
            "    {} workers, max per-worker peak {} (planner predicted {}), re-executed {}",
            stats.workers.len(),
            fmt_mib(stats.max_worker_peak_bytes() as usize),
            fmt_mib(split.predicted_worker_peak_bytes as usize),
            stats.reexecuted
        );
        for w in &stats.workers {
            println!(
                "      rows {:>5}..{:<5}  peak {}  {:.3} s",
                w.r0,
                w.r1,
                fmt_mib(w.peak_bytes as usize),
                w.secs
            );
        }
        suite.counter("shard.workers", stats.workers.len() as f64);
        suite.counter("shard.max_worker_peak_bytes", stats.max_worker_peak_bytes() as f64);
        suite.counter(
            "shard.predicted_worker_peak_bytes",
            split.predicted_worker_peak_bytes as f64,
        );

        // Many-tenant coalescing: one worker, K tenants submitting the
        // identical request. The first dispatch runs alone; the tenants
        // arriving while it builds queue up and ride the next dispatch as
        // one batch — visible in `batched` replies, the coalescing
        // counters, and the oracle's entry ledger.
        let tenants = 8u64;
        let n_t = if quick { 400 } else { 1000 };
        let c_t = 16usize;
        let mut rng = Rng::new(37);
        let t_oracle: Arc<dyn KernelOracle + Send + Sync> =
            Arc::new(RbfOracle::cpu(Arc::new(Matrix::randn(n_t, 16, &mut rng)), 0.4));
        // Admission is cap-gated (uncapped reservations always succeed and
        // would dispatch every tenant straight to the pool), so cap at one
        // request's predicted peak: tenant 0 takes the whole cap and the
        // rest queue behind it until its build frees the headroom.
        let one_req = planner::predicted_policy_peak_bytes(
            n_t,
            c_t,
            &MethodSpec::Nystrom,
            &planner::default_policy(),
        );
        let svc = ApproxService::new(
            Arc::clone(&t_oracle),
            ServiceConfig { workers: 1, memory_cap: Some(one_req), ..Default::default() },
        );
        t_oracle.reset_entries();
        let (tx, rx) = std::sync::mpsc::channel();
        let sw = std::time::Instant::now();
        for i in 0..tenants {
            svc.submit(
                ApproxRequest {
                    id: i,
                    method: MethodSpec::Nystrom,
                    c: c_t,
                    k: 4,
                    seed: 11,
                    policy: None,
                    precision: Precision::F64,
                    deadline: None,
                },
                tx.clone(),
            );
        }
        svc.drain();
        drop(tx);
        let resps: Vec<_> = rx.iter().collect();
        let shared = resps.iter().filter(|r| r.batched).count();
        let passes = t_oracle.entries_observed() as f64 / (n_t * c_t) as f64;
        let m = svc.metrics();
        println!(
            "  many-tenant coalescing: {} tenants, {:.1} oracle passes, {} rode a shared \
             pass, occupancy p95 {} in {:.3} s",
            resps.len(),
            passes,
            shared,
            m.batch_occupancy.quantile(0.95),
            sw.elapsed().as_secs_f64()
        );
        suite.counter("service.coalesced_requests", m.coalesced_requests.get() as f64);
        suite.counter("service.batch_occupancy_p95", m.batch_occupancy.quantile(0.95) as f64);
        suite.counter("service.batch_occupancy_max", m.batch_occupancy.max() as f64);
        suite.counter("service.tenant_oracle_passes", passes);
    }

    // ---- observability: per-stage profile + pipeline stall fractions ----
    // Installed LAST so every timed section above ran with the recorder
    // disabled (the spans cost one atomic load there). One traced streamed
    // build answers "is this pipeline oracle-bound or fold-bound" and
    // lands per-stage seconds + stall fractions in BENCH_stream.json.
    {
        fastspsd::obs::ensure_installed();
        let rep = exec::fast(&oracle, &p, FastConfig::uniform(s), &tiled, &mut Rng::new(1));
        let profile = rep.meta.stage_profile.expect("recorder is installed");
        println!("  span-traced fast[uniform] streamed t={DEFAULT_TILE} n={n}:");
        for line in profile.summary_lines() {
            println!("    {line}");
        }
        for agg in &profile.stages {
            suite.counter(&format!("stage.{}.total_secs", agg.stage.name()), agg.total_secs);
            suite.counter(&format!("stage.{}.count", agg.stage.name()), agg.count as f64);
        }
        if let Some(f) = profile.producer_stall_fraction() {
            suite.counter("pipeline.producer_stall_fraction", f);
        }
        if let Some(f) = profile.consumer_stall_fraction() {
            suite.counter("pipeline.consumer_stall_fraction", f);
        }
    }

    // Quick smoke runs land in a separate file so they never clobber the
    // full-budget perf trajectory — unless commit mode (`make bench-quick`)
    // asks for the canonical artifact.
    let path = fastspsd::benchkit::artifact_path("BENCH_stream");
    if let Err(e) = suite.write_json(&path) {
        eprintln!("warn: could not write {path}: {e}");
    }
}
