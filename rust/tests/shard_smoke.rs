//! `make shard-smoke` — the CI gate for the sharded scale-out plane: a
//! small-n sharded service round-trip (same bits as the unsharded run,
//! per-shard accounting on the reply) including one injected transient
//! worker death that must be re-executed invisibly.
//!
//! Tests here arm the process-global fault plan, so they serialize on a
//! file-local lock (the smoke binary is its own process; chaos.rs' lock
//! guards its process, this one guards ours).

use fastspsd::coordinator::oracle::{KernelOracle, RbfOracle};
use fastspsd::coordinator::{ApproxRequest, ApproxService, MethodSpec, ServiceConfig};
use fastspsd::exec::ExecPolicy;
use fastspsd::linalg::Matrix;
use fastspsd::sketch::SketchKind;
use fastspsd::stream::Precision;
use fastspsd::testkit::faults::{self, FaultPlan, FaultPoint, FaultSpec};
use fastspsd::util::Rng;
use std::sync::{mpsc, Arc, Mutex};

static SMOKE_LOCK: Mutex<()> = Mutex::new(());

fn smoke_guard() -> std::sync::MutexGuard<'static, ()> {
    SMOKE_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

const N: usize = 41;

fn service(workers: usize) -> ApproxService {
    let mut rng = Rng::new(2);
    let oracle = RbfOracle::cpu(Arc::new(Matrix::randn(N, 5, &mut rng)), 0.7);
    ApproxService::new(
        Arc::new(oracle) as Arc<dyn KernelOracle + Send + Sync>,
        ServiceConfig { workers, ..Default::default() },
    )
}

fn req(id: u64, policy: Option<ExecPolicy>) -> ApproxRequest {
    ApproxRequest {
        id,
        method: MethodSpec::Fast { s: 16, kind: SketchKind::Uniform },
        c: 6,
        k: 3,
        seed: 5,
        policy,
        precision: Precision::F64,
        deadline: None,
    }
}

fn serve_one(svc: &ApproxService, r: ApproxRequest) -> fastspsd::coordinator::ApproxResponse {
    let (tx, rx) = mpsc::channel();
    svc.submit(r, tx);
    svc.drain();
    rx.iter().next().unwrap()
}

#[test]
fn sharded_service_round_trip_matches_unsharded_and_reports_per_shard_accounting() {
    let _g = smoke_guard();
    let svc = service(2);
    let reference = serve_one(&svc, req(0, Some(ExecPolicy::streamed(8))));
    assert!(reference.error.is_none(), "{:?}", reference.error);
    assert!(reference.meta.as_ref().unwrap().shard.is_none());

    let resp = serve_one(
        &svc,
        req(1, Some(ExecPolicy::sharded(3, ExecPolicy::streamed(8)))),
    );
    assert!(resp.error.is_none(), "{:?}", resp.error);
    assert_eq!(resp.eigvals, reference.eigvals, "sharding must not move a single bit");
    let meta = resp.meta.expect("served requests carry meta");
    let stats = meta.shard.expect("a sharded policy reports per-shard accounting");
    assert_eq!(stats.shards, 3);
    assert_eq!(stats.workers.len(), 3);
    assert_eq!(stats.reexecuted, 0);
    let mut next = 0;
    for w in &stats.workers {
        assert_eq!(w.r0, next, "contiguous row-blocks");
        next = w.r1;
        // peak_bytes is allocator-measured and stays 0 here: the counting
        // allocator is only installed in the bench binary.
        assert!(w.secs >= 0.0);
    }
    assert_eq!(next, N, "the shards cover every row");
}

#[test]
fn sharded_resident_workers_merge_their_residency_stats_into_the_reply() {
    let _g = smoke_guard();
    let svc = service(1);
    let resp = serve_one(
        &svc,
        req(2, Some(ExecPolicy::sharded(2, ExecPolicy::resident(0).with_tile_rows(8)))),
    );
    assert!(resp.error.is_none(), "{:?}", resp.error);
    let meta = resp.meta.unwrap();
    assert_eq!(meta.shard.as_ref().unwrap().workers.len(), 2);
    let res = meta.residency.expect("per-shard residency stats merge into the reply");
    assert!(res.computes > 0, "both workers' computes are absorbed: {res:?}");
}

#[test]
fn injected_transient_worker_death_is_reexecuted_invisibly() {
    let _g = smoke_guard();
    let svc = service(1);
    let sharded = || Some(ExecPolicy::sharded(3, ExecPolicy::streamed(8)));
    let reference = serve_one(&svc, req(3, sharded()));
    assert!(reference.error.is_none(), "{:?}", reference.error);

    let plan = Arc::new(
        FaultPlan::none().fail(FaultPoint::ShardWorkerDeath, FaultSpec::transient(2)),
    );
    let resp = {
        let _armed = faults::arm(Arc::clone(&plan));
        serve_one(&svc, req(4, sharded()))
    };
    assert!(resp.error.is_none(), "a transient death must be absorbed: {:?}", resp.error);
    assert_eq!(resp.eigvals, reference.eigvals, "re-execution must reproduce the bits");
    let stats = resp.meta.unwrap().shard.unwrap();
    assert_eq!(stats.reexecuted, 1, "the re-executed row-range is accounted");
    assert_eq!(plan.injected(FaultPoint::ShardWorkerDeath), 1);
    let m = svc.metrics();
    assert_eq!(m.faulted.get(), 0, "the service never saw the death");
    assert_eq!(m.mem_in_use.get(), 0);
}
