//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The build image has no crates.io access, so this vendored shim provides
//! exactly the API subset `fastspsd` uses: [`Error`], [`Result`], the
//! [`anyhow!`] / [`bail!`] macros, and the [`Context`] extension trait.
//! Error chains are flattened into a single message joined with `": "`,
//! which matches how the real crate renders `{:#}` (alternate Display).

use std::fmt;

/// A flattened error: the full context chain rendered as one message.
pub struct Error {
    msg: String,
}

impl Error {
    /// Create an error from any displayable message.
    pub fn msg(m: impl fmt::Display) -> Self {
        Error { msg: m.to_string() }
    }

    /// Prepend a layer of context, matching `anyhow`'s `{:#}` rendering.
    pub fn context(self, msg: impl fmt::Display) -> Self {
        Error { msg: format!("{msg}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        // Render the source chain the way `{:#}` would.
        let mut msg = e.to_string();
        let mut src = e.source();
        while let Some(s) = src {
            msg.push_str(": ");
            msg.push_str(&s.to_string());
            src = s.source();
        }
        Error { msg }
    }
}

/// `anyhow::Result<T>` — `std::result::Result` with [`Error`] as default.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Extension trait adding `.context()` / `.with_context()` to results.
pub trait Context<T> {
    fn context(self, msg: impl fmt::Display) -> Result<T>;
    fn with_context<D: fmt::Display>(self, f: impl FnOnce() -> D) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context(self, msg: impl fmt::Display) -> Result<T> {
        self.map_err(|e| e.into().context(msg))
    }

    fn with_context<D: fmt::Display>(self, f: impl FnOnce() -> D) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn macro_and_context_render_like_anyhow_alternate() {
        let e: Error = anyhow!("inner {}", 7);
        assert_eq!(e.to_string(), "inner 7");
        let r: Result<()> = Err(e);
        let r = r.context("outer");
        assert_eq!(format!("{:#}", r.unwrap_err()), "outer: inner 7");
    }

    #[test]
    fn from_std_error_flattens_sources() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: Error = io.into();
        assert!(e.to_string().contains("gone"));
    }

    #[test]
    fn bail_returns_err() {
        fn f(x: i32) -> Result<i32> {
            if x < 0 {
                bail!("negative: {x}");
            }
            Ok(x)
        }
        assert!(f(1).is_ok());
        assert_eq!(f(-2).unwrap_err().to_string(), "negative: -2");
    }
}
