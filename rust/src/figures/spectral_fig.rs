//! Figures 11 & 12: approximate spectral clustering — NMI against c
//! (Fig 11) and against elapsed time (Fig 12).

use super::Ctx;
use crate::apps::{metrics::nmi, spectral};
use crate::cli::Args;
use crate::data;
use crate::exec::{self, ExecPolicy};
use crate::sketch::SketchKind;
use crate::spsd::{self, FastConfig};
use crate::util::{Rng, Stopwatch};

pub fn run(ctx: &Ctx, args: &Args) {
    let pol = ExecPolicy::Materialized;
    let datasets = ["PenDigit", "USPS", "Mushrooms", "DNA"];
    let only = args.get("dataset").map(|s| s.to_lowercase());
    let mut csv = ctx.csv("fig11_12.csv", "dataset,n,k,c,method,s,nmi,secs");
    for name in datasets {
        if let Some(o) = &only {
            if !name.eq_ignore_ascii_case(o) {
                continue;
            }
        }
        let spec = data::find_spec(name).unwrap();
        let (ds, oracle, _sig) = ctx.oracle_for(spec, 0.9);
        let n = ds.x.rows();
        let k = ds.classes;
        let cs = args.get_usize_list("cs", &[10, 20, 40, 80]);
        for &c in &cs {
            let c = c.min(n / 2);
            for rep in 0..ctx.reps {
                let mut rng = Rng::new(ctx.seed + rep as u64 * 977 + c as u64);
                let p = spsd::uniform_p(n, c, &mut rng);
                let mut eval =
                    |method: &str, s: usize, approx: &spsd::SpsdApprox, secs_build: f64, rng: &mut Rng| {
                        let sw = Stopwatch::start();
                        let pred = spectral::spectral_cluster_from_approx(approx, k, rng);
                        let score = nmi(&pred, &ds.labels);
                        csv.row(&format!(
                            "{name},{n},{k},{c},{method},{s},{score:.4},{:.4}",
                            secs_build + sw.secs()
                        ));
                    };
                let sw = Stopwatch::start();
                let a = exec::nystrom(oracle.as_ref(), &p, &pol).result;
                eval("nystrom", c, &a, sw.secs(), &mut rng);
                for f in [4usize, 8] {
                    let s = (f * c).min(n);
                    let sw = Stopwatch::start();
                    let a = exec::fast(
                        oracle.as_ref(),
                        &p,
                        FastConfig {
                            s,
                            kind: SketchKind::Uniform,
                            force_p_in_s: true,
                            leverage_basis: spsd::LeverageBasis::Gram,
                        },
                        &pol,
                        &mut rng,
                    )
                    .result;
                    eval(&format!("fast_s{f}c"), s, &a, sw.secs(), &mut rng);
                }
                let sw = Stopwatch::start();
                let a = exec::prototype(oracle.as_ref(), &p, &pol).result;
                eval("prototype", n, &a, sw.secs(), &mut rng);
            }
        }
    }
    csv.finish();
}
