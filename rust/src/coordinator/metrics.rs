//! Service metrics: counters and latency histograms.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// Monotonic counter.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, v: u64) {
        self.0.fetch_add(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Up/down gauge (e.g. bytes of predicted working set currently
/// in flight). `try_add_below` is the admission check-and-reserve the
/// service's memory cap uses: it either reserves `v` atomically or
/// refuses without changing the gauge.
#[derive(Debug, Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub fn add(&self, v: u64) {
        self.0.fetch_add(v, Ordering::Relaxed);
    }

    pub fn sub(&self, v: u64) {
        self.0.fetch_sub(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }

    /// Atomically add `v` only if the result stays ≤ `cap`; returns
    /// whether the reservation happened.
    pub fn try_add_below(&self, v: u64, cap: u64) -> bool {
        let mut cur = self.0.load(Ordering::Relaxed);
        loop {
            if cur.saturating_add(v) > cap {
                return false;
            }
            match self.0.compare_exchange_weak(
                cur,
                cur.saturating_add(v),
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => return true,
                Err(now) => cur = now,
            }
        }
    }
}

/// Latency histogram with exponential buckets from 1µs to ~17min.
#[derive(Debug)]
pub struct Histogram {
    /// bucket i covers [2^i µs, 2^(i+1) µs)
    buckets: [AtomicU64; 32],
    count: AtomicU64,
    sum_nanos: AtomicU64,
    max_nanos: AtomicU64,
    /// raw samples for exact quantiles (bounded; benches are small-N)
    samples: Mutex<Vec<u64>>,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum_nanos: AtomicU64::new(0),
            max_nanos: AtomicU64::new(0),
            samples: Mutex::new(Vec::new()),
        }
    }
}

impl Histogram {
    pub fn observe(&self, d: Duration) {
        let nanos = d.as_nanos() as u64;
        let micros = (nanos / 1_000).max(1);
        let bucket = (63 - micros.leading_zeros() as usize).min(31);
        self.buckets[bucket].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_nanos.fetch_add(nanos, Ordering::Relaxed);
        self.max_nanos.fetch_max(nanos, Ordering::Relaxed);
        let mut s = self.samples.lock().unwrap();
        if s.len() < 100_000 {
            s.push(nanos);
        }
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean(&self) -> Duration {
        let c = self.count();
        if c == 0 {
            return Duration::ZERO;
        }
        Duration::from_nanos(self.sum_nanos.load(Ordering::Relaxed) / c)
    }

    pub fn max(&self) -> Duration {
        Duration::from_nanos(self.max_nanos.load(Ordering::Relaxed))
    }

    /// Exact quantile from retained samples (q in [0, 1]). Sorts the
    /// sample store in place — no clone; reordering is invisible to the
    /// bucket counters and later `observe`s just append unsorted again.
    pub fn quantile(&self, q: f64) -> Duration {
        let mut s = self.samples.lock().unwrap();
        if s.is_empty() {
            return Duration::ZERO;
        }
        s.sort_unstable();
        let idx = ((s.len() as f64 - 1.0) * q).round() as usize;
        Duration::from_nanos(s[idx])
    }

    /// Every summary statistic from one lock and one sort — use this
    /// instead of separate `quantile` calls when reporting more than one.
    pub fn stats(&self) -> HistogramSummary {
        let count = self.count();
        let mean = self.mean();
        let max = self.max();
        let mut s = self.samples.lock().unwrap();
        if s.is_empty() {
            return HistogramSummary { count, mean, p50: Duration::ZERO, p95: Duration::ZERO, max };
        }
        s.sort_unstable();
        let at = |q: f64| {
            let idx = ((s.len() as f64 - 1.0) * q).round() as usize;
            Duration::from_nanos(s[idx])
        };
        HistogramSummary { count, mean, p50: at(0.5), p95: at(0.95), max }
    }

    pub fn summary(&self) -> String {
        let st = self.stats();
        format!(
            "n={} mean={:?} p50={:?} p95={:?} max={:?}",
            st.count, st.mean, st.p50, st.p95, st.max
        )
    }
}

/// Distribution of small integer samples (batch sizes, occupancy counts)
/// — the unitless sibling of [`Histogram`], with the same bounded sample
/// store and exact quantiles.
#[derive(Debug, Default)]
pub struct SampleDist {
    count: AtomicU64,
    max: AtomicU64,
    samples: Mutex<Vec<u64>>,
}

impl SampleDist {
    pub fn observe(&self, v: u64) {
        self.count.fetch_add(1, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
        let mut s = self.samples.lock().unwrap();
        if s.len() < 100_000 {
            s.push(v);
        }
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn max(&self) -> u64 {
        self.max.load(Ordering::Relaxed)
    }

    /// Exact quantile from retained samples (q in [0, 1]); 0 when empty.
    /// Sorts the store in place, like [`Histogram::quantile`].
    pub fn quantile(&self, q: f64) -> u64 {
        let mut s = self.samples.lock().unwrap();
        if s.is_empty() {
            return 0;
        }
        s.sort_unstable();
        let idx = ((s.len() as f64 - 1.0) * q).round() as usize;
        s[idx]
    }

    /// count / max / p50 / p95 from one lock and one sort.
    pub fn stats(&self) -> SampleDistSummary {
        let count = self.count();
        let max = self.max();
        let mut s = self.samples.lock().unwrap();
        if s.is_empty() {
            return SampleDistSummary { count, max, p50: 0, p95: 0 };
        }
        s.sort_unstable();
        let at = |q: f64| {
            let idx = ((s.len() as f64 - 1.0) * q).round() as usize;
            s[idx]
        };
        SampleDistSummary { count, max, p50: at(0.5), p95: at(0.95) }
    }
}

/// Point-in-time statistics of one [`SampleDist`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SampleDistSummary {
    pub count: u64,
    pub max: u64,
    pub p50: u64,
    pub p95: u64,
}

/// Point-in-time statistics of one [`Histogram`]: a single pass under a
/// single lock, instead of a clone-and-sort of the sample store per
/// quantile.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HistogramSummary {
    pub count: u64,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
    pub max: Duration,
}

/// All service-level metrics.
///
/// The old conflated `rejected` shed counter is split into its three
/// failure modes (`rejected_overload` / `expired_deadline` / `faulted`),
/// and the degrade-don't-die admission path adds `queued` and `degraded`.
#[derive(Debug, Default)]
pub struct Metrics {
    pub requests: Counter,
    pub completed: Counter,
    /// Requests whose algorithm returned a typed error (`Err`, not a
    /// panic — those are `faulted`).
    pub failed: Counter,
    /// Requests refused with `Overloaded` at submit: the admission queue
    /// was full, or no rung of the degrade ladder fits the memory cap.
    pub rejected_overload: Counter,
    /// Queued requests reaped after their deadline passed without the
    /// gauge ever opening enough headroom.
    pub expired_deadline: Counter,
    /// Requests whose worker panicked (isolated: the panic is caught, the
    /// reservation released, and a typed `Faulted` reply sent).
    pub faulted: Counter,
    /// Requests that waited in the admission queue (instead of being
    /// shed) before being served or reaped.
    pub queued: Counter,
    /// Requests served by a rung of the degrade ladder rather than
    /// exactly as requested.
    pub degraded: Counter,
    /// Requests that rode another request's stream pass instead of
    /// charging the oracle themselves (batch riders; the leader of a
    /// batch is not counted).
    pub coalesced_requests: Counter,
    /// Requests served per dispatched stream pass (1 = no coalescing);
    /// observed once per leader dispatch.
    pub batch_occupancy: SampleDist,
    /// Sum of `predicted_peak_bytes` across in-flight requests: the
    /// service-level working-set meter the memory cap gates on.
    pub mem_in_use: Gauge,
    pub latency: Histogram,
    pub queue_wait: Histogram,
}

impl Metrics {
    /// One coherent read of every counter plus both histogram summaries.
    /// Callers that report or compare several fields (figures, e2e, the
    /// service's own logging) should read this instead of the live
    /// atomics one by one mid-run.
    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            requests: self.requests.get(),
            completed: self.completed.get(),
            failed: self.failed.get(),
            rejected_overload: self.rejected_overload.get(),
            expired_deadline: self.expired_deadline.get(),
            faulted: self.faulted.get(),
            queued: self.queued.get(),
            degraded: self.degraded.get(),
            coalesced_requests: self.coalesced_requests.get(),
            batch_occupancy: self.batch_occupancy.stats(),
            mem_in_use: self.mem_in_use.get(),
            latency: self.latency.stats(),
            queue_wait: self.queue_wait.stats(),
        }
    }
}

/// A point-in-time copy of [`Metrics`] — plain integers and
/// [`HistogramSummary`]s, safe to hold across formatting without
/// torn reads from concurrently advancing counters.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub completed: u64,
    pub failed: u64,
    pub rejected_overload: u64,
    pub expired_deadline: u64,
    pub faulted: u64,
    pub queued: u64,
    pub degraded: u64,
    pub coalesced_requests: u64,
    pub batch_occupancy: SampleDistSummary,
    pub mem_in_use: u64,
    pub latency: HistogramSummary,
    pub queue_wait: HistogramSummary,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_counts() {
        let c = Counter::default();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
    }

    #[test]
    fn histogram_stats() {
        let h = Histogram::default();
        for ms in [1u64, 2, 3, 4, 100] {
            h.observe(Duration::from_millis(ms));
        }
        assert_eq!(h.count(), 5);
        assert_eq!(h.max(), Duration::from_millis(100));
        assert!(h.mean() >= Duration::from_millis(20));
        assert_eq!(h.quantile(0.5), Duration::from_millis(3));
        assert!(h.summary().contains("n=5"));
    }

    #[test]
    fn gauge_reserves_atomically_under_cap() {
        let g = Gauge::default();
        assert!(g.try_add_below(60, 100));
        assert!(!g.try_add_below(50, 100), "60+50 must not fit a cap of 100");
        assert_eq!(g.get(), 60, "a refused reservation must not move the gauge");
        assert!(g.try_add_below(40, 100));
        g.sub(100);
        assert_eq!(g.get(), 0);
        // u64::MAX cap never refuses (saturating add)
        assert!(g.try_add_below(u64::MAX, u64::MAX));
    }

    #[test]
    fn sample_dist_quantiles() {
        let d = SampleDist::default();
        assert_eq!(d.stats(), SampleDistSummary { count: 0, max: 0, p50: 0, p95: 0 });
        for v in [1u64, 1, 1, 4, 8] {
            d.observe(v);
        }
        assert_eq!(d.count(), 5);
        assert_eq!(d.max(), 8);
        assert_eq!(d.quantile(0.5), 1);
        let st = d.stats();
        assert_eq!(st.p95, 8);
        // the in-place sort is invisible to later observes
        d.observe(2);
        assert_eq!(d.stats().count, 6);
    }

    #[test]
    fn empty_histogram_is_safe() {
        let h = Histogram::default();
        assert_eq!(h.mean(), Duration::ZERO);
        assert_eq!(h.quantile(0.9), Duration::ZERO);
        let st = h.stats();
        assert_eq!(st.count, 0);
        assert_eq!(st.p95, Duration::ZERO);
    }

    #[test]
    fn stats_and_snapshot_agree_with_live_reads() {
        let m = Metrics::default();
        m.requests.inc();
        m.completed.inc();
        m.mem_in_use.add(42);
        for ms in [1u64, 2, 3] {
            m.latency.observe(Duration::from_millis(ms));
        }
        let snap = m.snapshot();
        assert_eq!(snap.requests, 1);
        assert_eq!(snap.completed, 1);
        assert_eq!(snap.mem_in_use, 42);
        assert_eq!(snap.latency.count, 3);
        assert_eq!(snap.latency.p50, Duration::from_millis(2));
        assert_eq!(snap.latency.max, Duration::from_millis(3));
        // the in-place sort inside stats() is invisible to later reads
        m.latency.observe(Duration::from_millis(1));
        assert_eq!(m.latency.quantile(1.0), Duration::from_millis(3));
        assert_eq!(m.latency.stats().count, 4);
    }
}
