//! Artifact manifest: what `python -m compile.aot` produced.

use super::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::path::{Path, PathBuf};

/// One AOT-compiled computation.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    /// HLO text file, relative to the manifest directory.
    pub file: PathBuf,
    /// `"rbf_block"` or `"matmul"`.
    pub kind: String,
    /// Input shapes, in argument order.
    pub inputs: Vec<Vec<usize>>,
}

/// Parsed `manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: Vec<ArtifactSpec>,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let json = Json::parse(&text).map_err(|e| anyhow!("parsing {path:?}: {e}"))?;
        if json.get("format").and_then(Json::as_str) != Some("hlo-text") {
            bail!("{path:?}: unsupported manifest format");
        }
        let mut artifacts = Vec::new();
        for a in json
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("{path:?}: missing artifacts array"))?
        {
            let name = a
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("artifact missing name"))?
                .to_string();
            let file = a
                .get("file")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("artifact {name}: missing file"))?;
            let kind = a
                .get("kind")
                .and_then(Json::as_str)
                .unwrap_or("unknown")
                .to_string();
            let mut inputs = Vec::new();
            for shp in a
                .get("inputs")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("artifact {name}: missing inputs"))?
            {
                let dims: Option<Vec<usize>> =
                    shp.as_arr().map(|ds| ds.iter().filter_map(Json::as_usize).collect());
                inputs.push(dims.ok_or_else(|| anyhow!("artifact {name}: bad shape"))?);
            }
            artifacts.push(ArtifactSpec { name, file: PathBuf::from(file), kind, inputs });
        }
        Ok(Manifest { dir, artifacts })
    }

    pub fn find(&self, name: &str) -> Option<&ArtifactSpec> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    /// All rbf_block artifacts as (d_bucket, name), ascending by d.
    pub fn rbf_buckets(&self) -> Vec<(usize, String)> {
        let mut out: Vec<(usize, String)> = self
            .artifacts
            .iter()
            .filter(|a| a.kind == "rbf_block")
            .map(|a| (a.inputs[1][1], a.name.clone()))
            .collect();
        out.sort();
        out
    }
}

/// Default artifact directory: `$FASTSPSD_ARTIFACTS` or `./artifacts`.
pub fn default_artifact_dir() -> PathBuf {
    std::env::var_os("FASTSPSD_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, body: &str) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.json"), body).unwrap();
    }

    #[test]
    fn loads_and_indexes() {
        let dir = std::env::temp_dir().join("fastspsd_manifest_test");
        write_manifest(
            &dir,
            r#"{"format": "hlo-text", "artifacts": [
                {"name": "rbf_block_256x256x16", "file": "a.hlo.txt", "kind": "rbf_block",
                 "inputs": [[1,1],[256,16],[256,16]], "dtype": "f32"},
                {"name": "rbf_block_256x256x128", "file": "b.hlo.txt", "kind": "rbf_block",
                 "inputs": [[1,1],[256,128],[256,128]], "dtype": "f32"},
                {"name": "matmul_256x256x256", "file": "c.hlo.txt", "kind": "matmul",
                 "inputs": [[256,256],[256,256]], "dtype": "f32"}
            ]}"#,
        );
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.artifacts.len(), 3);
        assert!(m.find("matmul_256x256x256").is_some());
        assert!(m.find("nope").is_none());
        assert_eq!(
            m.rbf_buckets(),
            vec![
                (16, "rbf_block_256x256x16".to_string()),
                (128, "rbf_block_256x256x128".to_string())
            ]
        );
    }

    #[test]
    fn missing_dir_is_error_with_hint() {
        let err = Manifest::load("/definitely/not/here").unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }

    #[test]
    fn bad_format_rejected() {
        let dir = std::env::temp_dir().join("fastspsd_manifest_bad");
        write_manifest(&dir, r#"{"format": "proto", "artifacts": []}"#);
        assert!(Manifest::load(&dir).is_err());
    }
}
