//! Synthetic dataset substrate.
//!
//! The paper evaluates on LIBSVM datasets (Tables 6 and 7) and one natural
//! image; neither is reachable here (no network), so we generate synthetic
//! stand-ins matched in size, dimension, class count, and — via
//! [`sigma::calibrate_sigma`] — in the spectral-decay parameter η that
//! drives every comparison (see DESIGN.md §3, Substitutions).

pub mod image;
pub mod sigma;

use crate::linalg::Matrix;
use crate::util::Rng;

/// A generated dataset: rows of `x` are points.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub name: String,
    pub x: Matrix,
    pub labels: Vec<usize>,
    pub classes: usize,
}

/// Gaussian-mixture generator: `classes` clusters with random centers
/// (spread `sep`), anisotropic within-class scales, in `d` dimensions.
/// Produces the decaying-spectrum RBF kernels the paper's datasets exhibit.
pub fn make_blobs(name: &str, n: usize, d: usize, classes: usize, sep: f64, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed);
    let classes = classes.max(1);
    // class centers
    let centers = Matrix::from_fn(classes, d, |_, _| rng.gaussian() * sep);
    // per-class anisotropic axis scales in [0.3, 1.2]
    let scales = Matrix::from_fn(classes, d, |_, _| 0.3 + 0.9 * rng.f64());
    let mut x = Matrix::zeros(n, d);
    let mut labels = Vec::with_capacity(n);
    for i in 0..n {
        let c = i % classes;
        for j in 0..d {
            x[(i, j)] = centers[(c, j)] + rng.gaussian() * scales[(c, j)];
        }
        labels.push(c);
    }
    // shuffle rows so class order is not positional
    let mut perm: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut perm);
    let x = x.select_rows(&perm);
    let labels: Vec<usize> = perm.iter().map(|&i| labels[i]).collect();
    Dataset { name: name.to_string(), x, labels, classes }
}

/// Shape spec for one of the paper's datasets.
#[derive(Debug, Clone, Copy)]
pub struct DatasetSpec {
    pub name: &'static str,
    pub n: usize,
    pub d: usize,
    pub classes: usize,
    /// cluster separation used by the generator (tuned so RBF spectra decay
    /// like the real datasets do at the paper's σ settings)
    pub sep: f64,
}

/// The kernel-approximation datasets of Table 6.
pub const TABLE6: [DatasetSpec; 5] = [
    DatasetSpec { name: "Letters", n: 15_000, d: 16, classes: 26, sep: 2.0 },
    DatasetSpec { name: "PenDigit", n: 10_992, d: 16, classes: 10, sep: 2.5 },
    DatasetSpec { name: "Cpusmall", n: 8_192, d: 12, classes: 8, sep: 2.0 },
    DatasetSpec { name: "Mushrooms", n: 8_124, d: 112, classes: 2, sep: 3.0 },
    DatasetSpec { name: "WineQuality", n: 4_898, d: 12, classes: 7, sep: 2.0 },
];

/// The clustering / classification datasets of Table 7.
pub const TABLE7: [DatasetSpec; 6] = [
    DatasetSpec { name: "MNIST", n: 60_000, d: 780, classes: 10, sep: 3.0 },
    DatasetSpec { name: "PenDigit", n: 10_992, d: 16, classes: 10, sep: 2.5 },
    DatasetSpec { name: "USPS", n: 9_298, d: 256, classes: 10, sep: 3.0 },
    DatasetSpec { name: "Mushrooms", n: 8_124, d: 112, classes: 2, sep: 3.0 },
    DatasetSpec { name: "Gisette", n: 7_000, d: 1024, classes: 2, sep: 3.5 },
    DatasetSpec { name: "DNA", n: 2_000, d: 180, classes: 3, sep: 2.5 },
];

impl DatasetSpec {
    /// Generate at a reduced size: `n' = max(min_n, n * scale)` (the
    /// experiments run at laptop scale; pass scale=1.0 for paper sizes).
    pub fn generate(&self, scale: f64, seed: u64) -> Dataset {
        let n = ((self.n as f64 * scale) as usize).clamp(200.min(self.n), self.n);
        make_blobs(self.name, n, self.d, self.classes, self.sep, seed)
    }
}

/// Look up a spec by (case-insensitive) name across both tables.
pub fn find_spec(name: &str) -> Option<DatasetSpec> {
    TABLE6
        .iter()
        .chain(TABLE7.iter())
        .find(|s| s.name.eq_ignore_ascii_case(name))
        .copied()
}

/// Split a dataset 50/50 into train/test (paper §6.3.2).
pub fn train_test_split(ds: &Dataset, rng: &mut Rng) -> (Dataset, Dataset) {
    let n = ds.x.rows();
    let mut perm: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut perm);
    let n_train = n / 2;
    let (tr, te) = perm.split_at(n_train);
    let make = |idx: &[usize], suffix: &str| Dataset {
        name: format!("{}-{}", ds.name, suffix),
        x: ds.x.select_rows(idx),
        labels: idx.iter().map(|&i| ds.labels[i]).collect(),
        classes: ds.classes,
    };
    (make(tr, "train"), make(te, "test"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn blob_shapes_and_labels() {
        let ds = make_blobs("t", 100, 5, 4, 2.0, 0);
        assert_eq!((ds.x.rows(), ds.x.cols()), (100, 5));
        assert_eq!(ds.labels.len(), 100);
        assert!(ds.labels.iter().all(|&l| l < 4));
        // all classes present
        for c in 0..4 {
            assert!(ds.labels.contains(&c));
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let a = make_blobs("t", 50, 3, 2, 1.5, 7);
        let b = make_blobs("t", 50, 3, 2, 1.5, 7);
        assert!(a.x.max_abs_diff(&b.x) == 0.0);
        assert_eq!(a.labels, b.labels);
        let c = make_blobs("t", 50, 3, 2, 1.5, 8);
        assert!(a.x.max_abs_diff(&c.x) > 0.0);
    }

    #[test]
    fn registry_and_scaling() {
        let spec = find_spec("pendigit").unwrap();
        assert_eq!(spec.n, 10_992);
        let ds = spec.generate(0.05, 1);
        assert_eq!(ds.x.rows(), (10_992.0 * 0.05) as usize);
        assert_eq!(ds.x.cols(), 16);
        assert!(find_spec("nope").is_none());
    }

    #[test]
    fn split_is_disjoint_and_complete() {
        let ds = make_blobs("t", 101, 4, 3, 2.0, 2);
        let mut rng = Rng::new(3);
        let (tr, te) = train_test_split(&ds, &mut rng);
        assert_eq!(tr.x.rows(), 50);
        assert_eq!(te.x.rows(), 51);
        assert_eq!(tr.labels.len() + te.labels.len(), 101);
    }

    #[test]
    fn blobs_are_separated() {
        // With sep >> within-class scale, same-class points are closer.
        let ds = make_blobs("t", 120, 8, 3, 6.0, 4);
        let mut same = 0.0;
        let mut diff = 0.0;
        let mut ns = 0;
        let mut nd = 0;
        for i in 0..60 {
            for j in (i + 1)..60 {
                let d2: f64 = (0..8).map(|t| (ds.x[(i, t)] - ds.x[(j, t)]).powi(2)).sum();
                if ds.labels[i] == ds.labels[j] {
                    same += d2;
                    ns += 1;
                } else {
                    diff += d2;
                    nd += 1;
                }
            }
        }
        assert!(diff / nd as f64 > 2.0 * same / ns as f64);
    }
}
