//! Streaming operations against the *implicit* approximation `C U C^T`:
//! matvec, top-k Lanczos and a regularized solve that never hold `C` (let
//! alone `C U C^T`) in memory — `C` is re-streamed from its
//! [`TileSource`] on every pass.
//!
//! How the panel is traversed is an execution policy, and the public
//! entry points live in [`exec`](crate::exec)
//! ([`exec::top_k_eigs`](crate::exec::top_k_eigs),
//! [`exec::solve_regularized`](crate::exec::solve_regularized)):
//!
//! - `ExecPolicy::Materialized` / `Streamed` — each pass re-observes the
//!   `n x c` panel (the oracle's entry counter keeps charging for it):
//!   the right trade exactly when `C` does not fit next to the rest of
//!   the workload. When `C` is resident, use
//!   [`SpsdApprox::eig_k`](crate::spsd::SpsdApprox::eig_k) instead.
//! - `ExecPolicy::Resident { spill: false, .. }` — the budget-gated
//!   cached-`C` mode (the old `*_budgeted` functions): tiles stay hot in
//!   a RAM LRU of at most `budget` bytes; when the whole panel fits, the
//!   oracle is charged exactly one `n·c` observation, a partial budget
//!   keeps a stable hot prefix resident (scan-resistant admission), and a
//!   zero budget is exactly the plain path.
//! - `ExecPolicy::Resident { spill: true, .. }` — cold tiles are
//!   *reloaded* from the disk arena, never *recomputed*: exactly one
//!   `n·c` at **any** RAM budget — including zero — and `n` may exceed
//!   RAM.
//!
//! Results are bit-identical across all of these (`tests/exec_api.rs`).

use super::{
    run_pipeline_validated, GramFold, MatvecFold, ResidencyConfig, ResidencyStats, ResidentSource,
    StreamConfig, TileConsumer, TileSource,
};
use crate::linalg::{eigh, guard, lanczos, Matrix};
use crate::obs::{self, Stage};

/// Second-pass consumer: `y[r0..r1] = tile · z`.
struct OutMatvec {
    z: Vec<f64>,
    y: Vec<f64>,
}

impl TileConsumer for OutMatvec {
    fn consume(&mut self, r0: usize, tile: &Matrix) {
        let part = tile.matvec(&self.z);
        self.y[r0..r0 + tile.rows()].copy_from_slice(&part);
    }
}

/// One validated pass with the config's quarantine mode; a poisoned tile
/// panics with the typed message (the implicit ops' contract matches
/// [`StreamingOracle`](super::StreamingOracle)).
fn stream_validated(src: &dyn TileSource, cfg: StreamConfig, consumers: &mut [&mut dyn TileConsumer]) {
    run_pipeline_validated(
        src,
        cfg.tile_rows,
        cfg.queue_depth,
        cfg.precision,
        cfg.validate,
        consumers,
    )
    .unwrap_or_else(|e| panic!("{e}"));
}

/// `y = C U C^T x` in two streaming passes over `src` (the `C` panel):
/// `t = C^T x` (fold), `z = U t`, `y = C z` (emit). Peak extra memory
/// `O(tile_rows · c + c²)`.
pub fn matvec_cuc(src: &dyn TileSource, u: &Matrix, x: &[f64], cfg: StreamConfig) -> Vec<f64> {
    let n = src.rows();
    let c = src.cols();
    assert_eq!(x.len(), n, "matvec_cuc: x must have n entries");
    assert_eq!((u.rows(), u.cols()), (c, c), "matvec_cuc: U must be c x c");
    let mut fold = MatvecFold::new(x, c);
    stream_validated(src, cfg, &mut [&mut fold]);
    let z = u.matvec(&fold.into_vec());
    let mut out = OutMatvec { z, y: vec![0.0; n] };
    stream_validated(src, cfg, &mut [&mut out]);
    out.y
}

/// The streamed Woodbury solve body (see
/// [`exec::solve_regularized`](crate::exec::solve_regularized)): one pass
/// over `C` folds the Gram `C^T C` ([`GramFold`]) and `C^T y`
/// ([`MatvecFold`]) together, the inner system `alpha I + G^T (C^T C) G`
/// (with `U = G G^T`) is solved at `c x c` scale, and a second pass emits
/// `C (G z)`. Peak extra memory `O(tile_rows · c + c²)` — `C` is never
/// resident.
fn solve_impl(
    src: &dyn TileSource,
    u: &Matrix,
    alpha: f64,
    y: &[f64],
    cfg: StreamConfig,
) -> Vec<f64> {
    let n = src.rows();
    let c = src.cols();
    assert!(alpha > 0.0, "alpha must be positive");
    assert_eq!(y.len(), n, "solve_regularized: y must have n entries");
    assert_eq!((u.rows(), u.cols()), (c, c), "solve_regularized: U must be c x c");
    // U = G G^T via its eigendecomposition, dropping the numerically-zero
    // part (same factorization as linalg::solve::woodbury_solve).
    let e = {
        let _s = obs::span(Stage::SolveEig);
        eigh(u)
    };
    let lmax = e.values.first().copied().unwrap_or(0.0).max(0.0);
    let tol = lmax * c as f64 * f64::EPSILON;
    let keep: Vec<usize> = (0..e.values.len()).filter(|&i| e.values[i] > tol).collect();
    if keep.is_empty() {
        return y.iter().map(|&yi| yi / alpha).collect();
    }
    let g = Matrix::from_fn(c, keep.len(), |i, j| {
        e.vectors[(i, keep[j])] * e.values[keep[j]].sqrt()
    });
    // One pass: C^T C and C^T y together.
    let mut gram = GramFold::new(c);
    let mut cty = MatvecFold::new(y, c);
    stream_validated(src, cfg, &mut [&mut gram, &mut cty]);
    // inner = alpha I + G^T (C^T C) G  (= alpha I + B^T B for B = C G)
    let ctc = gram.into_matrix();
    let mut inner = crate::linalg::gemm::symm_nt(&ctc.matmul(&g).transpose(), &g.transpose());
    inner.add_diag(alpha);
    let bty = g.tr_matvec(&cty.into_vec());
    let z = {
        let _s = obs::span(Stage::SolveWoodbury);
        // SPD by construction → the guarded solve is the plain LU solve on
        // every sane input; a degenerate core escalates the regularization
        // ladder (noted in numeric_health) instead of panicking.
        guard::guarded_spd_solve(&inner, &bty)
    };
    // Second pass: B z = C (G z).
    let gz = g.matvec(&z);
    let mut out = OutMatvec { z: gz, y: vec![0.0; n] };
    stream_validated(src, cfg, &mut [&mut out]);
    y.iter()
        .zip(&out.y)
        .map(|(&yi, &bi)| (yi - bi) / alpha)
        .collect()
}

/// Top-k Lanczos body over the streamed matvec. Memory stays
/// `O(tile_rows · c + n · iters)` (the Krylov basis); each Lanczos step
/// re-streams `src` twice — residency is what makes that free.
fn top_k_impl(
    src: &dyn TileSource,
    u: &Matrix,
    k: usize,
    seed: u64,
    cfg: StreamConfig,
) -> (Vec<f64>, Matrix) {
    let _s = obs::span(Stage::SolveEig);
    lanczos::lanczos_top_k_op(src.rows(), k, seed, |v| matvec_cuc(src, u, v, cfg))
}

/// Unified top-k driver behind
/// [`exec::top_k_eigs`](crate::exec::top_k_eigs): plain re-streaming when
/// `residency` is `None`, otherwise every pass goes through a
/// [`ResidentSource`] and the hit/miss/spill counters come back with the
/// eigenpairs.
pub(crate) fn run_top_k_eigs(
    src: &dyn TileSource,
    u: &Matrix,
    k: usize,
    seed: u64,
    cfg: StreamConfig,
    residency: Option<&ResidencyConfig>,
) -> ((Vec<f64>, Matrix), Option<ResidencyStats>) {
    match residency {
        None => (top_k_impl(src, u, k, seed, cfg), None),
        Some(rc) => {
            let resident = ResidentSource::new(src, rc);
            let out = top_k_impl(&resident, u, k, seed, cfg);
            let stats = resident.stats();
            (out, Some(stats))
        }
    }
}

/// Unified solve driver behind
/// [`exec::solve_regularized`](crate::exec::solve_regularized); see
/// [`run_top_k_eigs`] for the residency contract.
pub(crate) fn run_solve_regularized(
    src: &dyn TileSource,
    u: &Matrix,
    alpha: f64,
    y: &[f64],
    cfg: StreamConfig,
    residency: Option<&ResidencyConfig>,
) -> (Vec<f64>, Option<ResidencyStats>) {
    match residency {
        None => (solve_impl(src, u, alpha, y, cfg), None),
        Some(rc) => {
            let resident = ResidentSource::new(src, rc);
            let w = solve_impl(&resident, u, alpha, y, cfg);
            let stats = resident.stats();
            (w, Some(stats))
        }
    }
}

/// RAM-only residency matching the old budgeted ops' contract: the cache
/// grid equals the pipeline tile height, so every request is one grid
/// tile, extra memory is capped by `memory_budget`, and a zero budget
/// reproduces the plain re-streaming path exactly (bits and entries).
fn ram_residency(cfg: StreamConfig, n: usize, memory_budget: u64) -> ResidencyConfig {
    ResidencyConfig::ram_only(memory_budget).with_tile_rows(cfg.effective_tile_rows(n))
}

// ---------------------------------------------------------------------------
// Deprecated per-policy shims over the unified drivers (`exec` is the
// policy-carrying surface).
// ---------------------------------------------------------------------------

/// Top-k eigenpairs of the implicit `C U C^T`, re-streaming every pass.
#[deprecated(note = "use `exec::top_k_eigs` with `ExecPolicy::Streamed`")]
pub fn top_k_eigs(
    src: &dyn TileSource,
    u: &Matrix,
    k: usize,
    seed: u64,
    cfg: StreamConfig,
) -> (Vec<f64>, Matrix) {
    run_top_k_eigs(src, u, k, seed, cfg, None).0
}

/// Top-k with the budget-gated cached-`C` mode.
#[deprecated(note = "use `exec::top_k_eigs` with `ExecPolicy::ram_cached`")]
pub fn top_k_eigs_budgeted(
    src: &dyn TileSource,
    u: &Matrix,
    k: usize,
    seed: u64,
    cfg: StreamConfig,
    memory_budget: u64,
) -> (Vec<f64>, Matrix) {
    let rc = ram_residency(cfg, src.rows(), memory_budget);
    run_top_k_eigs(src, u, k, seed, cfg, Some(&rc)).0
}

/// Top-k through a caller-configured residency layer.
#[deprecated(note = "use `exec::top_k_eigs` with `ExecPolicy::Resident`")]
pub fn top_k_eigs_resident(
    src: &dyn TileSource,
    u: &Matrix,
    k: usize,
    seed: u64,
    cfg: StreamConfig,
    residency: &ResidencyConfig,
) -> (Vec<f64>, Matrix, ResidencyStats) {
    let ((vals, vecs), stats) = run_top_k_eigs(src, u, k, seed, cfg, Some(residency));
    (vals, vecs, stats.expect("residency stats"))
}

/// Regularized solve against the implicit `C U C^T`, re-streaming.
#[deprecated(note = "use `exec::solve_regularized` with `ExecPolicy::Streamed`")]
pub fn solve_regularized(
    src: &dyn TileSource,
    u: &Matrix,
    alpha: f64,
    y: &[f64],
    cfg: StreamConfig,
) -> Vec<f64> {
    run_solve_regularized(src, u, alpha, y, cfg, None).0
}

/// Regularized solve with the budget-gated cached-`C` mode.
#[deprecated(note = "use `exec::solve_regularized` with `ExecPolicy::ram_cached`")]
pub fn solve_regularized_budgeted(
    src: &dyn TileSource,
    u: &Matrix,
    alpha: f64,
    y: &[f64],
    cfg: StreamConfig,
    memory_budget: u64,
) -> Vec<f64> {
    let rc = ram_residency(cfg, src.rows(), memory_budget);
    run_solve_regularized(src, u, alpha, y, cfg, Some(&rc)).0
}

/// Regularized solve through a caller-configured residency layer.
#[deprecated(note = "use `exec::solve_regularized` with `ExecPolicy::Resident`")]
pub fn solve_regularized_resident(
    src: &dyn TileSource,
    u: &Matrix,
    alpha: f64,
    y: &[f64],
    cfg: StreamConfig,
    residency: &ResidencyConfig,
) -> (Vec<f64>, ResidencyStats) {
    let (w, stats) = run_solve_regularized(src, u, alpha, y, cfg, Some(residency));
    (w, stats.expect("residency stats"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::{self, ExecPolicy};
    use crate::stream::MatrixSource;
    use crate::util::Rng;

    fn toy(n: usize, c: usize, seed: u64) -> (Matrix, Matrix) {
        let mut rng = Rng::new(seed);
        let cmat = Matrix::randn(n, c, &mut rng);
        let mut u = Matrix::randn(c, c, &mut rng);
        u.symmetrize();
        (cmat, u)
    }

    #[test]
    fn matvec_matches_dense_chain() {
        let (cmat, u) = toy(37, 5, 0);
        let x: Vec<f64> = (0..37).map(|i| ((i * 7 % 11) as f64) - 5.0).collect();
        let dense = cmat.matmul(&u).matmul(&cmat.transpose());
        let expect = dense.matvec(&x);
        for tile in [1usize, 8, 37] {
            let src = MatrixSource::new(&cmat);
            let y = matvec_cuc(&src, &u, &x, StreamConfig::tiled(tile));
            let scale: f64 = expect.iter().map(|v| v * v).sum::<f64>().sqrt().max(1.0);
            for (a, b) in y.iter().zip(&expect) {
                assert!((a - b).abs() < 1e-10 * scale, "tile={tile}");
            }
        }
    }

    #[test]
    fn solve_regularized_matches_woodbury() {
        let mut rng = Rng::new(2);
        let cmat = Matrix::randn(33, 5, &mut rng);
        let g = Matrix::randn(5, 5, &mut rng);
        let u = g.matmul_tr(&g); // SPSD
        let y: Vec<f64> = (0..33).map(|_| rng.gaussian()).collect();
        let direct = crate::linalg::solve::woodbury_solve(&cmat, &u, 0.6, &y);
        for tile in [1usize, 8, 33] {
            let src = MatrixSource::new(&cmat);
            let w = exec::solve_regularized(&src, &u, 0.6, &y, &ExecPolicy::streamed(tile)).result;
            let scale: f64 = direct.iter().map(|v| v * v).sum::<f64>().sqrt().max(1e-12);
            for (a, b) in w.iter().zip(&direct) {
                assert!((a - b).abs() < 1e-8 * scale, "tile={tile}: {a} vs {b}");
            }
        }
        // rank-deficient U still works (the zero part is dropped)
        let g1 = Matrix::randn(5, 1, &mut rng);
        let u1 = g1.matmul_tr(&g1);
        let direct = crate::linalg::solve::woodbury_solve(&cmat, &u1, 0.6, &y);
        let src = MatrixSource::new(&cmat);
        let w = exec::solve_regularized(&src, &u1, 0.6, &y, &ExecPolicy::streamed(8)).result;
        for (a, b) in w.iter().zip(&direct) {
            assert!((a - b).abs() < 1e-8);
        }
    }

    #[test]
    fn cached_topk_matches_and_stops_restreaming() {
        use crate::coordinator::oracle::{KernelOracle, RbfOracle};
        use crate::stream::OracleColumnsSource;
        use std::sync::Arc;
        let mut rng = Rng::new(4);
        let x = Arc::new(Matrix::randn(50, 5, &mut rng));
        let o = RbfOracle::cpu(x, 0.6);
        let cols = [2usize, 11, 23, 40];
        let mut u = Matrix::randn(4, 4, &mut rng);
        u.symmetrize();
        let src = OracleColumnsSource::new(&o, &cols);
        let streamed = ExecPolicy::streamed(16);
        let cached = |budget| ExecPolicy::ram_cached(budget).with_tile_rows(16);

        o.reset_entries();
        let (vals_plain, _) = exec::top_k_eigs(&src, &u, 2, 9, &streamed).result;
        let entries_plain = o.entries_observed();

        o.reset_entries();
        let (vals_cached, _) = exec::top_k_eigs(&src, &u, 2, 9, &cached(u64::MAX)).result;
        let entries_cached = o.entries_observed();

        // identical arithmetic (cached tiles are bit-identical), far fewer
        // kernel evaluations: exactly one n·c observation instead of two
        // per Lanczos step
        for (a, b) in vals_plain.iter().zip(&vals_cached) {
            assert_eq!(a, b, "cached Lanczos must be bit-identical");
        }
        assert_eq!(entries_cached, 50 * 4, "cache must charge exactly one pass");
        assert!(entries_plain > entries_cached, "plain path must re-stream");

        // zero budget: identical results, identical (re-streaming) cost
        o.reset_entries();
        let (vals_zero, _) = exec::top_k_eigs(&src, &u, 2, 9, &cached(0)).result;
        assert_eq!(o.entries_observed(), entries_plain);
        for (a, b) in vals_plain.iter().zip(&vals_zero) {
            assert_eq!(a, b);
        }

        // and the cached solve agrees with the plain one
        let y: Vec<f64> = (0..50).map(|i| (i as f64 * 0.7).cos()).collect();
        let w_plain = exec::solve_regularized(&src, &u.gram_nt(), 0.4, &y, &streamed).result;
        let w_cached =
            exec::solve_regularized(&src, &u.gram_nt(), 0.4, &y, &cached(u64::MAX)).result;
        for (a, b) in w_plain.iter().zip(&w_cached) {
            assert_eq!(a, b);
        }
    }

    #[test]
    fn resident_spill_charges_one_pass_at_zero_ram() {
        use crate::coordinator::oracle::{KernelOracle, RbfOracle};
        use crate::stream::OracleColumnsSource;
        use std::sync::Arc;
        let mut rng = Rng::new(8);
        let x = Arc::new(Matrix::randn(45, 5, &mut rng));
        let o = RbfOracle::cpu(x, 0.5);
        let cols = [0usize, 7, 19, 31, 44];
        let mut u = Matrix::randn(5, 5, &mut rng);
        u.symmetrize();
        let src = OracleColumnsSource::new(&o, &cols);
        let streamed = ExecPolicy::streamed(9);

        o.reset_entries();
        let (vals_plain, vecs_plain) = exec::top_k_eigs(&src, &u, 3, 11, &streamed).result;
        let entries_plain = o.entries_observed();

        // zero RAM budget + disk spill: identical bits, one n·c charge
        o.reset_entries();
        let spilled = ExecPolicy::resident(0).with_tile_rows(9);
        let rep = exec::top_k_eigs(&src, &u, 3, 11, &spilled);
        let (vals, vecs) = rep.result;
        let stats = rep.meta.residency.expect("resident policy must report stats");
        assert_eq!(o.entries_observed(), 45 * 5, "spill must charge exactly one pass");
        assert!(entries_plain > 45 * 5, "plain path must re-stream");
        for (a, b) in vals_plain.iter().zip(&vals) {
            assert_eq!(a, b, "resident Lanczos must be bit-identical");
        }
        assert_eq!(vecs_plain.max_abs_diff(&vecs), 0.0);
        assert_eq!(stats.computes, 5, "45 rows / 9-row grid");
        assert_eq!(stats.ram_hits, 0);
        assert!(stats.spill_hits > 0, "re-reads must come from the arena");
        assert_eq!(stats.spilled_bytes, 45 * 5 * 8);

        // and the resident solve agrees with the plain one
        let y: Vec<f64> = (0..45).map(|i| (i as f64 * 0.3).sin()).collect();
        let w_plain = exec::solve_regularized(&src, &u.gram_nt(), 0.7, &y, &streamed).result;
        let rep = exec::solve_regularized(&src, &u.gram_nt(), 0.7, &y, &spilled);
        for (a, b) in w_plain.iter().zip(&rep.result) {
            assert_eq!(a, b);
        }
        assert!(rep.meta.residency.expect("stats").spill_hits > 0);
    }

    #[test]
    fn top_k_matches_materialized_eigs() {
        // SPSD chain: U = I so C U C^T = C C^T, eigenvalues = singular
        // values of C squared.
        let mut rng = Rng::new(1);
        let cmat = Matrix::randn(40, 4, &mut rng);
        let u = Matrix::identity(4);
        let src = MatrixSource::new(&cmat);
        let (vals, vecs) = exec::top_k_eigs(&src, &u, 3, 7, &ExecPolicy::streamed(9)).result;
        assert_eq!(vals.len(), 3);
        assert_eq!((vecs.rows(), vecs.cols()), (40, 3));
        let dense = cmat.matmul_tr(&cmat);
        let exact = crate::linalg::eigh(&dense);
        for i in 0..3 {
            assert!(
                (vals[i] - exact.values[i]).abs() < 1e-6 * exact.values[0],
                "eig {i}: {} vs {}",
                vals[i],
                exact.values[i]
            );
        }
    }
}
