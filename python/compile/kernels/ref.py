"""Pure-jnp oracles for the Pallas kernels (build-time correctness only)."""

from __future__ import annotations

import jax.numpy as jnp


def rbf_block_ref(gamma, x, y):
    """Reference RBF block: exp(-gamma * ||x_i - y_j||^2), computed directly."""
    # (m, 1, d) - (1, n, d) -> explicit pairwise differences; O(mnd) memory,
    # fine for oracle-sized inputs and immune to the cancellation the fused
    # kernel has to clamp.
    diff = x[:, None, :] - y[None, :, :]
    d2 = jnp.sum(diff * diff, axis=-1)
    g = jnp.asarray(gamma).reshape(())
    return jnp.exp(-g * d2)


def matmul_ref(x, y):
    return jnp.matmul(x, y, preferred_element_type=jnp.float32)


def poly_block_ref(gamma, coef0, degree, x, y):
    """Reference polynomial kernel block (gamma <x,y> + coef0)^degree."""
    g = jnp.asarray(gamma).reshape(())
    c0 = jnp.asarray(coef0).reshape(())
    d = jnp.asarray(degree).reshape(())
    return jnp.power(g * jnp.matmul(x, y.T) + c0, d)
