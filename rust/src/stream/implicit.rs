//! Streaming operations against the *implicit* approximation `C U C^T`:
//! matvec and top-k Lanczos that never hold `C` (let alone `C U C^T`) in
//! memory — `C` is re-streamed from its [`TileSource`] on every pass.
//!
//! This trades kernel recomputation for memory: each matvec re-observes
//! the `n x c` panel (the oracle's entry counter keeps charging for it),
//! which is the right trade exactly when `C` does not fit next to the rest
//! of the workload. When `C` is resident, use
//! [`SpsdApprox::eig_k`](crate::spsd::SpsdApprox::eig_k) instead.
//!
//! Between those extremes sit two opt-in modes, both built on the tile
//! residency layer ([`ResidentSource`](super::ResidentSource)):
//!
//! - the budget-gated cached-`C` mode ([`top_k_eigs_budgeted`] /
//!   [`solve_regularized_budgeted`]): tiles stay hot in a RAM cache of at
//!   most `memory_budget` bytes (the planner's
//!   [`Goal::memory_budget`](crate::coordinator::planner::Goal) unit).
//!   When the whole panel fits, later Lanczos matvecs read memory and the
//!   oracle is charged exactly one `n·c` observation; a partial budget
//!   keeps a stable hot prefix resident (scan-resistant admission), so
//!   re-streaming shrinks in proportion to the budget — extra memory never
//!   exceeds the budget, results stay bit-identical, and a zero budget is
//!   exactly the plain path.
//! - the spill mode ([`top_k_eigs_resident`] /
//!   [`solve_regularized_resident`] with a spilling
//!   [`ResidencyConfig`]): cold tiles are *reloaded* from the disk arena,
//!   never *recomputed*, so the oracle is charged exactly one `n·c` at
//!   **any** RAM budget — including zero — and `n` may exceed RAM.

use super::{
    run_pipeline, GramFold, MatvecFold, ResidencyConfig, ResidencyStats, ResidentSource,
    StreamConfig, TileConsumer, TileSource,
};
use crate::linalg::{eigh, lanczos, solve, Matrix};

/// Second-pass consumer: `y[r0..r1] = tile · z`.
struct OutMatvec {
    z: Vec<f64>,
    y: Vec<f64>,
}

impl TileConsumer for OutMatvec {
    fn consume(&mut self, r0: usize, tile: &Matrix) {
        let part = tile.matvec(&self.z);
        self.y[r0..r0 + tile.rows()].copy_from_slice(&part);
    }
}

/// `y = C U C^T x` in two streaming passes over `src` (the `C` panel):
/// `t = C^T x` (fold), `z = U t`, `y = C z` (emit). Peak extra memory
/// `O(tile_rows · c + c²)`.
pub fn matvec_cuc(src: &dyn TileSource, u: &Matrix, x: &[f64], cfg: StreamConfig) -> Vec<f64> {
    let n = src.rows();
    let c = src.cols();
    assert_eq!(x.len(), n, "matvec_cuc: x must have n entries");
    assert_eq!((u.rows(), u.cols()), (c, c), "matvec_cuc: U must be c x c");
    let mut fold = MatvecFold::new(x, c);
    run_pipeline(src, cfg.tile_rows, cfg.queue_depth, &mut [&mut fold]);
    let z = u.matvec(&fold.into_vec());
    let mut out = OutMatvec { z, y: vec![0.0; n] };
    run_pipeline(src, cfg.tile_rows, cfg.queue_depth, &mut [&mut out]);
    out.y
}

/// Solve `(C U C^T + alpha I) w = y` against the implicit approximation
/// (the streamed form of Lemma 11 / `woodbury_solve`): one pass over `C`
/// folds the Gram `C^T C` ([`GramFold`]) and `C^T y` ([`MatvecFold`])
/// together, the Woodbury inner system `alpha I + G^T (C^T C) G` (with
/// `U = G G^T`) is solved at `c x c` scale, and a second pass emits
/// `C (G z)`. Peak extra memory `O(tile_rows · c + c²)` — `C` is never
/// resident.
pub fn solve_regularized(
    src: &dyn TileSource,
    u: &Matrix,
    alpha: f64,
    y: &[f64],
    cfg: StreamConfig,
) -> Vec<f64> {
    let n = src.rows();
    let c = src.cols();
    assert!(alpha > 0.0, "alpha must be positive");
    assert_eq!(y.len(), n, "solve_regularized: y must have n entries");
    assert_eq!((u.rows(), u.cols()), (c, c), "solve_regularized: U must be c x c");
    // U = G G^T via its eigendecomposition, dropping the numerically-zero
    // part (same factorization as linalg::solve::woodbury_solve).
    let e = eigh(u);
    let lmax = e.values.first().copied().unwrap_or(0.0).max(0.0);
    let tol = lmax * c as f64 * f64::EPSILON;
    let keep: Vec<usize> = (0..e.values.len()).filter(|&i| e.values[i] > tol).collect();
    if keep.is_empty() {
        return y.iter().map(|&yi| yi / alpha).collect();
    }
    let g = Matrix::from_fn(c, keep.len(), |i, j| {
        e.vectors[(i, keep[j])] * e.values[keep[j]].sqrt()
    });
    // One pass: C^T C and C^T y together.
    let mut gram = GramFold::new(c);
    let mut cty = MatvecFold::new(y, c);
    run_pipeline(src, cfg.tile_rows, cfg.queue_depth, &mut [&mut gram, &mut cty]);
    // inner = alpha I + G^T (C^T C) G  (= alpha I + B^T B for B = C G)
    let ctc = gram.into_matrix();
    let mut inner = crate::linalg::gemm::symm_nt(&ctc.matmul(&g).transpose(), &g.transpose());
    inner.add_diag(alpha);
    let bty = g.tr_matvec(&cty.into_vec());
    let z = solve::lu_solve(&inner, &bty).expect("alpha I + B^T B is SPD");
    // Second pass: B z = C (G z).
    let gz = g.matvec(&z);
    let mut out = OutMatvec { z: gz, y: vec![0.0; n] };
    run_pipeline(src, cfg.tile_rows, cfg.queue_depth, &mut [&mut out]);
    y.iter()
        .zip(&out.y)
        .map(|(&yi, &bi)| (yi - bi) / alpha)
        .collect()
}

/// Top-k eigenpairs (descending) of the implicit `C U C^T` via Lanczos
/// over the streamed matvec. Memory stays `O(tile_rows · c + n · iters)`
/// (the Krylov basis); each Lanczos step re-streams `C` twice.
pub fn top_k_eigs(
    src: &dyn TileSource,
    u: &Matrix,
    k: usize,
    seed: u64,
    cfg: StreamConfig,
) -> (Vec<f64>, Matrix) {
    lanczos::lanczos_top_k_op(src.rows(), k, seed, |v| matvec_cuc(src, u, v, cfg))
}

/// RAM-only residency matching the budgeted ops' contract: the cache grid
/// equals the pipeline tile height, so every request is one grid tile,
/// extra memory is capped by `memory_budget`, and a zero budget reproduces
/// the plain re-streaming path exactly (bits and entries).
fn ram_residency(cfg: StreamConfig, n: usize, memory_budget: u64) -> ResidencyConfig {
    ResidencyConfig::ram_only(memory_budget).with_tile_rows(cfg.effective_tile_rows(n))
}

/// [`top_k_eigs`] with the opt-in cached-`C` mode, routed through the
/// residency layer: when the full panel fits `memory_budget` bytes the
/// first Lanczos pass makes every tile hot and later matvecs read memory
/// instead of re-evaluating kernel tiles (the oracle is charged exactly
/// one `n·c` observation). A partial budget keeps a stable hot prefix
/// resident — entries drop in proportion to the budget, extra memory
/// never exceeds it ([`predicted_implicit_peak_bytes`]'s capped term),
/// and results stay bit-identical. For one-`n·c` at *any* budget, use
/// [`top_k_eigs_resident`] with a spilling config instead.
///
/// [`predicted_implicit_peak_bytes`]: crate::coordinator::planner::predicted_implicit_peak_bytes
pub fn top_k_eigs_budgeted(
    src: &dyn TileSource,
    u: &Matrix,
    k: usize,
    seed: u64,
    cfg: StreamConfig,
    memory_budget: u64,
) -> (Vec<f64>, Matrix) {
    let resident = ResidentSource::new(src, &ram_residency(cfg, src.rows(), memory_budget));
    top_k_eigs(&resident, u, k, seed, cfg)
}

/// [`solve_regularized`] with the opt-in cached-`C` mode (see
/// [`top_k_eigs_budgeted`]): the emit pass reuses the tiles the fold pass
/// made hot when the budget allows.
pub fn solve_regularized_budgeted(
    src: &dyn TileSource,
    u: &Matrix,
    alpha: f64,
    y: &[f64],
    cfg: StreamConfig,
    memory_budget: u64,
) -> Vec<f64> {
    let resident = ResidentSource::new(src, &ram_residency(cfg, src.rows(), memory_budget));
    solve_regularized(&resident, u, alpha, y, cfg)
}

/// [`top_k_eigs`] through a caller-configured residency layer. With a
/// spilling [`ResidencyConfig`] the oracle is charged exactly one `n·c`
/// observation across all `q` Lanczos iterations at any RAM budget
/// (including 0 — every re-read comes from the disk arena), and results
/// are bit-identical to the uncached path. Returns the hit/miss/spill
/// counters alongside the eigenpairs.
pub fn top_k_eigs_resident(
    src: &dyn TileSource,
    u: &Matrix,
    k: usize,
    seed: u64,
    cfg: StreamConfig,
    residency: &ResidencyConfig,
) -> (Vec<f64>, Matrix, ResidencyStats) {
    let resident = ResidentSource::new(src, residency);
    let (vals, vecs) = top_k_eigs(&resident, u, k, seed, cfg);
    let stats = resident.stats();
    (vals, vecs, stats)
}

/// [`solve_regularized`] through a caller-configured residency layer (see
/// [`top_k_eigs_resident`]).
pub fn solve_regularized_resident(
    src: &dyn TileSource,
    u: &Matrix,
    alpha: f64,
    y: &[f64],
    cfg: StreamConfig,
    residency: &ResidencyConfig,
) -> (Vec<f64>, ResidencyStats) {
    let resident = ResidentSource::new(src, residency);
    let w = solve_regularized(&resident, u, alpha, y, cfg);
    let stats = resident.stats();
    (w, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stream::MatrixSource;
    use crate::util::Rng;

    fn toy(n: usize, c: usize, seed: u64) -> (Matrix, Matrix) {
        let mut rng = Rng::new(seed);
        let cmat = Matrix::randn(n, c, &mut rng);
        let mut u = Matrix::randn(c, c, &mut rng);
        u.symmetrize();
        (cmat, u)
    }

    #[test]
    fn matvec_matches_dense_chain() {
        let (cmat, u) = toy(37, 5, 0);
        let x: Vec<f64> = (0..37).map(|i| ((i * 7 % 11) as f64) - 5.0).collect();
        let dense = cmat.matmul(&u).matmul(&cmat.transpose());
        let expect = dense.matvec(&x);
        for tile in [1usize, 8, 37] {
            let src = MatrixSource::new(&cmat);
            let y = matvec_cuc(&src, &u, &x, StreamConfig::tiled(tile));
            let scale: f64 = expect.iter().map(|v| v * v).sum::<f64>().sqrt().max(1.0);
            for (a, b) in y.iter().zip(&expect) {
                assert!((a - b).abs() < 1e-10 * scale, "tile={tile}");
            }
        }
    }

    #[test]
    fn solve_regularized_matches_woodbury() {
        let mut rng = Rng::new(2);
        let cmat = Matrix::randn(33, 5, &mut rng);
        let g = Matrix::randn(5, 5, &mut rng);
        let u = g.matmul_tr(&g); // SPSD
        let y: Vec<f64> = (0..33).map(|_| rng.gaussian()).collect();
        let direct = crate::linalg::solve::woodbury_solve(&cmat, &u, 0.6, &y);
        for tile in [1usize, 8, 33] {
            let src = MatrixSource::new(&cmat);
            let w = solve_regularized(&src, &u, 0.6, &y, StreamConfig::tiled(tile));
            let scale: f64 = direct.iter().map(|v| v * v).sum::<f64>().sqrt().max(1e-12);
            for (a, b) in w.iter().zip(&direct) {
                assert!((a - b).abs() < 1e-8 * scale, "tile={tile}: {a} vs {b}");
            }
        }
        // rank-deficient U still works (the zero part is dropped)
        let g1 = Matrix::randn(5, 1, &mut rng);
        let u1 = g1.matmul_tr(&g1);
        let direct = crate::linalg::solve::woodbury_solve(&cmat, &u1, 0.6, &y);
        let src = MatrixSource::new(&cmat);
        let w = solve_regularized(&src, &u1, 0.6, &y, StreamConfig::tiled(8));
        for (a, b) in w.iter().zip(&direct) {
            assert!((a - b).abs() < 1e-8);
        }
    }

    #[test]
    fn budgeted_topk_matches_and_stops_restreaming() {
        use crate::coordinator::oracle::{KernelOracle, RbfOracle};
        use crate::stream::OracleColumnsSource;
        use std::sync::Arc;
        let mut rng = Rng::new(4);
        let x = Arc::new(Matrix::randn(50, 5, &mut rng));
        let o = RbfOracle::cpu(x, 0.6);
        let cols = [2usize, 11, 23, 40];
        let mut u = Matrix::randn(4, 4, &mut rng);
        u.symmetrize();
        let src = OracleColumnsSource::new(&o, &cols);
        let cfg = StreamConfig::tiled(16);

        o.reset_entries();
        let (vals_plain, _) = top_k_eigs(&src, &u, 2, 9, cfg);
        let entries_plain = o.entries_observed();

        o.reset_entries();
        let (vals_cached, _) = top_k_eigs_budgeted(&src, &u, 2, 9, cfg, u64::MAX);
        let entries_cached = o.entries_observed();

        // identical arithmetic (cached tiles are bit-identical), far fewer
        // kernel evaluations: exactly one n·c observation instead of two
        // per Lanczos step
        for (a, b) in vals_plain.iter().zip(&vals_cached) {
            assert_eq!(a, b, "cached Lanczos must be bit-identical");
        }
        assert_eq!(entries_cached, 50 * 4, "cache must charge exactly one pass");
        assert!(entries_plain > entries_cached, "plain path must re-stream");

        // zero budget: identical results, identical (re-streaming) cost
        o.reset_entries();
        let (vals_zero, _) = top_k_eigs_budgeted(&src, &u, 2, 9, cfg, 0);
        assert_eq!(o.entries_observed(), entries_plain);
        for (a, b) in vals_plain.iter().zip(&vals_zero) {
            assert_eq!(a, b);
        }

        // and the budgeted solve agrees with the plain one
        let y: Vec<f64> = (0..50).map(|i| (i as f64 * 0.7).cos()).collect();
        let w_plain = solve_regularized(&src, &u.gram_nt(), 0.4, &y, cfg);
        let w_cached = solve_regularized_budgeted(&src, &u.gram_nt(), 0.4, &y, cfg, u64::MAX);
        for (a, b) in w_plain.iter().zip(&w_cached) {
            assert_eq!(a, b);
        }
    }

    #[test]
    fn resident_spill_charges_one_pass_at_zero_ram() {
        use crate::coordinator::oracle::{KernelOracle, RbfOracle};
        use crate::stream::OracleColumnsSource;
        use std::sync::Arc;
        let mut rng = Rng::new(8);
        let x = Arc::new(Matrix::randn(45, 5, &mut rng));
        let o = RbfOracle::cpu(x, 0.5);
        let cols = [0usize, 7, 19, 31, 44];
        let mut u = Matrix::randn(5, 5, &mut rng);
        u.symmetrize();
        let src = OracleColumnsSource::new(&o, &cols);
        let cfg = StreamConfig::tiled(9);

        o.reset_entries();
        let (vals_plain, vecs_plain) = top_k_eigs(&src, &u, 3, 11, cfg);
        let entries_plain = o.entries_observed();

        // zero RAM budget + disk spill: identical bits, one n·c charge
        o.reset_entries();
        let rc = ResidencyConfig::new(0).with_tile_rows(9);
        let (vals, vecs, stats) = top_k_eigs_resident(&src, &u, 3, 11, cfg, &rc);
        assert_eq!(o.entries_observed(), 45 * 5, "spill must charge exactly one pass");
        assert!(entries_plain > 45 * 5, "plain path must re-stream");
        for (a, b) in vals_plain.iter().zip(&vals) {
            assert_eq!(a, b, "resident Lanczos must be bit-identical");
        }
        assert_eq!(vecs_plain.max_abs_diff(&vecs), 0.0);
        assert_eq!(stats.computes, 5, "45 rows / 9-row grid");
        assert_eq!(stats.ram_hits, 0);
        assert!(stats.spill_hits > 0, "re-reads must come from the arena");
        assert_eq!(stats.spilled_bytes, 45 * 5 * 8);

        // and the resident solve agrees with the plain one
        let y: Vec<f64> = (0..45).map(|i| (i as f64 * 0.3).sin()).collect();
        let w_plain = solve_regularized(&src, &u.gram_nt(), 0.7, &y, cfg);
        let (w_res, st) = solve_regularized_resident(&src, &u.gram_nt(), 0.7, &y, cfg, &rc);
        for (a, b) in w_plain.iter().zip(&w_res) {
            assert_eq!(a, b);
        }
        assert!(st.spill_hits > 0);
    }

    #[test]
    fn top_k_matches_materialized_eigs() {
        // SPSD chain: U = I so C U C^T = C C^T, eigenvalues = singular
        // values of C squared.
        let mut rng = Rng::new(1);
        let cmat = Matrix::randn(40, 4, &mut rng);
        let u = Matrix::identity(4);
        let src = MatrixSource::new(&cmat);
        let (vals, vecs) = top_k_eigs(&src, &u, 3, 7, StreamConfig::tiled(9));
        assert_eq!(vals.len(), 3);
        assert_eq!((vecs.rows(), vecs.cols()), (40, 3));
        let dense = cmat.matmul_tr(&cmat);
        let exact = crate::linalg::eigh(&dense);
        for i in 0..3 {
            assert!(
                (vals[i] - exact.values[i]).abs() < 1e-6 * exact.values[0],
                "eig {i}: {} vs {}",
                vals[i],
                exact.values[i]
            );
        }
    }
}
