//! Evaluation workloads from the paper's §6: approximate KPCA,
//! classification via KPCA features + KNN, and spectral clustering, plus
//! their quality metrics (misalignment, classification error, NMI).

pub mod kmeans;
pub mod knn;
pub mod kpca;
pub mod krr;
pub mod metrics;
pub mod spectral;

pub use kmeans::kmeans;
pub use knn::knn_classify;
pub use kpca::{exact_kpca, kpca_from_approx, misalignment, KpcaModel};
pub use metrics::{error_rate, nmi};
pub use spectral::{spectral_cluster_exact, spectral_cluster_from_approx};
