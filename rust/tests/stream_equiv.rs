//! Equivalence of the streamed builds with the materialized paths: the
//! tile pipeline must change *where* the arithmetic happens, not *what* it
//! computes. Gather-based paths (fast with column-selection sketches,
//! Nyström, CUR) are bit-identical for every tile size; reduction-grouping
//! paths (prototype, projection sketches) must stay within 1e-12 relative
//! Frobenius error. Tile sizes deliberately include 1, sizes that do not
//! divide n, and n itself.

use fastspsd::coordinator::oracle::{DenseOracle, KernelOracle, RbfOracle};
use fastspsd::cur::{self, FastCurConfig};
use fastspsd::exec::{self, ExecPolicy};
use fastspsd::linalg::Matrix;
use fastspsd::sketch::SketchKind;
use fastspsd::spsd::{self, FastConfig, LeverageBasis};
use fastspsd::stream::{
    self, run_pipeline_resumable, CheckpointConfig, GramFold, MatrixSource, MatvecFold, Precision,
    StreamConfig, TileConsumer, TileSource, ValidateMode,
};
use fastspsd::util::Rng;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};

const MAT: ExecPolicy = ExecPolicy::Materialized;
use std::sync::Arc;

const N: usize = 151; // prime: no tile size divides it
const TILES: [usize; 4] = [1, 7, 64, N];

fn rbf_oracle(n: usize, seed: u64) -> RbfOracle {
    let mut rng = Rng::new(seed);
    let x = Arc::new(Matrix::randn(n, 5, &mut rng));
    RbfOracle::cpu(x, 0.4)
}

fn rel_fro(a: &Matrix, b: &Matrix) -> f64 {
    a.sub(b).fro_norm() / b.fro_norm().max(1e-300)
}

#[test]
fn fast_streamed_matches_materialized_for_every_sketch_family() {
    // The acceptance criterion: streamed fast-model build on an RBF oracle
    // within 1e-12 relative Fro error of the materialized path for every
    // sketch family, across tile sizes that do and don't divide n.
    let o = rbf_oracle(N, 1);
    let p = spsd::uniform_p(N, 10, &mut Rng::new(2));
    let kinds = [
        (SketchKind::Uniform, true),
        (SketchKind::Leverage { scaled: false }, true),
        (SketchKind::Gaussian, false),
        (SketchKind::Srht, false),
        (SketchKind::CountSketch, false),
    ];
    for (kind, force_p) in kinds {
        let cfg = FastConfig {
            s: 30,
            kind,
            force_p_in_s: force_p,
            leverage_basis: LeverageBasis::Gram,
        };
        let mat = exec::fast(&o, &p, cfg, &MAT, &mut Rng::new(7)).result;
        let mat_full = mat.materialize();
        for tile in TILES {
            let st = exec::fast(&o, &p, cfg, &ExecPolicy::streamed(tile), &mut Rng::new(7)).result;
            assert_eq!(
                st.c.max_abs_diff(&mat.c),
                0.0,
                "{}: C panel must be bit-identical (tile={tile})",
                kind.name()
            );
            let err = rel_fro(&st.materialize(), &mat_full);
            assert!(err <= 1e-12, "{} tile={tile}: rel err {err}", kind.name());
            if kind.is_column_selection() {
                assert_eq!(
                    st.u.max_abs_diff(&mat.u),
                    0.0,
                    "{} tile={tile}: selection paths are pure gathers",
                    kind.name()
                );
            }
            assert_eq!(st.entries_observed, mat.entries_observed, "{}", kind.name());
        }
    }
}

#[test]
fn approx_leverage_error_within_1p5x_of_materialized_svd_leverage() {
    // Acceptance: the streamed Gram-based leverage build — which never
    // needs the n x c panel for scoring — matches the materialized
    // (resident-SVD) leverage build's error within 1.5x on the RBF
    // testbed, averaged over seeds.
    let o = rbf_oracle(N, 31);
    let k = o.full();
    let kf = k.fro_norm_sq();
    let mut e_gram = 0.0;
    let mut e_svd = 0.0;
    for seed in 0..5u64 {
        let p = spsd::uniform_p(N, 10, &mut Rng::new(40 + seed));
        let a = exec::fast(
            &o,
            &p,
            FastConfig::leverage(30),
            &ExecPolicy::streamed(32),
            &mut Rng::new(70 + seed),
        )
        .result;
        let b = exec::fast(
            &o,
            &p,
            FastConfig::leverage(30).with_basis(LeverageBasis::ExactSvd),
            &MAT,
            &mut Rng::new(70 + seed),
        )
        .result;
        e_gram += k.sub(&a.materialize()).fro_norm_sq() / kf;
        e_svd += k.sub(&b.materialize()).fro_norm_sq() / kf;
    }
    assert!(e_gram.is_finite() && e_gram < 5.0, "gram leverage err {e_gram} not sane");
    assert!(
        e_gram <= 1.5 * e_svd + 1e-9,
        "streamed gram-leverage err {e_gram} vs materialized svd-leverage err {e_svd}"
    );
}

#[test]
fn sketched_leverage_basis_streams_within_tolerance() {
    // The SRHT Gram-surrogate basis is deterministic per seed but its
    // folds regroup by tile, so streamed builds must match the whole-tile
    // build of the SAME config to reduction-reordering-of-scores accuracy:
    // the drawn S can only differ if a Bernoulli threshold sits inside the
    // ~1e-12 score wobble, which the shared rng stream makes measure-zero
    // at these sizes — and the model error must stay sane either way.
    let o = rbf_oracle(N, 33);
    let k = o.full();
    let cfg = FastConfig::leverage(30).with_basis(LeverageBasis::Sketched { m: 64 });
    let whole = exec::fast(
        &o,
        &spsd::uniform_p(N, 10, &mut Rng::new(50)),
        cfg,
        &ExecPolicy::Streamed(StreamConfig::whole()),
        &mut Rng::new(51),
    )
    .result;
    let e_whole = k.sub(&whole.materialize()).fro_norm_sq() / k.fro_norm_sq();
    assert!(e_whole.is_finite() && e_whole < 1.0, "sketched basis err {e_whole}");
    for tile in [7usize, 64] {
        let p = spsd::uniform_p(N, 10, &mut Rng::new(50));
        let st = exec::fast(&o, &p, cfg, &ExecPolicy::streamed(tile), &mut Rng::new(51)).result;
        assert_eq!(st.c.max_abs_diff(&whole.c), 0.0, "C is a pure gather (tile={tile})");
        let e_st = k.sub(&st.materialize()).fro_norm_sq() / k.fro_norm_sq();
        assert!(
            (e_st - e_whole).abs() <= 0.5 * e_whole.max(1e-6),
            "tile={tile}: sketched-basis streamed err {e_st} vs whole {e_whole}"
        );
    }
}

#[test]
fn nystrom_and_prototype_streamed_match() {
    let o = rbf_oracle(N, 3);
    let p = spsd::uniform_p(N, 12, &mut Rng::new(4));
    let ny = exec::nystrom(&o, &p, &MAT).result;
    let proto = exec::prototype(&o, &p, &MAT).result;
    for tile in TILES {
        let ny_s = exec::nystrom(&o, &p, &ExecPolicy::streamed(tile)).result;
        assert_eq!(ny_s.c.max_abs_diff(&ny.c), 0.0, "tile={tile}");
        assert_eq!(ny_s.u.max_abs_diff(&ny.u), 0.0, "tile={tile}");
        assert_eq!(ny_s.entries_observed, ny.entries_observed);

        let proto_s = exec::prototype(&o, &p, &ExecPolicy::streamed(tile)).result;
        assert_eq!(proto_s.c.max_abs_diff(&proto.c), 0.0, "tile={tile}");
        let err = rel_fro(&proto_s.u, &proto.u);
        assert!(err <= 1e-12, "prototype tile={tile}: rel err {err}");
        assert_eq!(proto_s.entries_observed, proto.entries_observed);
    }
}

#[test]
fn dense_oracle_selection_paths_are_bit_identical() {
    // On a DenseOracle the tiles are pure copies of K's rows, so even the
    // kernel evaluation cannot introduce noise: everything gather-based
    // must match to the bit.
    let mut rng = Rng::new(5);
    let g = Matrix::randn(97, 97, &mut rng);
    let k = g.matmul_tr(&g);
    let o = DenseOracle::new(k);
    let p = spsd::uniform_p(97, 9, &mut Rng::new(6));
    let mat = exec::fast(&o, &p, FastConfig::uniform(27), &MAT, &mut Rng::new(8)).result;
    for tile in [1usize, 13, 97] {
        let st =
            exec::fast(&o, &p, FastConfig::uniform(27), &ExecPolicy::streamed(tile), &mut Rng::new(8))
                .result;
        assert_eq!(st.c.max_abs_diff(&mat.c), 0.0);
        assert_eq!(st.u.max_abs_diff(&mat.u), 0.0);
    }
}

#[test]
fn cur_streamed_matches_materialized_across_tiles() {
    let mut rng = Rng::new(9);
    let a = Matrix::randn(106, 73, &mut rng); // no tile divides 106
    for cfg in [
        FastCurConfig::uniform(25, 25),
        FastCurConfig::leverage(25, 25),
        FastCurConfig::leverage_svd(25, 25),
    ] {
        let mut r1 = Rng::new(11);
        let cols = cur::select_uniform(73, 8, &mut r1);
        let rows = cur::select_uniform(106, 8, &mut r1);
        let mat = exec::cur_fast(&a, &cols, &rows, cfg, &MAT, &mut Rng::new(13)).result;
        for tile in [1usize, 7, 64, 106] {
            let st =
                exec::cur_fast(&a, &cols, &rows, cfg, &ExecPolicy::streamed(tile), &mut Rng::new(13))
                    .result;
            assert_eq!(st.c.max_abs_diff(&mat.c), 0.0, "C tile={tile}");
            assert_eq!(st.r.max_abs_diff(&mat.r), 0.0, "R tile={tile}");
            assert_eq!(st.u.max_abs_diff(&mat.u), 0.0, "{} U tile={tile}", mat.method);
        }
    }
}

#[test]
fn implicit_matvec_and_topk_match_materialized_approx() {
    let o = rbf_oracle(120, 14);
    let p = spsd::uniform_p(120, 10, &mut Rng::new(15));
    let approx = exec::fast(&o, &p, FastConfig::uniform(30), &MAT, &mut Rng::new(16)).result;
    let dense = approx.materialize();

    // matvec against the implicit C U C^T, re-streaming C from the oracle
    let x: Vec<f64> = (0..120).map(|i| ((i * 3 % 17) as f64) - 8.0).collect();
    let expect = dense.matvec(&x);
    let src = stream::OracleColumnsSource::new(&o, &approx.p_indices);
    let y = stream::matvec_cuc(&src, &approx.u, &x, StreamConfig::tiled(32));
    let scale: f64 = expect.iter().map(|v| v * v).sum::<f64>().sqrt().max(1e-12);
    for (a, b) in y.iter().zip(&expect) {
        assert!((a - b).abs() < 1e-9 * scale);
    }

    // top-k Lanczos against the implicit operator vs the O(nc²) eig
    let (vals, vecs) = exec::top_k_eigs(&src, &approx.u, 4, 21, &ExecPolicy::streamed(32)).result;
    let (vals_mat, _) = approx.eig_k(4);
    assert_eq!((vecs.rows(), vecs.cols()), (120, 4));
    for i in 0..4 {
        assert!(
            (vals[i] - vals_mat[i]).abs() < 1e-6 * vals_mat[0].abs().max(1e-12),
            "eig {i}: {} vs {}",
            vals[i],
            vals_mat[i]
        );
    }
}

#[test]
fn matrix_source_reassembles_through_every_tile_size() {
    let mut rng = Rng::new(17);
    let a = Matrix::randn(59, 8, &mut rng);
    for tile in [1usize, 7, 59, 64] {
        let src = MatrixSource::new(&a);
        let mut collect = stream::CollectConsumer::new(59, 8);
        stream::run_pipeline(&src, tile, 2, &mut [&mut collect]);
        assert_eq!(collect.into_matrix().max_abs_diff(&a), 0.0, "tile={tile}");
    }
}

// ---- checkpoint/resume equivalence ------------------------------------
//
// A streamed pass interrupted mid-flight and resumed from its checkpoint
// must produce bit-identical fold results to the uninterrupted pass, and
// the resume may charge the source only for the tiles after the
// checkpointed row — that re-charging contract is what makes resume
// cheaper than re-running.

const CK_N: usize = 40;
const CK_TILE: usize = 8; // 5 tiles; with_every(1) checkpoints after each

/// Wraps [`MatrixSource`] and counts how many tiles the pipeline charges
/// it for — the streamed analogue of "oracle entries observed".
struct CountingSource<'a> {
    inner: MatrixSource<'a>,
    tiles: AtomicUsize,
}

impl<'a> CountingSource<'a> {
    fn new(a: &'a Matrix) -> Self {
        CountingSource { inner: MatrixSource::new(a), tiles: AtomicUsize::new(0) }
    }

    fn tiles(&self) -> usize {
        self.tiles.load(Ordering::SeqCst)
    }
}

impl TileSource for CountingSource<'_> {
    fn rows(&self) -> usize {
        self.inner.rows()
    }

    fn cols(&self) -> usize {
        self.inner.cols()
    }

    fn tile(&self, r0: usize, r1: usize) -> Matrix {
        self.tiles.fetch_add(1, Ordering::SeqCst);
        self.inner.tile(r0, r1)
    }
}

/// Column-sum fold that panics when asked to fold the tile starting at
/// `panic_at` — the in-test stand-in for a mid-pass crash. Snapshots and
/// restores its accumulator so it keeps the pass checkpoint-eligible
/// (eligibility requires *every* consumer to snapshot).
struct BombFold {
    acc: Vec<f64>,
    panic_at: Option<usize>,
}

impl BombFold {
    fn new(width: usize, panic_at: Option<usize>) -> Self {
        BombFold { acc: vec![0.0; width], panic_at }
    }
}

impl TileConsumer for BombFold {
    fn consume(&mut self, r0: usize, tile: &Matrix) {
        if self.panic_at == Some(r0) {
            panic!("bomb: interrupted at row {r0}");
        }
        for r in 0..tile.rows() {
            for (a, v) in self.acc.iter_mut().zip(tile.row(r)) {
                *a += v;
            }
        }
    }

    fn snapshot(&self) -> Option<Matrix> {
        Some(Matrix::from_vec(1, self.acc.len(), self.acc.clone()))
    }

    fn restore(&mut self, state: &Matrix) -> bool {
        if state.rows() != 1 || state.cols() != self.acc.len() {
            return false;
        }
        self.acc.copy_from_slice(state.row(0));
        true
    }
}

#[test]
fn interrupted_pass_resumes_bit_identically_and_recharges_only_the_tail() {
    let dir = std::env::temp_dir().join(format!("fastspsd-ckpt-equiv-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();

    let mut rng = Rng::new(61);
    let a = Matrix::randn(CK_N, 6, &mut rng);
    let x: Vec<f64> = (0..CK_N).map(|i| ((i * 5 % 13) as f64) - 6.0).collect();
    let ckpt = CheckpointConfig::new(&dir).with_every(1);

    // Uninterrupted reference through the same resumable entry point; a
    // completed pass must leave no checkpoint behind.
    let (g_ref, v_ref, b_ref) = {
        let src = CountingSource::new(&a);
        let mut gram = GramFold::new(6);
        let mut mv = MatvecFold::new(&x, 6);
        let mut bomb = BombFold::new(6, None);
        run_pipeline_resumable(
            &src,
            CK_TILE,
            2,
            Precision::F64,
            ValidateMode::Off,
            &ckpt,
            &mut [&mut gram, &mut mv, &mut bomb],
        )
        .unwrap();
        assert!(
            std::fs::read_dir(&dir).unwrap().next().is_none(),
            "a completed pass discards its checkpoint"
        );
        (gram.into_matrix(), mv.into_vec(), bomb.acc)
    };

    // Interrupted run: the bomb goes off on the 4th tile (r0 = 24), after
    // the checkpoint covering rows [0, 24) was persisted.
    let src = CountingSource::new(&a);
    let blast = catch_unwind(AssertUnwindSafe(|| {
        let mut gram = GramFold::new(6);
        let mut mv = MatvecFold::new(&x, 6);
        let mut bomb = BombFold::new(6, Some(3 * CK_TILE));
        let _ = run_pipeline_resumable(
            &src,
            CK_TILE,
            2,
            Precision::F64,
            ValidateMode::Off,
            &ckpt,
            &mut [&mut gram, &mut mv, &mut bomb],
        );
    }));
    assert!(blast.is_err(), "the bomb must abort the pass");
    let ckpt_file = dir.join("ckpt-pass-1.bin");
    assert!(ckpt_file.exists(), "an interrupted pass leaves its checkpoint for the retry");

    // Resume with fresh consumers: state restores from the checkpoint and
    // only the two tiles at/after row 24 are re-streamed.
    let src2 = CountingSource::new(&a);
    let mut gram = GramFold::new(6);
    let mut mv = MatvecFold::new(&x, 6);
    let mut bomb = BombFold::new(6, None);
    run_pipeline_resumable(
        &src2,
        CK_TILE,
        2,
        Precision::F64,
        ValidateMode::Off,
        &ckpt,
        &mut [&mut gram, &mut mv, &mut bomb],
    )
    .unwrap();
    assert_eq!(src2.tiles(), 2, "resume re-charges the source only for rows >= 24");
    assert_eq!(
        gram.into_matrix().max_abs_diff(&g_ref),
        0.0,
        "resumed Gram fold is bit-identical to the uninterrupted pass"
    );
    assert_eq!(mv.into_vec(), v_ref, "resumed matvec fold is bit-identical");
    assert_eq!(bomb.acc, b_ref, "resumed custom fold is bit-identical");
    assert!(!ckpt_file.exists(), "a resumed pass discards its checkpoint on success");
    assert!(std::fs::read_dir(&dir).unwrap().next().is_none(), "checkpoint dir drained");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn whole_tile_resumable_pass_streams_unchanged_and_writes_no_checkpoint() {
    // tile = n takes the materialized fallback: one inline tile, nothing
    // worth resuming, so arming a checkpoint must be a no-op on disk.
    let dir = std::env::temp_dir().join(format!("fastspsd-ckpt-whole-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();

    let mut rng = Rng::new(62);
    let a = Matrix::randn(CK_N, 6, &mut rng);
    let plain = {
        let src = MatrixSource::new(&a);
        let mut gram = GramFold::new(6);
        stream::run_pipeline(&src, CK_N, 2, &mut [&mut gram]);
        gram.into_matrix()
    };

    let src = CountingSource::new(&a);
    let mut gram = GramFold::new(6);
    run_pipeline_resumable(
        &src,
        CK_N,
        2,
        Precision::F64,
        ValidateMode::Off,
        &CheckpointConfig::new(&dir).with_every(1),
        &mut [&mut gram],
    )
    .unwrap();
    assert_eq!(src.tiles(), 1, "whole-tile pass charges exactly one tile");
    assert_eq!(gram.into_matrix().max_abs_diff(&plain), 0.0, "whole-tile fold unchanged");
    assert!(
        std::fs::read_dir(&dir).unwrap().next().is_none(),
        "whole-tile pass writes no checkpoint"
    );
    let _ = std::fs::remove_dir_all(&dir);
}
