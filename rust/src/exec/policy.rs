//! The execution-policy vocabulary: *what to run* (the algorithm configs
//! in [`spsd`](crate::spsd) / [`cur`](crate::cur)) is separated from *how
//! to run it* ([`ExecPolicy`]) and from *what happened*
//! ([`RunReport`] / [`RunMeta`]).

use crate::linalg::NumericHealth;
use crate::obs::StageProfile;
use crate::shard::ShardStats;
use crate::stream::{
    Precision, ResidencyConfig, ResidencyStats, StreamConfig, ValidateMode,
    DEFAULT_RESIDENT_TILE_ROWS,
};
use std::path::PathBuf;

/// How a build or implicit operation should traverse its source.
///
/// Every algorithm entry point in [`exec`](crate::exec) takes one of
/// these; the paper's models themselves never change, only the traversal:
///
/// - [`Materialized`](ExecPolicy::Materialized) — whole-matrix tiles, the
///   historical in-memory path (bit-compatible with the pre-policy code).
/// - [`Streamed`](ExecPolicy::Streamed) — the bounded double-buffered tile
///   pipeline of [`stream`](crate::stream): peak extra memory
///   `O(tile_rows · c + s²)` instead of resident panels.
/// - [`Resident`](ExecPolicy::Resident) — the streamed pipeline behind the
///   tile residency layer ([`ResidentSource`]): a `budget`-byte hot-tile
///   LRU, optionally backed by a disk spill arena, so multi-pass plans pay
///   the underlying source exactly once per tile.
/// - [`Sharded`](ExecPolicy::Sharded) — the row-sharded execution plane of
///   [`shard`](crate::shard): N workers each own a contiguous row-block,
///   run the `inner` policy locally over it, and the coordinator merges the
///   tiny associative partial states before finishing the solve once.
///
/// A device (GPU / PJRT) tile backend slots in here as another variant —
/// callers match on nothing, they just hand the policy down.
///
/// [`ResidentSource`]: crate::stream::ResidentSource
#[derive(Debug, Clone, PartialEq)]
pub enum ExecPolicy {
    /// One whole-matrix tile: the materialized path.
    Materialized,
    /// Fixed-height row tiles through the double-buffered pipeline.
    Streamed(StreamConfig),
    /// Streamed through the tile residency layer.
    Resident {
        /// Max bytes of tiles held hot in the RAM LRU (0 = nothing stays
        /// hot; with `spill` every re-read then comes from disk).
        budget: u64,
        /// Write cold tiles through to a disk arena so they are reloaded,
        /// never recomputed (`false` = the budget-gated cached-`C`
        /// semantics: evicted tiles are recomputed).
        spill: bool,
        /// Pipeline *and* residency-grid tile height (`None` =
        /// [`DEFAULT_RESIDENT_TILE_ROWS`]). One value for both keeps every
        /// pipeline request aligned with the cache grid.
        tile_rows: Option<usize>,
        /// Directory for the spill arena (`None` = the system temp dir).
        /// Ignored unless `spill` is set.
        spill_dir: Option<PathBuf>,
        /// Element width tiles are computed, cached, and spilled at
        /// (`F32` halves cache/spill bytes; folds still accumulate f64).
        precision: Precision,
        /// Tile quarantine mode for the pipeline passes this policy runs
        /// (`Off` = the zero-overhead bit-compat default).
        validate: ValidateMode,
    },
    /// Row-sharded scale-out ([`shard`](crate::shard)): `shards` workers
    /// each own a contiguous row-block of the source and run `inner` over
    /// it; per-worker partial fold state is merged by the coordinator.
    /// Selection paths stay bit-identical to the unsharded `inner` run;
    /// reduction paths regroup floating-point sums (≤1e-12).
    Sharded {
        /// Worker count (clamped to `[1, n]` when ranges are cut).
        shards: usize,
        /// How each worker traverses its own row-block. Builders and
        /// accessors on a `Sharded` policy delegate to this inner policy.
        inner: Box<ExecPolicy>,
    },
}

impl ExecPolicy {
    /// Stream in `tile_rows`-high tiles with the default queue depth.
    pub fn streamed(tile_rows: usize) -> Self {
        ExecPolicy::Streamed(StreamConfig::tiled(tile_rows))
    }

    /// Residency with a RAM budget and disk spill (one source read per
    /// tile at any budget, including 0).
    pub fn resident(budget: u64) -> Self {
        ExecPolicy::Resident {
            budget,
            spill: true,
            tile_rows: None,
            spill_dir: None,
            precision: Precision::F64,
            validate: ValidateMode::Off,
        }
    }

    /// RAM-only residency: the budget-gated cached-panel mode the old
    /// `*_budgeted` entry points implemented (no arena; evicted tiles are
    /// recomputed, a zero budget reproduces plain re-streaming exactly).
    pub fn ram_cached(budget: u64) -> Self {
        ExecPolicy::Resident {
            budget,
            spill: false,
            tile_rows: None,
            spill_dir: None,
            precision: Precision::F64,
            validate: ValidateMode::Off,
        }
    }

    /// `shards` row-sharded workers, each running `inner` over its own
    /// contiguous row-block ([`plan_shards`](crate::coordinator::planner::plan_shards)
    /// picks both from a memory budget).
    pub fn sharded(shards: usize, inner: ExecPolicy) -> Self {
        ExecPolicy::Sharded { shards: shards.max(1), inner: Box::new(inner) }
    }

    /// Pin the tile height of a [`Resident`](ExecPolicy::Resident) policy
    /// (no-op for the other variants — use [`ExecPolicy::streamed`] to
    /// pick a streamed tile height).
    pub fn with_tile_rows(mut self, t: usize) -> Self {
        match &mut self {
            ExecPolicy::Resident { tile_rows, .. } => *tile_rows = Some(t.max(1)),
            ExecPolicy::Sharded { inner, .. } => {
                **inner = std::mem::take(&mut **inner).with_tile_rows(t);
            }
            _ => {}
        }
        self
    }

    /// Point a spilling [`Resident`](ExecPolicy::Resident) policy at a
    /// directory (no-op for the other variants and for `spill: false`;
    /// [`Sharded`](ExecPolicy::Sharded) delegates to its inner policy).
    pub fn with_spill_dir(mut self, dir: impl Into<PathBuf>) -> Self {
        let dir = dir.into();
        match &mut self {
            ExecPolicy::Resident { spill: true, spill_dir, .. } => *spill_dir = Some(dir),
            ExecPolicy::Sharded { inner, .. } => {
                **inner = std::mem::take(&mut **inner).with_spill_dir(dir);
            }
            _ => {}
        }
        self
    }

    /// Pick the tile element width. Takes effect on the
    /// [`Streamed`](ExecPolicy::Streamed) and
    /// [`Resident`](ExecPolicy::Resident) variants; a deliberate no-op on
    /// [`Materialized`](ExecPolicy::Materialized), whose whole-matrix path
    /// is the f64 bit-compat reference and has no tile plane to narrow.
    pub fn with_precision(mut self, p: Precision) -> Self {
        match &mut self {
            ExecPolicy::Materialized => {}
            ExecPolicy::Streamed(cfg) => cfg.precision = p,
            ExecPolicy::Resident { precision, .. } => *precision = p,
            ExecPolicy::Sharded { inner, .. } => {
                **inner = std::mem::take(&mut **inner).with_precision(p);
            }
        }
        self
    }

    /// The tile element width this policy runs at ([`Precision::F64`] for
    /// [`Materialized`](ExecPolicy::Materialized)).
    pub fn precision(&self) -> Precision {
        match self {
            ExecPolicy::Materialized => Precision::F64,
            ExecPolicy::Streamed(cfg) => cfg.precision,
            ExecPolicy::Resident { precision, .. } => *precision,
            ExecPolicy::Sharded { inner, .. } => inner.precision(),
        }
    }

    /// Pick the tile quarantine mode. Takes effect on the
    /// [`Streamed`](ExecPolicy::Streamed) and
    /// [`Resident`](ExecPolicy::Resident) variants; a deliberate no-op on
    /// [`Materialized`](ExecPolicy::Materialized) (whole-matrix builds
    /// have no tile pipeline to scan — its one inline tile is validated
    /// only when routed through a streamed config).
    pub fn with_validate(mut self, v: ValidateMode) -> Self {
        match &mut self {
            ExecPolicy::Materialized => {}
            ExecPolicy::Streamed(cfg) => cfg.validate = v,
            ExecPolicy::Resident { validate, .. } => *validate = v,
            ExecPolicy::Sharded { inner, .. } => {
                **inner = std::mem::take(&mut **inner).with_validate(v);
            }
        }
        self
    }

    /// The tile quarantine mode this policy runs with
    /// ([`ValidateMode::Off`] for
    /// [`Materialized`](ExecPolicy::Materialized)).
    pub fn validate(&self) -> ValidateMode {
        match self {
            ExecPolicy::Materialized => ValidateMode::Off,
            ExecPolicy::Streamed(cfg) => cfg.validate,
            ExecPolicy::Resident { validate, .. } => *validate,
            ExecPolicy::Sharded { inner, .. } => inner.validate(),
        }
    }

    /// The pipeline configuration this policy runs with.
    pub(crate) fn stream_config(&self) -> StreamConfig {
        match self {
            ExecPolicy::Materialized => StreamConfig::whole(),
            ExecPolicy::Streamed(cfg) => *cfg,
            ExecPolicy::Resident { tile_rows, precision, validate, .. } => {
                StreamConfig::tiled(tile_rows.unwrap_or(DEFAULT_RESIDENT_TILE_ROWS))
                    .with_precision(*precision)
                    .with_validate(*validate)
            }
            ExecPolicy::Sharded { inner, .. } => inner.stream_config(),
        }
    }

    /// The residency layer this policy asks for (`None` for the
    /// non-resident variants). The grid height always equals
    /// [`ExecPolicy::stream_config`]'s tile height, so pipeline requests
    /// align with cached tiles.
    pub(crate) fn residency_config(&self) -> Option<ResidencyConfig> {
        match self {
            ExecPolicy::Resident { budget, spill, tile_rows, spill_dir, precision, .. } => {
                let mut rc = if *spill {
                    ResidencyConfig::new(*budget)
                } else {
                    ResidencyConfig::ram_only(*budget)
                }
                .with_tile_rows(tile_rows.unwrap_or(DEFAULT_RESIDENT_TILE_ROWS))
                .with_precision(*precision);
                if *spill {
                    if let Some(dir) = spill_dir {
                        rc = rc.with_spill_dir(dir.clone());
                    }
                }
                Some(rc)
            }
            ExecPolicy::Sharded { inner, .. } => inner.residency_config(),
            _ => None,
        }
    }

    /// The RAM cache budget this policy grants (0 for non-resident
    /// policies) — the planner's capped cache term.
    pub(crate) fn cache_budget(&self) -> u64 {
        match self {
            ExecPolicy::Resident { budget, .. } => *budget,
            ExecPolicy::Sharded { inner, .. } => inner.cache_budget(),
            _ => 0,
        }
    }

    /// The tile height the planner's peak-bytes model should charge
    /// (`None` = the materialized path).
    pub(crate) fn planned_tile_rows(&self, n: usize) -> Option<usize> {
        match self {
            ExecPolicy::Materialized => None,
            ExecPolicy::Streamed(cfg) if cfg.is_whole(n) => None,
            ExecPolicy::Streamed(cfg) => Some(cfg.effective_tile_rows(n)),
            ExecPolicy::Resident { tile_rows, .. } => {
                Some(tile_rows.unwrap_or(DEFAULT_RESIDENT_TILE_ROWS).clamp(1, n.max(1)))
            }
            ExecPolicy::Sharded { inner, .. } => inner.planned_tile_rows(n),
        }
    }
}

impl Default for ExecPolicy {
    fn default() -> Self {
        ExecPolicy::Materialized
    }
}

/// One step the degrade-don't-die ladder took on a request.
///
/// The ladder trades accuracy for working-set bytes in the order the
/// theory prices it: free policy changes first, then the sampling scheme,
/// then the sketch size `c`/`s` (whose error bound degrades gracefully —
/// Gittens–Mahoney, arXiv 1303.1849).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DegradeAction {
    /// Execution policy swapped for a cheaper traversal of the same
    /// computation (bit-identical result, smaller predicted peak).
    PolicyTightened,
    /// Leverage-score sampling relaxed to uniform (drops the score state
    /// and the extra pass; weaker but still bounded error).
    SamplingRelaxed,
    /// Tile element width lowered f64 → f32: tile, live-tile, and
    /// panel-cache bytes halve while folds keep f64 accumulators. Costs
    /// only tile rounding (≈1e-7 relative), far below the sampling error —
    /// which is why it sits before the sketch shrink rungs.
    PrecisionLowered,
    /// Sketch sizes halved toward the rank floor (`c`, and `s`/`r` where
    /// the method has them).
    SketchShrunk,
}

/// How a degraded request was actually served: which rung of the ladder,
/// what `c` it ran with versus what was asked, and every action taken to
/// get there. Present in [`RunMeta::degraded`] and mirrored on
/// `ApproxResponse` so callers always see that accuracy was traded.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DegradeInfo {
    /// 1-based rung index on the request's ladder (rung 0 = undegraded is
    /// never recorded).
    pub rung: usize,
    /// The sketch size the caller asked for.
    pub requested_c: usize,
    /// The sketch size the request was served with.
    pub c: usize,
    /// Every action applied, in ladder order (cumulative up to this rung).
    pub actions: Vec<DegradeAction>,
}

/// What a run cost — the policy-independent half of every
/// [`RunReport`], and the block service responses embed.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RunMeta {
    /// Source entries observed during the run (`None` when the source has
    /// no entry counter — e.g. the implicit ops over a bare
    /// [`TileSource`](crate::stream::TileSource), or CUR's in-memory
    /// matrix, which reports only its `entries_for_u`).
    pub entries: Option<u64>,
    /// Wall-clock seconds inside the `exec` entry point.
    pub compute_secs: f64,
    /// Hit/miss/spill counters when the run went through the tile
    /// residency layer (`None` otherwise, including when a
    /// [`Resident`](ExecPolicy::Resident) policy had to fall back —
    /// projection sketches and the prototype model stream the full `K`,
    /// which is not a reloadable working set).
    pub residency: Option<ResidencyStats>,
    /// Planner-predicted peak working-set bytes under this policy
    /// (`None` where no prediction model exists, e.g. rectangular CUR).
    pub predicted_peak_bytes: Option<u64>,
    /// Measured peak extra allocation, when the benchkit counting
    /// allocator is installed as the global allocator (`None` otherwise).
    /// Process-global: only meaningful for single-threaded runs.
    pub actual_peak_bytes: Option<u64>,
    /// Which rung of the degrade ladder served this run (`None` = served
    /// exactly as requested). Set by the service admission path; the bare
    /// `exec` entry points always run what they are handed.
    pub degraded: Option<DegradeInfo>,
    /// Tile element width the run executed at (the policy's
    /// [`ExecPolicy::precision`]; [`Precision::F64`] unless narrowed).
    pub precision: Precision,
    /// Per-stage span aggregates for this run, when the span recorder is
    /// installed ([`obs::ensure_installed`](crate::obs::ensure_installed));
    /// `None` with the recorder disabled — tracing off means no bit of the
    /// report changes.
    pub stage_profile: Option<StageProfile>,
    /// Numeric integrity record: worst core condition estimate, strongest
    /// regularization, quarantined tiles, and corrupt spill reads. All
    /// zeros/`None` (see [`NumericHealth::is_clean`]) on a clean run.
    pub numeric_health: NumericHealth,
    /// Per-worker accounting when the run executed under
    /// [`ExecPolicy::Sharded`] (`None` otherwise, including when a
    /// sharded request fell back to its inner policy — e.g. projection
    /// sketches, whose full-`K` pass is not row-shardable here).
    pub shard: Option<ShardStats>,
}

/// The uniform return of every `exec` entry point: the algorithm's result
/// plus the [`RunMeta`] accounting.
#[derive(Debug, Clone)]
pub struct RunReport<T> {
    pub result: T,
    pub meta: RunMeta,
}

impl<T> RunReport<T> {
    /// Keep the accounting, transform the result.
    pub fn map<U>(self, f: impl FnOnce(T) -> U) -> RunReport<U> {
        RunReport { result: f(self.result), meta: self.meta }
    }

    /// Drop the accounting.
    pub fn into_result(self) -> T {
        self.result
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_resolution_round_trips() {
        assert_eq!(ExecPolicy::Materialized.stream_config(), StreamConfig::whole());
        assert!(ExecPolicy::Materialized.residency_config().is_none());

        let st = ExecPolicy::streamed(64);
        assert_eq!(st.stream_config(), StreamConfig::tiled(64));
        assert!(st.residency_config().is_none());
        assert_eq!(st.planned_tile_rows(1000), Some(64));
        assert_eq!(ExecPolicy::streamed(2000).planned_tile_rows(1000), None);

        let r = ExecPolicy::resident(1 << 20).with_tile_rows(32);
        let rc = r.residency_config().expect("resident policy must configure residency");
        assert_eq!(rc.ram_budget, 1 << 20);
        assert_eq!(rc.tile_rows, 32);
        assert!(rc.spill);
        assert_eq!(r.stream_config(), StreamConfig::tiled(32));
        assert_eq!(r.cache_budget(), 1 << 20);

        let ram = ExecPolicy::ram_cached(0);
        let rc = ram.residency_config().unwrap();
        assert!(!rc.spill);
        assert_eq!(rc.tile_rows, DEFAULT_RESIDENT_TILE_ROWS);

        // spill_dir must not silently enable spill on a ram-only policy
        let ram = ExecPolicy::ram_cached(0).with_spill_dir("/tmp");
        assert!(!ram.residency_config().unwrap().spill);
    }

    #[test]
    fn precision_threads_through_policy_resolution() {
        // default everywhere is f64
        assert_eq!(ExecPolicy::Materialized.precision(), Precision::F64);
        assert_eq!(ExecPolicy::streamed(64).precision(), Precision::F64);
        assert_eq!(ExecPolicy::resident(1 << 20).precision(), Precision::F64);

        let st = ExecPolicy::streamed(64).with_precision(Precision::F32);
        assert_eq!(st.precision(), Precision::F32);
        assert_eq!(st.stream_config().precision, Precision::F32);

        let r = ExecPolicy::resident(1 << 20).with_precision(Precision::F32);
        assert_eq!(r.precision(), Precision::F32);
        assert_eq!(r.stream_config().precision, Precision::F32);
        assert_eq!(r.residency_config().unwrap().precision, Precision::F32);

        // Materialized is the f64 reference path: narrowing is a no-op
        let m = ExecPolicy::Materialized.with_precision(Precision::F32);
        assert_eq!(m.precision(), Precision::F64);
    }

    #[test]
    fn validate_threads_through_policy_resolution() {
        // default everywhere is Off — the zero-overhead bit-compat path
        assert_eq!(ExecPolicy::Materialized.validate(), ValidateMode::Off);
        assert_eq!(ExecPolicy::streamed(64).validate(), ValidateMode::Off);
        assert_eq!(ExecPolicy::resident(1 << 20).validate(), ValidateMode::Off);

        let st = ExecPolicy::streamed(64).with_validate(ValidateMode::NonFinite);
        assert_eq!(st.validate(), ValidateMode::NonFinite);
        assert_eq!(st.stream_config().validate, ValidateMode::NonFinite);

        let r = ExecPolicy::resident(1 << 20).with_validate(ValidateMode::Full);
        assert_eq!(r.validate(), ValidateMode::Full);
        assert_eq!(r.stream_config().validate, ValidateMode::Full);

        // Materialized has no tile pipeline: a no-op, like precision
        let m = ExecPolicy::Materialized.with_validate(ValidateMode::Full);
        assert_eq!(m.validate(), ValidateMode::Off);
    }

    #[test]
    fn sharded_policy_delegates_to_its_inner() {
        let sh = ExecPolicy::sharded(4, ExecPolicy::streamed(32));
        assert_eq!(sh.stream_config(), StreamConfig::tiled(32));
        assert!(sh.residency_config().is_none());
        assert_eq!(sh.cache_budget(), 0);
        assert_eq!(sh.planned_tile_rows(1000), Some(32));
        assert_eq!(sh.precision(), Precision::F64);
        assert_eq!(sh.validate(), ValidateMode::Off);

        // builders recurse into the inner policy
        let sh = sh.with_precision(Precision::F32).with_validate(ValidateMode::NonFinite);
        assert_eq!(sh.precision(), Precision::F32);
        assert_eq!(sh.stream_config().precision, Precision::F32);
        assert_eq!(sh.validate(), ValidateMode::NonFinite);

        let shr = ExecPolicy::sharded(2, ExecPolicy::resident(1 << 20))
            .with_tile_rows(48)
            .with_spill_dir("/tmp");
        let rc = shr.residency_config().expect("sharded-resident configures residency");
        assert_eq!(rc.tile_rows, 48);
        assert!(rc.spill);
        assert_eq!(shr.cache_budget(), 1 << 20);
        assert_eq!(shr.stream_config(), StreamConfig::tiled(48));

        // worker count floor
        assert!(matches!(
            ExecPolicy::sharded(0, ExecPolicy::Materialized),
            ExecPolicy::Sharded { shards: 1, .. }
        ));
    }
}
