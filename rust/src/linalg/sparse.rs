//! Sparse CSR matrix substrate.
//!
//! The paper's cost model charges `nnz(A)`-time for CountSketch and notes
//! (§5.1) that CUR "preserves the sparsity" of `A` — unlike the SVD. This
//! module provides the CSR representation those claims live on: nnz-time
//! sketching, sparse row/column selection (so C and R stay sparse), and
//! the dense bridges the algorithms need.

use super::Matrix;
use crate::util::Rng;

/// Compressed sparse row matrix (f64).
#[derive(Debug, Clone, PartialEq)]
pub struct CsrMatrix {
    rows: usize,
    cols: usize,
    /// row i spans indptr[i]..indptr[i+1] in `indices`/`values`
    indptr: Vec<usize>,
    indices: Vec<usize>,
    values: Vec<f64>,
}

impl CsrMatrix {
    /// Build from (row, col, value) triplets; duplicate entries are summed.
    pub fn from_triplets(rows: usize, cols: usize, mut trip: Vec<(usize, usize, f64)>) -> Self {
        trip.sort_unstable_by_key(|&(r, c, _)| (r, c));
        let mut indptr = vec![0usize; rows + 1];
        let mut indices = Vec::with_capacity(trip.len());
        let mut values: Vec<f64> = Vec::with_capacity(trip.len());
        for &(r, c, v) in &trip {
            assert!(r < rows && c < cols, "triplet ({r},{c}) out of bounds");
            indptr[r + 1] += 1;
            indices.push(c);
            values.push(v);
        }
        for i in 0..rows {
            indptr[i + 1] += indptr[i];
        }
        // merge duplicates within rows (already sorted)
        let mut m = CsrMatrix { rows, cols, indptr, indices, values };
        m.merge_duplicates();
        m
    }

    fn merge_duplicates(&mut self) {
        let mut new_indptr = vec![0usize; self.rows + 1];
        let mut new_indices = Vec::with_capacity(self.indices.len());
        let mut new_values = Vec::with_capacity(self.values.len());
        for r in 0..self.rows {
            let lo = self.indptr[r];
            let hi = self.indptr[r + 1];
            let mut j = lo;
            while j < hi {
                let c = self.indices[j];
                let mut v = self.values[j];
                let mut k = j + 1;
                while k < hi && self.indices[k] == c {
                    v += self.values[k];
                    k += 1;
                }
                if v != 0.0 {
                    new_indices.push(c);
                    new_values.push(v);
                }
                j = k;
            }
            new_indptr[r + 1] = new_indices.len();
        }
        self.indptr = new_indptr;
        self.indices = new_indices;
        self.values = new_values;
    }

    pub fn from_dense(m: &Matrix, tol: f64) -> Self {
        let mut trip = Vec::new();
        for i in 0..m.rows() {
            for (j, &v) in m.row(i).iter().enumerate() {
                if v.abs() > tol {
                    trip.push((i, j, v));
                }
            }
        }
        CsrMatrix::from_triplets(m.rows(), m.cols(), trip)
    }

    pub fn to_dense(&self) -> Matrix {
        let mut out = Matrix::zeros(self.rows, self.cols);
        for r in 0..self.rows {
            for j in self.indptr[r]..self.indptr[r + 1] {
                out[(r, self.indices[j])] = self.values[j];
            }
        }
        out
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    pub fn density(&self) -> f64 {
        self.nnz() as f64 / (self.rows * self.cols).max(1) as f64
    }

    /// Sparse matrix–vector product.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.cols);
        let mut out = vec![0.0; self.rows];
        for r in 0..self.rows {
            let mut s = 0.0;
            for j in self.indptr[r]..self.indptr[r + 1] {
                s += self.values[j] * x[self.indices[j]];
            }
            out[r] = s;
        }
        out
    }

    /// CSR × dense — O(nnz · k) for a (cols x k) dense right factor.
    pub fn matmul_dense(&self, b: &Matrix) -> Matrix {
        assert_eq!(b.rows(), self.cols);
        let mut out = Matrix::zeros(self.rows, b.cols());
        for r in 0..self.rows {
            let dst = out.row_mut(r);
            for j in self.indptr[r]..self.indptr[r + 1] {
                let v = self.values[j];
                let brow = b.row(self.indices[j]);
                for (d, &x) in dst.iter_mut().zip(brow) {
                    *d += v * x;
                }
            }
        }
        out
    }

    /// Select rows, preserving sparsity (the "R" of sparse CUR).
    pub fn select_rows(&self, idx: &[usize]) -> CsrMatrix {
        let mut trip = Vec::new();
        for (newr, &r) in idx.iter().enumerate() {
            for j in self.indptr[r]..self.indptr[r + 1] {
                trip.push((newr, self.indices[j], self.values[j]));
            }
        }
        CsrMatrix::from_triplets(idx.len(), self.cols, trip)
    }

    /// Select columns, preserving sparsity (the "C" of sparse CUR).
    pub fn select_cols(&self, idx: &[usize]) -> CsrMatrix {
        let mut newcol = vec![usize::MAX; self.cols];
        for (nc, &c) in idx.iter().enumerate() {
            newcol[c] = nc;
        }
        let mut trip = Vec::new();
        for r in 0..self.rows {
            for j in self.indptr[r]..self.indptr[r + 1] {
                let nc = newcol[self.indices[j]];
                if nc != usize::MAX {
                    trip.push((r, nc, self.values[j]));
                }
            }
        }
        CsrMatrix::from_triplets(self.rows, idx.len(), trip)
    }

    /// Squared column norms in one nnz pass (adaptive-sampling weights).
    pub fn col_norms_sq(&self) -> Vec<f64> {
        let mut out = vec![0.0; self.cols];
        for j in 0..self.nnz() {
            out[self.indices[j]] += self.values[j] * self.values[j];
        }
        out
    }

    pub fn fro_norm_sq(&self) -> f64 {
        self.values.iter().map(|v| v * v).sum()
    }

    /// CountSketch `S^T A` in O(nnz) — the Table-4 claim for sparse inputs.
    /// `cols_map`/`signs` define S (one ±1 per *row* of A).
    pub fn countsketch_left(&self, s: usize, cols_map: &[usize], signs: &[f64]) -> Matrix {
        assert_eq!(cols_map.len(), self.rows);
        let mut out = Matrix::zeros(s, self.cols);
        for r in 0..self.rows {
            let target = cols_map[r];
            let sg = signs[r];
            let dst = out.row_mut(target);
            for j in self.indptr[r]..self.indptr[r + 1] {
                dst[self.indices[j]] += sg * self.values[j];
            }
        }
        out
    }
}

/// Sparse random matrix: each entry nonzero with probability `density`,
/// values standard normal.
pub fn sprandn(rows: usize, cols: usize, density: f64, rng: &mut Rng) -> CsrMatrix {
    let mut trip = Vec::new();
    for r in 0..rows {
        for c in 0..cols {
            if rng.bernoulli(density) {
                trip.push((r, c, rng.gaussian()));
            }
        }
    }
    CsrMatrix::from_triplets(rows, cols, trip)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> CsrMatrix {
        // [[1, 0, 2], [0, 0, 0], [3, 4, 0]]
        CsrMatrix::from_triplets(3, 3, vec![(0, 0, 1.0), (0, 2, 2.0), (2, 0, 3.0), (2, 1, 4.0)])
    }

    #[test]
    fn dense_roundtrip() {
        let s = sample();
        assert_eq!(s.nnz(), 4);
        let d = s.to_dense();
        assert_eq!(d[(0, 2)], 2.0);
        assert_eq!(d[(1, 1)], 0.0);
        let back = CsrMatrix::from_dense(&d, 0.0);
        assert_eq!(back, s);
    }

    #[test]
    fn duplicates_summed_and_zeros_dropped() {
        let s = CsrMatrix::from_triplets(2, 2, vec![(0, 0, 1.0), (0, 0, 2.0), (1, 1, 3.0), (1, 1, -3.0)]);
        assert_eq!(s.nnz(), 1);
        assert_eq!(s.to_dense()[(0, 0)], 3.0);
    }

    #[test]
    fn matvec_matches_dense() {
        let s = sample();
        let x = vec![1.0, 2.0, 3.0];
        assert_eq!(s.matvec(&x), s.to_dense().matvec(&x));
    }

    #[test]
    fn matmul_dense_matches() {
        let mut rng = Rng::new(0);
        let s = sprandn(10, 8, 0.3, &mut rng);
        let b = Matrix::randn(8, 5, &mut rng);
        let fast = s.matmul_dense(&b);
        let slow = s.to_dense().matmul(&b);
        assert!(fast.max_abs_diff(&slow) < 1e-12);
    }

    #[test]
    fn selection_preserves_sparsity() {
        let mut rng = Rng::new(1);
        let s = sprandn(20, 15, 0.2, &mut rng);
        let rows = s.select_rows(&[0, 5, 19]);
        assert_eq!(rows.rows(), 3);
        assert!(rows.density() <= 1.0);
        assert!(rows.to_dense().max_abs_diff(&s.to_dense().select_rows(&[0, 5, 19])) < 1e-15);
        let cols = s.select_cols(&[1, 7, 14]);
        assert!(cols.to_dense().max_abs_diff(&s.to_dense().select_cols(&[1, 7, 14])) < 1e-15);
        // sparse CUR pieces keep the same nnz density order as A
        assert!(cols.nnz() <= s.nnz());
    }

    #[test]
    fn col_norms_and_fro() {
        let s = sample();
        let n = s.col_norms_sq();
        assert_eq!(n, vec![10.0, 16.0, 4.0]);
        assert_eq!(s.fro_norm_sq(), 30.0);
    }

    #[test]
    fn countsketch_matches_dense_path() {
        let mut rng = Rng::new(2);
        let s = sprandn(30, 10, 0.25, &mut rng);
        let buckets = 8;
        let cols_map: Vec<usize> = (0..30).map(|_| rng.usize_below(buckets)).collect();
        let signs: Vec<f64> = (0..30).map(|_| rng.sign()).collect();
        let fast = s.countsketch_left(buckets, &cols_map, &signs);
        // dense reference
        let mut sk = Matrix::zeros(30, buckets);
        for r in 0..30 {
            sk[(r, cols_map[r])] = signs[r];
        }
        let slow = sk.tr_matmul(&s.to_dense());
        assert!(fast.max_abs_diff(&slow) < 1e-12);
    }

    #[test]
    fn sprandn_density() {
        let mut rng = Rng::new(3);
        let s = sprandn(100, 100, 0.1, &mut rng);
        let d = s.density();
        assert!((d - 0.1).abs() < 0.02, "density {d}");
    }
}
