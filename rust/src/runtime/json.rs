//! Minimal JSON parser substrate (no serde_json in the image).
//!
//! Parses the artifact manifest (objects, arrays, strings, numbers, bools,
//! null). Not a general-purpose validator — unknown escapes are passed
//! through and numbers are f64 — but strict enough to reject malformed
//! manifests with a useful error.

use std::collections::BTreeMap;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected {:?} at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|c| c as char), self.i)),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = self.peek().ok_or("bad escape")?;
                    self.i += 1;
                    out.push(match c {
                        b'n' => '\n',
                        b't' => '\t',
                        b'r' => '\r',
                        b'u' => {
                            // \uXXXX
                            let hex = self
                                .b
                                .get(self.i..self.i + 4)
                                .and_then(|h| std::str::from_utf8(h).ok())
                                .ok_or("bad \\u escape")?;
                            let cp = u32::from_str_radix(hex, 16).map_err(|e| e.to_string())?;
                            self.i += 4;
                            char::from_u32(cp).unwrap_or('\u{FFFD}')
                        }
                        other => other as char,
                    });
                }
                Some(c) => {
                    // copy raw UTF-8 bytes through
                    let start = self.i;
                    let _ = c;
                    while let Some(c2) = self.peek() {
                        if c2 == b'"' || c2 == b'\\' {
                            break;
                        }
                        self.i += 1;
                    }
                    out.push_str(std::str::from_utf8(&self.b[start..self.i]).map_err(|e| e.to_string())?);
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(format!("expected , or ] at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(format!("expected , or }} at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shape() {
        let j = Json::parse(
            r#"{"format": "hlo-text", "artifacts": [{"name": "a", "inputs": [[1, 1], [256, 16]]}], "n": 3.5, "ok": true, "none": null}"#,
        )
        .unwrap();
        assert_eq!(j.get("format").unwrap().as_str(), Some("hlo-text"));
        let arts = j.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts.len(), 1);
        let inputs = arts[0].get("inputs").unwrap().as_arr().unwrap();
        assert_eq!(inputs[1].as_arr().unwrap()[0].as_usize(), Some(256));
        assert_eq!(j.get("n").unwrap().as_f64(), Some(3.5));
        assert_eq!(j.get("ok").unwrap(), &Json::Bool(true));
        assert_eq!(j.get("none").unwrap(), &Json::Null);
    }

    #[test]
    fn string_escapes() {
        let j = Json::parse(r#""a\nb\t\"c\" A""#).unwrap();
        assert_eq!(j.as_str(), Some("a\nb\t\"c\" A"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{}extra").is_err());
        assert!(Json::parse("nope").is_err());
    }

    #[test]
    fn nested_arrays_and_negatives() {
        let j = Json::parse("[[-1, 2.5e2], []]").unwrap();
        let a = j.as_arr().unwrap();
        assert_eq!(a[0].as_arr().unwrap()[0].as_f64(), Some(-1.0));
        assert_eq!(a[0].as_arr().unwrap()[1].as_f64(), Some(250.0));
        assert!(a[1].as_arr().unwrap().is_empty());
    }
}
