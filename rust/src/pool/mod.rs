//! Worker thread pool substrate (no tokio in the image).
//!
//! A fixed pool of workers fed by an MPMC channel built on
//! `Mutex<VecDeque>` + `Condvar`, with a bounded-queue mode for
//! backpressure. `parallel_for` provides scoped data-parallel loops for the
//! coordinator and benches.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Queue {
    jobs: Mutex<VecDeque<Job>>,
    cond: Condvar,
    /// signaled when a job is popped (for bounded-queue producers)
    space: Condvar,
    capacity: usize,
    shutdown: AtomicBool,
    inflight: AtomicUsize,
    done: Condvar,
    panics: AtomicUsize,
}

/// Fixed-size worker pool with an optionally bounded job queue.
pub struct ThreadPool {
    queue: Arc<Queue>,
    workers: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    /// `threads` workers; `capacity` bounds the pending-job queue
    /// (`usize::MAX` for unbounded). Submitting beyond capacity blocks the
    /// producer — the coordinator's backpressure mechanism.
    pub fn new(threads: usize, capacity: usize) -> Self {
        assert!(threads > 0);
        let queue = Arc::new(Queue {
            jobs: Mutex::new(VecDeque::new()),
            cond: Condvar::new(),
            space: Condvar::new(),
            capacity,
            shutdown: AtomicBool::new(false),
            inflight: AtomicUsize::new(0),
            done: Condvar::new(),
            panics: AtomicUsize::new(0),
        });
        let workers = (0..threads)
            .map(|_| {
                let q = Arc::clone(&queue);
                std::thread::spawn(move || worker_loop(q))
            })
            .collect();
        ThreadPool { queue, workers }
    }

    /// Pool sized to the machine, unbounded queue.
    pub fn with_default_threads() -> Self {
        let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        ThreadPool::new(n, usize::MAX)
    }

    pub fn threads(&self) -> usize {
        self.workers.len()
    }

    /// Number of jobs queued or running.
    pub fn inflight(&self) -> usize {
        self.queue.inflight.load(Ordering::SeqCst)
    }

    /// Number of jobs that panicked (caught; the worker survives).
    pub fn panics(&self) -> usize {
        self.queue.panics.load(Ordering::SeqCst)
    }

    /// Submit a job; blocks while the queue is at capacity (backpressure).
    pub fn submit(&self, job: impl FnOnce() + Send + 'static) {
        let mut jobs = self.queue.jobs.lock().unwrap();
        while jobs.len() >= self.queue.capacity {
            jobs = self.queue.space.wait(jobs).unwrap();
        }
        self.queue.inflight.fetch_add(1, Ordering::SeqCst);
        jobs.push_back(Box::new(job));
        drop(jobs);
        self.queue.cond.notify_one();
    }

    /// Block until every submitted job has finished.
    pub fn wait_idle(&self) {
        let mut jobs = self.queue.jobs.lock().unwrap();
        while self.queue.inflight.load(Ordering::SeqCst) > 0 {
            jobs = self.queue.done.wait(jobs).unwrap();
        }
        drop(jobs);
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.queue.shutdown.store(true, Ordering::SeqCst);
        self.queue.cond.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(q: Arc<Queue>) {
    loop {
        let job = {
            let mut jobs = q.jobs.lock().unwrap();
            loop {
                if let Some(j) = jobs.pop_front() {
                    q.space.notify_one();
                    break j;
                }
                if q.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                jobs = q.cond.wait(jobs).unwrap();
            }
        };
        // Failure isolation: a panicking job must not kill the worker or
        // wedge `wait_idle` (the inflight count still drops below).
        if std::panic::catch_unwind(std::panic::AssertUnwindSafe(job)).is_err() {
            q.panics.fetch_add(1, Ordering::SeqCst);
        }
        if q.inflight.fetch_sub(1, Ordering::SeqCst) == 1 {
            let _guard = q.jobs.lock().unwrap();
            q.done.notify_all();
        }
    }
}

/// Scoped parallel-for over `0..n`: splits into contiguous chunks across up
/// to `max_threads` scoped threads and calls `f(i)` for each index.
pub fn parallel_for(n: usize, max_threads: usize, f: impl Fn(usize) + Sync) {
    if n == 0 {
        return;
    }
    let threads = max_threads
        .min(n)
        .min(std::thread::available_parallelism().map(|t| t.get()).unwrap_or(1))
        .max(1);
    if threads == 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let chunk = n.div_ceil(threads);
    let f = &f;
    std::thread::scope(|s| {
        for t in 0..threads {
            let lo = t * chunk;
            let hi = ((t + 1) * chunk).min(n);
            if lo >= hi {
                break;
            }
            s.spawn(move || {
                for i in lo..hi {
                    f(i);
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4, usize::MAX);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn bounded_queue_applies_backpressure() {
        // capacity 2, one slow worker: the producer must block rather than
        // queueing all jobs instantly.
        let pool = ThreadPool::new(1, 2);
        let started = std::time::Instant::now();
        for _ in 0..6 {
            pool.submit(|| std::thread::sleep(std::time::Duration::from_millis(10)));
        }
        // With capacity 2 and 10ms jobs, submitting 6 must take >= ~30ms.
        assert!(started.elapsed() >= std::time::Duration::from_millis(25));
        pool.wait_idle();
    }

    #[test]
    fn wait_idle_without_jobs_returns() {
        let pool = ThreadPool::new(2, 8);
        pool.wait_idle();
    }

    #[test]
    fn parallel_for_covers_range() {
        let hits: Vec<AtomicU64> = (0..97).map(|_| AtomicU64::new(0)).collect();
        parallel_for(97, 8, |i| {
            hits[i].fetch_add(1, Ordering::SeqCst);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn parallel_for_zero_and_one() {
        parallel_for(0, 4, |_| panic!("must not run"));
        let hit = AtomicU64::new(0);
        parallel_for(1, 4, |_| {
            hit.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(hit.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn panicking_job_does_not_wedge_pool() {
        let pool = ThreadPool::new(2, usize::MAX);
        let c = Arc::new(AtomicU64::new(0));
        for i in 0..10 {
            let c = Arc::clone(&c);
            pool.submit(move || {
                if i % 3 == 0 {
                    panic!("injected failure");
                }
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle(); // must not hang
        assert_eq!(c.load(Ordering::SeqCst), 6);
        assert_eq!(pool.panics(), 4);
        // pool still works afterwards
        let c2 = Arc::clone(&c);
        pool.submit(move || {
            c2.fetch_add(1, Ordering::SeqCst);
        });
        pool.wait_idle();
        assert_eq!(c.load(Ordering::SeqCst), 7);
    }

    #[test]
    fn drop_joins_workers() {
        let pool = ThreadPool::new(3, usize::MAX);
        let c = Arc::new(AtomicU64::new(0));
        for _ in 0..10 {
            let c = Arc::clone(&c);
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        drop(pool);
        assert_eq!(c.load(Ordering::SeqCst), 10);
    }
}
