//! Row-sharded execution plane: N workers each own a contiguous row-block
//! of the kernel, stream it through the existing tile pipeline, and a
//! coordinator merges the tiny associative partial states.
//!
//! The paper's fast model is linear-time precisely because every statistic
//! it needs — the `c×c` Gram, the leverage-score state, sketch folds `S^T C`
//! — is an associative sum over row blocks of `C`. That is what makes the
//! computation shardable: each worker runs the *same* consumers the
//! single-process build uses over a [`ShardSource`] (a row-range view of
//! the oracle), partial fold state rides the existing
//! [`TileConsumer::snapshot`]/`restore` plumbing, and [`ShardReduce`]
//! merges the `O(c²)` partials before the coordinator finishes the solve
//! once. Gittens–Mahoney (arXiv 1303.1849) frame exactly this large-scale
//! regime as the one where Nyström-type methods earn their keep, and the
//! modified-Nyström analysis (arXiv 1404.0138) shows the error bounds
//! survive the regrouped computation.
//!
//! Determinism contract (asserted in `tests/shard_equiv.rs`):
//!
//! - **Selection paths are bit-identical** across shard counts: Nyström,
//!   `fast[uniform]` (its `S` is drawn before any tile streams) and fast
//!   CUR are pure row gathers plus draws whose rng sequence does not
//!   depend on how rows were grouped, so every float matches the
//!   unsharded run exactly.
//! - **Reduction paths regroup floating-point sums**: the Gram / sketched
//!   leverage state merges per-shard partial sums, so scores (and the `U`
//!   built from them) agree with the unsharded run to summation
//!   reordering (≤1e-12 in the equivalence matrix), not bit-for-bit. The
//!   *number* of rng draws is unchanged (one Bernoulli per row, in row
//!   order), so the sampled index set stays aligned unless a draw lands
//!   within the regrouping error of its threshold.
//!
//! Worker death is handled through the existing fault machinery
//! ([`FaultPoint::ShardWorkerDeath`]): a dead worker's row-range is
//! re-executed once from scratch — never silently dropped — and a second
//! death of the same range propagates as a panic that the service turns
//! into a typed `ServiceError::Faulted` reply. Shard passes run
//! *sequentially* on the calling thread (the pipeline producer already
//! fans out on the global pool; nesting a second pool here could
//! deadlock), which also makes the per-worker [`AllocGauge`] measurement
//! sound and lands every per-shard span under the request's trace.

use crate::benchkit::alloc::{self, AllocGauge};
use crate::coordinator::oracle::KernelOracle;
use crate::cur::{self, CurDecomp, FastCurConfig};
use crate::linalg::{gemm, guarded_pinv, pinv, Matrix, MatrixF32, Precision, Tile};
use crate::obs::{self, Stage};
use crate::sketch::{self, SketchKind};
use crate::spsd::{self, FastConfig, LeverageBasis, SpsdApprox};
use crate::stream::{
    run_pipeline_validated, ColSubsetCollect, CollectConsumer, GramFold, LeverageFold,
    LeverageSampler, MatrixSource, MatvecFold, OracleColumnsSource, ResidencyConfig,
    ResidencyStats, ResidentSource, RowGather, SketchFold, StreamConfig, TileConsumer,
    TileSource,
};
use crate::testkit::faults::{self, FaultPoint};
use crate::util::{Rng, Stopwatch};
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};

/// Contiguous row ranges `[r0, r1)` partitioning `[0, n)` across `shards`
/// workers: the first `n % shards` ranges get one extra row. `shards` is
/// clamped to `[1, n]` so no worker owns an empty range (a 0-row kernel
/// degenerates to one empty shard).
pub fn shard_ranges(n: usize, shards: usize) -> Vec<(usize, usize)> {
    let shards = shards.clamp(1, n.max(1));
    let base = n / shards;
    let rem = n % shards;
    let mut out = Vec::with_capacity(shards);
    let mut r0 = 0;
    for i in 0..shards {
        let h = base + usize::from(i < rem);
        out.push((r0, r0 + h));
        r0 += h;
    }
    debug_assert_eq!(r0, n);
    out
}

/// A row-range view of a [`TileSource`] — the worker's whole world. The
/// pipeline running over it sees rows `[0, r1-r0)` and hands consumers
/// *local* offsets; [`OffsetConsumer`] rebases them to global rows.
pub struct ShardSource<'a> {
    inner: &'a dyn TileSource,
    r0: usize,
    r1: usize,
}

impl<'a> ShardSource<'a> {
    pub fn new(inner: &'a dyn TileSource, r0: usize, r1: usize) -> Self {
        assert!(r0 <= r1 && r1 <= inner.rows(), "shard range out of bounds");
        ShardSource { inner, r0, r1 }
    }
}

impl TileSource for ShardSource<'_> {
    fn rows(&self) -> usize {
        self.r1 - self.r0
    }

    fn cols(&self) -> usize {
        self.inner.cols()
    }

    fn tile(&self, a: usize, b: usize) -> Matrix {
        self.inner.tile(self.r0 + a, self.r0 + b)
    }

    fn tile_f32(&self, a: usize, b: usize) -> MatrixF32 {
        self.inner.tile_f32(self.r0 + a, self.r0 + b)
    }

    fn tile_elem(&self, a: usize, b: usize, prec: Precision) -> Tile {
        self.inner.tile_elem(self.r0 + a, self.r0 + b, prec)
    }
}

/// Rebases a consumer from shard-local to global row offsets: a worker's
/// pipeline emits tiles at local `r0`, but row-indexed consumers
/// ([`RowGather`], [`CollectConsumer`], [`SketchFold`]'s dense block,
/// [`MatvecFold`]'s `x` slice, the sketched leverage fold) speak global
/// rows. Snapshot/restore forward unchanged — the state is offset-free.
pub struct OffsetConsumer<'a> {
    inner: &'a mut dyn TileConsumer,
    base: usize,
}

impl<'a> OffsetConsumer<'a> {
    pub fn new(inner: &'a mut dyn TileConsumer, base: usize) -> Self {
        OffsetConsumer { inner, base }
    }
}

impl TileConsumer for OffsetConsumer<'_> {
    fn consume(&mut self, r0: usize, tile: &Matrix) {
        self.inner.consume(self.base + r0, tile);
    }

    fn consume_f32(&mut self, r0: usize, tile: &MatrixF32) {
        // Forward natively so a fold's narrow path stays on the narrow
        // path (the default would promote here and change the fold).
        self.inner.consume_f32(self.base + r0, tile);
    }

    fn snapshot(&self) -> Option<Matrix> {
        self.inner.snapshot()
    }

    fn restore(&mut self, state: &Matrix) -> bool {
        self.inner.restore(state)
    }
}

/// Coordinator-side merge of two workers' partial fold states. The
/// default merges the [`TileConsumer::snapshot`] matrices by summation —
/// exactly right for every prefix-sum fold (Gram, sketch, leverage,
/// matvec: the only consumers that snapshot), because each accumulator is
/// an associative sum over rows and disjoint row-ranges contribute
/// disjoint summands. `LeverageFold`'s row-ordered upper-triangle
/// accumulation was built for exactly this regrouping: the sum of
/// per-shard upper triangles *is* the upper triangle of the global sum.
pub trait ShardReduce: TileConsumer {
    /// Fold `other`'s partial state into `self`.
    fn reduce(&mut self, other: &Self) {
        let mut acc = self.snapshot().expect("ShardReduce requires a snapshotting consumer");
        let theirs = other.snapshot().expect("ShardReduce requires a snapshotting consumer");
        acc.axpy(1.0, &theirs);
        assert!(self.restore(&acc), "ShardReduce: consumer rejected merged state");
    }
}

impl ShardReduce for GramFold {}
impl ShardReduce for SketchFold<'_> {}
impl ShardReduce for LeverageFold<'_> {}
impl ShardReduce for MatvecFold<'_> {}

/// Allocator-measured accounting for one worker's pass over its row-range.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ShardWorkerStats {
    /// First global row of the range.
    pub r0: usize,
    /// One past the last global row of the range.
    pub r1: usize,
    /// Allocator-measured (not predicted) peak extra bytes while this
    /// worker's pass ran — 0 when the counting allocator is not installed.
    pub peak_bytes: u64,
    /// Wall-clock seconds of the (successful) pass.
    pub secs: f64,
}

/// Per-run shard accounting, carried on
/// [`RunMeta::shard`](crate::exec::RunMeta) and merged into service
/// replies. `workers` holds one entry per *successful* pass in execution
/// order; a range that died and was re-executed appears once (the
/// surviving attempt) and bumps `reexecuted`.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ShardStats {
    /// Worker count the plan requested (ranges may be fewer when `n` is
    /// smaller).
    pub shards: usize,
    pub workers: Vec<ShardWorkerStats>,
    /// Row-ranges re-executed after a worker death. Never silently
    /// dropped: a range either completes or the run fails typed.
    pub reexecuted: u32,
}

impl ShardStats {
    pub fn new(shards: usize) -> Self {
        ShardStats { shards, workers: Vec::new(), reexecuted: 0 }
    }

    /// The largest allocator-measured per-worker working set — the number
    /// the many-tenant bench reports per worker.
    pub fn max_worker_peak_bytes(&self) -> u64 {
        self.workers.iter().map(|w| w.peak_bytes).max().unwrap_or(0)
    }
}

/// Injected worker death (armed via [`FaultPoint::ShardWorkerDeath`]).
fn fail_if_armed(range: (usize, usize)) {
    if let Some(plan) = faults::current() {
        if plan.should_fail(FaultPoint::ShardWorkerDeath) {
            panic!("injected fault: shard worker death (rows {}..{})", range.0, range.1);
        }
    }
}

/// Run one worker's pass with death injection, a per-worker allocator
/// gauge, a [`Stage::ShardWorker`] span, and re-execution semantics: the
/// first panic re-runs `pass` from scratch (callers build fresh fold
/// state inside `pass`; global gathers are idempotent overwrites), the
/// second propagates to the caller's fault machinery.
fn run_worker<T>(range: (usize, usize), stats: &mut ShardStats, mut pass: impl FnMut() -> T) -> T {
    let mut retried = false;
    loop {
        let sw = Stopwatch::start();
        let gauge = AllocGauge::start();
        let out = catch_unwind(AssertUnwindSafe(|| {
            let _s = obs::span(Stage::ShardWorker);
            fail_if_armed(range);
            pass()
        }));
        match out {
            Ok(v) => {
                let peak =
                    if alloc::installed() { gauge.peak_extra_bytes() as u64 } else { 0 };
                stats.workers.push(ShardWorkerStats {
                    r0: range.0,
                    r1: range.1,
                    peak_bytes: peak,
                    secs: sw.secs(),
                });
                return v;
            }
            Err(payload) => {
                if retried {
                    resume_unwind(payload);
                }
                retried = true;
                stats.reexecuted += 1;
            }
        }
    }
}

/// Stream one shard's rows through `consumers`, which speak **global**
/// row offsets (each is wrapped in an [`OffsetConsumer`]). With a
/// residency config, the shard's view goes through its own
/// [`ResidentSource`] (per-worker LRU + spill arena) and the pass returns
/// its counters for the coordinator to absorb.
fn shard_pass(
    source: &dyn TileSource,
    range: (usize, usize),
    cfg: StreamConfig,
    residency: Option<&ResidencyConfig>,
    consumers: &mut [&mut dyn TileConsumer],
) -> Option<ResidencyStats> {
    let (r0, r1) = range;
    let view = ShardSource::new(source, r0, r1);
    let mut offset: Vec<OffsetConsumer<'_>> =
        consumers.iter_mut().map(|c| OffsetConsumer::new(&mut **c, r0)).collect();
    let mut refs: Vec<&mut dyn TileConsumer> =
        offset.iter_mut().map(|c| c as &mut dyn TileConsumer).collect();
    match residency {
        Some(rc) => {
            let res = ResidentSource::new(&view, rc);
            run_pipeline_validated(
                &res,
                cfg.tile_rows,
                cfg.queue_depth,
                cfg.precision,
                cfg.validate,
                &mut refs,
            )
            .unwrap_or_else(|e| panic!("{e}"));
            Some(res.stats())
        }
        None => {
            run_pipeline_validated(
                &view,
                cfg.tile_rows,
                cfg.queue_depth,
                cfg.precision,
                cfg.validate,
                &mut refs,
            )
            .unwrap_or_else(|e| panic!("{e}"));
            None
        }
    }
}

fn absorb_residency(acc: &mut Option<ResidencyStats>, rs: Option<ResidencyStats>) {
    if let Some(rs) = rs {
        acc.get_or_insert_with(ResidencyStats::default).absorb(&rs);
    }
}

/// Sharded Nyström: each worker collects its row-block of `C = K[:, P]`;
/// the coordinator takes `W = C[P, :]` and finishes `U = W†` once. Pure
/// row gathers — bit-identical to the unsharded build at any shard count.
pub(crate) fn nystrom_sharded(
    oracle: &dyn KernelOracle,
    p_idx: &[usize],
    shards: usize,
    cfg: StreamConfig,
    residency: Option<&ResidencyConfig>,
) -> (SpsdApprox, Option<ResidencyStats>, ShardStats) {
    let sw = Stopwatch::start();
    let before = oracle.entries_observed();
    let n = oracle.n();
    let mut stats = ShardStats::new(shards);
    let mut res_acc = None;
    let src = OracleColumnsSource::new(oracle, p_idx);
    let mut collect = CollectConsumer::new(n, p_idx.len());
    for range in shard_ranges(n, shards) {
        let rs = run_worker(range, &mut stats, || {
            shard_pass(&src, range, cfg, residency, &mut [&mut collect])
        });
        absorb_residency(&mut res_acc, rs);
    }
    let c = collect.into_matrix();
    let w = c.select_rows(p_idx);
    let mut u = {
        let _s = obs::span(Stage::SolveSvd);
        guarded_pinv(&w)
    };
    u.symmetrize();
    let approx = SpsdApprox {
        c,
        u,
        p_indices: p_idx.to_vec(),
        method: "nystrom".to_string(),
        entries_observed: oracle.entries_observed() - before,
        build_secs: sw.secs(),
    };
    (approx, res_acc, stats)
}

/// Sharded fast model (column-selection sketches; the exec layer routes
/// `ExactSvd`-basis leverage and projection sketches to the inner
/// policy). Uniform draws `S` up front, so the sharded build is a pure
/// gather — bit-identical. Leverage folds per-worker score partials,
/// merges them under [`Stage::ShardReduce`], then scores/draws/gathers in
/// one global row-order sweep, so only summation regrouping separates it
/// from the unsharded run.
pub(crate) fn fast_sharded(
    oracle: &dyn KernelOracle,
    p_idx: &[usize],
    cfg: FastConfig,
    shards: usize,
    stream_cfg: StreamConfig,
    residency: Option<&ResidencyConfig>,
    rng: &mut Rng,
) -> (SpsdApprox, Option<ResidencyStats>, ShardStats) {
    let sw = Stopwatch::start();
    let before = oracle.entries_observed();
    let n = oracle.n();
    let mut stats = ShardStats::new(shards);
    let mut res_acc = None;
    let src = OracleColumnsSource::new(oracle, p_idx);

    let (c_mat, stc, sks) = match cfg.kind {
        SketchKind::Uniform => {
            // S doesn't depend on C: same draw order as the unsharded
            // build, before any tile streams.
            let op = spsd::build_selection_sketch(None, p_idx, cfg, n, rng);
            let (indices, scales) = spsd::select_parts(&op);
            let mut collect = CollectConsumer::new(n, p_idx.len());
            for range in shard_ranges(n, shards) {
                let rs = run_worker(range, &mut stats, || {
                    shard_pass(&src, range, stream_cfg, residency, &mut [&mut collect])
                });
                absorb_residency(&mut res_acc, rs);
            }
            let c_mat = collect.into_matrix();
            let rows_s = c_mat.select_rows(&indices);
            let stc = spsd::scale_rows(&rows_s, &scales);
            let sks = spsd::assemble_sks(oracle, &rows_s, p_idx, &indices, &scales);
            (c_mat, stc, sks)
        }
        SketchKind::Leverage { scaled } => {
            // Per-worker partial score state (the O(c²) Gram or SRHT
            // surrogate), reduced by the coordinator. The SRHT draw (when
            // used) happens before any tile streams, exactly like the
            // unsharded pass-1 setup.
            let sk_op = match cfg.leverage_basis {
                LeverageBasis::Sketched { m } => {
                    Some(sketch::srht_sketch(n, m.max(p_idx.len()), rng))
                }
                LeverageBasis::Gram => None,
                LeverageBasis::ExactSvd => {
                    panic!("fast_sharded: ExactSvd leverage basis is routed to the inner policy")
                }
            };
            let mut collect = CollectConsumer::new(n, p_idx.len());
            let mut merged: Option<LeverageFold<'_>> = None;
            for range in shard_ranges(n, shards) {
                let (fold, rs) = run_worker(range, &mut stats, || {
                    // Fresh fold per attempt: a half-folded partial from a
                    // dead worker is discarded, never double-counted.
                    let mut fold = match &sk_op {
                        Some(op) => LeverageFold::sketched(op, p_idx.len()),
                        None => LeverageFold::exact(p_idx.len()),
                    };
                    let rs = shard_pass(
                        &src,
                        range,
                        stream_cfg,
                        residency,
                        &mut [&mut collect, &mut fold],
                    );
                    (fold, rs)
                });
                absorb_residency(&mut res_acc, rs);
                match merged.as_mut() {
                    None => merged = Some(fold),
                    Some(m) => {
                        let _s = obs::span(Stage::ShardReduce);
                        m.reduce(&fold);
                    }
                }
            }
            let est = merged.expect("at least one shard").into_estimate();

            let s_extra = cfg
                .s
                .saturating_sub(if cfg.force_p_in_s { p_idx.len() } else { 0 })
                .max(1);
            let forced = if cfg.force_p_in_s { p_idx.to_vec() } else { Vec::new() };
            let c_mat = collect.into_matrix();
            let mut sampler =
                LeverageSampler::new(&est, s_extra, scaled, forced, n, p_idx.len(), rng);
            // One global row-order sweep over the assembled panel — the
            // same rng call sequence (one Bernoulli per row, ascending)
            // as the unsharded pass 2.
            sampler.consume(0, &c_mat);
            let (mut indices, mut scales, mut rows_s, sampled) = sampler.into_parts();
            if sampled == 0 {
                // Degenerate draw: mirror run_fast's single uniform pick.
                let pick = rng.usize_below(n);
                if let Err(pos) = indices.binary_search(&pick) {
                    indices.insert(pos, pick);
                    scales.insert(pos, 1.0);
                    rows_s = c_mat.select_rows(&indices);
                }
            }
            let stc = spsd::scale_rows(&rows_s, &scales);
            let sks = spsd::assemble_sks(oracle, &rows_s, p_idx, &indices, &scales);
            (c_mat, stc, sks)
        }
        other => panic!(
            "fast_sharded supports column-selection sketches, not {} (exec routes projection \
             sketches to the inner policy)",
            other.name()
        ),
    };

    let stc_pinv = {
        let _s = obs::span(Stage::SolveSvd);
        guarded_pinv(&stc)
    };
    let u = gemm::symm_nt(&stc_pinv.matmul(&sks), &stc_pinv);
    let approx = SpsdApprox {
        c: c_mat,
        u,
        p_indices: p_idx.to_vec(),
        method: format!("fast[{}]", cfg.kind.name()),
        entries_observed: oracle.entries_observed() - before,
        build_secs: sw.secs(),
    };
    (approx, res_acc, stats)
}

/// Sharded fast CUR: workers gather their row-blocks of `C`, `R` and (for
/// uniform, whose indices exist up front) the core in one pass; the
/// coordinator draws any leverage indices from the assembled `C`/`R`
/// exactly as the unsharded build does and finishes `U` once. All gathers
/// plus draws whose sequence is grouping-independent — bit-identical.
pub(crate) fn cur_fast_sharded(
    a: &Matrix,
    col_idx: &[usize],
    row_idx: &[usize],
    cfg: FastCurConfig,
    shards: usize,
    stream_cfg: StreamConfig,
    residency: Option<&ResidencyConfig>,
    rng: &mut Rng,
) -> (CurDecomp, Option<ResidencyStats>, ShardStats) {
    let sw = Stopwatch::start();
    let (m, n) = (a.rows(), a.cols());
    assert!(
        cfg.kind.is_column_selection(),
        "fast CUR supports column-selection sketches, not {}",
        cfg.kind.name()
    );
    let forced_rows: &[usize] = if cfg.force_overlap { row_idx } else { &[] };
    let forced_cols: &[usize] = if cfg.force_overlap { col_idx } else { &[] };
    let mut stats = ShardStats::new(shards);
    let mut res_acc = None;
    let src = MatrixSource::new(a);
    let mut c_collect = ColSubsetCollect::new(m, col_idx.to_vec());
    let mut r_gather = RowGather::new(row_idx.to_vec(), n);

    let (c, r, sc_idx, sr_idx, core) = match cfg.kind {
        SketchKind::Uniform => {
            let dummy = Matrix::zeros(0, 0);
            let sc_idx = cur::build_indices(
                &dummy, cfg.kind, cfg.score_basis, cfg.s_c, m, forced_rows, rng,
            );
            let sr_idx = cur::build_indices(
                &dummy, cfg.kind, cfg.score_basis, cfg.s_r, n, forced_cols, rng,
            );
            let mut core_gather = RowGather::with_cols(sc_idx.clone(), sr_idx.clone());
            for range in shard_ranges(m, shards) {
                let rs = run_worker(range, &mut stats, || {
                    shard_pass(
                        &src,
                        range,
                        stream_cfg,
                        residency,
                        &mut [&mut c_collect, &mut r_gather, &mut core_gather],
                    )
                });
                absorb_residency(&mut res_acc, rs);
            }
            (
                c_collect.into_matrix(),
                r_gather.into_matrix(),
                sc_idx,
                sr_idx,
                core_gather.into_matrix(),
            )
        }
        _ => {
            // Leverage: pass over all shards gathers C and R; the draws
            // and the core gather happen once on the coordinator, exactly
            // as the unsharded streamed build does.
            for range in shard_ranges(m, shards) {
                let rs = run_worker(range, &mut stats, || {
                    shard_pass(
                        &src,
                        range,
                        stream_cfg,
                        residency,
                        &mut [&mut c_collect, &mut r_gather],
                    )
                });
                absorb_residency(&mut res_acc, rs);
            }
            let c = c_collect.into_matrix();
            let r = r_gather.into_matrix();
            let sc_idx =
                cur::build_indices(&c, cfg.kind, cfg.score_basis, cfg.s_c, m, forced_rows, rng);
            let rt = r.transpose();
            let sr_idx =
                cur::build_indices(&rt, cfg.kind, cfg.score_basis, cfg.s_r, n, forced_cols, rng);
            let core = Matrix::from_fn(sc_idx.len(), sr_idx.len(), |i, j| {
                a[(sc_idx[i], sr_idx[j])]
            });
            (c, r, sc_idx, sr_idx, core)
        }
    };

    let stc = c.select_rows(&sc_idx);
    let rsr = r.select_cols(&sr_idx);
    let u = {
        let _s = obs::span(Stage::SolveSvd);
        pinv(&stc).matmul(&core).matmul(&pinv(&rsr))
    };
    let decomp = CurDecomp {
        c,
        u,
        r,
        method: format!("fast[{}]", cfg.kind.name()),
        build_secs: sw.secs(),
        entries_for_u: (sc_idx.len() * sr_idx.len()) as u64,
    };
    (decomp, res_acc, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::oracle::DenseOracle;

    fn test_oracle(n: usize) -> DenseOracle {
        let mut rng = Rng::new(7);
        let g = Matrix::randn(n, 6, &mut rng);
        DenseOracle::new(g.matmul_tr(&g))
    }

    #[test]
    fn shard_ranges_partition_contiguously() {
        for (n, shards) in [(10, 3), (7, 7), (5, 9), (53, 4), (1, 1), (0, 4)] {
            let ranges = shard_ranges(n, shards);
            assert!(!ranges.is_empty());
            assert_eq!(ranges[0].0, 0);
            assert_eq!(ranges.last().unwrap().1, n);
            for w in ranges.windows(2) {
                assert_eq!(w[0].1, w[1].0, "ranges must be contiguous");
            }
            let hs: Vec<usize> = ranges.iter().map(|&(a, b)| b - a).collect();
            let (min, max) = (hs.iter().min().unwrap(), hs.iter().max().unwrap());
            assert!(max - min <= 1, "balanced to within one row: {hs:?}");
        }
    }

    #[test]
    fn shard_source_views_global_rows() {
        let mut rng = Rng::new(3);
        let a = Matrix::randn(19, 5, &mut rng);
        let src = MatrixSource::new(&a);
        let view = ShardSource::new(&src, 6, 15);
        assert_eq!((view.rows(), view.cols()), (9, 5));
        assert_eq!(view.tile(2, 7).max_abs_diff(&a.block(8, 13, 0, 5)), 0.0);
    }

    #[test]
    fn offset_consumer_rebases_row_indexed_folds() {
        let mut rng = Rng::new(4);
        let a = Matrix::randn(24, 4, &mut rng);
        let x: Vec<f64> = (0..24).map(|i| 0.5 + i as f64).collect();
        let mut whole = MatvecFold::new(&x, 4);
        whole.consume(0, &a);
        let expected = whole.into_vec();

        let src = MatrixSource::new(&a);
        let mut fold = MatvecFold::new(&x, 4);
        for range in shard_ranges(24, 3) {
            shard_pass(&src, range, StreamConfig::tiled(5), None, &mut [&mut fold]);
        }
        let got = fold.into_vec();
        for (g, e) in got.iter().zip(&expected) {
            assert!((g - e).abs() <= 1e-12 * e.abs().max(1.0), "{g} vs {e}");
        }
    }

    #[test]
    fn shard_reduce_merges_partial_gram_state() {
        let mut rng = Rng::new(5);
        let a = Matrix::randn(30, 6, &mut rng);
        let mut whole = GramFold::new(6);
        whole.consume(0, &a);
        let want = whole.snapshot().unwrap();

        let top = a.block(0, 18, 0, 6);
        let bot = a.block(18, 30, 0, 6);
        let mut g0 = GramFold::new(6);
        g0.consume(0, &top);
        let mut g1 = GramFold::new(6);
        g1.consume(0, &bot);
        g0.reduce(&g1);
        let got = g0.snapshot().unwrap();
        assert!(got.max_abs_diff(&want) <= 1e-12 * want.fro_norm().max(1.0));
    }

    #[test]
    fn sharded_nystrom_matches_unsharded_bit_for_bit() {
        let o = test_oracle(41);
        let p = vec![1usize, 9, 17, 33];
        let (base, _) = spsd::run_nystrom(&o, &p, StreamConfig::tiled(8), None);
        for shards in [1usize, 2, 5] {
            let (sh, _, st) = nystrom_sharded(&o, &p, shards, StreamConfig::tiled(8), None);
            assert_eq!(sh.c.max_abs_diff(&base.c), 0.0, "{shards} shards: C drifted");
            assert_eq!(sh.u.max_abs_diff(&base.u), 0.0, "{shards} shards: U drifted");
            assert_eq!(st.workers.len(), shards, "one stats entry per worker");
            assert_eq!(st.reexecuted, 0);
        }
    }
}
