"""Layer-1 Pallas kernel: tiled matmul used by sketch products.

The coordinator offloads dense products (C^T S assembly, KPCA feature
projections V^T k(x)) to this kernel. The grid tiles the output; each tile
contracts the full shared dimension in VMEM — for the AOT shape buckets used
here (k <= 1024) both panels fit VMEM comfortably (see DESIGN.md §Perf), so
no k-grid accumulator is needed and the MXU sees one large contraction per
tile instead of many small ones.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(x_ref, y_ref, o_ref):
    o_ref[...] = jax.lax.dot_general(
        x_ref[...],
        y_ref[...],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


@functools.partial(jax.jit, static_argnames=("bm", "bn"))
def matmul(x: jax.Array, y: jax.Array, *, bm: int = 128, bn: int = 128) -> jax.Array:
    """(m, k) @ (k, n) -> (m, n) via the Pallas tile kernel."""
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"contraction dims differ: {k} vs {k2}"
    assert m % bm == 0 and n % bn == 0, (m, n, bm, bn)
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        _matmul_kernel,
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        interpret=True,
    )(x, y)
