//! Checkpoint/resume for streaming passes.
//!
//! A long `n·c` pass whose consumers are **row-ordered sums** (GramFold,
//! SketchFold, LeverageFold, MatvecFold — the folds with
//! [`TileConsumer::snapshot`]) can persist its fold state every K tiles
//! and, after an interruption, resume from the last completed tile
//! instead of re-paying the whole stream: the oracle is re-charged only
//! for tiles after the checkpoint, and because those folds add tiles in
//! ascending row order, an interrupted+resumed pass is **bit-identical**
//! to an uninterrupted one (asserted in `tests/stream_equiv.rs`).
//!
//! The context is armed per thread ([`arm`]) because the pipeline folds
//! consumers on the caller's thread: the service worker arms it around a
//! retried request, [`run_pipeline_resumable`] arms it around a single
//! pass. Each pipeline run under an armed context takes the next pass
//! ordinal, giving the deterministic file name `ckpt-pass-<k>.bin` — a
//! re-run of the same request replays the same pass sequence, so pass k
//! finds exactly its own checkpoint. Checkpoint files use the same
//! checksummed [`record`](super::record) codec as the spill arena, are
//! written atomically (tmp + rename), bind the pass shape (`n`, `cols`,
//! tile height, element width, consumer count) so a stale or foreign
//! file can never restore into the wrong pass, and are deleted when the
//! pass completes. Any integrity or shape mismatch on load means
//! *start from row 0* — never wrong bits, at worst a full re-stream.
//!
//! [`TileConsumer::snapshot`]: super::TileConsumer::snapshot
//! [`run_pipeline_resumable`]: super::run_pipeline_resumable

use super::record::{self, RECORD_HEADER_BYTES};
use crate::linalg::{Matrix, Precision};
use std::cell::RefCell;
use std::fs::File;
use std::io::{Read as _, Write as _};
use std::path::{Path, PathBuf};

/// Record tag for checkpoint files (distinct from the 8/4 element-width
/// tags of arena tile records, so the codecs can never be confused).
const CKPT_TAG: u8 = 0xC5;

/// Identifies a checkpoint as belonging to this codec revision.
const CKPT_MAGIC: u64 = 0x4653_5053_4443_4B50; // "FSPSDCKP"

/// Default tiles-between-checkpoints when `FASTSPSD_CKPT_EVERY` is unset.
pub const DEFAULT_CKPT_EVERY: usize = 16;

/// Where and how often a streaming pass checkpoints its fold state.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CheckpointConfig {
    /// Directory checkpoint files live in (typically the spill dir).
    pub dir: PathBuf,
    /// Persist fold state every `every` folded tiles.
    pub every: usize,
}

impl CheckpointConfig {
    /// Checkpoint into `dir` every `FASTSPSD_CKPT_EVERY` tiles
    /// (default [`DEFAULT_CKPT_EVERY`]).
    pub fn new(dir: impl Into<PathBuf>) -> Self {
        let every = std::env::var("FASTSPSD_CKPT_EVERY")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .filter(|&k| k > 0)
            .unwrap_or(DEFAULT_CKPT_EVERY);
        CheckpointConfig { dir: dir.into(), every }
    }

    pub fn with_every(mut self, every: usize) -> Self {
        self.every = every.max(1);
        self
    }
}

struct Ctx {
    cfg: CheckpointConfig,
    /// Pipeline runs seen under this context so far (the pass ordinal).
    passes: u64,
}

thread_local! {
    static CTX: RefCell<Option<Ctx>> = const { RefCell::new(None) };
}

/// Arm checkpointing for pipeline runs on **this thread** until the
/// guard drops (which restores whatever was armed before, including its
/// pass counter).
#[must_use = "dropping the guard immediately disarms checkpointing"]
pub fn arm(cfg: &CheckpointConfig) -> CheckpointGuard {
    let prev = CTX.with(|c| c.borrow_mut().replace(Ctx { cfg: cfg.clone(), passes: 0 }));
    CheckpointGuard { prev }
}

/// Restores the previously armed context (if any) on drop.
pub struct CheckpointGuard {
    prev: Option<Ctx>,
}

impl Drop for CheckpointGuard {
    fn drop(&mut self) {
        CTX.with(|c| *c.borrow_mut() = self.prev.take());
    }
}

/// One pipeline run's checkpoint assignment.
pub(crate) struct PassSpec {
    pub path: PathBuf,
    pub every: usize,
}

/// Claim the next pass ordinal under the armed context (None when
/// disarmed). Called once per pipeline run, whether or not the run's
/// consumers end up supporting snapshots — the ordinal sequence must be
/// a function of the run sequence alone so a retried request maps each
/// pass onto the same file.
pub(crate) fn next_pass_spec() -> Option<PassSpec> {
    CTX.with(|c| {
        c.borrow_mut().as_mut().map(|ctx| {
            ctx.passes += 1;
            PassSpec {
                path: ctx.cfg.dir.join(format!("ckpt-pass-{}.bin", ctx.passes)),
                every: ctx.cfg.every.max(1),
            }
        })
    })
}

/// The shape a checkpoint is bound to; every field must match on load.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) struct PassMeta {
    pub n: usize,
    pub cols: usize,
    pub tile_rows: usize,
    pub precision: Precision,
    pub consumers: usize,
}

fn push_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

/// Persist `snaps` + resume point atomically (tmp + rename). IO failure
/// returns `false` and is ignored by the pipeline — a missed checkpoint
/// only costs resume granularity, never correctness.
pub(crate) fn save(path: &Path, meta: &PassMeta, next_r0: usize, snaps: &[Matrix]) -> bool {
    let mut payload = Vec::new();
    push_u64(&mut payload, CKPT_MAGIC);
    push_u64(&mut payload, meta.n as u64);
    push_u64(&mut payload, meta.cols as u64);
    push_u64(&mut payload, meta.tile_rows as u64);
    payload.push(record::width_tag(meta.precision));
    push_u64(&mut payload, next_r0 as u64);
    push_u64(&mut payload, snaps.len() as u64);
    for s in snaps {
        push_u64(&mut payload, s.rows() as u64);
        push_u64(&mut payload, s.cols() as u64);
        for &v in s.data() {
            payload.extend_from_slice(&v.to_le_bytes());
        }
    }
    let rec = record::encode(CKPT_TAG, &payload);
    let tmp = path.with_extension("tmp");
    let ok = File::create(&tmp)
        .and_then(|mut f| f.write_all(&rec))
        .and_then(|_| std::fs::rename(&tmp, path))
        .is_ok();
    if !ok {
        let _ = std::fs::remove_file(&tmp);
    }
    ok
}

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl Reader<'_> {
    fn u64(&mut self) -> Option<u64> {
        let b = self.buf.get(self.pos..self.pos + 8)?;
        self.pos += 8;
        Some(u64::from_le_bytes(b.try_into().unwrap()))
    }

    fn u8(&mut self) -> Option<u8> {
        let b = *self.buf.get(self.pos)?;
        self.pos += 1;
        Some(b)
    }

    fn matrix(&mut self) -> Option<Matrix> {
        let rows = self.u64()? as usize;
        let cols = self.u64()? as usize;
        let elems = rows.checked_mul(cols)?;
        let bytes = self.buf.get(self.pos..self.pos + elems.checked_mul(8)?)?;
        self.pos += elems * 8;
        let data: Vec<f64> = bytes
            .chunks_exact(8)
            .map(|b| f64::from_le_bytes(b.try_into().unwrap()))
            .collect();
        Some(Matrix::from_vec(rows, cols, data))
    }
}

/// Load a checkpoint for the pass shaped by `meta`. Returns the resume
/// row and one snapshot per consumer, or `None` for *any* problem —
/// missing file, failed checksum, foreign shape, misaligned resume row —
/// in which case the pass simply starts from row 0.
pub(crate) fn load(path: &Path, meta: &PassMeta) -> Option<(usize, Vec<Matrix>)> {
    let mut bytes = Vec::new();
    File::open(path).ok()?.read_to_end(&mut bytes).ok()?;
    if bytes.len() < RECORD_HEADER_BYTES {
        return None;
    }
    let header: [u8; RECORD_HEADER_BYTES] = bytes[..RECORD_HEADER_BYTES].try_into().unwrap();
    let payload = &bytes[RECORD_HEADER_BYTES..];
    record::verify(CKPT_TAG, &header, payload).ok()?;
    let mut r = Reader { buf: payload, pos: 0 };
    if r.u64()? != CKPT_MAGIC
        || r.u64()? as usize != meta.n
        || r.u64()? as usize != meta.cols
        || r.u64()? as usize != meta.tile_rows
        || r.u8()? != record::width_tag(meta.precision)
    {
        return None;
    }
    let next_r0 = r.u64()? as usize;
    if next_r0 == 0 || next_r0 >= meta.n || next_r0 % meta.tile_rows != 0 {
        return None; // nothing to resume, or a row not on a tile boundary
    }
    let count = r.u64()? as usize;
    if count != meta.consumers {
        return None;
    }
    let mut snaps = Vec::with_capacity(count);
    for _ in 0..count {
        snaps.push(r.matrix()?);
    }
    if r.pos != r.buf.len() {
        return None; // trailing garbage: not a record this codec wrote
    }
    Some((next_r0, snaps))
}

/// Remove a completed pass's checkpoint (best effort).
pub(crate) fn discard(path: &Path) {
    let _ = std::fs::remove_file(path);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn meta() -> PassMeta {
        PassMeta { n: 40, cols: 3, tile_rows: 8, precision: Precision::F64, consumers: 2 }
    }

    fn snaps() -> Vec<Matrix> {
        let mut rng = Rng::new(41);
        vec![Matrix::randn(3, 3, &mut rng), Matrix::randn(1, 3, &mut rng)]
    }

    #[test]
    fn save_load_round_trip_is_bit_exact() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("fastspsd-ckpt-test-{}.bin", std::process::id()));
        let s = snaps();
        assert!(save(&path, &meta(), 16, &s));
        let (r0, back) = load(&path, &meta()).expect("clean checkpoint must load");
        assert_eq!(r0, 16);
        assert_eq!(back.len(), 2);
        for (a, b) in s.iter().zip(&back) {
            assert_eq!(a.max_abs_diff(b), 0.0);
        }
        discard(&path);
        assert!(!path.exists());
        assert!(load(&path, &meta()).is_none(), "discarded checkpoint must not load");
    }

    #[test]
    fn shape_or_integrity_mismatch_never_restores() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("fastspsd-ckpt-test2-{}.bin", std::process::id()));
        assert!(save(&path, &meta(), 24, &snaps()));
        // foreign shapes are rejected field by field
        for wrong in [
            PassMeta { n: 41, ..meta() },
            PassMeta { cols: 4, ..meta() },
            PassMeta { tile_rows: 7, ..meta() },
            PassMeta { precision: Precision::F32, ..meta() },
            PassMeta { consumers: 1, ..meta() },
        ] {
            assert!(load(&path, &wrong).is_none(), "{wrong:?} must not restore");
        }
        // a flipped payload byte fails the checksum
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = RECORD_HEADER_BYTES + (bytes.len() - RECORD_HEADER_BYTES) / 2;
        bytes[mid] ^= 0x10;
        std::fs::write(&path, &bytes).unwrap();
        assert!(load(&path, &meta()).is_none(), "corrupt checkpoint must not restore");
        discard(&path);
    }

    #[test]
    fn misaligned_or_degenerate_resume_rows_are_rejected() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("fastspsd-ckpt-test3-{}.bin", std::process::id()));
        for bad_r0 in [0usize, 5, 40, 48] {
            assert!(save(&path, &meta(), bad_r0, &snaps()));
            assert!(load(&path, &meta()).is_none(), "next_r0={bad_r0} must not restore");
        }
        discard(&path);
    }

    #[test]
    fn armed_context_hands_out_sequential_pass_files_and_restores_prev() {
        let cfg = CheckpointConfig::new("/tmp/ck-a").with_every(4);
        assert!(next_pass_spec().is_none(), "disarmed by default");
        let g1 = arm(&cfg);
        let s1 = next_pass_spec().unwrap();
        let s2 = next_pass_spec().unwrap();
        assert_eq!(s1.path, PathBuf::from("/tmp/ck-a/ckpt-pass-1.bin"));
        assert_eq!(s2.path, PathBuf::from("/tmp/ck-a/ckpt-pass-2.bin"));
        assert_eq!(s1.every, 4);
        {
            let inner = CheckpointConfig::new("/tmp/ck-b").with_every(2);
            let _g2 = arm(&inner);
            let s = next_pass_spec().unwrap();
            assert_eq!(s.path, PathBuf::from("/tmp/ck-b/ckpt-pass-1.bin"));
        }
        // outer context back, counter intact
        let s3 = next_pass_spec().unwrap();
        assert_eq!(s3.path, PathBuf::from("/tmp/ck-a/ckpt-pass-3.bin"));
        drop(g1);
        assert!(next_pass_spec().is_none());
    }
}
