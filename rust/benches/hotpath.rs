//! Bench: hot-path microbenchmarks for the perf pass (EXPERIMENTS.md §Perf):
//! GEMM, SVD/pinv, RBF block computation (pure-rust vs PJRT when artifacts
//! exist), and the assemble path of the fast model.

use fastspsd::benchkit::{black_box, BenchSuite};
use fastspsd::coordinator::engine::{rbf_cross_cpu, KernelEngine};
use fastspsd::linalg::{pinv, svd_thin, Matrix};
use fastspsd::util::Rng;

fn main() {
    let mut rng = Rng::new(0);
    let mut suite = BenchSuite::new("hot paths");
    suite.header();

    // GEMM scaling
    for &n in &[128usize, 256, 512] {
        let a = Matrix::randn(n, n, &mut rng);
        let b = Matrix::randn(n, n, &mut rng);
        let s = suite.bench(&format!("gemm {n}x{n}x{n}"), || {
            black_box(a.matmul(&b));
        });
        let flops = 2.0 * (n as f64).powi(3);
        println!("    {:.2} GFLOP/s", flops / s.mean_secs() / 1e9);
    }

    // factorizations at algorithm-relevant sizes
    let c128 = Matrix::randn(1024, 64, &mut rng);
    suite.bench("svd_thin 1024x64", || {
        black_box(svd_thin(&c128));
    });
    suite.bench("pinv 1024x64", || {
        black_box(pinv(&c128));
    });
    let sq = Matrix::randn(256, 256, &mut rng);
    suite.bench("svd_thin 256x256", || {
        black_box(svd_thin(&sq));
    });

    // RBF blocks: pure rust vs PJRT (if artifacts available)
    let x = Matrix::randn(512, 16, &mut rng);
    suite.bench("rbf_cross_cpu 512x512x16", || {
        black_box(rbf_cross_cpu(&x, &x, 0.5));
    });
    let engine = KernelEngine::auto();
    if engine.is_pjrt() {
        suite.bench("rbf_cross_pjrt 512x512x16", || {
            black_box(engine.rbf_cross(&x, &x, 0.5));
        });
        let x1024 = Matrix::randn(1024, 128, &mut rng);
        suite.bench("rbf_cross_pjrt 1024x1024x128", || {
            black_box(engine.rbf_cross(&x1024, &x1024, 0.5));
        });
        suite.bench("rbf_cross_cpu  1024x1024x128", || {
            black_box(rbf_cross_cpu(&x1024, &x1024, 0.5));
        });
    } else {
        println!("  (PJRT engine unavailable — run `make artifacts` to bench the AOT path)");
    }
}
