//! Worker thread pool substrate (no tokio in the image).
//!
//! A fixed pool of workers fed by an MPMC channel built on
//! `Mutex<VecDeque>` + `Condvar`, with a bounded-queue mode for
//! backpressure. Since GEMM v2, all data-parallel loops in the crate run
//! through one lazily-initialized [`global`] pool via [`parallel_for`] /
//! [`ThreadPool::scoped`] — per-call `std::thread::scope` spawning is gone
//! from the hot paths, and `FASTSPSD_THREADS` pins the parallel width for
//! deterministic single-threaded runs.

use std::collections::VecDeque;
use std::marker::PhantomData;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Queue {
    jobs: Mutex<VecDeque<Job>>,
    cond: Condvar,
    /// signaled when a job is popped (for bounded-queue producers)
    space: Condvar,
    capacity: usize,
    shutdown: AtomicBool,
    inflight: AtomicUsize,
    done: Condvar,
    panics: AtomicUsize,
}

/// Fixed-size worker pool with an optionally bounded job queue.
pub struct ThreadPool {
    queue: Arc<Queue>,
    workers: Vec<JoinHandle<()>>,
}

/// Parallel width for this process: `FASTSPSD_THREADS` when set to a
/// positive integer (deterministic test/bench runs), otherwise the
/// machine's available parallelism. Read once and cached.
pub fn configured_threads() -> usize {
    static N: OnceLock<usize> = OnceLock::new();
    *N.get_or_init(|| {
        match std::env::var("FASTSPSD_THREADS")
            .ok()
            .and_then(|v| v.trim().parse::<usize>().ok())
        {
            Some(n) if n > 0 => n,
            _ => std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
        }
    })
}

/// The process-wide pool: lazily initialized with [`configured_threads`]
/// workers and an unbounded queue, shared by GEMM, kernel-block evaluation,
/// and sketch application. Never dropped (workers live for the process).
pub fn global() -> &'static ThreadPool {
    static POOL: OnceLock<ThreadPool> = OnceLock::new();
    POOL.get_or_init(|| ThreadPool::new(configured_threads(), usize::MAX))
}

impl ThreadPool {
    /// `threads` workers; `capacity` bounds the pending-job queue
    /// (`usize::MAX` for unbounded). Submitting beyond capacity blocks the
    /// producer — the coordinator's backpressure mechanism.
    pub fn new(threads: usize, capacity: usize) -> Self {
        assert!(threads > 0);
        let queue = Arc::new(Queue {
            jobs: Mutex::new(VecDeque::new()),
            cond: Condvar::new(),
            space: Condvar::new(),
            capacity,
            shutdown: AtomicBool::new(false),
            inflight: AtomicUsize::new(0),
            done: Condvar::new(),
            panics: AtomicUsize::new(0),
        });
        let workers = (0..threads)
            .map(|_| {
                let q = Arc::clone(&queue);
                std::thread::spawn(move || worker_loop(q))
            })
            .collect();
        ThreadPool { queue, workers }
    }

    /// Pool sized to the machine, unbounded queue.
    pub fn with_default_threads() -> Self {
        ThreadPool::new(configured_threads(), usize::MAX)
    }

    pub fn threads(&self) -> usize {
        self.workers.len()
    }

    /// Number of jobs queued or running.
    pub fn inflight(&self) -> usize {
        self.queue.inflight.load(Ordering::SeqCst)
    }

    /// Number of jobs that panicked (caught; the worker survives).
    pub fn panics(&self) -> usize {
        self.queue.panics.load(Ordering::SeqCst)
    }

    /// Submit a job; blocks while the queue is at capacity (backpressure).
    pub fn submit(&self, job: impl FnOnce() + Send + 'static) {
        let mut jobs = self.queue.jobs.lock().unwrap();
        while jobs.len() >= self.queue.capacity {
            jobs = self.queue.space.wait(jobs).unwrap();
        }
        self.queue.inflight.fetch_add(1, Ordering::SeqCst);
        jobs.push_back(Box::new(job));
        drop(jobs);
        self.queue.cond.notify_one();
    }

    /// Block until every submitted job has finished.
    pub fn wait_idle(&self) {
        let mut jobs = self.queue.jobs.lock().unwrap();
        while self.queue.inflight.load(Ordering::SeqCst) > 0 {
            jobs = self.queue.done.wait(jobs).unwrap();
        }
        drop(jobs);
    }

    /// Pop one pending job without blocking (used by waiting scope owners
    /// to help drain the queue).
    fn try_pop(&self) -> Option<Job> {
        let mut jobs = self.queue.jobs.lock().unwrap();
        let job = jobs.pop_front();
        if job.is_some() {
            self.queue.space.notify_one();
        }
        job
    }

    /// Scoped data-parallel execution on the pool: jobs spawned through the
    /// [`Scope`] may borrow from the caller's stack; `scoped` returns only
    /// after every spawned job has finished. While waiting, the calling
    /// thread helps execute queued jobs, so a pool worker may itself open a
    /// scope (nested parallelism) without deadlocking the pool. If any
    /// scoped job panicked, `scoped` panics after all jobs have settled.
    pub fn scoped<'env, R>(&self, f: impl FnOnce(&Scope<'_, 'env>) -> R) -> R {
        let scope = Scope {
            pool: self,
            state: Arc::new(ScopeState {
                remaining: Mutex::new(0),
                done: Condvar::new(),
                panicked: AtomicBool::new(false),
            }),
            _env: PhantomData,
        };
        // If `f` panics we must still wait for every spawned job before
        // unwinding — the jobs borrow the caller's stack (same contract as
        // std::thread::scope).
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&scope)));
        // Wait for the scope's jobs, helping drain the queue only while our
        // own jobs are still pending (ours may be queued behind others, and
        // helping keeps nested scopes deadlock-free) — a completed scope
        // returns immediately instead of adopting unrelated work. Every job
        // of this scope was queued before `f` returned, so once the queue
        // is observed empty the stragglers are already running on workers
        // and their completion guards will signal `done`.
        loop {
            if *scope.state.remaining.lock().unwrap() == 0 {
                break;
            }
            if let Some(job) = self.try_pop() {
                execute_job(&self.queue, job);
                continue;
            }
            let remaining = scope.state.remaining.lock().unwrap();
            if *remaining != 0 {
                let _woken = scope.state.done.wait(remaining).unwrap();
            }
        }
        match result {
            Ok(r) => {
                if scope.state.panicked.load(Ordering::SeqCst) {
                    panic!("a job spawned in ThreadPool::scoped panicked");
                }
                r
            }
            Err(payload) => std::panic::resume_unwind(payload),
        }
    }
}

struct ScopeState {
    remaining: Mutex<usize>,
    done: Condvar,
    panicked: AtomicBool,
}

/// Handle for spawning borrow-carrying jobs inside [`ThreadPool::scoped`].
pub struct Scope<'pool, 'env> {
    pool: &'pool ThreadPool,
    state: Arc<ScopeState>,
    // Invariant over 'env, like std::thread::Scope: the closure may borrow
    // anything that outlives the `scoped` call, mutably or not.
    _env: PhantomData<&'env mut &'env ()>,
}

/// Decrements the scope's pending count even if the job panics (the drop
/// runs during unwinding), recording the panic for re-raise in `scoped`.
struct ScopeGuard(Arc<ScopeState>);

impl Drop for ScopeGuard {
    fn drop(&mut self) {
        if std::thread::panicking() {
            self.0.panicked.store(true, Ordering::SeqCst);
        }
        let mut remaining = self.0.remaining.lock().unwrap();
        *remaining -= 1;
        if *remaining == 0 {
            self.0.done.notify_all();
        }
    }
}

impl<'pool, 'env> Scope<'pool, 'env> {
    /// Queue `job` on the pool. The job may borrow from `'env`.
    pub fn spawn(&self, job: impl FnOnce() + Send + 'env) {
        *self.state.remaining.lock().unwrap() += 1;
        let state = Arc::clone(&self.state);
        let boxed: Box<dyn FnOnce() + Send + 'env> = Box::new(move || {
            let _guard = ScopeGuard(state);
            job();
        });
        // SAFETY: `ThreadPool::scoped` does not return until `remaining`
        // reaches 0, i.e. until this closure (and everything it borrows
        // from 'env) has finished running, so extending the lifetime to
        // 'static never lets the job outlive its borrows.
        let boxed: Job = unsafe {
            std::mem::transmute::<Box<dyn FnOnce() + Send + 'env>, Job>(boxed)
        };
        let mut jobs = self.pool.queue.jobs.lock().unwrap();
        // Scoped jobs ignore the capacity bound: blocking here could
        // deadlock a scope opened from within a worker.
        self.pool.queue.inflight.fetch_add(1, Ordering::SeqCst);
        jobs.push_back(boxed);
        drop(jobs);
        self.pool.queue.cond.notify_one();
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.queue.shutdown.store(true, Ordering::SeqCst);
        self.queue.cond.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Run one job with the pool's panic isolation and inflight accounting
/// (shared by workers and helping scope owners).
fn execute_job(q: &Queue, job: Job) {
    // Failure isolation: a panicking job must not kill the worker or
    // wedge `wait_idle` (the inflight count still drops below).
    if std::panic::catch_unwind(std::panic::AssertUnwindSafe(job)).is_err() {
        q.panics.fetch_add(1, Ordering::SeqCst);
    }
    if q.inflight.fetch_sub(1, Ordering::SeqCst) == 1 {
        let _guard = q.jobs.lock().unwrap();
        q.done.notify_all();
    }
}

fn worker_loop(q: Arc<Queue>) {
    loop {
        let job = {
            let mut jobs = q.jobs.lock().unwrap();
            loop {
                if let Some(j) = jobs.pop_front() {
                    q.space.notify_one();
                    break j;
                }
                if q.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                jobs = q.cond.wait(jobs).unwrap();
            }
        };
        execute_job(&q, job);
    }
}

/// Data-parallel loop over `0..n` on the [`global`] pool: splits into
/// contiguous chunks across up to `max_threads` workers (further capped by
/// [`configured_threads`]) and calls `f(i)` for each index exactly once.
/// The caller runs the first chunk itself and helps drain the queue while
/// waiting, so no thread is ever spawned per call. Chunk boundaries never
/// change which `f(i)` runs, so results are identical across widths.
pub fn parallel_for(n: usize, max_threads: usize, f: impl Fn(usize) + Sync) {
    if n == 0 {
        return;
    }
    let width = max_threads.min(n).min(configured_threads()).max(1);
    if width == 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let chunk = n.div_ceil(width);
    let f = &f;
    global().scoped(|scope| {
        for t in 1..width {
            let lo = t * chunk;
            let hi = ((t + 1) * chunk).min(n);
            if lo >= hi {
                break;
            }
            scope.spawn(move || {
                for i in lo..hi {
                    f(i);
                }
            });
        }
        // The caller computes the first chunk while the pool runs the rest.
        for i in 0..chunk.min(n) {
            f(i);
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4, usize::MAX);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn bounded_queue_applies_backpressure() {
        // capacity 2, one slow worker: the producer must block rather than
        // queueing all jobs instantly.
        let pool = ThreadPool::new(1, 2);
        let started = std::time::Instant::now();
        for _ in 0..6 {
            pool.submit(|| std::thread::sleep(std::time::Duration::from_millis(10)));
        }
        // With capacity 2 and 10ms jobs, submitting 6 must take >= ~30ms.
        assert!(started.elapsed() >= std::time::Duration::from_millis(25));
        pool.wait_idle();
    }

    #[test]
    fn wait_idle_without_jobs_returns() {
        let pool = ThreadPool::new(2, 8);
        pool.wait_idle();
    }

    #[test]
    fn parallel_for_covers_range() {
        let hits: Vec<AtomicU64> = (0..97).map(|_| AtomicU64::new(0)).collect();
        parallel_for(97, 8, |i| {
            hits[i].fetch_add(1, Ordering::SeqCst);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn parallel_for_zero_and_one() {
        parallel_for(0, 4, |_| panic!("must not run"));
        let hit = AtomicU64::new(0);
        parallel_for(1, 4, |_| {
            hit.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(hit.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn scoped_borrows_stack_data() {
        let pool = ThreadPool::new(3, usize::MAX);
        let mut results = vec![0u64; 64];
        {
            let chunks: Vec<&mut [u64]> = results.chunks_mut(16).collect();
            pool.scoped(|scope| {
                for (t, chunk) in chunks.into_iter().enumerate() {
                    scope.spawn(move || {
                        for (i, v) in chunk.iter_mut().enumerate() {
                            *v = (t * 16 + i) as u64;
                        }
                    });
                }
            });
        }
        assert!(results.iter().enumerate().all(|(i, &v)| v == i as u64));
    }

    #[test]
    fn scoped_nested_does_not_deadlock() {
        // A scoped job that itself opens a scope on the same pool; with one
        // worker this only terminates because waiters help drain the queue.
        let pool = ThreadPool::new(1, usize::MAX);
        let total = AtomicU64::new(0);
        pool.scoped(|outer| {
            outer.spawn(|| {
                pool.scoped(|inner| {
                    for _ in 0..8 {
                        inner.spawn(|| {
                            total.fetch_add(1, Ordering::SeqCst);
                        });
                    }
                });
            });
        });
        assert_eq!(total.load(Ordering::SeqCst), 8);
    }

    #[test]
    #[should_panic(expected = "scoped panicked")]
    fn scoped_propagates_job_panics() {
        let pool = ThreadPool::new(2, usize::MAX);
        pool.scoped(|scope| {
            scope.spawn(|| panic!("inner failure"));
        });
    }

    #[test]
    fn panicking_job_does_not_wedge_pool() {
        let pool = ThreadPool::new(2, usize::MAX);
        let c = Arc::new(AtomicU64::new(0));
        for i in 0..10 {
            let c = Arc::clone(&c);
            pool.submit(move || {
                if i % 3 == 0 {
                    panic!("injected failure");
                }
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle(); // must not hang
        assert_eq!(c.load(Ordering::SeqCst), 6);
        assert_eq!(pool.panics(), 4);
        // pool still works afterwards
        let c2 = Arc::clone(&c);
        pool.submit(move || {
            c2.fetch_add(1, Ordering::SeqCst);
        });
        pool.wait_idle();
        assert_eq!(c.load(Ordering::SeqCst), 7);
    }

    #[test]
    fn drop_joins_workers() {
        let pool = ThreadPool::new(3, usize::MAX);
        let c = Arc::new(AtomicU64::new(0));
        for _ in 0..10 {
            let c = Arc::clone(&c);
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        drop(pool);
        assert_eq!(c.load(Ordering::SeqCst), 10);
    }

    #[test]
    fn global_pool_is_shared_and_configured() {
        let p1 = global() as *const ThreadPool;
        let p2 = global() as *const ThreadPool;
        assert_eq!(p1, p2);
        assert!(global().threads() >= 1);
        assert!(configured_threads() >= 1);
    }
}
