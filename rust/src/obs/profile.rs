//! Per-stage aggregation of one trace's span records into the
//! [`StageProfile`] carried by `RunMeta` — the "where did this request's
//! time go" answer, cheap enough to attach to every reply.

use super::{SpanRecord, Stage};

/// Aggregated statistics for one stage across a trace.
#[derive(Debug, Clone, PartialEq)]
pub struct StageAgg {
    /// Which stage this row aggregates.
    pub stage: Stage,
    /// Number of spans.
    pub count: u64,
    /// Summed wall-clock duration over all threads, seconds.
    pub total_secs: f64,
    /// Longest single span, seconds.
    pub max_secs: f64,
    /// Summed self time (duration minus same-thread children) of the
    /// spans recorded on the trace's main thread — these partition the
    /// main thread's wall-clock without double counting, so they are
    /// the safe quantity to sum across stages.
    pub main_self_secs: f64,
}

/// Per-stage totals/counts/maxima plus pipeline stall fractions for one
/// trace. Built by [`StageProfile::from_records`] from a drained trace;
/// rows appear in taxonomy order and only for stages that occurred.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct StageProfile {
    /// One row per stage that occurred, in [`Stage::ALL`] order.
    pub stages: Vec<StageAgg>,
}

const NS: f64 = 1e-9;

impl StageProfile {
    /// Aggregate `records` (one drained trace). `main_thread` is the
    /// recorder thread id of the thread that ran the traced body (the
    /// one `compute_secs` was measured on) — its self times feed
    /// [`covered_secs`](Self::covered_secs).
    pub fn from_records(records: &[SpanRecord], main_thread: u32) -> StageProfile {
        let mut stages = Vec::new();
        for stage in Stage::ALL {
            let mut count = 0u64;
            let mut total_ns = 0u64;
            let mut max_ns = 0u64;
            let mut main_self_ns = 0u64;
            for r in records.iter().filter(|r| r.stage == stage) {
                count += 1;
                total_ns += r.dur_ns;
                max_ns = max_ns.max(r.dur_ns);
                if r.thread == main_thread {
                    main_self_ns += r.self_ns;
                }
            }
            if count > 0 {
                stages.push(StageAgg {
                    stage,
                    count,
                    total_secs: total_ns as f64 * NS,
                    max_secs: max_ns as f64 * NS,
                    main_self_secs: main_self_ns as f64 * NS,
                });
            }
        }
        StageProfile { stages }
    }

    /// The row for `stage`, if it occurred.
    pub fn get(&self, stage: Stage) -> Option<&StageAgg> {
        self.stages.iter().find(|a| a.stage == stage)
    }

    /// Summed duration of `stage` over all threads, seconds (0 when the
    /// stage did not occur).
    pub fn total_secs(&self, stage: Stage) -> f64 {
        self.get(stage).map_or(0.0, |a| a.total_secs)
    }

    /// Main-thread compute accounted for by spans: the sum of main-thread
    /// self times over every stage except [`Stage::AdmissionQueue`]
    /// (queue wait precedes compute). Because `exec.run` umbrellas the
    /// whole body and same-thread self times partition it exactly, this
    /// sums to the traced body's duration — within a few percent of
    /// `RunMeta::compute_secs` on any real run.
    pub fn covered_secs(&self) -> f64 {
        self.stages
            .iter()
            .filter(|a| a.stage != Stage::AdmissionQueue)
            .map(|a| a.main_self_secs)
            .sum()
    }

    /// Fraction of producer-side pipeline time spent blocked pushing
    /// into the bounded channel: `stall / (produce + stall)`. High means
    /// the pipeline is consumer-(fold-)bound. `None` when no pipeline
    /// producer ran in this trace.
    pub fn producer_stall_fraction(&self) -> Option<f64> {
        let work = self.total_secs(Stage::PipelineProduce);
        let stall = self.total_secs(Stage::PipelineProduceStall);
        if work + stall > 0.0 {
            Some(stall / (work + stall))
        } else {
            None
        }
    }

    /// Fraction of consumer-side pipeline time spent blocked waiting for
    /// a tile: `stall / (fold + stall)`. High means the pipeline is
    /// producer-(oracle-)bound. `None` when no pipeline consumer ran.
    pub fn consumer_stall_fraction(&self) -> Option<f64> {
        let work = self.total_secs(Stage::PipelineFold);
        let stall = self.total_secs(Stage::PipelineFoldStall);
        if work + stall > 0.0 {
            Some(stall / (work + stall))
        } else {
            None
        }
    }

    /// Human-readable per-stage lines (figures/CLI reporting):
    /// `name  total  count  max`.
    pub fn summary_lines(&self) -> Vec<String> {
        let mut out: Vec<String> = self
            .stages
            .iter()
            .map(|a| {
                format!(
                    "{:<24} total {:>10.3} ms  count {:>6}  max {:>10.3} ms",
                    a.stage.name(),
                    a.total_secs * 1e3,
                    a.count,
                    a.max_secs * 1e3,
                )
            })
            .collect();
        if let Some(f) = self.producer_stall_fraction() {
            out.push(format!("pipeline producer stall fraction: {f:.3}"));
        }
        if let Some(f) = self.consumer_stall_fraction() {
            out.push(format!("pipeline consumer stall fraction: {f:.3}"));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(stage: Stage, thread: u32, start: u64, dur: u64, self_ns: u64) -> SpanRecord {
        SpanRecord { stage, trace: 1, thread, depth: 0, start_ns: start, dur_ns: dur, self_ns }
    }

    #[test]
    fn aggregates_per_stage_in_taxonomy_order() {
        let recs = vec![
            rec(Stage::SolveEig, 1, 50, 10, 10),
            rec(Stage::OracleTile, 2, 0, 30, 30),
            rec(Stage::OracleTile, 2, 40, 50, 50),
            rec(Stage::ExecRun, 1, 0, 100, 40),
        ];
        let p = StageProfile::from_records(&recs, 1);
        let names: Vec<&str> = p.stages.iter().map(|a| a.stage.name()).collect();
        assert_eq!(names, vec!["exec.run", "oracle.tile", "solve.eig"]);
        let ot = p.get(Stage::OracleTile).unwrap();
        assert_eq!(ot.count, 2);
        assert!((ot.total_secs - 80e-9).abs() < 1e-15);
        assert!((ot.max_secs - 50e-9).abs() < 1e-15);
        assert_eq!(ot.main_self_secs, 0.0, "thread 2 is not main");
        // covered = main-thread selves: exec.run(40) + solve.eig(10)
        assert!((p.covered_secs() - 50e-9).abs() < 1e-15);
        assert_eq!(p.total_secs(Stage::GramFold), 0.0);
        assert!(p.get(Stage::GramFold).is_none());
    }

    #[test]
    fn stall_fractions_from_stage_totals() {
        let recs = vec![
            rec(Stage::PipelineProduce, 2, 0, 75, 75),
            rec(Stage::PipelineProduceStall, 2, 75, 25, 25),
            rec(Stage::PipelineFold, 1, 0, 40, 40),
            rec(Stage::PipelineFoldStall, 1, 40, 60, 60),
        ];
        let p = StageProfile::from_records(&recs, 1);
        assert!((p.producer_stall_fraction().unwrap() - 0.25).abs() < 1e-12);
        assert!((p.consumer_stall_fraction().unwrap() - 0.60).abs() < 1e-12);
        let none = StageProfile::from_records(&[rec(Stage::Plan, 1, 0, 5, 5)], 1);
        assert!(none.producer_stall_fraction().is_none());
        assert!(none.consumer_stall_fraction().is_none());
        assert_eq!(none.summary_lines().len(), 1);
    }

    #[test]
    fn admission_queue_excluded_from_covered() {
        let recs = vec![
            rec(Stage::AdmissionQueue, 1, 0, 1_000_000, 1_000_000),
            rec(Stage::ExecRun, 1, 1_000_000, 100, 100),
        ];
        let p = StageProfile::from_records(&recs, 1);
        assert!((p.covered_secs() - 100e-9).abs() < 1e-15);
    }
}
