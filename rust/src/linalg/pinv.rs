//! Moore–Penrose pseudo-inverse via the thin SVD.

use super::svd::svd_thin;
use super::Matrix;

/// `A† = V diag(1/s) U^T` with LAPACK-style rank tolerance.
pub fn pinv(a: &Matrix) -> Matrix {
    if a.rows() == 0 || a.cols() == 0 {
        return Matrix::zeros(a.cols(), a.rows());
    }
    let f = svd_thin(a);
    let rank = f.rank(a.rows(), a.cols());
    if rank == 0 {
        return Matrix::zeros(a.cols(), a.rows());
    }
    // V_r diag(1/s_r) U_r^T
    let vs = Matrix::from_fn(f.v.rows(), rank, |i, j| f.v[(i, j)] / f.s[j]);
    let idx: Vec<usize> = (0..rank).collect();
    let ur = f.u.select_cols(&idx);
    vs.matmul_tr(&ur)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    /// The four Penrose conditions.
    fn check_penrose(a: &Matrix, ap: &Matrix, tol: f64) {
        let a_ap_a = a.matmul(ap).matmul(a);
        assert!(a_ap_a.max_abs_diff(a) < tol, "A A† A = A");
        let ap_a_ap = ap.matmul(a).matmul(ap);
        assert!(ap_a_ap.max_abs_diff(ap) < tol, "A† A A† = A†");
        let aap = a.matmul(ap);
        assert!(aap.max_abs_diff(&aap.transpose()) < tol, "(A A†) symmetric");
        let apa = ap.matmul(a);
        assert!(apa.max_abs_diff(&apa.transpose()) < tol, "(A† A) symmetric");
    }

    #[test]
    fn full_rank_square_is_inverse() {
        let mut rng = Rng::new(0);
        let a = Matrix::randn(6, 6, &mut rng);
        let ap = pinv(&a);
        assert!(a.matmul(&ap).max_abs_diff(&Matrix::identity(6)) < 1e-8);
    }

    #[test]
    fn penrose_conditions_various_shapes() {
        let mut rng = Rng::new(1);
        for &(m, n) in &[(8, 8), (12, 5), (5, 12)] {
            let a = Matrix::randn(m, n, &mut rng);
            check_penrose(&a, &pinv(&a), 1e-8);
        }
    }

    #[test]
    fn rank_deficient_penrose() {
        let mut rng = Rng::new(2);
        let b = Matrix::randn(10, 3, &mut rng);
        let c = Matrix::randn(3, 8, &mut rng);
        let a = b.matmul(&c);
        check_penrose(&a, &pinv(&a), 1e-7);
    }

    #[test]
    fn zero_and_empty() {
        let z = pinv(&Matrix::zeros(4, 2));
        assert_eq!((z.rows(), z.cols()), (2, 4));
        assert_eq!(z, Matrix::zeros(2, 4));
        let e = pinv(&Matrix::zeros(0, 3));
        assert_eq!((e.rows(), e.cols()), (3, 0));
    }

    #[test]
    fn diag_pinv() {
        let a = Matrix::diag(&[2.0, 0.0, 4.0]);
        let ap = pinv(&a);
        assert!((ap[(0, 0)] - 0.5).abs() < 1e-12);
        assert!(ap[(1, 1)].abs() < 1e-12);
        assert!((ap[(2, 2)] - 0.25).abs() < 1e-12);
    }
}
