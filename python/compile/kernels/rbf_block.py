"""Layer-1 Pallas kernel: fused RBF kernel block.

Computes one (m, n) block of the RBF kernel matrix

    K[i, j] = exp(-gamma * ||x_i - y_j||^2)
            = exp(-gamma * (||x_i||^2 + ||y_j||^2 - 2 <x_i, y_j>))

as a single fused kernel. The `-2 X Y^T` contraction is the MXU-shaped hot
spot (a (bm, d) x (d, bn) matmul); the row norms and the exp are elementwise
VPU work fused into the same kernel so the distance matrix never round-trips
through HBM.

TPU mapping (see DESIGN.md "Hardware adaptation"): the grid tiles the output
into (bm, bn) blocks; BlockSpec streams the X panel per grid-row and the Y
panel per grid-column HBM->VMEM. `gamma` rides along as a (1, 1) operand
broadcast to every block. `interpret=True` is mandatory in this environment:
real TPU lowering emits a Mosaic custom-call the CPU PJRT plugin cannot run.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rbf_block_kernel(gamma_ref, x_ref, y_ref, o_ref):
    """One (bm, bn) output tile: fused norms + matmul + exp."""
    x = x_ref[...]  # (bm, d) f32 in VMEM
    y = y_ref[...]  # (bn, d) f32 in VMEM
    # Row norms: VPU elementwise + reduce.
    xx = jnp.sum(x * x, axis=1, keepdims=True)  # (bm, 1)
    yy = jnp.sum(y * y, axis=1, keepdims=True)  # (bn, 1)
    # The MXU part: X @ Y^T via dot_general contracting the feature dim.
    xy = jax.lax.dot_general(
        x,
        y,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (bm, bn)
    d2 = xx + yy.T - 2.0 * xy
    # Clamp tiny negatives from cancellation so exp never sees > 1.
    d2 = jnp.maximum(d2, 0.0)
    o_ref[...] = jnp.exp(-gamma_ref[0, 0] * d2)


@functools.partial(jax.jit, static_argnames=("bm", "bn"))
def rbf_block(gamma: jax.Array, x: jax.Array, y: jax.Array, *, bm: int = 128, bn: int = 128) -> jax.Array:
    """RBF kernel block K = exp(-gamma * dist2(x, y)) via the Pallas kernel.

    Args:
      gamma: (1, 1) f32, the RBF precision 1 / (2 sigma^2).
      x: (m, d) f32 row-block of data points.
      y: (n, d) f32 column-block of data points.
      bm, bn: output tile sizes; m % bm == 0 and n % bn == 0.

    Returns:
      (m, n) f32 kernel block.
    """
    m, d = x.shape
    n, d2 = y.shape
    assert d == d2, f"feature dims differ: {d} vs {d2}"
    assert m % bm == 0 and n % bn == 0, (m, n, bm, bn)
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        _rbf_block_kernel,
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),  # gamma broadcast
            pl.BlockSpec((bm, d), lambda i, j: (i, 0)),  # X panel per row
            pl.BlockSpec((bn, d), lambda i, j: (j, 0)),  # Y panel per col
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        interpret=True,  # CPU PJRT cannot execute Mosaic custom-calls
    )(gamma, x, y)
