//! Trace invariants for the span recorder (ISSUE 7): spans nest and
//! partition self time per thread, trace ids propagate across the
//! pipeline's producer thread, an exec entry point that mints its own
//! trace annotates its `RunMeta`, and a served Resident+spill+leverage
//! request yields a stage profile whose durations account for the whole
//! compute window plus a loadable Chrome trace file.
//!
//! Every test uses per-trace drains (`drain_trace`), never `drain_all`,
//! so the tests stay independent under the parallel test runner.

use fastspsd::coordinator::oracle::RbfOracle;
use fastspsd::coordinator::{
    ApproxRequest, ApproxService, KernelOracle, MethodSpec, ServiceConfig,
};
use fastspsd::exec::{self, ExecPolicy};
use fastspsd::linalg::Matrix;
use fastspsd::obs::{self, sink, Stage};
use fastspsd::sketch::SketchKind;
use fastspsd::spsd::FastConfig;
use fastspsd::util::Rng;
use std::path::PathBuf;
use std::sync::{mpsc, Arc};
use std::time::Duration;

fn oracle(n: usize) -> RbfOracle {
    let mut rng = Rng::new(3);
    RbfOracle::cpu(Arc::new(Matrix::randn(n, 6, &mut rng)), 0.5)
}

fn fresh_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("fastspsd-obs-{}-{name}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn count(profile: &fastspsd::StageProfile, stage: Stage) -> u64 {
    profile.get(stage).map_or(0, |a| a.count)
}

#[test]
fn spans_nest_and_partition_self_time_per_thread() {
    obs::ensure_installed();
    let trace = obs::TraceId::mint().raw();
    let _scope = obs::trace_scope(trace);
    {
        let _outer = obs::span(Stage::GramFold);
        std::thread::sleep(Duration::from_millis(4));
        {
            let _inner = obs::span(Stage::SolveEig);
            std::thread::sleep(Duration::from_millis(4));
        }
    }
    let records = obs::drain_trace(trace);
    assert_eq!(records.len(), 2);
    let outer = records.iter().find(|r| r.stage == Stage::GramFold).unwrap();
    let inner = records.iter().find(|r| r.stage == Stage::SolveEig).unwrap();
    assert_eq!(outer.depth, 0);
    assert_eq!(inner.depth, 1);
    assert_eq!(outer.thread, inner.thread);
    assert!(inner.start_ns >= outer.start_ns, "child starts inside its parent");
    assert!(inner.start_ns + inner.dur_ns <= outer.start_ns + outer.dur_ns);
    // Self time partitions exactly: parent self = parent dur − child dur.
    assert_eq!(outer.self_ns, outer.dur_ns - inner.dur_ns);
    assert_eq!(inner.self_ns, inner.dur_ns, "a leaf owns its whole duration");
    assert!(obs::drain_trace(trace).is_empty(), "a drain consumes the trace");
}

#[test]
fn exec_mints_a_trace_and_the_profile_accounts_for_compute() {
    obs::ensure_installed();
    let n = 96;
    let o = oracle(n);
    let mut rng = Rng::new(11);
    let p = fastspsd::spsd::uniform_p(n, 8, &mut rng);
    let rep =
        exec::fast(&o, &p, FastConfig::uniform(24), &ExecPolicy::streamed(16), &mut rng);
    let profile = rep.meta.stage_profile.expect("installed recorder annotates RunMeta");
    assert_eq!(count(&profile, Stage::ExecRun), 1, "one umbrella span per entry point");
    // The umbrella nests every same-thread stage, so main-thread self
    // times must sum back to (within measurement slack of) compute_secs.
    let covered = profile.covered_secs();
    let compute = rep.meta.compute_secs;
    assert!(
        (covered - compute).abs() <= 0.05 * compute + 1e-3,
        "covered {covered}s vs compute {compute}s"
    );
}

#[test]
fn trace_propagates_to_the_pipeline_producer_thread() {
    obs::ensure_installed();
    let n = 96;
    let tile = 16;
    let o = oracle(n);
    let mut rng = Rng::new(5);
    let p = fastspsd::spsd::uniform_p(n, 8, &mut rng);
    let rep =
        exec::fast(&o, &p, FastConfig::uniform(24), &ExecPolicy::streamed(tile), &mut rng);
    let profile = rep.meta.stage_profile.expect("installed recorder annotates RunMeta");
    // Producer-side spans only reach this profile if the pool-spawned
    // producer inherited the caller's trace id across the thread hop.
    let produce = profile.get(Stage::PipelineProduce).expect("producer spans in the trace");
    assert!(produce.count >= (n / tile) as u64, "one produce span per tile");
    assert!(produce.total_secs > 0.0);
    assert_eq!(
        produce.main_self_secs, 0.0,
        "tiles are built on the pool thread, not the consumer thread"
    );
    // Both stall sides were measured, so the stall fractions exist.
    assert!(profile.producer_stall_fraction().is_some());
    assert!(profile.consumer_stall_fraction().is_some());
}

/// The ISSUE 7 acceptance path: a served Resident+spill+leverage request
/// carries a stage profile whose durations sum to the compute window
/// (±5%), and the service writes a loadable Chrome trace showing
/// admission → plan → pipeline → solve with residency tiles.
#[test]
fn served_resident_spill_leverage_request_is_fully_profiled() {
    let n = 96;
    let spill = fresh_dir("svc-spill");
    let traces = fresh_dir("svc-traces");
    let svc = ApproxService::new(
        Arc::new(oracle(n)) as Arc<dyn KernelOracle + Send + Sync>,
        ServiceConfig {
            workers: 1,
            spill_dir: Some(spill.clone()),
            trace_dir: Some(traces.clone()),
            ..Default::default()
        },
    );
    let (tx, rx) = mpsc::channel();
    svc.submit(
        ApproxRequest {
            id: 7,
            method: MethodSpec::Fast { s: 24, kind: SketchKind::Leverage { scaled: false } },
            c: 8,
            k: 3,
            seed: 7,
            policy: Some(ExecPolicy::resident(0).with_tile_rows(16)),
            precision: fastspsd::stream::Precision::F64,
            deadline: None,
        },
        tx,
    );
    svc.drain();
    let r = rx.iter().next().unwrap();
    assert!(r.error.is_none(), "{:?}", r.error);
    assert!(r.queue_wait_secs >= 0.0 && r.ladder_secs >= 0.0);
    let meta = r.meta.as_ref().unwrap();
    let profile = meta.stage_profile.as_ref().expect("traced service annotates RunMeta");

    // Lifecycle stages: queued, planned, executed, solved.
    assert_eq!(count(profile, Stage::AdmissionQueue), 1);
    assert!(count(profile, Stage::Plan) >= 1, "submit-side planning rides the trace");
    assert_eq!(count(profile, Stage::ExecRun), 1);
    assert!(count(profile, Stage::SolveEig) >= 1, "downstream eig is span-tagged");
    // Residency tiles: a zero RAM budget writes every tile through the
    // arena on pass 1 and reloads it from disk on pass 2 (leverage is
    // the two-pass sketch).
    assert!(count(profile, Stage::ResidencySpillWrite) > 0);
    assert!(count(profile, Stage::ResidencySpillRead) > 0);

    // The profile accounts for the whole compute window, not just a slice.
    let covered = profile.covered_secs();
    let compute = meta.compute_secs;
    assert!(
        (covered - compute).abs() <= 0.05 * compute + 1e-3,
        "covered {covered}s vs compute {compute}s"
    );

    // And the same records landed on disk as a loadable Chrome trace.
    let path = traces.join("trace-req-7.json");
    let text = std::fs::read_to_string(&path).expect("trace file written at reply time");
    let stages = sink::validate_chrome_json(&text).expect("well-formed trace_event JSON");
    for name in [
        "admission.queue",
        "plan",
        "exec.run",
        "pipeline.produce",
        "pipeline.fold",
        "residency.spill_write",
        "residency.spill_read",
        "solve.eig",
    ] {
        assert!(stages.contains(name), "chrome trace is missing {name}: {stages:?}");
    }
    let _ = std::fs::remove_dir_all(&spill);
    let _ = std::fs::remove_dir_all(&traces);
}
