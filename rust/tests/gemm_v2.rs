//! Integration tests for the GEMM v2 dense-compute layer: packed/pooled
//! products vs a naive reference across odd shapes, caller-provided-buffer
//! variants, SYRK, the fused RBF epilogue, and pooled-execution
//! determinism (set FASTSPSD_THREADS to pin the width externally).

use fastspsd::coordinator::engine::{rbf_cross_cpu, rbf_gram_cpu};
use fastspsd::linalg::{gemm, Matrix};
use fastspsd::util::Rng;

fn naive(a: &Matrix, b: &Matrix) -> Matrix {
    let mut c = Matrix::zeros(a.rows(), b.cols());
    for i in 0..a.rows() {
        for j in 0..b.cols() {
            let mut s = 0.0;
            for t in 0..a.cols() {
                s += a[(i, t)] * b[(t, j)];
            }
            c[(i, j)] = s;
        }
    }
    c
}

#[test]
fn gemm_matches_naive_across_odd_shapes() {
    let mut rng = Rng::new(0);
    let shapes = [
        (1usize, 1usize, 1usize),
        (1, 17, 1),
        (2, 1, 33),
        (3, 4, 5),
        (4, 4, 4),
        (5, 5, 5),
        (7, 31, 11),
        (16, 8, 24),
        (33, 9, 65),
        (63, 65, 64),
        (1, 100, 100),
        (100, 1, 100),
        (100, 100, 1),
    ];
    for &(m, k, n) in &shapes {
        let a = Matrix::randn(m, k, &mut rng);
        let b = Matrix::randn(k, n, &mut rng);
        let reference = naive(&a, &b);
        assert!(gemm::gemm(&a, &b).max_abs_diff(&reference) < 1e-10, "gemm {m}x{k}x{n}");

        let mut out = Matrix::from_fn(m, n, |_, _| f64::NAN);
        gemm::gemm_into(&a, &b, &mut out);
        assert!(out.max_abs_diff(&reference) < 1e-10, "gemm_into {m}x{k}x{n}");

        let mut out_tn = Matrix::from_fn(m, n, |_, _| f64::NAN);
        gemm::gemm_tn_into(&a.transpose(), &b, &mut out_tn);
        assert!(out_tn.max_abs_diff(&reference) < 1e-10, "gemm_tn_into {m}x{k}x{n}");

        let mut out_nt = Matrix::from_fn(m, n, |_, _| f64::NAN);
        gemm::gemm_nt_into(&a, &b.transpose(), &mut out_nt);
        assert!(out_nt.max_abs_diff(&reference) < 1e-10, "gemm_nt_into {m}x{k}x{n}");
    }
}

#[test]
fn syrk_matches_naive_across_odd_shapes() {
    let mut rng = Rng::new(1);
    for &(m, k) in &[(1usize, 1usize), (2, 3), (4, 4), (5, 1), (13, 29), (40, 7), (65, 64)] {
        let a = Matrix::randn(m, k, &mut rng);
        let reference = naive(&a, &a.transpose());
        let s = gemm::syrk_nt(&a);
        assert!(s.max_abs_diff(&reference) < 1e-10, "syrk_nt {m}x{k}");
        assert_eq!(s.max_abs_diff(&s.transpose()), 0.0, "syrk_nt symmetry {m}x{k}");
        let st = gemm::syrk_tn(&a.transpose());
        assert!(st.max_abs_diff(&reference) < 1e-10, "syrk_tn {m}x{k}");
    }
}

#[test]
fn symm_nt_matches_full_product() {
    // A W A^T with symmetric W — the prototype/fast-model U shape.
    let mut rng = Rng::new(2);
    let a = Matrix::randn(23, 11, &mut rng);
    let mut w = Matrix::randn(11, 11, &mut rng);
    w.symmetrize();
    let aw = a.matmul(&w);
    let full = naive(&aw, &a.transpose());
    let sym = gemm::symm_nt(&aw, &a);
    assert!(sym.max_abs_diff(&full) < 1e-9);
    assert_eq!(sym.max_abs_diff(&sym.transpose()), 0.0);
}

#[test]
fn fused_rbf_matches_reference_formula() {
    let mut rng = Rng::new(3);
    for &(m, n, d) in &[(1usize, 1usize, 1usize), (7, 5, 3), (40, 33, 16), (65, 64, 8)] {
        let x = Matrix::randn(m, d, &mut rng);
        let y = Matrix::randn(n, d, &mut rng);
        let gamma = 0.37;
        let k = rbf_cross_cpu(&x, &y, gamma);
        for i in 0..m {
            for j in 0..n {
                let d2: f64 = (0..d).map(|t| (x[(i, t)] - y[(j, t)]).powi(2)).sum();
                let expect = (-gamma * d2).exp();
                assert!(
                    (k[(i, j)] - expect).abs() < 1e-10,
                    "({i},{j}) of {m}x{n}x{d}: {} vs {expect}",
                    k[(i, j)]
                );
            }
        }
    }
}

#[test]
fn fused_rbf_gram_matches_cross() {
    let mut rng = Rng::new(4);
    let x = Matrix::randn(50, 6, &mut rng);
    let y = x.clone(); // distinct allocation forces the cross path
    let gram = rbf_gram_cpu(&x, 1.3);
    let cross = rbf_cross_cpu(&x, &y, 1.3);
    assert!(gram.max_abs_diff(&cross) < 1e-12);
    assert_eq!(gram.max_abs_diff(&gram.transpose()), 0.0);
}

#[test]
fn pooled_execution_is_deterministic() {
    // Above the parallel threshold, repeated runs and width-capped runs
    // must agree bit for bit (the summation order is width-invariant).
    let mut rng = Rng::new(5);
    let a = Matrix::randn(220, 140, &mut rng);
    let b = Matrix::randn(140, 190, &mut rng);
    let serial = gemm::gemm_with_threads(&a, &b, 1);
    let pooled = gemm::gemm(&a, &b);
    for (x, y) in serial.data().iter().zip(pooled.data()) {
        assert_eq!(x.to_bits(), y.to_bits());
    }
    for threads in [2, 3, 7] {
        let c = gemm::gemm_with_threads(&a, &b, threads);
        for (x, y) in serial.data().iter().zip(c.data()) {
            assert_eq!(x.to_bits(), y.to_bits(), "width {threads}");
        }
    }
    // and the fused kernel path is deterministic too
    let k1 = rbf_cross_cpu(&a, &b.transpose(), 0.2);
    let k2 = rbf_cross_cpu(&a, &b.transpose(), 0.2);
    assert_eq!(k1.max_abs_diff(&k2), 0.0);
}
