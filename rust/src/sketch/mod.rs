//! Matrix sketching (paper §3.1): the five sketching matrices of Lemma 2 /
//! Table 4 — uniform sampling, leverage-score sampling, Gaussian
//! projection, SRHT, and CountSketch.
//!
//! A sketch `S ∈ R^{n x s}` is represented by [`SketchOp`] so that `S^T A`
//! applies in the cheapest form for each family (row gather for column
//! selection, signed row-hash accumulation for CountSketch, fast
//! Walsh–Hadamard for SRHT) rather than by dense multiplication.

pub mod srht;

use crate::linalg::{eigh, svd_thin, Matrix};
use crate::util::Rng;

/// Which sketching family (and options) to use.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SketchKind {
    Uniform,
    /// Leverage-score sampling w.r.t. the row leverage scores of `C`;
    /// `scaled=false` is the paper's §4.5 stability trick.
    Leverage { scaled: bool },
    Gaussian,
    Srht,
    CountSketch,
}

impl SketchKind {
    pub fn name(self) -> &'static str {
        match self {
            SketchKind::Uniform => "uniform",
            SketchKind::Leverage { scaled: true } => "leverage",
            SketchKind::Leverage { scaled: false } => "leverage-unscaled",
            SketchKind::Gaussian => "gaussian",
            SketchKind::Srht => "srht",
            SketchKind::CountSketch => "countsketch",
        }
    }

    /// Column selection sketches only observe an `s x s` block of `K`
    /// (Table 4 #Entries column); projections need all of it.
    pub fn is_column_selection(self) -> bool {
        matches!(self, SketchKind::Uniform | SketchKind::Leverage { .. })
    }
}

/// An `n x s` sketching matrix in applicable form.
#[derive(Debug, Clone)]
pub enum SketchOp {
    /// Column-selection: column j of S is `scales[j] * e_{indices[j]}`.
    Select { n: usize, indices: Vec<usize>, scales: Vec<f64> },
    /// CountSketch: input row i maps to output row `cols[i]` with `signs[i]`.
    RowHash { n: usize, s: usize, cols: Vec<usize>, signs: Vec<f64> },
    /// Dense n x s (Gaussian).
    Dense(Matrix),
    /// SRHT: sign-flip rows, Walsh–Hadamard, then select `rows` (already
    /// scaled). `n_pad` is the power-of-two padding length.
    SrhtOp { n: usize, n_pad: usize, signs: Vec<f64>, rows: Vec<usize>, scale: f64 },
}

impl SketchOp {
    /// Number of input rows n.
    pub fn n(&self) -> usize {
        match self {
            SketchOp::Select { n, .. } => *n,
            SketchOp::RowHash { n, .. } => *n,
            SketchOp::Dense(m) => m.rows(),
            SketchOp::SrhtOp { n, .. } => *n,
        }
    }

    /// Sketch size s (columns of S).
    pub fn s(&self) -> usize {
        match self {
            SketchOp::Select { indices, .. } => indices.len(),
            SketchOp::RowHash { s, .. } => *s,
            SketchOp::Dense(m) => m.cols(),
            SketchOp::SrhtOp { rows, .. } => rows.len(),
        }
    }

    /// Selected index set (column-selection sketches only).
    pub fn indices(&self) -> Option<&[usize]> {
        match self {
            SketchOp::Select { indices, .. } => Some(indices),
            _ => None,
        }
    }

    /// `S^T A` (s x m) for `A` (n x m).
    pub fn apply_left(&self, a: &Matrix) -> Matrix {
        assert_eq!(a.rows(), self.n(), "sketch size mismatch");
        match self {
            SketchOp::Select { indices, scales, .. } => {
                let mut out = a.select_rows(indices);
                for (r, &sc) in scales.iter().enumerate() {
                    if sc != 1.0 {
                        for v in out.row_mut(r) {
                            *v *= sc;
                        }
                    }
                }
                out
            }
            SketchOp::RowHash { s, cols, signs, .. } => {
                let mut out = Matrix::zeros(*s, a.cols());
                for i in 0..a.rows() {
                    let dst_row = cols[i];
                    let sg = signs[i];
                    let src = a.row(i);
                    let dst = out.row_mut(dst_row);
                    for (d, &v) in dst.iter_mut().zip(src) {
                        *d += sg * v;
                    }
                }
                out
            }
            SketchOp::Dense(s_mat) => s_mat.tr_matmul(a),
            SketchOp::SrhtOp { n_pad, signs, rows, scale, .. } => {
                // (D A) padded to n_pad, FWHT per column, select rows.
                let mut work = Matrix::zeros(*n_pad, a.cols());
                for i in 0..a.rows() {
                    let sg = signs[i];
                    let src = a.row(i);
                    let dst = work.row_mut(i);
                    for (d, &v) in dst.iter_mut().zip(src) {
                        *d = sg * v;
                    }
                }
                srht::fwht_columns(&mut work);
                let mut out = work.select_rows(rows);
                for v in out.data_mut() {
                    *v *= *scale;
                }
                out
            }
        }
    }

    /// Streaming building block: fold the contribution of input rows
    /// `[r0, r0 + tile.rows())` into `acc += S[r0..r1, :]^T · tile`, where
    /// `tile` holds those rows of the streamed matrix. Summed over an
    /// ordered tile partition of `[0, n)` this reproduces
    /// [`apply_left`](Self::apply_left) — bit-identically for `Select` and
    /// `RowHash` (each destination element sees the same additions in the
    /// same order), and up to reduction reordering for `Dense` / `SrhtOp`
    /// (the SRHT path evaluates the selected Sylvester-Hadamard rows
    /// directly, `H[r][i] = (-1)^popcount(r & i)`, instead of a full FWHT).
    pub fn fold_rows(&self, r0: usize, tile: &Matrix, acc: &mut Matrix) {
        let r1 = r0 + tile.rows();
        assert!(r1 <= self.n(), "fold_rows: tile past the end of S");
        assert_eq!(
            (acc.rows(), acc.cols()),
            (self.s(), tile.cols()),
            "fold_rows: accumulator must be s x tile-width"
        );
        match self {
            SketchOp::Select { indices, scales, .. } => {
                for (pos, &i) in indices.iter().enumerate() {
                    if i >= r0 && i < r1 {
                        let sc = scales[pos];
                        let src = tile.row(i - r0);
                        let dst = acc.row_mut(pos);
                        for (d, &v) in dst.iter_mut().zip(src) {
                            *d += sc * v;
                        }
                    }
                }
            }
            SketchOp::RowHash { cols, signs, .. } => {
                for i in r0..r1 {
                    let sg = signs[i];
                    let src = tile.row(i - r0);
                    let dst = acc.row_mut(cols[i]);
                    for (d, &v) in dst.iter_mut().zip(src) {
                        *d += sg * v;
                    }
                }
            }
            SketchOp::Dense(s_mat) => {
                let sub = s_mat.block(r0, r1, 0, s_mat.cols());
                acc.axpy(1.0, &crate::linalg::gemm::gemm_tn(&sub, tile));
            }
            SketchOp::SrhtOp { signs, rows, scale, .. } => {
                // Padded rows (i >= n) are zero, so only real rows fold.
                for (out_r, &hr) in rows.iter().enumerate() {
                    let dst = acc.row_mut(out_r);
                    for i in r0..r1 {
                        let h = if (hr & i).count_ones() % 2 == 1 { -1.0 } else { 1.0 };
                        let w = *scale * h * signs[i];
                        let src = tile.row(i - r0);
                        for (d, &v) in dst.iter_mut().zip(src) {
                            *d += w * v;
                        }
                    }
                }
            }
        }
    }

    /// `S^T A S` for square symmetric `A` (n x n). Column selections gather
    /// the `s x s` sub-block directly (no transposes, no dense products);
    /// the projection families apply left twice.
    pub fn conjugate(&self, a: &Matrix) -> Matrix {
        assert_eq!(a.rows(), a.cols(), "conjugate needs a square matrix");
        if let SketchOp::Select { indices, scales, .. } = self {
            let s = indices.len();
            let mut out = Matrix::zeros(s, s);
            for (r, &i) in indices.iter().enumerate() {
                let src = a.row(i);
                let sr = scales[r];
                let dst = out.row_mut(r);
                for (c, &j) in indices.iter().enumerate() {
                    dst[c] = sr * scales[c] * src[j];
                }
            }
            return out;
        }
        let sta = self.apply_left(a); // s x n
        let stat = self.apply_left(&sta.transpose()); // s x s = S^T (S^T A)^T
        stat.transpose()
    }
}

/// Row leverage scores of `C`: `l_i = ||row i of U_C||^2` where `U_C` is an
/// orthonormal basis of col(C). Sums to rank(C).
pub fn leverage_scores(c: &Matrix) -> Vec<f64> {
    let f = svd_thin(c);
    let rank = f.rank(c.rows(), c.cols());
    (0..c.rows())
        .map(|i| (0..rank).map(|j| f.u[(i, j)] * f.u[(i, j)]).sum())
        .collect()
}

/// A whitening factor for (approximate) row-leverage scores, derived from a
/// `c x c` Gram — or Gram surrogate — of `C` instead of an `n x c`
/// orthogonal factor: with `G = C^T C = V Λ V^T` and
/// `W = V_+ Λ_+^{-1/2}` (the numerically-positive part),
/// `||C_i W||² = C_i G^+ C_i^T = l_i` — the row leverage scores, from
/// `O(c²)` state. This is what makes the streamed leverage family possible:
/// the Gram folds tile-by-tile while `C` streams
/// ([`LeverageFold`](crate::stream::LeverageFold)), and scoring a row needs
/// only that row plus `W` — never the `n x c` panel at once.
#[derive(Debug, Clone)]
pub struct LeverageEstimate {
    /// `r x c` whitening factor, stored transposed (`W^T = Λ_+^{-1/2}
    /// V_+^T`) so scoring walks both operands sequentially: each of the
    /// `r` factor rows is a contiguous slice dotted against the (also
    /// contiguous) input row — the per-row scoring pass is the streamed
    /// leverage hot path.
    pub whiten: Matrix,
    /// Numerical rank of the Gram (= `Σ_i l_i` in exact arithmetic), the
    /// normalizer for sampling probabilities.
    pub rank: f64,
}

impl LeverageEstimate {
    /// Leverage score of one row of `C`: `||W^T row||²`. Sequential slice
    /// dot products, so the result depends only on the row and the factor
    /// — not on how rows were grouped into tiles upstream.
    pub fn row_score(&self, row: &[f64]) -> f64 {
        debug_assert_eq!(row.len(), self.whiten.cols(), "row width != factor width");
        let mut total = 0.0;
        for j in 0..self.whiten.rows() {
            let mut dot = 0.0;
            for (a, b) in row.iter().zip(self.whiten.row(j)) {
                dot += a * b;
            }
            total += dot * dot;
        }
        total
    }

    /// Scores for every row of `c`.
    pub fn scores(&self, c: &Matrix) -> Vec<f64> {
        (0..c.rows()).map(|i| self.row_score(c.row(i))).collect()
    }
}

/// Build the leverage whitening factor from a symmetric PSD `c x c` Gram —
/// the exact `C^T C` or a sketched surrogate `C^T Ω Ω^T C`:
/// eigendecompose, drop the numerically-zero part (same relative tolerance
/// as the Woodbury solve), keep `W = V_+ Λ_+^{-1/2}`.
pub fn approx_leverage_from_gram(gram: &Matrix) -> LeverageEstimate {
    let c = gram.rows();
    assert_eq!(c, gram.cols(), "gram must be square");
    let e = eigh(gram);
    let lmax = e.values.first().copied().unwrap_or(0.0).max(0.0);
    let tol = lmax * c as f64 * f64::EPSILON;
    let keep: Vec<usize> = (0..e.values.len()).filter(|&i| e.values[i] > tol).collect();
    let whiten = Matrix::from_fn(keep.len(), c, |j, i| {
        e.vectors[(i, keep[j])] / e.values[keep[j]].sqrt()
    });
    LeverageEstimate { whiten, rank: keep.len() as f64 }
}

/// Uniform column selection, `s` distinct indices, scales `sqrt(n/s)`
/// (or 1.0 when `scaled` is false).
pub fn uniform(n: usize, s: usize, scaled: bool, rng: &mut Rng) -> SketchOp {
    let s = s.min(n);
    let indices = rng.sample_without_replacement(n, s);
    let scale = if scaled { (n as f64 / s as f64).sqrt() } else { 1.0 };
    SketchOp::Select { n, indices: indices.clone(), scales: vec![scale; indices.len()] }
}

/// Leverage-score sampling per Algorithm 2: index i enters S with
/// probability `min(1, s * l_i / rank)`, scaled by `1/sqrt(s l_i / rank)`
/// when `scaled` (the paper's §4.5 trick is `scaled=false`). Expected
/// number of columns is ~s.
pub fn leverage(scores: &[f64], s: usize, scaled: bool, rng: &mut Rng) -> SketchOp {
    let n = scores.len();
    let rank: f64 = scores.iter().sum();
    let mut indices = Vec::new();
    let mut scales = Vec::new();
    for (i, &l) in scores.iter().enumerate() {
        let p = if rank > 0.0 { (s as f64 * l / rank).min(1.0) } else { s as f64 / n as f64 };
        if rng.bernoulli(p) {
            indices.push(i);
            scales.push(if scaled && p > 0.0 { 1.0 / p.sqrt() } else { 1.0 });
        }
    }
    if indices.is_empty() {
        // degenerate: fall back to one uniform pick so S is non-empty
        indices.push(rng.usize_below(n));
        scales.push(1.0);
    }
    SketchOp::Select { n, indices, scales }
}

/// Force `P ⊂ S` (Corollary 5 / §4.5): union the sketch's index set with
/// `p_idx`, giving the forced indices probability 1 (scale 1).
pub fn with_forced_indices(op: SketchOp, p_idx: &[usize]) -> SketchOp {
    match op {
        SketchOp::Select { n, mut indices, mut scales } => {
            for &p in p_idx {
                if let Some(pos) = indices.iter().position(|&i| i == p) {
                    scales[pos] = 1.0; // probability forced to 1 => no scaling
                } else {
                    indices.push(p);
                    scales.push(1.0);
                }
            }
            // keep deterministic order
            let mut order: Vec<usize> = (0..indices.len()).collect();
            order.sort_by_key(|&i| indices[i]);
            SketchOp::Select {
                n,
                indices: order.iter().map(|&i| indices[i]).collect(),
                scales: order.iter().map(|&i| scales[i]).collect(),
            }
        }
        other => other,
    }
}

/// Gaussian projection `S = G / sqrt(s)`.
pub fn gaussian(n: usize, s: usize, rng: &mut Rng) -> SketchOp {
    let scale = 1.0 / (s as f64).sqrt();
    SketchOp::Dense(Matrix::from_fn(n, s, |_, _| rng.gaussian() * scale))
}

/// Subsampled randomized Hadamard transform.
pub fn srht_sketch(n: usize, s: usize, rng: &mut Rng) -> SketchOp {
    let n_pad = n.next_power_of_two();
    // More rows than the padded transform has cannot be sampled; the scale
    // must use the clamped count too, or E[S S^T] = (n_pad/s)·I ≠ I and
    // every downstream estimate (e.g. the sketched leverage surrogate) is
    // uniformly biased by s/n_pad.
    let s = s.min(n_pad);
    let signs: Vec<f64> = (0..n).map(|_| rng.sign()).collect();
    let rows = rng.sample_without_replacement(n_pad, s);
    // S^T x = sqrt(n_pad/s) * P^T (H x / sqrt(n_pad)) with D folded in.
    let scale = (n_pad as f64 / s as f64).sqrt() / (n_pad as f64).sqrt();
    SketchOp::SrhtOp { n, n_pad, signs, rows, scale }
}

/// CountSketch: each row hashed to one of `s` buckets with a random sign.
pub fn countsketch(n: usize, s: usize, rng: &mut Rng) -> SketchOp {
    let cols: Vec<usize> = (0..n).map(|_| rng.usize_below(s)).collect();
    let signs: Vec<f64> = (0..n).map(|_| rng.sign()).collect();
    SketchOp::RowHash { n, s, cols, signs }
}

/// Build a sketch of the requested kind. For `Leverage`, `c` supplies the
/// matrix whose row leverage scores drive the sampling.
pub fn build(kind: SketchKind, n: usize, s: usize, c: Option<&Matrix>, rng: &mut Rng) -> SketchOp {
    match kind {
        SketchKind::Uniform => uniform(n, s, true, rng),
        SketchKind::Leverage { scaled } => {
            let scores = leverage_scores(c.expect("leverage sketch needs C"));
            leverage(&scores, s, scaled, rng)
        }
        SketchKind::Gaussian => gaussian(n, s, rng),
        SketchKind::Srht => srht_sketch(n, s, rng),
        SketchKind::CountSketch => countsketch(n, s, rng),
    }
}

/// Materialize S as a dense n x s matrix (tests / small problems).
pub fn materialize(op: &SketchOp) -> Matrix {
    let n = op.n();
    let eye = Matrix::identity(n);
    op.apply_left(&eye).transpose()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn apply_left_matches_materialized_all_kinds() {
        let mut rng = Rng::new(0);
        let n = 24;
        let a = Matrix::randn(n, 5, &mut rng);
        for kind in [
            SketchKind::Uniform,
            SketchKind::Leverage { scaled: true },
            SketchKind::Leverage { scaled: false },
            SketchKind::Gaussian,
            SketchKind::Srht,
            SketchKind::CountSketch,
        ] {
            let c = Matrix::randn(n, 4, &mut rng);
            let op = build(kind, n, 8, Some(&c), &mut rng);
            let sta = op.apply_left(&a);
            let s_dense = materialize(&op);
            let expect = s_dense.tr_matmul(&a);
            assert!(
                sta.max_abs_diff(&expect) < 1e-9,
                "{}: apply_left != S^T A",
                kind.name()
            );
            assert_eq!(sta.rows(), op.s());
        }
    }

    #[test]
    fn fold_rows_over_partition_matches_apply_left() {
        let mut rng = Rng::new(20);
        let n = 30;
        let a = Matrix::randn(n, 4, &mut rng);
        for kind in [
            SketchKind::Uniform,
            SketchKind::Leverage { scaled: false },
            SketchKind::Gaussian,
            SketchKind::Srht,
            SketchKind::CountSketch,
        ] {
            let c = Matrix::randn(n, 3, &mut rng);
            let op = build(kind, n, 9, Some(&c), &mut rng);
            let direct = op.apply_left(&a);
            // fold over an uneven partition: 11 + 11 + 8 rows
            let mut acc = Matrix::zeros(op.s(), 4);
            let mut r0 = 0;
            for height in [11usize, 11, 8] {
                let tile = a.block(r0, r0 + height, 0, 4);
                op.fold_rows(r0, &tile, &mut acc);
                r0 += height;
            }
            let tol = match kind {
                SketchKind::Gaussian | SketchKind::Srht => 1e-12 * direct.fro_norm().max(1.0),
                _ => 0.0, // gather/hash paths are bit-identical
            };
            assert!(
                acc.max_abs_diff(&direct) <= tol,
                "{}: fold_rows != apply_left",
                kind.name()
            );
        }
    }

    /// Dense `n x s` sketch built *by definition* from the op's fields —
    /// independent of `apply_left` (unlike [`materialize`]) so a bug shared
    /// by `apply_left` and `fold_rows` cannot self-certify. The SRHT arm
    /// intentionally returns `None`: its independent reference is the FWHT
    /// path inside `apply_left`, which `fold_rows`'s direct
    /// Sylvester-Hadamard row evaluation never touches.
    fn dense_by_definition(op: &SketchOp) -> Option<Matrix> {
        match op {
            SketchOp::Select { n, indices, scales } => Some(Matrix::from_fn(
                *n,
                indices.len(),
                |i, j| if indices[j] == i { scales[j] } else { 0.0 },
            )),
            SketchOp::RowHash { n, s, cols, signs } => Some(Matrix::from_fn(
                *n,
                *s,
                |i, j| if cols[i] == j { signs[i] } else { 0.0 },
            )),
            SketchOp::Dense(m) => Some(m.clone()),
            SketchOp::SrhtOp { .. } => None,
        }
    }

    #[test]
    fn fold_rows_pinned_against_materialized_stc_every_family() {
        // The PR-2 static review caught one operator-precedence bug in the
        // SRHT fold; this pins every `fold_rows` family against an
        // independently-materialized `S^T A` (by-definition dense S where
        // possible, the FWHT path for SRHT) over single-row, ragged and
        // whole-matrix partitions, with n both a power of two and not.
        let mut rng = Rng::new(40);
        for n in [32usize, 45] {
            let a = Matrix::randn(n, 5, &mut rng);
            for kind in [
                SketchKind::Uniform,
                SketchKind::Leverage { scaled: true },
                SketchKind::Leverage { scaled: false },
                SketchKind::Gaussian,
                SketchKind::Srht,
                SketchKind::CountSketch,
            ] {
                let basis = Matrix::randn(n, 3, &mut rng).matmul(&Matrix::randn(3, 6, &mut rng));
                let op = build(kind, n, 10, Some(&basis), &mut rng);
                let s_dense = match dense_by_definition(&op) {
                    Some(s) => s,
                    None => materialize(&op), // SRHT: FWHT reference
                };
                let expect = s_dense.tr_matmul(&a);
                for tile in [1usize, 7, n] {
                    let mut acc = Matrix::zeros(op.s(), 5);
                    let mut r0 = 0;
                    while r0 < n {
                        let r1 = (r0 + tile).min(n);
                        op.fold_rows(r0, &a.block(r0, r1, 0, 5), &mut acc);
                        r0 = r1;
                    }
                    let tol = 1e-10 * expect.fro_norm().max(1.0);
                    assert!(
                        acc.max_abs_diff(&expect) <= tol,
                        "{} n={n} tile={tile}: fold_rows != materialized S^T A",
                        kind.name()
                    );
                }
            }
        }
    }

    #[test]
    fn gram_estimate_matches_exact_scores() {
        // l_i = C_i (C^T C)^+ C_i^T must agree with the SVD definition.
        let mut rng = Rng::new(41);
        let c = Matrix::randn(50, 3, &mut rng).matmul(&Matrix::randn(3, 7, &mut rng));
        let exact = leverage_scores(&c);
        let est = approx_leverage_from_gram(&c.gram_tn());
        assert!((est.rank - 3.0).abs() < 1e-6, "rank {} != 3", est.rank);
        let approx = est.scores(&c);
        for (i, (a, e)) in approx.iter().zip(&exact).enumerate() {
            assert!((a - e).abs() < 1e-8, "row {i}: gram {a} vs svd {e}");
        }
    }

    #[test]
    fn gram_estimate_handles_zero_matrix() {
        let est = approx_leverage_from_gram(&Matrix::zeros(4, 4));
        assert_eq!(est.rank, 0.0);
        assert_eq!(est.whiten.rows(), 0, "no kept directions");
        assert_eq!(est.row_score(&[1.0, 2.0, 3.0, 4.0]), 0.0);
    }

    #[test]
    fn conjugate_is_symmetric_for_symmetric_input() {
        let mut rng = Rng::new(1);
        let g = Matrix::randn(16, 16, &mut rng);
        let k = g.matmul_tr(&g);
        let op = uniform(16, 6, true, &mut rng);
        let sks = op.conjugate(&k);
        assert_eq!((sks.rows(), sks.cols()), (6, 6));
        assert!(sks.max_abs_diff(&sks.transpose()) < 1e-9);
    }

    #[test]
    fn conjugate_select_matches_dense_path() {
        let mut rng = Rng::new(10);
        let g = Matrix::randn(18, 18, &mut rng);
        let k = g.matmul_tr(&g);
        let op = uniform(18, 7, true, &mut rng);
        let fast = op.conjugate(&k);
        let s_dense = materialize(&op);
        let expect = s_dense.tr_matmul(&k).matmul(&s_dense);
        assert!(fast.max_abs_diff(&expect) < 1e-9);
    }

    #[test]
    fn leverage_scores_sum_to_rank() {
        let mut rng = Rng::new(2);
        let b = Matrix::randn(30, 3, &mut rng);
        let c = b.matmul(&Matrix::randn(3, 6, &mut rng)); // rank 3
        let l = leverage_scores(&c);
        let sum: f64 = l.iter().sum();
        assert!((sum - 3.0).abs() < 1e-8, "sum={sum}");
        assert!(l.iter().all(|&x| (-1e-12..=1.0 + 1e-9).contains(&x)));
    }

    #[test]
    fn uniform_scaling_preserves_expected_gram() {
        // E[S S^T] = I  =>  E[x^T S S^T x] = ||x||^2 (sanity via averaging)
        let mut rng = Rng::new(3);
        let n = 40;
        let x = Matrix::randn(n, 1, &mut rng);
        let mut acc = 0.0;
        let trials = 3000;
        for _ in 0..trials {
            let op = uniform(n, 10, true, &mut rng);
            let sx = op.apply_left(&x);
            acc += sx.fro_norm_sq();
        }
        let expect = x.fro_norm_sq();
        let mean = acc / trials as f64;
        assert!((mean - expect).abs() / expect < 0.1, "mean={mean} expect={expect}");
    }

    #[test]
    fn gaussian_preserves_norms_on_average() {
        let mut rng = Rng::new(4);
        let n = 30;
        let x = Matrix::randn(n, 1, &mut rng);
        let mut acc = 0.0;
        let trials = 800;
        for _ in 0..trials {
            let op = gaussian(n, 20, &mut rng);
            acc += op.apply_left(&x).fro_norm_sq();
        }
        let expect = x.fro_norm_sq();
        assert!((acc / trials as f64 - expect).abs() / expect < 0.15);
    }

    #[test]
    fn countsketch_unbiased_gram() {
        let mut rng = Rng::new(5);
        let n = 25;
        let x = Matrix::randn(n, 1, &mut rng);
        let mut acc = 0.0;
        let trials = 2000;
        for _ in 0..trials {
            let op = countsketch(n, 12, &mut rng);
            acc += op.apply_left(&x).fro_norm_sq();
        }
        let expect = x.fro_norm_sq();
        assert!((acc / trials as f64 - expect).abs() / expect < 0.1);
    }

    #[test]
    fn srht_isometry_on_average() {
        let mut rng = Rng::new(6);
        let n = 24; // padded to 32
        let x = Matrix::randn(n, 1, &mut rng);
        let mut acc = 0.0;
        let trials = 1500;
        for _ in 0..trials {
            let op = srht_sketch(n, 12, &mut rng);
            acc += op.apply_left(&x).fro_norm_sq();
        }
        let expect = x.fro_norm_sq();
        assert!((acc / trials as f64 - expect).abs() / expect < 0.1);
    }

    #[test]
    fn srht_oversubscribed_s_clamps_rows_and_scale_together() {
        // s > n_pad: only n_pad rows exist, and the scale must reflect the
        // clamped count — with all rows kept the transform is orthogonal,
        // so S^T S (= C^T Ω Ω^T C at C = I) must be the identity, not
        // (n_pad/s)·I.
        let mut rng = Rng::new(30);
        let n = 20; // pads to 32
        let op = srht_sketch(n, 100, &mut rng);
        assert_eq!(op.s(), 32, "row count clamps to n_pad");
        let sta = op.apply_left(&Matrix::identity(n)); // 32 x 20 = S^T
        let gram = sta.gram_tn(); // S S^T... = Σ_r S^T-rows outer = I_n
        assert!(
            gram.max_abs_diff(&Matrix::identity(n)) < 1e-10,
            "full-row SRHT must be an exact isometry"
        );
    }

    #[test]
    fn forced_indices_union() {
        let mut rng = Rng::new(7);
        let op = uniform(20, 5, false, &mut rng);
        let forced = vec![0usize, 19];
        let op2 = with_forced_indices(op, &forced);
        let idx = op2.indices().unwrap();
        assert!(idx.contains(&0) && idx.contains(&19));
        // sorted, unique
        let mut sorted = idx.to_vec();
        sorted.dedup();
        assert_eq!(sorted.len(), idx.len());
        assert!(idx.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn leverage_unscaled_has_unit_scales() {
        let mut rng = Rng::new(8);
        let c = Matrix::randn(30, 4, &mut rng);
        let scores = leverage_scores(&c);
        let op = leverage(&scores, 10, false, &mut rng);
        if let SketchOp::Select { scales, .. } = &op {
            assert!(scales.iter().all(|&s| s == 1.0));
        } else {
            panic!("leverage must be Select");
        }
    }

    #[test]
    fn subspace_embedding_property_gaussian() {
        // Property 1 sanity: singular values of S^T U near 1 for orthonormal U.
        let mut rng = Rng::new(9);
        let n = 60;
        let k = 3;
        let q = crate::linalg::qr::qr_thin(&Matrix::randn(n, k, &mut rng)).q;
        let op = gaussian(n, 50, &mut rng);
        let stu = op.apply_left(&q);
        let f = svd_thin(&stu);
        for &s in &f.s {
            assert!((s - 1.0).abs() < 0.6, "singular value {s} too far from 1");
        }
    }
}
