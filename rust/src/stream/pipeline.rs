//! The bounded double-buffered tile scheduler.
//!
//! `run_pipeline` splits a [`TileSource`](super::TileSource) into
//! `tile_rows`-high tiles, computes them on the global thread pool, and
//! feeds each tile to every consumer *in row order* on the caller's
//! thread. The producer runs at most `queue_depth` tiles ahead (a bounded
//! `Mutex<VecDeque>` + two condvars), so peak live tiles are
//! `queue_depth + 2` (one being produced, `queue_depth` queued, one being
//! folded) regardless of `n` — this is what turns the paper's entry-count
//! accounting into a memory bound.
//!
//! Consumption order is deterministic (ascending `r0`), so gather-style
//! consumers are bit-identical to the materialized path and
//! accumulation-style consumers differ only by reduction grouping.
//!
//! Both sides are span-traced ([`obs`]): tile builds as
//! `pipeline.produce`, folds as `pipeline.fold`, and the time each side
//! spends blocked on the bounded channel as `pipeline.produce.stall` /
//! `pipeline.fold.stall` — the stall fractions that answer whether a run
//! is oracle-bound or fold-bound (EXPERIMENTS.md §Observability).

use super::{TileConsumer, TileSource};
use crate::linalg::{Precision, Tile};
use crate::obs::{self, Stage};
use crate::pool;
use crate::testkit::faults::{self, FaultPlan, FaultPoint};
use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};

struct ChanState {
    buf: VecDeque<(usize, Tile)>,
    /// Producer finished pushing every tile.
    tx_done: bool,
    /// Consumer stopped (normally or by unwinding); producer must bail out
    /// rather than block on a queue nobody drains.
    rx_dead: bool,
}

/// Bounded SPSC tile queue.
struct Chan {
    state: Mutex<ChanState>,
    nonempty: Condvar,
    nonfull: Condvar,
    capacity: usize,
}

impl Chan {
    fn new(capacity: usize) -> Self {
        Chan {
            state: Mutex::new(ChanState {
                buf: VecDeque::with_capacity(capacity),
                tx_done: false,
                rx_dead: false,
            }),
            nonempty: Condvar::new(),
            nonfull: Condvar::new(),
            capacity,
        }
    }

    /// Blocks while the queue is full. Returns false when the receiver is
    /// gone (the producer should stop computing tiles).
    fn push(&self, item: (usize, Tile)) -> bool {
        let mut st = self.state.lock().unwrap();
        while st.buf.len() >= self.capacity && !st.rx_dead {
            st = self.nonfull.wait(st).unwrap();
        }
        if st.rx_dead {
            return false;
        }
        st.buf.push_back(item);
        drop(st);
        self.nonempty.notify_one();
        true
    }

    fn close_tx(&self) {
        self.state.lock().unwrap().tx_done = true;
        self.nonempty.notify_all();
    }

    fn close_rx(&self) {
        self.state.lock().unwrap().rx_dead = true;
        self.nonfull.notify_all();
    }

    /// Blocks until a tile is available; `None` once the producer is done
    /// and the queue is drained.
    fn pop(&self) -> Option<(usize, Tile)> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(item) = st.buf.pop_front() {
                drop(st);
                self.nonfull.notify_one();
                return Some(item);
            }
            if st.tx_done {
                return None;
            }
            st = self.nonempty.wait(st).unwrap();
        }
    }
}

/// Marks the receiver dead on drop so a panicking consumer can never
/// deadlock the producer against a full queue.
struct RxGuard<'a>(&'a Chan);

impl Drop for RxGuard<'_> {
    fn drop(&mut self) {
        self.0.close_rx();
    }
}

/// Marks the producer done on drop — including when `TileSource::tile`
/// panics (the pool catches job panics without rethrowing, so without this
/// guard the consumer would wait on `nonempty` forever).
struct TxGuard<'a>(&'a Chan);

impl Drop for TxGuard<'_> {
    fn drop(&mut self) {
        self.0.close_tx();
    }
}

/// Stream `src` through `consumers` in `tile_rows`-high f64 tiles — the
/// historical surface, an exact alias of
/// [`run_pipeline_prec`]`(.., Precision::F64, ..)`; every seam it crosses
/// is bit-identical to the pre-typed-tile pipeline.
pub fn run_pipeline(
    src: &dyn TileSource,
    tile_rows: usize,
    queue_depth: usize,
    consumers: &mut [&mut dyn TileConsumer],
) {
    run_pipeline_prec(src, tile_rows, queue_depth, Precision::F64, consumers);
}

/// Stream `src` through `consumers` in `tile_rows`-high tiles of the
/// requested element width.
///
/// When one tile covers every row the pipeline is skipped entirely: the
/// tile is computed inline and fed once (the materialized fallback). A
/// `queue_depth` of 1 still overlaps producer and consumer; 2 (the
/// default) double-buffers. The width changes only what the channel
/// carries: consumption order, fault seams, and span accounting are
/// identical in both precisions, and every consumer folds into f64 state
/// regardless of the tile type.
pub fn run_pipeline_prec(
    src: &dyn TileSource,
    tile_rows: usize,
    queue_depth: usize,
    precision: Precision,
    consumers: &mut [&mut dyn TileConsumer],
) {
    let n = src.rows();
    if n == 0 {
        return;
    }
    // Chaos seam: a globally armed FaultPlan can schedule a panic before
    // the fold of the Nth tile (captured once per pipeline run).
    let faults = faults::current();
    let t = tile_rows.clamp(1, n);
    if t >= n {
        let tile = {
            let _s = obs::span(Stage::PipelineProduce);
            src.tile_elem(0, n, precision)
        };
        trip_fold_fault(&faults, 0);
        let _s = obs::span(Stage::PipelineFold);
        for c in consumers.iter_mut() {
            c.consume_tile(0, &tile);
        }
        return;
    }
    // Forward the caller's trace id into the pool-spawned producer so
    // both sides of the pipeline land in the same request timeline.
    let trace = obs::current_trace_raw();
    let chan = Chan::new(queue_depth.max(1));
    let chan_ref = &chan;
    pool::global().scoped(|scope| {
        scope.spawn(move || {
            let _trace = obs::trace_scope(trace);
            let _done = TxGuard(chan_ref);
            let mut r0 = 0;
            while r0 < n {
                let r1 = (r0 + t).min(n);
                let tile = {
                    let _s = obs::span(Stage::PipelineProduce);
                    src.tile_elem(r0, r1, precision)
                };
                let pushed = {
                    let _s = obs::span(Stage::PipelineProduceStall);
                    chan_ref.push((r0, tile))
                };
                if !pushed {
                    return; // receiver gone — stop producing
                }
                r0 = r1;
            }
        });
        let _guard = RxGuard(chan_ref);
        loop {
            let item = {
                let _s = obs::span(Stage::PipelineFoldStall);
                chan_ref.pop()
            };
            let Some((r0, tile)) = item else { break };
            trip_fold_fault(&faults, r0);
            let _s = obs::span(Stage::PipelineFold);
            for c in consumers.iter_mut() {
                c.consume_tile(r0, &tile);
            }
        }
    });
}

/// Panic on the fold the armed plan scheduled (counted once per tile, on
/// the consumer thread, so the unwind exercises the RxGuard exactly like
/// a real consumer bug would).
fn trip_fold_fault(faults: &Option<std::sync::Arc<FaultPlan>>, r0: usize) {
    if let Some(plan) = faults {
        if plan.should_fail(FaultPoint::ConsumerFold) {
            panic!("injected fault: consumer fold at r0={r0}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;
    use crate::stream::{CollectConsumer, MatrixSource, TileSource};
    use crate::util::Rng;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn visits_every_row_once_in_order_for_awkward_tile_sizes() {
        let mut rng = Rng::new(0);
        let a = Matrix::randn(29, 3, &mut rng);
        for tile in [1usize, 2, 7, 13, 28, 29, 64] {
            struct Probe {
                next: usize,
            }
            impl TileConsumer for Probe {
                fn consume(&mut self, r0: usize, tile: &Matrix) {
                    assert_eq!(r0, self.next, "tiles must arrive in order");
                    assert!(tile.rows() > 0);
                    self.next = r0 + tile.rows();
                }
            }
            let src = MatrixSource::new(&a);
            let mut probe = Probe { next: 0 };
            let mut collect = CollectConsumer::new(29, 3);
            run_pipeline(&src, tile, 2, &mut [&mut probe, &mut collect]);
            assert_eq!(probe.next, 29, "tile={tile}");
            assert_eq!(collect.into_matrix().max_abs_diff(&a), 0.0, "tile={tile}");
        }
    }

    #[test]
    fn producer_stays_within_queue_depth() {
        // A source that counts outstanding tiles: produced - consumed must
        // never exceed depth + 2 (one in production, depth queued, one
        // being folded).
        struct CountingSource {
            produced: AtomicUsize,
        }
        impl TileSource for CountingSource {
            fn rows(&self) -> usize {
                64
            }
            fn cols(&self) -> usize {
                2
            }
            fn tile(&self, r0: usize, r1: usize) -> Matrix {
                self.produced.fetch_add(1, Ordering::SeqCst);
                Matrix::from_fn(r1 - r0, 2, |i, j| (r0 + i + j) as f64)
            }
        }
        struct SlowConsumer<'a> {
            src: &'a CountingSource,
            consumed: usize,
            max_outstanding: usize,
        }
        impl TileConsumer for SlowConsumer<'_> {
            fn consume(&mut self, _r0: usize, _tile: &Matrix) {
                std::thread::sleep(std::time::Duration::from_millis(2));
                let produced = self.src.produced.load(Ordering::SeqCst);
                self.max_outstanding = self.max_outstanding.max(produced - self.consumed);
                self.consumed += 1;
            }
        }
        for depth in [1usize, 2, 3] {
            let src = CountingSource { produced: AtomicUsize::new(0) };
            let mut cons = SlowConsumer { src: &src, consumed: 0, max_outstanding: 0 };
            run_pipeline(&src, 4, depth, &mut [&mut cons]);
            assert_eq!(cons.consumed, 16);
            assert!(
                cons.max_outstanding <= depth + 2,
                "depth {depth}: {} tiles outstanding",
                cons.max_outstanding
            );
        }
    }

    #[test]
    fn f32_stream_is_tile_size_invariant_for_gathers() {
        // Collect-style consumers see the same demoted values whatever the
        // tiling: the per-row demotion is independent of tile boundaries.
        let mut rng = Rng::new(3);
        let a = Matrix::randn(29, 3, &mut rng);
        let src = MatrixSource::new(&a);
        let mut reference = CollectConsumer::new(29, 3);
        run_pipeline_prec(&src, 29, 2, Precision::F32, &mut [&mut reference]);
        let reference = reference.into_matrix();
        assert_eq!(reference.max_abs_diff(&a.demote().promote()), 0.0);
        for tile in [1usize, 2, 7, 13, 28] {
            let mut collect = CollectConsumer::new(29, 3);
            run_pipeline_prec(&src, tile, 2, Precision::F32, &mut [&mut collect]);
            assert_eq!(collect.into_matrix().max_abs_diff(&reference), 0.0, "tile={tile}");
        }
    }

    #[test]
    fn empty_source_is_a_noop() {
        let a = Matrix::zeros(0, 4);
        let src = MatrixSource::new(&a);
        struct MustNotRun;
        impl TileConsumer for MustNotRun {
            fn consume(&mut self, _: usize, _: &Matrix) {
                panic!("no tiles expected");
            }
        }
        run_pipeline(&src, 8, 2, &mut [&mut MustNotRun]);
    }

    #[test]
    fn panicking_producer_does_not_deadlock_consumer() {
        // A TileSource that panics mid-stream: the TxGuard must close the
        // channel so the consumer unblocks, and ThreadPool::scoped must
        // re-raise the job panic so the truncated stream never escapes
        // silently.
        struct BombSource;
        impl TileSource for BombSource {
            fn rows(&self) -> usize {
                32
            }
            fn cols(&self) -> usize {
                2
            }
            fn tile(&self, r0: usize, r1: usize) -> Matrix {
                if r0 >= 8 {
                    panic!("producer bomb");
                }
                Matrix::zeros(r1 - r0, 2)
            }
        }
        struct Sink;
        impl TileConsumer for Sink {
            fn consume(&mut self, _: usize, _: &Matrix) {}
        }
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            run_pipeline(&BombSource, 4, 2, &mut [&mut Sink]);
        }));
        assert!(result.is_err(), "producer panic must propagate, not hang or vanish");
    }

    #[test]
    fn panicking_consumer_does_not_deadlock_producer() {
        let mut rng = Rng::new(1);
        let a = Matrix::randn(128, 2, &mut rng);
        let src = MatrixSource::new(&a);
        struct Bomb {
            seen: usize,
        }
        impl TileConsumer for Bomb {
            fn consume(&mut self, _: usize, _: &Matrix) {
                self.seen += 1;
                if self.seen == 2 {
                    panic!("consumer bomb");
                }
            }
        }
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut bomb = Bomb { seen: 0 };
            run_pipeline(&src, 4, 1, &mut [&mut bomb]);
        }));
        assert!(result.is_err(), "panic must propagate, not hang");
    }
}
