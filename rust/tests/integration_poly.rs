//! PJRT integration for the polynomial-kernel artifact (skips without
//! artifacts).

use fastspsd::coordinator::engine::{poly_cross_cpu, KernelEngine};
use fastspsd::linalg::Matrix;
use fastspsd::runtime::{default_artifact_dir, RuntimeHandle};
use fastspsd::util::Rng;

#[test]
fn poly_pjrt_matches_cpu() {
    let rt = match RuntimeHandle::spawn(default_artifact_dir()) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("SKIP (no artifacts): {e:#}");
            return;
        }
    };
    if rt.manifest().find("poly_block_256x256x16").is_none() {
        eprintln!("SKIP (artifacts predate poly_block — run `make artifacts`)");
        return;
    }
    let engine = KernelEngine::pjrt(rt);
    let mut rng = Rng::new(0);
    for &(m, n, d) in &[(256usize, 256usize, 16usize), (300, 280, 10)] {
        let x = Matrix::randn(m, d, &mut rng).scale(0.3);
        let y = Matrix::randn(n, d, &mut rng).scale(0.3);
        let fast = engine.poly_cross(&x, &y, 0.7, 1.0, 2.0);
        let slow = poly_cross_cpu(&x, &y, 0.7, 1.0, 2.0);
        assert!(
            fast.max_abs_diff(&slow) < 1e-4,
            "({m},{n},{d}) diff={}",
            fast.max_abs_diff(&slow)
        );
    }
    assert!(engine.pjrt_tiles.load(std::sync::atomic::Ordering::Relaxed) > 0);
}
