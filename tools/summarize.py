#!/usr/bin/env python3
"""Summarize out/*.csv experiment results into markdown tables.

Usage: python tools/summarize.py [out_dir]

Prints one compact markdown table per figure/table CSV, averaging over
repetitions, shaped like the series the paper plots.
"""

import csv
import os
import sys
from collections import defaultdict


def load(path):
    with open(path) as f:
        return list(csv.DictReader(f))


def mean(xs):
    xs = list(xs)
    return sum(xs) / len(xs) if xs else float("nan")


def fig3(rows, name):
    print(f"\n### {name}: rel error ‖K−CUCᵀ‖²/‖K‖² (mean over reps)\n")
    datasets = sorted({r["dataset"] for r in rows})
    for ds in datasets:
        for eta in sorted({r["eta"] for r in rows if r["dataset"] == ds}):
            sel = [r for r in rows if r["dataset"] == ds and r["eta"] == eta]
            n = sel[0]["n"]
            c = sel[0]["c"]
            base = {}
            for m in ("nystrom", "prototype"):
                base[m] = mean(float(r["rel_err"]) for r in sel if r["method"] == m)
            print(f"**{ds}** (n={n}, c={c}, η={eta}): nystrom={base['nystrom']:.3e}  prototype={base['prototype']:.3e}")
            print("| s/n | fast[uniform] | fast[leverage] |")
            print("|---|---|---|")
            svals = sorted({float(r["s_over_n"]) for r in sel if r["method"].startswith("fast")})
            for s in svals:
                u = mean(
                    float(r["rel_err"])
                    for r in sel
                    if r["method"] == "fast[uniform]" and abs(float(r["s_over_n"]) - s) < 1e-9
                )
                l = mean(
                    float(r["rel_err"])
                    for r in sel
                    if r["method"] == "fast[leverage-unscaled]"
                    and abs(float(r["s_over_n"]) - s) < 1e-9
                )
                print(f"| {s:.3f} | {u:.3e} | {l:.3e} |")
            print()


def generic_by(rows, name, group_keys, series_key, value_key, extra=()):
    print(f"\n### {name}: {value_key} by {series_key} (mean over reps)\n")
    groups = defaultdict(list)
    for r in rows:
        groups[tuple(r[k] for k in group_keys)].append(r)
    methods = sorted({r[series_key] for r in rows})
    header = list(group_keys) + methods + list(extra)
    print("| " + " | ".join(header) + " |")
    print("|" + "---|" * len(header))
    for key in sorted(groups, key=lambda t: tuple((len(x), x) for x in t)):
        sel = groups[key]
        cells = list(key)
        for m in methods:
            v = mean(float(r[value_key]) for r in sel if r[series_key] == m)
            cells.append(f"{v:.3e}" if v == v else "—")
        for e in extra:
            cells.append(sel[0].get(e, ""))
        print("| " + " | ".join(str(c) for c in cells) + " |")


def main():
    out = sys.argv[1] if len(sys.argv) > 1 else "out"
    handlers = {
        "fig3.csv": lambda r: fig3(r, "Fig 3"),
        "fig4.csv": lambda r: fig3(r, "Fig 4"),
        "fig2.csv": lambda r: generic_by(r, "Fig 2", ["setting", "s_c", "s_r"], "setting", "rel_err"),
        "fig5_6.csv": lambda r: generic_by(r, "Fig 5/6", ["dataset", "c"], "method", "misalignment"),
        "fig7_8.csv": lambda r: generic_by(r, "Fig 7/8 (k=3)", ["dataset", "c"], "method", "class_err"),
        "fig9_10.csv": lambda r: generic_by(r, "Fig 9/10 (k=10)", ["dataset", "c"], "method", "class_err"),
        "fig11_12.csv": lambda r: generic_by(r, "Fig 11/12", ["dataset", "c"], "method", "nmi"),
        "table3.csv": lambda r: generic_by(r, "Table 3 (time)", ["n", "c"], "method", "u_secs"),
        "table4.csv": lambda r: generic_by(r, "Table 4 (time)", ["n", "c", "s"], "sketch", "u_secs"),
        "table5.csv": lambda r: generic_by(r, "Table 5 (time)", ["m", "n"], "method", "u_secs"),
        "ablate_p_in_s.csv": lambda r: generic_by(r, "Ablation P⊂S", ["s"], "force_p", "rel_err_mean"),
        "ablate_leverage_scaling.csv": lambda r: generic_by(
            r, "Ablation leverage scaling", ["s"], "scaled", "rel_err_max"
        ),
        "ablate_engine_fill.csv": lambda r: generic_by(
            r, "Ablation engine fill", ["m"], "d", "pjrt_secs", extra=("cpu_secs",)
        ),
    }
    for fname, fn in handlers.items():
        path = os.path.join(out, fname)
        if os.path.exists(path):
            rows = load(path)
            if rows:
                fn(rows)


if __name__ == "__main__":
    main()
