//! Figure-2 style CUR image reconstruction (c = r = 100) comparing the
//! optimal U, Drineas-08 U, and the fast U at increasing sketch sizes.
//! Writes PGM files under out/ so the reconstructions can be eyeballed.
//!
//! ```sh
//! cargo run --release --example cur_image -- --rows 480 --cols 292
//! ```

use fastspsd::cli::Args;
use fastspsd::figures::{cur_fig, Ctx};

fn main() {
    let mut argv: Vec<String> = std::env::args().skip(1).collect();
    argv.insert(0, "fig2".into());
    argv.push("--pgm".into());
    let args = Args::parse(argv);
    let ctx = Ctx::from_args(&args);
    cur_fig::fig2(&ctx, &args);
}
