//! Spectral shifting extension (paper §3.2.2, after Wang et al. 2014).
//!
//! The paper notes that the spectral-shifting strategy "can be used for
//! any kernel approximation model beyond the prototype model" — including
//! the fast model built here. The shifted approximation is
//!
//! ```text
//! K ≈ C U C^T + δ (I_n - U_C U_C^T),   δ = (tr(K) - tr(C U C^T)) / (n - rank(C))
//! ```
//!
//! i.e. the residual trace mass is spread over the orthogonal complement,
//! which helps when the kernel's tail spectrum is flat (small σ / small η).
//! For an RBF kernel `tr(K) = n` exactly, so the shift needs **no extra
//! kernel entries**.

use super::SpsdApprox;
use crate::linalg::{gemm, qr, solve, Matrix};

/// A spectrally shifted low-rank approximation
/// `K̃ = C U C^T + δ (I - Q Q^T)` with `Q` an orthonormal basis of col(C).
#[derive(Debug, Clone)]
pub struct ShiftedApprox {
    pub base: SpsdApprox,
    pub delta: f64,
    /// n x rank(C) orthonormal basis of col(C).
    pub q: Matrix,
}

/// Apply spectral shifting given the exact trace of K (for RBF kernels,
/// `trace_k = n`). `delta` is clamped at 0 so the result stays SPSD.
pub fn spectral_shift(base: SpsdApprox, trace_k: f64) -> ShiftedApprox {
    let n = base.c.rows();
    let q = qr::orthonormal_basis(&base.c, 1e-12);
    let rank = q.cols();
    // tr(C U C^T) = tr(U (C^T C)); C^T C is a Gram — triangular SYRK
    let ctc = gemm::syrk_tn(&base.c);
    let tr_approx = base.u.matmul(&ctc).trace();
    let denom = (n - rank).max(1) as f64;
    let delta = ((trace_k - tr_approx) / denom).max(0.0);
    ShiftedApprox { base, delta, q }
}

impl ShiftedApprox {
    /// Materialize `C U C^T + δ (I - Q Q^T)` (evaluation only).
    pub fn materialize(&self) -> Matrix {
        let mut m = self.base.materialize();
        let qqt = gemm::syrk_nt(&self.q);
        for i in 0..m.rows() {
            for j in 0..m.cols() {
                let eye = if i == j { 1.0 } else { 0.0 };
                m[(i, j)] += self.delta * (eye - qqt[(i, j)]);
            }
        }
        m
    }

    pub fn rel_fro_error(&self, k: &Matrix) -> f64 {
        k.sub(&self.materialize()).fro_norm_sq() / k.fro_norm_sq()
    }

    /// Top-k eigenpairs: on col(C) the operator is `C U C^T`; on the
    /// complement it is `δ I`. We return the top-k of the low-rank part
    /// with eigenvalues shifted comparison-correctly (values below δ are
    /// reported as δ since the complement dominates there).
    pub fn eig_k(&self, k: usize) -> (Vec<f64>, Matrix) {
        let (vals, vecs) = solve::eig_k_of_cuc(&self.base.c, &self.base.u, k);
        let vals = vals.into_iter().map(|v| v.max(self.delta)).collect();
        (vals, vecs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::oracle::{DenseOracle, KernelOracle};
    use crate::exec::{self, ExecPolicy};
    use crate::spsd::{uniform_p, FastConfig};
    use crate::testkit::gen;
    use crate::util::Rng;

    /// Kernel with a flat tail: decayed SPSD + eps * I.
    fn flat_tail_kernel(n: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        let mut k = gen::spsd(&mut rng, n, 5);
        // normalize then add a substantial flat tail
        let s = k.trace() / n as f64;
        k = k.scale(1.0 / s);
        for i in 0..n {
            k[(i, i)] += 0.5;
        }
        k
    }

    #[test]
    fn shift_improves_flat_tail_kernels() {
        let n = 80;
        let k = flat_tail_kernel(n, 0);
        let o = DenseOracle::new(k.clone());
        let mut rng = Rng::new(1);
        let p = uniform_p(n, 10, &mut rng);
        let base = exec::fast(&o, &p, FastConfig::uniform(40), &ExecPolicy::Materialized, &mut rng).result;
        let e_base = base.rel_fro_error(&k);
        let shifted = spectral_shift(base, k.trace());
        let e_shift = shifted.rel_fro_error(&k);
        assert!(
            e_shift < e_base,
            "shift should help on flat tails: {e_shift} vs {e_base}"
        );
        assert!(shifted.delta > 0.0);
    }

    #[test]
    fn shift_is_noop_when_rank_captured() {
        // exactly low-rank K with rank(C)=rank(K): residual trace ~ 0
        let mut rng = Rng::new(2);
        let k = gen::spsd(&mut rng, 50, 4);
        let o = DenseOracle::new(k.clone());
        let p = uniform_p(50, 8, &mut rng);
        let base = exec::nystrom(&o, &p, &ExecPolicy::Materialized).result;
        let shifted = spectral_shift(base, k.trace());
        assert!(shifted.delta.abs() < 1e-8, "delta={}", shifted.delta);
        assert!(shifted.rel_fro_error(&k) < 1e-9);
    }

    #[test]
    fn delta_never_negative() {
        // over-estimating trace of the approximation must clamp at 0
        let mut rng = Rng::new(3);
        let k = gen::spsd(&mut rng, 30, 30);
        let o = DenseOracle::new(k.clone());
        let p = uniform_p(30, 5, &mut rng);
        let base = exec::nystrom(&o, &p, &ExecPolicy::Materialized).result;
        let shifted = spectral_shift(base, 0.0); // impossible trace
        assert_eq!(shifted.delta, 0.0);
    }

    #[test]
    fn eig_k_floors_at_delta() {
        let n = 60;
        let k = flat_tail_kernel(n, 4);
        let o = DenseOracle::new(k.clone());
        let mut rng = Rng::new(5);
        let p = uniform_p(n, 8, &mut rng);
        let base = exec::fast(&o, &p, FastConfig::uniform(30), &ExecPolicy::Materialized, &mut rng).result;
        let shifted = spectral_shift(base, k.trace());
        let (vals, vecs) = shifted.eig_k(8);
        assert_eq!(vecs.cols(), 8.min(vecs.cols()));
        for &v in &vals {
            assert!(v >= shifted.delta - 1e-12);
        }
    }
}
