//! Request planner: turn an accuracy/budget target into (method, c, s,
//! tile_rows).
//!
//! This encodes the paper's complexity model as a routing policy — the
//! coordinator's answer to "I have n points and want 1+ε error against the
//! best rank-k approximation; what do I run?":
//!
//! - prototype needs `c = O(k/ε)` but observes n² entries (Thm 1),
//! - Nyström needs `c ≥ Ω(√(nk/ε))` (Wang & Zhang 2013 lower bound),
//! - fast needs `c = O(k/ε)` and `s = O(c√(n/ε))` with `nc + (s−c)²`
//!   entries (Thm 3 / Remark 4) — linear in n.
//!
//! `plan` picks the cheapest method whose predicted *entry* count fits the
//! entry budget AND whose predicted *peak working set* fits the memory
//! budget — streaming the build through the tile pipeline (a `tile_rows`
//! in the plan) when that is what makes it fit. Constants are calibrated
//! pragmatically (c = 2k/ε, matching the paper's near-optimal column
//! selection results).

use crate::exec::{DegradeAction, DegradeInfo, ExecPolicy};
use crate::obs::{self, Stage};
use crate::sketch::SketchKind;
use crate::stream::{
    panel_bytes, panel_bytes_prec, Precision, StreamConfig, ValidateMode, DEFAULT_QUEUE_DEPTH,
    DEFAULT_RESIDENT_TILE_ROWS,
};

/// Which model to run. Lives here (with the entry/peak/flop models that
/// price it) so that both the serving layer and the [`exec`](crate::exec)
/// policy layer can name methods without depending on each other;
/// [`service`](super::service) re-exports it for request construction.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum MethodSpec {
    Nystrom,
    Prototype,
    Fast { s: usize, kind: SketchKind },
    /// Fast CUR of the kernel matrix itself (paper §5 / eq. 9): the
    /// request's `c` picks the columns, `r` rows are drawn uniformly,
    /// and `U` comes from uniform `s x s` sketches.
    Cur { r: usize, s: usize },
}

impl MethodSpec {
    pub fn name(&self) -> String {
        match self {
            MethodSpec::Nystrom => "nystrom".into(),
            MethodSpec::Prototype => "prototype".into(),
            MethodSpec::Fast { s, kind } => format!("fast[{},s={s}]", kind.name()),
            MethodSpec::Cur { r, s } => format!("cur[fast,r={r},s={s}]"),
        }
    }
}

/// What the caller wants.
#[derive(Debug, Clone, Copy)]
pub struct Goal {
    /// matrix size
    pub n: usize,
    /// target rank of the downstream task
    pub k: usize,
    /// relative-error parameter ε in (0, 1]
    pub epsilon: f64,
    /// max kernel entries the caller can afford to evaluate
    /// (`u64::MAX` = unconstrained)
    pub entry_budget: u64,
    /// max bytes of peak working memory the build may use
    /// (`u64::MAX` = unconstrained)
    pub memory_budget: u64,
}

impl Goal {
    /// Goal with both budgets unconstrained.
    pub fn unbounded(n: usize, k: usize, epsilon: f64) -> Self {
        Goal { n, k, epsilon, entry_budget: u64::MAX, memory_budget: u64::MAX }
    }
}

/// A concrete plan.
#[derive(Debug, Clone, PartialEq)]
pub struct Plan {
    pub method: MethodSpec,
    pub c: usize,
    /// predicted kernel entries observed
    pub predicted_entries: u64,
    /// How the build should traverse the kernel — handed straight to the
    /// `exec` entry points (replaces the old loose `tile_rows` field).
    pub policy: ExecPolicy,
    /// predicted peak working-set bytes under `policy`
    pub predicted_peak_bytes: u64,
}

impl Plan {
    /// The streamed tile height of this plan (`None` = materialized), for
    /// callers that only care about the tiling.
    pub fn tile_rows(&self) -> Option<usize> {
        let mut policy = &self.policy;
        loop {
            match policy {
                ExecPolicy::Materialized => return None,
                ExecPolicy::Streamed(cfg) => return Some(cfg.tile_rows),
                ExecPolicy::Resident { tile_rows, .. } => {
                    return Some(tile_rows.unwrap_or(DEFAULT_RESIDENT_TILE_ROWS))
                }
                ExecPolicy::Sharded { inner, .. } => policy = inner,
            }
        }
    }
}

/// The policy a request runs under when it carries none: the materialized
/// path — bit-compatible with the historical builds and right whenever
/// the working set fits. Budget-constrained callers should instead derive
/// a policy from [`plan`] (streaming) or [`plan_residency`] (residency
/// splits).
pub fn default_policy() -> ExecPolicy {
    ExecPolicy::Materialized
}

/// Sketch sizes from the paper's theory with pragmatic constants.
pub fn theory_c(k: usize, epsilon: f64) -> usize {
    ((2.0 * k as f64 / epsilon).ceil() as usize).max(k + 1)
}

pub fn theory_s(n: usize, c: usize, epsilon: f64) -> usize {
    ((c as f64 * (n as f64 / epsilon).sqrt()).ceil() as usize).max(2 * c)
}

pub fn nystrom_c_lower_bound(n: usize, k: usize, epsilon: f64) -> usize {
    ((n as f64 * k as f64 / epsilon).sqrt().ceil()) as usize
}

/// Predicted entries for each model (Table 3 right column; served CUR
/// materializes the kernel, so it observes `n²`).
pub fn predicted_entries(n: usize, c: usize, s: usize, method: &MethodSpec) -> u64 {
    match method {
        MethodSpec::Nystrom => (n * c) as u64,
        MethodSpec::Prototype => (n as u64) * (n as u64) + (n * c) as u64,
        MethodSpec::Fast { .. } => {
            let extra = s.saturating_sub(c) as u64;
            (n * c) as u64 + extra * extra
        }
        MethodSpec::Cur { .. } => (n as u64) * (n as u64),
    }
}

/// Bytes per stored kernel entry (f64).
const ENTRY_BYTES: u64 = 8;

/// Tiles simultaneously alive in the pipeline at the default queue depth:
/// one being produced + queued + one being folded.
fn live_tiles() -> u64 {
    (DEFAULT_QUEUE_DEPTH + 2) as u64
}

/// Predicted peak working-set bytes for a build. `tile_rows = None` is the
/// materialized path; `Some(t)` streams `t`-row tiles through the
/// pipeline. The terms are the dominant allocations: the `C` panel (an
/// output — every method pays it), the sketch-sized intermediates, and
/// either the full `n x n` kernel (materialized prototype / projection
/// sketches) or the live tiles.
pub fn predicted_peak_bytes(
    n: usize,
    c: usize,
    s: usize,
    method: &MethodSpec,
    tile_rows: Option<usize>,
) -> u64 {
    predicted_peak_bytes_prec(n, c, s, method, tile_rows, Precision::F64)
}

/// [`predicted_peak_bytes`] at an explicit tile element width: the
/// streamed **live-tile** term is charged at `prec` (that is the memory
/// f32 tiles actually halve), while outputs, solves, and sketch state stay
/// at [`ENTRY_BYTES`] — folds accumulate into f64 no matter the tile type,
/// and the collected `C`/`U` panels are promoted f64.
pub fn predicted_peak_bytes_prec(
    n: usize,
    c: usize,
    s: usize,
    method: &MethodSpec,
    tile_rows: Option<usize>,
    prec: Precision,
) -> u64 {
    let (n, c, s) = (n as u64, c as u64, s as u64);
    let t = tile_rows.map(|t| t as u64);
    let tile_bytes = prec.bytes() as u64;
    match method {
        MethodSpec::Nystrom => {
            let base = n * c + 2 * c * c;
            ENTRY_BYTES * base + tile_bytes * t.map_or(0, |t| live_tiles() * t * c)
        }
        MethodSpec::Prototype => match t {
            // C + K + C† + U (the materialized whole tile is always f64 —
            // the bit-compat reference path has no narrow plane)
            None => ENTRY_BYTES * (n * n + 2 * n * c + c * c),
            // C + C† + U + live tiles of K rows
            Some(t) => {
                ENTRY_BYTES * (2 * n * c + c * c) + tile_bytes * live_tiles() * t * n
            }
        },
        MethodSpec::Fast { kind, .. } => {
            // column-selection accounting (what the planner emits):
            // C + C[S,:] + S^T C + S^T K S + U. The leverage family adds
            // its streamed score state — Gram + whitening factor, 2c² —
            // and, now that scores come from the streamed estimator rather
            // than an SVD of the resident panel, nothing n-dependent
            // beyond the C output itself.
            let lev = if matches!(kind, SketchKind::Leverage { .. }) { 2 * c * c } else { 0 };
            let base = n * c + 2 * s * c + s * s + c * c + lev;
            ENTRY_BYTES * base + tile_bytes * t.map_or(0, |t| live_tiles() * t * c)
        }
        MethodSpec::Cur { r, .. } => {
            // Served CUR works on the materialized square kernel:
            // K (n²) + C (n·c) + R (r·n) + core (s²) + the sketched
            // row/column gathers (s·(c+r)) + U (c·r). The n² term is
            // unconditional — the service materializes K under every
            // policy and the pipeline then streams over the resident
            // matrix — so tiling only adds its live row tiles on top.
            let r = *r as u64;
            let base = n * n + n * c + r * n + s * s + s * (c + r) + c * r;
            ENTRY_BYTES * base + tile_bytes * t.map_or(0, |t| live_tiles() * t * n)
        }
    }
}

/// Predicted peak working-set bytes for running `method` under an
/// arbitrary [`ExecPolicy`] — the build-side peak model
/// ([`predicted_peak_bytes`]) at the policy's tile height, plus the
/// residency layer's hot-tile cache as a separate term capped at the
/// panel it caches (`min(budget, n·c·8)`; the `K`-streaming methods have
/// no reloadable panel, so the cap uses the `n x c` output panel every
/// cacheable method shares). This is what [`exec`](crate::exec) reports
/// in `RunMeta::predicted_peak_bytes` and what the service meters
/// in-flight requests by.
pub fn predicted_policy_peak_bytes(
    n: usize,
    c: usize,
    method: &MethodSpec,
    policy: &ExecPolicy,
) -> u64 {
    if let ExecPolicy::Sharded { inner, .. } = policy {
        // Shard workers run sequentially on the calling thread, each
        // under `inner`, so the coordinator's aggregate peak is the inner
        // policy's peak — sharding shrinks each worker's row span, not
        // the model terms one pass charges.
        return predicted_policy_peak_bytes(n, c, method, inner);
    }
    let s = method_s(method, c);
    let prec = policy.precision();
    let base = predicted_peak_bytes_prec(n, c, s, method, policy.planned_tile_rows(n), prec);
    // Only methods that actually route through the residency layer get the
    // cache term — the full-K streamers (prototype, projection-sketch
    // fast) strip a Resident policy down to plain streaming, so charging
    // them a cache would shed requests for memory the run never allocates
    // — and the cap is the panel that method's layer caches: the `n x c`
    // column panel for Nyström / selection-sketch fast, but the full
    // `n x n` kernel for served CUR (its tiles are rows of the
    // materialized K).
    // Cached tiles live at the policy's element width, so the cap halves
    // with the rest of the tile plane under an f32 policy.
    let cache_panel = match method {
        MethodSpec::Nystrom => Some(panel_bytes_prec(n, c, prec)),
        MethodSpec::Fast { kind, .. } if kind.is_column_selection() => {
            Some(panel_bytes_prec(n, c, prec))
        }
        MethodSpec::Cur { .. } => Some(panel_bytes_prec(n, n, prec)),
        _ => None,
    };
    match (policy, cache_panel) {
        (ExecPolicy::Resident { budget, .. }, Some(panel)) => base + (*budget).min(panel),
        _ => base,
    }
}

/// The sketch size a method's peak/entry models should charge.
fn method_s(method: &MethodSpec, c: usize) -> usize {
    match method {
        MethodSpec::Fast { s, .. } => *s,
        MethodSpec::Cur { s, .. } => *s,
        MethodSpec::Nystrom => c,
        MethodSpec::Prototype => 0,
    }
}

/// Peak working set of a residency-backed implicit op (Lanczos / the
/// regularized solve against the implicit `C U C^T`): pipeline live tiles
/// + the `O(c²)` fold/Woodbury state + the hot-tile cache as a **separate
/// term capped at its budget** — `min(cache_budget, n·c·8)`. The old
/// cached-`C` accounting was all-or-nothing (`n·c` when the panel fit,
/// zero otherwise); with the LRU + spill arena the cache occupies exactly
/// its budget in the spilling regime, which makes this prediction
/// n-independent there (the Krylov basis, an output of size `n·k`, is
/// excluded as with every other output panel).
pub fn predicted_implicit_peak_bytes(
    n: usize,
    c: usize,
    tile_rows: usize,
    cache_budget: u64,
) -> u64 {
    predicted_implicit_peak_bytes_prec(n, c, tile_rows, cache_budget, Precision::F64)
}

/// [`predicted_implicit_peak_bytes`] at an explicit tile element width:
/// live tiles and the cached panel are charged at `prec`, the `O(c²)`
/// fold/Woodbury state stays f64 (it is accumulated at full width whatever
/// the tiles are).
pub fn predicted_implicit_peak_bytes_prec(
    n: usize,
    c: usize,
    tile_rows: usize,
    cache_budget: u64,
    prec: Precision,
) -> u64 {
    let (c64, t) = (c as u64, tile_rows.max(1) as u64);
    let live = (prec.bytes() as u64) * live_tiles() * t * c64;
    let state = ENTRY_BYTES * 2 * c64 * c64;
    live + state + panel_bytes_prec(n, c, prec).min(cache_budget)
}

/// How an implicit op should split a memory budget between the pipeline's
/// live tiles and the residency layer's hot-tile LRU.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ResidencySplit {
    /// Tile height for both the pipeline and the residency grid.
    pub tile_rows: usize,
    /// Bytes for the hot-tile LRU (the `ResidencyConfig::ram_budget`).
    pub cache_budget: u64,
    /// `min(1, cache_budget / panel)`: the fraction of the `n x c` panel
    /// the cache can hold — the steady-state RAM hit rate of a cyclic
    /// re-reading workload, which the residency layer's scan-resistant
    /// admission actually realizes (a plain LRU would thrash to zero hits
    /// on scans; see `ResidentSource::admit`).
    pub predicted_hit_rate: f64,
    /// Cold tiles must go to the spill arena (the cache cannot hold the
    /// panel); without spill they would be recomputed.
    pub spill: bool,
    /// [`predicted_implicit_peak_bytes`] at this split.
    pub predicted_peak_bytes: u64,
}

impl ResidencySplit {
    /// This split as an [`ExecPolicy`], ready to hand to the `exec` entry
    /// points (the spill directory stays unset — the service fills in its
    /// own).
    pub fn policy(&self) -> ExecPolicy {
        ExecPolicy::Resident {
            budget: self.cache_budget,
            spill: self.spill,
            tile_rows: Some(self.tile_rows),
            spill_dir: None,
            precision: Precision::F64,
            validate: ValidateMode::Off,
        }
    }
}

/// Pick the tile_rows / cache-budget split for a residency-backed implicit
/// op under `memory_budget` bytes: the pipeline's live set gets at most a
/// quarter of the budget (preferring the default 256-row tile, shrinking
/// to fit, floor one row), the `O(c²)` state is reserved, and everything
/// left goes to the hot-tile LRU — capped at the panel size, since a cache
/// larger than the working set buys nothing. Never fails: a budget below
/// the floor terms (one-row live tiles + the `c²` state) degrades to the
/// most frugal split (tile_rows 1, empty cache, spill on) and the
/// overshoot is visible in `predicted_peak_bytes` — the same graceful-
/// degradation convention as [`plan`].
pub fn plan_residency(n: usize, c: usize, memory_budget: u64) -> ResidencySplit {
    let n = n.max(1);
    let c = c.max(1);
    let per_row = ENTRY_BYTES * live_tiles() * c as u64;
    let tile_rows = ((memory_budget / 4) / per_row)
        .clamp(1, DEFAULT_RESIDENT_TILE_ROWS as u64)
        .min(n as u64) as usize;
    let live = per_row * tile_rows as u64;
    let state = ENTRY_BYTES * 2 * (c as u64) * (c as u64);
    let panel = panel_bytes(n, c);
    let cache_budget = memory_budget.saturating_sub(live + state).min(panel);
    let predicted_hit_rate = if panel == 0 {
        1.0
    } else {
        (cache_budget as f64 / panel as f64).min(1.0)
    };
    ResidencySplit {
        tile_rows,
        cache_budget,
        predicted_hit_rate,
        spill: cache_budget < panel,
        predicted_peak_bytes: predicted_implicit_peak_bytes(n, c, tile_rows, cache_budget),
    }
}

/// How a sharded build splits rows and memory across workers.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ShardSplit {
    /// Worker count (≥ 1, capped at one row per worker).
    pub shards: usize,
    /// Rows the widest worker owns (`ceil(n / shards)`).
    pub rows_per_shard: usize,
    /// Bytes of the memory budget one worker may use.
    pub per_worker_budget: u64,
    /// Pipeline tile height inside each worker.
    pub tile_rows: usize,
    /// Modeled peak for one worker's pass: its live tiles plus its
    /// `rows_per_shard x c` slice of the shared output panel.
    pub predicted_worker_peak_bytes: u64,
}

impl ShardSplit {
    /// This split as an [`ExecPolicy`], ready to hand to the `exec` entry
    /// points.
    pub fn policy(&self) -> ExecPolicy {
        ExecPolicy::Sharded {
            shards: self.shards,
            inner: Box::new(ExecPolicy::Streamed(StreamConfig::tiled(self.tile_rows))),
        }
    }
}

/// Split `memory_budget` across `shards` row-sharded workers. Each worker
/// streams only its own row-block, so its working set is its live tiles
/// plus its slice of the `n x c` output panel; the live set gets at most a
/// quarter of the per-worker budget (the [`plan_residency`] rule), with
/// the tile height clamped to the worker's row span. Never fails: an
/// infeasible budget degrades to one-row tiles and the overshoot is
/// visible in `predicted_worker_peak_bytes`.
pub fn plan_shards(n: usize, c: usize, shards: usize, memory_budget: u64) -> ShardSplit {
    let n = n.max(1);
    let c = c.max(1);
    let shards = shards.clamp(1, n);
    let rows_per_shard = n.div_ceil(shards);
    let per_worker_budget = memory_budget / shards as u64;
    let per_row = ENTRY_BYTES * live_tiles() * c as u64;
    let tile_rows = ((per_worker_budget / 4) / per_row)
        .clamp(1, DEFAULT_RESIDENT_TILE_ROWS as u64)
        .min(rows_per_shard as u64) as usize;
    let live = per_row * tile_rows as u64;
    ShardSplit {
        shards,
        rows_per_shard,
        per_worker_budget,
        tile_rows,
        predicted_worker_peak_bytes: live + panel_bytes(rows_per_shard, c),
    }
}

/// Predicted flops: U computation (Table 3 middle column) plus the
/// downstream O(nc²) eig/solve every method pays. This is where the
/// paper's "linear vs quadratic in n" separation shows up: at the c each
/// model needs for a (1+ε) guarantee, Nyström's c = Ω(√(nk/ε)) makes its
/// downstream term n·c² = n²k/ε quadratic, while the fast model stays
/// linear (with a large k,ε-dependent constant).
pub fn predicted_flops(n: usize, c: usize, s: usize, method: &MethodSpec) -> f64 {
    let (nf, cf, sf) = (n as f64, c as f64, s as f64);
    let downstream = nf * cf * cf;
    match method {
        MethodSpec::Nystrom => cf.powi(3) + downstream,
        MethodSpec::Prototype => nf * nf * cf + downstream,
        MethodSpec::Fast { .. } => nf * cf * cf + sf * sf * cf + downstream,
        MethodSpec::Cur { r, .. } => {
            let rf = *r as f64;
            nf * cf * cf + sf * sf * (cf + rf) + downstream
        }
    }
}

/// Fit a candidate against the memory budget: keep the materialized path
/// when it fits, otherwise stream (prototype is the method whose floor
/// streaming actually lowers — `C` dominates the others, so tiling can't
/// save a build whose output already exceeds the budget). Returns `None`
/// when no tile height makes it fit.
fn fit_memory(mut plan: Plan, n: usize, s: usize, memory_budget: u64) -> Option<Plan> {
    if plan.predicted_peak_bytes <= memory_budget {
        return Some(plan);
    }
    if !matches!(plan.method, MethodSpec::Prototype) {
        return None;
    }
    let (nn, cc) = (n as u64, plan.c as u64);
    let base = ENTRY_BYTES * (2 * nn * cc + cc * cc);
    let per_tile_row = ENTRY_BYTES * live_tiles() * nn;
    if memory_budget < base + per_tile_row {
        return None; // even one-row tiles overshoot
    }
    let t = (((memory_budget - base) / per_tile_row) as usize).clamp(1, n);
    plan.policy = ExecPolicy::Streamed(StreamConfig::tiled(t));
    plan.predicted_peak_bytes = predicted_peak_bytes(n, plan.c, s, &plan.method, Some(t));
    Some(plan)
}

/// Choose the fastest method whose predicted entry count and peak memory
/// both fit the budgets. Never panics: an infeasible pair of budgets
/// degrades to the fewest-entries candidate in its most memory-frugal form
/// (the caller sees the overshoot in the plan's predicted fields).
pub fn plan(goal: Goal) -> Plan {
    let _s = obs::span(Stage::Plan);
    let n = goal.n.max(2);
    let eps = goal.epsilon.clamp(1e-6, 1.0);
    // Fast model at theory sizes.
    let c_fast = theory_c(goal.k, eps).min(n / 2).max(1);
    let s_fast = theory_s(n, c_fast, eps).min(n);
    let fast = MethodSpec::Fast { s: s_fast, kind: SketchKind::Uniform };

    // Nyström needs a much larger c for the same guarantee.
    let c_ny = nystrom_c_lower_bound(n, goal.k, eps).min(n / 2).max(1);

    // Prototype: small c but n² observation.
    let c_proto = theory_c(goal.k, eps).min(n / 2).max(1);

    let make = |method: MethodSpec, c: usize, s: usize| Plan {
        method,
        c,
        predicted_entries: predicted_entries(n, c, s, &method),
        policy: ExecPolicy::Materialized,
        predicted_peak_bytes: predicted_peak_bytes(n, c, s, &method, None),
    };
    let mut candidates = [
        make(fast, c_fast, s_fast),
        make(MethodSpec::Nystrom, c_ny, c_ny),
        make(MethodSpec::Prototype, c_proto, n),
    ];
    // fastest first
    candidates.sort_by(|a, b| {
        let fa = predicted_flops(n, a.c, plan_s(a), &a.method);
        let fb = predicted_flops(n, b.c, plan_s(b), &b.method);
        fa.partial_cmp(&fb).unwrap()
    });
    for cand in &candidates {
        if cand.predicted_entries > goal.entry_budget {
            continue;
        }
        if let Some(fitted) = fit_memory(cand.clone(), n, plan_s(cand), goal.memory_budget) {
            return fitted;
        }
    }
    // nothing fits both budgets: degrade gracefully to the fewest-entries
    // candidate, streamed as tightly as its method allows
    let fallback = candidates
        .iter()
        .min_by_key(|p| p.predicted_entries)
        .unwrap()
        .clone();
    let s = plan_s(&fallback);
    fit_memory(fallback.clone(), n, s, goal.memory_budget).unwrap_or_else(|| {
        if matches!(fallback.method, MethodSpec::Prototype) {
            let mut p = fallback;
            p.policy = ExecPolicy::Streamed(StreamConfig::tiled(1));
            p.predicted_peak_bytes = predicted_peak_bytes(n, p.c, s, &p.method, Some(1));
            p
        } else {
            fallback
        }
    })
}

fn plan_s(p: &Plan) -> usize {
    method_s(&p.method, p.c)
}

// ---------------------------------------------------------------------
// The degrade-don't-die ladder (ISSUE 6)
// ---------------------------------------------------------------------

/// One rung of the degrade ladder: a cheaper way to serve the same
/// request, priced by the peak model, with the accuracy trade recorded in
/// `info` so responses can report it.
#[derive(Debug, Clone, PartialEq)]
pub struct DegradeStep {
    pub method: MethodSpec,
    pub c: usize,
    pub policy: ExecPolicy,
    pub predicted_peak_bytes: u64,
    pub info: DegradeInfo,
}

/// The ladder a loaded service walks instead of shedding: each rung costs
/// strictly fewer predicted peak bytes than the one before, ordered by
/// accuracy price —
///
/// 1. **Policy tightened** (free): a traversal with a smaller working set
///    for the *same* computation — prototype materialized → streamed,
///    resident cache budget → 0, streamed column-selection gathers →
///    materialized (drops the live-tile term).
/// 2. **Sampling relaxed** (mild): leverage → uniform column selection,
///    dropping the `2c²` score state and the extra pass; the uniform
///    bound is weaker but still holds (Gittens–Mahoney, arXiv 1303.1849).
/// 3. **Sketch shrunk** (graceful): `c` halves toward the rank floor
///    `max(k+1, 2)`, with `s` (and CUR's `r`) halved alongside — the
///    error bound degrades continuously in `c`, which is exactly the
///    lever the theory says to pull before refusing service.
///
/// Returns every rung below the requested configuration, best first. An
/// empty ladder means the request is already at the floor.
pub fn degrade_ladder(
    n: usize,
    k: usize,
    method: &MethodSpec,
    c: usize,
    policy: &ExecPolicy,
) -> Vec<DegradeStep> {
    let _s = obs::span(Stage::Plan);
    let n = n.max(1);
    let mut rungs: Vec<DegradeStep> = Vec::new();
    let mut m = *method;
    let mut cc = c.clamp(1, n);
    let mut pol = policy.clone();
    let mut actions: Vec<DegradeAction> = Vec::new();
    let mut predicted = predicted_policy_peak_bytes(n, cc, &m, &pol);

    let mut push = |rungs: &mut Vec<DegradeStep>,
                    m: MethodSpec,
                    cc: usize,
                    pol: &ExecPolicy,
                    predicted: u64,
                    actions: &[DegradeAction]| {
        rungs.push(DegradeStep {
            method: m,
            c: cc,
            policy: pol.clone(),
            predicted_peak_bytes: predicted,
            info: DegradeInfo {
                rung: rungs.len() + 1,
                requested_c: c,
                c: cc,
                actions: actions.to_vec(),
            },
        });
    };

    // Rung: tighten the execution policy — zero accuracy cost, taken only
    // when the peak model says it actually helps.
    if let Some(tight) = tightened_policy(n, &m, &pol) {
        let p2 = predicted_policy_peak_bytes(n, cc, &m, &tight);
        if p2 < predicted {
            pol = tight;
            predicted = p2;
            actions.push(DegradeAction::PolicyTightened);
            push(&mut rungs, m, cc, &pol, predicted, &actions);
        }
    }

    // Rung: leverage → uniform sampling.
    if let MethodSpec::Fast { s, kind } = m {
        if matches!(kind, SketchKind::Leverage { .. }) {
            m = MethodSpec::Fast { s, kind: SketchKind::Uniform };
            predicted = predicted_policy_peak_bytes(n, cc, &m, &pol);
            actions.push(DegradeAction::SamplingRelaxed);
            push(&mut rungs, m, cc, &pol, predicted, &actions);
        }
    }

    // Rung: lower the tile element width f64 → f32 — halves the live-tile
    // and cached-panel terms at a tile-rounding accuracy cost (≈1e-7
    // relative, far below the sampling error), so it sits before any
    // sketch shrink. Skipped when the policy is already narrow or is
    // Materialized (whose whole-matrix path has no tile plane to narrow).
    if pol.precision() == Precision::F64 && !matches!(pol, ExecPolicy::Materialized) {
        let narrow = pol.clone().with_precision(Precision::F32);
        let p2 = predicted_policy_peak_bytes(n, cc, &m, &narrow);
        if p2 < predicted {
            pol = narrow;
            predicted = p2;
            actions.push(DegradeAction::PrecisionLowered);
            push(&mut rungs, m, cc, &pol, predicted, &actions);
        }
    }

    // Rungs: halve the sketch sizes toward the rank floor.
    let floor = (k + 1).clamp(2, cc.max(2));
    loop {
        let next_c = (cc / 2).clamp(floor.min(cc), cc);
        let shrunk = shrink_method(&m, next_c, n);
        if next_c == cc && shrunk == m {
            break;
        }
        cc = next_c;
        m = shrunk;
        let p2 = predicted_policy_peak_bytes(n, cc, &m, &pol);
        // halving can only shrink the model; keep the rung ordering honest
        predicted = p2.min(predicted);
        actions.push(DegradeAction::SketchShrunk);
        push(&mut rungs, m, cc, &pol, p2, &actions);
    }

    rungs
}

/// A traversal of the same computation with a strictly smaller modeled
/// working set, when one exists.
fn tightened_policy(n: usize, method: &MethodSpec, policy: &ExecPolicy) -> Option<ExecPolicy> {
    match (method, policy) {
        // Sharding is an orchestration wrapper; tighten the per-worker
        // policy it carries and keep the shard split.
        (_, ExecPolicy::Sharded { shards, inner }) => {
            tightened_policy(n, method, inner)
                .map(|tight| ExecPolicy::Sharded { shards: *shards, inner: Box::new(tight) })
        }
        // The prototype's materialized path holds the full n x n tile;
        // streaming it caps live tiles at the pipeline depth.
        (MethodSpec::Prototype, p) if p.planned_tile_rows(n).is_none() => {
            Some(ExecPolicy::Streamed(StreamConfig::tiled((n / 8).max(1))))
        }
        // A resident cache budget is pure working-set headroom; dropping
        // it to 0 keeps results bit-identical (spill still dedups reads).
        (_, ExecPolicy::Resident { budget, spill, tile_rows, spill_dir, precision, validate })
            if *budget > 0 =>
        {
            Some(ExecPolicy::Resident {
                budget: 0,
                spill: *spill,
                tile_rows: *tile_rows,
                spill_dir: spill_dir.clone(),
                precision: *precision,
                validate: *validate,
            })
        }
        // Streamed column gathers pay live-tile bytes on top of the panel
        // they assemble anyway; the materialized gather drops that term.
        (MethodSpec::Nystrom, ExecPolicy::Streamed(_)) => Some(ExecPolicy::Materialized),
        (MethodSpec::Fast { kind, .. }, ExecPolicy::Streamed(_))
            if kind.is_column_selection() =>
        {
            Some(ExecPolicy::Materialized)
        }
        _ => None,
    }
}

/// Halve a method's own sketch sizes consistently with a new `c`.
fn shrink_method(m: &MethodSpec, new_c: usize, n: usize) -> MethodSpec {
    match *m {
        MethodSpec::Nystrom | MethodSpec::Prototype => *m,
        MethodSpec::Fast { s, kind } => {
            MethodSpec::Fast { s: (s / 2).max(2 * new_c).min(s).min(n), kind }
        }
        MethodSpec::Cur { r, s } => MethodSpec::Cur {
            r: (r / 2).max(2).min(r),
            s: (s / 2).max(2 * new_c).min(s).min(n),
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fast_wins_at_large_n() {
        // Theorem 1 / §1.1: under a 1+ε guarantee the fast model is the
        // only linear-time option once n is large enough that Nyström's
        // c = Ω(√(nk/ε)) makes its downstream n·c² quadratic.
        let p = plan(Goal::unbounded(100_000_000, 5, 0.5));
        assert!(matches!(p.method, MethodSpec::Fast { .. }), "{p:?}");
        // and it stays far below n² observation
        let n2 = 100_000_000u64 as f64 * 100_000_000u64 as f64;
        assert!((p.predicted_entries as f64) < n2 / 1e3);
        assert_eq!(p.policy, ExecPolicy::Materialized, "no memory pressure, no tiling");
        assert_eq!(p.tile_rows(), None);
    }

    #[test]
    fn predicted_flops_linear_vs_quadratic_in_n() {
        // Fast model flops grow ~linearly in n at guarantee sizes; Nyström's
        // grow ~quadratically. Ratio test across a 10x n jump.
        let (k, eps) = (5, 0.5);
        let flops = |n: usize| {
            let c_f = theory_c(k, eps);
            let s_f = theory_s(n, c_f, eps);
            let fast =
                predicted_flops(n, c_f, s_f, &MethodSpec::Fast { s: s_f, kind: SketchKind::Uniform });
            let c_n = nystrom_c_lower_bound(n, k, eps);
            let ny = predicted_flops(n, c_n, c_n, &MethodSpec::Nystrom);
            (fast, ny)
        };
        let (f1, n1) = flops(1_000_000);
        let (f10, n10) = flops(10_000_000);
        let fast_growth = f10 / f1;
        let ny_growth = n10 / n1;
        assert!(fast_growth < 15.0, "fast growth {fast_growth} should be ~linear");
        assert!(ny_growth > 60.0, "nystrom growth {ny_growth} should be ~quadratic");
    }

    #[test]
    fn tiny_budget_falls_back_to_cheapest() {
        let p = plan(Goal { n: 10_000, k: 5, epsilon: 0.1, entry_budget: 10, memory_budget: u64::MAX });
        // can't fit anything: returns cheapest (never prototype)
        assert!(!matches!(p.method, MethodSpec::Prototype));
    }

    #[test]
    fn small_n_clamps() {
        let p = plan(Goal::unbounded(50, 10, 0.01));
        assert!(p.c <= 25);
        if let MethodSpec::Fast { s, .. } = p.method {
            assert!(s <= 50);
        }
    }

    #[test]
    fn theory_sizes_clamp_against_tiny_n() {
        // theory_c/theory_s blow far past n at small n and harsh targets;
        // plan must clamp c ≤ n/2 and s ≤ n without panicking, for every
        // method that could be selected.
        for n in [2usize, 3, 5, 8, 16] {
            for k in [1usize, 4, 50] {
                for eps in [1e-6, 0.01, 1.0] {
                    let p = plan(Goal::unbounded(n, k, eps));
                    assert!(p.c >= 1 && p.c <= (n.max(2) / 2).max(1), "n={n} k={k} {p:?}");
                    if let MethodSpec::Fast { s, .. } = p.method {
                        assert!(s <= n.max(2), "n={n} k={k} s={s}");
                    }
                }
            }
        }
    }

    #[test]
    fn entry_budget_crossover_points() {
        // Sweep the entry budget downward and watch the method cross over:
        // prototype-class budgets admit everything, then the n²-observing
        // prototype drops out, then fast, leaving Nyström (fewest entries
        // at fixed c when its c fits), then nothing fits and the planner
        // degrades to the fewest-entries candidate.
        // n large enough that the fast model is flops-fastest (its point)
        // while Nyström still observes fewer entries at its own c.
        let (n, k, eps) = (10_000_000usize, 5, 0.05);
        let c_f = theory_c(k, eps).min(n / 2).max(1);
        let s_f = theory_s(n, c_f, eps).min(n);
        let fast_entries = predicted_entries(n, c_f, s_f, &MethodSpec::Fast { s: s_f, kind: SketchKind::Uniform });
        let c_n = nystrom_c_lower_bound(n, k, eps).min(n / 2).max(1);
        let ny_entries = predicted_entries(n, c_n, c_n, &MethodSpec::Nystrom);
        assert!(ny_entries < fast_entries, "test shape: nystrom must be cheaper in entries");

        // budget exactly at fast's requirement: fast is admissible and
        // (being flops-fastest at this n) chosen
        let p = plan(Goal { n, k, epsilon: eps, entry_budget: fast_entries, memory_budget: u64::MAX });
        assert!(matches!(p.method, MethodSpec::Fast { .. }), "{p:?}");
        assert!(p.predicted_entries <= fast_entries);

        // one entry below fast's requirement: falls through to Nyström
        let p = plan(Goal { n, k, epsilon: eps, entry_budget: fast_entries - 1, memory_budget: u64::MAX });
        assert!(matches!(p.method, MethodSpec::Nystrom), "{p:?}");

        // below every method: graceful degradation, never a panic, and the
        // overshoot is visible to the caller
        let p = plan(Goal { n, k, epsilon: eps, entry_budget: ny_entries - 1, memory_budget: u64::MAX });
        assert!(p.predicted_entries > ny_entries - 1);
        assert!(!matches!(p.method, MethodSpec::Prototype));
    }

    #[test]
    fn prototype_only_when_budget_allows_n2() {
        let n = 2_000u64;
        let with_budget = plan(Goal {
            n: n as usize,
            k: 5,
            epsilon: 0.05,
            entry_budget: n * n / 2,
            memory_budget: u64::MAX,
        });
        assert!(
            !matches!(with_budget.method, MethodSpec::Prototype),
            "n²-observing prototype must not be chosen under an n²/2 budget"
        );
    }

    #[test]
    fn memory_budget_tiles_the_prototype() {
        // Entry budget forces prototype (only it fits nothing else… use an
        // unconstrained entry budget but a memory budget below n²·8: the
        // planner may pick any method, but if prototype were materialized
        // it would blow the budget — verify the fitted form directly.
        let (n, c) = (4_000usize, 20usize);
        let mat = predicted_peak_bytes(n, c, 0, &MethodSpec::Prototype, None);
        let budget = mat / 4;
        let fitted = fit_memory(
            Plan {
                method: MethodSpec::Prototype,
                c,
                predicted_entries: predicted_entries(n, c, n, &MethodSpec::Prototype),
                policy: ExecPolicy::Materialized,
                predicted_peak_bytes: mat,
            },
            n,
            0,
            budget,
        )
        .expect("a tile height must fit an n²/4 budget");
        let t = fitted.tile_rows().expect("must stream");
        assert!(t >= 1 && t < n);
        assert!(fitted.predicted_peak_bytes <= budget, "{fitted:?}");

        // exact boundary: a budget equal to the one-row-tile peak must be
        // accepted with t = 1, not rejected as infeasible
        let one_row = predicted_peak_bytes(n, c, 0, &MethodSpec::Prototype, Some(1));
        let fitted = fit_memory(
            Plan {
                method: MethodSpec::Prototype,
                c,
                predicted_entries: predicted_entries(n, c, n, &MethodSpec::Prototype),
                policy: ExecPolicy::Materialized,
                predicted_peak_bytes: mat,
            },
            n,
            0,
            one_row,
        )
        .expect("budget at the one-row peak is feasible");
        assert_eq!(fitted.tile_rows(), Some(1));
        assert_eq!(fitted.predicted_peak_bytes, one_row);

        // and end-to-end: a plan under that memory budget never reports a
        // materialized peak above it when it claims to fit
        let p = plan(Goal { n, k: 5, epsilon: 0.1, entry_budget: u64::MAX, memory_budget: budget });
        assert!(p.predicted_peak_bytes <= budget, "{p:?}");
    }

    #[test]
    fn infeasible_memory_budget_degrades_without_panic() {
        // 1-byte memory budget: nothing fits; the planner still returns a
        // plan (fewest entries, most frugal form) instead of panicking.
        let p = plan(Goal { n: 5_000, k: 5, epsilon: 0.1, entry_budget: u64::MAX, memory_budget: 1 });
        assert!(p.predicted_peak_bytes > 1);
        assert!(!matches!(p.method, MethodSpec::Prototype));
        // and with both budgets impossible
        let p = plan(Goal { n: 5_000, k: 5, epsilon: 0.1, entry_budget: 1, memory_budget: 1 });
        assert!(p.predicted_entries > 1);
    }

    #[test]
    fn leverage_fast_peak_adds_only_c2_state() {
        // The streamed leverage estimator costs a fixed 2c² (Gram +
        // whitening factor) over the uniform fast peak — crucially, the
        // surcharge is n-independent (no resident-SVD n·c scratch), so
        // tile_rows planning and service routing stay honest for the
        // leverage family.
        let (c, s) = (40usize, 160usize);
        let uni = |n: usize, t: Option<usize>| {
            predicted_peak_bytes(n, c, s, &MethodSpec::Fast { s, kind: SketchKind::Uniform }, t)
        };
        let lev = |n: usize, t: Option<usize>| {
            predicted_peak_bytes(
                n,
                c,
                s,
                &MethodSpec::Fast { s, kind: SketchKind::Leverage { scaled: false } },
                t,
            )
        };
        let surcharge = (2 * c * c * 8) as u64;
        for t in [None, Some(64), Some(1)] {
            assert_eq!(lev(50_000, t) - uni(50_000, t), surcharge, "{t:?}");
            assert_eq!(lev(500_000, t) - uni(500_000, t), surcharge, "n-independent {t:?}");
        }
    }

    #[test]
    fn implicit_peak_charges_the_cache_as_a_capped_term() {
        let (n, c, t) = (50_000usize, 40usize, 256usize);
        let panel = panel_bytes(n, c);
        let base = predicted_implicit_peak_bytes(n, c, t, 0);
        // below the panel the surcharge is exactly the budget…
        for budget in [1u64, 1 << 20, panel - 1] {
            assert_eq!(predicted_implicit_peak_bytes(n, c, t, budget) - base, budget);
        }
        // …and above it the term caps at the panel (no all-or-nothing cliff)
        for budget in [panel, panel + 1, u64::MAX] {
            assert_eq!(predicted_implicit_peak_bytes(n, c, t, budget) - base, panel);
        }
    }

    #[test]
    fn implicit_peak_is_n_independent_in_the_spilling_regime() {
        // With a fixed cache budget below the panel, growing n 100x must
        // not change the predicted peak at all: live tiles are t-sized,
        // state is c-sized, and the cache term is the budget — this is the
        // bound that makes n-larger-than-RAM runs plannable.
        let (c, t) = (32usize, 128usize);
        let budget: u64 = 4 << 20; // 4 MiB, far below both panels
        let small = predicted_implicit_peak_bytes(100_000, c, t, budget);
        let large = predicted_implicit_peak_bytes(10_000_000, c, t, budget);
        assert!(budget < panel_bytes(100_000, c));
        assert_eq!(small, large);

        // and plan_residency reproduces that: same split, same peak
        let s1 = plan_residency(100_000, c, budget);
        let s2 = plan_residency(10_000_000, c, budget);
        assert_eq!(s1.tile_rows, s2.tile_rows);
        assert_eq!(s1.cache_budget, s2.cache_budget);
        assert_eq!(s1.predicted_peak_bytes, s2.predicted_peak_bytes);
        assert!(s1.spill && s2.spill);
        assert!(s2.predicted_hit_rate < s1.predicted_hit_rate);
    }

    #[test]
    fn residency_split_shapes() {
        let (n, c) = (100_000usize, 32usize);
        // unconstrained: everything hot, no spill, full hit rate
        let s = plan_residency(n, c, u64::MAX);
        assert_eq!(s.cache_budget, panel_bytes(n, c), "cache caps at the panel");
        assert!(!s.spill);
        assert_eq!(s.predicted_hit_rate, 1.0);
        assert_eq!(s.tile_rows, DEFAULT_RESIDENT_TILE_ROWS);

        // zero budget: one-row tiles, empty cache, spill required
        let s = plan_residency(n, c, 0);
        assert_eq!(s.tile_rows, 1);
        assert_eq!(s.cache_budget, 0);
        assert!(s.spill);
        assert_eq!(s.predicted_hit_rate, 0.0);

        // cache budget grows monotonically with the memory budget
        let mut prev = 0u64;
        for budget in [1u64 << 16, 1 << 20, 1 << 24, 1 << 28] {
            let s = plan_residency(n, c, budget);
            assert!(s.cache_budget >= prev, "budget {budget}");
            assert!(s.cache_budget <= panel_bytes(n, c));
            prev = s.cache_budget;
        }

        // small n clamps the tile height
        assert_eq!(plan_residency(10, c, u64::MAX).tile_rows, 10);
    }

    #[test]
    fn peak_bytes_monotone_in_tile_rows() {
        for &t in &[1usize, 8, 64, 512] {
            let a = predicted_peak_bytes(10_000, 50, 200, &MethodSpec::Prototype, Some(t));
            let b = predicted_peak_bytes(10_000, 50, 200, &MethodSpec::Prototype, Some(t * 2));
            assert!(a < b);
            // streamed prototype beats materialized once tiles are thin
            let mat = predicted_peak_bytes(10_000, 50, 200, &MethodSpec::Prototype, None);
            assert!(a < mat);
        }
    }

    #[test]
    fn theory_sizes_monotone() {
        assert!(theory_c(10, 0.1) > theory_c(5, 0.1));
        assert!(theory_c(5, 0.05) > theory_c(5, 0.1));
        assert!(theory_s(10_000, 20, 0.1) > theory_s(1_000, 20, 0.1));
    }

    #[test]
    fn policy_peak_adds_the_capped_cache_term() {
        let (n, c) = (50_000usize, 40usize);
        let m = MethodSpec::Nystrom;
        let mat = predicted_policy_peak_bytes(n, c, &m, &ExecPolicy::Materialized);
        assert_eq!(mat, predicted_peak_bytes(n, c, c, &m, None));
        let st = predicted_policy_peak_bytes(n, c, &m, &ExecPolicy::streamed(64));
        assert_eq!(st, predicted_peak_bytes(n, c, c, &m, Some(64)));
        // a whole-matrix "streamed" config is the materialized model
        assert_eq!(
            predicted_policy_peak_bytes(n, c, &m, &ExecPolicy::Streamed(StreamConfig::whole())),
            mat
        );
        // residency charges its cache as a separate term, capped at the panel
        let panel = panel_bytes(n, c);
        let res = |b: u64| {
            predicted_policy_peak_bytes(n, c, &m, &ExecPolicy::resident(b).with_tile_rows(64))
        };
        assert_eq!(res(1 << 20) - res(0), 1 << 20);
        assert_eq!(res(u64::MAX) - res(0), panel);
        // …but not for methods whose run strips residency (full-K
        // streamers fall back to plain streaming): no phantom cache term
        let proto = |b: u64| {
            predicted_policy_peak_bytes(
                n,
                c,
                &MethodSpec::Prototype,
                &ExecPolicy::resident(b).with_tile_rows(64),
            )
        };
        assert_eq!(proto(u64::MAX), proto(0), "prototype never allocates a cache");
        let gauss = MethodSpec::Fast { s: 4 * c, kind: SketchKind::Gaussian };
        assert_eq!(
            predicted_policy_peak_bytes(n, c, &gauss, &ExecPolicy::resident(u64::MAX)),
            predicted_policy_peak_bytes(n, c, &gauss, &ExecPolicy::resident(0)),
            "projection sketches never allocate a cache"
        );
    }

    #[test]
    fn cur_models_are_plannable() {
        let (n, c) = (4_000usize, 30usize);
        let m = MethodSpec::Cur { r: 30, s: 120 };
        // served CUR materializes the kernel: n² entries, n²-dominated peak
        assert_eq!(predicted_entries(n, c, 120, &m), (n * n) as u64);
        let mat = predicted_peak_bytes(n, c, 120, &m, None);
        assert!(mat >= (n * n * 8) as u64);
        // the n² term is unconditional (the service materializes K under
        // every policy); tiling only adds its live row tiles on top
        let st = predicted_peak_bytes(n, c, 120, &m, Some(64));
        assert!(st >= (n * n * 8) as u64, "streamed CUR still holds K: {st}");
        assert_eq!(st - mat, 8 * 4 * 64 * n as u64, "tiling adds only live tiles");
        assert!(predicted_flops(n, c, 120, &m) > 0.0);
    }

    #[test]
    fn residency_split_exports_its_policy() {
        let s = plan_residency(100_000, 32, 4 << 20);
        match s.policy() {
            ExecPolicy::Resident { budget, spill, tile_rows, spill_dir, precision, validate } => {
                assert_eq!(budget, s.cache_budget);
                assert_eq!(spill, s.spill);
                assert_eq!(tile_rows, Some(s.tile_rows));
                assert!(spill_dir.is_none());
                assert_eq!(precision, Precision::F64, "splits default to the wide plane");
                assert_eq!(validate, ValidateMode::Off, "splits default to free streaming");
            }
            other => panic!("expected a resident policy, got {other:?}"),
        }
        assert_eq!(default_policy(), ExecPolicy::Materialized);
    }

    #[test]
    fn f32_halves_the_live_tile_and_cache_terms() {
        let (n, c) = (50_000usize, 40usize);
        let m = MethodSpec::Nystrom;
        // the streamed live-tile term halves; the f64 base does not move
        let base = predicted_peak_bytes_prec(n, c, c, &m, None, Precision::F32);
        assert_eq!(base, predicted_peak_bytes(n, c, c, &m, None), "no tiles, no change");
        let wide = predicted_peak_bytes(n, c, c, &m, Some(64));
        let narrow = predicted_peak_bytes_prec(n, c, c, &m, Some(64), Precision::F32);
        let wide_tiles = wide - base;
        assert_eq!(narrow - base, wide_tiles / 2, "tile term halves exactly");

        // the policy-level model agrees through the precision knob…
        let st32 = ExecPolicy::streamed(64).with_precision(Precision::F32);
        assert_eq!(predicted_policy_peak_bytes(n, c, &m, &st32), narrow);
        // …and an f32 resident cache caps at the halved panel
        let res = |p: Precision| {
            predicted_policy_peak_bytes(
                n,
                c,
                &m,
                &ExecPolicy::resident(u64::MAX).with_tile_rows(64).with_precision(p),
            )
        };
        let cap64 = res(Precision::F64)
            - predicted_policy_peak_bytes(
                n,
                c,
                &m,
                &ExecPolicy::resident(0).with_tile_rows(64),
            );
        assert_eq!(cap64, panel_bytes(n, c));
        let cap32 = res(Precision::F32)
            - predicted_policy_peak_bytes(
                n,
                c,
                &m,
                &ExecPolicy::resident(0).with_tile_rows(64).with_precision(Precision::F32),
            );
        assert_eq!(cap32, panel_bytes_prec(n, c, Precision::F32));
        assert_eq!(cap32 * 2, cap64);

        // implicit ops: same halving for live tiles + cached panel
        let imp64 = predicted_implicit_peak_bytes(n, c, 256, u64::MAX);
        let imp32 =
            predicted_implicit_peak_bytes_prec(n, c, 256, u64::MAX, Precision::F32);
        let state = ENTRY_BYTES * 2 * (c as u64) * (c as u64);
        assert_eq!(imp32 - state, (imp64 - state) / 2);
    }

    #[test]
    fn degrade_ladder_lowers_precision_before_shrinking_sketches() {
        // Resident policy, uniform fast: no sampling rung applies, so the
        // first accuracy-costing rung must be the precision drop — before
        // any SketchShrunk — and it must narrow the policy it carries.
        let (n, k) = (50_000usize, 5usize);
        let m = MethodSpec::Fast { s: 256, kind: SketchKind::Uniform };
        let pol = ExecPolicy::resident(0).with_tile_rows(64);
        let ladder = degrade_ladder(n, k, &m, 64, &pol);
        assert!(!ladder.is_empty());
        let prec_rung = ladder
            .iter()
            .find(|s| s.info.actions.contains(&DegradeAction::PrecisionLowered))
            .expect("an f64 tiled policy must offer a precision rung");
        assert_eq!(
            prec_rung.info.actions.last(),
            Some(&DegradeAction::PrecisionLowered),
            "precision drop precedes every sketch shrink"
        );
        assert!(!prec_rung.info.actions.contains(&DegradeAction::SketchShrunk));
        assert_eq!(prec_rung.policy.precision(), Precision::F32);
        assert_eq!(prec_rung.c, 64, "precision rung keeps the requested c");
        // later rungs keep the narrowed policy
        let last = ladder.last().unwrap();
        assert_eq!(last.policy.precision(), Precision::F32);
        assert!(last.info.actions.contains(&DegradeAction::SketchShrunk));

        // an already-narrow policy gets no second precision rung
        let ladder32 =
            degrade_ladder(n, k, &m, 64, &pol.clone().with_precision(Precision::F32));
        assert!(ladder32
            .iter()
            .all(|s| !s.info.actions.contains(&DegradeAction::PrecisionLowered)));

        // Materialized never narrows (it is the f64 reference path)
        let mat = degrade_ladder(n, k, &MethodSpec::Nystrom, 64, &ExecPolicy::Materialized);
        assert!(mat
            .iter()
            .all(|s| !s.info.actions.contains(&DegradeAction::PrecisionLowered)));
    }

    #[test]
    fn degrade_ladder_is_monotone_and_floored() {
        let (n, k) = (5_000usize, 5usize);
        let m = MethodSpec::Fast { s: 256, kind: SketchKind::Leverage { scaled: true } };
        let ladder = degrade_ladder(n, k, &m, 64, &ExecPolicy::streamed(64));
        assert!(!ladder.is_empty());
        let rung0 = predicted_policy_peak_bytes(n, 64, &m, &ExecPolicy::streamed(64));
        let mut prev = rung0;
        for (i, step) in ladder.iter().enumerate() {
            assert_eq!(step.info.rung, i + 1);
            assert_eq!(step.info.requested_c, 64);
            assert!(
                step.predicted_peak_bytes <= prev,
                "rung {}: {} > {}",
                i + 1,
                step.predicted_peak_bytes,
                prev
            );
            assert!(step.c >= k + 1, "c never shrinks below the rank floor");
            assert_eq!(step.info.c, step.c);
            prev = step.predicted_peak_bytes;
        }
        // the ladder must end at the floor with uniform sampling
        let last = ladder.last().unwrap();
        assert_eq!(last.c, k + 1);
        assert!(matches!(last.method, MethodSpec::Fast { kind: SketchKind::Uniform, .. }));
        assert!(last.info.actions.contains(&DegradeAction::SamplingRelaxed));
        assert!(last.info.actions.contains(&DegradeAction::SketchShrunk));
        assert!(last.predicted_peak_bytes < rung0);
    }

    #[test]
    fn degrade_ladder_tightens_prototype_and_respects_floor() {
        // Materialized prototype: first rung streams it (free), then c
        // halves. Every rung's prediction must strictly improve on rung 0.
        let (n, k) = (2_000usize, 3usize);
        let ladder =
            degrade_ladder(n, k, &MethodSpec::Prototype, 32, &ExecPolicy::Materialized);
        assert!(!ladder.is_empty());
        assert_eq!(ladder[0].info.actions, vec![DegradeAction::PolicyTightened]);
        assert!(matches!(ladder[0].policy, ExecPolicy::Streamed(_)));
        let rung0 =
            predicted_policy_peak_bytes(n, 32, &MethodSpec::Prototype, &ExecPolicy::Materialized);
        assert!(ladder[0].predicted_peak_bytes < rung0, "streaming must beat n² residency");

        // already at the floor → empty ladder for a floor-c Nyström
        let flat = degrade_ladder(n, k, &MethodSpec::Nystrom, k + 1, &ExecPolicy::Materialized);
        assert!(flat.is_empty(), "{flat:?}");
    }

    #[test]
    fn degrade_ladder_shrinks_cur_consistently() {
        let (n, k) = (1_000usize, 4usize);
        let m = MethodSpec::Cur { r: 64, s: 256 };
        let ladder = degrade_ladder(n, k, &m, 64, &ExecPolicy::Materialized);
        assert!(!ladder.is_empty());
        for step in &ladder {
            if let MethodSpec::Cur { r, s } = step.method {
                assert!(r >= 2 && s >= 2 * step.c, "r={r} s={s} c={}", step.c);
            } else {
                panic!("CUR must stay CUR down the ladder");
            }
        }
    }

    #[test]
    fn plan_shards_splits_rows_and_budget() {
        let (n, c) = (10_000usize, 64usize);
        let split = plan_shards(n, c, 4, 64 << 20);
        assert_eq!(split.shards, 4);
        assert_eq!(split.rows_per_shard, 2_500);
        assert_eq!(split.per_worker_budget, 16 << 20);
        assert!(split.tile_rows >= 1 && split.tile_rows <= split.rows_per_shard);
        // the worker model charges its panel slice, not the whole panel
        assert!(split.predicted_worker_peak_bytes < panel_bytes(n, c));
        // shards are capped at one row per worker, floor 1
        assert_eq!(plan_shards(3, c, 100, u64::MAX).shards, 3);
        assert_eq!(plan_shards(n, c, 0, u64::MAX).shards, 1);
        // a starvation budget degrades to one-row tiles, never panics
        assert_eq!(plan_shards(n, c, 4, 0).tile_rows, 1);
        // and the policy wraps a streamed inner at the chosen tile height
        match split.policy() {
            ExecPolicy::Sharded { shards, inner } => {
                assert_eq!(shards, 4);
                assert_eq!(*inner, ExecPolicy::Streamed(StreamConfig::tiled(split.tile_rows)));
            }
            p => panic!("expected sharded policy, got {p:?}"),
        }
    }

    #[test]
    fn sharded_policy_prices_as_its_inner_and_tightens_inside() {
        let n = 5_000usize;
        let m = MethodSpec::Nystrom;
        let inner = ExecPolicy::streamed(128);
        let sharded = ExecPolicy::sharded(4, inner.clone());
        // sequential workers: the aggregate peak is the inner policy's
        assert_eq!(
            predicted_policy_peak_bytes(n, 64, &m, &sharded),
            predicted_policy_peak_bytes(n, 64, &m, &inner),
        );
        // the plan's tile accessor sees through the wrapper
        let plan = Plan {
            method: m,
            c: 64,
            predicted_entries: 0,
            policy: sharded.clone(),
            predicted_peak_bytes: 0,
        };
        assert_eq!(plan.tile_rows(), Some(128));
        // tightening rewraps: the shard split survives, the inner shrinks
        match tightened_policy(n, &m, &sharded) {
            Some(ExecPolicy::Sharded { shards: 4, inner }) => {
                assert_eq!(*inner, ExecPolicy::Materialized);
            }
            p => panic!("expected rewrapped sharded policy, got {p:?}"),
        }
    }
}
