//! Criterion-lite benchmark harness substrate (no `criterion` in the image).
//!
//! Each `cargo bench` target (`harness = false`) builds a [`BenchSuite`],
//! registers closures, and gets warmup + adaptive iteration counts +
//! mean/p50/p95 reporting. Results can also be captured programmatically
//! for the table-generation benches, and dumped as machine-readable JSON
//! (`BenchSuite::write_json`) so the perf trajectory is tracked across PRs
//! (EXPERIMENTS.md §Perf). Setting `FASTSPSD_BENCH_QUICK=1` shrinks the
//! warmup/budget for CI-style smoke runs (`make perf-check`).

pub mod alloc;

use std::time::{Duration, Instant};

/// One benchmark's measured statistics.
#[derive(Debug, Clone)]
pub struct Stats {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
    pub min: Duration,
    /// Throughput in GFLOP/s when the bench declared its flop count.
    pub gflops: Option<f64>,
}

impl Stats {
    pub fn mean_secs(&self) -> f64 {
        self.mean.as_secs_f64()
    }
}

fn fmt_dur(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else {
        format!("{:.1} µs", s * 1e6)
    }
}

/// Measure one closure: warm up for `warmup`, then run until `budget`
/// elapses (at least `min_iters` iterations).
pub fn measure(name: &str, warmup: Duration, budget: Duration, min_iters: usize, mut f: impl FnMut()) -> Stats {
    // Warmup.
    let w = Instant::now();
    while w.elapsed() < warmup {
        f();
    }
    // Timed runs.
    let mut samples: Vec<Duration> = Vec::new();
    let start = Instant::now();
    while samples.len() < min_iters || (start.elapsed() < budget && samples.len() < 10_000) {
        let t = Instant::now();
        f();
        samples.push(t.elapsed());
    }
    samples.sort();
    let total: Duration = samples.iter().sum();
    let n = samples.len();
    Stats {
        name: name.to_string(),
        iters: n,
        mean: total / n as u32,
        p50: samples[n / 2],
        p95: samples[(n * 95 / 100).min(n - 1)],
        min: samples[0],
        gflops: None,
    }
}

/// A named collection of benchmarks with uniform budgets.
pub struct BenchSuite {
    pub title: String,
    pub warmup: Duration,
    pub budget: Duration,
    pub min_iters: usize,
    /// Whether this suite ran with the quick-mode budgets (recorded in the
    /// JSON so smoke numbers are never mistaken for full-budget ones).
    pub quick: bool,
    pub results: Vec<Stats>,
    /// Named scalar counters recorded alongside the timings (service
    /// robustness counters — queue waits, degraded serves, IO retries —
    /// land here and in the JSON's `"counters"` object).
    pub counters: Vec<(String, f64)>,
}

/// True when `FASTSPSD_BENCH_QUICK` requests a fast smoke run.
pub fn quick_mode() -> bool {
    std::env::var("FASTSPSD_BENCH_QUICK").map(|v| v != "0" && !v.is_empty()).unwrap_or(false)
}

/// True when `FASTSPSD_BENCH_COMMIT` pins results to the canonical
/// `BENCH_*.json` artifacts even in quick mode (`make bench-quick` — the
/// JSON's `"quick"` flag still records which budget produced the numbers,
/// so smoke results are never mistaken for full-budget ones).
pub fn commit_mode() -> bool {
    std::env::var("FASTSPSD_BENCH_COMMIT").map(|v| v != "0" && !v.is_empty()).unwrap_or(false)
}

/// Where a bench should write its JSON: `<stem>.json` (the committed perf
/// trajectory) normally and under commit mode, `<stem>.quick.json` for
/// plain quick runs so smoke numbers never clobber the trajectory.
pub fn artifact_path(stem: &str) -> String {
    if quick_mode() && !commit_mode() {
        format!("{stem}.quick.json")
    } else {
        format!("{stem}.json")
    }
}

impl BenchSuite {
    pub fn new(title: &str) -> Self {
        let (warmup, budget) = if quick_mode() {
            (Duration::from_millis(50), Duration::from_millis(200))
        } else {
            (Duration::from_millis(200), Duration::from_secs(1))
        };
        BenchSuite {
            title: title.to_string(),
            warmup,
            budget,
            min_iters: 3,
            quick: quick_mode(),
            results: Vec::new(),
            counters: Vec::new(),
        }
    }

    /// Quick preset for expensive end-to-end benches.
    pub fn slow(title: &str) -> Self {
        BenchSuite {
            warmup: Duration::from_millis(0),
            budget: Duration::from_millis(1),
            min_iters: 1,
            ..BenchSuite::new(title)
        }
    }

    pub fn bench(&mut self, name: &str, f: impl FnMut()) -> &Stats {
        let stats = measure(name, self.warmup, self.budget, self.min_iters, f);
        self.push(stats)
    }

    /// Like [`bench`](Self::bench) but annotates throughput from the
    /// benchmark's known flop count per iteration.
    pub fn bench_flops(&mut self, name: &str, flops: f64, f: impl FnMut()) -> &Stats {
        let mut stats = measure(name, self.warmup, self.budget, self.min_iters, f);
        stats.gflops = Some(flops / stats.mean_secs() / 1e9);
        self.push(stats)
    }

    fn push(&mut self, stats: Stats) -> &Stats {
        let gf = stats
            .gflops
            .map(|g| format!("  {g:8.2} GFLOP/s"))
            .unwrap_or_default();
        println!(
            "  {:<44} {:>12} (p50 {:>12}, p95 {:>12}, {} iters){}",
            stats.name,
            fmt_dur(stats.mean),
            fmt_dur(stats.p50),
            fmt_dur(stats.p95),
            stats.iters,
            gf
        );
        self.results.push(stats);
        self.results.last().unwrap()
    }

    pub fn header(&self) {
        println!("\n== {} ==", self.title);
    }

    /// Record (and print) a named scalar counter. Later values under the
    /// same name overwrite earlier ones, so a suite can update a counter
    /// as sections refine it.
    pub fn counter(&mut self, name: &str, value: f64) {
        println!("  {name:<44} {value}");
        if let Some(slot) = self.counters.iter_mut().find(|(n, _)| n == name) {
            slot.1 = value;
        } else {
            self.counters.push((name.to_string(), value));
        }
    }

    /// Mean of the named result, if present (for speedup summaries).
    pub fn mean_of(&self, name: &str) -> Option<f64> {
        self.results.iter().find(|s| s.name == name).map(|s| s.mean_secs())
    }

    /// Dump every result as machine-readable JSON (hand-rolled — no serde
    /// in the image): `{"suite": ..., "results": [{name, mean_secs, ...}]}`.
    pub fn to_json(&self) -> String {
        let mut out = String::new();
        out.push_str("{\n");
        out.push_str(&format!("  \"suite\": \"{}\",\n", escape(&self.title)));
        out.push_str(&format!("  \"quick\": {},\n", self.quick));
        out.push_str("  \"results\": [\n");
        for (i, s) in self.results.iter().enumerate() {
            out.push_str(&format!(
                "    {{\"name\": \"{}\", \"iters\": {}, \"mean_secs\": {:.9e}, \"p50_secs\": {:.9e}, \"p95_secs\": {:.9e}, \"min_secs\": {:.9e}, \"gflops\": {}}}{}\n",
                escape(&s.name),
                s.iters,
                s.mean.as_secs_f64(),
                s.p50.as_secs_f64(),
                s.p95.as_secs_f64(),
                s.min.as_secs_f64(),
                s.gflops.map(|g| format!("{g:.3}")).unwrap_or_else(|| "null".into()),
                if i + 1 < self.results.len() { "," } else { "" },
            ));
        }
        out.push_str("  ],\n");
        out.push_str("  \"counters\": {");
        for (i, (name, value)) in self.counters.iter().enumerate() {
            out.push_str(&format!(
                "{}\"{}\": {}",
                if i == 0 { "" } else { ", " },
                escape(name),
                if value.is_finite() { format!("{value}") } else { "null".into() },
            ));
        }
        out.push_str("}\n}\n");
        out
    }

    /// Write [`to_json`](Self::to_json) to `path`, reporting where it went.
    pub fn write_json(&self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, self.to_json())?;
        println!("  results written to {path}");
        Ok(())
    }
}

fn escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

/// Keep a value alive and opaque to the optimizer.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_counts_iterations() {
        let s = measure(
            "noop",
            Duration::from_millis(1),
            Duration::from_millis(5),
            4,
            || {
                black_box(3 + 4);
            },
        );
        assert!(s.iters >= 4);
        assert!(s.min <= s.p50 && s.p50 <= s.p95);
        assert!(s.gflops.is_none());
    }

    #[test]
    fn suite_records_results() {
        let mut suite = BenchSuite::slow("t");
        suite.bench("a", || {
            black_box(1);
        });
        suite.bench("b", || {
            black_box(2);
        });
        assert_eq!(suite.results.len(), 2);
        assert_eq!(suite.results[0].name, "a");
        assert!(suite.mean_of("a").is_some());
        assert!(suite.mean_of("zzz").is_none());
    }

    #[test]
    fn bench_flops_annotates_throughput() {
        let mut suite = BenchSuite::slow("t");
        let s = suite.bench_flops("f", 1e6, || {
            black_box((0..100).sum::<u64>());
        });
        assert!(s.gflops.unwrap() > 0.0);
    }

    #[test]
    fn json_is_well_formed_enough() {
        let mut suite = BenchSuite::slow("json \"suite\"");
        suite.bench("plain", || {
            black_box(1);
        });
        suite.bench_flops("with flops", 1e9, || {
            black_box(2);
        });
        suite.counter("service.queued", 3.0);
        suite.counter("service.queued", 4.0); // overwrites
        suite.counter("service.degraded", 0.0);
        let j = suite.to_json();
        assert!(j.contains("\"suite\": \"json \\\"suite\\\"\""));
        assert!(j.contains("\"quick\": "));
        assert!(j.contains("\"name\": \"plain\""));
        assert!(j.contains("\"gflops\": null"));
        assert!(j.contains("\"counters\": {\"service.queued\": 4, \"service.degraded\": 0}"));
        assert!(j.matches('{').count() == j.matches('}').count());
        // trailing-comma discipline: one comma between the two results
        assert!(j.contains("}},\n") || j.contains("},\n"));
    }

    #[test]
    fn artifact_path_routes_quick_runs_away_from_the_trajectory() {
        // env-var driven modes can't be toggled safely in-process (tests
        // share the environment), so pin the pure path logic instead: the
        // canonical name is used exactly when quick mode is off or commit
        // mode overrides it.
        let path = |quick: bool, commit: bool, stem: &str| {
            if quick && !commit {
                format!("{stem}.quick.json")
            } else {
                format!("{stem}.json")
            }
        };
        assert_eq!(path(false, false, "BENCH_x"), "BENCH_x.json");
        assert_eq!(path(true, false, "BENCH_x"), "BENCH_x.quick.json");
        assert_eq!(path(true, true, "BENCH_x"), "BENCH_x.json");
        assert_eq!(path(false, true, "BENCH_x"), "BENCH_x.json");
        // and the real function agrees with the current process state
        assert_eq!(
            artifact_path("BENCH_x"),
            path(quick_mode(), commit_mode(), "BENCH_x")
        );
    }

    #[test]
    fn fmt_dur_ranges() {
        assert!(fmt_dur(Duration::from_secs(2)).ends_with(" s"));
        assert!(fmt_dur(Duration::from_millis(5)).ends_with(" ms"));
        assert!(fmt_dur(Duration::from_micros(5)).ends_with(" µs"));
    }
}
