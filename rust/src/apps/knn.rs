//! K-nearest-neighbor classifier (the paper's §6.3.2 downstream task,
//! matching MATLAB's `knnclassify` with 10 neighbors).

use crate::linalg::Matrix;
use crate::pool::parallel_for;
use std::sync::Mutex;

/// Classify each row of `test` by majority vote among its `k` nearest
/// training rows (Euclidean distance in feature space). Ties break toward
/// the nearer neighbor's class.
pub fn knn_classify(train: &Matrix, labels: &[usize], test: &Matrix, k: usize) -> Vec<usize> {
    assert_eq!(train.rows(), labels.len());
    assert_eq!(train.cols(), test.cols());
    assert!(k >= 1);
    let n_test = test.rows();
    let out = Mutex::new(vec![0usize; n_test]);
    let nclasses = labels.iter().copied().max().map(|m| m + 1).unwrap_or(1);
    parallel_for(n_test, 16, |t| {
        let q = test.row(t);
        // top-k via simple selection over a (dist, label) scan
        let mut best: Vec<(f64, usize)> = Vec::with_capacity(k + 1);
        for i in 0..train.rows() {
            let d: f64 = train
                .row(i)
                .iter()
                .zip(q)
                .map(|(a, b)| (a - b) * (a - b))
                .sum();
            if best.len() < k || d < best.last().unwrap().0 {
                let pos = best.partition_point(|&(bd, _)| bd < d);
                best.insert(pos, (d, labels[i]));
                if best.len() > k {
                    best.pop();
                }
            }
        }
        // majority vote, ties -> smaller summed distance
        let mut votes = vec![0usize; nclasses];
        let mut dist_sum = vec![0.0f64; nclasses];
        for &(d, l) in &best {
            votes[l] += 1;
            dist_sum[l] += d;
        }
        let mut win = 0usize;
        for c in 1..nclasses {
            if votes[c] > votes[win] || (votes[c] == votes[win] && dist_sum[c] < dist_sum[win]) {
                win = c;
            }
        }
        out.lock().unwrap()[t] = win;
    });
    out.into_inner().unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn separable_blobs_classified_perfectly() {
        let mut rng = Rng::new(0);
        let mut train = Matrix::zeros(40, 2);
        let mut labels = Vec::new();
        for i in 0..40 {
            let c = i % 2;
            train[(i, 0)] = c as f64 * 10.0 + rng.gaussian() * 0.3;
            train[(i, 1)] = rng.gaussian() * 0.3;
            labels.push(c);
        }
        let mut test = Matrix::zeros(10, 2);
        let mut expect = Vec::new();
        for i in 0..10 {
            let c = i % 2;
            test[(i, 0)] = c as f64 * 10.0 + rng.gaussian() * 0.3;
            test[(i, 1)] = rng.gaussian() * 0.3;
            expect.push(c);
        }
        let pred = knn_classify(&train, &labels, &test, 5);
        assert_eq!(pred, expect);
    }

    #[test]
    fn k1_nearest_neighbor() {
        let train = Matrix::from_vec(3, 1, vec![0.0, 5.0, 10.0]);
        let labels = vec![0, 1, 2];
        let test = Matrix::from_vec(2, 1, vec![4.4, 9.0]);
        assert_eq!(knn_classify(&train, &labels, &test, 1), vec![1, 2]);
    }

    #[test]
    fn tie_breaks_toward_nearer_class() {
        // k=2: one neighbor of each class, the closer one must win.
        let train = Matrix::from_vec(2, 1, vec![0.0, 3.0]);
        let labels = vec![0, 1];
        let test = Matrix::from_vec(1, 1, vec![1.0]);
        assert_eq!(knn_classify(&train, &labels, &test, 2), vec![0]);
    }
}
