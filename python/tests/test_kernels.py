"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes/values; fixed cases pin the AOT shape buckets.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.rbf_block import rbf_block
from compile.kernels.matmul import matmul
from compile.kernels.ref import rbf_block_ref, matmul_ref

jax.config.update("jax_platform_name", "cpu")


def _rand(shape, seed, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape, dtype=np.float32) * scale)


# ---------------------------------------------------------------- rbf_block

@settings(max_examples=25, deadline=None)
@given(
    mt=st.integers(1, 3),
    nt=st.integers(1, 3),
    d=st.sampled_from([1, 2, 7, 16, 33]),
    gamma=st.floats(1e-3, 10.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_rbf_block_matches_ref(mt, nt, d, gamma, seed):
    bm, bn = 8, 8
    m, n = mt * bm, nt * bn
    x = _rand((m, d), seed)
    y = _rand((n, d), seed + 1)
    g = jnp.full((1, 1), gamma, dtype=jnp.float32)
    out = rbf_block(g, x, y, bm=bm, bn=bn)
    ref = rbf_block_ref(gamma, x, y)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("d", [16, 128, 1024])
def test_rbf_block_aot_buckets(d):
    """The exact shapes that get AOT-compiled must agree with the oracle."""
    x = _rand((256, d), 42)
    y = _rand((256, d), 43)
    g = jnp.full((1, 1), 0.125, dtype=jnp.float32)
    out = rbf_block(g, x, y, bm=128, bn=128)
    ref = rbf_block_ref(0.125, x, y)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4, atol=1e-5)


def test_rbf_block_gamma_zero_is_all_ones():
    x = _rand((16, 4), 0)
    y = _rand((8, 4), 1)
    g = jnp.zeros((1, 1), dtype=jnp.float32)
    out = rbf_block(g, x, y, bm=8, bn=8)
    np.testing.assert_allclose(np.asarray(out), np.ones((16, 8), np.float32), atol=0)


def test_rbf_block_self_diagonal_is_one():
    x = _rand((16, 8), 7)
    g = jnp.full((1, 1), 0.5, dtype=jnp.float32)
    out = np.asarray(rbf_block(g, x, x, bm=8, bn=8))
    np.testing.assert_allclose(np.diag(out), np.ones(16, np.float32), rtol=1e-5)
    # symmetry of the self-block
    np.testing.assert_allclose(out, out.T, rtol=1e-5, atol=1e-6)


def test_rbf_block_values_in_unit_interval():
    x = _rand((16, 4), 3, scale=10.0)
    y = _rand((16, 4), 4, scale=10.0)
    g = jnp.full((1, 1), 2.0, dtype=jnp.float32)
    out = np.asarray(rbf_block(g, x, y, bm=8, bn=8))
    assert out.min() >= 0.0 and out.max() <= 1.0 + 1e-6


def test_rbf_block_zero_feature_padding_invariance():
    """Padding features with zero columns must not change the block."""
    x = _rand((8, 5), 11)
    y = _rand((8, 5), 12)
    xp = jnp.pad(x, ((0, 0), (0, 11)))
    yp = jnp.pad(y, ((0, 0), (0, 11)))
    g = jnp.full((1, 1), 0.3, dtype=jnp.float32)
    a = rbf_block(g, x, y, bm=8, bn=8)
    b = rbf_block(g, xp, yp, bm=8, bn=8)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)


# ------------------------------------------------------------------ matmul

@settings(max_examples=25, deadline=None)
@given(
    mt=st.integers(1, 3),
    nt=st.integers(1, 3),
    k=st.sampled_from([1, 3, 16, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref(mt, nt, k, seed):
    bm, bn = 8, 8
    m, n = mt * bm, nt * bn
    x = _rand((m, k), seed)
    y = _rand((k, n), seed + 1)
    out = matmul(x, y, bm=bm, bn=bn)
    ref = matmul_ref(x, y)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("k", [256, 1024])
def test_matmul_aot_buckets(k):
    x = _rand((256, k), 5)
    y = _rand((k, 256), 6)
    out = matmul(x, y, bm=128, bn=128)
    ref = matmul_ref(x, y)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4, atol=1e-4)


def test_matmul_identity():
    x = _rand((8, 8), 9)
    eye = jnp.eye(8, dtype=jnp.float32)
    out = matmul(x, eye, bm=8, bn=8)
    np.testing.assert_allclose(np.asarray(out), np.asarray(x), rtol=1e-6)
