//! Disabled-mode cost of the span recorder (ISSUE 7). One `#[test]` in
//! its own binary on purpose: installing the recorder is process-global
//! and irreversible, so this is the only integration binary in which
//! `obs::ensure_installed` must never run — every span site below takes
//! the one-atomic-load fast path.

use fastspsd::coordinator::oracle::RbfOracle;
use fastspsd::exec::{self, ExecPolicy};
use fastspsd::linalg::Matrix;
use fastspsd::obs::{self, Stage};
use fastspsd::spsd::{self, FastConfig};
use fastspsd::util::Rng;
use std::hint::black_box;
use std::sync::Arc;
use std::time::Instant;

const N: usize = 192;
const TILE: usize = 16;

fn build(o: &RbfOracle, seed: u64) -> exec::RunReport<spsd::SpsdApprox> {
    let mut rng = Rng::new(seed);
    let p = spsd::uniform_p(N, 8, &mut rng);
    exec::fast(o, &p, FastConfig::uniform(24), &ExecPolicy::streamed(TILE), &mut rng)
}

#[test]
fn disabled_recorder_is_bit_invisible_and_costs_under_one_percent() {
    assert!(!obs::installed(), "this binary must never install the recorder");

    let mut rng = Rng::new(3);
    let o = RbfOracle::cpu(Arc::new(Matrix::randn(N, 6, &mut rng)), 0.5);

    // Bit-equality: two identical builds through the fully instrumented
    // streamed path give identical numbers, and no profile is attached.
    let a = build(&o, 9);
    let b = build(&o, 9);
    assert!(a.meta.stage_profile.is_none(), "no recorder, no profile");
    assert!(b.meta.stage_profile.is_none());
    assert_eq!(a.result.c.max_abs_diff(&b.result.c), 0.0);
    assert_eq!(a.result.u.max_abs_diff(&b.result.u), 0.0);
    assert_eq!(a.result.p_indices, b.result.p_indices);

    // Wall time of one build (the instrumented code, spans disabled).
    let reps = 5;
    let t0 = Instant::now();
    for _ in 0..reps {
        black_box(build(&o, 9));
    }
    let build_secs = t0.elapsed().as_secs_f64() / reps as f64;

    // Direct cost of one disabled span: open + drop, which is a single
    // relaxed atomic load and an inert guard.
    let iters = 1_000_000u32;
    let t0 = Instant::now();
    for _ in 0..iters {
        let g = obs::span(Stage::PipelineFold);
        black_box(&g);
    }
    let per_span = t0.elapsed().as_secs_f64() / f64::from(iters);
    assert!(per_span < 2e-7, "disabled span cost {per_span}s is not one atomic load");

    // <1% overhead: even a generous over-count of the span sites this
    // build passes through (per-tile produce/stall/fold/consumer spans
    // plus the fixed solve/exec spans) stays under 1% of the build.
    let spans = 32.0 * N.div_ceil(TILE) as f64 + 256.0;
    let overhead = per_span * spans;
    assert!(
        overhead < 0.01 * build_secs,
        "estimated disabled-span overhead {overhead}s vs build {build_secs}s"
    );
}
