//! Layer-3 coordinator: the service that turns the paper's algorithms into
//! a system.
//!
//! - [`oracle`] — the `KernelOracle` abstraction: "give me the K[I, J]
//!   block" without ever materializing the n x n kernel matrix. This is the
//!   interface the SPSD models consume, and the entry-counting hook behind
//!   the paper's Figure 1 / Table 3 "#entries" accounting.
//! - [`engine`] — the block scheduler: tiles a kernel (or matmul) request
//!   into fixed 256x256 AOT shapes, pads rows/features with zeros, batches
//!   the tiles to the PJRT runtime thread, and crops + assembles results.
//! - [`service`] — the request loop: bounded-queue approximation service
//!   with worker routing, per-request timing, metrics, and the
//!   degrade-don't-die admission path (bounded deadline-reaped queue +
//!   [`planner::degrade_ladder`] serving under memory pressure).
//! - [`metrics`] — counters + latency histograms.

pub mod engine;
pub mod metrics;
pub mod oracle;
pub mod planner;
pub mod service;

pub use engine::KernelEngine;
pub use oracle::{DenseOracle, KernelOracle, PolyOracle, RbfOracle};
pub use planner::{degrade_ladder, DegradeStep};
pub use service::{
    ApproxRequest, ApproxResponse, ApproxService, MethodSpec, ServiceConfig, ServiceError,
};
