//! PJRT runtime: load the AOT artifacts and execute them from rust.
//!
//! The `xla` crate's PJRT types wrap `Rc` internals and are not `Send`, so
//! a dedicated **runtime thread** owns the `PjRtClient` and every compiled
//! executable; the rest of the system talks to it through a cloneable,
//! `Send` [`RuntimeHandle`] over an mpsc channel. Block requests arrive in
//! batches (the coordinator's dynamic batcher groups them) and the PJRT CPU
//! client parallelizes internally.
//!
//! Pattern adapted from /opt/xla-example/load_hlo — interchange is HLO
//! *text* (`HloModuleProto::from_text_file`), and lowering used
//! `return_tuple=True`, so results unwrap with `to_tuple1`.

pub mod json;
pub mod manifest;

pub use manifest::{default_artifact_dir, ArtifactSpec, Manifest};

use anyhow::{anyhow, Context, Result};
#[cfg(feature = "pjrt")]
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};

/// One computation to run: artifact name + flat f32 inputs with shapes.
pub struct ExecRequest {
    pub artifact: String,
    pub inputs: Vec<(Vec<f32>, Vec<usize>)>,
}

type Reply = mpsc::Sender<Result<Vec<Vec<f32>>>>;

enum Msg {
    Exec { reqs: Vec<ExecRequest>, reply: Reply },
}

/// Counters exported by the runtime thread.
#[derive(Debug, Default)]
pub struct RuntimeStats {
    pub batches: AtomicU64,
    pub executions: AtomicU64,
    pub exec_nanos: AtomicU64,
}

impl RuntimeStats {
    /// (batches, executions, total exec seconds)
    pub fn snapshot(&self) -> (u64, u64, f64) {
        (
            self.batches.load(Ordering::Relaxed),
            self.executions.load(Ordering::Relaxed),
            self.exec_nanos.load(Ordering::Relaxed) as f64 * 1e-9,
        )
    }
}

/// Cloneable, `Send + Sync` handle to the runtime thread. (The raw mpsc
/// `Sender` is not `Sync`, so it lives behind a mutex; contention is
/// negligible because submissions are batched.)
pub struct RuntimeHandle {
    tx: Mutex<mpsc::Sender<Msg>>,
    manifest: Arc<Manifest>,
    stats: Arc<RuntimeStats>,
    // joined on last drop
    join: Arc<Mutex<Option<std::thread::JoinHandle<()>>>>,
}

impl Clone for RuntimeHandle {
    fn clone(&self) -> Self {
        RuntimeHandle {
            tx: Mutex::new(self.tx.lock().unwrap().clone()),
            manifest: Arc::clone(&self.manifest),
            stats: Arc::clone(&self.stats),
            join: Arc::clone(&self.join),
        }
    }
}

impl RuntimeHandle {
    /// Load the manifest, spawn the runtime thread, compile every artifact
    /// on it, and return once compilation succeeded (or failed).
    pub fn spawn(artifact_dir: impl AsRef<std::path::Path>) -> Result<RuntimeHandle> {
        let manifest = Arc::new(Manifest::load(artifact_dir)?);
        let (tx, rx) = mpsc::channel::<Msg>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let man = Arc::clone(&manifest);
        let stats = Arc::new(RuntimeStats::default());
        let st = Arc::clone(&stats);
        let join = std::thread::Builder::new()
            .name("fastspsd-pjrt".into())
            .spawn(move || runtime_thread(man, rx, ready_tx, st))
            .context("spawning runtime thread")?;
        ready_rx
            .recv()
            .map_err(|_| anyhow!("runtime thread died during startup"))??;
        Ok(RuntimeHandle {
            tx: Mutex::new(tx),
            manifest,
            stats,
            join: Arc::new(Mutex::new(Some(join))),
        })
    }

    /// Spawn from the default artifact directory
    /// (`$FASTSPSD_ARTIFACTS` or `./artifacts`).
    pub fn spawn_default() -> Result<RuntimeHandle> {
        Self::spawn(default_artifact_dir())
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn stats(&self) -> &RuntimeStats {
        &self.stats
    }

    /// Execute a batch of requests; results in request order.
    pub fn execute_batch(&self, reqs: Vec<ExecRequest>) -> Result<Vec<Vec<f32>>> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .lock()
            .unwrap()
            .send(Msg::Exec { reqs, reply })
            .map_err(|_| anyhow!("runtime thread is gone"))?;
        rx.recv().map_err(|_| anyhow!("runtime thread dropped the reply"))?
    }

    /// Execute a single request.
    pub fn execute_one(&self, artifact: &str, inputs: Vec<(Vec<f32>, Vec<usize>)>) -> Result<Vec<f32>> {
        let mut out = self.execute_batch(vec![ExecRequest { artifact: artifact.to_string(), inputs }])?;
        Ok(out.pop().expect("one result per request"))
    }
}

impl Drop for RuntimeHandle {
    fn drop(&mut self) {
        // When the last clone goes away the channel disconnects, the thread
        // loop exits, and we join it (only the final clone holds Some).
        if Arc::strong_count(&self.join) == 1 {
            let (dummy_tx, _) = mpsc::channel();
            let tx = std::mem::replace(self.tx.get_mut().unwrap(), dummy_tx);
            drop(tx);
            if let Some(j) = self.join.lock().unwrap().take() {
                let _ = j.join();
            }
        }
    }
}

/// Without the `pjrt` feature (the default in this image — the `xla` crate
/// is not available) the runtime thread reports failure at startup, so
/// `RuntimeHandle::spawn` returns `Err` and every caller falls back to the
/// pure-rust engine path.
#[cfg(not(feature = "pjrt"))]
fn runtime_thread(
    _manifest: Arc<Manifest>,
    _rx: mpsc::Receiver<Msg>,
    ready: mpsc::Sender<Result<()>>,
    _stats: Arc<RuntimeStats>,
) {
    let _ = ready.send(Err(anyhow!(
        "PJRT support not compiled in (build with --features pjrt and an xla dependency)"
    )));
}

#[cfg(feature = "pjrt")]
fn runtime_thread(
    manifest: Arc<Manifest>,
    rx: mpsc::Receiver<Msg>,
    ready: mpsc::Sender<Result<()>>,
    stats: Arc<RuntimeStats>,
) {
    // Compile everything up front; report the first failure through `ready`.
    let setup = (|| -> Result<(xla::PjRtClient, HashMap<String, xla::PjRtLoadedExecutable>)> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PjRtClient::cpu: {e}"))?;
        let mut exes = HashMap::new();
        for spec in &manifest.artifacts {
            let path = manifest.dir.join(&spec.file);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow!("loading {path:?}: {e}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {}: {e}", spec.name))?;
            exes.insert(spec.name.clone(), exe);
        }
        Ok((client, exes))
    })();
    let (client, exes) = match setup {
        Ok(x) => {
            let _ = ready.send(Ok(()));
            x
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    let _client = client; // keep alive for the executables' lifetime

    while let Ok(Msg::Exec { reqs, reply }) = rx.recv() {
        let t0 = std::time::Instant::now();
        let result = run_batch(&manifest, &exes, &reqs);
        stats.batches.fetch_add(1, Ordering::Relaxed);
        stats.executions.fetch_add(reqs.len() as u64, Ordering::Relaxed);
        stats
            .exec_nanos
            .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        let _ = reply.send(result);
    }
}

#[cfg(feature = "pjrt")]
fn run_batch(
    manifest: &Manifest,
    exes: &HashMap<String, xla::PjRtLoadedExecutable>,
    reqs: &[ExecRequest],
) -> Result<Vec<Vec<f32>>> {
    let mut out = Vec::with_capacity(reqs.len());
    for req in reqs {
        let spec = manifest
            .find(&req.artifact)
            .ok_or_else(|| anyhow!("unknown artifact {:?}", req.artifact))?;
        let exe = exes.get(&req.artifact).expect("compiled at startup");
        if req.inputs.len() != spec.inputs.len() {
            return Err(anyhow!(
                "{}: expected {} inputs, got {}",
                req.artifact,
                spec.inputs.len(),
                req.inputs.len()
            ));
        }
        let mut literals = Vec::with_capacity(req.inputs.len());
        for (i, (data, shape)) in req.inputs.iter().enumerate() {
            if shape != &spec.inputs[i] {
                return Err(anyhow!(
                    "{}: input {i} shape {:?} != manifest {:?}",
                    req.artifact,
                    shape,
                    spec.inputs[i]
                ));
            }
            let numel: usize = shape.iter().product();
            if data.len() != numel {
                return Err(anyhow!(
                    "{}: input {i} has {} elements for shape {:?}",
                    req.artifact,
                    data.len(),
                    shape
                ));
            }
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(data)
                .reshape(&dims)
                .map_err(|e| anyhow!("reshape input {i} of {}: {e}", req.artifact))?;
            literals.push(lit);
        }
        let results = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("execute {}: {e}", req.artifact))?;
        let lit = results[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal {}: {e}", req.artifact))?;
        // aot.py lowers with return_tuple=True → unwrap the 1-tuple.
        let inner = lit.to_tuple1().map_err(|e| anyhow!("to_tuple1 {}: {e}", req.artifact))?;
        let vals = inner
            .to_vec::<f32>()
            .map_err(|e| anyhow!("to_vec {}: {e}", req.artifact))?;
        out.push(vals);
    }
    Ok(out)
}
